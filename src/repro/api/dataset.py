"""Dataset — an immutable prepared mining input (DESIGN.md §5).

A `Dataset` is the unit a `MinerSession` queries: the occurrence bitmap is
packed exactly **once** at construction (`core.engine.pack_problem`), padded
up to a *shape bucket*, and reused by every phase of every query.  The
bucket — (transactions, positives, items) each rounded up to a configured
grid — is the shape part of the session's compiled-program cache key:
padding is all zero bits, zero-support items can never be accepted, counted,
emitted, or generate children, so results are invariant to it, and any two
datasets that land in the same bucket replay the same compiled programs
with zero re-traces.

Constructors: `from_dense` (bool matrix), `from_transactions` (lists of
items, int ids or string tokens), `from_tsv` (label + item tokens per line),
`from_paper_problem` (the Table-1 synthetic generator, with planted signal
carried along for scoring).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from repro.core.bitmap import DEFAULT_ITEM_TILE, item_tiling
from repro.core.engine import PackedProblem, pack_problem, pack_problem_from_bits

__all__ = [
    "BucketPolicy",
    "DEFAULT_BUCKETS",
    "EXACT_BUCKETS",
    "ShapeBucket",
    "Dataset",
]


@dataclass(frozen=True)
class ShapeBucket:
    """Program dims a dataset is padded to — the shape half of a cache key.

    `item_tile` is the item-axis tile width of the device database layout
    (DESIGN.md §8): 0 means one tile spanning all `items` (every pre-tiling
    bucket; zero layout overhead), nonzero means `items` is a multiple of it
    and the program sweeps `items / item_tile` tiles.  It shapes the traced
    program, so it is part of the bucket — and thereby of the cache key.
    """

    transactions: int  # n_pad
    positives: int     # npos_pad
    items: int         # m_pad (a multiple of item_tile when tiled)

    item_tile: int = 0  # 0 = single tile of width `items`

    @property
    def tile(self) -> int:
        """Concrete tile width (the kernel's per-sweep item extent)."""
        return self.item_tile or self.items

    @property
    def n_tiles(self) -> int:
        return self.items // self.tile if self.items else 1

    @property
    def words(self) -> int:
        from repro.core.bitmap import num_words

        return num_words(self.transactions)


@dataclass(frozen=True)
class BucketPolicy:
    """How dataset dims round up to shared program shapes.

    Geometric buckets (default ×2 from per-dim floors) bound padding waste at
    `growth`× while collapsing the infinite space of dataset shapes onto a
    few dozen buckets.  `exact=True` disables padding entirely (every
    dataset gets its own program shapes — the legacy `lamp_distributed`
    behavior, and the right choice for a single huge one-off matrix).
    """

    min_transactions: int = 64
    min_positives: int = 16
    min_items: int = 64
    growth: float = 2.0
    exact: bool = False
    #: item-tile width cap: item dims past this are stored tiled (rounded up
    #: to a tile multiple) so paper-scale databases sweep in [B, item_tile]
    #: chunks.  Applies to exact buckets too — tiling is a layout property,
    #: not a padding policy.
    item_tile: int = DEFAULT_ITEM_TILE

    def _round(self, value: int, floor: int) -> int:
        if value <= floor:
            return floor
        steps = math.ceil(math.log(value / floor) / math.log(self.growth))
        return math.ceil(floor * self.growth ** steps)

    def bucket_for(self, n: int, n_pos: int, m: int) -> ShapeBucket:
        if self.exact:
            m_pad, tile = item_tiling(m, self.item_tile)
            return ShapeBucket(
                transactions=n, positives=n_pos, items=m_pad,
                item_tile=tile if m_pad > tile else 0,
            )
        m_pad, tile = item_tiling(self._round(m, self.min_items), self.item_tile)
        return ShapeBucket(
            transactions=self._round(n, self.min_transactions),
            positives=self._round(n_pos, self.min_positives),
            items=m_pad,
            item_tile=tile if m_pad > tile else 0,
        )


DEFAULT_BUCKETS = BucketPolicy()
EXACT_BUCKETS = BucketPolicy(exact=True)


class Dataset:
    """Immutable prepared input: packed bitmaps + labels + names + bucket."""

    def __init__(
        self,
        db_bool: np.ndarray,
        labels: np.ndarray | None = None,
        *,
        item_names: "tuple[str, ...] | list[str] | None" = None,
        name: str = "dataset",
        bucket_policy: BucketPolicy = DEFAULT_BUCKETS,
        planted: "list[list[int]] | None" = None,
    ):
        db_bool = np.asarray(db_bool, dtype=bool)
        if db_bool.ndim != 2:
            raise ValueError(f"db_bool must be [transactions, items], got {db_bool.shape}")
        n, m = db_bool.shape
        if labels is not None:
            labels = np.asarray(labels, dtype=bool)
            if labels.shape != (n,):
                raise ValueError(f"labels must be [{n}], got {labels.shape}")
            labels = labels.copy()
            labels.flags.writeable = False
        if item_names is not None:
            item_names = tuple(str(s) for s in item_names)
            if len(item_names) != m:
                raise ValueError(
                    f"item_names has {len(item_names)} entries for {m} items"
                )
        n_pos = int(labels.sum()) if labels is not None else max(1, n // 2)
        bucket = bucket_policy.bucket_for(n, n_pos, m)
        self.name = str(name)
        self.labels = labels
        self.item_names = item_names
        self.planted = planted
        self.bucket = bucket
        # the one and only pack of this database (threaded through every
        # phase and through results reconstruction)
        self.packed: PackedProblem = pack_problem(
            db_bool,
            labels,
            n_pad=bucket.transactions,
            npos_pad=bucket.positives,
            m_pad=bucket.items,
            m_tile=bucket.tile,
        )

    # ------------------------------------------------------------ properties
    @property
    def n_transactions(self) -> int:
        return self.packed.n

    @property
    def n_items(self) -> int:
        return self.packed.m

    @property
    def n_pos(self) -> int:
        return self.packed.n_pos

    @property
    def db_bits(self) -> np.ndarray:
        """[m_pad, w_pad] u32 packed occurrence bitmap (read-only)."""
        return self.packed.db_bits

    def __repr__(self) -> str:
        return (
            f"Dataset({self.name!r}, {self.n_items} items x "
            f"{self.n_transactions} transactions, n_pos={self.n_pos}, "
            f"bucket=({self.bucket.transactions}, {self.bucket.positives}, "
            f"{self.bucket.items}))"
        )

    # ---------------------------------------------------------- constructors
    @classmethod
    def placeholder(cls, bucket: ShapeBucket, *, name: str = "warmup") -> "Dataset":
        """A minimal labelled dataset padded into exactly `bucket`.

        Compiled programs depend on the bucket dims and config only — actual
        data enters as runtime arguments — so `MinerSession.warmup(bucket)`
        uses this to shape the program arguments without any real data: two
        transactions (one positive), one item, all-zero bits, zero cost at
        any bucket size (padding is packed words, not a dense matrix).
        """
        if not isinstance(bucket, ShapeBucket):
            raise TypeError(
                f"placeholder() takes a ShapeBucket, got {type(bucket).__name__}"
            )
        n = min(2, bucket.transactions)
        if n < 1 or bucket.positives < 1:
            raise ValueError(f"bucket too small to placeholder: {bucket}")
        labels = np.zeros(n, dtype=bool)
        labels[0] = True
        labels.flags.writeable = False
        ds = cls.__new__(cls)
        ds.name = str(name)
        ds.labels = labels
        ds.item_names = None
        ds.planted = None
        ds.bucket = bucket
        ds.packed = pack_problem(
            np.zeros((n, 1), dtype=bool),
            labels,
            n_pad=bucket.transactions,
            npos_pad=bucket.positives,
            m_pad=bucket.items,
            m_tile=bucket.tile,
        )
        return ds

    @classmethod
    def from_dense(
        cls,
        db_bool: np.ndarray,
        labels: np.ndarray | None = None,
        *,
        item_names=None,
        name: str = "dense",
        bucket_policy: BucketPolicy = DEFAULT_BUCKETS,
        planted=None,
    ) -> "Dataset":
        """Prepare a dense [transactions, items] bool matrix."""
        return cls(db_bool, labels, item_names=item_names, name=name,
                   bucket_policy=bucket_policy, planted=planted)

    @classmethod
    def from_packed_words(
        cls,
        db_bits: np.ndarray,
        labels: np.ndarray | None = None,
        *,
        n_transactions: int,
        item_names=None,
        name: str = "packed",
        bucket_policy: BucketPolicy = DEFAULT_BUCKETS,
        planted=None,
    ) -> "Dataset":
        """Prepare an already word-packed [items, words] uint32 database.

        The paper-scale entry: `data.synthetic.paper_problem_packed`
        generates alz_rec_30 (250k items) straight into packed words, and
        this constructor tiles them without ever materializing the dense
        [transactions, items] bool matrix.  `n_transactions` cannot be
        recovered from packed words, so it is required.
        """
        db_bits = np.asarray(db_bits, dtype=np.uint32)
        if db_bits.ndim != 2:
            raise ValueError(f"db_bits must be [items, words], got {db_bits.shape}")
        m = db_bits.shape[0]
        n = int(n_transactions)
        if labels is not None:
            labels = np.asarray(labels, dtype=bool)
            if labels.shape != (n,):
                raise ValueError(f"labels must be [{n}], got {labels.shape}")
            labels = labels.copy()
            labels.flags.writeable = False
        if item_names is not None:
            item_names = tuple(str(s) for s in item_names)
            if len(item_names) != m:
                raise ValueError(
                    f"item_names has {len(item_names)} entries for {m} items"
                )
        n_pos = int(labels.sum()) if labels is not None else max(1, n // 2)
        bucket = bucket_policy.bucket_for(n, n_pos, m)
        ds = cls.__new__(cls)
        ds.name = str(name)
        ds.labels = labels
        ds.item_names = item_names
        ds.planted = planted
        ds.bucket = bucket
        ds.packed = pack_problem_from_bits(
            db_bits,
            labels,
            n=n,
            n_pad=bucket.transactions,
            npos_pad=bucket.positives,
            m_pad=bucket.items,
            m_tile=bucket.tile,
        )
        return ds

    @classmethod
    def from_transactions(
        cls,
        transactions,
        labels=None,
        *,
        n_items: int | None = None,
        item_names=None,
        name: str = "transactions",
        bucket_policy: BucketPolicy = DEFAULT_BUCKETS,
    ) -> "Dataset":
        """Prepare a list of transactions, each an iterable of items.

        Items may be integer column ids, or arbitrary string tokens — tokens
        are assigned columns in sorted order and become the item names.
        """
        txns = [list(t) for t in transactions]
        has_str = any(isinstance(i, str) for t in txns for i in t)
        if has_str:
            vocab = sorted({str(i) for t in txns for i in t})
            col = {tok: j for j, tok in enumerate(vocab)}
            txns = [[col[str(i)] for i in t] for t in txns]
            if item_names is None:
                item_names = tuple(vocab)
        m = n_items if n_items is not None else (
            1 + max((i for t in txns for i in t), default=-1)
        )
        db = np.zeros((len(txns), max(m, 1)), dtype=bool)
        for r, t in enumerate(txns):
            db[r, t] = True
        return cls(db, labels, item_names=item_names, name=name,
                   bucket_policy=bucket_policy)

    @classmethod
    def from_tsv(
        cls,
        path: str,
        *,
        name: str | None = None,
        bucket_policy: BucketPolicy = DEFAULT_BUCKETS,
    ) -> "Dataset":
        """Load `<label><TAB>item<TAB>item...` lines (one transaction each).

        The first field is the case/control label (1/0); the remaining
        fields are item tokens (strings are fine — they become item names).
        """
        labels, txns = [], []
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line or line.startswith("#"):
                    continue
                fields = line.split("\t")
                labels.append(bool(int(fields[0])))
                txns.append(fields[1:])
        return cls.from_transactions(
            txns, np.asarray(labels, dtype=bool),
            name=name or path, bucket_policy=bucket_policy,
        )

    @classmethod
    def from_paper_problem(
        cls,
        problem: str,
        scale_items: float = 1.0,
        scale_trans: float = 1.0,
        *,
        seed: int | None = None,
        bucket_policy: BucketPolicy = DEFAULT_BUCKETS,
    ) -> "Dataset":
        """A (scaled) Table-1 synthetic problem, with planted signal and
        SNP-style item names carried along."""
        from repro.data.synthetic import paper_problem

        db, labels, planted, spec = paper_problem(
            problem, scale_items, scale_trans, seed=seed
        )
        names = tuple(f"snp{j:05d}" for j in range(spec.n_items))
        ds = cls(db, labels, item_names=names, name=spec.name,
                 bucket_policy=bucket_policy, planted=planted)
        ds.spec = spec
        return ds
