"""repro.api — the canonical public mining surface (DESIGN.md §5).

    from repro.api import Dataset, MinerSession

    session = MinerSession()                      # mesh + program cache
    ds = Dataset.from_paper_problem("hapmap_dom_10", 0.02)   # packed once
    report = session.mine(ds)                     # cold: compiles per phase
    report = session.mine(ds)                     # warm: zero re-compiles
    print(report.summary())
    print(report.results.describe(10))
    print(session.cache_info())

`Dataset` packs the occurrence bitmap once and pads to a shape bucket;
`MinerSession` caches compiled BSP programs by (mode, bucket, runtime
config) so phases, repeat queries, and same-bucket datasets all share them;
`MineReport`/`PhaseReport` are the typed answers.  The legacy
`repro.core.engine.lamp_distributed` dict API remains as a deprecation shim
over this package.
"""

from .config import AlgorithmConfig, RuntimeConfig
from .dataset import (
    DEFAULT_BUCKETS,
    EXACT_BUCKETS,
    BucketPolicy,
    Dataset,
    ShapeBucket,
)
from .report import MineReport, PhaseReport
from .session import PIPELINES, CacheInfo, MinerSession, ProgramInfo

__all__ = [
    "AlgorithmConfig",
    "BucketPolicy",
    "CacheInfo",
    "Dataset",
    "DEFAULT_BUCKETS",
    "EXACT_BUCKETS",
    "MineReport",
    "MinerSession",
    "PhaseReport",
    "PIPELINES",
    "ProgramInfo",
    "RuntimeConfig",
    "ShapeBucket",
]
