"""repro.api — the canonical public mining surface (DESIGN.md §5, §7).

    from repro.api import Dataset, MinerSession, SignificantPatternQuery

    session = MinerSession()                      # mesh + program cache
    ds = Dataset.from_paper_problem("hapmap_dom_10", 0.02)   # packed once
    report = session.run(ds, SignificantPatternQuery(alpha=0.05))
    report = session.run(ds, SignificantPatternQuery(statistic="chi2"))
    report = session.run(ds, ClosedFrequentQuery(min_sup=50, top_k=10))
    report = session.run(ds, TopKSignificantQuery(k=10))
    print(report.summary())
    print(report.results.describe(10))
    print(session.cache_info())

`Dataset` packs the occurrence bitmap once and pads to a shape bucket;
`Query` objects (query.py) are the mining objectives — significant
patterns under any registered `repro.stats` statistic, closed-frequent
enumeration, alpha-free top-k — all executed by one engine;
`MinerSession` caches compiled BSP programs by (mode, bucket, runtime
config, statistic) with LRU bounding so phases, repeat queries, and
same-bucket datasets all share them; `MineReport`/`PhaseReport` are the
typed answers.  `session.mine(...)` remains as a thin wrapper that builds
a `SignificantPatternQuery`; the legacy `repro.core.engine.lamp_distributed`
dict API remains as a deprecation shim over this package.
"""

from .config import AlgorithmConfig, RuntimeConfig
from .dataset import (
    DEFAULT_BUCKETS,
    EXACT_BUCKETS,
    BucketPolicy,
    Dataset,
    ShapeBucket,
)
from .query import (
    QUERIES,
    ClosedFrequentQuery,
    Query,
    SignificantPatternQuery,
    TopKSignificantQuery,
)
from .report import MineReport, PhaseReport
from .session import PIPELINES, CacheInfo, MinerSession, ProgramInfo

__all__ = [
    "AlgorithmConfig",
    "BucketPolicy",
    "CacheInfo",
    "ClosedFrequentQuery",
    "Dataset",
    "DEFAULT_BUCKETS",
    "EXACT_BUCKETS",
    "MineReport",
    "MinerSession",
    "PhaseReport",
    "PIPELINES",
    "ProgramInfo",
    "QUERIES",
    "Query",
    "RuntimeConfig",
    "ShapeBucket",
    "SignificantPatternQuery",
    "TopKSignificantQuery",
]
