"""Session configuration, split along the algorithm/runtime seam.

`AlgorithmConfig` is *what* to compute (statistical level, min-support
policy, phase staging) — it never appears in a compiled-program cache key,
because alpha/min_sup/delta all enter the BSP program as runtime arguments.
`RuntimeConfig` is *how* to run it (batch sizes, caps, kernel, stealing) —
it is hashable and, resolved against a shape bucket, forms the non-shape
half of the cache key.

`RuntimeConfig.resolve(bucket, n_devices)` is the library home of the
per-dataset stack sizing heuristic that used to live in `launch/mine.py`
(CLI-only — library callers got an unsized stack).  It sizes by items per
miner and then clamps by per-miner stack *memory*, which scales with the
word width W = ceil(transactions/32): the old items-only rule ignored W, so
scaling transactions up (scale_trans) silently multiplied stack bytes.
Resolution uses bucket dims, not exact dims, so same-bucket datasets
resolve to the same EngineConfig and share compiled programs.

Three knobs resolve to backend-/bucket-concrete values here and therefore
land in the program cache key: `kernel_impl="auto"` becomes "pallas" on
TPU, "pallas_gpu" on GPU, "ref" elsewhere (the dispatch point's
`resolve_impl`); `kernel_blocks=None` becomes the autotuner's (block_b,
block_m, block_w) triple for (expand_batch, bucket tile, bucket words) —
see kernels/support_count/autotune (DESIGN.md §8); and `sync_period` — the
superstep interval between lambda/histogram syncs (DESIGN.md §6) — passes
through verbatim, so sessions with different sync cadences never share a
compiled superstep program.
"""

from __future__ import annotations

from dataclasses import dataclass, fields, replace

from repro.core.engine import EngineConfig
from repro.core.expand import resolve_kernel_impl
from repro.kernels.support_count import autotune
from repro.obs.trace import DEFAULT_TRACE_CAP
from repro.topo.topology import Topology

from .dataset import ShapeBucket

__all__ = ["AlgorithmConfig", "RuntimeConfig"]


@dataclass(frozen=True)
class AlgorithmConfig:
    """What to compute: test statistic, significance level, phase staging."""

    alpha: float = 0.05          # family-wise error rate target
    statistic: str = "fisher"    # repro.stats registry key: "fisher" | "chi2"
    pipeline: str = "three_phase"  # PIPELINES key: "three_phase" | "fused23"
    min_sup_floor: int = 1       # lower bound on the lambda-derived min_sup


@dataclass(frozen=True)
class RuntimeConfig:
    """How to run it: caps, kernel, stealing.  Hashable — cache-key half."""

    expand_batch: int = 16         # B: nodes popped per device per superstep
    stack_cap: int | None = None   # CAP; None = auto-size via resolve()
    steal_max: int = 256           # T: max nodes per GIVE
    push_cap: int = 1024           # C: max child pushes per superstep
    out_cap: int = 4096            # significant-sample buffer
    max_steps: int = 100_000
    n_random_perms: int = 4
    seed: int = 0
    steal_enabled: bool = True
    kernel_impl: str = "auto"      # "auto" (pallas on TPU, pallas_gpu on GPU,
    #                                ref elsewhere) | any ops.VALID_IMPLS name
    #: (block_b, block_m, block_w) for the Pallas kernel; None = let the
    #: autotuner choose per (expand_batch, bucket tile, bucket words) at
    #: resolve time — the resolved triple joins the program cache key
    kernel_blocks: tuple[int, int, int] | None = None
    #: superstep trace sampling period (DESIGN.md §9): 0 = tracing off
    #: (default); k > 0 records one TraceField row every k-th superstep.
    #: Part of EngineConfig and hence of the program cache key — traced and
    #: untraced sessions never share a compiled superstep program.
    trace_period: int = 0
    trace_cap: int = 0             # trace ring slots; 0 = default when tracing
    sync_period: int = 4           # supersteps between lambda/histogram syncs
    #: checkpoint cadence (DESIGN.md §11): 0 = classic whole-phase program;
    #: k > 0 compiles the segmented program (the BSP carry round-trips to
    #: host every k supersteps) enabling frontier checkpoint/resume and
    #: cooperative soft deadlines.  Part of the program cache key.
    ckpt_period: int = 0
    #: machine shape (repro.topo): None = flat 1-D miners mesh; a Topology
    #: switches the session onto the 2-D [hosts, local] mesh with the
    #: hierarchical two-level lifeline schedule.  Hashable, so topology
    #: lands in the resolved EngineConfig and hence the program cache key —
    #: flat and hierarchical programs never collide.
    topology: Topology | None = None
    stack_mem_mb: int = 256        # per-miner stack memory ceiling (resolve())
    # session-level knob (NOT part of any compiled program, so it never
    # reaches the resolved EngineConfig cache key): max compiled programs a
    # MinerSession retains before LRU eviction.  Long-lived serving
    # processes cycling many (mode, bucket, statistic) combinations stay
    # bounded; evictions are counted in CacheInfo.
    max_programs: int = 64

    @classmethod
    def from_engine_config(cls, cfg: EngineConfig) -> "RuntimeConfig":
        """Adopt a legacy EngineConfig verbatim (stack_cap stays fixed)."""
        return cls(**{f.name: getattr(cfg, f.name) for f in fields(EngineConfig)})

    def with_options(self, **kw) -> "RuntimeConfig":
        return replace(self, **kw)

    def resolve(self, bucket: ShapeBucket, n_devices: int) -> EngineConfig:
        """Concrete EngineConfig for one shape bucket.

        stack_cap default: 2 nodes per depth-1 root dealt to this miner
        (the launcher's old items-based rule), floored at 8192, then clamped
        so the per-miner stack — stack_cap * (W + 4) * 4 bytes, W the packed
        word width — stays under `stack_mem_mb`.  The clamp never goes below
        what one superstep can produce (push_cap + steal_max + expand_batch).
        """
        cap = self.stack_cap
        if cap is None:
            cap = max(8192, 2 * bucket.items // max(n_devices, 1) + 64)
            node_bytes = 4 * (bucket.words + 4)  # occ [W]u32 + meta [4]i32
            mem_cap = (self.stack_mem_mb * 2**20) // node_bytes
            floor = 2 * (self.push_cap + self.steal_max + self.expand_batch)
            cap = max(min(cap, mem_cap), floor)
        impl = resolve_kernel_impl(self.kernel_impl)
        blocks = self.kernel_blocks
        if blocks is None and impl != "ref":
            # pin the autotuned triple: the per-tile sweep shape is
            # (expand_batch, bucket tile, bucket words)
            blocks = autotune.choose_blocks(
                self.expand_batch, bucket.tile, bucket.words, impl
            )
        return EngineConfig(
            expand_batch=self.expand_batch,
            stack_cap=int(cap),
            steal_max=self.steal_max,
            push_cap=self.push_cap,
            out_cap=self.out_cap,
            max_steps=self.max_steps,
            n_random_perms=self.n_random_perms,
            seed=self.seed,
            steal_enabled=self.steal_enabled,
            # "auto" impl and None blocks resolve here — per backend and
            # bucket — so the resolved config (and with it the session's
            # program cache key) is concrete
            kernel_impl=impl,
            kernel_blocks=blocks,
            trace_period=self.trace_period,
            # tracing on with no explicit ring size: supply the default cap
            trace_cap=(
                self.trace_cap
                if self.trace_cap or not self.trace_period
                else DEFAULT_TRACE_CAP
            ),
            sync_period=self.sync_period,
            ckpt_period=self.ckpt_period,
            topology=self.topology,
        )
