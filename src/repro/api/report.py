"""Typed mining reports — the session's answer objects (DESIGN.md §5).

`PhaseReport` wraps one engine pass (which compiled program ran, whether it
was a warm cache hit, wall/compile time, and the raw `MineOutput` for
telemetry); `MineReport` is the full query answer that replaces the legacy
untyped dict: the LAMP quantities, the `ResultSet` of mined patterns, and
per-phase reports.  `to_legacy_dict()` reproduces the documented
`lamp_distributed` dict exactly for the deprecation shim.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.engine import MineOutput
from repro.obs.trace import SuperstepTrace
from repro.results import ResultSet

__all__ = ["PhaseReport", "MineReport"]


@dataclass(frozen=True)
class PhaseReport:
    """One engine pass: what ran, how long, and its raw output."""

    mode: str                  # "lamp1" | "count" | "test" | "count2d"
    wall_s: float              # end-to-end phase wall time (incl. compile)
    compile_s: float           # program compile time (0.0 on a warm hit)
    cache_hit: bool            # True = reused an already-compiled program
    supersteps: int
    lam_final: int
    n_nodes: int               # total nodes popped across miners
    steals: int                # total steal receptions across miners
    steal_rounds: int          # hunger-gated exchange rounds that executed
    emit_dropped: int          # pattern records lost to out_cap saturation
    output: MineOutput = field(repr=False)  # full raw telemetry
    # kernel provenance (DESIGN.md §8) — the *resolved* support-count
    # dispatch this pass actually ran with, so a committed number can never
    # silently come from a different kernel than claimed:
    kernel_impl: str = "ref"   # concrete ops.VALID_IMPLS name (never "auto")
    kernel_blocks: "tuple[int, int, int] | None" = None  # autotuned (bb, bm, bw)
    item_tile: int = 0         # tile width of the db layout (0 = untiled legacy)
    n_item_tiles: int = 1      # tiles per support-count sweep
    # decoded device superstep timeline (repro.obs, DESIGN.md §9); present
    # iff the session ran with trace_period > 0:
    trace: SuperstepTrace | None = field(default=None, repr=False)
    trace_dropped: int = 0     # sampled trace records lost to ring wrap
    # per-schedule-round steal attribution (DESIGN.md §12; traced sessions
    # only): round name -> {tier, steps, fired, donated, received}, and
    # Jain's donation fairness split by steal tier ("local"/"cross" on the
    # hierarchical schedule, "flat" on the one-level schedule)
    steal_by_round: dict | None = field(default=None, repr=False)
    tier_fairness: dict | None = None
    # fault-tolerance provenance (DESIGN.md §11; segmented runs only):
    partial: bool = False      # stopped cooperatively at a superstep boundary
    resumed: bool = False      # frontier restored from a checkpoint
    ckpt_writes: int = 0       # frontier checkpoints written this phase
    ckpt_bytes: int = 0        # total frontier payload bytes written
    ckpt_path: str | None = None  # newest published step dir (None = none)

    @property
    def stats(self):
        """Per-device counter arrays (STAT_NAMES keyed)."""
        return self.output.stats


@dataclass(frozen=True)
class MineReport:
    """The answer to one mining query.

    Significant-pattern queries fill every field; other objectives leave
    the LAMP quantities that don't apply to them as NaN (alpha/delta) or
    their trivial values, and tag themselves via `query`/`statistic`.
    """

    dataset: str               # Dataset.name
    pipeline: str              # "three_phase" | "fused23" | objective tag
    alpha: float               # NaN for alpha-free objectives
    lambda_final: int
    min_sup: int
    correction_factor: int     # k: number of testable (closed) patterns
    delta: float               # alpha / k, the corrected level (NaN if unused)
    n_significant: int
    results: ResultSet         # the mined patterns themselves
    phases: tuple[PhaseReport, ...]
    wall_s: float              # full query wall time
    statistic: str | None = "fisher"  # repro.stats key; None = untested
    query: str = "significant"        # objective tag (api.query.QUERIES key)
    #: True when the query stopped at a soft deadline before completing —
    #: `results` covers only the explored region (results.complete is
    #: False) and `ckpt_path` names the frontier checkpoint to resume from
    partial: bool = False
    ckpt_path: str | None = None

    @property
    def cold(self) -> bool:
        """True when any phase had to compile (first query of its bucket)."""
        return any(not p.cache_hit for p in self.phases)

    @property
    def kernel_impl(self) -> str:
        """Resolved support-count kernel that carried the expand path.

        All phases of one query resolve identically (same session runtime,
        same bucket), so the first phase speaks for the query.
        """
        return self.phases[0].kernel_impl if self.phases else "ref"

    @property
    def kernel_blocks(self) -> "tuple[int, int, int] | None":
        return self.phases[0].kernel_blocks if self.phases else None

    @property
    def item_tile(self) -> int:
        return self.phases[0].item_tile if self.phases else 0

    def summary(self) -> str:
        import math

        tag = "cold" if self.cold else "warm"
        if self.query == "closed-frequent":
            head = (f"{self.dataset}[closed-frequent] min_sup={self.min_sup} "
                    f"closed={self.n_significant}")
        else:
            stat = f" stat={self.statistic}" if self.statistic != "fisher" else ""
            delta = "n/a" if math.isnan(self.delta) else f"{self.delta:.3e}"
            head = (
                f"{self.dataset}[{self.pipeline}]{stat} "
                f"lambda={self.lambda_final} min_sup={self.min_sup} "
                f"k={self.correction_factor} delta={delta} "
                f"significant={self.n_significant}"
            )
        return f"{head} ({self.wall_s:.3f}s {tag})"

    def to_legacy_dict(self) -> dict:
        """The documented `lamp_distributed` return dict, exactly."""
        return {
            "lambda_final": self.lambda_final,
            "min_sup": self.min_sup,
            "correction_factor": self.correction_factor,
            "delta": self.delta,
            "n_significant": self.n_significant,
            "results": self.results,
            "phase_outputs": tuple(p.output for p in self.phases),
        }
