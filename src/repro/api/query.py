"""Queries as first-class objects — the mining objectives of one engine.

The paper's contribution is *generalizing* a closed-pattern miner into a
significant-pattern miner: the same GLB traversal, re-targeted by a
different pruning bound (§3).  This module makes that generalization the
API: a `Query` is a frozen description of an objective, executed by
`MinerSession.run(dataset, query)` against the session's warm compiled
programs.  Three objectives ship:

  SignificantPatternQuery(alpha, statistic, pipeline)
      Full LAMP staging (lambda search -> correction factor -> corrected
      test) under any registered `repro.stats.TestStatistic`.  The default
      query — `session.mine(...)` is a thin wrapper that builds one.

  ClosedFrequentQuery(min_sup, top_k)
      The task-parallel FPM literature's base workload: every closed
      itemset with support >= min_sup.  No statistic and no multiple-
      testing staging — a single "test"-mode traversal whose emission gate
      is constant-true (statistic=None), reusing the pattern-record path
      end to end.  Works on unlabelled datasets.

  TopKSignificantQuery(k, statistic)
      Alpha-free: the k individually most significant patterns.  A host
      bisection over the corrected level delta drives repeated "test"
      traversals on the warm session — after the first probe compiles the
      program, every probe is a zero-trace dispatch; each probe's Tarone
      bound min_sup(delta) keeps the traversals pruned.

Adding an objective is ~50 lines: subclass `Query`, implement `run` in
terms of `session.run_phase` / `session._build_results`, and (optionally)
register it in `QUERIES` for the launchers.  Constructors validate their
parameters eagerly so a bad query fails at build time, not after a
traversal.
"""

from __future__ import annotations

import math
import time
import warnings
from abc import ABC, abstractmethod
from dataclasses import dataclass

import numpy as np

from repro.stats import get_statistic

from .report import MineReport

__all__ = [
    "QUERIES",
    "Query",
    "ClosedFrequentQuery",
    "SignificantPatternQuery",
    "TopKSignificantQuery",
]


class Query(ABC):
    """A frozen mining objective, executable against any MinerSession."""

    @abstractmethod
    def run(self, session, dataset) -> MineReport:
        """Execute on `session` (repro.api.MinerSession) over `dataset`."""

    def _require_labels(self, dataset) -> None:
        if dataset.labels is None:
            raise ValueError(
                f"{type(self).__name__} tests against class labels, but "
                f"dataset {dataset.name!r} has none; construct it with "
                "labels=..., or use ClosedFrequentQuery for unlabelled data"
            )


@dataclass(frozen=True)
class SignificantPatternQuery(Query):
    """All patterns significant at family-wise level alpha (LAMP staging)."""

    alpha: float = 0.05
    statistic: str = "fisher"
    pipeline: str = "three_phase"

    def __post_init__(self):
        if not (isinstance(self.alpha, float) and 0.0 < self.alpha < 1.0):
            raise ValueError(
                f"SignificantPatternQuery.alpha must be a float in (0, 1), "
                f"got {self.alpha!r}"
            )
        get_statistic(self.statistic)  # fail on typos at construction

    def run(self, session, dataset) -> MineReport:
        from .session import PIPELINES

        self._require_labels(dataset)
        try:
            stage = PIPELINES[self.pipeline]
        except KeyError:
            raise ValueError(
                f"unknown pipeline {self.pipeline!r}; available: "
                f"{sorted(PIPELINES)}"
            ) from None
        return stage(session, dataset, self)


@dataclass(frozen=True)
class ClosedFrequentQuery(Query):
    """All closed itemsets with support >= min_sup (top_k largest kept)."""

    min_sup: int
    top_k: int | None = None

    def __post_init__(self):
        if not (isinstance(self.min_sup, int) and self.min_sup >= 1):
            raise ValueError(
                f"ClosedFrequentQuery.min_sup must be an int >= 1, got "
                f"{self.min_sup!r} (support thresholds count transactions)"
            )
        if self.top_k is not None and not (
            isinstance(self.top_k, int) and self.top_k >= 1
        ):
            raise ValueError(
                f"ClosedFrequentQuery.top_k must be None or an int >= 1, "
                f"got {self.top_k!r}"
            )

    def run(self, session, dataset) -> MineReport:
        t0 = time.perf_counter()
        # one traversal: mode "test" with no statistic emits every counted
        # closed set (delta >= 1 keeps the runtime gate wide open)
        ph = session.run_phase(
            dataset, "test", min_sup=self.min_sup, delta=1.0, statistic=None,
        )
        if ph.partial:  # soft deadline: emitted-so-far closed sets, no root
            report = session._partial_mine_report(
                dataset, [ph], pipeline="closed-frequent",
                query_tag="closed-frequent", alpha=float("nan"),
                statistic=None, t0=t0, min_sup=self.min_sup, k=1, lam=self.min_sup,
            )
            if self.top_k is not None:
                report.results.patterns = report.results.patterns[: self.top_k]
            return report
        k = ph.output.sig_count  # device emissions + the host-counted root

        # the root closed set (closure of the empty itemset) never transits
        # the device buffers; append its record host-side so the pattern
        # list matches the count (and the sequential lcm_closed oracle)
        results = session._build_results(
            dataset, ph.output, alpha=float("nan"), min_sup=self.min_sup,
            k=1, delta=float("nan"), filter_host=False, statistic=None,
            records=session._root_record(dataset, ph.output, None,
                                         float("nan"), self.min_sup),
        )
        if self.top_k is not None:
            results.patterns = results.patterns[: self.top_k]
        return MineReport(
            dataset=dataset.name,
            pipeline="closed-frequent",
            alpha=float("nan"),
            lambda_final=self.min_sup,
            min_sup=self.min_sup,
            correction_factor=1,
            delta=float("nan"),
            n_significant=k,
            results=results,
            phases=(ph,),
            wall_s=time.perf_counter() - t0,
            statistic=None,
            query="closed-frequent",  # the QUERIES key, round-trippable
        )


@dataclass(frozen=True)
class TopKSignificantQuery(Query):
    """The k individually most significant patterns, no alpha required.

    Bisects the corrected level delta on the warm session: each probe runs
    one "test" traversal at (delta, min_sup(delta)) — min_sup(delta) is the
    smallest support whose Tarone bound can still reach delta, so probes
    stay pruned — and counts the significant patterns; the bracket closes
    on the smallest probed delta admitting >= k patterns, whose emitted
    records are exactly re-tested on the host and truncated to the k best.
    Only the first probe can compile; the rest replay the cached program.

    Patterns with P > 0.5 are never considered (delta is bisected inside
    (0, 0.5]); if fewer than k patterns clear that ceiling, all of them are
    returned (check `report.n_significant`).

    Why bisection rather than one `count2d` histogram pass (which would fix
    the exact k-th delta in a single traversal): on a warm serving session
    the `test` program is typically already compiled by significant-pattern
    queries of the same statistic, so every probe is a zero-compile
    dispatch at a Tarone-pruned min_sup, whereas `count2d` would compile a
    second program per (bucket, statistic) and always pay one full
    min_sup=1-ish enumeration.
    """

    k: int
    statistic: str = "fisher"
    max_probes: int = 24

    def __post_init__(self):
        if not (isinstance(self.k, int) and self.k >= 1):
            raise ValueError(
                f"TopKSignificantQuery.k must be an int >= 1, got {self.k!r}"
            )
        if not (isinstance(self.max_probes, int) and self.max_probes >= 1):
            raise ValueError(
                f"TopKSignificantQuery.max_probes must be an int >= 1, got "
                f"{self.max_probes!r}"
            )
        get_statistic(self.statistic)

    def run(self, session, dataset) -> MineReport:
        self._require_labels(dataset)
        t0 = time.perf_counter()
        stat = get_statistic(self.statistic)
        n, n_pos = dataset.n_transactions, dataset.n_pos
        # Tarone bound per support: min_sup(delta) prunes every probe
        f = np.asarray(
            stat.min_attainable_pvalue(np.arange(n + 1), n, n_pos),
            dtype=np.float64,
        )

        phases = []
        # postprocess counts the root closed set host-side when its P-value
        # clears delta (possible for chi2: p_root = 0.5), but the root never
        # rides the emission buffers — exclude it so the bisection counts
        # exactly the emittable patterns it will later truncate to k
        root_p = float(stat.pvalue(n, n_pos, n, n_pos)[0])

        def probe(delta: float):
            reachable = np.flatnonzero(f[1:] <= delta)
            if reachable.size == 0:
                return None, 0
            ph = session.run_phase(
                dataset, "test", min_sup=int(reachable[0]) + 1, delta=delta,
                statistic=self.statistic,
            )
            phases.append(ph)
            if ph.partial:  # soft deadline mid-probe: abort the bisection
                return ph, -1
            return ph, ph.output.sig_count - (1 if root_p <= delta else 0)

        hi = 0.5
        stopped = False
        ph_hi, c_hi = probe(hi)
        if c_hi < 0:  # deadline hit inside the very first probe
            return session._partial_mine_report(
                dataset, phases, pipeline="topk", query_tag="topk",
                alpha=float("nan"), statistic=self.statistic, t0=t0,
                min_sup=1, k=1, delta=hi, lam=0,
            )
        if c_hi >= self.k:
            lo = max(float(f.min()) / 2.0, 1e-290)
            for _ in range(self.max_probes - 1):
                if c_hi == self.k or hi <= lo * (1.0 + 1e-9):
                    break
                mid = math.sqrt(lo * hi)  # geometric: delta spans decades
                ph, c = probe(mid)
                if c < 0:  # deadline: keep the last accepted hi bracket
                    stopped = True
                    break
                if c >= self.k:
                    hi, ph_hi, c_hi = mid, ph, c
                else:
                    lo = mid

        if ph_hi is None:
            raise RuntimeError(
                "TopKSignificantQuery: no pattern can attain P <= 0.5 on "
                "this dataset (Tarone bound excludes every support)"
            )
        if ph_hi.output.emit_dropped:
            # massive P-value ties can pin the bracket above out_cap: the
            # emitted record set is then an arbitrary subset, so the k kept
            # below may not be the true best-k.  The ResultSet's complete
            # flag carries the same signal (n_dropped > 0); never silent.
            warnings.warn(
                f"top-k emission overflow: the accepted probe (delta={hi:.3e}, "
                f"{c_hi} significant) dropped {ph_hi.output.emit_dropped} "
                "records to out_cap saturation, so the returned top-k may be "
                "incomplete — raise RuntimeConfig.out_cap or lower k",
                RuntimeWarning,
                stacklevel=2,
            )
        results = session._build_results(
            dataset, ph_hi.output, alpha=float("nan"), min_sup=1,
            k=1, delta=hi, filter_host=False, statistic=self.statistic,
        )
        results.patterns = results.patterns[: self.k]
        if stopped:
            # the accepted bracket's patterns are valid, but the bisection
            # never refined delta to the exact k-th level — flag the answer
            results.truncated = True
        # all probes are reported, with the ACCEPTED one last — phases[-1]
        # is the traversal that produced the returned patterns (rejected
        # lo-side probes are near-empty runs; telemetry readers key on -1)
        phases = [p for p in phases if p is not ph_hi] + [ph_hi]
        return MineReport(
            dataset=dataset.name,
            pipeline="topk",
            alpha=float("nan"),
            lambda_final=0,
            min_sup=1,
            correction_factor=1,
            delta=hi,
            n_significant=len(results.patterns),
            results=results,
            phases=tuple(phases),
            wall_s=time.perf_counter() - t0,
            statistic=self.statistic,
            query="topk",
            partial=stopped,
            ckpt_path=phases[-1].ckpt_path,
        )


#: objective registry for launchers/config surfaces (name -> Query class)
QUERIES: dict[str, type[Query]] = {
    "significant": SignificantPatternQuery,
    "closed-frequent": ClosedFrequentQuery,
    "topk": TopKSignificantQuery,
}
