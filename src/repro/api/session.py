"""MinerSession — compile-once, query-many pattern mining.

The paper's deliverable is a miner that answers queries at scale; the
deployment mode that matters is *repeated* queries.  A session owns the
device mesh and a bounded LRU cache of AOT-compiled BSP programs keyed by

    (mode, shape bucket, resolved RuntimeConfig, statistic)

— everything the compiled artifact actually depends on (resolution makes
the key concrete: `kernel_impl="auto"` becomes the backend's kernel and
`sync_period` — the lambda-sync cadence baked into the superstep program —
rides along).  The statistic component is the registered test whose device
P-value is *traced into* the emission gate of modes "test"/"count2d", so
fisher and chi2 programs never collide; modes "lamp1"/"count" never trace
a statistic (its Tarone thresholds are runtime data) and key it as None,
so every statistic shares their programs.  Statistical parameters
(alpha / min_sup / delta) and the dataset's exact dims enter the program
as runtime arguments, so:

  * phase 2 ("count") and phase 3 ("test"/"count2d") of one query never
    re-trace what phase 1 already traced for a different mode only once each;
  * a repeat query — same dataset, or any dataset in the same bucket —
    replays fully warm programs with **zero** new traces or compiles;
  * `cache_info()` exposes hits/misses/evictions and per-program lowering
    stats (compile seconds, cost analysis) for inspection and tests.

Queries are first-class objects (repro.api.query): `run(dataset, query)`
executes any registered objective — SignificantPatternQuery (the classic
LAMP staging, any statistic), ClosedFrequentQuery, TopKSignificantQuery —
and `mine(...)` survives as a thin wrapper that builds a
SignificantPatternQuery from the session's AlgorithmConfig.  The LAMP
stagings themselves (`PIPELINES`: "three_phase" | "fused23") are functions
over a session, sharing its packed dataset and warm programs across phases.

Thread-safety contract (DESIGN.md §10): a session executes **one query at a
time** — `run` / `mine` / `run_phase` must never be called concurrently
from multiple threads (the serve fleet enforces this by pinning each
session to its own single-thread executor).  The program cache itself is
lock-protected, so the *introspection and warmup* surface —
`cache_info()`, `has_programs()`, `clear_cache()`, `warmup()` — is safe to
call from other threads while a query runs (warmup compiles outside the
lock; a lost compile race keeps the first-inserted program).
"""

from __future__ import annotations

import math
import os
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Callable

import numpy as np

import jax

from repro.core import collectives
from repro.core.engine import (
    VALID_MODES,
    EngineConfig,
    MineOutput,
    build_phase_program,
    make_phase_args,  # noqa: F401  (re-exported for compatibility)
    make_program_args,
    mesh_axis,
    phase_in_specs,
    phase_out_specs,
    postprocess_phase,
    run_segments,
    segments_raw_output,
)
from repro.core.lifeline import build_schedule
from repro.obs import MetricsRegistry, SpanTracer
from repro.stats import get_statistic

from .config import AlgorithmConfig, RuntimeConfig
from .dataset import Dataset, ShapeBucket
from .query import Query, SignificantPatternQuery
from .report import MineReport, PhaseReport

__all__ = ["CacheInfo", "MinerSession", "PIPELINES", "PIPELINE_MODES",
           "ProgramInfo"]

#: engine modes each LAMP staging compiles — the warmup/affinity surface
#: (serve.fleet) uses this to decide what "fully warm for a bucket" means
PIPELINE_MODES: dict[str, tuple[str, ...]] = {
    "three_phase": ("lamp1", "count", "test"),
    "fused23": ("lamp1", "count2d"),
}

#: sentinel distinguishing "argument omitted" from an explicit None —
#: statistic=None elsewhere means "no test", which mine() must reject
_USE_SESSION_DEFAULT = "<session-default>"


@dataclass(frozen=True)
class ProgramInfo:
    """Lowering stats for one cached compiled program."""

    mode: str
    bucket: ShapeBucket
    compile_s: float
    calls: int
    flops: float | None    # XLA cost analysis, when the backend reports it
    statistic: str | None = None  # traced emission test ("test"/"count2d" only)


@dataclass(frozen=True)
class CacheInfo:
    """Snapshot of the session's compiled-program cache."""

    hits: int
    misses: int
    programs: tuple[ProgramInfo, ...]
    evictions: int = 0   # programs dropped by the max_programs LRU bound

    @property
    def n_programs(self) -> int:
        return len(self.programs)

    def __str__(self) -> str:
        lines = [f"cache: {self.hits} hits / {self.misses} misses, "
                 f"{self.n_programs} compiled programs"
                 + (f", {self.evictions} evicted" if self.evictions else "")]
        for p in self.programs:
            stat = f" stat={p.statistic}" if p.statistic is not None else ""
            lines.append(
                f"  [{p.mode:8s}]{stat} bucket=({p.bucket.transactions}, "
                f"{p.bucket.positives}, {p.bucket.items}) "
                f"compile={p.compile_s:.2f}s calls={p.calls}"
                + (f" flops={p.flops:.3g}" if p.flops is not None else "")
            )
        return "\n".join(lines)


class _Program:
    __slots__ = ("compiled", "compile_s", "flops", "calls")

    def __init__(self, compiled, compile_s: float, flops: float | None):
        self.compiled = compiled
        self.compile_s = compile_s
        self.flops = flops
        self.calls = 0


class MinerSession:
    """A persistent miner: one mesh, one program cache, many queries."""

    def __init__(
        self,
        devices=None,
        *,
        algorithm: AlgorithmConfig | None = None,
        runtime: RuntimeConfig | None = None,
        tracer: SpanTracer | None = None,
        metrics: MetricsRegistry | None = None,
    ):
        self.devices = jax.devices() if devices is None else list(devices)
        self.n_devices = len(self.devices)
        self.algorithm = algorithm or AlgorithmConfig()
        self.runtime = runtime or RuntimeConfig()
        # the machine shape decides the mesh: flat 1-D "miners" (the
        # classic path) or the 2-D [hosts, local] topo mesh with the
        # hierarchical steal schedule (repro.topo, DESIGN.md §12)
        if self.runtime.topology is not None:
            self.mesh = collectives.make_topo_mesh(
                self.runtime.topology, self.devices
            )
        else:
            self.mesh = collectives.make_miner_mesh(self.devices)
        # observability (DESIGN.md §9): every session gets a host span
        # timeline and a metrics registry; callers share one across sessions
        # (or export them) by passing their own
        self.tracer = tracer or SpanTracer()
        self.metrics = metrics or MetricsRegistry()
        m = self.metrics
        self._m_hits = m.counter(
            "miner_cache_hits_total", "compiled-program cache hits")
        self._m_misses = m.counter(
            "miner_cache_misses_total", "compiled-program cache misses")
        self._m_evictions = m.counter(
            "miner_cache_evictions_total", "programs evicted by the LRU bound")
        self._m_programs = m.gauge(
            "miner_cached_programs", "compiled programs currently cached")
        self._m_compile = m.histogram(
            "miner_compile_seconds", "phase-program compile latency")
        self._m_phase = m.histogram(
            "miner_phase_seconds", "engine phase wall time", labels=("mode",))
        self._m_query = m.histogram(
            "miner_query_seconds", "full query wall time", labels=("query",))
        self._m_emit_drop = m.counter(
            "miner_emit_dropped_total",
            "pattern records lost to out_cap saturation")
        self._m_trace_drop = m.counter(
            "miner_trace_dropped_total",
            "superstep trace records lost to ring wrap")
        self._m_ckpt_write = m.histogram(
            "miner_ckpt_write_seconds", "frontier checkpoint write latency")
        self._m_ckpt_restore = m.histogram(
            "miner_ckpt_restore_seconds",
            "frontier checkpoint restore (incl. reshard) latency")
        self._m_ckpt_bytes = m.counter(
            "miner_ckpt_bytes_total", "frontier checkpoint payload bytes")
        if self.runtime.max_programs < 1:
            raise ValueError(
                f"RuntimeConfig.max_programs must be >= 1, got "
                f"{self.runtime.max_programs} (the session needs room for at "
                "least the program it is about to run)"
            )
        # insertion/use-ordered: front = least recently used (LRU eviction)
        self._programs: OrderedDict[tuple, _Program] = OrderedDict()
        self._schedules: dict[tuple[int, int], object] = {}
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        # guards the program cache + counters only (queries stay single-
        # threaded per session; see the class docstring's contract) so
        # cache_info/has_programs/warmup are safe from other threads
        self._cache_lock = threading.RLock()
        # one-shot ResultStream installed by run(stream=...), consumed by
        # _build_results mid-query (same thread)
        self._stream = None
        # one-shot fault-tolerance state installed by run(ckpt_dir=...,
        # resume_from=..., should_stop=...) — consumed by run_phase,
        # cleared in run()'s finally (DESIGN.md §11).  _phase_seq numbers
        # each phase of the current query so every phase checkpoints into
        # its own "<seq>_<mode>" subdirectory and a resumed query lines its
        # phases back up deterministically.
        self._ckpt_dir = None
        self._resume_from = None
        self._should_stop = None
        self._phase_seq = 0

    # -------------------------------------------------------------- programs
    def _schedule(self, cfg: EngineConfig):
        key = (cfg.n_random_perms, cfg.seed, cfg.topology)
        if key not in self._schedules:
            if cfg.topology is not None:
                from repro.topo.hierarchy import build_hierarchical_schedule

                self._schedules[key] = build_hierarchical_schedule(
                    cfg.topology, cfg.n_random_perms, cfg.seed
                )
            else:
                self._schedules[key] = build_schedule(
                    self.n_devices, cfg.n_random_perms, cfg.seed
                )
        return self._schedules[key]

    def _program(self, mode: str, bucket: ShapeBucket, cfg: EngineConfig,
                 statistic: str | None, args):
        """Fetch-or-compile the phase program for (mode, bucket, cfg, stat).

        The (long) build+compile runs outside the cache lock so a warmup
        thread never stalls a running query's cache lookups; a concurrent
        compile of the same key is a benign race — first insert wins.
        """
        key = (mode, bucket, cfg, statistic)
        with self._cache_lock:
            entry = self._programs.get(key)
            if entry is not None:
                self._hits += 1
                self._m_hits.inc()
                self._programs.move_to_end(key)  # most recently used
                return entry, True
            self._misses += 1
            self._m_misses.inc()
        shardy = build_phase_program(
            (bucket.transactions, bucket.positives, bucket.items),
            cfg=cfg, schedule=self._schedule(cfg), mesh=self.mesh, mode=mode,
            statistic=statistic,
        )
        t0 = time.perf_counter()
        with self.tracer.span("compile", mode=mode, statistic=statistic):
            compiled = jax.jit(shardy).lower(*args).compile()
        compile_s = time.perf_counter() - t0
        self._m_compile.observe(compile_s)
        try:
            cost = collectives.normalize_cost_analysis(compiled.cost_analysis())
            flops = float(cost["flops"]) if "flops" in cost else None
        except Exception:  # backend without cost analysis
            flops = None
        entry = _Program(compiled, compile_s, flops)
        with self._cache_lock:
            existing = self._programs.get(key)
            if existing is not None:  # another thread won the compile race
                self._programs.move_to_end(key)
                return existing, True
            self._programs[key] = entry
            while len(self._programs) > self.runtime.max_programs:
                self._programs.popitem(last=False)  # evict least recently used
                self._evictions += 1
                self._m_evictions.inc()
            self._m_programs.set(len(self._programs))
        return entry, False

    def cache_info(self) -> CacheInfo:
        with self._cache_lock:
            return CacheInfo(
                hits=self._hits,
                misses=self._misses,
                evictions=self._evictions,
                programs=tuple(
                    ProgramInfo(mode=key[0], bucket=key[1],
                                compile_s=p.compile_s, calls=p.calls,
                                flops=p.flops, statistic=key[3])
                    for key, p in self._programs.items()
                ),
            )

    def clear_cache(self) -> int:
        """Drop every cached compiled program; returns how many were held.

        Hit/miss/eviction counters are preserved (a clear is not an LRU
        eviction); the next query of any (mode, bucket, statistic) recompiles.
        """
        with self._cache_lock:
            n = len(self._programs)
            self._programs.clear()
            return n

    # --------------------------------------------------------------- warmup
    def _pipeline_modes(self, pipeline: str | None) -> tuple[str, ...]:
        pipeline = pipeline or self.algorithm.pipeline
        try:
            return PIPELINE_MODES[pipeline]
        except KeyError:
            raise ValueError(
                f"unknown pipeline {pipeline!r}; available: "
                f"{sorted(PIPELINE_MODES)}"
            ) from None

    def has_programs(
        self,
        bucket: ShapeBucket,
        statistic: str | None = _USE_SESSION_DEFAULT,
        *,
        pipeline: str | None = None,
    ) -> bool:
        """True when every phase program `pipeline` needs for this bucket
        (under `statistic`) is already compiled — i.e. a significant-pattern
        query on any same-bucket dataset would dispatch fully warm.  The
        serve fleet's affinity scoring keys on this (DESIGN.md §10)."""
        if statistic is _USE_SESSION_DEFAULT:
            statistic = self.algorithm.statistic
        modes = self._pipeline_modes(pipeline)
        cfg = self.runtime.resolve(bucket, self.n_devices)
        with self._cache_lock:
            return all(
                (mode, bucket, cfg,
                 statistic if mode in ("test", "count2d") else None)
                in self._programs
                for mode in modes
            )

    def warmup(
        self,
        target,
        *,
        statistic: str | None = _USE_SESSION_DEFAULT,
        pipeline: str | None = None,
        alpha: float | None = None,
    ) -> int:
        """Pre-compile every phase program for a bucket before traffic needs
        it — the serve fleet's startup policy (DESIGN.md §10).

        `target` is a `ShapeBucket` (a placeholder dataset is synthesized to
        shape the program arguments; no real data required) or a `Dataset`
        (its bucket is warmed and its packed bits reused).  Returns the
        number of programs actually compiled (0 = was already fully warm).
        Safe to call from a different thread than the query thread.
        """
        if statistic is _USE_SESSION_DEFAULT:
            statistic = self.algorithm.statistic
        if statistic is not None:
            get_statistic(statistic)  # actionable ValueError on typos
        modes = self._pipeline_modes(pipeline)
        ds = target if isinstance(target, Dataset) else \
            Dataset.placeholder(target)
        alpha = self.algorithm.alpha if alpha is None else alpha
        cfg = self.runtime.resolve(ds.bucket, self.n_devices)
        compiled = 0
        with self.tracer.span("warmup", statistic=statistic,
                              bucket=str(ds.bucket)):
            for mode in modes:
                # program-shaped args (classic or segmented per ckpt_period)
                args, _ = make_program_args(
                    ds.packed, n_proc=self.n_devices, cfg=cfg, mode=mode,
                    alpha=alpha, min_sup=1, delta=0.0, statistic=statistic,
                )
                if jax.process_count() > 1:
                    from repro.topo import bootstrap

                    args = bootstrap.globalize_args(
                        args, self.mesh,
                        phase_in_specs(cfg, mesh_axis(self.mesh)),
                    )
                stat_key = statistic if mode in ("test", "count2d") else None
                _, hit = self._program(mode, ds.bucket, cfg, stat_key, args)
                compiled += 0 if hit else 1
        return compiled

    # ---------------------------------------------------------------- phases
    def run_phase(
        self,
        dataset: Dataset,
        mode: str,
        *,
        min_sup: int = 1,
        delta: float = 0.0,
        alpha: float | None = None,
        statistic: str | None = "fisher",
    ) -> PhaseReport:
        """One engine pass on a warm (or newly compiled) program.

        `statistic` names the registered test gating emission in modes
        "test"/"count2d" (None emits every counted closed set — the
        closed-frequent objective); modes "lamp1"/"count" use it only for
        the host-built Tarone threshold table, so their compiled programs
        are shared across statistics.
        """
        if mode not in VALID_MODES:
            raise ValueError(
                f"unknown engine mode {mode!r}; valid modes: "
                f"{', '.join(VALID_MODES)}"
            )
        if statistic is not None:
            get_statistic(statistic)  # actionable ValueError on typos
        t0 = time.perf_counter()
        alpha = self.algorithm.alpha if alpha is None else alpha
        partial = resumed = False
        ckpt = {"writes": 0, "bytes": 0, "path": None}
        with self.tracer.span(f"phase:{mode}", dataset=dataset.name):
            cfg = self.runtime.resolve(dataset.bucket, self.n_devices)
            if (self._ckpt_dir or self._resume_from) and cfg.ckpt_period <= 0:
                raise ValueError(
                    "ckpt_dir/resume_from need the segmented program: set "
                    "RuntimeConfig.ckpt_period > 0"
                )
            with self.tracer.span("pack"):
                args, ctx = make_program_args(
                    dataset.packed, n_proc=self.n_devices, cfg=cfg, mode=mode,
                    alpha=alpha, min_sup=min_sup, delta=delta,
                    statistic=statistic,
                )
            multiproc = jax.process_count() > 1
            if multiproc:
                # global-array marshalling (repro.topo.bootstrap): the mesh
                # spans other processes' devices, so host numpy arguments
                # must become global jax.Arrays *before* lowering (the AOT
                # program bakes in their shardings)
                from repro.topo import bootstrap

                if cfg.ckpt_period > 0:
                    raise NotImplementedError(
                        "segmented (ckpt_period > 0) passes are not yet "
                        "supported under a multi-process mesh: the per-"
                        "segment host round-trip of the carry needs "
                        "allgather plumbing"
                    )
                args = bootstrap.globalize_args(
                    args, self.mesh, phase_in_specs(cfg, mesh_axis(self.mesh))
                )
            # the statistic is traced only into the emission gate; lamp1/count
            # programs are statistic-free and shared under the None key
            stat_key = statistic if mode in ("test", "count2d") else None
            entry, hit = self._program(mode, dataset.bucket, cfg, stat_key,
                                       args)
            if cfg.ckpt_period > 0:
                with self.tracer.span("dispatch", cache_hit=hit):
                    raw, partial, resumed = self._run_segmented(
                        entry, dataset, cfg, mode=mode, alpha=alpha,
                        delta=delta, statistic=statistic, ctx=ctx, ckpt=ckpt,
                    )
            else:
                with self.tracer.span("dispatch", cache_hit=hit):
                    raw = entry.compiled(*args)
                if multiproc:
                    # every process gathers the same full numpy outputs, so
                    # postprocess (and the ResultSet) is identical everywhere
                    raw = bootstrap.fetch_outputs(
                        raw, phase_out_specs(cfg, mesh_axis(self.mesh))
                    )
            with self.tracer.span("postprocess"):
                out = postprocess_phase(
                    raw, packed=dataset.packed, n_proc=self.n_devices, cfg=cfg,
                    mode=mode, thr=ctx["thr"], start_sup=ctx["start_sup"],
                    delta=delta, statistic=statistic, partial=partial,
                    schedule=self._schedule(cfg),
                )
        entry.calls += 1
        wall_s = time.perf_counter() - t0
        self._m_phase.labels(mode=mode).observe(wall_s)
        if out.emit_dropped:
            self._m_emit_drop.inc(out.emit_dropped)
        if out.trace_dropped:
            self._m_trace_drop.inc(out.trace_dropped)
        return PhaseReport(
            mode=mode,
            wall_s=wall_s,
            compile_s=0.0 if hit else entry.compile_s,
            cache_hit=hit,
            supersteps=out.supersteps,
            lam_final=out.lam_final,
            n_nodes=int(out.stats["popped"].sum()),
            steals=int(out.stats["steals_got"].sum()),
            # gated rounds actually executed: per-miner counters are all
            # equal (the census is replicated), so read miner 0's
            steal_rounds=int(out.stats["steal_rounds"][0]),
            emit_dropped=out.emit_dropped,
            output=out,
            kernel_impl=cfg.kernel_impl,
            kernel_blocks=cfg.kernel_blocks,
            item_tile=dataset.bucket.item_tile,
            n_item_tiles=dataset.bucket.n_tiles,
            trace=out.trace,
            trace_dropped=out.trace_dropped,
            steal_by_round=(out.trace.steal_by_round()
                            if out.trace is not None else None),
            tier_fairness=(out.trace.tier_fairness()
                           if out.trace is not None else None),
            partial=partial,
            resumed=resumed,
            ckpt_writes=ckpt["writes"],
            ckpt_bytes=ckpt["bytes"],
            ckpt_path=ckpt["path"],
        )

    def _run_segmented(self, entry, dataset, cfg, *, mode, alpha, delta,
                       statistic, ctx, ckpt):
        """Drive one phase through the segmented program (DESIGN.md §11).

        Resumes the frontier from `self._resume_from` (elastically resharded
        onto this session's device count), checkpoints every segment into a
        per-phase "<seq>_<mode>" subdir of `self._ckpt_dir`, and stops
        cooperatively when `self._should_stop()` fires at a segment
        boundary.  Returns (raw 10-tuple, partial, resumed).
        """
        from repro.ckpt import mining as ckpt_mining

        tag = f"{self._phase_seq:02d}_{mode}"
        self._phase_seq += 1
        provenance = ckpt_mining.make_provenance(
            dataset.packed, mode=mode, statistic=statistic, alpha=alpha,
            start_sup=ctx["start_sup"], delta=delta,
        )
        carry = ctx["carry0"]
        resumed = False
        if self._resume_from:
            t0r = time.perf_counter()
            restored = ckpt_mining.restore_frontier(
                os.path.join(self._resume_from, tag), provenance=provenance,
                n_proc=self.n_devices, cfg=cfg, mode=mode,
            )
            self._m_ckpt_restore.observe(time.perf_counter() - t0r)
            if restored is not None:
                carry = restored
                resumed = True
        on_segment = None
        if self._ckpt_dir:
            phase_dir = os.path.join(self._ckpt_dir, tag)

            def on_segment(c):
                t0w = time.perf_counter()
                path, nbytes = ckpt_mining.save_frontier(
                    c, phase_dir, provenance=provenance,
                )
                self._m_ckpt_write.observe(time.perf_counter() - t0w)
                self._m_ckpt_bytes.inc(nbytes)
                ckpt["writes"] += 1
                ckpt["bytes"] += nbytes
                ckpt["path"] = path

        carry, partial = run_segments(
            entry.compiled, carry, cfg=cfg, static=ctx["static"],
            should_stop=self._should_stop, on_segment=on_segment,
        )
        return segments_raw_output(carry), partial, resumed

    # --------------------------------------------------------------- queries
    def run(self, dataset: Dataset, query: Query, *, stream=None,
            ckpt_dir: str | None = None, resume_from: str | None = None,
            should_stop=None) -> MineReport:
        """Execute one first-class query object (repro.api.query).

        `stream` (a `repro.results.ResultStream`) delivers the final
        top-`head_k` patterns to a callback *during* result construction —
        before full reconstruction finishes — for the serving layer's
        top-k-first delivery (DESIGN.md §10).  The returned report is
        identical with or without it.

        Fault tolerance (DESIGN.md §11; requires RuntimeConfig.ckpt_period
        > 0): `ckpt_dir` checkpoints each phase's frontier every segment;
        `resume_from` (usually a previous run's ckpt_dir) restores every
        phase that has a valid checkpoint — elastically resharded onto this
        session's device count — and the resumed query's ResultSet is
        bit-identical to an uninterrupted run; `should_stop()` polled at
        segment boundaries stops the query cooperatively, returning a
        partial MineReport (report.partial, results.complete == False) plus
        the checkpoint path to resume from.  `should_stop` is silently
        ignored when ckpt_period == 0 (the classic program has no boundary
        to stop at — the serve layer degrades to plain timeouts there).
        """
        if not isinstance(query, Query):
            raise TypeError(
                f"run() takes a repro.api.Query (e.g. "
                f"SignificantPatternQuery(alpha=0.05)), got {type(query).__name__}"
            )
        if (ckpt_dir or resume_from) and not self.runtime.ckpt_period:
            raise ValueError(
                "ckpt_dir/resume_from need the segmented program: set "
                "RuntimeConfig.ckpt_period > 0"
            )
        t0 = time.perf_counter()
        self._stream = stream
        self._ckpt_dir = ckpt_dir
        self._resume_from = resume_from
        self._should_stop = should_stop if self.runtime.ckpt_period else None
        self._phase_seq = 0
        try:
            with self.tracer.span(f"query:{type(query).__name__}",
                                  dataset=dataset.name):
                report = query.run(self, dataset)
        finally:
            self._stream = None
            self._ckpt_dir = None
            self._resume_from = None
            self._should_stop = None
            self._phase_seq = 0
        self._m_query.labels(query=report.query).observe(
            time.perf_counter() - t0
        )
        return report

    def mine(
        self,
        dataset: Dataset,
        *,
        alpha: float | None = None,
        pipeline: str | None = None,
        statistic: str = _USE_SESSION_DEFAULT,
    ) -> MineReport:
        """Answer one significant-pattern query (full LAMP staging).

        Thin wrapper: builds a `SignificantPatternQuery` from the session's
        AlgorithmConfig defaults and runs it.  Unlike `run_phase`, an
        explicit `statistic=None` is rejected here — an untested
        enumeration is a different objective (`ClosedFrequentQuery`), not a
        significance query with the default test.
        """
        if statistic is None:
            raise ValueError(
                "mine(statistic=None) is ambiguous: significance mining "
                "needs a registered statistic (omit the argument for the "
                "session default); for an untested closed-frequent "
                "enumeration use run(dataset, ClosedFrequentQuery(min_sup=...))"
            )
        query = SignificantPatternQuery(
            alpha=self.algorithm.alpha if alpha is None else alpha,
            statistic=(self.algorithm.statistic
                       if statistic is _USE_SESSION_DEFAULT else statistic),
            pipeline=self.algorithm.pipeline if pipeline is None else pipeline,
        )
        return self.run(dataset, query)

    def _build_results(self, dataset: Dataset, phase_out: MineOutput, *,
                       alpha, min_sup, k, delta, filter_host,
                       statistic: str | None = "fisher", records=None):
        """Emitted records of one phase output -> ResultSet (repro.results).

        `records=(occ, sup, pos_sup)` overrides the phase output's emitted
        arrays (used to append host-side records, e.g. the root closed set).
        """
        from repro.results import build_result_set

        occ, sup, pos_sup = (
            (phase_out.sig_occ, phase_out.sig_sup, phase_out.sig_pos_sup)
            if records is None else records
        )
        # consume the one-shot stream installed by run(stream=...) — a
        # multi-phase pipeline builds results exactly once, at the end
        stream, self._stream = self._stream, None
        # the dataset was packed exactly once; reconstruction reuses its bits
        with self.tracer.span("reconstruct", n_records=len(sup)):
            return build_result_set(
                occ, sup, pos_sup,
                dataset.packed.db_bits,
                n=dataset.n_transactions, n_pos=dataset.n_pos, alpha=alpha,
                min_sup=min_sup, correction_factor=k, delta=delta,
                filter_host=filter_host, dropped=phase_out.emit_dropped,
                item_names=dataset.item_names, statistic=statistic,
                stream=stream,
            )

    def _root_record(self, dataset: Dataset, phase_out: MineOutput,
                     statistic: str | None, delta: float, min_sup: int):
        """Emitted records + the root closed set, when the run counts it.

        The root never transits the device buffers; `postprocess_phase`
        counts it host-side (same support guard, same test), so the pattern
        list must append it under *exactly* the same conditions or
        n_significant and len(results) disagree: root support n >= min_sup,
        and — for a testing run — labels present with the statistic's root
        P-value <= delta (Fisher's is exactly 1 and never fires; chi2's is
        0.5, reachable when delta >= 0.5, i.e. alpha near 1 with k == 1).
        statistic=None is the closed-frequent objective: the support guard
        alone decides, labels optional.  Returns None (caller keeps the
        device records as-is) when the root does not qualify.
        """
        n, n_pos = dataset.n_transactions, dataset.n_pos
        if n < min_sup:
            return None  # postprocess's root_sup >= start_sup guard
        if statistic is not None:
            if dataset.labels is None or float(
                get_statistic(statistic).pvalue(n, n_pos, n, n_pos)[0]
            ) > delta:
                return None
        return (
            np.concatenate([phase_out.sig_occ,
                            dataset.packed.occ0[None, :]], axis=0),
            np.concatenate([phase_out.sig_sup, [n]]),
            np.concatenate([phase_out.sig_pos_sup,
                            [n_pos if dataset.labels is not None else 0]]),
        )

    def _partial_mine_report(
        self, dataset: Dataset, phases, *, pipeline: str, query_tag: str,
        alpha: float, statistic: str | None, t0: float, min_sup: int = 0,
        k: int = 0, delta: float = float("nan"), lam: int | None = None,
        filter_host: bool = False,
    ) -> MineReport:
        """A MineReport for a query stopped at a soft deadline (§11).

        The last phase is the one that stopped; its emitted-so-far records
        (modes "test"/"count2d") become a truncated ResultSet — the root
        record is *not* folded in (the run never finished deciding it).
        Phases that emit nothing (lamp1/count) yield an empty truncated
        ResultSet.  LAMP quantities the stopped staging never derived stay
        at their NaN/0 placeholders.
        """
        from repro.results import ResultSet

        ph = phases[-1]
        out = ph.output
        if out.sig_occ is not None and len(out.sig_occ):
            results = self._build_results(
                dataset, out, alpha=alpha, min_sup=min_sup, k=max(k, 1),
                delta=(alpha if math.isnan(delta) else delta),
                filter_host=filter_host, statistic=statistic,
            )
        else:
            self._stream = None  # the one-shot stream has nothing to carry
            results = ResultSet(
                n_transactions=dataset.n_transactions, n_pos=dataset.n_pos,
                alpha=alpha, min_sup=min_sup, correction_factor=max(k, 1),
                delta=delta, statistic=statistic,
                item_names=dataset.item_names,
            )
        results.truncated = True
        return MineReport(
            dataset=dataset.name,
            pipeline=pipeline,
            alpha=alpha,
            lambda_final=ph.lam_final if lam is None else lam,
            min_sup=min_sup,
            correction_factor=k,
            delta=delta,
            n_significant=out.sig_count,
            results=results,
            phases=tuple(phases),
            wall_s=time.perf_counter() - t0,
            statistic=statistic,
            query=query_tag,
            partial=True,
            ckpt_path=ph.ckpt_path,
        )


# -------------------------------------------------------------- pipelines
def _pipeline_three_phase(session: MinerSession, dataset: Dataset,
                          query: SignificantPatternQuery) -> MineReport:
    """The paper's §3.3 staging: lamp1 -> count -> test (three traversals)."""
    t0 = time.perf_counter()
    alpha, statistic = query.alpha, query.statistic
    ph1 = session.run_phase(dataset, "lamp1", alpha=alpha, statistic=statistic)
    if ph1.partial:  # soft deadline mid-lambda-search: nothing emitted yet
        return session._partial_mine_report(
            dataset, [ph1], pipeline="three_phase", query_tag="significant",
            alpha=alpha, statistic=statistic, t0=t0,
        )
    min_sup = max(ph1.lam_final - 1, session.algorithm.min_sup_floor)

    # phase 2: exact closed-set count at min_sup
    ph2 = session.run_phase(dataset, "count", min_sup=min_sup, alpha=alpha,
                            statistic=statistic)
    if ph2.partial:
        return session._partial_mine_report(
            dataset, [ph1, ph2], pipeline="three_phase",
            query_tag="significant", alpha=alpha, statistic=statistic, t0=t0,
            min_sup=min_sup, lam=ph1.lam_final,
        )
    k = int(ph2.output.hist[min_sup:].sum())
    delta = alpha / max(k, 1)
    # phase 3: significance testing at delta
    ph3 = session.run_phase(dataset, "test", min_sup=min_sup, delta=delta,
                            alpha=alpha, statistic=statistic)
    if ph3.partial:  # records emitted so far are already delta-filtered
        return session._partial_mine_report(
            dataset, [ph1, ph2, ph3], pipeline="three_phase",
            query_tag="significant", alpha=alpha, statistic=statistic, t0=t0,
            min_sup=min_sup, k=k, delta=delta, lam=ph1.lam_final,
        )
    # the device already filtered at delta; reconstruct + exact stats only
    # (the root closed set is appended iff the statistic counts it — it is
    # in ph3's n_sig exactly when significant, so list and count agree)
    results = session._build_results(
        dataset, ph3.output, alpha=alpha, min_sup=min_sup, k=k, delta=delta,
        filter_host=False, statistic=statistic,
        records=session._root_record(dataset, ph3.output, statistic, delta,
                                     min_sup),
    )
    return MineReport(
        dataset=dataset.name,
        pipeline="three_phase",
        alpha=alpha,
        lambda_final=ph1.lam_final,
        min_sup=min_sup,
        correction_factor=k,
        delta=delta,
        n_significant=ph3.output.sig_count,
        results=results,
        phases=(ph1, ph2, ph3),
        wall_s=time.perf_counter() - t0,
        statistic=statistic,
    )


def _pipeline_fused23(session: MinerSession, dataset: Dataset,
                      query: SignificantPatternQuery) -> MineReport:
    """Beyond-paper: lamp1 -> count2d, two traversals.

    One enumeration pass builds a 2-D (support x pos-support) histogram;
    P-values depend only on that pair — true of every margin-determined
    statistic (fisher, chi2) — so the correction factor AND the significant
    count both fall out of the histogram — the third engine pass
    disappears entirely.  The same pass emits alpha-level pattern records
    (delta <= alpha always), which the host filters down to the exact final
    delta, so pattern identities survive the fusion too (DESIGN.md §4).
    """
    t0 = time.perf_counter()
    alpha, statistic = query.alpha, query.statistic
    stat = get_statistic(statistic)
    ph1 = session.run_phase(dataset, "lamp1", alpha=alpha, statistic=statistic)
    if ph1.partial:  # soft deadline mid-lambda-search: nothing emitted yet
        return session._partial_mine_report(
            dataset, [ph1], pipeline="fused23", query_tag="significant",
            alpha=alpha, statistic=statistic, t0=t0,
        )
    min_sup = max(ph1.lam_final - 1, session.algorithm.min_sup_floor)

    n, n_pos = dataset.n_transactions, dataset.n_pos
    ph2 = session.run_phase(dataset, "count2d", min_sup=min_sup, delta=alpha,
                            alpha=alpha, statistic=statistic)
    if ph2.partial:  # emitted-so-far records are an alpha-level superset;
        # the exact final delta is unknown, so keep the superset (k=0 tags
        # the correction as underived)
        return session._partial_mine_report(
            dataset, [ph1, ph2], pipeline="fused23", query_tag="significant",
            alpha=alpha, statistic=statistic, t0=t0, min_sup=min_sup,
            lam=ph1.lam_final, delta=alpha, filter_host=True,
        )
    h2 = ph2.output.hist2d
    sups_grid = np.arange(n + 1)
    mask = (h2 > 0) & (sups_grid[:, None] >= min_sup)
    k = int(h2[mask].sum())
    delta = alpha / max(k, 1)
    xs, ns = np.nonzero(mask)
    pv = stat.pvalue(xs, ns, n, n_pos) if len(xs) else np.zeros(0)
    sig_mask = pv <= delta
    n_sig = int(h2[xs[sig_mask], ns[sig_mask]].sum()) if len(xs) else 0
    # records were emitted at the alpha superset level; exact-filter at delta
    # (root appended iff significant — the 2-D histogram counted it then)
    results = session._build_results(
        dataset, ph2.output, alpha=alpha, min_sup=min_sup, k=k, delta=delta,
        filter_host=True, statistic=statistic,
        records=session._root_record(dataset, ph2.output, statistic, delta,
                                     min_sup),
    )
    return MineReport(
        dataset=dataset.name,
        pipeline="fused23",
        alpha=alpha,
        lambda_final=ph1.lam_final,
        min_sup=min_sup,
        correction_factor=k,
        delta=delta,
        n_significant=n_sig,
        results=results,
        phases=(ph1, ph2),
        wall_s=time.perf_counter() - t0,
        statistic=statistic,
    )


#: First-class LAMP staging registry — selected by
#: `SignificantPatternQuery.pipeline` (and `MinerSession.mine(pipeline=...)`);
#: extend by registering a `(session, dataset, query) -> MineReport` here.
PIPELINES: dict[
    str, Callable[[MinerSession, Dataset, SignificantPatternQuery], MineReport]
] = {
    "three_phase": _pipeline_three_phase,
    "fused23": _pipeline_fused23,
}
