"""MinerSession — compile-once, query-many significant-pattern mining.

The paper's deliverable is a miner that answers queries at scale; the
deployment mode that matters is *repeated* queries.  A session owns the
device mesh and a cache of AOT-compiled BSP programs keyed by

    (mode, shape bucket, resolved RuntimeConfig)

— everything the compiled artifact actually depends on (resolution makes
the key concrete: `kernel_impl="auto"` becomes the backend's kernel and
`sync_period` — the lambda-sync cadence baked into the superstep program —
rides along, so different cadences never collide in the cache).  Statistical
parameters (alpha / min_sup / delta) and the dataset's exact dims enter the
program as runtime arguments, so:

  * phase 2 ("count") and phase 3 ("test"/"count2d") of one query never
    re-trace what phase 1 already traced for a different mode only once each;
  * a repeat query — same dataset, or any dataset in the same bucket —
    replays fully warm programs with **zero** new traces or compiles;
  * `cache_info()` exposes hits/misses and per-program lowering stats
    (compile seconds, cost analysis) for inspection and tests.

Pipelines (`PIPELINES`: "three_phase" | "fused23") are functions over a
session, not free functions that re-enter `mine()` from scratch — they
share the session's packed dataset and warm programs across phases.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable

import numpy as np

import jax

from repro.core import collectives
from repro.core.engine import (
    EngineConfig,
    MineOutput,
    build_phase_program,
    make_phase_args,
    postprocess_phase,
)
from repro.core.fisher import fisher_pvalue
from repro.core.lifeline import build_schedule

from .config import AlgorithmConfig, RuntimeConfig
from .dataset import Dataset, ShapeBucket
from .report import MineReport, PhaseReport

__all__ = ["CacheInfo", "MinerSession", "PIPELINES", "ProgramInfo"]


@dataclass(frozen=True)
class ProgramInfo:
    """Lowering stats for one cached compiled program."""

    mode: str
    bucket: ShapeBucket
    compile_s: float
    calls: int
    flops: float | None    # XLA cost analysis, when the backend reports it


@dataclass(frozen=True)
class CacheInfo:
    """Snapshot of the session's compiled-program cache."""

    hits: int
    misses: int
    programs: tuple[ProgramInfo, ...]

    @property
    def n_programs(self) -> int:
        return len(self.programs)

    def __str__(self) -> str:
        lines = [f"cache: {self.hits} hits / {self.misses} misses, "
                 f"{self.n_programs} compiled programs"]
        for p in self.programs:
            lines.append(
                f"  [{p.mode:8s}] bucket=({p.bucket.transactions}, "
                f"{p.bucket.positives}, {p.bucket.items}) "
                f"compile={p.compile_s:.2f}s calls={p.calls}"
                + (f" flops={p.flops:.3g}" if p.flops is not None else "")
            )
        return "\n".join(lines)


class _Program:
    __slots__ = ("compiled", "compile_s", "flops", "calls")

    def __init__(self, compiled, compile_s: float, flops: float | None):
        self.compiled = compiled
        self.compile_s = compile_s
        self.flops = flops
        self.calls = 0


class MinerSession:
    """A persistent miner: one mesh, one program cache, many queries."""

    def __init__(
        self,
        devices=None,
        *,
        algorithm: AlgorithmConfig | None = None,
        runtime: RuntimeConfig | None = None,
    ):
        self.devices = jax.devices() if devices is None else list(devices)
        self.n_devices = len(self.devices)
        self.mesh = collectives.make_miner_mesh(self.devices)
        self.algorithm = algorithm or AlgorithmConfig()
        self.runtime = runtime or RuntimeConfig()
        self._programs: dict[tuple, _Program] = {}
        self._schedules: dict[tuple[int, int], object] = {}
        self._hits = 0
        self._misses = 0

    # -------------------------------------------------------------- programs
    def _schedule(self, cfg: EngineConfig):
        key = (cfg.n_random_perms, cfg.seed)
        if key not in self._schedules:
            self._schedules[key] = build_schedule(self.n_devices, *key)
        return self._schedules[key]

    def _program(self, mode: str, bucket: ShapeBucket, cfg: EngineConfig, args):
        """Fetch-or-compile the phase program for (mode, bucket, cfg)."""
        key = (mode, bucket, cfg)
        entry = self._programs.get(key)
        if entry is not None:
            self._hits += 1
            return entry, True
        self._misses += 1
        shardy = build_phase_program(
            (bucket.transactions, bucket.positives, bucket.items),
            cfg=cfg, schedule=self._schedule(cfg), mesh=self.mesh, mode=mode,
        )
        t0 = time.perf_counter()
        compiled = jax.jit(shardy).lower(*args).compile()
        compile_s = time.perf_counter() - t0
        try:
            cost = collectives.normalize_cost_analysis(compiled.cost_analysis())
            flops = float(cost["flops"]) if "flops" in cost else None
        except Exception:  # backend without cost analysis
            flops = None
        entry = _Program(compiled, compile_s, flops)
        self._programs[key] = entry
        return entry, False

    def cache_info(self) -> CacheInfo:
        return CacheInfo(
            hits=self._hits,
            misses=self._misses,
            programs=tuple(
                ProgramInfo(mode=key[0], bucket=key[1], compile_s=p.compile_s,
                            calls=p.calls, flops=p.flops)
                for key, p in self._programs.items()
            ),
        )

    # ---------------------------------------------------------------- phases
    def run_phase(
        self,
        dataset: Dataset,
        mode: str,
        *,
        min_sup: int = 1,
        delta: float = 0.0,
        alpha: float | None = None,
    ) -> PhaseReport:
        """One engine pass on a warm (or newly compiled) program."""
        assert mode in ("lamp1", "count", "test", "count2d")
        t0 = time.perf_counter()
        alpha = self.algorithm.alpha if alpha is None else alpha
        cfg = self.runtime.resolve(dataset.bucket, self.n_devices)
        args, ctx = make_phase_args(
            dataset.packed, n_proc=self.n_devices, cfg=cfg, mode=mode,
            alpha=alpha, min_sup=min_sup, delta=delta,
        )
        entry, hit = self._program(mode, dataset.bucket, cfg, args)
        raw = entry.compiled(*args)
        out = postprocess_phase(
            raw, packed=dataset.packed, n_proc=self.n_devices, cfg=cfg,
            mode=mode, thr=ctx["thr"], start_sup=ctx["start_sup"], delta=delta,
        )
        entry.calls += 1
        return PhaseReport(
            mode=mode,
            wall_s=time.perf_counter() - t0,
            compile_s=0.0 if hit else entry.compile_s,
            cache_hit=hit,
            supersteps=out.supersteps,
            lam_final=out.lam_final,
            n_nodes=int(out.stats["popped"].sum()),
            steals=int(out.stats["steals_got"].sum()),
            # gated rounds actually executed: per-miner counters are all
            # equal (the census is replicated), so read miner 0's
            steal_rounds=int(out.stats["steal_rounds"][0]),
            emit_dropped=out.emit_dropped,
            output=out,
        )

    # --------------------------------------------------------------- queries
    def mine(
        self,
        dataset: Dataset,
        *,
        alpha: float | None = None,
        pipeline: str | None = None,
    ) -> MineReport:
        """Answer one significant-pattern query (full LAMP staging)."""
        pipeline = self.algorithm.pipeline if pipeline is None else pipeline
        try:
            run = PIPELINES[pipeline]
        except KeyError:
            raise ValueError(
                f"unknown pipeline {pipeline!r}; available: {sorted(PIPELINES)}"
            ) from None
        return run(self, dataset, self.algorithm.alpha if alpha is None else alpha)

    def _build_results(self, dataset: Dataset, phase_out: MineOutput, *,
                       alpha, min_sup, k, delta, filter_host):
        """Emitted records of one phase output -> ResultSet (repro.results)."""
        from repro.results import build_result_set

        # the dataset was packed exactly once; reconstruction reuses its bits
        return build_result_set(
            phase_out.sig_occ, phase_out.sig_sup, phase_out.sig_pos_sup,
            dataset.packed.db_bits,
            n=dataset.n_transactions, n_pos=dataset.n_pos, alpha=alpha,
            min_sup=min_sup, correction_factor=k, delta=delta,
            filter_host=filter_host, dropped=phase_out.emit_dropped,
            item_names=dataset.item_names,
        )


# -------------------------------------------------------------- pipelines
def _pipeline_three_phase(session: MinerSession, dataset: Dataset,
                          alpha: float) -> MineReport:
    """The paper's §3.3 staging: lamp1 -> count -> test (three traversals)."""
    t0 = time.perf_counter()
    ph1 = session.run_phase(dataset, "lamp1", alpha=alpha)
    min_sup = max(ph1.lam_final - 1, session.algorithm.min_sup_floor)

    # phase 2: exact closed-set count at min_sup
    ph2 = session.run_phase(dataset, "count", min_sup=min_sup, alpha=alpha)
    k = int(ph2.output.hist[min_sup:].sum())
    delta = alpha / max(k, 1)
    # phase 3: significance testing at delta
    ph3 = session.run_phase(dataset, "test", min_sup=min_sup, delta=delta,
                            alpha=alpha)
    # the device already filtered at delta; reconstruct + exact stats only
    results = session._build_results(
        dataset, ph3.output, alpha=alpha, min_sup=min_sup, k=k, delta=delta,
        filter_host=False,
    )
    return MineReport(
        dataset=dataset.name,
        pipeline="three_phase",
        alpha=alpha,
        lambda_final=ph1.lam_final,
        min_sup=min_sup,
        correction_factor=k,
        delta=delta,
        n_significant=ph3.output.sig_count,
        results=results,
        phases=(ph1, ph2, ph3),
        wall_s=time.perf_counter() - t0,
    )


def _pipeline_fused23(session: MinerSession, dataset: Dataset,
                      alpha: float) -> MineReport:
    """Beyond-paper: lamp1 -> count2d, two traversals.

    One enumeration pass builds a 2-D (support x pos-support) histogram;
    P-values depend only on that pair, so the correction factor AND the
    significant count both fall out of the histogram — the third engine pass
    disappears entirely.  The same pass emits alpha-level pattern records
    (delta <= alpha always), which the host filters down to the exact final
    delta, so pattern identities survive the fusion too (DESIGN.md §4).
    """
    t0 = time.perf_counter()
    ph1 = session.run_phase(dataset, "lamp1", alpha=alpha)
    min_sup = max(ph1.lam_final - 1, session.algorithm.min_sup_floor)

    n, n_pos = dataset.n_transactions, dataset.n_pos
    ph2 = session.run_phase(dataset, "count2d", min_sup=min_sup, delta=alpha,
                            alpha=alpha)
    h2 = ph2.output.hist2d
    sups_grid = np.arange(n + 1)
    mask = (h2 > 0) & (sups_grid[:, None] >= min_sup)
    k = int(h2[mask].sum())
    delta = alpha / max(k, 1)
    xs, ns = np.nonzero(mask)
    pv = fisher_pvalue(xs, ns, n, n_pos) if len(xs) else np.zeros(0)
    sig_mask = pv <= delta
    n_sig = int(h2[xs[sig_mask], ns[sig_mask]].sum()) if len(xs) else 0
    # records were emitted at the alpha superset level; exact-filter at delta
    results = session._build_results(
        dataset, ph2.output, alpha=alpha, min_sup=min_sup, k=k, delta=delta,
        filter_host=True,
    )
    return MineReport(
        dataset=dataset.name,
        pipeline="fused23",
        alpha=alpha,
        lambda_final=ph1.lam_final,
        min_sup=min_sup,
        correction_factor=k,
        delta=delta,
        n_significant=n_sig,
        results=results,
        phases=(ph1, ph2),
        wall_s=time.perf_counter() - t0,
    )


#: First-class LAMP pipeline registry — select with
#: `MinerSession.mine(ds, pipeline=<name>)`; extend by registering here.
PIPELINES: dict[str, Callable[[MinerSession, Dataset, float], MineReport]] = {
    "three_phase": _pipeline_three_phase,
    "fused23": _pipeline_fused23,
}
