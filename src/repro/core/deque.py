"""Circular work deque over fixed [CAP, ...] storage (DESIGN.md §6).

The per-miner stack used to be a flat array with slot 0 pinned to the
physical bottom: every steal round removed the donated bottom-k by a
full-stack ``jnp.take`` shift of the 2 payload arrays — O(stack_cap * W)
memory traffic per round whether or not anyone was hungry.  The deque keeps
the same fixed storage but addresses it circularly through two scalars:

    head  physical row of the logical bottom (slot 0, the oldest node)
    sp    live node count; logical slot i lives at (head + i) % cap

Expansion pops and pushes at the logical *top* by pointer arithmetic; a
steal donates the logical *bottom-k* (oldest, shallowest subtrees) with
O(steal_max) gathers and advances ``head`` — no shift ever happens.  The
visible semantics (pop order, donated node identity) are exactly the old
shift-stack's; tests/test_deque_stack.py property-checks that equivalence
against a NumPy oracle.

All helpers are pure index/pointer arithmetic on jnp (or np) scalars and
work inside compiled superstep bodies; the payload arrays themselves are
gathered/scattered by the callers (core/expand.py, core/steal.py).
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "advance_head",
    "bottom_indices",
    "push_positions",
    "top_indices",
]


def top_indices(head, sp, rows, cap: int):
    """Physical rows of the top ``len(rows)`` nodes, top-first.

    ``rows`` is an offset vector (0 = current top).  Offsets past the bottom
    wrap to in-range garbage rows; callers mask with ``rows < sp``.
    """
    return (head + sp - 1 - rows) % cap


def bottom_indices(head, rows, cap: int):
    """Physical rows of the bottom ``len(rows)`` nodes, bottom-first.

    Used both to gather a donation's payload and to scatter a received one
    (a receiver is empty, so its bottom region is free).
    """
    return (head + rows) % cap


def push_positions(head, base_sp, offsets, valid, cap: int):
    """Scatter positions for pushing ``offsets``-th new nodes above ``base_sp``.

    Returns ``(pos, overflow)``: physical rows for valid, in-capacity pushes
    and ``cap`` (out of bounds — dropped by ``.at[].set(mode="drop")``) for
    the rest; ``overflow`` is True when any valid push didn't fit.
    """
    logical = base_sp + offsets
    fits = logical < cap
    overflow = jnp.any(valid & ~fits)
    pos = jnp.where(valid & fits, (head + logical) % cap, cap)
    return pos, overflow


def advance_head(head, k, cap: int):
    """Consume the bottom-k nodes (a donation): slide the bottom pointer."""
    return (head + k) % cap
