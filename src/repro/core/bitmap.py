"""Packed-bitmap transaction database (paper §4.6).

The paper's target is a *dense* database with relatively few transactions
(GWAS mutation matrices: 10k-250k items x ~300-700 individuals).  It explicitly
drops database-reduction and counts supports with the POPCNT instruction on
64-bit registers.  The TPU adaptation keeps the same representation and widens
the word-parallel popcount to (8,128) vector registers:

    db_bits[j, w]   uint32 word w of item j's transaction column
    occ[..., w]     occurrence bitmap of an itemset (node payload)
    support(occ)    = sum_w popcount(occ[w])
    supports vs DB  = popcount-GEMM: S[b, j] = sum_w popcount(occ[b, w] & db[j, w])

`supports_ref` here is the pure-jnp oracle; the Pallas kernel in
repro.kernels.support_count implements the same contraction with VMEM tiling.

Item-tiled layout (DESIGN.md §8): at paper scale the item axis is the one
that grows (Table 1 tops out at 250k items against a few hundred
transactions), so the database is carried as a `BitmapLayout` — `db_bits`
reshaped into item-axis tiles `[T, m_tile, W]` with an all-zero padded tail.
One array threads through the whole engine (the old `db_mw`/`db_wm` twin
arrays are gone); the support-count op sweeps it tile by tile so the
per-superstep working set is `[B, m_tile]`-sized regardless of total items,
and the flat `[m_pad, W]` view is a free reshape for host-side code
(root dealing, closure reconstruction).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

WORD_BITS = 32

#: default item-tile width: the support-count kernel sweep processes at most
#: this many item columns at once.  4096 lanes keeps a [B=16, m_tile] int32
#: output block + a [m_tile, W] tile comfortably inside one TPU core's VMEM
#: at every Table-1 word width, while one tile covers every toy problem
#: (m <= 4096 stays single-tile: zero layout overhead, legacy shapes).
DEFAULT_ITEM_TILE = 4096

__all__ = [
    "WORD_BITS",
    "DEFAULT_ITEM_TILE",
    "BitmapLayout",
    "item_tiling",
    "num_words",
    "pack_db",
    "unpack_occ",
    "full_occ",
    "popcount_np",
    "support_np",
    "supports_np",
    "support_jnp",
    "supports_ref",
]


def num_words(n_transactions: int) -> int:
    return (n_transactions + WORD_BITS - 1) // WORD_BITS


def item_tiling(m: int, max_tile: int = DEFAULT_ITEM_TILE) -> tuple[int, int]:
    """(m_pad, m_tile) for an m-item axis: single tile for small m (zero
    padding overhead, legacy program shapes), else m rounded up to a
    multiple of `max_tile` (padded tail items are all-zero columns)."""
    if m <= max_tile:
        return m, m
    n_tiles = -(-m // max_tile)
    return n_tiles * max_tile, max_tile


@dataclass(frozen=True)
class BitmapLayout:
    """Item-axis-tiled packed database: `tiles[t, r, w]` is word w of item
    `t * m_tile + r`.  The canonical device carrier of the transaction
    database (DESIGN.md §8): one array replaces the old item-major /
    word-major twin copies, and every support-count path (engine expand,
    host reconstruction, benchmarks) sweeps it through the same kernel
    dispatch in `repro.kernels.support_count.ops`.

    Items at positions >= `m` (the padded tail of the last tile) are
    all-zero columns: zero support, never accepted, counted, emitted, or
    extended — results are invariant to the tile padding, exactly like
    bucket padding (DESIGN.md §5).
    """

    tiles: np.ndarray  # [T, m_tile, W] uint32, read-only
    m: int             # actual item count (tail beyond m is zero padding)

    def __post_init__(self):
        if self.tiles.ndim != 3:
            raise ValueError(f"tiles must be [T, m_tile, W], got {self.tiles.shape}")
        if not (0 <= self.m <= self.m_pad):
            raise ValueError(f"m={self.m} outside [0, {self.m_pad}]")

    @property
    def n_tiles(self) -> int:
        return self.tiles.shape[0]

    @property
    def m_tile(self) -> int:
        return self.tiles.shape[1]

    @property
    def w(self) -> int:
        return self.tiles.shape[2]

    @property
    def m_pad(self) -> int:
        return self.tiles.shape[0] * self.tiles.shape[1]

    @property
    def flat(self) -> np.ndarray:
        """[m_pad, W] item-major view (a reshape — no copy)."""
        return self.tiles.reshape(self.m_pad, self.w)

    def tail_mask(self) -> np.ndarray:
        """[m_pad] bool: True for real items, False for the padded tail."""
        return np.arange(self.m_pad) < self.m

    @classmethod
    def from_db_bits(
        cls,
        db_bits: np.ndarray,
        *,
        m: int | None = None,
        m_tile: int | None = None,
        m_pad: int | None = None,
    ) -> "BitmapLayout":
        """Tile an item-major [M, W] packed database.

        `m` is the actual item count (default: all M rows are real items);
        `m_pad`/`m_tile` fix the padded extent and tile width (defaults via
        `item_tiling`).  `m_pad` must be a multiple of `m_tile`.
        """
        db_bits = np.asarray(db_bits, dtype=np.uint32)
        rows, w = db_bits.shape
        m = rows if m is None else m
        if m_pad is None and m_tile is None:
            m_pad, m_tile = item_tiling(max(rows, 1))
        elif m_tile is None:
            m_pad2, m_tile = item_tiling(m_pad)
            if m_pad2 != m_pad:
                raise ValueError(
                    f"m_pad={m_pad} is not a multiple of the default tile "
                    f"{m_tile}; pass m_tile explicitly"
                )
        elif m_pad is None:
            m_pad = -(-max(rows, 1) // m_tile) * m_tile
        if m_pad % m_tile != 0:
            raise ValueError(f"m_pad={m_pad} not a multiple of m_tile={m_tile}")
        if m_pad < rows:
            raise ValueError(f"m_pad={m_pad} smaller than db_bits rows={rows}")
        tiles = np.zeros((m_pad // m_tile, m_tile, w), dtype=np.uint32)
        tiles.reshape(m_pad, w)[:rows] = db_bits
        tiles.flags.writeable = False
        return cls(tiles=tiles, m=m)


def pack_db(db_bool: np.ndarray) -> np.ndarray:
    """[N_transactions, M_items] bool -> [M, W] uint32 (bit t of word w = transaction 32w+t)."""
    db_bool = np.asarray(db_bool, dtype=bool)
    n, m = db_bool.shape
    w = num_words(n)
    padded = np.zeros((w * WORD_BITS, m), dtype=bool)
    padded[:n] = db_bool
    # bitorder='little': bit k of byte corresponds to row (byte*8 + k)
    bytes_ = np.packbits(padded, axis=0, bitorder="little")  # [W*4, M]
    words = bytes_.reshape(w, 4, m).astype(np.uint32)
    out = words[:, 0] | (words[:, 1] << 8) | (words[:, 2] << 16) | (words[:, 3] << 24)
    return np.ascontiguousarray(out.T)  # [M, W]


def unpack_occ(occ: np.ndarray, n_transactions: int) -> np.ndarray:
    """[..., W] uint32 -> [..., N] bool."""
    occ = np.asarray(occ, dtype=np.uint32)
    b0 = occ & 0xFF
    b1 = (occ >> 8) & 0xFF
    b2 = (occ >> 16) & 0xFF
    b3 = (occ >> 24) & 0xFF
    bytes_ = np.stack([b0, b1, b2, b3], axis=-1).astype(np.uint8)  # [..., W, 4]
    bits = np.unpackbits(bytes_, axis=-1, bitorder="little")  # [..., W, 32]
    bits = bits.reshape(*occ.shape[:-1], occ.shape[-1] * WORD_BITS)
    return bits[..., :n_transactions].astype(bool)


def full_occ(n_transactions: int) -> np.ndarray:
    """All-transactions occurrence bitmap with the tail bits zeroed."""
    w = num_words(n_transactions)
    occ = np.full(w, 0xFFFFFFFF, dtype=np.uint32)
    tail = n_transactions % WORD_BITS
    if tail:
        occ[-1] = np.uint32((1 << tail) - 1)
    return occ


# ------------------------------------------------------------------ numpy path
def popcount_np(x: np.ndarray) -> np.ndarray:
    return np.bitwise_count(x)


def support_np(occ: np.ndarray) -> np.ndarray:
    """[..., W] -> [...] int32 popcount sum."""
    return popcount_np(occ).sum(axis=-1).astype(np.int32)


def supports_np(occ: np.ndarray, db_bits: np.ndarray) -> np.ndarray:
    """Popcount-GEMM oracle. occ [..., W], db_bits [M, W] -> [..., M] int32."""
    inter = occ[..., None, :] & db_bits  # [..., M, W]
    return popcount_np(inter).sum(axis=-1).astype(np.int32)


# -------------------------------------------------------------------- jax path
def support_jnp(occ: jax.Array) -> jax.Array:
    return jnp.sum(jax.lax.population_count(occ), axis=-1).astype(jnp.int32)


def supports_ref(occ: jax.Array, db_bits: jax.Array) -> jax.Array:
    """Pure-jnp popcount-GEMM (oracle for the Pallas kernel).

    occ [B, W] uint32, db_bits [M, W] uint32 -> [B, M] int32.
    """
    inter = occ[:, None, :] & db_bits[None, :, :]  # [B, M, W]
    return jnp.sum(jax.lax.population_count(inter), axis=-1).astype(jnp.int32)
