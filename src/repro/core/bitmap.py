"""Packed-bitmap transaction database (paper §4.6).

The paper's target is a *dense* database with relatively few transactions
(GWAS mutation matrices: 10k-250k items x ~300-700 individuals).  It explicitly
drops database-reduction and counts supports with the POPCNT instruction on
64-bit registers.  The TPU adaptation keeps the same representation and widens
the word-parallel popcount to (8,128) vector registers:

    db_bits[j, w]   uint32 word w of item j's transaction column
    occ[..., w]     occurrence bitmap of an itemset (node payload)
    support(occ)    = sum_w popcount(occ[w])
    supports vs DB  = popcount-GEMM: S[b, j] = sum_w popcount(occ[b, w] & db[j, w])

`supports_ref` here is the pure-jnp oracle; the Pallas kernel in
repro.kernels.support_count implements the same contraction with VMEM tiling.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

WORD_BITS = 32

__all__ = [
    "WORD_BITS",
    "num_words",
    "pack_db",
    "unpack_occ",
    "full_occ",
    "popcount_np",
    "support_np",
    "supports_np",
    "support_jnp",
    "supports_ref",
]


def num_words(n_transactions: int) -> int:
    return (n_transactions + WORD_BITS - 1) // WORD_BITS


def pack_db(db_bool: np.ndarray) -> np.ndarray:
    """[N_transactions, M_items] bool -> [M, W] uint32 (bit t of word w = transaction 32w+t)."""
    db_bool = np.asarray(db_bool, dtype=bool)
    n, m = db_bool.shape
    w = num_words(n)
    padded = np.zeros((w * WORD_BITS, m), dtype=bool)
    padded[:n] = db_bool
    # bitorder='little': bit k of byte corresponds to row (byte*8 + k)
    bytes_ = np.packbits(padded, axis=0, bitorder="little")  # [W*4, M]
    words = bytes_.reshape(w, 4, m).astype(np.uint32)
    out = words[:, 0] | (words[:, 1] << 8) | (words[:, 2] << 16) | (words[:, 3] << 24)
    return np.ascontiguousarray(out.T)  # [M, W]


def unpack_occ(occ: np.ndarray, n_transactions: int) -> np.ndarray:
    """[..., W] uint32 -> [..., N] bool."""
    occ = np.asarray(occ, dtype=np.uint32)
    b0 = occ & 0xFF
    b1 = (occ >> 8) & 0xFF
    b2 = (occ >> 16) & 0xFF
    b3 = (occ >> 24) & 0xFF
    bytes_ = np.stack([b0, b1, b2, b3], axis=-1).astype(np.uint8)  # [..., W, 4]
    bits = np.unpackbits(bytes_, axis=-1, bitorder="little")  # [..., W, 32]
    bits = bits.reshape(*occ.shape[:-1], occ.shape[-1] * WORD_BITS)
    return bits[..., :n_transactions].astype(bool)


def full_occ(n_transactions: int) -> np.ndarray:
    """All-transactions occurrence bitmap with the tail bits zeroed."""
    w = num_words(n_transactions)
    occ = np.full(w, 0xFFFFFFFF, dtype=np.uint32)
    tail = n_transactions % WORD_BITS
    if tail:
        occ[-1] = np.uint32((1 << tail) - 1)
    return occ


# ------------------------------------------------------------------ numpy path
def popcount_np(x: np.ndarray) -> np.ndarray:
    return np.bitwise_count(x)


def support_np(occ: np.ndarray) -> np.ndarray:
    """[..., W] -> [...] int32 popcount sum."""
    return popcount_np(occ).sum(axis=-1).astype(np.int32)


def supports_np(occ: np.ndarray, db_bits: np.ndarray) -> np.ndarray:
    """Popcount-GEMM oracle. occ [..., W], db_bits [M, W] -> [..., M] int32."""
    inter = occ[..., None, :] & db_bits  # [..., M, W]
    return popcount_np(inter).sum(axis=-1).astype(np.int32)


# -------------------------------------------------------------------- jax path
def support_jnp(occ: jax.Array) -> jax.Array:
    return jnp.sum(jax.lax.population_count(occ), axis=-1).astype(jnp.int32)


def supports_ref(occ: jax.Array, db_bits: jax.Array) -> jax.Array:
    """Pure-jnp popcount-GEMM (oracle for the Pallas kernel).

    occ [B, W] uint32, db_bits [M, W] uint32 -> [B, M] int32.
    """
    inter = occ[:, None, :] & db_bits[None, :, :]  # [B, M, W]
    return jnp.sum(jax.lax.population_count(inter), axis=-1).astype(jnp.int32)
