"""Distributed termination detection (paper §4.3) — Mattern's time algorithm.

Inside one compiled BSP superstep loop, termination is exact:
`psum(stack_sizes) == 0` at a superstep boundary implies no work and no
in-flight messages (collectives complete before the check).  That removes the
race Mattern's algorithm exists to fix — *within* a pod.

Across pods, the control plane (launch/elastic.py) is asynchronous again:
pod controllers exchange work-summary and steal messages over a slow network
with real in-flight time.  There we use the paper's choice — Mattern's
bounded clock-counter ("time") algorithm on a spanning tree (the paper uses a
ternary tree; so do we).

Each process keeps a logical clock `t`, a message counter `c` (sends minus
receives of *basic* messages), and stamps every basic message with its send
time.  A wave (initiated by the root, propagated down the ternary tree and
accumulated back up) collects (max_clock, sum_counters, any_stale_receive).
The wave at clock T declares termination iff the summed counter is zero AND
no process received a basic message stamped from a *past* wave epoch after
reporting — the "messages crossing the past/future boundary" test.

This module is transport-agnostic: `TerminationDetector` is driven by the pod
controller via callbacks, and the simulated-transport unit tests exercise the
classic false-termination races (message in flight during the wave).
"""

from __future__ import annotations

from dataclasses import dataclass, field

__all__ = ["TerminationDetector", "TernaryTree"]


class TernaryTree:
    """Spanning tree with fan-out 3 over process ids 0..P-1 (paper §4.3)."""

    def __init__(self, n_proc: int, fanout: int = 3):
        self.n = n_proc
        self.fanout = fanout

    def parent(self, i: int) -> int | None:
        return None if i == 0 else (i - 1) // self.fanout

    def children(self, i: int) -> list[int]:
        lo = i * self.fanout + 1
        return [c for c in range(lo, lo + self.fanout) if c < self.n]


@dataclass
class _WaveAccum:
    max_clock: int = 0
    counter_sum: int = 0
    stale: bool = False
    pending: int = 0  # children yet to report


class TerminationDetector:
    """Mattern bounded clock-counter algorithm for one process.

    Basic-message hooks:
      on_basic_send()            -> returns the timestamp to attach
      on_basic_receive(stamp)    -> call with the sender's stamp

    Control-wave driver (host): the root calls start_wave(); control messages
    are returned as (dst, payload) tuples from the handlers and must be
    delivered by the transport; handle_control() processes them.  When a wave
    completes at the root, `terminated` is set if it detected global quiet.
    """

    WAVE_DOWN = "wave_down"
    WAVE_UP = "wave_up"

    def __init__(self, rank: int, tree: TernaryTree, is_idle=lambda: True):
        self.rank = rank
        self.tree = tree
        self.is_idle = is_idle
        self.clock = 0  # logical time = number of waves seen
        self.counter = 0  # basic sends - receives
        self.stale_since_report = False
        self.terminated = False
        self._acc: _WaveAccum | None = None

    # ---- basic message instrumentation (paper: every payload carries a stamp)
    def on_basic_send(self) -> int:
        self.counter += 1
        return self.clock

    def on_basic_receive(self, stamp: int) -> None:
        self.counter -= 1
        # a message stamped before my current epoch crossed the wave boundary
        if stamp < self.clock:
            self.stale_since_report = True

    # ---- control wave
    def start_wave(self):
        assert self.rank == 0, "only the root initiates waves"
        self.clock += 1
        return self._begin_wave(self.clock)

    def _begin_wave(self, wave_clock: int):
        self.clock = max(self.clock, wave_clock)
        self._acc = _WaveAccum(pending=len(self.tree.children(self.rank)))
        out = [
            (c, (self.WAVE_DOWN, wave_clock)) for c in self.tree.children(self.rank)
        ]
        if self._acc.pending == 0:
            out += self._report_up()
        return out

    def _report_up(self):
        acc = self._acc
        assert acc is not None
        acc.max_clock = max(acc.max_clock, self.clock)
        acc.counter_sum += self.counter
        acc.stale = acc.stale or self.stale_since_report or not self.is_idle()
        self.stale_since_report = False
        self._acc = None
        parent = self.tree.parent(self.rank)
        if parent is None:
            # root: wave complete — Mattern's test
            if acc.counter_sum == 0 and not acc.stale:
                self.terminated = True
            return []
        return [(parent, (self.WAVE_UP, (acc.max_clock, acc.counter_sum, acc.stale)))]

    def handle_control(self, payload):
        kind, data = payload
        if kind == self.WAVE_DOWN:
            return self._begin_wave(data)
        if kind == self.WAVE_UP:
            max_clock, counter_sum, stale = data
            acc = self._acc
            assert acc is not None and acc.pending > 0
            acc.max_clock = max(acc.max_clock, max_clock)
            acc.counter_sum += counter_sum
            acc.stale = acc.stale or stale
            acc.pending -= 1
            if acc.pending == 0:
                return self._report_up()
            return []
        raise ValueError(kind)
