"""Back-compat shim — Fisher's exact test moved to `repro.stats.fisher`.

The statistics became pluggable (`repro.stats`: a `TestStatistic` registry
threaded through the engine, the LAMP staging, and the results pipeline),
so the Fisher implementation lives beside its chi-square sibling now.  This
module keeps the historical import path alive; new code should import from
`repro.stats` (functions) or use `repro.stats.get_statistic("fisher")`.
"""

from __future__ import annotations

from repro.stats.fisher import (  # noqa: F401
    FisherExact,
    fisher_pvalue,
    fisher_pvalue_jnp,
    lamp_count_thresholds,
    log_comb,
    min_attainable_pvalue,
    min_attainable_pvalue_jnp,
)

__all__ = [
    "log_comb",
    "fisher_pvalue",
    "min_attainable_pvalue",
    "lamp_count_thresholds",
    "fisher_pvalue_jnp",
    "min_attainable_pvalue_jnp",
]
