"""Superstep phase 2 — STEAL: one lifeline/random work-exchange round.

Hungry miners (empty stack) request along the round's permutation; a victim
donates the bottom half of its stack (oldest/shallowest subtrees), capped at
`steal_max` nodes, via the inverse permutation.  REQUEST/GIVE/REJECT
collapses into *one* collective (DESIGN.md §2/§6); the round schedule
(hypercube lifelines interleaved with frozen random permutations) comes from
core/lifeline.py.

The exchange is engineered around two measured costs, not just bytes:

* **No big arrays in control flow.**  The stacks are circular deques
  (core/deque.py): a donation is an O(steal_max) bottom-k gather plus a
  pointer advance, a reception an O(steal_max) scatter — both run
  unconditionally (k = 0 rows are dropped), so the [stack_cap, W] arrays
  never cross a `lax.switch`/`lax.cond` boundary (branch copies of the full
  stack dwarfed the actual steal traffic in the old shift design).
* **One collective, and only when needed.**  The requester's bit arrives
  via the piggybacked hunger census (a static [rounds, P] victim->requester
  table indexed into the census vector — no REQUEST ppermute), and the
  reply rides a single ppermute of one packed [steal_max, W+5] u32 payload
  (occ | bit-cast meta | k) instead of three.  The whole exchange is gated
  on "anyone hungry" via `lax.cond` over that small payload, so rounds
  where every miner has work move zero steal bytes.

All communication goes through core/collectives.py — this module never
imports a version-sensitive JAX API directly.
"""

from __future__ import annotations

import functools

import numpy as np

import jax.numpy as jnp
from jax import lax

from .collectives import MINERS_AXIS, axis_index, ppermute
from .deque import advance_head, bottom_indices
from .lifeline import LifelineSchedule

__all__ = ["build_steal_round"]


def build_steal_round(schedule: LifelineSchedule, cfg, axis=MINERS_AXIS):
    """Returns steal_round(t, hungry_vec, n_hungry, occ_stack, meta, sp, head)
    -> (occ_stack, meta, sp, head, got, gave, k_given, k_recv).

    `hungry_vec` [P] is the superstep's hunger census (1 per empty miner),
    `n_hungry` its sum; both are replicated psum results, so the `lax.cond`
    gate takes the same branch on every miner.

    `axis` is a single mesh axis name (1-D miners mesh: every round's reply
    ppermutes its *global* pairs over that axis) or the topo-mesh axis tuple
    ("hosts", "local") — then the schedule must be factorized (repro.topo
    hierarchy): each round's reply is one ppermute over just the round's own
    axis, so intra-host rounds never touch the cross-host interconnect.
    The REQUEST side is axis-free either way: it reads the requester's bit
    out of the globally-replicated hunger census.
    """
    T = cfg.steal_max
    cap = cfg.stack_cap
    assert cap >= T, "stack_cap must cover one full steal payload"
    P = schedule.n_proc
    R = schedule.n_rounds
    # req_src[r, i]: the miner whose request reaches victim i in round r
    # (requests travel requester -> victim; at most one per victim, since
    # every round is a permutation), -1 when nobody can request from i.
    req_src = np.full((R, P), -1, np.int32)
    for r, (req_pairs, _rep_pairs) in enumerate(schedule.rounds):
        for s, d in req_pairs:
            req_src[r, d] = s
    req_src = jnp.asarray(req_src)

    if isinstance(axis, tuple):
        if not schedule.factorized:
            raise ValueError(
                "a flat (one-level) schedule cannot run on the 2-D topo mesh: "
                "its rounds do not factorize onto single mesh axes — build a "
                "hierarchical schedule (repro.topo.build_hierarchical_schedule)"
            )
        reply_branches = [
            functools.partial(ppermute, perm=rep, axis_name=round_axis)
            for round_axis, (_req, rep)
            in zip(schedule.round_axes, schedule.axis_rounds)
        ]
    else:
        reply_branches = [
            functools.partial(ppermute, perm=rep, axis_name=axis)
            for (_req, rep) in schedule.rounds
        ]

    def steal_round(t, hungry_vec, n_hungry, occ_stack, meta, sp, head):
        r = t % R
        me = axis_index(axis)
        # REQUEST, with zero traffic: read the requester's hungry bit out of
        # the piggybacked census instead of ppermuting it
        requester = req_src[r, me]
        req_in = jnp.where(requester >= 0,
                           hungry_vec[jnp.clip(requester, 0, P - 1)], 0)
        donate = (req_in > 0) & (sp > 1)
        k = jnp.where(donate, jnp.minimum(sp // 2, T), 0)
        rows = jnp.arange(T)
        src = bottom_indices(head, rows, cap)        # O(steal_max) gather
        pay_mask = rows < k
        pay_occ = jnp.where(pay_mask[:, None], occ_stack[src], 0)
        pay_meta = jnp.where(pay_mask[:, None], meta[src], 0)
        # the donated bottom-k leaves by pointer arithmetic — no stack shift
        head = advance_head(head, k, cap)
        sp = sp - k
        # GIVE/REJECT: one packed [T, W+5] u32 ppermute (occ | meta | k);
        # a zero k column *is* the REJECT.  Gated: no exchange unless
        # someone is actually hungry this superstep.
        packed = jnp.concatenate(
            [
                pay_occ,
                lax.bitcast_convert_type(pay_meta, jnp.uint32),
                jnp.broadcast_to(k.astype(jnp.uint32), (T, 1)),
            ],
            axis=1,
        )
        recv = lax.cond(
            n_hungry > 0,
            lambda p: lax.switch(r, reply_branches, p),
            jnp.zeros_like,
            packed,
        )
        w = occ_stack.shape[-1]
        recv_k = lax.bitcast_convert_type(recv[0, -1], jnp.int32)
        got = recv_k > 0  # only ever true for requesters (they had sp == 0)
        # a receiver is empty, so its bottom may live anywhere: pin it to
        # physical row 0 and write one static [0:T) slice — a single
        # dynamic-update-slice instead of a T-row scatter (identity rewrite
        # on every non-receiver, since wmask is all-False there)
        head = jnp.where(got, 0, head)
        wmask = (rows < recv_k)[:, None]
        occ_stack = occ_stack.at[:T].set(
            jnp.where(wmask, recv[:, :w], occ_stack[:T])
        )
        meta = meta.at[:T].set(
            jnp.where(wmask, lax.bitcast_convert_type(recv[:, w:-1], jnp.int32),
                      meta[:T])
        )
        sp = jnp.where(got, recv_k, sp)
        return (occ_stack, meta, sp, head, got.astype(jnp.int32),
                donate.astype(jnp.int32), k, jnp.where(got, recv_k, 0))

    return steal_round
