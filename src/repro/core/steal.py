"""Superstep phase 2 — STEAL: one lifeline/random work-exchange round.

Hungry miners (empty stack) send a request bit along the round's permutation;
a victim donates the bottom half of its stack (oldest/shallowest subtrees),
capped at `steal_max` nodes, via the inverse permutation.  REQUEST/GIVE/
REJECT collapses into one paired ppermute exchange (DESIGN.md §2); the round
schedule (hypercube lifelines interleaved with frozen random permutations)
comes from core/lifeline.py.

All communication goes through core/collectives.py — this module never
imports a version-sensitive JAX API directly.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
from jax import lax

from .collectives import MINERS_AXIS, ppermute
from .lifeline import LifelineSchedule

__all__ = ["build_steal_round"]


def build_steal_round(schedule: LifelineSchedule, cfg, axis: str = MINERS_AXIS):
    """Returns steal_round(t, occ_stack, meta, sp)
    -> (occ_stack, meta, sp, got, gave, k_given)."""
    T = cfg.steal_max
    cap = cfg.stack_cap

    def one_round(req_pairs, rep_pairs, occ_stack, meta, sp):
        hungry = (sp == 0).astype(jnp.int32)
        req_in = ppermute(hungry, req_pairs, axis)
        donate = (req_in > 0) & (sp > 1)
        k = jnp.where(donate, jnp.minimum(sp // 2, T), 0)
        rows = jnp.arange(T)
        pay_mask = rows < k
        pay_occ = jnp.where(pay_mask[:, None], occ_stack[:T], 0)
        pay_meta = jnp.where(pay_mask[:, None], meta[:T], 0)
        # remove donated bottom-k, shift stack down
        idx = jnp.arange(cap) + k
        occ_stack = jnp.take(occ_stack, idx, axis=0, mode="fill", fill_value=0)
        meta = jnp.take(meta, idx, axis=0, mode="fill", fill_value=0)
        sp = sp - k
        # reply to (the only possible) requester
        recv_k = ppermute(k, rep_pairs, axis)
        recv_occ = ppermute(pay_occ, rep_pairs, axis)
        recv_meta = ppermute(pay_meta, rep_pairs, axis)
        got = recv_k > 0  # only ever true for requesters (they had sp == 0)
        wmask = (rows < recv_k)[:, None]
        occ_stack = occ_stack.at[:T].set(jnp.where(wmask, recv_occ, occ_stack[:T]))
        meta = meta.at[:T].set(jnp.where(wmask, recv_meta, meta[:T]))
        sp = jnp.where(got, recv_k, sp)
        return occ_stack, meta, sp, got.astype(jnp.int32), donate.astype(jnp.int32), k

    branches = [
        functools.partial(one_round, req, rep) for (req, rep) in schedule.rounds
    ]

    def steal_round(t, occ_stack, meta, sp):
        return lax.switch(t % schedule.n_rounds, branches, occ_stack, meta, sp)

    return steal_round
