"""Distributed BSP miner: LCM+LAMP with lifeline work stealing (paper §4).

One logical miner per device.  The whole search runs as a single compiled
`shard_map` program over a 1-D mesh axis "miners"; each superstep
(`lax.while_loop` body) is a pipeline of three phase modules:

  1. EXPAND   core/expand.py — pop up to `expand_batch` nodes; one
              popcount-GEMM gives every extension's support; deferred-PPC
              validation, closed-set counting, child generation (core/lcm.py
              documents the deferred-PPC scheme).
  2. STEAL    core/steal.py — one lifeline/random exchange round over the
              schedule from core/lifeline.py; REQUEST/GIVE/REJECT collapses
              into one paired ppermute exchange (DESIGN.md §2).
  3. GLOBAL   core/global_sync.py — psum the support histogram -> recompute
              lambda (paper §4.4's piggyback; staleness only costs work),
              psum stack sizes -> exact BSP termination test (paper §4.3's
              DTD is only needed on the async host plane).

This module holds only the config, the while-loop driver that wires the
phases together, and the host-side pre/postprocess; every version-sensitive
JAX API (shard_map, collectives, mesh) lives in core/collectives.py.

Node payload (fixed size, steal-friendly):  occ [W]u32, core i32, pc i32,
sup i32, flags i32   (flags bit0: "resume" node — already counted, continues
child generation past the per-superstep push cap).

Modes:
  lamp1   dynamic lambda by support increase  -> lambda_final
  count   static min_sup                      -> k = CS(min_sup)
  test    static min_sup + delta              -> #significant + pattern records
  count2d static min_sup (+delta=alpha)       -> 2-D (sup x pos-sup) histogram
                                                 + alpha-level pattern records

Pattern records (modes "test"/"count2d", DESIGN.md §4): each significant node
appends (occ [W]u32, core, sup, pos_sup) to a fixed out_cap buffer — the same
dense payload shape as stack nodes — and repro.results reconstructs the
closure itemsets host-side; overflowed emissions are counted (emit_dropped)
and surfaced as a RuntimeWarning from mine().

LAMP pipelines (`lamp_distributed(..., pipeline=...)`, registry PIPELINES):
  three_phase   the paper's §3.3 staging: lamp1 -> count -> test
  fused23       beyond-paper: lamp1 -> count2d; phases 2+3 fall out of the
                2-D histogram, saving one full traversal
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from . import collectives
from .bitmap import full_occ, num_words, pack_db, supports_np
from .collectives import MINERS_AXIS
from .expand import build_expand
from .fisher import lamp_count_thresholds
from .global_sync import build_global_sync, recompute_lambda
from .lifeline import LifelineSchedule, build_schedule
from .steal import build_steal_round

INT_MAX = np.int32(2**31 - 1)

STAT_NAMES = (
    "popped", "rejected", "closed", "pushed", "steals_got", "gives",
    "idle_steps", "supersteps", "overflow", "stolen_nodes", "emit_dropped",
)
_NSTAT = len(STAT_NAMES)


@dataclass(frozen=True)
class EngineConfig:
    expand_batch: int = 16         # B: nodes popped per device per superstep
    stack_cap: int = 8192          # CAP
    steal_max: int = 256           # T: max nodes per GIVE
    push_cap: int = 1024           # C: max child pushes per superstep
    out_cap: int = 1024            # significant-sample buffer (mode="test")
    max_steps: int = 100_000
    n_random_perms: int = 4
    seed: int = 0
    steal_enabled: bool = True     # False = the paper's "naive approach" (§5.4)
    kernel_impl: str = "ref"       # "ref" | "pallas" (TPU) | "pallas_interpret"
    trace_cap: int = 0             # >0: record popped-per-superstep [trace_cap]


@dataclass
class MineOutput:
    hist: np.ndarray               # [N+2] global closed-set support histogram
    lam_final: int
    supersteps: int
    stats: dict[str, np.ndarray]   # per-device counters [P]
    sig_count: int = 0             # mode="test"
    sig_sup: np.ndarray | None = None
    sig_pos_sup: np.ndarray | None = None
    trace: np.ndarray | None = None  # [P, trace_cap] popped per superstep
    hist2d: np.ndarray | None = None  # [N+1, Npos+1] (mode="count2d")
    # emitted pattern records (modes "test"/"count2d"; DESIGN.md §4):
    sig_occ: np.ndarray | None = None   # [K, W]u32 occurrence bitmaps
    sig_core: np.ndarray | None = None  # [K] core item of the emitting node
    emit_dropped: int = 0          # records lost to out_cap saturation
    db_bits: np.ndarray | None = None  # [M, W]u32 packed DB (reused downstream)


def _thresholds_int(n: int, n_pos: int, alpha: float) -> np.ndarray:
    thr = lamp_count_thresholds(n, n_pos, alpha)
    out = np.minimum(np.floor(thr), float(INT_MAX)).astype(np.int64)
    out = out.astype(np.int32)
    out[0] = INT_MAX  # bucket 0 never drives lambda
    out[np.isinf(thr)] = INT_MAX
    return out


def preprocess(db_bool: np.ndarray, n_proc: int, cfg: EngineConfig, min_sup: int = 1):
    """Paper §4.5: expand the root on the host, deal depth-1 nodes round-robin.

    Returns (db_bits [M,W], init_occ [P,CAP,W], init_meta [P,CAP,4],
             init_sp [P], root_support).
    """
    db_bool = np.asarray(db_bool, dtype=bool)
    n, m = db_bool.shape
    w = num_words(n)
    db_bits = pack_db(db_bool)
    occ0 = full_occ(n)
    s = supports_np(occ0, db_bits)
    in_clo = s == n
    cand = np.flatnonzero((~in_clo) & (s >= max(1, min_sup)))
    clo_cum = np.concatenate([[0], np.cumsum(in_clo)])  # clo_cum[e] = |clo ∩ [0,e)|

    cap = cfg.stack_cap
    init_occ = np.zeros((n_proc, cap, w), dtype=np.uint32)
    init_meta = np.zeros((n_proc, cap, 4), dtype=np.int32)
    init_sp = np.zeros(n_proc, dtype=np.int32)
    for e in cand:
        p = int(e) % n_proc  # the paper's  i mod P = p_i  assignment
        sp = init_sp[p]
        assert sp < cap, "stack_cap too small for depth-1 preprocess"
        init_occ[p, sp] = occ0 & db_bits[e]
        init_meta[p, sp] = (e, clo_cum[e], s[e], 0)
        init_sp[p] = sp + 1
    return db_bits, init_occ, init_meta, init_sp, n


def build_mine_step(
    *, n: int, n_pos: int, m: int, cfg: EngineConfig,
    schedule: LifelineSchedule, mode: str, axis: str = MINERS_AXIS,
):
    """Wire the superstep phases into the per-device BSP program body."""
    NB = n + 2
    NB2 = (n + 1) * (n_pos + 1) if mode == "count2d" else 1
    expand = build_expand(n=n, n_pos=n_pos, m=m, cfg=cfg, mode=mode)
    steal_round = build_steal_round(schedule, cfg, axis)
    global_sync = build_global_sync(nb=NB, mode=mode, axis=axis)

    def body(carry, db_mw, db_wm, pos_mask, thr, delta):
        (occ_stack, meta, sp, hist, hist2d, lam, t, stats, out_occ, out_meta,
         out_ptr, n_sig, trace, _work) = carry
        popped_before = stats[0]
        (occ_stack, meta, sp, hist, hist2d, stats, out_occ, out_meta, out_ptr,
         sig_cnt) = expand(
            occ_stack, meta, sp, hist, hist2d, lam, stats, db_mw, db_wm,
            pos_mask, out_occ, out_meta, out_ptr, delta,
        )
        if cfg.trace_cap:
            trace = trace.at[jnp.minimum(t, cfg.trace_cap - 1)].add(
                stats[0] - popped_before
            )
        n_sig = n_sig + sig_cnt
        if cfg.steal_enabled:
            occ_stack, meta, sp, got, gave, k_given = steal_round(t, occ_stack, meta, sp)
            stats = stats.at[4].add(got)
            stats = stats.at[5].add(gave)
            stats = stats.at[9].add(k_given)
        stats = stats.at[6].add((sp == 0).astype(jnp.int32))
        stats = stats.at[7].add(1)

        lam, work = global_sync(hist, sp, lam, thr)
        return (occ_stack, meta, sp, hist, hist2d, lam, t + 1, stats, out_occ,
                out_meta, out_ptr, n_sig, trace, work)

    def program(init_occ, init_meta, init_sp, db_mw, db_wm, pos_mask, thr,
                lam0, delta):
        # per-device views arrive with a leading length-1 shard axis
        occ_stack = init_occ[0]
        meta = init_meta[0]
        sp = init_sp[0]
        w = occ_stack.shape[-1]
        hist = jnp.zeros(NB, jnp.int32)
        hist2d = jnp.zeros(NB2, jnp.int32)
        stats = jnp.zeros(_NSTAT, jnp.int32)
        out_occ = jnp.zeros((cfg.out_cap, w), jnp.uint32)
        out_meta = jnp.zeros((cfg.out_cap, 3), jnp.int32)
        out_ptr = jnp.int32(0)
        n_sig = jnp.int32(0)
        t = jnp.int32(0)
        trace = jnp.zeros(max(cfg.trace_cap, 1), jnp.int32)

        def cond_fn(carry):
            (_occ, _meta, _sp, _hist, _hist2d, _lam, t, _stats, _out_occ,
             _out_meta, _out_ptr, _n_sig, _trace, work) = carry
            # work was psum'd at the previous superstep boundary:
            return (work > 0) & (t < cfg.max_steps)  # exact BSP termination

        work0 = collectives.psum(sp, axis)
        carry = (occ_stack, meta, sp, hist, hist2d, lam0, t, stats, out_occ,
                 out_meta, out_ptr, n_sig, trace, work0)
        carry = lax.while_loop(
            cond_fn, lambda c: body(c, db_mw, db_wm, pos_mask, thr, delta), carry
        )
        (_, _, _, hist, hist2d, lam, t, stats, out_occ, out_meta, out_ptr,
         n_sig, trace, _) = carry
        g_hist = collectives.psum(hist, axis)
        g_hist2d = collectives.psum(hist2d, axis)  # once, at termination — not per step
        g_sig = collectives.psum(n_sig, axis)
        return (
            g_hist, lam, t, stats[None], out_occ[None], out_meta[None],
            out_ptr[None], g_sig, trace[None], g_hist2d,
        )

    return program


def mine(
    db_bool: np.ndarray,
    labels: np.ndarray | None = None,
    *,
    mode: str = "lamp1",
    alpha: float = 0.05,
    min_sup: int = 1,
    delta: float = 0.0,
    cfg: EngineConfig = EngineConfig(),
    devices=None,
) -> MineOutput:
    """Run one engine pass over all (or the given) local devices."""
    assert mode in ("lamp1", "count", "test", "count2d")
    db_bool = np.asarray(db_bool, dtype=bool)
    n, m = db_bool.shape
    w = num_words(n)
    if devices is None:
        devices = jax.devices()
    n_proc = len(devices)
    mesh = collectives.make_miner_mesh(devices)
    schedule = build_schedule(n_proc, cfg.n_random_perms, cfg.seed)

    if labels is not None:
        labels = np.asarray(labels, dtype=bool)
        n_pos = int(labels.sum())
        pos_mask_bits = pack_db(labels[:, None])[0]  # [W]
    else:
        n_pos = max(1, n // 2)
        pos_mask_bits = np.zeros(w, dtype=np.uint32)

    start_sup = min_sup if mode != "lamp1" else 1
    db_bits, init_occ, init_meta, init_sp, root_sup = preprocess(
        db_bool, n_proc, cfg, start_sup
    )
    thr = _thresholds_int(n, n_pos, alpha)

    program = build_mine_step(
        n=n, n_pos=n_pos, m=m, cfg=cfg, schedule=schedule, mode=mode
    )
    shardy = collectives.shard_map(
        program,
        mesh=mesh,
        in_specs=(
            P(MINERS_AXIS), P(MINERS_AXIS), P(MINERS_AXIS),  # stacks
            P(), P(), P(), P(),  # db_mw, db_wm, pos_mask, thr
            P(), P(),  # lam0, delta
        ),
        out_specs=(P(), P(), P(), P(MINERS_AXIS), P(MINERS_AXIS),
                   P(MINERS_AXIS), P(MINERS_AXIS), P(), P(MINERS_AXIS), P()),
    )
    lam0 = np.int32(start_sup)
    out = jax.jit(shardy)(
        init_occ, init_meta, init_sp,
        db_bits, np.ascontiguousarray(db_bits.T), pos_mask_bits, thr,
        lam0, np.float32(delta),
    )
    (g_hist, lam, t, stats, out_occ, out_meta, out_ptr, g_sig, trace,
     g_hist2d) = jax.tree.map(np.asarray, out)
    # count the root closed set (clo of the empty itemset), support = N
    g_hist = g_hist.copy()
    if root_sup >= start_sup:
        g_hist[root_sup] += 1
        if mode == "lamp1":
            # replay the lambda recursion including the root contribution
            lam = int(recompute_lambda(g_hist, thr, int(lam), xp=np))

    stats_dict = {name: stats[:, i] for i, name in enumerate(STAT_NAMES)}
    if np.any(stats_dict["overflow"]):
        raise RuntimeError("stack overflow in engine: increase stack_cap/push_cap")
    if int(t) >= cfg.max_steps:
        raise RuntimeError("engine hit max_steps before termination")

    sig_sup = sig_pos = sig_occ = sig_core = None
    n_sig = int(g_sig)
    emit_dropped = int(stats_dict["emit_dropped"].sum())
    if mode in ("test", "count2d"):
        # cross-device gather of the emitted pattern records
        ptrs = out_ptr.reshape(-1)
        occ_rows = [out_occ[p, : int(ptrs[p])] for p in range(n_proc)]
        meta_rows = [out_meta[p, : int(ptrs[p])] for p in range(n_proc)]
        sig_occ = (np.concatenate(occ_rows, axis=0) if occ_rows
                   else np.zeros((0, w), np.uint32))
        allmeta = (np.concatenate(meta_rows, axis=0) if meta_rows
                   else np.zeros((0, 3), np.int32))
        sig_core, sig_sup, sig_pos = allmeta[:, 0], allmeta[:, 1], allmeta[:, 2]
        if emit_dropped:
            warnings.warn(
                f"pattern emission overflow: {emit_dropped} significant records "
                f"dropped (out_cap={cfg.out_cap} saturated); counts stay exact "
                "but the emitted pattern set is incomplete — raise "
                "EngineConfig.out_cap",
                RuntimeWarning,
                stacklevel=2,
            )
    if mode == "test":
        # root significance (host-side, same test as on device)
        if root_sup >= start_sup and labels is not None:
            from .fisher import fisher_pvalue

            p_root = fisher_pvalue(root_sup, n_pos, n, n_pos)[0]
            if p_root <= delta:
                n_sig += 1

    hist2d = None
    if mode == "count2d":
        hist2d = g_hist2d.reshape(n + 1, n_pos + 1).copy()
        if root_sup >= start_sup:
            hist2d[root_sup if root_sup <= n else n, n_pos] += 1
    return MineOutput(
        hist=g_hist,
        lam_final=int(lam),
        supersteps=int(t),
        stats=stats_dict,
        sig_count=n_sig,
        sig_sup=sig_sup,
        sig_pos_sup=sig_pos,
        trace=trace if cfg.trace_cap else None,
        hist2d=hist2d,
        sig_occ=sig_occ,
        sig_core=sig_core,
        emit_dropped=emit_dropped,
        db_bits=db_bits,
    )


# --------------------------------------------------------------- pipelines
def _build_results(db_bool, labels, phase_out, *, alpha, min_sup, k, delta,
                   filter_host):
    """Emitted records of one phase output -> ResultSet (repro.results)."""
    from repro.results import build_result_set

    db_bool = np.asarray(db_bool, dtype=bool)
    labels = np.asarray(labels, dtype=bool)
    # the phase already packed the database; never re-pack at GWAS scale
    db_bits = (phase_out.db_bits if phase_out.db_bits is not None
               else pack_db(db_bool))
    return build_result_set(
        phase_out.sig_occ, phase_out.sig_sup, phase_out.sig_pos_sup, db_bits,
        n=db_bool.shape[0], n_pos=int(labels.sum()), alpha=alpha,
        min_sup=min_sup, correction_factor=k, delta=delta,
        filter_host=filter_host, dropped=phase_out.emit_dropped,
    )


def _pipeline_three_phase(db_bool, labels, alpha, cfg, devices):
    """The paper's §3.3 staging: lamp1 -> count -> test (three traversals)."""
    p1 = mine(db_bool, labels, mode="lamp1", alpha=alpha, cfg=cfg, devices=devices)
    min_sup = max(p1.lam_final - 1, 1)

    # phase 2: exact closed-set count at min_sup
    p2 = mine(db_bool, labels, mode="count", min_sup=min_sup, cfg=cfg, devices=devices)
    k = int(p2.hist[min_sup:].sum())
    delta = alpha / max(k, 1)
    # phase 3: significance testing at delta
    p3 = mine(
        db_bool, labels, mode="test", min_sup=min_sup, delta=delta,
        cfg=cfg, devices=devices,
    )
    # the device already filtered at delta; reconstruct + exact stats only
    results = _build_results(
        db_bool, labels, p3, alpha=alpha, min_sup=min_sup, k=k, delta=delta,
        filter_host=False,
    )
    return {
        "lambda_final": p1.lam_final,
        "min_sup": min_sup,
        "correction_factor": k,
        "delta": delta,
        "n_significant": p3.sig_count,
        "results": results,
        "phase_outputs": (p1, p2, p3),
    }


def _pipeline_fused23(db_bool, labels, alpha, cfg, devices):
    """Beyond-paper (EXPERIMENTS.md §Perf): lamp1 -> count2d, two traversals.

    One enumeration pass builds a 2-D (support x pos-support) histogram;
    P-values depend only on that pair, so the correction factor AND the
    significant count both fall out of the histogram — the third engine pass
    disappears entirely.  The same pass emits alpha-level pattern records
    (delta <= alpha always), which the host filters down to the exact final
    delta, so pattern identities survive the fusion too (DESIGN.md §4).
    """
    p1 = mine(db_bool, labels, mode="lamp1", alpha=alpha, cfg=cfg, devices=devices)
    min_sup = max(p1.lam_final - 1, 1)

    n = db_bool.shape[0]
    n_pos = int(np.asarray(labels, bool).sum())
    p2 = mine(db_bool, labels, mode="count2d", min_sup=min_sup, delta=alpha,
              cfg=cfg, devices=devices)
    h2 = p2.hist2d
    sups_grid = np.arange(n + 1)
    mask = (h2 > 0) & (sups_grid[:, None] >= min_sup)
    k = int(h2[mask].sum())
    delta = alpha / max(k, 1)
    xs, ns = np.nonzero(mask)
    from .fisher import fisher_pvalue

    pv = fisher_pvalue(xs, ns, n, n_pos) if len(xs) else np.zeros(0)
    sig_mask = pv <= delta
    n_sig = int(h2[xs[sig_mask], ns[sig_mask]].sum()) if len(xs) else 0
    # records were emitted at the alpha superset level; exact-filter at delta
    results = _build_results(
        db_bool, labels, p2, alpha=alpha, min_sup=min_sup, k=k, delta=delta,
        filter_host=True,
    )
    return {
        "lambda_final": p1.lam_final,
        "min_sup": min_sup,
        "correction_factor": k,
        "delta": delta,
        "n_significant": n_sig,
        "results": results,
        "phase_outputs": (p1, p2),
    }


#: First-class LAMP pipeline registry — select with
#: `lamp_distributed(..., pipeline=<name>)`; extend by registering here.
PIPELINES: dict[str, Callable] = {
    "three_phase": _pipeline_three_phase,
    "fused23": _pipeline_fused23,
}


def lamp_distributed(
    db_bool: np.ndarray,
    labels: np.ndarray,
    alpha: float = 0.05,
    cfg: EngineConfig = EngineConfig(),
    devices=None,
    fuse_phase23: bool = False,
    pipeline: str | None = None,
):
    """Full distributed LAMP (paper §3.3 + §4). Returns a dict.

    The phase staging is pluggable: `pipeline` names an entry in PIPELINES
    ("three_phase" | "fused23").  `fuse_phase23=True` is the backward-
    compatible alias for pipeline="fused23".

    Every pipeline returns the same keys, including "results": a
    `repro.results.ResultSet` with the identified significant itemsets
    (closures, exact Fisher P-values, Bonferroni q-values), top-k selection
    and TSV/JSON export.
    """
    if pipeline is None:
        pipeline = "fused23" if fuse_phase23 else "three_phase"
    elif fuse_phase23 and pipeline != "fused23":
        raise ValueError(
            f"fuse_phase23=True conflicts with pipeline={pipeline!r}"
        )
    try:
        run = PIPELINES[pipeline]
    except KeyError:
        raise ValueError(
            f"unknown pipeline {pipeline!r}; available: {sorted(PIPELINES)}"
        ) from None
    return run(db_bool, labels, alpha, cfg, devices)
