"""Distributed BSP miner: LCM+LAMP with lifeline work stealing (paper §4).

One logical miner per device.  The whole search runs as a single compiled
`shard_map` program over a 1-D mesh axis "miners"; each superstep
(`lax.while_loop` body) is a pipeline of three phase modules:

  1. EXPAND   core/expand.py — pop up to `expand_batch` nodes; one
              popcount-GEMM gives every extension's support; deferred-PPC
              validation, closed-set counting, child generation (core/lcm.py
              documents the deferred-PPC scheme).
  2. STEAL    core/steal.py — one lifeline/random exchange round over the
              schedule from core/lifeline.py; REQUEST rides the hunger
              census, GIVE/REJECT is one packed ppermute, gated via
              `lax.cond` on "anyone hungry" (DESIGN.md §2/§6).
  3. GLOBAL   core/global_sync.py — the [P]-int hunger census doubles as
              the exact BSP termination test (paper §4.3's DTD is only
              needed on the async host plane); mode "lamp1" additionally
              psums the *since-last-sync delta* of the support histogram
              every `sync_period` supersteps and recomputes lambda (paper
              §4.4's piggyback; staleness only costs work, never
              correctness).

Each per-miner stack is a circular deque over fixed [stack_cap, W] storage
(core/deque.py): EXPAND pops/pushes at the logical top by pointer
arithmetic, a steal donates the logical bottom-k with O(steal_max) gathers
and advances the bottom pointer — nothing ever shifts.

This module holds only the config, the while-loop driver that wires the
phases together, and the host-side pre/postprocess; every version-sensitive
JAX API (shard_map, collectives, mesh) lives in core/collectives.py.

Node payload (fixed size, steal-friendly):  occ [W]u32, core i32, pc i32,
sup i32, flags i32   (flags bit0: "resume" node — already counted, continues
child generation past the per-superstep push cap).

Modes:
  lamp1   dynamic lambda by support increase  -> lambda_final
  count   static min_sup                      -> k = CS(min_sup)
  test    static min_sup + delta              -> #significant + pattern records
  count2d static min_sup (+delta=alpha)       -> 2-D (sup x pos-sup) histogram
                                                 + alpha-level pattern records

The hypothesis test is pluggable (`statistic`, a repro.stats registry name):
modes "test"/"count2d" trace the statistic's device P-value into their
emission gate (distinct compiled programs per statistic; statistic=None
emits every counted closed set — the closed-frequent objective), while
"lamp1"/"count" consume it only as the host-built Tarone threshold table
(runtime data — their programs are statistic-free).

Pattern records (modes "test"/"count2d", DESIGN.md §4): each significant node
appends (occ [W]u32, core, sup, pos_sup) to a fixed out_cap buffer — the same
dense payload shape as stack nodes — and repro.results reconstructs the
closure itemsets host-side; overflowed emissions are counted (emit_dropped)
and surfaced as a RuntimeWarning from mine().

The program dims are *shape buckets* (DESIGN.md §5): arrays are sized by
padded (transactions, positives, items) while the dataset's actual counts
arrive as runtime scalars, so one compiled program serves every same-bucket
dataset.  This module provides the building blocks — `pack_problem` /
`deal_roots` (host pre), `build_phase_program` / `make_phase_args`
(compile + call), `postprocess_phase` (host post) — plus the one-shot
`mine()`.  The LAMP stagings (three_phase | fused23) live in
`repro.api.session.PIPELINES` as functions over a compile-once
`MinerSession`; the legacy `lamp_distributed` dict entry survives here as
a deprecation shim.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.obs.trace import N_FIELDS, SuperstepTrace, decode_trace
from repro.stats import get_statistic
from repro.topo.topology import Topology

from . import collectives
from .bitmap import (
    DEFAULT_ITEM_TILE,
    BitmapLayout,
    full_occ,
    item_tiling,
    num_words,
    pack_db,
    supports_np,
)
from .collectives import MINERS_AXIS
from .expand import build_expand
from .global_sync import build_global_sync, hunger_census, recompute_lambda
from .lifeline import LifelineSchedule, build_schedule
from .stats import STAT_NAMES, Stat
from .steal import build_steal_round

INT_MAX = np.int32(2**31 - 1)

_NSTAT = len(STAT_NAMES)

#: the engine's pass modes (see module docstring); anything else is a typo
VALID_MODES = ("lamp1", "count", "test", "count2d")


@dataclass(frozen=True)
class EngineConfig:
    expand_batch: int = 16         # B: nodes popped per device per superstep
    stack_cap: int = 8192          # CAP
    steal_max: int = 256           # T: max nodes per GIVE
    push_cap: int = 1024           # C: max child pushes per superstep
    out_cap: int = 1024            # significant-sample buffer (mode="test")
    max_steps: int = 100_000
    n_random_perms: int = 4
    seed: int = 0
    steal_enabled: bool = True     # False = the paper's "naive approach" (§5.4)
    kernel_impl: str = "auto"      # "auto" | kernels/support_count/ops.VALID_IMPLS
    #: resolved (block_b, block_m, block_w) for the Pallas kernel; None lets
    #: the autotuner choose at trace time.  RuntimeConfig.resolve pins the
    #: tuned triple here so it joins the compiled-program cache key.
    kernel_blocks: tuple[int, int, int] | None = None
    #: superstep trace sampling period: 0 = off; k > 0 records one
    #: [N_FIELDS]i32 record (repro.obs.trace.TraceField) every k-th
    #: superstep into a [trace_cap, N_FIELDS] device ring (DESIGN.md §9)
    trace_period: int = 0
    trace_cap: int = 0             # ring slots; required > 0 when tracing
    sync_period: int = 4           # supersteps between lambda/histogram syncs
    #: checkpoint cadence (DESIGN.md §11): 0 = the classic whole-phase
    #: program; k > 0 compiles the *segmented* program — the BSP carry
    #: round-trips to the host every k supersteps so it can be checkpointed
    #: (ckpt/mining.py), restored elastically, and stopped cooperatively at
    #: a superstep boundary.  Part of the program cache key by construction
    #: (the key holds the resolved EngineConfig), so segmented and classic
    #: programs never collide.
    ckpt_period: int = 0
    #: machine shape (repro.topo): None = the classic flat 1-D "miners"
    #: mesh; a Topology switches the pass onto the 2-D [hosts, local] mesh
    #: with the hierarchical two-level lifeline schedule (intra-host rounds
    #: cheap and frequent, cross-host rounds rare).  Frozen and hashable,
    #: so flat and hierarchical programs never collide in a program cache.
    #: A single process can force a simulated shape (e.g. 2x4 on 8 local
    #: devices); under jax.distributed the shape must match the real
    #: process layout.
    topology: Topology | None = None


#: the BSP carry's leaf names, in carry-tuple order — the frontier schema
#: shared by the segmented program, the host segment loop, and the
#: checkpoint mapping (ckpt/mining.py).  Per-miner scalars (sp, head, lam,
#: t, out_ptr, n_sig, work) ride [P] vectors host-side.
CARRY_FIELDS = (
    "occ_stack", "meta", "sp", "head", "hist", "hist_snap", "g_hist_acc",
    "hist2d", "lam", "t", "stats", "out_occ", "out_meta", "out_ptr",
    "n_sig", "trace", "work",
)


@dataclass
class MineOutput:
    hist: np.ndarray               # [N+2] global closed-set support histogram
    lam_final: int
    supersteps: int
    stats: dict[str, np.ndarray]   # per-device counters [P]
    sig_count: int = 0             # mode="test"
    sig_sup: np.ndarray | None = None
    sig_pos_sup: np.ndarray | None = None
    trace: SuperstepTrace | None = None  # decoded ring (trace_period > 0)
    hist2d: np.ndarray | None = None  # [N+1, Npos+1] (mode="count2d")
    # emitted pattern records (modes "test"/"count2d"; DESIGN.md §4):
    sig_occ: np.ndarray | None = None   # [K, W]u32 occurrence bitmaps
    sig_core: np.ndarray | None = None  # [K] core item of the emitting node
    emit_dropped: int = 0          # records lost to out_cap saturation
    trace_dropped: int = 0         # sampled trace records lost to ring wrap
    db_bits: np.ndarray | None = None  # [M, W]u32 packed DB (reused downstream)
    #: False when the pass stopped cooperatively at a superstep boundary
    #: (soft deadline) before draining the frontier — counts/records cover
    #: only the explored region (DESIGN.md §11)
    complete: bool = True


def _thresholds_int(
    n: int, n_pos: int, alpha: float, statistic: str | None = "fisher"
) -> np.ndarray:
    """Integer Tarone support-increase table for the named statistic.

    statistic=None (closed-frequent: no test, static min_sup only) gets an
    all-INT_MAX table — lambda can never advance, and no mode that runs
    without a statistic reads it anyway.
    """
    if statistic is None:
        return np.full(n + 2, INT_MAX, dtype=np.int32)
    thr = get_statistic(statistic).count_thresholds(n, n_pos, alpha)
    out = np.minimum(np.floor(thr), float(INT_MAX)).astype(np.int64)
    out = out.astype(np.int32)
    out[0] = INT_MAX  # bucket 0 never drives lambda
    out[np.isinf(thr)] = INT_MAX
    return out


@dataclass(frozen=True)
class PackedProblem:
    """A transaction database packed once, padded to program (bucket) dims.

    The core-level prepared input: `repro.api.Dataset` wraps one of these
    (adding labels, item names, and the bucket policy), and `mine()` builds
    an exact-fit instance per call.  Padded items/words/positives are zero
    bits, so they have zero support and can never be accepted, counted,
    emitted, or generate children — results are invariant to the padding
    (DESIGN.md §5).

    The database is carried as one item-tiled `BitmapLayout` (DESIGN.md §8):
    `db_tiles` [T, m_tile, W] is what the device program takes, `db_bits`
    [m_pad, W] is its free item-major reshape for host-side code.  `m_pad`
    (the program item dim) always equals `layout.m_pad` == T * m_tile.
    """

    layout: BitmapLayout   # item-tiled packed DB; layout.m_pad == m_pad
    pos_mask: np.ndarray   # [w_pad] u32 positive-transaction bitmap
    occ0: np.ndarray       # [w_pad] u32 root occurrence (all actual transactions)
    n: int                 # actual transactions
    n_pos: int             # actual positives
    m: int                 # actual items
    n_pad: int             # bucket transactions (program dim)
    npos_pad: int          # bucket positives (program dim)
    m_pad: int             # bucket items, tile-aligned (program dim)
    has_labels: bool = True

    def __post_init__(self):
        if self.layout.m_pad != self.m_pad:
            raise ValueError(
                f"m_pad={self.m_pad} != layout.m_pad={self.layout.m_pad}"
            )

    @property
    def db_tiles(self) -> np.ndarray:
        """[T, m_tile, w_pad] — the device program's database argument."""
        return self.layout.tiles

    @property
    def db_bits(self) -> np.ndarray:
        """[m_pad, w_pad] item-major view (host-side: root deal, closures)."""
        return self.layout.flat

    @property
    def m_tile(self) -> int:
        return self.layout.m_tile

    @property
    def w_pad(self) -> int:
        return self.layout.w


def pack_problem(
    db_bool: np.ndarray,
    labels: np.ndarray | None = None,
    *,
    n_pad: int | None = None,
    npos_pad: int | None = None,
    m_pad: int | None = None,
    m_tile: int | None = None,
) -> PackedProblem:
    """Pack the bool matrix exactly once, padding to the given program dims.

    Defaults pad to the exact dataset shape (the legacy one-shot path);
    `repro.api.Dataset` passes its shape-bucket dims so same-bucket datasets
    produce identically-shaped arguments and share compiled programs.

    `m_tile` caps the item-tile width (default `DEFAULT_ITEM_TILE`): the
    item dim is rounded up to a tile multiple when it exceeds one tile, and
    the program item dim becomes that tile-aligned extent.
    """
    db_bool = np.asarray(db_bool, dtype=bool)
    n, m = db_bool.shape
    if labels is not None:
        labels = np.asarray(labels, dtype=bool)
        n_pos = int(labels.sum())
    else:
        n_pos = max(1, n // 2)
    n_pad = n if n_pad is None else n_pad
    npos_pad = n_pos if npos_pad is None else npos_pad
    m_pad = m if m_pad is None else m_pad
    if n_pad < n or npos_pad < n_pos or m_pad < m:
        raise ValueError(
            f"bucket dims ({n_pad}, {npos_pad}, {m_pad}) smaller than dataset "
            f"({n}, {n_pos}, {m})"
        )
    packed = pack_db(db_bool)  # [m, w]
    return pack_problem_from_bits(
        packed, labels, n=n, n_pad=n_pad, npos_pad=npos_pad, m_pad=m_pad,
        m_tile=m_tile, n_pos=n_pos,
    )


def pack_problem_from_bits(
    db_bits: np.ndarray,
    labels: np.ndarray | None = None,
    *,
    n: int,
    n_pad: int | None = None,
    npos_pad: int | None = None,
    m_pad: int | None = None,
    m_tile: int | None = None,
    n_pos: int | None = None,
) -> PackedProblem:
    """`pack_problem` for an already word-packed [M, W] database.

    The paper-scale entry (data/synthetic.py generates alz_rec_30 straight
    into packed words — a dense [n, m] bool intermediate would be ~91 GB of
    float draws upstream): no repacking, just zero-pad into the tiled layout.
    `n` (actual transactions) cannot be recovered from packed words, so it
    is required; `n_pos` defaults from `labels` (or n // 2 unlabeled).
    """
    db_bits = np.asarray(db_bits, dtype=np.uint32)
    m, w = db_bits.shape
    if labels is not None:
        labels = np.asarray(labels, dtype=bool)
        if n_pos is None:
            n_pos = int(labels.sum())
    elif n_pos is None:
        n_pos = max(1, n // 2)
    n_pad = n if n_pad is None else n_pad
    npos_pad = n_pos if npos_pad is None else npos_pad
    m_pad = m if m_pad is None else m_pad
    w_pad = num_words(n_pad)
    if w > w_pad:
        raise ValueError(f"db_bits has {w} words but n_pad={n_pad} fits {w_pad}")
    max_tile = DEFAULT_ITEM_TILE if m_tile is None else m_tile
    m_pad, tile = item_tiling(max(m_pad, 1), max_tile)

    padded = np.zeros((m, w_pad), dtype=np.uint32)
    padded[:, :w] = db_bits
    layout = BitmapLayout.from_db_bits(padded, m=m, m_tile=tile, m_pad=m_pad)
    pos_mask = np.zeros(w_pad, dtype=np.uint32)
    if labels is not None:
        pos_bits = pack_db(labels[:, None])[0]
        pos_mask[: pos_bits.shape[0]] = pos_bits
    occ0 = np.zeros(w_pad, dtype=np.uint32)
    root = full_occ(n)
    occ0[: root.shape[0]] = root
    for arr in (pos_mask, occ0):
        arr.flags.writeable = False
    return PackedProblem(
        layout=layout,
        pos_mask=pos_mask,
        occ0=occ0,
        n=n, n_pos=n_pos, m=m,
        n_pad=n_pad, npos_pad=npos_pad, m_pad=m_pad,
        has_labels=labels is not None,
    )


def deal_roots(packed: PackedProblem, n_proc: int, cfg: EngineConfig, min_sup: int = 1):
    """Paper §4.5: expand the root on the host, deal depth-1 nodes round-robin.

    Returns (init_occ [P,CAP,W], init_meta [P,CAP,4], init_sp [P]).
    """
    db_bits, occ0 = packed.db_bits, packed.occ0
    s = supports_np(occ0, db_bits)            # padded items have s == 0
    in_clo = s == packed.n
    cand = np.flatnonzero((~in_clo) & (s >= max(1, min_sup)))
    clo_cum = np.concatenate([[0], np.cumsum(in_clo)])  # clo_cum[e] = |clo ∩ [0,e)|

    cap = cfg.stack_cap
    init_occ = np.zeros((n_proc, cap, packed.w_pad), dtype=np.uint32)
    init_meta = np.zeros((n_proc, cap, 4), dtype=np.int32)
    init_sp = np.zeros(n_proc, dtype=np.int32)
    for e in cand:
        p = int(e) % n_proc  # the paper's  i mod P = p_i  assignment
        sp = init_sp[p]
        assert sp < cap, "stack_cap too small for depth-1 preprocess"
        init_occ[p, sp] = occ0 & db_bits[e]
        init_meta[p, sp] = (e, clo_cum[e], s[e], 0)
        init_sp[p] = sp + 1
    return init_occ, init_meta, init_sp


def build_mine_step(
    *, n: int, n_pos: int, m: int, cfg: EngineConfig,
    schedule: LifelineSchedule, mode: str, axis=MINERS_AXIS,
    statistic: str | None = "fisher",
):
    """Wire the superstep phases into the per-device BSP program body.

    `n`/`n_pos`/`m` are program (shape-bucket) dims; the dataset's actual
    transaction/positive counts are runtime scalar arguments of the returned
    program, so one compiled program serves every same-bucket dataset.
    `statistic` names the registered test whose device P-value gates
    emission in modes "test"/"count2d" (None = emit every counted closed
    set); it is traced into the program, so fisher/chi2/None programs are
    distinct compilation artifacts.
    """
    if cfg.trace_period < 0:
        raise ValueError(f"trace_period must be >= 0, got {cfg.trace_period}")
    if cfg.trace_period and cfg.trace_cap <= 0:
        raise ValueError(
            "trace_period > 0 requires trace_cap > 0 (the ring needs slots); "
            "RuntimeConfig.resolve() defaults the cap when only the period "
            "is set"
        )
    NB = n + 2
    NB2 = (n + 1) * (n_pos + 1) if mode == "count2d" else 1
    # lambda-sync state (last-synced global hist + local snapshot) only
    # exists in mode "lamp1"; other modes carry 1-element dummies
    SNB = NB if mode == "lamp1" else 1
    n_proc = schedule.n_proc
    expand = build_expand(n=n, n_pos=n_pos, m=m, cfg=cfg, mode=mode,
                          statistic=statistic)
    steal_round = build_steal_round(schedule, cfg, axis)
    global_sync = build_global_sync(
        nb=NB, mode=mode, sync_period=cfg.sync_period, axis=axis
    )

    def body(carry, db_tiles, pos_mask, thr, delta, n_act, npos_act):
        (occ_stack, meta, sp, head, hist, hist_snap, g_hist_acc, hist2d, lam,
         t, stats, out_occ, out_meta, out_ptr, n_sig, trace, _work) = carry
        stats_before = stats
        (occ_stack, meta, sp, hist, hist2d, stats, out_occ, out_meta, out_ptr,
         sig_cnt) = expand(
            occ_stack, meta, sp, head, hist, hist2d, lam, stats, db_tiles,
            pos_mask, out_occ, out_meta, out_ptr, delta, n_act, npos_act,
        )
        n_sig = n_sig + sig_cnt
        # the [P]-int hunger census: REQUEST side of the steal exchange,
        # gate for its payload ppermute, and the exact termination test
        # (steals only redistribute; they cannot turn an all-empty
        # superstep into work)
        hungry_vec = hunger_census(sp, n_proc, axis)
        n_hungry = jnp.sum(hungry_vec)
        if cfg.steal_enabled:
            occ_stack, meta, sp, head, got, gave, k_given, k_recv = steal_round(
                t, hungry_vec, n_hungry, occ_stack, meta, sp, head
            )
            stats = stats.at[Stat.STEALS_GOT].add(got)
            stats = stats.at[Stat.GIVES].add(gave)
            stats = stats.at[Stat.STOLEN_NODES].add(k_given)
            stats = stats.at[Stat.STEAL_ROUNDS].add(
                (n_hungry > 0).astype(jnp.int32)
            )
        else:
            k_given = k_recv = jnp.int32(0)
        stats = stats.at[Stat.IDLE_STEPS].add((sp == 0).astype(jnp.int32))
        stats = stats.at[Stat.SUPERSTEPS].add(1)

        if cfg.trace_period:
            # record *before* global_sync so LAMBDA is the value in force
            # during this superstep's expand; volumes are this-step stat
            # deltas.  Unsampled steps write to slot == trace_cap, which
            # mode="drop" discards — no branch, no psum, one 11-int store.
            deltas = stats - stats_before
            fired = (n_hungry > 0) & bool(cfg.steal_enabled)
            rec = jnp.stack([
                t,                           # TraceField.STEP
                lam,                         # TraceField.LAMBDA
                sp,                          # TraceField.DEPTH
                n_hungry,                    # TraceField.HUNGRY
                fired.astype(jnp.int32),     # TraceField.FIRED
                deltas[Stat.POPPED],         # TraceField.POPPED
                deltas[Stat.PUSHED],         # TraceField.PUSHED
                deltas[Stat.CLOSED],         # TraceField.CLOSED
                sig_cnt,                     # TraceField.EMITTED
                k_given,                     # TraceField.DONATED
                k_recv,                      # TraceField.RECEIVED
            ]).astype(jnp.int32)
            sampled = (t % cfg.trace_period) == 0
            idx = t // cfg.trace_period
            slot = jnp.where(sampled, idx % cfg.trace_cap, cfg.trace_cap)
            trace = trace.at[slot].set(rec, mode="drop")
            stats = stats.at[Stat.TRACE_DROPPED].add(
                (sampled & (idx >= cfg.trace_cap)).astype(jnp.int32)
            )

        lam, g_hist_acc, hist_snap = global_sync(
            t, hist, hist_snap, g_hist_acc, lam, thr
        )
        work = jnp.int32(n_proc) - n_hungry
        return (occ_stack, meta, sp, head, hist, hist_snap, g_hist_acc,
                hist2d, lam, t + 1, stats, out_occ, out_meta, out_ptr, n_sig,
                trace, work)

    def program(init_occ, init_meta, init_sp, db_tiles, pos_mask, thr,
                lam0, delta, n_act, npos_act):
        # per-device views arrive with a leading length-1 shard axis
        occ_stack = init_occ[0]
        meta = init_meta[0]
        sp = init_sp[0]
        head = jnp.int32(0)
        w = occ_stack.shape[-1]
        hist = jnp.zeros(NB, jnp.int32)
        hist_snap = jnp.zeros(SNB, jnp.int32)
        g_hist_acc = jnp.zeros(SNB, jnp.int32)
        hist2d = jnp.zeros(NB2, jnp.int32)
        stats = jnp.zeros(_NSTAT, jnp.int32)
        out_occ = jnp.zeros((cfg.out_cap, w), jnp.uint32)
        out_meta = jnp.zeros((cfg.out_cap, 3), jnp.int32)
        out_ptr = jnp.int32(0)
        n_sig = jnp.int32(0)
        t = jnp.int32(0)
        # the superstep trace ring ([trace_cap, N_FIELDS] i32 per miner);
        # a 1-slot dummy keeps the carry structure static when tracing is off
        trace = jnp.zeros((max(cfg.trace_cap, 1), N_FIELDS), jnp.int32)

        def cond_fn(carry):
            (_occ, _meta, _sp, _head, _hist, _snap, _ghist, _hist2d, _lam, t,
             _stats, _out_occ, _out_meta, _out_ptr, _n_sig, _trace,
             work) = carry
            # work (miners with non-empty stacks) was psum'd at the previous
            # superstep boundary:
            return (work > 0) & (t < cfg.max_steps)  # exact BSP termination

        work0 = jnp.int32(n_proc) - jnp.sum(hunger_census(sp, n_proc, axis))
        carry = (occ_stack, meta, sp, head, hist, hist_snap, g_hist_acc,
                 hist2d, lam0, t, stats, out_occ, out_meta, out_ptr, n_sig,
                 trace, work0)
        carry = lax.while_loop(
            cond_fn,
            lambda c: body(c, db_tiles, pos_mask, thr, delta, n_act, npos_act),
            carry,
        )
        (_, _, _, _, hist, _, _, hist2d, lam, t, stats, out_occ, out_meta,
         out_ptr, n_sig, trace, _) = carry
        # one exact full-histogram psum at termination (the in-loop lambda
        # only ever saw sync_period-stale deltas; postprocess replays the
        # recursion from this exact histogram)
        g_hist = collectives.psum(hist, axis)
        g_hist2d = collectives.psum(hist2d, axis)  # once, at termination — not per step
        g_sig = collectives.psum(n_sig, axis)
        return (
            g_hist, lam, t, stats[None], out_occ[None], out_meta[None],
            out_ptr[None], g_sig, trace[None], g_hist2d,
        )

    def seg_program(occ_stack, meta, sp, head, hist, hist_snap, g_hist_acc,
                    hist2d, lam, t, stats, out_occ, out_meta, out_ptr, n_sig,
                    trace, work, db_tiles, pos_mask, thr, delta, n_act,
                    npos_act, t_stop):
        # the segmented (checkpointable) variant: the full carry is a
        # program *argument* (host round-trip every segment) and the loop
        # runs to the runtime bound t_stop instead of draining the frontier.
        # Per-miner leaves arrive with a leading length-1 shard axis;
        # per-miner scalars ride [P] vectors (so [1] per device).
        carry = tuple(
            x[0] for x in (occ_stack, meta, sp, head, hist, hist_snap,
                           g_hist_acc, hist2d, lam, t, stats, out_occ,
                           out_meta, out_ptr, n_sig, trace, work)
        )

        def cond_fn(carry):
            t, work = carry[9], carry[16]
            # work was psum'd at the previous boundary — uniform across
            # miners, so the loop exits in lockstep; t_stop is runtime data
            # (no recompile per segment)
            return (work > 0) & (t < t_stop)

        carry = lax.while_loop(
            cond_fn,
            lambda c: body(c, db_tiles, pos_mask, thr, delta, n_act, npos_act),
            carry,
        )
        # no terminal psums here: the host sums the per-miner histograms
        # once the frontier drains (segments_raw_output) — int32 addition
        # commutes, so the result is bit-identical to the device psum
        return tuple(x[None] for x in carry)

    return seg_program if cfg.ckpt_period > 0 else program


def mesh_axis(mesh) -> "str | tuple":
    """The collective-axis argument for this mesh: one name, or the topo
    tuple ("hosts", "local") — what hunger_census/steal/psum thread through."""
    names = tuple(mesh.axis_names)
    return names if len(names) > 1 else names[0]


def make_mesh_and_schedule(cfg: EngineConfig, devices):
    """The (mesh, lifeline schedule) pair cfg.topology selects.

    Flat (topology=None): the classic 1-D miners mesh + one-level schedule.
    With a Topology: the 2-D [hosts, local] mesh + the hierarchical
    two-level schedule (repro.topo) — the device list must match the
    topology's P exactly.
    """
    n_proc = len(devices)
    if cfg.topology is None:
        return (
            collectives.make_miner_mesh(devices),
            build_schedule(n_proc, cfg.n_random_perms, cfg.seed),
        )
    from repro.topo.hierarchy import build_hierarchical_schedule

    if cfg.topology.n_proc != n_proc:
        raise ValueError(
            f"topology {cfg.topology} needs {cfg.topology.n_proc} devices, "
            f"got {n_proc}"
        )
    return (
        collectives.make_topo_mesh(cfg.topology, devices),
        build_hierarchical_schedule(cfg.topology, cfg.n_random_perms, cfg.seed),
    )


def phase_in_specs(cfg: EngineConfig, axis=MINERS_AXIS) -> tuple:
    """PartitionSpecs of the phase program's argument tuple, in order.

    `axis` is `mesh_axis(mesh)` — a tuple shards the miner dim over both
    topo axes.  Exposed so the multi-process bootstrap (repro.topo) can wrap
    host numpy arguments into identically-sharded global arrays.
    """
    s = P(axis)
    if cfg.ckpt_period > 0:
        # segmented: every carry leaf miner-sharded, then the static
        # operands db_tiles, pos_mask, thr, delta, n_act, npos_act, t_stop
        return tuple(s for _ in CARRY_FIELDS) + (P(),) * 7
    return (s, s, s) + (P(),) * 7


def phase_out_specs(cfg: EngineConfig, axis=MINERS_AXIS) -> tuple:
    """PartitionSpecs of the phase program's outputs, in order."""
    s = P(axis)
    if cfg.ckpt_period > 0:
        return tuple(s for _ in CARRY_FIELDS)
    return (P(), P(), P(), s, s, s, s, P(), s, P())


def build_phase_program(
    packed_dims: tuple[int, int, int],
    *,
    cfg: EngineConfig,
    schedule: LifelineSchedule,
    mesh,
    mode: str,
    statistic: str | None = "fisher",
):
    """shard_map'd (unjitted) BSP program for one engine pass.

    `packed_dims` = (n_pad, npos_pad, m_pad) — the program (bucket) dims.
    The returned callable takes the argument tuple built by
    `make_phase_args` and is what `repro.api.MinerSession` AOT-compiles and
    caches; `mine()` wraps it in a fresh `jax.jit` per call.  `statistic`
    reaches the traced emission test (modes "test"/"count2d" only), so it
    must join any cache key for those modes.

    The mesh decides the collective wiring: a 1-D miners mesh runs every
    round over its single axis (flat or hierarchical schedule alike); the
    2-D topo mesh requires a factorized (hierarchical) schedule and splits
    the census psum and per-round ppermutes across the two axes.
    """
    n_pad, npos_pad, m_pad = packed_dims
    axis = mesh_axis(mesh)
    program = build_mine_step(
        n=n_pad, n_pos=npos_pad, m=m_pad, cfg=cfg, schedule=schedule,
        mode=mode, axis=axis, statistic=statistic,
    )
    return collectives.shard_map(
        program,
        mesh=mesh,
        in_specs=phase_in_specs(cfg, axis),
        out_specs=phase_out_specs(cfg, axis),
    )


def make_phase_args(
    packed: PackedProblem,
    *,
    n_proc: int,
    cfg: EngineConfig,
    mode: str,
    alpha: float,
    min_sup: int,
    delta: float,
    statistic: str | None = "fisher",
):
    """Build the program argument tuple (and the postprocess context).

    Every array's shape/dtype is a function of (bucket dims, cfg, n_proc)
    only, so repeat queries on a warm compiled program always re-match its
    input signature exactly.  The statistic enters here as *runtime data*
    (its Tarone threshold table); its traced half lives in
    `build_phase_program`.

    Returns (args, ctx) with ctx = dict(thr, start_sup) for postprocess.
    """
    start_sup = min_sup if mode != "lamp1" else 1
    init_occ, init_meta, init_sp = deal_roots(packed, n_proc, cfg, start_sup)
    thr = _thresholds_int(packed.n, packed.n_pos, alpha, statistic)
    thr_pad = np.full(packed.n_pad + 2, INT_MAX, dtype=np.int32)
    thr_pad[: thr.shape[0]] = thr
    args = (
        init_occ, init_meta, init_sp,
        packed.db_tiles, packed.pos_mask, thr_pad,
        np.int32(start_sup), np.float32(delta),
        np.int32(packed.n), np.int32(packed.n_pos),
    )
    return args, dict(thr=thr_pad, start_sup=start_sup)


def init_carry(
    packed: PackedProblem,
    *,
    n_proc: int,
    cfg: EngineConfig,
    mode: str,
    init_occ: np.ndarray,
    init_meta: np.ndarray,
    init_sp: np.ndarray,
    start_sup: int,
) -> dict[str, np.ndarray]:
    """Host-side initial BSP carry for the segmented program.

    A dict keyed by CARRY_FIELDS, every leaf a global [P, ...] numpy array
    (per-miner scalars as [P] vectors).  Mirrors exactly what the classic
    program initialises on-device before its while loop, including the
    boundary-census `work` the loop cond reads.
    """
    NB = packed.n_pad + 2
    SNB = NB if mode == "lamp1" else 1
    NB2 = (packed.n_pad + 1) * (packed.npos_pad + 1) if mode == "count2d" else 1
    w = init_occ.shape[-1]
    i32, P_ = np.int32, n_proc
    return {
        "occ_stack": np.ascontiguousarray(init_occ),
        "meta": np.ascontiguousarray(init_meta),
        "sp": np.ascontiguousarray(init_sp),
        "head": np.zeros(P_, i32),
        "hist": np.zeros((P_, NB), i32),
        "hist_snap": np.zeros((P_, SNB), i32),
        "g_hist_acc": np.zeros((P_, SNB), i32),
        "hist2d": np.zeros((P_, NB2), i32),
        "lam": np.full(P_, start_sup, i32),
        "t": np.zeros(P_, i32),
        "stats": np.zeros((P_, _NSTAT), i32),
        "out_occ": np.zeros((P_, cfg.out_cap, w), np.uint32),
        "out_meta": np.zeros((P_, cfg.out_cap, 3), i32),
        "out_ptr": np.zeros(P_, i32),
        "n_sig": np.zeros(P_, i32),
        "trace": np.zeros((P_, max(cfg.trace_cap, 1), N_FIELDS), i32),
        # miners with non-empty stacks — the same census the classic program
        # computes on-device before entering its loop
        "work": np.full(P_, int((np.asarray(init_sp) > 0).sum()), i32),
    }


def make_program_args(
    packed: PackedProblem,
    *,
    n_proc: int,
    cfg: EngineConfig,
    mode: str,
    alpha: float,
    min_sup: int,
    delta: float,
    statistic: str | None = "fisher",
):
    """`make_phase_args`, shaped for whichever program variant cfg selects.

    ckpt_period == 0: identical to `make_phase_args`.  ckpt_period > 0: the
    args tuple matches the segmented program's signature — carry leaves in
    CARRY_FIELDS order, then the static operands, then a t_stop placeholder
    — and ctx gains `carry0` (the initial carry dict) and `static` (the
    operands `run_segments` re-passes every dispatch).
    """
    args, ctx = make_phase_args(
        packed, n_proc=n_proc, cfg=cfg, mode=mode, alpha=alpha,
        min_sup=min_sup, delta=delta, statistic=statistic,
    )
    if cfg.ckpt_period <= 0:
        return args, ctx
    carry0 = init_carry(
        packed, n_proc=n_proc, cfg=cfg, mode=mode,
        init_occ=args[0], init_meta=args[1], init_sp=args[2],
        start_sup=ctx["start_sup"],
    )
    # db_tiles, pos_mask, thr / delta, n_act, npos_act — lam0 (args[6])
    # rides the carry instead
    static = args[3:6] + args[7:10]
    seg_args = tuple(carry0[k] for k in CARRY_FIELDS) + static + (np.int32(0),)
    ctx = dict(ctx, carry0=carry0, static=static)
    return seg_args, ctx


def run_segments(
    dispatch,
    carry: dict[str, np.ndarray],
    *,
    cfg: EngineConfig,
    static: tuple,
    should_stop=None,
    on_segment=None,
):
    """Host loop driving the segmented program to frontier exhaustion.

    Each iteration runs one ckpt_period-superstep segment on device, pulls
    the carry back to host, fires the engine.superstep fault point, then
    hands the carry to `on_segment` (the checkpoint writer) — in that order,
    so an injected death loses the running segment's checkpoint, the
    harshest recovery case.  `should_stop` is polled at the loop bottom
    only: a cooperative stop always has at least one segment of progress
    behind it, so a partial result is never empty-by-construction.

    Returns (carry, partial).
    """
    from repro.testing import faults

    partial = False
    while int(carry["work"][0]) > 0 and int(carry["t"][0]) < cfg.max_steps:
        t_stop = min(int(carry["t"][0]) + cfg.ckpt_period, cfg.max_steps)
        raw = dispatch(
            *(carry[k] for k in CARRY_FIELDS), *static, np.int32(t_stop)
        )
        carry = {k: np.asarray(v) for k, v in zip(CARRY_FIELDS, raw)}
        faults.check("engine.superstep", t=int(carry["t"][0]))
        if on_segment is not None:
            on_segment(carry)
        if (
            should_stop is not None
            and int(carry["work"][0]) > 0
            and int(carry["t"][0]) < cfg.max_steps
            and should_stop()
        ):
            partial = True
            break
    return carry, partial


def segments_raw_output(carry: dict[str, np.ndarray]):
    """Terminal carry -> the classic program's 10-tuple raw output.

    The host stands in for the classic program's termination psums; int32
    addition commutes (mod 2^32), so the sums are bit-identical to the
    device reduction regardless of miner count or summation order.
    """
    g_hist = carry["hist"].sum(axis=0, dtype=np.int32)
    g_hist2d = carry["hist2d"].sum(axis=0, dtype=np.int32)
    g_sig = carry["n_sig"].sum(dtype=np.int32)
    return (
        g_hist, carry["lam"][0], carry["t"][0], carry["stats"],
        carry["out_occ"], carry["out_meta"], carry["out_ptr"], g_sig,
        carry["trace"], g_hist2d,
    )


def postprocess_phase(
    raw_out,
    *,
    packed: PackedProblem,
    n_proc: int,
    cfg: EngineConfig,
    mode: str,
    thr: np.ndarray,
    start_sup: int,
    delta: float,
    statistic: str | None = "fisher",
    partial: bool = False,
    schedule: LifelineSchedule | None = None,
) -> MineOutput:
    """Device output -> MineOutput: slice padding, fold in the root closed
    set, gather emitted pattern records, surface overflow.  `statistic`
    must match the program's: the root closed set never transits the device
    buffers, so its significance is re-decided host-side with the same test
    (or counted unconditionally when statistic is None — closed-frequent).
    `schedule` (when given) keys the decoded trace's per-round/per-tier
    steal attribution by the round names the pass actually cycled."""
    n, n_pos = packed.n, packed.n_pos
    root_sup = n  # support of the root closure == all transactions
    (g_hist, lam, t, stats, out_occ, out_meta, out_ptr, g_sig, trace,
     g_hist2d) = jax.tree.map(np.asarray, raw_out)
    # count the root closed set (clo of the empty itemset), support = N
    g_hist = g_hist.copy()
    if root_sup >= start_sup:
        g_hist[root_sup] += 1
        if mode == "lamp1":
            # replay the lambda recursion including the root contribution
            lam = int(recompute_lambda(g_hist, thr, int(lam), xp=np))
    # bucket padding (hist bins past n+1 are structurally zero) is an
    # implementation detail — slice back to the dataset's exact shape
    g_hist = g_hist[: n + 2]

    stats_dict = {name: stats[:, i] for i, name in enumerate(STAT_NAMES)}
    if np.any(stats_dict["overflow"]):
        raise RuntimeError("stack overflow in engine: increase stack_cap/push_cap")
    # a cooperative (soft-deadline) stop legitimately leaves the frontier
    # undrained — only an *uninterrupted* pass hitting max_steps is an error
    if not partial and int(t) >= cfg.max_steps:
        raise RuntimeError("engine hit max_steps before termination")

    sig_sup = sig_pos = sig_occ = sig_core = None
    n_sig = int(g_sig)
    emit_dropped = int(stats_dict["emit_dropped"].sum())
    if mode in ("test", "count2d"):
        # cross-device gather of the emitted pattern records: one boolean
        # mask over the flattened [P * out_cap] record axis, device-major —
        # identical order to the old per-device slice-and-concat loop
        ptrs = out_ptr.reshape(-1)
        live = (np.arange(cfg.out_cap)[None, :] < ptrs[:, None]).reshape(-1)
        sig_occ = out_occ.reshape(n_proc * cfg.out_cap, -1)[live]
        allmeta = out_meta.reshape(n_proc * cfg.out_cap, 3)[live]
        sig_core, sig_sup, sig_pos = allmeta[:, 0], allmeta[:, 1], allmeta[:, 2]
        if emit_dropped:
            warnings.warn(
                f"pattern emission overflow: {emit_dropped} significant records "
                f"dropped (out_cap={cfg.out_cap} saturated); counts stay exact "
                "but the emitted pattern set is incomplete — raise "
                "EngineConfig.out_cap",
                RuntimeWarning,
                stacklevel=3,
            )
    if mode == "test":
        # root significance (host-side, same test as on device)
        if statistic is None:
            # closed-frequent objective: the root closed set counts whenever
            # it clears the support threshold — there is no test to fail
            if root_sup >= start_sup:
                n_sig += 1
        elif root_sup >= start_sup and packed.has_labels:
            p_root = get_statistic(statistic).pvalue(root_sup, n_pos, n, n_pos)[0]
            if p_root <= delta:
                n_sig += 1

    hist2d = None
    if mode == "count2d":
        hist2d = g_hist2d.reshape(packed.n_pad + 1, packed.npos_pad + 1)
        hist2d = hist2d[: n + 1, : n_pos + 1].copy()
        if root_sup >= start_sup:
            hist2d[root_sup if root_sup <= n else n, n_pos] += 1

    trace_dec = None
    trace_dropped = 0
    if cfg.trace_period:
        trace_dec = decode_trace(
            trace, supersteps=int(t), period=cfg.trace_period,
            round_names=schedule.names if schedule is not None else None,
            round_tiers=schedule.tiers if schedule is not None else None,
        )
        trace_dropped = trace_dec.dropped
        if trace_dropped:
            warnings.warn(
                f"superstep trace ring wrapped: {trace_dropped} oldest "
                f"sampled records overwritten (trace_cap={cfg.trace_cap}, "
                f"trace_period={cfg.trace_period}, {int(t)} supersteps); "
                "the decoded timeline covers only the most recent window — "
                "raise trace_cap or trace_period",
                RuntimeWarning,
                stacklevel=3,
            )
    return MineOutput(
        hist=g_hist,
        lam_final=int(lam),
        supersteps=int(t),
        stats=stats_dict,
        sig_count=n_sig,
        sig_sup=sig_sup,
        sig_pos_sup=sig_pos,
        trace=trace_dec,
        hist2d=hist2d,
        sig_occ=sig_occ,
        sig_core=sig_core,
        emit_dropped=emit_dropped,
        trace_dropped=trace_dropped,
        db_bits=packed.db_bits,
        complete=not partial,
    )


def mine(
    db_bool: np.ndarray,
    labels: np.ndarray | None = None,
    *,
    mode: str = "lamp1",
    alpha: float = 0.05,
    min_sup: int = 1,
    delta: float = 0.0,
    cfg: EngineConfig = EngineConfig(),
    devices=None,
    packed: PackedProblem | None = None,
    statistic: str | None = "fisher",
    ckpt_dir: str | None = None,
    resume_from: str | None = None,
    should_stop=None,
    ckpt_keep: int = 3,
) -> MineOutput:
    """Run one engine pass over all (or the given) local devices.

    The one-shot low-level entry: packs the database (unless a prepared
    `packed` is given), compiles the phase program for this call, runs it,
    and postprocesses.  For repeated queries use `repro.api.MinerSession`,
    which caches compiled programs across phases, queries, and same-bucket
    datasets.

    With `cfg.ckpt_period > 0` the pass runs segmented (DESIGN.md §11):
    `ckpt_dir` checkpoints the frontier every segment, `resume_from`
    restores the newest valid step (elastically resharded onto this call's
    device count), and `should_stop()` polled at segment boundaries stops
    the pass cooperatively (MineOutput.complete=False).
    """
    if mode not in VALID_MODES:
        raise ValueError(
            f"unknown engine mode {mode!r}; valid modes: {', '.join(VALID_MODES)}"
        )
    if (ckpt_dir or resume_from or should_stop is not None) and cfg.ckpt_period <= 0:
        raise ValueError(
            "ckpt_dir/resume_from/should_stop need the segmented program: "
            "set EngineConfig.ckpt_period > 0"
        )
    if packed is None:
        packed = pack_problem(db_bool, labels)
    if devices is None:
        devices = jax.devices()
    n_proc = len(devices)
    mesh, schedule = make_mesh_and_schedule(cfg, devices)

    args, ctx = make_program_args(
        packed, n_proc=n_proc, cfg=cfg, mode=mode, alpha=alpha,
        min_sup=min_sup, delta=delta, statistic=statistic,
    )
    shardy = build_phase_program(
        (packed.n_pad, packed.npos_pad, packed.m_pad),
        cfg=cfg, schedule=schedule, mesh=mesh, mode=mode, statistic=statistic,
    )
    fn = jax.jit(shardy)
    partial = False
    if cfg.ckpt_period > 0:
        from repro.ckpt import mining as ckpt_mining

        provenance = ckpt_mining.make_provenance(
            packed, mode=mode, statistic=statistic, alpha=alpha,
            start_sup=ctx["start_sup"], delta=delta,
        )
        carry = ctx["carry0"]
        if resume_from:
            restored = ckpt_mining.restore_frontier(
                resume_from, provenance=provenance, n_proc=n_proc, cfg=cfg,
                mode=mode,
            )
            if restored is not None:
                carry = restored
        on_segment = None
        if ckpt_dir:
            def on_segment(c):
                ckpt_mining.save_frontier(
                    c, ckpt_dir, provenance=provenance, keep=ckpt_keep
                )
        carry, partial = run_segments(
            fn, carry, cfg=cfg, static=ctx["static"],
            should_stop=should_stop, on_segment=on_segment,
        )
        raw = segments_raw_output(carry)
    else:
        raw = fn(*args)
    return postprocess_phase(
        raw, packed=packed, n_proc=n_proc, cfg=cfg, mode=mode,
        thr=ctx["thr"], start_sup=ctx["start_sup"], delta=delta,
        statistic=statistic, partial=partial, schedule=schedule,
    )


# ----------------------------------------------------- legacy public shim
def lamp_distributed(
    db_bool: np.ndarray,
    labels: np.ndarray,
    alpha: float = 0.05,
    cfg: EngineConfig = EngineConfig(),
    devices=None,
    fuse_phase23: bool = False,
    pipeline: str | None = None,
):
    """Deprecated one-shot LAMP entry — use `repro.api` instead.

    .. deprecated::
        The canonical surface is session-based::

            from repro.api import Dataset, MinerSession
            report = MinerSession().mine(Dataset.from_dense(db, labels))

        `MinerSession` compiles each phase program once and reuses it across
        phases, repeat queries, and same-bucket datasets; this shim rebuilds
        a fresh session per call (re-compiling every phase, exactly like the
        historical behavior) and flattens the typed `MineReport` back into
        the documented legacy dict: lambda_final, min_sup,
        correction_factor, delta, n_significant, results, phase_outputs.

    The phase staging is pluggable: `pipeline` names an entry in PIPELINES
    ("three_phase" | "fused23").  `fuse_phase23=True` is the backward-
    compatible alias for pipeline="fused23".
    """
    warnings.warn(
        "lamp_distributed() is deprecated: use repro.api.MinerSession.mine() "
        "on a repro.api.Dataset (compile-once sessions, typed MineReport)",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.api import (
        EXACT_BUCKETS, AlgorithmConfig, Dataset, MinerSession, RuntimeConfig,
    )
    from repro.api.session import PIPELINES as _pipelines

    if pipeline is None:
        pipeline = "fused23" if fuse_phase23 else "three_phase"
    elif fuse_phase23 and pipeline != "fused23":
        raise ValueError(
            f"fuse_phase23=True conflicts with pipeline={pipeline!r}"
        )
    if pipeline not in _pipelines:
        raise ValueError(
            f"unknown pipeline {pipeline!r}; available: {sorted(_pipelines)}"
        )
    # exact buckets: bit-for-bit the historical program shapes
    ds = Dataset.from_dense(db_bool, labels, bucket_policy=EXACT_BUCKETS)
    session = MinerSession(
        devices=devices,
        algorithm=AlgorithmConfig(alpha=alpha, pipeline=pipeline),
        runtime=RuntimeConfig.from_engine_config(cfg),
    )
    return session.mine(ds).to_legacy_dict()


def __getattr__(name: str):
    # PIPELINES moved to repro.api.session (imported lazily: api -> core is
    # the module-level direction; this back-compat alias must not cycle).
    if name == "PIPELINES":
        from repro.api.session import PIPELINES

        return PIPELINES
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
