"""Distributed BSP miner: LCM+LAMP with lifeline work stealing (paper §4).

One logical miner per device.  The whole search runs as a single compiled
`shard_map` program over a 1-D mesh axis "miners":

  superstep (lax.while_loop body):
    1. EXPAND   pop up to `expand_batch` nodes from the local stack; one
                popcount-GEMM gives every extension's support; deferred-PPC
                validation, closed-set counting, child generation (core/lcm.py
                documents the deferred-PPC scheme).
    2. STEAL    one lifeline/random exchange round (core/lifeline.py): hungry
                devices (empty stack) send a request bit along the round's
                permutation; a victim donates half its stack (bottom half =
                oldest/shallowest subtrees), capped at `steal_max` nodes, via
                the inverse permutation.  REQUEST/GIVE/REJECT collapses into
                one paired ppermute exchange (DESIGN.md §2).
    3. GLOBAL   psum the support histogram -> recompute lambda (paper §4.4:
                the piggybacked gather/broadcast; staleness only costs work),
                psum stack sizes -> exact BSP termination test (paper §4.3's
                DTD is only needed on the async host plane; core/termination.py).

Node payload (fixed size, steal-friendly):  occ [W]u32, core i32, pc i32,
sup i32, flags i32   (flags bit0: "resume" node — already counted, continues
child generation past the per-superstep push cap).

Modes:
  lamp1  dynamic lambda by support increase  -> lambda_final
  count  static min_sup                      -> k = CS(min_sup)
  test   static min_sup + delta              -> #significant + sample buffer
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from .bitmap import full_occ, num_words, pack_db, supports_np
from .fisher import lamp_count_thresholds, fisher_pvalue_jnp
from .lifeline import LifelineSchedule, build_schedule

INT_MAX = np.int32(2**31 - 1)

STAT_NAMES = (
    "popped", "rejected", "closed", "pushed", "steals_got", "gives",
    "idle_steps", "supersteps", "overflow", "stolen_nodes",
)
_NSTAT = len(STAT_NAMES)


@dataclass(frozen=True)
class EngineConfig:
    expand_batch: int = 16         # B: nodes popped per device per superstep
    stack_cap: int = 8192          # CAP
    steal_max: int = 256           # T: max nodes per GIVE
    push_cap: int = 1024           # C: max child pushes per superstep
    out_cap: int = 1024            # significant-sample buffer (mode="test")
    max_steps: int = 100_000
    n_random_perms: int = 4
    seed: int = 0
    steal_enabled: bool = True     # False = the paper's "naive approach" (§5.4)
    kernel_impl: str = "ref"       # "ref" | "pallas" (TPU) | "pallas_interpret"
    trace_cap: int = 0             # >0: record popped-per-superstep [trace_cap]


@dataclass
class MineOutput:
    hist: np.ndarray               # [N+2] global closed-set support histogram
    lam_final: int
    supersteps: int
    stats: dict[str, np.ndarray]   # per-device counters [P]
    sig_count: int = 0             # mode="test"
    sig_sup: np.ndarray | None = None
    sig_pos_sup: np.ndarray | None = None
    trace: np.ndarray | None = None  # [P, trace_cap] popped per superstep
    hist2d: np.ndarray | None = None  # [N+1, Npos+1] (mode="count2d")


def _thresholds_int(n: int, n_pos: int, alpha: float) -> np.ndarray:
    thr = lamp_count_thresholds(n, n_pos, alpha)
    out = np.minimum(np.floor(thr), float(INT_MAX)).astype(np.int64)
    out = out.astype(np.int32)
    out[0] = INT_MAX  # bucket 0 never drives lambda
    out[np.isinf(thr)] = INT_MAX
    return out


def _supports(occ_nodes, db_mw, db_wm, impl):
    if impl == "ref":
        inter = occ_nodes[:, None, :] & db_mw[None, :, :]
        return jnp.sum(lax.population_count(inter), axis=-1).astype(jnp.int32)
    from repro.kernels.support_count.ops import support_counts

    return support_counts(
        occ_nodes, db_wm, interpret=(impl == "pallas_interpret")
    )


def preprocess(db_bool: np.ndarray, n_proc: int, cfg: EngineConfig, min_sup: int = 1):
    """Paper §4.5: expand the root on the host, deal depth-1 nodes round-robin.

    Returns (db_bits [M,W], init_occ [P,CAP,W], init_meta [P,CAP,4],
             init_sp [P], root_support).
    """
    db_bool = np.asarray(db_bool, dtype=bool)
    n, m = db_bool.shape
    w = num_words(n)
    db_bits = pack_db(db_bool)
    occ0 = full_occ(n)
    s = supports_np(occ0, db_bits)
    in_clo = s == n
    cand = np.flatnonzero((~in_clo) & (s >= max(1, min_sup)))
    clo_cum = np.concatenate([[0], np.cumsum(in_clo)])  # clo_cum[e] = |clo ∩ [0,e)|

    cap = cfg.stack_cap
    init_occ = np.zeros((n_proc, cap, w), dtype=np.uint32)
    init_meta = np.zeros((n_proc, cap, 4), dtype=np.int32)
    init_sp = np.zeros(n_proc, dtype=np.int32)
    for e in cand:
        p = int(e) % n_proc  # the paper's  i mod P = p_i  assignment
        sp = init_sp[p]
        assert sp < cap, "stack_cap too small for depth-1 preprocess"
        init_occ[p, sp] = occ0 & db_bits[e]
        init_meta[p, sp] = (e, clo_cum[e], s[e], 0)
        init_sp[p] = sp + 1
    return db_bits, init_occ, init_meta, init_sp, n


def _make_steal_round(schedule: LifelineSchedule, cfg: EngineConfig, w: int, axis: str):
    """Returns steal_round(t, occ_stack, meta, sp) -> (occ_stack, meta, sp, got, gave, k_given)."""
    T = cfg.steal_max
    cap = cfg.stack_cap

    def one_round(req_pairs, rep_pairs, occ_stack, meta, sp):
        hungry = (sp == 0).astype(jnp.int32)
        req_in = lax.ppermute(hungry, axis, perm=list(req_pairs))
        donate = (req_in > 0) & (sp > 1)
        k = jnp.where(donate, jnp.minimum(sp // 2, T), 0)
        rows = jnp.arange(T)
        pay_mask = rows < k
        pay_occ = jnp.where(pay_mask[:, None], occ_stack[:T], 0)
        pay_meta = jnp.where(pay_mask[:, None], meta[:T], 0)
        # remove donated bottom-k, shift stack down
        idx = jnp.arange(cap) + k
        occ_stack = jnp.take(occ_stack, idx, axis=0, mode="fill", fill_value=0)
        meta = jnp.take(meta, idx, axis=0, mode="fill", fill_value=0)
        sp = sp - k
        # reply to (the only possible) requester
        recv_k = lax.ppermute(k, axis, perm=list(rep_pairs))
        recv_occ = lax.ppermute(pay_occ, axis, perm=list(rep_pairs))
        recv_meta = lax.ppermute(pay_meta, axis, perm=list(rep_pairs))
        got = recv_k > 0  # only ever true for requesters (they had sp == 0)
        wmask = (rows < recv_k)[:, None]
        occ_stack = occ_stack.at[:T].set(jnp.where(wmask, recv_occ, occ_stack[:T]))
        meta = meta.at[:T].set(jnp.where(wmask, recv_meta, meta[:T]))
        sp = jnp.where(got, recv_k, sp)
        return occ_stack, meta, sp, got.astype(jnp.int32), donate.astype(jnp.int32), k

    branches = [
        functools.partial(one_round, req, rep) for (req, rep) in schedule.rounds
    ]

    def steal_round(t, occ_stack, meta, sp):
        return lax.switch(t % schedule.n_rounds, branches, occ_stack, meta, sp)

    return steal_round


def build_mine_step(
    *, n: int, n_pos: int, m: int, w: int, cfg: EngineConfig,
    schedule: LifelineSchedule, mode: str, axis: str = "miners",
):
    """Returns the per-device BSP program body used under shard_map."""
    B, CAP, C = cfg.expand_batch, cfg.stack_cap, cfg.push_cap
    NB = n + 2
    NB2 = (n + 1) * (n_pos + 1) if mode == "count2d" else 1
    steal_round = _make_steal_round(schedule, cfg, w, axis)
    dyn_lambda = mode == "lamp1"
    testing = mode == "test"
    hist2d_mode = mode == "count2d"

    def expand(occ_stack, meta, sp, hist, hist2d, lam, stats, db_mw, db_wm,
               pos_mask, out_buf, out_ptr, delta):
        take = jnp.minimum(sp, B)
        rows = jnp.arange(B)
        node_idx = jnp.clip(sp - 1 - rows, 0, CAP - 1)
        row_valid = rows < take
        occ_nodes = occ_stack[node_idx]          # [B, W]
        meta_nodes = meta[node_idx]              # [B, 4]
        core = meta_nodes[:, 0]
        pc = meta_nodes[:, 1]
        sup = meta_nodes[:, 2]
        flags = meta_nodes[:, 3]
        sp_after = sp - take

        alive = row_valid & (sup >= lam)
        supports = _supports(occ_nodes, db_mw, db_wm, cfg.kernel_impl)  # [B, M]
        item_ids = jnp.arange(m)[None, :]
        in_clo = supports == sup[:, None]
        prefix_ct = jnp.sum(in_clo & (item_ids < core[:, None]), axis=1)
        is_resume = (flags & 1) == 1
        ppc_ok = is_resume | (core < 0) | (prefix_ct == pc)
        accepted = alive & ppc_ok
        counted = accepted & (~is_resume)

        hist = hist.at[jnp.clip(sup, 0, NB - 1)].add(counted.astype(jnp.int32))
        if hist2d_mode:
            pos_sup2 = jnp.sum(
                lax.population_count(occ_nodes & pos_mask[None, :]), axis=1
            ).astype(jnp.int32)
            cell = jnp.clip(sup, 0, n) * (n_pos + 1) + jnp.clip(pos_sup2, 0, n_pos)
            hist2d = hist2d.at[cell].add(counted.astype(jnp.int32))

        sig_cnt = jnp.int32(0)
        if testing:
            pos_sup = jnp.sum(
                lax.population_count(occ_nodes & pos_mask[None, :]), axis=1
            ).astype(jnp.int32)
            pvals = fisher_pvalue_jnp(sup, pos_sup, n, n_pos)
            sig = counted & (pvals <= delta)
            sig_cnt = jnp.sum(sig.astype(jnp.int32))
            # append (sup, pos_sup) samples of significant sets
            sig_idx = jnp.nonzero(sig, size=B, fill_value=-1)[0]
            pos = jnp.where(sig_idx >= 0, out_ptr + jnp.arange(B), cfg.out_cap + 1)
            vals = jnp.stack(
                [sup[jnp.clip(sig_idx, 0, B - 1)], pos_sup[jnp.clip(sig_idx, 0, B - 1)]],
                axis=1,
            )
            out_buf = out_buf.at[pos].set(vals, mode="drop")
            out_ptr = jnp.minimum(out_ptr + sig_cnt, cfg.out_cap)

        # ---- children
        cand = (
            accepted[:, None]
            & (item_ids > core[:, None])
            & (supports < sup[:, None])
            & (supports >= lam)
        )
        clo_cum_excl = jnp.cumsum(in_clo.astype(jnp.int32), axis=1) - in_clo.astype(jnp.int32)
        flat = cand.reshape(-1)
        cand_idx = jnp.nonzero(flat, size=C, fill_value=-1)[0]
        valid_child = cand_idx >= 0
        n_taken = jnp.sum(valid_child.astype(jnp.int32))
        child_b = jnp.clip(cand_idx // m, 0, B - 1)
        child_j = jnp.clip(cand_idx % m, 0, m - 1)
        child_occ = occ_nodes[child_b] & db_mw[child_j]
        child_meta = jnp.stack(
            [
                child_j,
                clo_cum_excl[child_b, child_j],
                supports[child_b, child_j],
                jnp.zeros_like(child_j),
            ],
            axis=1,
        )
        push_pos = jnp.where(valid_child, sp_after + jnp.arange(C), CAP + C)
        overflow = jnp.any(valid_child & (push_pos >= CAP))
        occ_stack = occ_stack.at[push_pos].set(child_occ, mode="drop")
        meta = meta.at[push_pos].set(child_meta, mode="drop")
        sp2 = jnp.minimum(sp_after + n_taken, CAP)

        # ---- resume parents whose children overflowed the push cap
        row_counts = jnp.sum(cand.astype(jnp.int32), axis=1)
        row_offset = jnp.cumsum(row_counts) - row_counts
        taken_per_row = jnp.clip(C - row_offset, 0, row_counts)
        needs_resume = accepted & (taken_per_row < row_counts)
        pos_in_row = jnp.cumsum(cand.astype(jnp.int32), axis=1) - cand.astype(jnp.int32)
        first_untaken = cand & (pos_in_row == taken_per_row[:, None])
        cursor = jnp.argmax(first_untaken, axis=1)  # first candidate not pushed
        res_meta = jnp.stack(
            [cursor - 1, jnp.zeros(B, jnp.int32), sup, jnp.ones(B, jnp.int32)], axis=1
        )
        res_pos = jnp.where(needs_resume, sp2 + jnp.cumsum(needs_resume) - 1, CAP + C)
        overflow = overflow | jnp.any(needs_resume & (res_pos >= CAP))
        occ_stack = occ_stack.at[res_pos].set(occ_nodes, mode="drop")
        meta = meta.at[res_pos].set(res_meta, mode="drop")
        sp3 = jnp.minimum(sp2 + jnp.sum(needs_resume.astype(jnp.int32)), CAP)

        stats = stats.at[0].add(jnp.sum(alive.astype(jnp.int32)))
        stats = stats.at[1].add(jnp.sum((alive & ~ppc_ok).astype(jnp.int32)))
        stats = stats.at[2].add(jnp.sum(counted.astype(jnp.int32)))
        stats = stats.at[3].add(n_taken)
        stats = stats.at[8].add(overflow.astype(jnp.int32))
        return (occ_stack, meta, sp3, hist, hist2d, stats, out_buf, out_ptr,
                sig_cnt)

    def body(carry, db_mw, db_wm, pos_mask, thr, delta):
        (occ_stack, meta, sp, hist, hist2d, lam, t, stats, out_buf, out_ptr,
         n_sig, trace, _work) = carry
        popped_before = stats[0]
        (occ_stack, meta, sp, hist, hist2d, stats, out_buf, out_ptr,
         sig_cnt) = expand(
            occ_stack, meta, sp, hist, hist2d, lam, stats, db_mw, db_wm,
            pos_mask, out_buf, out_ptr, delta,
        )
        if cfg.trace_cap:
            trace = trace.at[jnp.minimum(t, cfg.trace_cap - 1)].add(
                stats[0] - popped_before
            )
        n_sig = n_sig + sig_cnt
        if cfg.steal_enabled:
            occ_stack, meta, sp, got, gave, k_given = steal_round(t, occ_stack, meta, sp)
            stats = stats.at[4].add(got)
            stats = stats.at[5].add(gave)
            stats = stats.at[9].add(k_given)
        stats = stats.at[6].add((sp == 0).astype(jnp.int32))
        stats = stats.at[7].add(1)

        if dyn_lambda:
            # one fused collective: [histogram | stack size] (paper §4.4's
            # piggyback of the counter onto the termination traffic)
            packed = lax.psum(jnp.concatenate([hist, sp[None]]), axis)
            g_hist, work = packed[:NB], packed[NB]
            cs = jnp.cumsum(g_hist[::-1])[::-1]  # cs[x] = #closed with sup >= x
            cond = cs > thr
            best = jnp.max(jnp.where(cond, jnp.arange(NB), 0))
            lam = jnp.maximum(lam, jnp.maximum(best + 1, 1)).astype(jnp.int32)
        else:
            work = lax.psum(sp, axis)
        return (occ_stack, meta, sp, hist, hist2d, lam, t + 1, stats, out_buf,
                out_ptr, n_sig, trace, work)

    def program(init_occ, init_meta, init_sp, db_mw, db_wm, pos_mask, thr,
                lam0, delta):
        # per-device views arrive with a leading length-1 shard axis
        occ_stack = init_occ[0]
        meta = init_meta[0]
        sp = init_sp[0]
        hist = jnp.zeros(NB, jnp.int32)
        hist2d = jnp.zeros(NB2, jnp.int32)
        stats = jnp.zeros(_NSTAT, jnp.int32)
        out_buf = jnp.zeros((cfg.out_cap, 2), jnp.int32)
        out_ptr = jnp.int32(0)
        n_sig = jnp.int32(0)
        t = jnp.int32(0)
        trace = jnp.zeros(max(cfg.trace_cap, 1), jnp.int32)

        def cond_fn(carry):
            t = carry[5]
            work = carry[-1]  # psum'd at the previous superstep boundary:
            return (work > 0) & (t < cfg.max_steps)  # exact BSP termination

        work0 = lax.psum(sp, axis)
        carry = (occ_stack, meta, sp, hist, hist2d, lam0, t, stats, out_buf,
                 out_ptr, n_sig, trace, work0)
        carry = lax.while_loop(
            cond_fn, lambda c: body(c, db_mw, db_wm, pos_mask, thr, delta), carry
        )
        (_, _, _, hist, hist2d, lam, t, stats, out_buf, out_ptr, n_sig, trace,
         _) = carry
        g_hist = lax.psum(hist, axis)
        g_hist2d = lax.psum(hist2d, axis)  # once, at termination — not per step
        g_sig = lax.psum(n_sig, axis)
        return (
            g_hist, lam, t, stats[None], out_buf[None], out_ptr[None], g_sig,
            trace[None], g_hist2d,
        )

    return program


def mine(
    db_bool: np.ndarray,
    labels: np.ndarray | None = None,
    *,
    mode: str = "lamp1",
    alpha: float = 0.05,
    min_sup: int = 1,
    delta: float = 0.0,
    cfg: EngineConfig = EngineConfig(),
    devices=None,
) -> MineOutput:
    """Run one engine pass over all (or the given) local devices."""
    assert mode in ("lamp1", "count", "test", "count2d")
    db_bool = np.asarray(db_bool, dtype=bool)
    n, m = db_bool.shape
    w = num_words(n)
    if devices is None:
        devices = jax.devices()
    n_proc = len(devices)
    mesh = Mesh(np.array(devices), ("miners",))
    schedule = build_schedule(n_proc, cfg.n_random_perms, cfg.seed)

    if labels is not None:
        labels = np.asarray(labels, dtype=bool)
        n_pos = int(labels.sum())
        pos_mask_bits = pack_db(labels[:, None])[0]  # [W]
    else:
        n_pos = max(1, n // 2)
        pos_mask_bits = np.zeros(w, dtype=np.uint32)

    start_sup = min_sup if mode != "lamp1" else 1
    db_bits, init_occ, init_meta, init_sp, root_sup = preprocess(
        db_bool, n_proc, cfg, start_sup
    )
    thr = _thresholds_int(n, n_pos, alpha)

    program = build_mine_step(
        n=n, n_pos=n_pos, m=m, w=w, cfg=cfg, schedule=schedule, mode=mode
    )
    shardy = jax.shard_map(
        program,
        mesh=mesh,
        in_specs=(
            P("miners"), P("miners"), P("miners"),  # stacks
            P(), P(), P(), P(),  # db_mw, db_wm, pos_mask, thr
            P(), P(),  # lam0, delta
        ),
        out_specs=(P(), P(), P(), P("miners"), P("miners"), P("miners"), P(),
                   P("miners"), P()),
        check_vma=False,
    )
    lam0 = np.int32(start_sup)
    out = jax.jit(shardy)(
        init_occ, init_meta, init_sp,
        db_bits, np.ascontiguousarray(db_bits.T), pos_mask_bits, thr,
        lam0, np.float32(delta),
    )
    (g_hist, lam, t, stats, out_buf, out_ptr, g_sig, trace,
     g_hist2d) = jax.tree.map(np.asarray, out)
    # count the root closed set (clo of the empty itemset), support = N
    g_hist = g_hist.copy()
    if root_sup >= start_sup:
        g_hist[root_sup] += 1
        if mode == "lamp1":
            # replay the lambda recursion including the root contribution
            cs = np.cumsum(g_hist[::-1])[::-1]
            cond = cs > thr
            best = int(np.max(np.where(cond, np.arange(len(g_hist)), 0)))
            lam = max(int(lam), best + 1, 1)

    stats_dict = {name: stats[:, i] for i, name in enumerate(STAT_NAMES)}
    if np.any(stats_dict["overflow"]):
        raise RuntimeError("stack overflow in engine: increase stack_cap/push_cap")
    if int(t) >= cfg.max_steps:
        raise RuntimeError("engine hit max_steps before termination")

    sig_sup = sig_pos = None
    n_sig = int(g_sig)
    if mode == "test":
        bufs, ptrs = out_buf, out_ptr.reshape(-1)
        rows = [bufs[p, : int(ptrs[p])] for p in range(n_proc)]
        allrows = np.concatenate(rows, axis=0) if rows else np.zeros((0, 2), np.int32)
        sig_sup, sig_pos = allrows[:, 0], allrows[:, 1]
        # root significance (host-side, same test as on device)
        if root_sup >= start_sup and labels is not None:
            from .fisher import fisher_pvalue

            p_root = fisher_pvalue(root_sup, n_pos, n, n_pos)[0]
            if p_root <= delta:
                n_sig += 1

    hist2d = None
    if mode == "count2d":
        hist2d = g_hist2d.reshape(n + 1, n_pos + 1).copy()
        if root_sup >= start_sup:
            hist2d[root_sup if root_sup <= n else n, n_pos] += 1
    return MineOutput(
        hist=g_hist,
        lam_final=int(lam),
        supersteps=int(t),
        stats=stats_dict,
        sig_count=n_sig,
        sig_sup=sig_sup,
        sig_pos_sup=sig_pos,
        trace=trace if cfg.trace_cap else None,
        hist2d=hist2d,
    )


def lamp_distributed(
    db_bool: np.ndarray,
    labels: np.ndarray,
    alpha: float = 0.05,
    cfg: EngineConfig = EngineConfig(),
    devices=None,
    fuse_phase23: bool = False,
):
    """Full distributed LAMP (paper §3.3 + §4). Returns a dict.

    fuse_phase23=True (beyond-paper, EXPERIMENTS.md §Perf): one enumeration
    pass builds a 2-D (support x pos-support) histogram; P-values depend only
    on that pair, so the correction factor AND the significant count both fall
    out of the histogram — the third engine pass disappears entirely.
    """
    # phase 1: support increase -> lambda_final, min_sup
    p1 = mine(db_bool, labels, mode="lamp1", alpha=alpha, cfg=cfg, devices=devices)
    min_sup = max(p1.lam_final - 1, 1)

    if fuse_phase23:
        n = db_bool.shape[0]
        n_pos = int(np.asarray(labels, bool).sum())
        p2 = mine(db_bool, labels, mode="count2d", min_sup=min_sup, cfg=cfg,
                  devices=devices)
        h2 = p2.hist2d
        sups_grid = np.arange(n + 1)
        mask = (h2 > 0) & (sups_grid[:, None] >= min_sup)
        k = int(h2[mask].sum())
        delta = alpha / max(k, 1)
        xs, ns = np.nonzero(mask)
        from .fisher import fisher_pvalue

        pv = fisher_pvalue(xs, ns, n, n_pos) if len(xs) else np.zeros(0)
        sig_mask = pv <= delta
        n_sig = int(h2[xs[sig_mask], ns[sig_mask]].sum()) if len(xs) else 0
        return {
            "lambda_final": p1.lam_final,
            "min_sup": min_sup,
            "correction_factor": k,
            "delta": delta,
            "n_significant": n_sig,
            "phase_outputs": (p1, p2),
        }

    # phase 2: exact closed-set count at min_sup
    p2 = mine(db_bool, labels, mode="count", min_sup=min_sup, cfg=cfg, devices=devices)
    k = int(p2.hist[min_sup:].sum())
    delta = alpha / max(k, 1)
    # phase 3: significance testing at delta
    p3 = mine(
        db_bool, labels, mode="test", min_sup=min_sup, delta=delta,
        cfg=cfg, devices=devices,
    )
    return {
        "lambda_final": p1.lam_final,
        "min_sup": min_sup,
        "correction_factor": k,
        "delta": delta,
        "n_significant": p3.sig_count,
        "phase_outputs": (p1, p2, p3),
    }
