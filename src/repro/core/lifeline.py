"""Lifeline graph: hypercube with random edges (paper §4.2, Saraswat GLB).

The paper sets l=2 (binary hypercube of smallest dimension z with P <= 2^z)
and w=1 random steal attempts.  The BSP adaptation needs *permutations* for
`lax.ppermute`, which are static per call site:

  * hypercube dim d  ->  the involution  i <-> i XOR 2^d  (pairs where both
    endpoints exist; GLB's "hypercube with holes" for non-power-of-two P)
  * random edges     ->  a fixed pool of R random permutations drawn at launch
    (the paper's random victim choice, frozen into the round schedule; the
    lifeline graph itself is likewise fixed per run)

The steal schedule cycles:  random, hc_0, random, hc_1, ..., random, hc_{z-1},
so every (z+... ) window contains w=1 random attempt per lifeline attempt,
mirroring the paper's Steal() loop (1 random try then the z lifeline tries).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["LifelineSchedule", "build_schedule"]


@dataclass(frozen=True)
class LifelineSchedule:
    n_proc: int
    dim: int  # z
    # each entry: (request_pairs, reply_pairs) as tuples of (src, dst) in
    # *global* miner-rank coordinates — what the census-indexed REQUEST
    # table and any single-axis mesh consume
    rounds: tuple
    names: tuple  # debug labels, e.g. ("rand0", "hc0", "rand1", "hc1", ...)
    # -------- two-level (topology-factorized) extension; repro.topo -------
    # A hierarchical schedule additionally factorizes every round onto ONE
    # mesh axis of the [hosts, local] topo mesh: `round_axes[r]` names that
    # axis and `axis_rounds[r]` holds the same (request, reply) pairs in
    # that axis's own coordinates (identical pairing replicated along the
    # other axis).  None (the flat default) means the schedule can only run
    # on a 1-D mesh via its global `rounds`.
    round_axes: tuple | None = None
    axis_rounds: tuple | None = None
    # per-round steal tier for telemetry: "local" (intra-host) | "cross"
    # (host-crossing) | "flat" (one-level schedule — no tier structure)
    tiers: tuple | None = None

    @property
    def n_rounds(self) -> int:
        return len(self.rounds)

    @property
    def factorized(self) -> bool:
        """True when every round maps onto a single topo-mesh axis."""
        return self.round_axes is not None

    def round_tier(self, r: int) -> str:
        return "flat" if self.tiers is None else self.tiers[r]


def _hypercube_pairs(p: int, d: int):
    pairs = []
    for i in range(p):
        j = i ^ (1 << d)
        if j < p:
            pairs.append((i, j))
    return tuple(pairs)  # involution: request and reply use the same pairs


def _random_perm_pairs(p: int, rng: np.random.Generator):
    # derangement-ish: resample until no fixed points (self-steals are wasted)
    while True:
        perm = rng.permutation(p)
        if p == 1 or not np.any(perm == np.arange(p)):
            break
    req = tuple((i, int(perm[i])) for i in range(p))
    inv = np.empty(p, dtype=np.int64)
    inv[perm] = np.arange(p)
    rep = tuple((i, int(inv[i])) for i in range(p))
    return req, rep


def build_schedule(n_proc: int, n_random: int = 4, seed: int = 0) -> LifelineSchedule:
    """Cyclic steal-round schedule for P processes (paper: l=2, w=1)."""
    assert n_proc >= 1
    z = max(1, int(np.ceil(np.log2(max(n_proc, 2)))))
    rng = np.random.default_rng(seed)
    rounds = []
    names = []
    n_random = max(1, n_random)
    ri = 0
    for d in range(z):
        req, rep = _random_perm_pairs(n_proc, rng)
        rounds.append((req, rep))
        names.append(f"rand{ri}")
        ri += 1
        hc = _hypercube_pairs(n_proc, d)
        rounds.append((hc, hc))
        names.append(f"hc{d}")
    # extra random permutations to decorrelate long runs
    for _ in range(max(0, n_random - z)):
        req, rep = _random_perm_pairs(n_proc, rng)
        rounds.append((req, rep))
        names.append(f"rand{ri}")
        ri += 1
    return LifelineSchedule(n_proc=n_proc, dim=z, rounds=tuple(rounds), names=tuple(names))
