"""Named per-miner engine counters (the `stats` vector in the BSP carry).

Every phase module indexes the shared `stats [len(Stat)]i32` array through
`Stat.*` members — never through magic integers — so a carry-layout change
cannot silently misattribute a counter.  `STAT_NAMES` (the key order of
`MineOutput.stats`) is derived from the enum, keeping the device vector and
the host dict in lockstep by construction.
"""

from __future__ import annotations

import enum

__all__ = ["Stat", "STAT_NAMES"]


class Stat(enum.IntEnum):
    """Index of each counter in the per-miner stats vector."""

    POPPED = 0         # nodes popped alive (sup >= lambda) by EXPAND
    REJECTED = 1       # alive pops failing the deferred-PPC check
    CLOSED = 2         # closed sets counted into the histogram
    PUSHED = 3         # children pushed
    STEALS_GOT = 4     # steal replies received with nodes
    GIVES = 5          # donations made
    IDLE_STEPS = 6     # supersteps ended with an empty stack
    SUPERSTEPS = 7     # superstep count (per miner; all equal)
    OVERFLOW = 8       # stack/push-cap overflow events (fatal in postprocess)
    STOLEN_NODES = 9   # total nodes donated
    EMIT_DROPPED = 10  # pattern records lost to out_cap saturation
    STEAL_ROUNDS = 11  # hunger-gated exchange rounds actually executed
    TRACE_DROPPED = 12  # sampled trace records lost to ring saturation


STAT_NAMES = tuple(s.name.lower() for s in Stat)
