"""Version-portable distributed-runtime layer (the engine's only JAX surface).

The paper (§4) argues that a scalable miner needs a "well-engineered
communication protocol" kept *separate* from the mining logic.  This module
is that separation for the JAX substrate: every version-sensitive JAX API the
BSP engine depends on — `shard_map`, the SPMD collectives, mesh construction,
simulated multi-host device counts, and compiled-artifact cost introspection —
is wrapped here, so the superstep-phase modules (expand/steal/global_sync) and
the launchers never import a moving target directly.

Portability shims handled here:

  * `shard_map` location:  `jax.shard_map` (new) -> `jax.sharding.shard_map`
    (transitional) -> `jax.experimental.shard_map.shard_map` (old).
  * The replication-check kwarg rename: `check_vma` (new) vs `check_rep`
    (old).  `shard_map()` below accepts `check_replication=` and forwards to
    whichever kwarg the resolved function actually takes.
  * `Compiled.cost_analysis()` return type: dict (old) vs single-element
    list-of-dict (new).  `normalize_cost_analysis()` always returns a dict.

Everything else (`psum`, `ppermute`, mesh building, host device-count
forcing) is stable across the versions we target but lives here anyway so the
engine has exactly one import for its distributed runtime.
"""

from __future__ import annotations

import functools
import inspect
import os
from typing import Any, Callable, Sequence

import numpy as np

import jax
from jax import lax
from jax.sharding import Mesh, PartitionSpec

__all__ = [
    "MINERS_AXIS",
    "HOSTS_AXIS",
    "LOCAL_AXIS",
    "TOPO_AXES",
    "resolve_shard_map",
    "shard_map",
    "psum",
    "ppermute",
    "axis_index",
    "make_miner_mesh",
    "make_topo_mesh",
    "force_host_device_count",
    "host_device_count_env",
    "device_count",
    "normalize_cost_analysis",
]

# The engine's canonical 1-D mesh axis: one logical miner per device.
MINERS_AXIS = "miners"

# The 2-D topology mesh axes (repro.topo): miners laid out
# [n_hosts, devices_per_host]; global rank = hosts-index * dph + local-index.
HOSTS_AXIS = "hosts"
LOCAL_AXIS = "local"
TOPO_AXES = (HOSTS_AXIS, LOCAL_AXIS)

_CHECK_KWARGS = ("check_vma", "check_rep")  # newest first


@functools.lru_cache(maxsize=1)
def resolve_shard_map() -> Callable:
    """Locate `shard_map` across JAX versions (newest location first)."""
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        fn = getattr(jax.sharding, "shard_map", None)
    if fn is None:
        try:
            from jax.experimental.shard_map import shard_map as fn  # type: ignore
        except ImportError:  # pragma: no cover - no known jax lacks all three
            fn = None
    if fn is None:  # pragma: no cover
        raise ImportError(
            "no shard_map found in jax, jax.sharding, or jax.experimental"
        )
    return fn


@functools.lru_cache(maxsize=1)
def _check_kwarg_name() -> str | None:
    """Which replication-check kwarg the resolved shard_map accepts."""
    fn = resolve_shard_map()
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):  # pragma: no cover - C-implemented fn
        return _CHECK_KWARGS[0]
    if any(p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()):
        return _CHECK_KWARGS[0]
    for name in _CHECK_KWARGS:
        if name in params:
            return name
    return None


def shard_map(
    f: Callable,
    *,
    mesh: Mesh,
    in_specs,
    out_specs,
    check_replication: bool = False,
) -> Callable:
    """Version-portable `shard_map(f)` with the check kwarg normalized.

    `check_replication=False` (the engine default) disables the static
    replication/VMA checker: the BSP program's out_specs deliberately mix
    replicated collective results with per-miner outputs, which old checkers
    reject.
    """
    sm = resolve_shard_map()
    kwargs: dict[str, Any] = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    check_name = _check_kwarg_name()
    if check_name is not None:
        kwargs[check_name] = check_replication
    try:
        return sm(f, **kwargs)
    except TypeError:
        # Signature introspection lied (e.g. a wrapper without the kwarg):
        # retry with the other spelling, then bare.
        for name in _CHECK_KWARGS:
            if name == check_name:
                continue
            try:
                kw = dict(kwargs)
                kw.pop(check_name, None)
                kw[name] = check_replication
                return sm(f, **kw)
            except TypeError:
                pass
        kwargs.pop(check_name, None)
        return sm(f, **kwargs)


# ---------------------------------------------------------------- collectives
# Thin aliases today, but they pin the engine's collective surface to this
# module: a non-XLA backend (or a tracing/shim layer) only has to replace
# these two functions and `shard_map` above.

def psum(x, axis_name=MINERS_AXIS):
    """Sum `x` across the mesh axis (every miner gets the total).

    A *tuple* of axis names runs the staged hierarchical reduction: the
    last-named (innermost, intra-host) axis first, then outward — on the
    topo mesh that is one cheap on-host stage followed by one cross-host
    stage over already-reduced values.  Integer sums commute, so the result
    is bit-identical to a flat single-axis psum over the same miners.
    """
    if isinstance(axis_name, tuple):
        for name in reversed(axis_name):
            x = lax.psum(x, name)
        return x
    return lax.psum(x, axis_name)


def ppermute(x, perm: Sequence[tuple[int, int]], axis_name: str = MINERS_AXIS):
    """Point-to-point permutation: (src, dst) pairs; absent dst receives 0."""
    return lax.ppermute(x, axis_name, perm=list(perm))


def axis_index(axis_name=MINERS_AXIS):
    """This miner's position on the mesh axis (0..P-1), as a traced scalar.

    A *tuple* of axis names yields the flattened row-major rank — on the
    topo mesh `(HOSTS_AXIS, LOCAL_AXIS)` that is the global miner rank
    `host * devices_per_host + local`, matching `Topology.rank_of`.
    """
    if isinstance(axis_name, tuple):
        idx = lax.axis_index(axis_name[0])
        for name in axis_name[1:]:
            idx = idx * lax.psum(1, name) + lax.axis_index(name)
        return idx
    return lax.axis_index(axis_name)


# ----------------------------------------------------------------------- mesh
def make_miner_mesh(devices=None, axis_name: str = MINERS_AXIS) -> Mesh:
    """1-D mesh over all (or the given) devices — one logical miner each."""
    if devices is None:
        devices = jax.devices()
    return Mesh(np.asarray(devices), (axis_name,))


def make_topo_mesh(topology, devices=None) -> Mesh:
    """[n_hosts, devices_per_host] mesh with axes ("hosts", "local").

    `devices` defaults to every global device; jax orders them by process
    (each process owns a contiguous block), so the row-major reshape puts
    host h's devices in mesh row h and global rank = h * dph + l — exactly
    `Topology.rank_of`.  A single process can *simulate* a multi-host shape
    by reshaping its local devices the same way (the cross-host axis then
    permutes on-host, semantically identical, latency aside).
    """
    if devices is None:
        devices = jax.devices()
    devices = np.asarray(devices)
    if devices.size != topology.n_proc:
        raise ValueError(
            f"topology {topology} needs {topology.n_proc} devices, "
            f"got {devices.size}"
        )
    grid = devices.reshape(topology.n_hosts, topology.devices_per_host)
    return Mesh(grid, TOPO_AXES)


def device_count() -> int:
    return jax.device_count()


_FORCE_FLAG = "--xla_force_host_platform_device_count"


def host_device_count_env(n: int, env: dict | None = None) -> dict:
    """Return a copy of `env` (default os.environ) with XLA_FLAGS forcing `n`
    simulated host devices, replacing any existing device-count flag.

    For use when building a *subprocess* environment: the flag must precede
    the child's first jax init.
    """
    env = dict(os.environ if env is None else env)
    flags = [
        f for f in env.get("XLA_FLAGS", "").split() if not f.startswith(_FORCE_FLAG)
    ]
    flags.insert(0, f"{_FORCE_FLAG}={int(n)}")
    env["XLA_FLAGS"] = " ".join(flags)
    return env


def force_host_device_count(n: int) -> bool:
    """Force `n` simulated host devices in *this* process.

    Must run before the first jax backend init (jax locks the device count
    then).  Returns True if the setting can still take effect, False if jax
    is already initialized with a different count (callers should then fall
    back to a subprocess with `host_device_count_env`).
    """
    os.environ["XLA_FLAGS"] = host_device_count_env(n)["XLA_FLAGS"]
    try:
        already = jax._src.xla_bridge._backends  # type: ignore[attr-defined]
        initialized = bool(already)
    except Exception:  # pragma: no cover - private API moved; assume live
        initialized = True
    return (not initialized) or jax.device_count() == n


# ------------------------------------------------------------ cost analysis
def normalize_cost_analysis(cost) -> dict:
    """Normalize `Compiled.cost_analysis()` across JAX versions.

    Old JAX returns a dict; newer JAX returns a list with one dict per
    partition (usually length 1).  Multi-entry lists are merged by summing
    numeric values (per-partition costs of one SPMD program).  Always returns
    a plain dict; {} for None/empty.
    """
    if cost is None:
        return {}
    if isinstance(cost, dict):
        return dict(cost)
    if isinstance(cost, (list, tuple)):
        merged: dict = {}
        for entry in cost:
            if not isinstance(entry, dict):
                continue
            for k, v in entry.items():
                if isinstance(v, (int, float)) and isinstance(
                    merged.get(k, 0.0), (int, float)
                ):
                    merged[k] = merged.get(k, 0.0) + v
                else:
                    merged.setdefault(k, v)
        return merged
    raise TypeError(f"unrecognized cost_analysis() return: {type(cost)!r}")
