"""Superstep phase 3 — GLOBAL: fused histogram psum, lambda, termination.

mode="lamp1": one fused collective carries [histogram | stack size] — the
paper §4.4's piggyback of the frequency counter onto the termination traffic
(staleness only costs work, never correctness) — then lambda is recomputed
from the global histogram.  Other modes psum only the stack sizes.

The returned `work` (global outstanding nodes) drives the exact BSP
termination test: `work == 0` at a superstep boundary implies no work and no
in-flight messages, because collectives complete before the check (paper
§4.3's DTD is only needed on the async host plane; core/termination.py).

`recompute_lambda` is shared between the on-device update (jnp, inside the
compiled loop) and the host-side replay in `engine.mine()` that folds the
root closed set into the final lambda (np).
"""

from __future__ import annotations

import jax.numpy as jnp

from .collectives import MINERS_AXIS, psum

__all__ = ["recompute_lambda", "build_global_sync"]


def recompute_lambda(g_hist, thr, lam, xp=jnp):
    """Largest lambda with CS(lambda) <= thr, never decreasing (paper §3.2).

    g_hist [NB] global closed-set histogram, thr [NB] integer Tarone
    thresholds, lam the current lambda.  Works for jnp (device) and np (host
    replay) alike.
    """
    nb = g_hist.shape[0]
    cs = xp.cumsum(g_hist[::-1])[::-1]  # cs[x] = #closed with sup >= x
    cond = cs > thr
    best = xp.max(xp.where(cond, xp.arange(nb), 0))
    return xp.maximum(xp.maximum(lam, best + 1), 1)


def build_global_sync(*, nb: int, mode: str, axis: str = MINERS_AXIS):
    """Returns global_sync(hist, sp, lam, thr) -> (lam, work)."""
    dyn_lambda = mode == "lamp1"

    def global_sync(hist, sp, lam, thr):
        if dyn_lambda:
            # one fused collective: [histogram | stack size]
            packed = psum(jnp.concatenate([hist, sp[None]]), axis)
            g_hist, work = packed[:nb], packed[nb]
            lam = recompute_lambda(g_hist, thr, lam).astype(jnp.int32)
        else:
            work = psum(sp, axis)
        return lam, work

    return global_sync
