"""Superstep phase 3 — GLOBAL: hunger census, periodic lambda sync, termination.

The per-superstep collective footprint is one tiny psum: `hunger_census`
sums the one-hot "my stack is empty" vector, so every miner learns *which*
miners are hungry ([P] ints, 4P bytes).  The census serves three masters:
its sum gates the steal exchange (no payload ppermute unless someone is
hungry), the vector itself replaces the steal round's REQUEST ppermute (the
victim reads its requester's bit out of the census — core/steal.py), and
`n_hungry == P` is the exact BSP termination test — the census runs after
EXPAND, the steal round only redistributes nodes, so an all-hungry census
at a superstep boundary implies zero outstanding work and no in-flight
messages (collectives complete before the check; paper §4.3's DTD is only
needed on the async host plane, core/termination.py).

mode="lamp1" additionally syncs the support histogram — but only every
`sync_period` supersteps, and only the *delta* accumulated since the last
sync (paper §4.4: the frequency counter piggybacks on whatever traffic
already flows, and its staleness only costs extra work, never correctness:
any closed set with support >= the final lambda survives every stale-lambda
pruning decision, so the final lambda and every reported result are
invariant; only sub-lambda histogram diagnostics and the superstep count can
move).  `lax.cond` keeps the [n+2]-bin psum out of the non-boundary rounds
entirely; the predicate is the replicated step counter, so every miner takes
the same branch.

`recompute_lambda` is shared between the on-device update (jnp, inside the
compiled loop) and the host-side replay in `engine.postprocess_phase` that
folds the root closed set into the final lambda (np).
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from .collectives import MINERS_AXIS, axis_index, psum

__all__ = ["hunger_census", "recompute_lambda", "build_global_sync"]


def hunger_census(sp, n_proc: int, axis=MINERS_AXIS):
    """[P]-int psum of the one-hot hunger bit: who is out of work right now.

    `vec[i] == 1` iff miner i's stack is empty; `vec.sum()` is the gate /
    termination count.  4P bytes buys the whole REQUEST side of the steal
    handshake — one collective where the old design used two.

    On the 2-D topo mesh (`axis` = ("hosts", "local")) the census splits
    into two stages: one intra-host psum (after which every device holds
    its *host's* partial census — already enough for a local steal round)
    followed by one cross-host psum of the partials.  `collectives.psum`
    runs the stages innermost-first; integer addition commutes, so the
    result is bit-identical to the flat single-axis census.
    """
    vec = jnp.zeros(n_proc, jnp.int32).at[axis_index(axis)].set(
        (sp == 0).astype(jnp.int32)
    )
    return psum(vec, axis)


def recompute_lambda(g_hist, thr, lam, xp=jnp):
    """Largest lambda with CS(lambda) <= thr, never decreasing (paper §3.2).

    g_hist [NB] global closed-set histogram, thr [NB] integer Tarone
    thresholds, lam the current lambda.  Works for jnp (device) and np (host
    replay) alike.
    """
    nb = g_hist.shape[0]
    cs = xp.cumsum(g_hist[::-1])[::-1]  # cs[x] = #closed with sup >= x
    cond = cs > thr
    best = xp.max(xp.where(cond, xp.arange(nb), 0))
    return xp.maximum(xp.maximum(lam, best + 1), 1)


def build_global_sync(*, nb: int, mode: str, sync_period: int = 1,
                      axis=MINERS_AXIS):
    """Returns global_sync(t, hist, hist_snap, g_hist, lam, thr)
    -> (lam, g_hist, hist_snap).

    `hist` is the local full histogram, `hist_snap` its value at the last
    sync, `g_hist` the merged global histogram as of the last sync.  For
    modes other than "lamp1" the call is the identity (their lambda is a
    static min_sup) and the engine carries 1-element dummies.
    """
    dyn_lambda = mode == "lamp1"
    assert sync_period >= 1

    def global_sync(t, hist, hist_snap, g_hist, lam, thr):
        if not dyn_lambda:
            return lam, g_hist, hist_snap

        def do_sync(ops):
            hist, hist_snap, g_hist, lam = ops
            g_hist = g_hist + psum(hist - hist_snap, axis)  # delta only
            lam = recompute_lambda(g_hist, thr, lam).astype(jnp.int32)
            return lam, g_hist, hist

        def skip(ops):
            hist, hist_snap, g_hist, lam = ops
            return lam, g_hist, hist_snap

        if sync_period == 1:
            return do_sync((hist, hist_snap, g_hist, lam))
        return lax.cond(
            (t + 1) % sync_period == 0,
            do_sync, skip, (hist, hist_snap, g_hist, lam),
        )

    return global_sync
