"""LAMP — limitless-arity multiple testing procedure (paper §3) — host reference.

Three phases (paper §3.3):

  Phase 1  support-increase: a single LCM run with a dynamically rising support
           threshold lambda.  Maintain bucket counts cnt[s] = #closed sets with
           support exactly s found so far; advance lambda while

               CS(lambda) > alpha / f(lambda - 1)          (Eq. 3.1 rearranged)

           where CS(lambda) = sum_{s >= lambda} cnt[s].  Subtrees with support
           < lambda are pruned — they can only touch buckets whose condition is
           already (permanently) satisfied.  Terminates with lambda_final;
           min_sup = lambda_final - 1.

  Phase 2  count k = CS(min_sup) exactly with a fresh frequent-closed mining at
           min_sup.  delta = alpha / k is the corrected significance level.

  Phase 3  Fisher-exact test every closed set with support >= min_sup against
           delta; emit the significant ones.

The distributed engine (core/engine.py) runs the same schedule with the bucket
histogram psum'd across devices every superstep (paper §4.4: the lambda
broadcast may lag without affecting correctness — a stale, smaller lambda only
prunes less).

This module is the sequential oracle used by tests and small benchmarks.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.stats import get_statistic

from .bitmap import pack_db, support_np
from .lcm import MiningStats, lcm_closed

__all__ = ["LampResult", "SignificantPattern", "lamp_phase1", "lamp", "Phase1State"]


@dataclass
class SignificantPattern:
    items: frozenset
    support: int
    pos_support: int
    pvalue: float


@dataclass
class LampResult:
    n_transactions: int
    n_pos: int
    alpha: float
    lambda_final: int  # lambda at phase-1 termination
    min_sup: int  # = lambda_final - 1 (paper: "smaller than the last lambda by 1")
    correction_factor: int  # k = CS(min_sup) from phase 2
    delta: float  # alpha / k
    significant: list[SignificantPattern]
    phase1_stats: MiningStats | None = None
    phase2_stats: MiningStats | None = None


class Phase1State:
    """Support-increase bookkeeping shared by the oracle and the engine tests.

    `statistic` names the registered `repro.stats.TestStatistic` whose
    Tarone bound drives the thresholds (default: Fisher, the paper's test).
    """

    def __init__(self, n_transactions: int, n_pos: int, alpha: float,
                 statistic: str = "fisher"):
        self.N = n_transactions
        self.thr = get_statistic(statistic).count_thresholds(
            n_transactions, n_pos, alpha
        )
        self.cnt = np.zeros(n_transactions + 2, dtype=np.int64)
        self.lam = 1

    def cs(self, lam: int) -> int:
        return int(self.cnt[lam:].sum())

    def observe(self, support: int) -> int:
        """Count one closed itemset; advance lambda per Eq 3.1; return new lambda."""
        if support >= self.lam:
            self.cnt[support] += 1
            while self.lam <= self.N and self.cs(self.lam) > self.thr[self.lam]:
                self.lam += 1
        return self.lam


def lamp_phase1(db_bool: np.ndarray, n_pos: int, alpha: float,
                statistic: str = "fisher"):
    """Run phase 1; returns (lambda_final, min_sup, stats)."""
    db_bool = np.asarray(db_bool, dtype=bool)
    n = db_bool.shape[0]
    state = Phase1State(n, n_pos, alpha, statistic)
    _, stats = lcm_closed(db_bool, min_sup=1, dynamic_min_sup=state.observe)
    lam_final = state.lam
    return lam_final, max(lam_final - 1, 1), stats


def lamp(db_bool: np.ndarray, labels: np.ndarray, alpha: float = 0.05,
         statistic: str = "fisher") -> LampResult:
    """Full three-phase LAMP on a labelled transaction database.

    db_bool: [N, M] bool; labels: [N] bool (positive class); `statistic`
    selects the registered test (Tarone bound AND phase-3 extraction).
    """
    db_bool = np.asarray(db_bool, dtype=bool)
    labels = np.asarray(labels, dtype=bool)
    n, m = db_bool.shape
    n_pos = int(labels.sum())
    stat = get_statistic(statistic)

    # ---- phase 1: find min_sup by support increase
    lam_final, min_sup, st1 = lamp_phase1(db_bool, n_pos, alpha, statistic)

    # ---- phase 2: exact closed-set count at min_sup (+ collect for phase 3)
    from .bitmap import unpack_occ  # local import to avoid cycle at module load

    collected: list[tuple[frozenset, int, int]] = []
    pos_mask = labels

    def on_closed(occ, sup, clo_items):
        occ_bool = unpack_occ(occ, n)
        pos_sup = int(np.count_nonzero(occ_bool & pos_mask))
        collected.append((frozenset(clo_items.tolist()), sup, pos_sup))

    _, st2 = lcm_closed(db_bool, min_sup=min_sup, on_closed=on_closed)
    k = len(collected)
    delta = alpha / max(k, 1)

    # ---- phase 3: exact extraction (paper: ~10 ms; merged sweep here)
    significant = []
    if k:
        sups = np.array([c[1] for c in collected])
        pos_sups = np.array([c[2] for c in collected])
        pvals = stat.pvalue(sups, pos_sups, n, n_pos)
        for (items, sup, psup), p in zip(collected, pvals):
            if p <= delta:
                significant.append(SignificantPattern(items, sup, psup, float(p)))
    significant.sort(key=lambda s: s.pvalue)

    return LampResult(
        n_transactions=n,
        n_pos=n_pos,
        alpha=alpha,
        lambda_final=lam_final,
        min_sup=min_sup,
        correction_factor=k,
        delta=delta,
        significant=significant,
        phase1_stats=st1,
        phase2_stats=st2,
    )
