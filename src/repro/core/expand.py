"""Superstep phase 1 — EXPAND: popcount-GEMM expansion + deferred-PPC.

Pops up to `expand_batch` nodes from the local stack; one popcount-GEMM
(`supports_gemm`) gives every extension's support; deferred-PPC validation,
closed-set counting, pattern-record emission (modes "test"/"count2d"),
2-D histogram accumulation (mode="count2d"), child generation, and the
resume-node path for parents whose children overflowed the per-superstep
push cap (core/lcm.py documents the deferred-PPC scheme).

Pattern emission (DESIGN.md §4): a significant node appends a fixed-size
record — occurrence bitmap [W]u32 into `out_occ` plus (core, sup, pos_sup)
i32 into `out_meta`, the same steal-friendly payload shape as stack nodes —
for host-side closure reconstruction in repro.results.  mode="test" emits at
the corrected level `delta`; mode="count2d" emits the alpha-level superset
(delta is unknown until the 2-D histogram is reduced, and delta <= alpha
always, so the host can filter down exactly).  Emissions past `out_cap` are
dropped but *counted* in the emit_dropped stat so the host can warn.

This phase is pure per-miner compute — no collectives — so it is the natural
unit to retarget at an accelerator kernel: `supports_gemm` routes through the
single dispatch point in kernels/support_count/ops (DESIGN.md §8), which
selects ref / pallas / pallas_interpret / pallas_gpu per `cfg.kernel_impl`
and sweeps the item-tiled database `[T, m_tile, W]` tile by tile — the
per-superstep working set is `[B, m_tile]`-sized regardless of total items.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.kernels.support_count.ops import resolve_impl, support_counts_tiled
from repro.stats import get_statistic

from .deque import push_positions, top_indices
from .stats import Stat

__all__ = ["resolve_kernel_impl", "supports_gemm", "build_expand"]

# back-compat alias: the "auto" resolution now lives at the kernel dispatch
# point (ops.resolve_impl) so every support-count caller shares it
resolve_kernel_impl = resolve_impl


def supports_gemm(occ_nodes, db_tiles, impl: str,
                  blocks: tuple[int, int, int] | None = None):
    """[B, W] x [T, m_tile, W] -> [B, T*m_tile] support counts (traced)."""
    return support_counts_tiled(occ_nodes, db_tiles, impl=impl, blocks=blocks)


def build_expand(*, n: int, n_pos: int, m: int, cfg, mode: str,
                 statistic: str | None = "fisher"):
    """Returns the expand phase for one superstep.

    `n`, `n_pos`, `m` are the *program* (shape-bucket) dims: every array is
    sized by them, and datasets padded up to the same bucket share one
    compiled program (repro.api).  The dataset's actual transaction/positive
    counts arrive at run time as the traced scalars `n_act`/`npos_act`
    (needed only by the exact Fisher test); padded items have zero support,
    so they can never be accepted, counted, emitted, or become children.

    The stack is a circular deque (core/deque.py): `head` is the physical
    row of the logical bottom, pops read below the logical top, and pushes
    scatter above it — `head` itself only moves on steals, so EXPAND takes
    it read-only.

    `statistic` names the registered `repro.stats.TestStatistic` whose
    device P-value gates emission in modes "test"/"count2d"; it is baked
    into the traced program, so it belongs in any compiled-program cache
    key for those modes.  `statistic=None` emits *every* counted closed set
    — the runtime `delta` argument is ignored on that branch (there is no
    P-value to compare it against) — the plain closed-frequent objective:
    same traversal, no test.

    The database arrives as one item-tiled array `db_tiles` [T, m_tile, W]
    with T * m_tile == m (the program item dim; tile-tail items beyond the
    dataset's real count are all-zero columns, excluded like any bucket
    padding).  The kernel sweeps the tiles; host-style flat indexing
    (child-occ gather) uses the free `[m, W]` reshape view.

    expand(occ_stack, meta, sp, head, hist, hist2d, lam, stats, db_tiles,
           pos_mask, out_occ, out_meta, out_ptr, delta, n_act, npos_act)
      -> (occ_stack, meta, sp, hist, hist2d, stats, out_occ, out_meta,
          out_ptr, sig_cnt)
    """
    B, CAP, C = cfg.expand_batch, cfg.stack_cap, cfg.push_cap
    kernel_impl = resolve_kernel_impl(cfg.kernel_impl)
    kernel_blocks = getattr(cfg, "kernel_blocks", None)
    NB = n + 2
    testing = mode == "test"
    hist2d_mode = mode == "count2d"
    emitting = testing or hist2d_mode
    pvalue_device = (
        get_statistic(statistic).pvalue_device if statistic is not None else None
    )

    def expand(occ_stack, meta, sp, head, hist, hist2d, lam, stats, db_tiles,
               pos_mask, out_occ, out_meta, out_ptr, delta, n_act,
               npos_act):
        assert db_tiles.shape[0] * db_tiles.shape[1] == m, (db_tiles.shape, m)
        db_flat = db_tiles.reshape(m, db_tiles.shape[2])  # [m, W] view
        take = jnp.minimum(sp, B)
        rows = jnp.arange(B)
        node_idx = top_indices(head, sp, rows, CAP)
        row_valid = rows < take
        occ_nodes = occ_stack[node_idx]          # [B, W]
        meta_nodes = meta[node_idx]              # [B, 4]
        core = meta_nodes[:, 0]
        pc = meta_nodes[:, 1]
        sup = meta_nodes[:, 2]
        flags = meta_nodes[:, 3]
        sp_after = sp - take

        alive = row_valid & (sup >= lam)
        supports = supports_gemm(
            occ_nodes, db_tiles, kernel_impl, kernel_blocks
        )  # [B, M]
        item_ids = jnp.arange(m)[None, :]
        in_clo = supports == sup[:, None]
        prefix_ct = jnp.sum(in_clo & (item_ids < core[:, None]), axis=1)
        is_resume = (flags & 1) == 1
        ppc_ok = is_resume | (core < 0) | (prefix_ct == pc)
        accepted = alive & ppc_ok
        counted = accepted & (~is_resume)

        hist = hist.at[jnp.clip(sup, 0, NB - 1)].add(counted.astype(jnp.int32))

        sig_cnt = jnp.int32(0)
        if emitting:
            pos_sup = jnp.sum(
                lax.population_count(occ_nodes & pos_mask[None, :]), axis=1
            ).astype(jnp.int32)
            if hist2d_mode:
                # bucket-dim strides: sup <= n_act <= n and pos_sup <= npos_act
                # <= n_pos, so the (sup, pos_sup) -> cell map is dataset-invariant
                cell = jnp.clip(sup, 0, n) * (n_pos + 1) + jnp.clip(pos_sup, 0, n_pos)
                hist2d = hist2d.at[cell].add(counted.astype(jnp.int32))
            # emit pattern records at delta (mode="test": the corrected level;
            # mode="count2d": alpha — a superset the host filters exactly);
            # statistic=None emits every counted node (closed-frequent)
            if pvalue_device is None:
                sig = counted
            else:
                pvals = pvalue_device(sup, pos_sup, n_act, npos_act, k_max=n_pos)
                sig = counted & (pvals <= delta)
            sig_cnt = jnp.sum(sig.astype(jnp.int32))
            sig_idx = jnp.nonzero(sig, size=B, fill_value=-1)[0]
            src = jnp.clip(sig_idx, 0, B - 1)
            pos = jnp.where(sig_idx >= 0, out_ptr + jnp.arange(B), cfg.out_cap + 1)
            out_occ = out_occ.at[pos].set(occ_nodes[src], mode="drop")
            rec = jnp.stack([core[src], sup[src], pos_sup[src]], axis=1)
            out_meta = out_meta.at[pos].set(rec, mode="drop")
            # overflowing emissions are dropped by the scatter; count them
            stats = stats.at[Stat.EMIT_DROPPED].add(
                jnp.maximum(out_ptr + sig_cnt - cfg.out_cap, 0)
            )
            out_ptr = jnp.minimum(out_ptr + sig_cnt, cfg.out_cap)

        # ---- children
        cand = (
            accepted[:, None]
            & (item_ids > core[:, None])
            & (supports < sup[:, None])
            & (supports >= lam)
        )
        clo_cum_excl = jnp.cumsum(in_clo.astype(jnp.int32), axis=1) - in_clo.astype(jnp.int32)
        # compact the candidate indices via cumsum + vectorized binary
        # search: jnp.nonzero(size=C) would lower to a [B*m]-trip scalar
        # scan loop on CPU — measured as the single largest superstep cost
        flat = cand.reshape(-1).astype(jnp.int32)
        cand_cum = jnp.cumsum(flat)
        n_taken = jnp.minimum(cand_cum[-1], C)  # children pushed this step
        # index of the (c+1)-th set bit, ascending — nonzero's order exactly
        cand_idx = jnp.searchsorted(cand_cum, jnp.arange(1, C + 1), side="left")
        valid_child = cand_idx < flat.shape[0]
        cand_idx = jnp.minimum(cand_idx, flat.shape[0] - 1)
        child_b = jnp.clip(cand_idx // m, 0, B - 1)
        child_j = jnp.clip(cand_idx % m, 0, m - 1)
        child_occ = occ_nodes[child_b] & db_flat[child_j]
        child_meta = jnp.stack(
            [
                child_j,
                clo_cum_excl[child_b, child_j],
                supports[child_b, child_j],
                jnp.zeros_like(child_j),
            ],
            axis=1,
        )
        # the compacted child block is *contiguous* above sp_after, so the
        # push is a full-array select + one gather instead of a C-row
        # scatter (XLA's scatter expander would unroll that into a per-row
        # thunk loop — measured as the dominant superstep cost on CPU)
        logical = (jnp.arange(CAP) - head) % CAP  # logical slot per phys row
        rel = logical - sp_after                  # index into the child block
        in_push = (rel >= 0) & (rel < n_taken)
        child_src = jnp.clip(rel, 0, C - 1)
        occ_stack = jnp.where(in_push[:, None], child_occ[child_src], occ_stack)
        meta = jnp.where(in_push[:, None], child_meta[child_src], meta)
        overflow = sp_after + n_taken > CAP  # dropped pushes are fatal anyway
        sp2 = jnp.minimum(sp_after + n_taken, CAP)

        # ---- resume parents whose children overflowed the push cap
        row_counts = jnp.sum(cand.astype(jnp.int32), axis=1)
        row_offset = jnp.cumsum(row_counts) - row_counts
        taken_per_row = jnp.clip(C - row_offset, 0, row_counts)
        needs_resume = accepted & (taken_per_row < row_counts)
        pos_in_row = jnp.cumsum(cand.astype(jnp.int32), axis=1) - cand.astype(jnp.int32)
        first_untaken = cand & (pos_in_row == taken_per_row[:, None])
        cursor = jnp.argmax(first_untaken, axis=1)  # first candidate not pushed
        res_meta = jnp.stack(
            [cursor - 1, jnp.zeros(B, jnp.int32), sup, jnp.ones(B, jnp.int32)], axis=1
        )
        res_pos, res_overflow = push_positions(
            head, sp2, jnp.cumsum(needs_resume) - 1, needs_resume, CAP
        )
        overflow = overflow | res_overflow
        occ_stack = occ_stack.at[res_pos].set(occ_nodes, mode="drop")
        meta = meta.at[res_pos].set(res_meta, mode="drop")
        sp3 = jnp.minimum(sp2 + jnp.sum(needs_resume.astype(jnp.int32)), CAP)

        stats = stats.at[Stat.POPPED].add(jnp.sum(alive.astype(jnp.int32)))
        stats = stats.at[Stat.REJECTED].add(
            jnp.sum((alive & ~ppc_ok).astype(jnp.int32))
        )
        stats = stats.at[Stat.CLOSED].add(jnp.sum(counted.astype(jnp.int32)))
        stats = stats.at[Stat.PUSHED].add(n_taken)
        stats = stats.at[Stat.OVERFLOW].add(overflow.astype(jnp.int32))
        return (occ_stack, meta, sp3, hist, hist2d, stats, out_occ, out_meta,
                out_ptr, sig_cnt)

    return expand
