"""Block-size autotuner for the support-count popcount-GEMM (DESIGN.md §8).

The kernel's block sizes used to be hard-coded `(8, 512, 32)` — tuned once
by hand for one toy shape.  Paper-scale problems span three decades of item
counts and word widths (Table 1: 11,914 x 22 words up to 250,120 x 12, plus
mcf7's 400-word transaction axis), and the right tiling moves with them.

Two layers, cheapest first:

  1. a *seed table* measured by `benchmarks/kernel_roofline.py` (or any
     caller of `measure_blocks`) and persisted as JSON — on load, a shape
     bucket that was measured wins outright;
  2. an *analytic* roofline fallback (the same VPU/HBM model the roofline
     benchmark reports): among power-of-two candidates that divide the
     bucket-padded dims and fit the VMEM budget, minimize modeled time =
     padded word-ops / VPU throughput + HBM bytes / bandwidth + a per-grid-
     step overhead that penalizes tiny blocks; padding waste is priced in
     because the model runs on padded dims.

`choose_blocks` is deterministic for a given (shape bucket, impl, loaded
seed table), so a resolved `RuntimeConfig` — which folds the chosen triple
into the compiled-program cache key — stays stable across a session's life.
Point `REPRO_SC_AUTOTUNE` at a seed JSON (the artifact CI uploads) to carry
measured tunings across processes.
"""

from __future__ import annotations

import functools
import json
import os
import time

import numpy as np

__all__ = [
    "VMEM_BUDGET",
    "candidate_blocks",
    "choose_blocks",
    "clear_seed_table",
    "load_seed_table",
    "measure_blocks",
    "modeled_time_us",
    "save_seed_table",
    "vmem_bytes",
]

#: per-grid-step VMEM working set ceiling: half of a v5e core's 16 MiB so
#: the pipeline can double-buffer the next block's DMA behind the compute
VMEM_BUDGET = 8 * 2**20

# roofline constants (shared with benchmarks/kernel_roofline.py)
VPU_INT_OPS = 4.8e12   # v5e 8x128 lanes, ~940 MHz, 4 ALUs
HBM_BW = 819e9
GRID_STEP_US = 0.5     # modeled per-step dispatch/DMA-issue overhead

_ENV_SEED = "REPRO_SC_AUTOTUNE"

_CAND_B = (8, 16, 32, 64)
_CAND_M = (128, 256, 512, 1024, 2048)
_CAND_W = (8, 16, 32, 64, 128)
# GPU (triton lowering): smaller lane budget, shared-memory-sized blocks
_CAND_M_GPU = (64, 128, 256)
_CAND_W_GPU = (8, 16, 32)

_seed_rows: list[dict] = []


def vmem_bytes(bb: int, bm: int, bw: int) -> int:
    """Working set of one grid step: occ + db + out blocks + the [bb, bw, bm]
    popcount intermediate (all 4-byte words)."""
    return 4 * (bb * bw + bw * bm + bb * bm + bb * bw * bm)


def _pow2ceil(x: int, floor: int) -> int:
    out = floor
    while out < x:
        out *= 2
    return out


def bucket_dims(b: int, m: int, w: int) -> tuple[int, int, int]:
    """Power-of-two shape bucket (floors = smallest candidate blocks): the
    stable padded dims ragged caller shapes collapse onto, and the key the
    block choice (and therefore the jit cache) is a function of."""
    return _pow2ceil(b, 8), _pow2ceil(m, 128), _pow2ceil(w, 8)


def candidate_blocks(b: int, m: int, w: int, impl: str = "pallas"):
    """Power-of-two (bb, bm, bw) triples that divide the bucketed dims and
    fit the VMEM budget."""
    bp, mp, wp = bucket_dims(b, m, w)
    cand_m = _CAND_M_GPU if impl == "pallas_gpu" else _CAND_M
    cand_w = _CAND_W_GPU if impl == "pallas_gpu" else _CAND_W
    out = []
    for bb in _CAND_B:
        if bb > bp:
            continue
        for bm in cand_m:
            if bm > mp:
                continue
            for bw in cand_w:
                if bw > wp:
                    continue
                if vmem_bytes(bb, bm, bw) <= VMEM_BUDGET:
                    out.append((bb, bm, bw))
    # tiny shapes can undercut every candidate floor
    return out or [(min(8, bp), min(128, mp), min(8, wp))]


def modeled_time_us(b: int, m: int, w: int, blocks: tuple[int, int, int]) -> float:
    """Analytic roofline time for one full [B, M, W] sweep at these blocks.

    Runs on *bucket-padded* dims, so block choices that force more padding
    pay for it; the per-grid-step term penalizes shredding the sweep into
    tiny blocks (each step re-issues DMA and loop control).
    """
    bb, bm, bw = blocks
    bp, mp, wp = bucket_dims(b, m, w)
    bp = -(-bp // bb) * bb
    mp = -(-mp // bm) * bm
    wp = -(-wp // bw) * bw
    words = bp * mp * wp
    int_ops = 3 * words  # AND + popcount + accumulate
    # db streams once per b-block row; occ + out are small in comparison
    bytes_hbm = (bp // bb) * (wp * mp * 4) + (bp * wp + bp * mp) * 4
    steps = (bp // bb) * (mp // bm) * (wp // bw)
    return (int_ops / VPU_INT_OPS + bytes_hbm / HBM_BW) * 1e6 + steps * GRID_STEP_US


def _seed_lookup(b: int, m: int, w: int, impl: str):
    key = bucket_dims(b, m, w)
    best = None
    for row in _seed_rows:
        if row.get("impl", "pallas") != impl:
            continue
        if tuple(row["bucket"]) != key:
            continue
        if best is None or row["time_us"] < best["time_us"]:
            best = row
    return tuple(best["blocks"]) if best else None


@functools.lru_cache(maxsize=512)
def _choose(b: int, m: int, w: int, impl: str, seed_gen: int):
    seeded = _seed_lookup(b, m, w, impl)
    if seeded is not None:
        return seeded
    cands = candidate_blocks(b, m, w, impl)
    return min(
        cands,
        key=lambda blk: (modeled_time_us(b, m, w, blk), -blk[1], -blk[2]),
    )


_seed_gen = 0  # bumped on table load so the lru cache can't serve stale picks


def choose_blocks(b: int, m: int, w: int, impl: str = "pallas") -> tuple[int, int, int]:
    """The (block_b, block_m, block_w) triple for a [B, W] x [M, W] sweep.

    Deterministic per (shape bucket, impl, loaded seed table); the blocks
    always divide the power-of-two bucket of each dim, so callers that pad
    to `bucket_dims` never need per-block re-padding.
    """
    if impl == "ref":  # the jnp contraction has no blocks
        return (0, 0, 0)
    return _choose(*bucket_dims(b, m, w), impl, _seed_gen)


# ------------------------------------------------------------- seed table IO
def load_seed_table(path: str) -> int:
    """Load measured rows ({impl, bucket, blocks, time_us}); returns count."""
    global _seed_gen
    with open(path) as f:
        rows = json.load(f)
    _seed_rows.extend(rows["rows"] if isinstance(rows, dict) else rows)
    _seed_gen += 1
    _choose.cache_clear()
    return len(_seed_rows)


def clear_seed_table() -> None:
    global _seed_gen
    _seed_rows.clear()
    _seed_gen += 1
    _choose.cache_clear()


def save_seed_table(path: str, rows: list[dict]) -> str:
    with open(path, "w") as f:
        json.dump({"suite": "support-count-autotune", "rows": rows}, f, indent=1)
        f.write("\n")
    return path


def _maybe_load_env() -> None:
    path = os.environ.get(_ENV_SEED)
    if path and os.path.exists(path):
        try:
            load_seed_table(path)
        except (OSError, ValueError, KeyError):
            pass  # a bad seed file must never break kernel dispatch


_maybe_load_env()


# ------------------------------------------------------------------ measure
def measure_blocks(
    b: int,
    m: int,
    w: int,
    *,
    impl: str = "auto",
    iters: int = 3,
    max_candidates: int = 8,
    seed: int = 0,
) -> list[dict]:
    """Time the top analytic candidates on the active backend.

    Returns seed-table rows sorted fastest-first (feed to `save_seed_table`
    and later `load_seed_table` / `REPRO_SC_AUTOTUNE`).  On CPU this times
    the interpreted kernel — meaningless for TPU placement but a consistent
    ordering for CPU CI, which is where pallas_interpret carries mines.
    """
    from .ops import resolve_impl, support_counts

    impl = resolve_impl(impl)
    rng = np.random.default_rng(seed)
    occ = rng.integers(0, 2**32, size=(b, w), dtype=np.uint32)
    db = rng.integers(0, 2**32, size=(m, w), dtype=np.uint32)
    cands = sorted(
        candidate_blocks(b, m, w, impl),
        key=lambda blk: modeled_time_us(b, m, w, blk),
    )[:max_candidates]
    rows = []
    for blk in cands:
        out = support_counts(occ, db, impl=impl, blocks=blk)  # compile
        np.asarray(out)
        t0 = time.perf_counter()
        for _ in range(iters):
            np.asarray(support_counts(occ, db, impl=impl, blocks=blk))
        dt = (time.perf_counter() - t0) / iters
        rows.append({
            "impl": impl,
            "bucket": list(bucket_dims(b, m, w)),
            "shape": [b, m, w],
            "blocks": list(blk),
            "time_us": round(dt * 1e6, 2),
            "modeled_us": round(modeled_time_us(b, m, w, blk), 2),
            "vmem_kib": round(vmem_bytes(*blk) / 1024, 1),
        })
    rows.sort(key=lambda r: r["time_us"])
    return rows
