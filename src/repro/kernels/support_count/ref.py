"""Pure-jnp oracle for the support-count kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def support_count_ref(occ: jax.Array, db_t: jax.Array) -> jax.Array:
    """occ [B, W] uint32, db_t [W, M] uint32 -> [B, M] int32."""
    inter = occ[:, :, None] & db_t[None, :, :]  # [B, W, M]
    return jnp.sum(jax.lax.population_count(inter), axis=1).astype(jnp.int32)
