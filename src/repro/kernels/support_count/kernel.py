"""Pallas TPU kernel: popcount-semiring GEMM for itemset support counting.

This is the paper's §4.6 hot spot (POPCNT support counting on dense bitmaps)
adapted to the TPU memory hierarchy:

    S[b, j] = sum_w popcount(occ[b, w] & db_T[w, j])

  occ   [B, W]  uint32   occurrence bitmaps of a node batch (rows of the stack)
  db_T  [W, M]  uint32   transaction database, *word-major* so the item axis
                         lies across the 128-wide lane dimension
  S     [B, M]  int32    support of every candidate extension of every node

The contraction runs on the VPU (bitwise AND + popcount have no MXU path);
the job of the kernel is purely data movement: tile (B, M, W) so each block's
working set sits in VMEM and the inner accumulation never leaves vregs.

Grid = (B/bb, M/bm, W/bw) with the W axis innermost; the fp32/int32 output
block is initialized at w==0 and accumulated across the W grid steps —
the standard Pallas reduction-grid pattern.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _support_count_kernel(occ_ref, db_ref, out_ref):
    w_idx = pl.program_id(2)

    @pl.when(w_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    occ = occ_ref[...]  # [bb, bw] uint32
    db = db_ref[...]  # [bw, bm] uint32
    inter = occ[:, :, None] & db[None, :, :]  # [bb, bw, bm]
    counts = jax.lax.population_count(inter).astype(jnp.int32)
    out_ref[...] += jnp.sum(counts, axis=1)


def support_count_pallas(
    occ: jax.Array,
    db_t: jax.Array,
    *,
    block_b: int = 8,
    block_m: int = 512,
    block_w: int = 32,
    interpret: bool = False,
) -> jax.Array:
    """occ [B, W] uint32, db_t [W, M] uint32 -> [B, M] int32.

    B, M, W must already be multiples of the block sizes (ops.py pads).
    VMEM per step: bb*bw + bw*bm + bb*bm words + the [bb, bw, bm] intermediate;
    defaults: 8*32 + 32*512 + 8*512 + 8*32*512 words ≈ 660 KiB — well under
    16 MiB VMEM, leaving room for double buffering.
    """
    b, w = occ.shape
    w2, m = db_t.shape
    assert w == w2, (occ.shape, db_t.shape)
    assert b % block_b == 0 and m % block_m == 0 and w % block_w == 0

    grid = (b // block_b, m // block_m, w // block_w)
    return pl.pallas_call(
        _support_count_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_w), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_w, block_m), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_m), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, m), jnp.int32),
        interpret=interpret,
    )(occ, db_t)
