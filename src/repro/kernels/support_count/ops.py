"""jit'd public wrapper for the support-count kernel (padding + layout)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import support_count_pallas
from .ref import support_count_ref


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)  # zero words: AND contributes nothing


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_m", "block_w", "impl", "interpret")
)
def support_counts(
    occ: jax.Array,
    db_t: jax.Array,
    *,
    block_b: int = 8,
    block_m: int = 512,
    block_w: int = 32,
    impl: str = "pallas",
    interpret: bool = False,
) -> jax.Array:
    """Support of every item-extension of every node: [B, W] x [W, M] -> [B, M].

    Zero-pads every axis to its block multiple (bit-safe: padded words are 0,
    so they contribute no counts) and slices the result back.
    impl: "pallas" (TPU target; interpret=True on CPU) or "ref" (pure jnp).
    """
    b, w = occ.shape
    _, m = db_t.shape
    if impl == "ref":
        return support_count_ref(occ, db_t)
    block_b = min(block_b, max(8, b))
    occ_p = _pad_to(_pad_to(occ, 0, block_b), 1, block_w)
    db_p = _pad_to(_pad_to(db_t, 0, block_w), 1, block_m)
    out = support_count_pallas(
        occ_p, db_p,
        block_b=block_b, block_m=block_m, block_w=block_w,
        interpret=interpret,
    )
    return out[:b, :m]
