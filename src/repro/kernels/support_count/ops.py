"""THE dispatch point for support counting (DESIGN.md §8).

Every support-count in the system — the engine's expand phase, host-side
closure reconstruction, benchmarks, tests — goes through this module, so a
kernel variant or block-size change lands everywhere at once.  Variants:

  ref               pure-jnp popcount contraction (oracle; CPU default)
  pallas            Pallas TPU kernel (VMEM-tiled popcount-GEMM)
  pallas_interpret  the same kernel through the Pallas interpreter — the
                    carrier for CPU CI mines (kernel semantics, no TPU)
  pallas_gpu        the same kernel through the Triton lowering, with
                    GPU-sized blocks from the autotuner

Block sizes come from `autotune.choose_blocks` (measured seed table, then
an analytic roofline) instead of the old hard-coded `(8, 512, 32)`.

The database argument is item-major `[M, W]` — `pack_db`'s native layout
and the flat view of `core.bitmap.BitmapLayout` — not the word-major
transpose the pre-§8 wrapper wanted; the kernel-facing transpose happens
per tile at trace time.  Two entries:

  `support_counts`       public eager wrapper: bucket-pads (b, m, w) to
                         power-of-two grids so ragged call shapes share one
                         compiled program (the old wrapper re-jitted per
                         distinct shape and re-specialized `block_b` per odd
                         batch size), tiles the item axis, slices back.
  `support_counts_tiled` traced hot path over a pre-tiled `[T, m_tile, W]`
                         database — what the engine's expand phase calls
                         inside its superstep; sweeps tile by tile so the
                         working set stays [B, m_tile]-sized at 250k items.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.bitmap import item_tiling

from . import autotune
from .kernel import support_count_pallas

__all__ = [
    "VALID_IMPLS",
    "resolve_impl",
    "support_counts",
    "support_counts_tiled",
    "tile_counts",
]

#: concrete kernel variants ("auto" resolves per backend via `resolve_impl`)
VALID_IMPLS = ("ref", "pallas", "pallas_interpret", "pallas_gpu")


def resolve_impl(impl: str, backend: str | None = None) -> str:
    """Resolve the "auto" kernel selection against the active backend.

    "auto" means: the Pallas popcount-GEMM on TPU, its Triton lowering on
    GPU, the jnp reference contraction everywhere else.  Concrete names
    pass through untouched, so explicit choices (incl. "pallas_interpret"
    for CPU testing/CI mines) still win.
    """
    if impl == "auto":
        backend = jax.default_backend() if backend is None else backend
        return {"tpu": "pallas", "gpu": "pallas_gpu"}.get(backend, "ref")
    if impl not in VALID_IMPLS:
        raise ValueError(
            f"unknown kernel impl {impl!r}; valid: auto, {', '.join(VALID_IMPLS)}"
        )
    return impl


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)  # zero words: AND contributes nothing


def tile_counts(
    occ: jax.Array,
    tile_mw: jax.Array,
    *,
    impl: str,
    blocks: tuple[int, int, int] | None = None,
) -> jax.Array:
    """One tile: occ [B, W] x tile [m_tile, W] -> [B, m_tile] int32 (traced).

    The word-major transpose the kernel wants is taken here at trace time
    (cheap next to the [B, m_tile, W] contraction; for a loop-invariant
    database XLA hoists it).  Padding to block multiples is bit-safe: padded
    words/items are zero, so they contribute no counts.
    """
    b, w = occ.shape
    mt, w2 = tile_mw.shape
    assert w == w2, (occ.shape, tile_mw.shape)
    if impl == "ref":
        inter = occ[:, None, :] & tile_mw[None, :, :]
        return jnp.sum(lax.population_count(inter), axis=-1).astype(jnp.int32)
    if blocks is None:
        blocks = autotune.choose_blocks(b, mt, w, impl)
    bb, bm, bw = blocks
    occ_p = _pad_to(_pad_to(occ, 0, bb), 1, bw)
    db_wm = _pad_to(_pad_to(tile_mw, 0, bm), 1, bw).T
    out = support_count_pallas(
        occ_p, db_wm, block_b=bb, block_m=bm, block_w=bw,
        interpret=(impl == "pallas_interpret"),
    )
    return out[:b, :mt]


def support_counts_tiled(
    occ: jax.Array,
    db_tiles: jax.Array,
    *,
    impl: str,
    blocks: tuple[int, int, int] | None = None,
) -> jax.Array:
    """occ [B, W] x db_tiles [T, m_tile, W] -> [B, T*m_tile] int32 (traced).

    The engine's expand-phase entry: sweeps the item tiles sequentially
    (`lax.map` keeps the program rolled — one kernel instance, not T), so
    per-superstep intermediates scale with m_tile, never with total items.
    Bit-identical to the untiled contraction: popcount sums are exact
    integers and tile order only permutes independent output columns.
    """
    t = db_tiles.shape[0]
    if t == 1:
        return tile_counts(occ, db_tiles[0], impl=impl, blocks=blocks)
    out = lax.map(
        lambda tile: tile_counts(occ, tile, impl=impl, blocks=blocks),
        db_tiles,
    )  # [T, B, m_tile]
    return jnp.moveaxis(out, 0, 1).reshape(occ.shape[0], -1)


@functools.partial(jax.jit, static_argnames=("impl", "blocks"))
def _support_counts_padded(occ, db_tiles, *, impl, blocks):
    return support_counts_tiled(occ, db_tiles, impl=impl, blocks=blocks)


def support_counts(
    occ,
    db_bits,
    *,
    impl: str = "auto",
    blocks: tuple[int, int, int] | None = None,
    m_tile: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Support of every item against every bitmap: [B, W] x [M, W] -> [B, M].

    The public eager wrapper (host reconstruction, benchmarks, tests).
    Bucket-pads every axis to its power-of-two grid *before* the jit
    boundary, so all ragged shapes in a bucket share one compiled program,
    then slices the exact [B, M] result back out.  `interpret=True` is
    shorthand for impl="pallas_interpret" (back-compat with the pre-§8
    signature); the database is item-major [M, W].
    """
    if interpret:
        impl = "pallas_interpret"
    impl = resolve_impl(impl)
    occ = jnp.asarray(occ, dtype=jnp.uint32)
    db = jnp.asarray(db_bits, dtype=jnp.uint32)
    b, w = occ.shape
    m, w2 = db.shape
    assert w == w2, (occ.shape, db.shape)
    bp, mp, wp = autotune.bucket_dims(b, m, w)
    if blocks is None and impl != "ref":
        blocks = autotune.choose_blocks(b, m, w, impl)
    mt = m_tile if m_tile is not None else item_tiling(mp)[1]
    mp = -(-mp // mt) * mt
    occ_p = _pad_to(_pad_to(occ, 0, bp), 1, wp)
    db_p = _pad_to(_pad_to(db, 0, mp), 1, wp)
    out = _support_counts_padded(
        occ_p, db_p.reshape(mp // mt, mt, wp), impl=impl, blocks=blocks
    )
    return out[:b, :m]
