"""Pallas TPU kernel: blockwise online-softmax (flash) attention, forward.

Used on the serving/prefill hot path (32k-token prefill shapes) where the
naive [S, S] score matrix would not fit HBM, let alone VMEM.  The kernel
streams KV blocks through VMEM while the query block and the online-softmax
state (running max m, normalizer l, accumulator acc) stay resident — the
classic flash schedule, re-tiled for (8, 128) vregs and the MXU:

  grid = (B*H, Sq/bq, Skv/bk)   KV axis innermost
  q block   [bq, D]   VMEM (revisited across the KV sweep)
  k,v block [bk, D]   VMEM (streamed)
  scratch   m [bq,1], l [bq,1], acc [bq, D]  f32 VMEM

Causal blocks strictly above the diagonal band are skipped with pl.when —
on TPU this avoids both the MXU work and the VMEM traffic for masked blocks.
Training uses the differentiable chunked-scan path in repro.models.layers;
this kernel is the inference-prefill fast path (see DESIGN.md §5).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, kv_len: int, q_offset: int,
    block_q: int, block_k: int, num_k_blocks: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q + q_offset  # global position of first query row
    k_start = ki * block_k

    # entire block strictly above the causal diagonal? -> skip all work
    if causal:
        needed = k_start <= q_start + block_q - 1
    else:
        needed = ki >= 0  # always true (traced)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # [bq, D]
        k = k_ref[0].astype(jnp.float32)  # [bk, D]
        v = v_ref[0].astype(jnp.float32)  # [bk, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [bq, bk]

        kv_ids = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kv_ids < kv_len
        if causal:
            q_ids = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            mask = mask & (kv_ids <= q_ids)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scr[...]  # [bq, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        # every q row sees at least one valid key in its first unskipped block
        # (causal: key 0 is always visible), so m_new is finite for real rows
        # and masked entries vanish via exp(_NEG_INF - m_new) == 0.
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)  # [bq, 1]
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        l = l_scr[...]
        safe_l = jnp.where(l > 0.0, l, 1.0)
        o_ref[0] = (acc_scr[...] / safe_l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, causal: bool = True, scale: float | None = None,
    kv_len: int | None = None, q_offset: int = 0,
    block_q: int = 128, block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """q [BH, Sq, D], k/v [BH, Skv, D] -> [BH, Sq, D].

    Sq/Skv must be multiples of block_q/block_k (ops.py pads); kv_len masks the
    padded tail.  q_offset: global position of q row 0 (Skv - Sq for the usual
    causal prefill-with-cache layout).
    """
    bh, sq, d = q.shape
    _, skv, _ = k.shape
    assert sq % block_q == 0 and skv % block_k == 0, (sq, skv, block_q, block_k)
    if scale is None:
        scale = d ** -0.5
    if kv_len is None:
        kv_len = skv
    nq, nk = sq // block_q, skv // block_k

    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, kv_len=kv_len, q_offset=q_offset,
        block_q=block_q, block_k=block_k, num_k_blocks=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
