"""jit'd public wrapper for flash attention (padding, GQA head mapping)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import attention_ref


def _pad_seq(x: jax.Array, multiple: int) -> jax.Array:
    s = x.shape[1]
    rem = (-s) % multiple
    if rem == 0:
        return x
    return jnp.pad(x, ((0, 0), (0, rem), (0, 0)))


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "impl", "interpret"),
)
def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, causal: bool = True, scale: float | None = None,
    block_q: int = 128, block_k: int = 128,
    impl: str = "pallas", interpret: bool = False,
) -> jax.Array:
    """Multi-head attention with GQA.

    q [B, Hq, Sq, D]; k, v [B, Hkv, Skv, D]; Hq % Hkv == 0.
    Returns [B, Hq, Sq, D].
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    if group > 1:  # expand kv heads to match q heads (wrapper-level GQA)
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)

    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hq, skv, d)
    vf = v.reshape(b * hq, skv, d)
    q_offset = skv - sq if causal else 0

    if impl == "ref":
        out = attention_ref(qf, kf, vf, causal=causal, scale=scale, q_offset=q_offset)
        return out.reshape(b, hq, sq, d)

    qp = _pad_seq(qf, block_q)
    kp = _pad_seq(kf, block_k)
    vp = _pad_seq(vf, block_k)
    out = flash_attention_pallas(
        qp, kp, vp,
        causal=causal, scale=scale, kv_len=skv, q_offset=q_offset,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    return out[:, :sq].reshape(b, hq, sq, d)
