"""Pure-jnp oracle for flash attention (naive full-matrix softmax)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, causal: bool = True, scale: float | None = None,
    kv_len: int | None = None, q_offset: int = 0,
) -> jax.Array:
    """q [BH, Sq, D], k/v [BH, Skv, D] -> [BH, Sq, D] in f32 accumulation."""
    bh, sq, d = q.shape
    _, skv, _ = k.shape
    if scale is None:
        scale = d ** -0.5
    if kv_len is None:
        kv_len = skv
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    kv_ids = jnp.arange(skv)[None, None, :]
    mask = kv_ids < kv_len
    if causal:
        q_ids = (jnp.arange(sq) + q_offset)[None, :, None]
        mask = mask & (kv_ids <= q_ids)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
