"""Exact one-sided Fisher's exact test and Tarone's minimum-attainable P-value bound.

This is the statistical core of LAMP (paper §3.1-3.2):

  P(I) = sum_{n_i = n(I)}^{min(x(I), N_pos)}  C(N_pos, n_i) C(N - N_pos, x - n_i) / C(N, x)

  f(x) = C(N_pos, x) / C(N, x)        (lower bound, paper Eq. in §3.2; general form
                                        uses n* = min(x, N_pos))

Everything is computed in log-space with lgamma for exactness at GWAS scales
(N up to ~13k transactions).  Two parallel implementations:

  * numpy (host): used by the sequential oracle and phase-3 extraction.
  * jax.numpy (device): used by the distributed engine for batched testing.

The `FisherExact` class at the bottom adapts these functions to the
`TestStatistic` protocol (stats/base.py) and registers them as "fisher" —
the default statistic of every query.  The function-level API is kept
public (and re-exported by the legacy `repro.core.fisher` shim) because the
oracles and half the test suite call it directly.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from .base import TestStatistic, register_statistic

__all__ = [
    "FisherExact",
    "log_comb",
    "fisher_pvalue",
    "min_attainable_pvalue",
    "lamp_count_thresholds",
    "fisher_pvalue_jnp",
    "min_attainable_pvalue_jnp",
]


# --------------------------------------------------------------------------- numpy
def log_comb(n, k):
    """log C(n, k) with -inf for invalid k (k<0 or k>n). Vectorized."""
    n = np.asarray(n, dtype=np.float64)
    k = np.asarray(k, dtype=np.float64)
    from scipy.special import gammaln  # scipy is a test/analysis dep; host-side only

    valid = (k >= 0) & (k <= n)
    kk = np.where(valid, k, 0.0)
    out = gammaln(n + 1) - gammaln(kk + 1) - gammaln(n - kk + 1)
    return np.where(valid, out, -np.inf)


def fisher_pvalue(x, n, N, N_pos):
    """One-sided (enrichment) Fisher exact P-value.

    x: total support of the itemset; n: support within positives.
    Returns P[#positives >= n | margins] under the hypergeometric null.
    Vectorized over x, n (same shape).
    """
    x = np.atleast_1d(np.asarray(x, dtype=np.int64))
    n = np.atleast_1d(np.asarray(n, dtype=np.int64))
    hi = np.minimum(x, N_pos)  # [B]
    max_hi = int(hi.max()) if hi.size else 0
    ni = np.arange(max_hi + 1)[None, :]  # [1, K]
    mask = (ni >= n[:, None]) & (ni <= hi[:, None])
    logp = (
        log_comb(N_pos, ni)
        + log_comb(N - N_pos, x[:, None] - ni)
        - log_comb(N, x)[:, None]
    )
    logp = np.where(mask, logp, -np.inf)
    m = np.max(logp, axis=1, keepdims=True)
    m = np.where(np.isfinite(m), m, 0.0)
    p = np.exp(m[:, 0]) * np.sum(np.exp(logp - m), axis=1)
    return np.clip(p, 0.0, 1.0)


def min_attainable_pvalue(x, N, N_pos):
    """Tarone bound f(x): smallest achievable P-value for an itemset of support x.

    Attained when the itemset covers n* = min(x, N_pos) positives.
    f(x) = C(N_pos, n*) C(N-N_pos, x-n*) / C(N, x); reduces to the paper's
    C(N_pos, x)/C(N, x) for x <= N_pos.
    """
    x = np.asarray(x, dtype=np.int64)
    n_star = np.minimum(x, N_pos)
    logf = (
        log_comb(N_pos, n_star)
        + log_comb(N - N_pos, x - n_star)
        - log_comb(N, x)
    )
    return np.exp(np.clip(logf, -745.0, 0.0))


def lamp_count_thresholds(N, N_pos, alpha):
    """thr[lam] = alpha / f(lam-1) for lam = 0..N+1 (thr[0] unused).

    The support-increase procedure advances lambda while
    CS(lambda) > thr[lambda]  <=>  f(lambda-1) > alpha / CS(lambda)  (paper Eq. 3.1).
    Monotone non-decreasing in lam on [1, N_pos+1]; clamped beyond N_pos+1 so the
    minimum support never exceeds N_pos (f is no longer monotone past N_pos).
    """
    lam = np.arange(N + 2)
    f = min_attainable_pvalue(np.maximum(lam - 1, 0), N, N_pos)
    thr = alpha / np.maximum(f, 1e-300)
    # freeze thresholds past N_pos + 1: f() loses monotonicity there, so lambda
    # must never be advanced past N_pos + 1.
    cap = min(N_pos + 1, N + 1)
    thr[cap + 1 :] = np.inf
    return thr


# --------------------------------------------------------------------------- jax
def _log_comb_jnp(n, k):
    n = jnp.asarray(n, jnp.float32)
    k = jnp.asarray(k, jnp.float32)
    valid = (k >= 0) & (k <= n)
    kk = jnp.where(valid, k, 0.0)
    out = (
        jax.scipy.special.gammaln(n + 1)
        - jax.scipy.special.gammaln(kk + 1)
        - jax.scipy.special.gammaln(n - kk + 1)
    )
    return jnp.where(valid, out, -jnp.inf)


def fisher_pvalue_jnp(x, n, N, N_pos, k_max: int | None = None):
    """Batched one-sided Fisher exact P-value on device (float32 log-space).

    x, n: int arrays [B].  The n_i summation axis must be statically sized:
    by default it is N_pos+1 (requires a concrete N_pos); pass `k_max` — any
    static upper bound on N_pos — to let N and N_pos be traced runtime
    scalars, so one compiled program serves every dataset whose positives fit
    the bound (the shape-bucket sharing in repro.api).  Terms past the true
    N_pos are masked out via hi = min(x, N_pos), so the value is unchanged.
    """
    x = jnp.asarray(x, jnp.int32)
    n = jnp.asarray(n, jnp.int32)
    ni_hi = int(N_pos) if k_max is None else int(k_max)
    ni = jnp.arange(ni_hi + 1, dtype=jnp.int32)[None, :]
    hi = jnp.minimum(x, N_pos)[:, None]
    mask = (ni >= n[:, None]) & (ni <= hi)
    logp = (
        _log_comb_jnp(N_pos, ni)
        + _log_comb_jnp(N - N_pos, x[:, None] - ni)
        - _log_comb_jnp(N, x)[:, None]
    )
    logp = jnp.where(mask, logp, -jnp.inf)
    m = jnp.max(logp, axis=1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(m[:, 0]) * jnp.sum(jnp.exp(logp - m), axis=1)
    return jnp.clip(p, 0.0, 1.0)


def min_attainable_pvalue_jnp(x, N, N_pos):
    x = jnp.asarray(x, jnp.int32)
    n_star = jnp.minimum(x, N_pos)
    logf = (
        _log_comb_jnp(N_pos, n_star)
        + _log_comb_jnp(N - N_pos, x - n_star)
        - _log_comb_jnp(N, x)
    )
    return jnp.exp(jnp.clip(logf, -87.0, 0.0))


# ------------------------------------------------------------ TestStatistic
class FisherExact(TestStatistic):
    """Fisher's exact test as a registered `TestStatistic` ("fisher")."""

    name = "fisher"

    def pvalue(self, x, n, N, N_pos):
        return fisher_pvalue(x, n, N, N_pos)

    def pvalue_device(self, x, n, N, N_pos, *, k_max: int | None = None):
        return fisher_pvalue_jnp(x, n, N, N_pos, k_max=k_max)

    def min_attainable_pvalue(self, x, N, N_pos):
        return min_attainable_pvalue(x, N, N_pos)

    def count_thresholds(self, N, N_pos, alpha):
        return lamp_count_thresholds(N, N_pos, alpha)


register_statistic(FisherExact())
