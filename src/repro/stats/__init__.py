"""repro.stats — pluggable test statistics (the query layer's seam).

The engine runs *one* GLB traversal; what makes it a Fisher miner, a
chi-square miner, or a plain closed-frequent enumerator is which
`TestStatistic` (or none) is threaded through the device emission test,
the LAMP threshold table, and the host's exact re-test.  This package owns
that seam:

    from repro.stats import get_statistic
    stat = get_statistic("chi2")
    stat.pvalue(x, n, N, N_pos)            # host exact (float64)
    stat.pvalue_device(x, n, N, N_pos, k_max=...)   # in-superstep (float32)
    stat.count_thresholds(N, N_pos, alpha) # Tarone support-increase table

Registered statistics: "fisher" (exact hypergeometric tail — the default,
moved here from repro.core.fisher, which remains a re-export shim) and
"chi2" (continuity-corrected one-sided chi-square upper bound).  Add your
own with `register_statistic` — see stats/base.py for the soundness
contract the LAMP staging relies on and tests/test_stats.py property-checks.
"""

from .base import (
    STATISTICS,
    TestStatistic,
    get_statistic,
    register_statistic,
    thresholds_from_bound,
)
from .chi2 import ChiSquared, chi2_pvalue, chi2_pvalue_jnp
from .fisher import (
    FisherExact,
    fisher_pvalue,
    fisher_pvalue_jnp,
    lamp_count_thresholds,
    log_comb,
    min_attainable_pvalue,
    min_attainable_pvalue_jnp,
)

__all__ = [
    "STATISTICS",
    "TestStatistic",
    "get_statistic",
    "register_statistic",
    "thresholds_from_bound",
    "ChiSquared",
    "chi2_pvalue",
    "chi2_pvalue_jnp",
    "FisherExact",
    "fisher_pvalue",
    "fisher_pvalue_jnp",
    "lamp_count_thresholds",
    "log_comb",
    "min_attainable_pvalue",
    "min_attainable_pvalue_jnp",
]
