"""TestStatistic — the pluggable hypothesis-test seam of the miner.

The paper's generalization (§3) re-targets one closed-pattern traversal by
swapping the pruning bound; LAMP's own lineage swaps the *test* (Fisher,
chi-square, Mann-Whitney) under the same Tarone staging.  Everything the
engine and the LAMP staging need from a test statistic is four functions:

  pvalue(x, n, N, N_pos)            exact host P-value (numpy, float64) —
                                    drives ResultSet's reported values and
                                    the sequential oracle
  pvalue_device(x, n, N, N_pos,     batched device P-value (jax, float32) —
                k_max=...)          the engine's in-superstep emission test;
                                    k_max is a static bound on N_pos for
                                    statistics that sum over it (Fisher),
                                    ignored by closed-form ones (chi2)
  min_attainable_pvalue(x, N,       Tarone's f(x): a lower bound on the
                        N_pos)      P-value of ANY pattern with support x —
                                    what makes low-support patterns
                                    untestable and drives the lambda staging
  count_thresholds(N, N_pos, alpha) thr[lam] = alpha / f(lam-1), the integer
                                    support-increase table (monotone
                                    non-decreasing on [1, N_pos+1])

Soundness contract (what the LAMP staging actually relies on, and what
tests/test_stats.py property-checks for every registered statistic):

  * f(x) <= pvalue(x, n) for every attainable n — f really is attainable-
    minimum or lower;
  * count_thresholds is monotone non-decreasing on [1, N_pos+1], which is
    equivalent to f being non-increasing there.  A statistic whose raw
    per-support minimum is not monotone can register its *running-minimum
    envelope* instead (still a valid lower bound, merely a slightly
    conservative prune) — see stats/chi2.py.

Statistics register by name in `STATISTICS`; the name is what flows through
`Query.statistic`, `MinerSession.run_phase(..., statistic=)`, and into the
session's compiled-program cache key.  A new statistic is ~50 lines: subclass
`TestStatistic`, implement the four methods, call `register_statistic`.
"""

from __future__ import annotations

from abc import ABC, abstractmethod

import numpy as np

__all__ = [
    "STATISTICS",
    "TestStatistic",
    "get_statistic",
    "register_statistic",
    "thresholds_from_bound",
]


class TestStatistic(ABC):
    """One hypothesis test over a 2x2 margin (x, n, N, N_pos)."""

    #: registry key; also the cache-key component in MinerSession
    name: str = ""

    @abstractmethod
    def pvalue(self, x, n, N, N_pos) -> np.ndarray:
        """Exact one-sided (enrichment) P-value, host float64, vectorized
        over same-shape x (total support) and n (positive support)."""

    @abstractmethod
    def pvalue_device(self, x, n, N, N_pos, *, k_max: int | None = None):
        """Batched device P-value (jax float32).  N / N_pos may be traced
        runtime scalars; `k_max` is a static upper bound on N_pos for
        statistics whose kernel sums over it (shape-bucket sharing)."""

    @abstractmethod
    def min_attainable_pvalue(self, x, N, N_pos) -> np.ndarray:
        """Tarone bound f(x): lower bound on pvalue(x, n) over all n."""

    @abstractmethod
    def count_thresholds(self, N, N_pos, alpha) -> np.ndarray:
        """thr[lam] = alpha / f(lam-1) for lam = 0..N+1 (thr[0] unused),
        monotone non-decreasing on [1, N_pos+1], +inf past the cap."""

    def __repr__(self) -> str:
        return f"<TestStatistic {self.name!r}>"


def thresholds_from_bound(f, N: int, N_pos: int, alpha: float) -> np.ndarray:
    """Generic count_thresholds: alpha / f(lam-1), frozen past N_pos + 1.

    `f(x_array) -> lower-bound array` must be non-increasing on the capped
    range; the cap keeps lambda from ever advancing past N_pos + 1 (the
    same guard fisher's table applies — beyond it the raw per-support
    minimum need not be monotone).
    """
    lam = np.arange(N + 2)
    fx = np.asarray(f(np.maximum(lam - 1, 0)), dtype=np.float64)
    thr = alpha / np.maximum(fx, 1e-300)
    cap = min(N_pos + 1, N + 1)
    thr[cap + 1:] = np.inf
    return thr


#: name -> TestStatistic instance (the query layer's statistic registry)
STATISTICS: dict[str, TestStatistic] = {}


def register_statistic(stat: TestStatistic) -> TestStatistic:
    """Register (or replace) a statistic under `stat.name`."""
    if not stat.name:
        raise ValueError("TestStatistic.name must be a non-empty string")
    STATISTICS[stat.name] = stat
    return stat


def get_statistic(name: str) -> TestStatistic:
    """Resolve a registered statistic by name (actionable on typos)."""
    try:
        return STATISTICS[name]
    except KeyError:
        raise ValueError(
            f"unknown test statistic {name!r}; registered statistics: "
            f"{sorted(STATISTICS)}"
        ) from None
