"""One-sided continuity-corrected chi-square test as a `TestStatistic`.

LAMP's own lineage generalizes its Fisher test to the chi-square
approximation (the cheap screen of choice at cohort scales where the
hypergeometric tail sum is overkill).  For the 2x2 table of a pattern with
total support x and positive support n in a cohort of N transactions
(N_pos positives),

    a = n            b = x - n
    c = N_pos - n    d = N - N_pos - x + n

the Yates continuity-corrected statistic is

    T = N * (|ad - bc| - N/2)^2 / ((a+b)(c+d)(a+c)(b+d))
      = N * (max(|n*N - x*N_pos| - N/2, 0))^2 / (x (N-x) N_pos (N-N_pos))

and the one-sided (enrichment) upper-bound P-value is the normal tail at
the *signed* root,  p = P(Z >= sign(n*N - x*N_pos) * sqrt(T)).  The tail is
evaluated entirely in log-space (`log_ndtr`) — at GWAS scales T reaches the
thousands and the naive sf() underflows even float64 — then exponentiated
with the same clips the Fisher implementation uses (-745 host / -87
device).  Degenerate margins (x = 0, x = N, N_pos in {0, N}) zero the
denominator; T is defined as 0 there, giving the null p = 0.5.

Tarone bound.  The statistic is monotone in n for fixed x (T's numerator
grows with |n*N - x*N_pos| while the denominator ignores n), so the
per-support minimum is attained at n* = min(x, N_pos).  Unlike Fisher's
f(x), that raw minimum is not guaranteed monotone in x under the continuity
correction, so `min_attainable_pvalue` returns its *running-minimum
envelope* over x — still a valid lower bound for every support (envelope <=
raw minimum <= any attainable p), merely a conservative prune where the raw
curve wiggles — which makes `count_thresholds` monotone by construction
(the soundness contract in stats/base.py).

Verified against a scipy oracle (chi2.logsf(T, df=1) - log 2 on the
enrichment side) in tests/test_stats.py.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp
from jax.scipy.special import log_ndtr as log_ndtr_jnp

from .base import TestStatistic, register_statistic, thresholds_from_bound

__all__ = ["ChiSquared", "chi2_pvalue", "chi2_pvalue_jnp"]


def _signed_root(x, n, N, N_pos, xp):
    """z = sign(n*N - x*N_pos) * sqrt(T) for the Yates-corrected T."""
    num = n * N - x * N_pos
    corr = xp.maximum(xp.abs(num) - N / 2.0, 0.0)
    denom = x * (N - x) * N_pos * (N - N_pos)
    t = xp.where(denom > 0, N * corr * corr / xp.maximum(denom, 1.0), 0.0)
    return xp.sign(num) * xp.sqrt(t)


def chi2_pvalue(x, n, N, N_pos):
    """One-sided continuity-corrected chi-square P-value (host float64)."""
    from scipy.special import log_ndtr  # host-side dep, same as log_comb

    x = np.atleast_1d(np.asarray(x, dtype=np.float64))
    n = np.atleast_1d(np.asarray(n, dtype=np.float64))
    z = _signed_root(x, n, float(N), float(N_pos), np)
    # P(Z >= z) = ndtr(-z), in log space to survive the deep tail
    return np.exp(np.clip(log_ndtr(-z), -745.0, 0.0))


def chi2_pvalue_jnp(x, n, N, N_pos, k_max: int | None = None):
    """Batched device P-value (float32).  Closed-form — `k_max` (the static
    N_pos bound Fisher's summation axis needs) is accepted and ignored, so
    both statistics share one engine call signature."""
    del k_max
    x = jnp.asarray(x, jnp.float32)
    n = jnp.asarray(n, jnp.float32)
    N = jnp.asarray(N, jnp.float32)
    N_pos = jnp.asarray(N_pos, jnp.float32)
    z = _signed_root(x, n, N, N_pos, jnp)
    return jnp.exp(jnp.clip(log_ndtr_jnp(-z), -87.0, 0.0))


class ChiSquared(TestStatistic):
    """Continuity-corrected one-sided chi-square, registered as "chi2"."""

    name = "chi2"

    def pvalue(self, x, n, N, N_pos):
        return chi2_pvalue(x, n, N, N_pos)

    def pvalue_device(self, x, n, N, N_pos, *, k_max: int | None = None):
        return chi2_pvalue_jnp(x, n, N, N_pos, k_max=k_max)

    def min_attainable_pvalue(self, x, N, N_pos):
        x = np.atleast_1d(np.asarray(x, dtype=np.int64))
        grid = np.arange(0, int(N) + 1)
        raw = chi2_pvalue(grid, np.minimum(grid, int(N_pos)), N, N_pos)
        env = np.minimum.accumulate(raw)  # monotone non-increasing envelope
        return env[np.clip(x, 0, int(N))]

    def count_thresholds(self, N, N_pos, alpha):
        return thresholds_from_bound(
            lambda xs: self.min_attainable_pvalue(xs, N, N_pos), N, N_pos, alpha
        )


register_statistic(ChiSquared())
