"""Recurrent blocks: RG-LRU (RecurrentGemma/Griffin) and xLSTM (mLSTM, sLSTM).

Training-time parallelism choices (DESIGN.md hardware-adaptation notes):
  * RG-LRU: elementwise linear recurrence -> jax.lax.associative_scan (TPU log-
    depth scan) — the canonical way to run Griffin on TPUs.
  * mLSTM : matrix-memory recurrence trained in the *chunkwise-parallel* form
    (state carried across chunks, quadratic within a chunk).  A step-by-step
    recurrence (`mlstm_step`) is the decode path AND the test oracle.
  * sLSTM : sequential by construction (h_{t-1} feeds the gates; the xLSTM
    paper states it cannot be parallelized) -> lax.scan over time with
    x-projections hoisted out of the loop.  Carried state is O(d) so reverse-
    mode memory stays linear and small.

All recurrent states are f32; stabilizers keep exp() arguments <= 0.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from . import nn
from .layers import dot, rms_norm
from .sharding import shard

F32 = jnp.float32


# ------------------------------------------------------------ causal conv1d
def conv1d_init(key, width, channels):
    return {"w": nn.dense_init(key, (width, channels)) , "b": jnp.zeros((channels,))}


def conv1d_apply(p, x, state=None):
    """Depthwise causal conv along time. x [B, S, C]; state [B, width-1, C].

    Returns (y, new_state). With state=None the left context is zeros (train).
    """
    width = p["w"].shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(
        xp[:, i : i + x.shape[1], :] * p["w"][width - 1 - i].astype(x.dtype)
        for i in range(width)
    )
    new_state = xp[:, -(width - 1) :, :] if width > 1 else state
    return y + p["b"].astype(x.dtype), new_state


# ------------------------------------------------------------------- RG-LRU
_RG_C = 8.0
_RG_BLOCKS = 8  # block-diagonal gate projections (Griffin appendix)


def rglru_init(key, d, w, conv_width):
    ks = nn.split_keys(key, ["x", "gate", "out", "conv", "wa", "wi", "lam"])
    bd = w // _RG_BLOCKS
    return {
        "w_x": nn.dense_init(ks["x"], (d, w)),
        "w_gate": nn.dense_init(ks["gate"], (d, w)),
        "w_out": nn.dense_init(ks["out"], (w, d)),
        "conv": conv1d_init(ks["conv"], conv_width, w),
        "w_a": nn.dense_init(ks["wa"], (_RG_BLOCKS, bd, bd), in_axis=1),
        "w_i": nn.dense_init(ks["wi"], (_RG_BLOCKS, bd, bd), in_axis=1),
        # softplus(lam_p) ~ 0.4..0.8 at init => a^c in the Griffin range
        "lam": jnp.full((w,), 0.56, F32),
    }


def _block_diag(x, w):
    b, s, c = x.shape
    nb, bd, _ = w.shape
    xb = x.reshape(b, s, nb, bd)
    return jnp.einsum("bsnk,nkj->bsnj", xb.astype(F32), w.astype(F32)).reshape(b, s, c)


def rglru_block(p, x, state=None):
    """Griffin recurrent block. x [B, S, d] -> [B, S, d].

    state: {"h": [B, w] f32, "conv": [B, cw-1, w]} for decode; None for train.
    """
    gate = jax.nn.gelu(dot(x, p["w_gate"]).astype(F32))
    u, conv_state = conv1d_apply(
        p["conv"], dot(x, p["w_x"]), None if state is None else state["conv"]
    )
    u = shard(u, "dp", None, "tp")
    r = jax.nn.sigmoid(_block_diag(u, p["w_a"]))
    i = jax.nn.sigmoid(_block_diag(u, p["w_i"]))
    log_a = -_RG_C * jax.nn.softplus(p["lam"].astype(F32)) * r  # [B,S,w] <= 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(-jnp.expm1(2.0 * log_a), 1e-12))
    b_term = beta * (i * u.astype(F32))

    if state is not None:  # fold the carried state into the first step
        b_term = b_term.at[:, 0].add(a[:, 0] * state["h"])
    if x.shape[1] == 1:  # decode fast path
        h = b_term
    else:
        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        _, h = lax.associative_scan(combine, (a, b_term), axis=1)
    new_h = h[:, -1]

    y = dot((gate * h).astype(x.dtype), p["w_out"])
    return y, {"h": new_h, "conv": conv_state}


# -------------------------------------------------------------------- mLSTM
def mlstm_init(key, d, n_heads, conv_width=4):
    up = 2 * d
    ks = nn.split_keys(key, ["up", "gate", "q", "k", "v", "if_", "conv", "down", "norm"])
    return {
        "w_up": nn.dense_init(ks["up"], (d, up)),
        "w_ogate": nn.dense_init(ks["gate"], (d, up)),
        "conv": conv1d_init(ks["conv"], conv_width, up),
        "w_q": nn.dense_init(ks["q"], (up, up)),
        "w_k": nn.dense_init(ks["k"], (up, up)),
        "w_v": nn.dense_init(ks["v"], (up, up)),
        "w_if": nn.dense_init(ks["if_"], (up, 2 * n_heads)),
        "b_if": jnp.concatenate([jnp.zeros(n_heads), jnp.full((n_heads,), 3.0)]),
        "norm": jnp.ones((up,)),
        "w_down": nn.dense_init(ks["down"], (up, d)),
    }


def mlstm_step(q, k, v, i_raw, f_raw, state):
    """One recurrence step (decode path & test oracle).

    q,k,v [B,H,dh]; i_raw,f_raw [B,H]; state (C [B,H,dh,dh], n [B,H,dh], m [B,H]).
    """
    C, n, m = state
    logf = jax.nn.log_sigmoid(f_raw.astype(F32))
    logi = i_raw.astype(F32)
    m_new = jnp.maximum(logf + m, logi)
    fp = jnp.exp(logf + m - m_new)[..., None]
    ip = jnp.exp(logi - m_new)[..., None]
    C = fp[..., None] * C + ip[..., None] * (k[..., :, None] * v[..., None, :]).astype(F32)
    n = fp * n + ip * k.astype(F32)
    num = jnp.einsum("bhd,bhde->bhe", q.astype(F32), C)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", q.astype(F32), n))
    den = jnp.maximum(den, jnp.exp(-m_new))[..., None]
    return num / den, (C, n, m_new)


def mlstm_chunked(q, k, v, i_raw, f_raw, state=None, chunk=256):
    """Chunkwise-parallel mLSTM: q,k,v [B,H,S,dh]; gates [B,H,S].

    Returns (h [B,H,S,dh], final_state).  Matches scanning `mlstm_step` over
    time (tests assert this).
    """
    b, h, s, dh = q.shape
    if state is None:
        state = (
            jnp.zeros((b, h, dh, dh), F32),
            jnp.zeros((b, h, dh), F32),
            jnp.full((b, h), -1e30, F32),
        )
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:  # pad with -inf input gates: padded steps contribute nothing
        q, k, v = (jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0))) for t in (q, k, v))
        i_raw = jnp.pad(i_raw, ((0, 0), (0, 0), (0, pad)), constant_values=-1e30)
        f_raw = jnp.pad(f_raw, ((0, 0), (0, 0), (0, pad)), constant_values=30.0)
    sp = q.shape[2]
    nc = sp // chunk

    def to_chunks(t):
        return t.reshape(b, h, nc, chunk, *t.shape[3:]).transpose(2, 0, 1, 3, *range(4, t.ndim + 1))

    qs, ks_, vs = to_chunks(q), to_chunks(k), to_chunks(v)
    is_, fs = to_chunks(i_raw), to_chunks(f_raw)

    def chunk_step(carry, xs):
        C, n, m = carry
        qc, kc, vc, ic, fc = xs  # [B,H,L,dh], gates [B,H,L]
        logf = jax.nn.log_sigmoid(fc.astype(F32))
        logi = ic.astype(F32)
        F = jnp.cumsum(logf, axis=-1)  # [B,H,L] inclusive decay from chunk start
        # intra-chunk log-weights D[t,j] = F_t - F_j + logi_j  (j <= t)
        D = F[..., :, None] - F[..., None, :] + logi[..., None, :]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        D = jnp.where(tri, D, -jnp.inf)
        m_intra = jnp.max(D, axis=-1)  # [B,H,L]
        m_t = jnp.maximum(m[..., None] + F, m_intra)
        inter_w = jnp.exp(m[..., None] + F - m_t)  # [B,H,L]
        wmat = jnp.exp(D - m_t[..., None])  # [B,H,L,L]
        qf = qc.astype(F32)
        qkT = jnp.einsum("bhld,bhjd->bhlj", qf, kc.astype(F32))
        num = inter_w[..., None] * jnp.einsum("bhld,bhde->bhle", qf, C) + jnp.einsum(
            "bhlj,bhlj,bhje->bhle", wmat, qkT, vc.astype(F32)
        )
        den = inter_w * jnp.einsum("bhld,bhd->bhl", qf, n) + jnp.einsum(
            "bhlj,bhlj->bhl", wmat, qkT
        )
        hb = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # state update to end of chunk
        FL = F[..., -1:]
        m_new = jnp.maximum(
            m + FL[..., 0], jnp.max(FL - F + logi, axis=-1)
        )
        wk = jnp.exp(FL - F + logi - m_new[..., None])  # [B,H,L]
        C_new = jnp.exp(m + FL[..., 0] - m_new)[..., None, None] * C + jnp.einsum(
            "bhj,bhjd,bhje->bhde", wk, kc.astype(F32), vc.astype(F32)
        )
        n_new = jnp.exp(m + FL[..., 0] - m_new)[..., None] * n + jnp.einsum(
            "bhj,bhjd->bhd", wk, kc.astype(F32)
        )
        return (C_new, n_new, m_new), hb

    state, hs = lax.scan(chunk_step, state, (qs, ks_, vs, is_, fs))
    hcat = hs.transpose(1, 2, 0, 3, 4).reshape(b, h, sp, dh)
    return hcat[:, :, :s], state


def mlstm_block(p, x, n_heads, state=None, chunk=256):
    """xLSTM mLSTM block. x [B, S, d] -> [B, S, d]. state for decode."""
    b, s, d = x.shape
    up = p["w_up"].shape[1]
    dh = up // n_heads
    xu = dot(x, p["w_up"])
    ogate = jax.nn.silu(dot(x, p["w_ogate"]).astype(F32))
    conv_in, conv_state = conv1d_apply(
        p["conv"], xu, None if state is None else state["conv"]
    )
    conv_in = jax.nn.silu(conv_in.astype(F32)).astype(x.dtype)

    def heads(t):
        return t.reshape(b, s, n_heads, dh).transpose(0, 2, 1, 3)

    q = heads(dot(conv_in, p["w_q"])) * (dh ** -0.5)
    k = heads(dot(conv_in, p["w_k"]))
    v = heads(dot(xu, p["w_v"]))
    gif = (dot(conv_in, p["w_if"]).astype(F32) + p["b_if"]).transpose(0, 2, 1)  # [B,2H,S]
    i_raw, f_raw = gif[:, :n_heads], gif[:, n_heads:]

    rec_state = None if state is None else state["rec"]
    if state is None or s > 1:
        h, rec_state = mlstm_chunked(q, k, v, i_raw, f_raw, rec_state, chunk=chunk)
    else:
        h1, rec_state = mlstm_step(
            q[:, :, 0], k[:, :, 0], v[:, :, 0], i_raw[:, :, 0], f_raw[:, :, 0], rec_state
        )
        h = h1[:, :, None]
    hm = h.transpose(0, 2, 1, 3).reshape(b, s, up)
    hm = rms_norm(hm.astype(x.dtype), p["norm"])
    y = dot((hm.astype(F32) * ogate).astype(x.dtype), p["w_down"])
    return y, {"rec": rec_state, "conv": conv_state}


# -------------------------------------------------------------------- sLSTM
def slstm_init(key, d, n_heads, conv_width=4, ff_ratio=4.0 / 3.0):
    dh = d // n_heads
    ff = int(d * ff_ratio)
    ks = nn.split_keys(
        key, ["conv", "wi", "wf", "wz", "wo", "ri", "rf", "rz", "ro", "up", "gate", "down", "norm"]
    )
    p = {
        "conv": conv1d_init(ks["conv"], conv_width, d),
        "norm": jnp.ones((d,)),
        "w_up": nn.dense_init(ks["up"], (d, ff)),
        "w_gate": nn.dense_init(ks["gate"], (d, ff)),
        "w_down": nn.dense_init(ks["down"], (ff, d)),
    }
    for g in ("i", "f", "z", "o"):
        p[f"w_{g}"] = nn.dense_init(ks[f"w{g}"], (d, d))
        p[f"r_{g}"] = nn.dense_init(ks[f"r{g}"], (n_heads, dh, dh), in_axis=1)
    p["b_f"] = jnp.full((d,), 3.0)  # forget-gate bias: remember by default
    return p


def slstm_block(p, x, n_heads, state=None):
    """xLSTM sLSTM block (sequential scan). x [B, S, d] -> [B, S, d]."""
    b, s, d = x.shape
    dh = d // n_heads
    conv_x, conv_state = conv1d_apply(
        p["conv"], x, None if state is None else state["conv"]
    )
    conv_x = jax.nn.silu(conv_x.astype(F32)).astype(x.dtype)
    # hoist the x-projections out of the scan
    xi = dot(conv_x, p["w_i"]).astype(F32)
    xf = (dot(conv_x, p["w_f"]).astype(F32) + p["b_f"])
    xz = dot(x, p["w_z"]).astype(F32)
    xo = dot(x, p["w_o"]).astype(F32)

    def hview(t):  # [B, S, d] -> [S, B, H, dh]
        return t.reshape(b, s, n_heads, dh).transpose(1, 0, 2, 3)

    xi, xf, xz, xo = hview(xi), hview(xf), hview(xz), hview(xo)

    if state is None:
        zeros = jnp.zeros((b, n_heads, dh), F32)
        rec0 = {"c": zeros, "n": zeros + 1e-6, "h": zeros, "m": jnp.zeros((b, n_heads), F32)}
    else:
        rec0 = state["rec"]

    def step(rec, xs):
        xi_t, xf_t, xz_t, xo_t = xs  # [B, H, dh]
        hprev = rec["h"]
        ri = jnp.einsum("bhk,hkj->bhj", hprev, p["r_i"].astype(F32))
        rf = jnp.einsum("bhk,hkj->bhj", hprev, p["r_f"].astype(F32))
        rz = jnp.einsum("bhk,hkj->bhj", hprev, p["r_z"].astype(F32))
        ro = jnp.einsum("bhk,hkj->bhj", hprev, p["r_o"].astype(F32))
        it = xi_t + ri
        ft = xf_t + rf
        z = jnp.tanh(xz_t + rz)
        o = jax.nn.sigmoid(xo_t + ro)
        # per-head scalar stabilizer (max over the head's channels)
        m_new = jnp.maximum(
            jnp.max(ft, axis=-1) + rec["m"], jnp.max(it, axis=-1)
        )  # [B, H]
        fp = jnp.exp(ft + (rec["m"] - m_new)[..., None])
        ip = jnp.exp(it - m_new[..., None])
        c = fp * rec["c"] + ip * z
        n = fp * rec["n"] + ip
        h = o * c / jnp.maximum(n, 1e-6)
        return {"c": c, "n": n, "h": h, "m": m_new}, h

    rec, hs = lax.scan(step, rec0, (xi, xf, xz, xo))  # hs [S, B, H, dh]
    hm = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    hm = rms_norm(hm, p["norm"])
    # post-up gated MLP (ratio 4/3)
    u = dot(hm, p["w_up"])
    g = jax.nn.gelu(dot(hm, p["w_gate"]).astype(F32)).astype(x.dtype)
    y = dot(u * g, p["w_down"])
    return y, {"rec": rec, "conv": conv_state}
