"""Minimal pytree parameter system (no flax): init fns return nested dicts of
f32 arrays; `abstract_init` gives allocation-free ShapeDtypeStructs for the
dry-run; spec trees mirror params for NamedSharding.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, shape, in_axis=0, dtype=jnp.float32):
    fan_in = shape[in_axis] if shape else 1
    scale = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * scale).astype(dtype)


def zeros_init(_key, shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype=jnp.float32):
    return jnp.ones(shape, dtype)


def split_keys(key, names):
    keys = jax.random.split(key, len(names))
    return dict(zip(names, keys))


def abstract_init(init_fn, *args):
    """Shapes/dtypes of init_fn(key, *args) without allocating (dry-run path)."""
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: init_fn(k, *args), key)


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree
    )


def count_params(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))
