"""Sharding rules: logical activation/parameter axes -> mesh axes.

The production mesh is ("data", "model") or ("pod", "data", "model")
(launch/mesh.py).  Logical rules:

  batch        -> ("pod","data")   (dp axes; "pod" only when multi-pod)
  tp/feature   -> "model"          (attention heads / ffn hidden / vocab / experts)
  fsdp         -> "data"           (second param axis: ZeRO-3 style)
  seq (SP)     -> "model"          (norm/residual segments, long-context decode KV)

Models call `shard(x, *logical_axes)`; outside a `use_rules` context this is a
no-op, so model code stays mesh-agnostic (smoke tests run without any mesh).
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass, field

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


@dataclass(frozen=True)
class ShardingRules:
    dp: tuple[str, ...] = ("data",)  # ("pod","data") when multi-pod
    tp: str | None = "model"
    fsdp: str | None = "data"
    sp: str | None = "model"  # sequence parallelism axis (None disables SP)
    shard_kv_seq: bool = True  # decode: shard KV cache seq dim over tp

    def axis(self, name: str):
        if name == "dp":
            return self.dp if len(self.dp) > 1 else self.dp[0]
        if name == "tp":
            return self.tp
        if name == "fsdp":
            return self.fsdp
        if name == "sp":
            return self.sp
        if name is None or name == "none":
            return None
        raise ValueError(name)


def current_rules() -> ShardingRules | None:
    return getattr(_state, "rules", None)


@contextmanager
def use_rules(rules: ShardingRules | None):
    old = current_rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = old


def spec(*logical) -> P:
    """PartitionSpec from logical axis names under the current rules."""
    rules = current_rules()
    if rules is None:
        return P()
    return P(*(rules.axis(a) if a else None for a in logical))


def shard(x, *logical):
    """with_sharding_constraint under the current rules (no-op without rules)."""
    rules = current_rules()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec(*logical))
