"""Model assembly for all assigned architectures.

One generic decoder/encoder built from the block pattern in ArchConfig:
  attn / local_attn  -> layers.attention (+ MLP or MoE sublayer)
  rglru              -> recurrent.rglru_block (+ MLP sublayer; Griffin layout)
  mlstm / slstm      -> recurrent blocks (carry their own projections)

Layer stacking uses lax.scan over the repeating pattern *unit* (compile-time
O(1) in depth) with optional remat; config.block_tail layers are applied
unscanned.  Decode carries a cache pytree: KV (ring buffer for local
attention) or recurrent state per block.

Public entry points:
  init_params / abstract_params        parameter pytrees (real / ShapeDtypeStruct)
  param_partition_specs                matching PartitionSpec tree
  init_cache / abstract_cache          decode cache pytrees
  forward_train -> per-token loss      (seq-chunked CE; never materializes
                                        the full [B,S,V] logits)
  forward_prefill -> last logits+cache
  forward_decode  -> logits + cache
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from . import nn
from .layers import (
    apply_rope, attention, dot, mlp_apply, mlp_init, moe_apply, moe_init, rms_norm,
)
from .recurrent import (
    mlstm_block, mlstm_init, rglru_block, rglru_init, slstm_block, slstm_init,
)
from .sharding import shard, spec

F32 = jnp.float32


# ------------------------------------------------------------------- builders
def _attn_init(key, cfg: ArchConfig):
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = nn.split_keys(key, ["q", "k", "v", "o", "qn", "kn"])
    p = {
        "wq": nn.dense_init(ks["q"], (d, hq * dh)),
        "wk": nn.dense_init(ks["k"], (d, hkv * dh)),
        "wv": nn.dense_init(ks["v"], (d, hkv * dh)),
        "wo": nn.dense_init(ks["o"], (hq * dh, d)),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,))
        p["k_norm"] = jnp.ones((dh,))
    return p


def _block_init(key, cfg: ArchConfig, kind: str):
    d = cfg.d_model
    ks = nn.split_keys(key, ["mix", "mlp"])
    p: dict[str, Any] = {"ln1": jnp.ones((d,))}
    if kind in ("attn", "local_attn"):
        p["attn"] = _attn_init(ks["mix"], cfg)
    elif kind == "rglru":
        p["rglru"] = rglru_init(ks["mix"], d, cfg.rnn_width or d, cfg.conv_width)
    elif kind == "mlstm":
        p["mlstm"] = mlstm_init(ks["mix"], d, cfg.n_heads, cfg.conv_width)
        return p  # own projections; no MLP sublayer
    elif kind == "slstm":
        p["slstm"] = slstm_init(ks["mix"], d, cfg.n_heads, cfg.conv_width)
        return p
    else:
        raise ValueError(kind)
    p["ln2"] = jnp.ones((d,))
    if cfg.n_experts:
        p["moe"] = moe_init(ks["mlp"], d, cfg.d_ff, cfg.n_experts, cfg.mlp)
    else:
        p["mlp"] = mlp_init(ks["mlp"], d, cfg.d_ff, cfg.mlp)
    return p


def _unit_init(key, cfg: ArchConfig):
    keys = jax.random.split(key, len(cfg.pattern))
    return {f"b{j}": _block_init(k, cfg, kind)
            for j, (k, kind) in enumerate(zip(keys, cfg.pattern))}


def init_params(cfg: ArchConfig, key):
    ks = nn.split_keys(key, ["embed", "units", "tail", "head"])
    d = cfg.d_model
    p: dict[str, Any] = {
        "embed": nn.dense_init(ks["embed"], (cfg.vocab, d)),
        "final_norm": jnp.ones((d,)),
    }
    unit_keys = jax.random.split(ks["units"], cfg.n_units)
    p["units"] = jax.vmap(lambda k: _unit_init(k, cfg))(unit_keys)
    if cfg.block_tail:
        tkeys = jax.random.split(ks["tail"], len(cfg.block_tail))
        p["tail"] = {
            f"t{j}": _block_init(k, cfg, kind)
            for j, (k, kind) in enumerate(zip(tkeys, cfg.block_tail))
        }
    if not cfg.tie_embeddings:
        p["unembed"] = nn.dense_init(ks["head"], (d, cfg.vocab))
    return p


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))


# ------------------------------------------------------------ partition specs
def param_partition_specs(cfg: ArchConfig):
    """PartitionSpec tree mirroring init_params (TP over 'tp', FSDP over 'fsdp').

    Convention: 2D weights shard (fsdp, tp) on (in, out) for up-projections and
    (tp, fsdp) for down-projections; vectors replicate; experts shard on 'tp'.
    A leading scan/stack axis (units) is never sharded.
    """

    def attn_spec(p):
        out = {
            "wq": spec("fsdp", "tp"), "wk": spec("fsdp", "tp"),
            "wv": spec("fsdp", "tp"), "wo": spec("tp", "fsdp"),
        }
        if "q_norm" in p:
            out["q_norm"] = spec(None)
            out["k_norm"] = spec(None)
        return out

    def mlp_spec(p):
        out = {"w_in": spec("fsdp", "tp"), "w_out": spec("tp", "fsdp")}
        if "w_gate" in p:
            out["w_gate"] = spec("fsdp", "tp")
        return out

    def moe_spec(p):
        out = {
            "router": spec("fsdp", None),
            "w_in": spec("tp", "fsdp", None),
            "w_out": spec("tp", None, "fsdp"),
        }
        if "w_gate" in p:
            out["w_gate"] = spec("tp", "fsdp", None)
        return out

    def conv_spec(_p):
        return {"w": spec(None, "tp"), "b": spec("tp")}

    def rglru_spec(p):
        return {
            "w_x": spec("fsdp", "tp"), "w_gate": spec("fsdp", "tp"),
            "w_out": spec("tp", "fsdp"), "conv": conv_spec(p["conv"]),
            "w_a": spec(None, None, None), "w_i": spec(None, None, None),
            "lam": spec("tp"),
        }

    def mlstm_spec(p):
        return {
            "w_up": spec("fsdp", "tp"), "w_ogate": spec("fsdp", "tp"),
            "conv": conv_spec(p["conv"]),
            "w_q": spec("fsdp", "tp"), "w_k": spec("fsdp", "tp"),
            "w_v": spec("fsdp", "tp"), "w_if": spec("fsdp", None),
            "b_if": spec(None), "norm": spec("tp"), "w_down": spec("tp", "fsdp"),
        }

    def slstm_spec(p):
        out = {
            "conv": conv_spec(p["conv"]), "norm": spec(None),
            "w_up": spec("fsdp", "tp"), "w_gate": spec("fsdp", "tp"),
            "w_down": spec("tp", "fsdp"), "b_f": spec(None),
        }
        for g in ("i", "f", "z", "o"):
            out[f"w_{g}"] = spec("fsdp", None)
            out[f"r_{g}"] = spec(None, None, None)
        return out

    def block_spec(p, kind):
        out = {"ln1": spec(None)}
        if kind in ("attn", "local_attn"):
            out["attn"] = attn_spec(p["attn"])
        elif kind == "rglru":
            out["rglru"] = rglru_spec(p["rglru"])
        elif kind == "mlstm":
            out["mlstm"] = mlstm_spec(p["mlstm"])
            return out
        elif kind == "slstm":
            out["slstm"] = slstm_spec(p["slstm"])
            return out
        if "ln2" in p:
            out["ln2"] = spec(None)
        if "moe" in p:
            out["moe"] = moe_spec(p["moe"])
        if "mlp" in p:
            out["mlp"] = mlp_spec(p["mlp"])
        return out

    aparams = abstract_params(cfg)
    specs: dict[str, Any] = {
        "embed": spec("tp", "fsdp"),
        "final_norm": spec(None),
    }
    unit0 = jax.tree.map(lambda x: x, aparams["units"])  # stacked leaves
    specs["units"] = {
        f"b{j}": _prepend_axis(block_spec(_index_tree(unit0[f"b{j}"]), kind))
        for j, kind in enumerate(cfg.pattern)
    }
    if cfg.block_tail:
        specs["tail"] = {
            f"t{j}": block_spec(aparams["tail"][f"t{j}"], kind)
            for j, kind in enumerate(cfg.block_tail)
        }
    if not cfg.tie_embeddings:
        specs["unembed"] = spec("fsdp", "tp")
    return specs


def _index_tree(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), tree)


def _prepend_axis(spec_tree):
    return jax.tree.map(
        lambda s: P(*((None,) + tuple(s))), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# ------------------------------------------------------------------ the model
def _heads(t, n, dh):
    b, s, _ = t.shape
    return t.reshape(b, s, n, dh).transpose(0, 2, 1, 3)


def _attn_apply(p, cfg: ArchConfig, x, positions, cache, kind):
    b, s, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    window = cfg.local_window if kind == "local_attn" else 0
    # pin sharding at the projection outputs — relying on backward
    # propagation through reshape/transpose/rope leaves GSPMD free to
    # replicate the weights (observed: full [d, d] weight all-gathers)
    tp_mode = cfg.attn_sharding == "tp_heads"
    qf = shard(dot(x, p["wq"]), "dp", None if tp_mode else "sp",
               "tp" if tp_mode else None)
    kf = shard(dot(x, p["wk"]), "dp", None if tp_mode else "sp", None)
    vf = shard(dot(x, p["wv"]), "dp", None if tp_mode else "sp", None)
    q = _heads(qf, hq, dh)
    k = _heads(kf, hkv, dh)
    v = _heads(vf, hkv, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    rope_pos = positions
    q = apply_rope(q, rope_pos, cfg.rope_theta, cfg.m_rope_sections)
    k = apply_rope(k, rope_pos, cfg.rope_theta, cfg.m_rope_sections)
    # TP attention (§Perf it2): q-heads shard over 'model'; KV heads replicate
    # (GQA kv counts rarely divide TP=16) and are expanded to per-q-head form
    # so the head axis shards cleanly — wq/wo gradients stay TP-sharded, which
    # removes the full-size weight-grad all-reduces the earlier
    # context-parallel scheme paid (EXPERIMENTS.md §Perf, cmd-r+ cell).
    # Archs with hq % 16 != 0 pad the head axis (surfaced in useful-ratio).
    k_raw, v_raw = k, v  # cache stores unrepeated GQA heads
    if s > 1:
        if cfg.attn_sharding == "tp_heads":
            if hq != hkv:
                k = jnp.repeat(k, hq // hkv, axis=1)
                v = jnp.repeat(v, hq // hkv, axis=1)
            q = shard(q, "dp", "tp", None, None)
            k = shard(k, "dp", "tp", None, None)
            v = shard(v, "dp", "tp", None, None)
        else:  # "context": batch+seq sharding, heads replicated (§Perf)
            q = shard(q, "dp", None, "sp", None)
            k = shard(k, "dp", None, None, None)
            v = shard(v, "dp", None, None, None)

    t_pos = positions[..., 0] if positions.ndim == 3 else positions  # [B, S]
    if cache is None:
        out = attention(q, k, v, causal=cfg.causal, window=window,
                        q_offset=t_pos[:, 0], chunk=1024)
        new_cache = None
    elif s > 1 and not window:
        # fresh full-attention prefill: attend over the fresh (repeated,
        # TP-head-sharded) kv and write the cache on the side.  Chunked
        # prefill continuation is only supported for windowed caches.
        out = attention(q, k, v, causal=cfg.causal, q_offset=t_pos[:, 0],
                        chunk=1024)
        ck, cv, cpos = cache["k"], cache["v"], cache["pos"]
        zero = jnp.int32(0)
        start = t_pos[0, 0]
        nk = lax.dynamic_update_slice(ck, k_raw.astype(ck.dtype),
                                      (zero, zero, start, zero))
        nv = lax.dynamic_update_slice(cv, v_raw.astype(cv.dtype),
                                      (zero, zero, start, zero))
        npos = lax.dynamic_update_slice(cpos, t_pos, (zero, start))
        new_cache = {"k": shard(nk, "dp", None, "sp", None),
                     "v": shard(nv, "dp", None, "sp", None), "pos": npos}
    else:
        ck, cv, cpos = cache["k"], cache["v"], cache["pos"]
        size = ck.shape[2]
        k_w, v_w, pos_w = k_raw, v_raw, t_pos
        if s > size:  # ring buffer smaller than the write: keep only the tail
            k_w, v_w = k_raw[:, :, -size:], v_raw[:, :, -size:]
            pos_w = t_pos[:, -size:]
        sw = k_w.shape[2]
        if sw == 1 or not window:
            # contiguous write -> dynamic-update-slice (in-place aliasable;
            # scatter here made XLA double-buffer the whole cache)
            start = jnp.mod(pos_w[0, 0], size) if window else pos_w[0, 0]
            zero = jnp.int32(0)
            nk = lax.dynamic_update_slice(ck, k_w.astype(ck.dtype),
                                          (zero, zero, start, zero))
            nv = lax.dynamic_update_slice(cv, v_w.astype(cv.dtype),
                                          (zero, zero, start, zero))
            npos = lax.dynamic_update_slice(cpos, pos_w, (zero, start))
        else:  # windowed prefill may wrap the ring: scatter (cache is small)
            slots = jnp.mod(pos_w[0], size)
            nk = ck.at[:, :, slots].set(k_w.astype(ck.dtype))
            nv = cv.at[:, :, slots].set(v_w.astype(cv.dtype))
            npos = cpos.at[:, slots].set(pos_w)
        nk = shard(nk, "dp", None, "sp", None)
        nv = shard(nv, "dp", None, "sp", None)
        if window and s > 1:
            # windowed prefill: the ring may already have evicted keys that
            # early queries need — attend over [old ring ∥ fresh kv] instead
            ka = jnp.concatenate([ck.astype(k_raw.dtype), k_raw], axis=2)
            va = jnp.concatenate([cv.astype(v_raw.dtype), v_raw], axis=2)
            pa = jnp.concatenate([cpos, t_pos], axis=1)
            out = attention(q, ka, va, causal=cfg.causal, window=window,
                            q_offset=t_pos[:, 0], kv_pos=pa, chunk=1024)
        else:
            out = attention(q, nk, nv, causal=cfg.causal, window=window,
                            q_offset=t_pos[:, 0], kv_pos=npos, chunk=1024)
        new_cache = {"k": nk, "v": nv, "pos": npos}
    if s > 1:
        if cfg.attn_sharding == "tp_heads":
            out = shard(out, "dp", "tp", None, None)
        else:
            out = shard(out, "dp", None, "sp", None)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, hq * dh)
    return dot(out, p["wo"]), new_cache


def _block_apply(p, cfg: ArchConfig, kind, x, positions, cache):
    h = rms_norm(x, p["ln1"])
    if kind in ("attn", "local_attn") and cfg.parallel_block:
        # Cohere/GPT-J parallel residual: both branches read one normed input
        # (one TP all-gather) and their sum is reduced once.
        mix, new_cache = _attn_apply(p["attn"], cfg, h, positions, cache, kind)
        if cfg.n_experts:
            y = moe_apply(p["moe"], h, top_k=cfg.top_k, kind=cfg.mlp,
                          capacity_factor=cfg.moe_capacity_factor)
        else:
            y = mlp_apply(p["mlp"], h, cfg.mlp)
        return x + mix + y, new_cache
    if kind in ("attn", "local_attn"):
        mix, new_cache = _attn_apply(p["attn"], cfg, h, positions, cache, kind)
    elif kind == "rglru":
        mix, new_cache = rglru_block(p["rglru"], h, cache)
    elif kind == "mlstm":
        mix, new_cache = mlstm_block(p["mlstm"], h, cfg.n_heads, cache)
        return x + mix, new_cache
    elif kind == "slstm":
        mix, new_cache = slstm_block(p["slstm"], h, cfg.n_heads, cache)
        return x + mix, new_cache
    else:
        raise ValueError(kind)
    x = x + mix
    h2 = rms_norm(x, p["ln2"])
    if cfg.n_experts:
        y = moe_apply(p["moe"], h2, top_k=cfg.top_k, kind=cfg.mlp,
                      capacity_factor=cfg.moe_capacity_factor)
    else:
        y = mlp_apply(p["mlp"], h2, cfg.mlp)
    return x + y, new_cache


def _apply_stack(params, cfg: ArchConfig, x, positions, cache, *, train: bool):
    """Scan over units + tail. cache=None in train mode."""

    def unit_fn(x, unit_params, unit_cache):
        new_caches = {}
        for j, kind in enumerate(cfg.pattern):
            c = None if unit_cache is None else unit_cache[f"b{j}"]
            x, nc = _block_apply(unit_params[f"b{j}"], cfg, kind, x, positions, c)
            new_caches[f"b{j}"] = nc
        x = shard(x, "dp", "sp" if train else None, None)
        return x, (None if unit_cache is None else new_caches)

    if train and cfg.remat:
        unit_fn = jax.checkpoint(
            unit_fn, policy=jax.checkpoint_policies.nothing_saveable,
            static_argnums=(),
        )

    if cache is None:
        def scan_body(x, up):
            x, _ = unit_fn(x, up, None)
            return x, None

        x, _ = lax.scan(scan_body, x, params["units"])
        new_cache = None
    else:
        # cache lives in the scan CARRY, updated in place per unit.  As scan
        # xs/ys it is loop-invariant input + freshly assembled output, which
        # lets XLA hoist dtype conversions of the entire stacked cache out of
        # the loop (observed: a full f32 copy of a 64-layer KV cache).
        def scan_body(carry, up):
            x, caches, i = carry
            uc = jax.tree.map(
                lambda t: lax.dynamic_index_in_dim(t, i, 0, keepdims=False), caches
            )
            x, nc = unit_fn(x, up, uc)
            caches = jax.tree.map(
                lambda t, v: lax.dynamic_update_index_in_dim(
                    t, v.astype(t.dtype), i, 0
                ),
                caches, nc,
            )
            return (x, caches, i + 1), None

        (x, new_unit_caches, _), _ = lax.scan(
            scan_body, (x, cache["units"], jnp.int32(0)), params["units"]
        )
        new_cache = {"units": new_unit_caches}
    if cfg.block_tail:
        tail_caches = {}
        for j, kind in enumerate(cfg.block_tail):
            c = None if cache is None else cache["tail"][f"t{j}"]
            x, nc = _block_apply(params["tail"][f"t{j}"], cfg, kind, x, positions, c)
            tail_caches[f"t{j}"] = nc
        if cache is not None:
            new_cache["tail"] = tail_caches
    if cache is not None:
        new_cache["len"] = cache["len"] + x.shape[1]
    return x, new_cache


def _embed(params, cfg: ArchConfig, tokens_or_embeds):
    dt = jnp.bfloat16 if cfg.dtype == "bf16" else jnp.float32
    if tokens_or_embeds.dtype in (jnp.int32, jnp.int64):
        x = jnp.take(params["embed"], tokens_or_embeds, axis=0).astype(dt)
        x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
    else:
        x = tokens_or_embeds.astype(dt)
    return shard(x, "dp", None, None)


def _unembed_matrix(params):
    return params["unembed"] if "unembed" in params else params["embed"].T


def chunked_ce_loss(h, labels, unembed, norm_w, chunk=512):
    """Mean CE over positions without materializing [B, S, V] logits."""
    b, s, d = h.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = h.shape[1] // chunk
    hs = h.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, nc, chunk).transpose(1, 0, 2)

    def step(acc, xs):
        hc, lc = xs
        hc = rms_norm(hc, norm_w)
        logits = jnp.einsum("bsd,dv->bsv", hc, unembed.astype(hc.dtype),
                            preferred_element_type=F32)
        logits = shard(logits, "dp", None, "tp")
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1
        )[..., 0]
        valid = lc >= 0
        loss = jnp.sum(jnp.where(valid, logz - gold, 0.0))
        return (acc[0] + loss, acc[1] + jnp.sum(valid)), None

    (tot, cnt), _ = lax.scan(step, (jnp.float32(0.0), jnp.int32(0)), (hs, ls))
    return tot / jnp.maximum(cnt, 1)


def forward_train(params, cfg: ArchConfig, batch):
    """batch: {"inputs": tokens [B,S] or embeds [B,S,d], "labels": [B,S],
    "positions": [B,S] or [B,S,3]}.  Returns mean CE loss."""
    x = _embed(params, cfg, batch["inputs"])
    positions = batch["positions"]
    x, _ = _apply_stack(params, cfg, x, positions, None, train=True)
    return chunked_ce_loss(x, batch["labels"], _unembed_matrix(params),
                           params["final_norm"])


def forward_prefill(params, cfg: ArchConfig, batch, cache):
    """Prefill: run the full prompt, fill the cache, return last-token logits."""
    x = _embed(params, cfg, batch["inputs"])
    positions = batch["positions"]
    x, cache = _apply_stack(params, cfg, x, positions, cache, train=False)
    h_last = rms_norm(x[:, -1], params["final_norm"])
    logits = jnp.einsum("bd,dv->bv", h_last, _unembed_matrix(params).astype(h_last.dtype),
                        preferred_element_type=F32)
    return shard(logits, "dp", "tp"), cache


def forward_decode(params, cfg: ArchConfig, tokens, cache):
    """One decode step. tokens [B, 1] int32."""
    x = _embed(params, cfg, tokens)
    pos = cache["len"]
    positions = jnp.broadcast_to(pos[None, None], (x.shape[0], 1)).astype(jnp.int32)
    if cfg.m_rope_sections:
        positions = positions[..., None].repeat(3, axis=-1)
    x, cache = _apply_stack(params, cfg, x, positions, cache, train=False)
    h = rms_norm(x[:, 0], params["final_norm"])
    logits = jnp.einsum("bd,dv->bv", h, _unembed_matrix(params).astype(h.dtype),
                        preferred_element_type=F32)
    return shard(logits, "dp", "tp"), cache


# ---------------------------------------------------------------------- cache
def _block_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int, dtype):
    d = cfg.d_model
    if kind in ("attn", "local_attn"):
        size = min(cfg.local_window, max_len) if kind == "local_attn" else max_len
        hkv, dh = cfg.n_kv_heads, cfg.head_dim_
        return {
            "k": jnp.zeros((batch, hkv, size, dh), dtype),
            "v": jnp.zeros((batch, hkv, size, dh), dtype),
            "pos": jnp.full((batch, size), -1, jnp.int32),
        }
    w = cfg.rnn_width or d
    cw = cfg.conv_width - 1
    if kind == "rglru":
        return {"h": jnp.zeros((batch, w), F32),
                "conv": jnp.zeros((batch, cw, w), dtype)}
    if kind == "mlstm":
        up = 2 * d
        dh = up // cfg.n_heads
        return {
            "rec": (
                jnp.zeros((batch, cfg.n_heads, dh, dh), F32),
                jnp.zeros((batch, cfg.n_heads, dh), F32),
                jnp.full((batch, cfg.n_heads), -1e30, F32),
            ),
            "conv": jnp.zeros((batch, cw, up), dtype),
        }
    if kind == "slstm":
        dh = d // cfg.n_heads
        z = jnp.zeros((batch, cfg.n_heads, dh), F32)
        return {
            "rec": {"c": z, "n": z + 1e-6, "h": z, "m": jnp.zeros((batch, cfg.n_heads), F32)},
            "conv": jnp.zeros((batch, cw, d), dtype),
        }
    raise ValueError(kind)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None):
    if dtype is None:
        dtype = jnp.bfloat16 if cfg.dtype == "bf16" else jnp.float32
    unit_cache = {
        f"b{j}": _block_cache(cfg, kind, batch, max_len, dtype)
        for j, kind in enumerate(cfg.pattern)
    }
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_units,) + x.shape), unit_cache
    )
    cache = {"units": stacked, "len": jnp.int32(0)}
    if cfg.block_tail:
        cache["tail"] = {
            f"t{j}": _block_cache(cfg, kind, batch, max_len, dtype)
            for j, kind in enumerate(cfg.block_tail)
        }
    return cache


def abstract_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len, dtype))


def cache_partition_specs(cfg: ArchConfig, cache_abs, dp_divides: bool = True):
    """KV tensors: batch->dp, seq->sp (flash-decode style); states: batch->dp.

    dp_divides=False (e.g. long_500k's global_batch=1): replicate the batch
    dim — pjit input shardings require exact divisibility.
    """
    from .sharding import current_rules

    rules = current_rules()

    def ax(name):
        if name == "dp" and not dp_divides:
            return None
        return None if rules is None else rules.axis(name)

    def leaf_spec(path, x):
        names = [getattr(k, "key", getattr(k, "name", None)) for k in path]
        rank = len(x.shape)
        stacked = "units" in names
        lead = (None,) if stacked else ()
        if "k" in names or "v" in names:
            return P(*(lead + (ax("dp"), None, ax("sp"), None)))
        if "pos" in names:
            return P(*(lead + (ax("dp"), ax("sp"))))
        if rank - len(lead) >= 1 and names[-1] != "len":
            rest = (None,) * (rank - len(lead) - 1)
            return P(*(lead + (ax("dp"),) + rest))
        return P()

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_abs)
