"""Transformer building blocks: RMSNorm, RoPE / M-RoPE, GQA attention
(differentiable chunked online-softmax), sliding-window attention, MLP
variants, capacity-based MoE.

All matmuls run in bf16 with f32 accumulation (preferred_element_type);
norms and softmax statistics in f32.  Activation sharding constraints go
through models.sharding.shard — no-ops outside a mesh context.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from . import nn
from .sharding import shard

F32 = jnp.float32
_NEG = -1e30


def rms_norm(x, w, eps=1e-6):
    x32 = x.astype(F32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * lax.rsqrt(var + eps)) * w.astype(F32)).astype(x.dtype)


def dot(x, w):
    """Matmul in the activation dtype.

    No preferred_element_type=f32 + downcast here: that poisons the backward
    pass with f32 gradient operands (2x collective payload and MXU flops —
    EXPERIMENTS.md §Perf, cmd-r+ iteration 3).  TPU MXUs accumulate bf16
    products in f32 internally; explicit f32 accumulation is reserved for
    softmax logits and the CE loss.
    """
    return jnp.einsum("...d,df->...f", x, w.astype(x.dtype))


# --------------------------------------------------------------- RoPE / M-RoPE
def rope_inv_freq(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=F32) / head_dim)


def apply_rope(x, positions, theta, m_rope_sections=()):
    """x [B, H, S, D]; positions [B, S] (or [B, S, 3] with M-RoPE sections).

    M-RoPE (Qwen2-VL): the D/2 frequency slots are split into (t, h, w)
    sections; each slot rotates by its section's position component.
    """
    b, h, s, d = x.shape
    inv = rope_inv_freq(d, theta)  # [D/2]
    if m_rope_sections:
        assert sum(m_rope_sections) == d // 2, (m_rope_sections, d)
        sec_id = jnp.repeat(
            jnp.arange(len(m_rope_sections)), jnp.array(m_rope_sections),
            total_repeat_length=d // 2,
        )
        if positions.ndim == 2:  # text-only stream: t == h == w
            positions = positions[..., None].repeat(3, axis=-1)
        pos = jnp.take_along_axis(
            positions.astype(F32), sec_id[None, None, :].repeat(s, 1).repeat(b, 0), axis=2
        )  # [B, S, D/2]
    else:
        pos = positions.astype(F32)[..., None]  # [B, S, 1]
    ang = pos * inv  # [B, S, D/2]
    cos = jnp.cos(ang)[:, None, :, :]
    sin = jnp.sin(ang)[:, None, :, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------ chunked GQA attention
def attention(q, k, v, *, causal=True, window=0, q_offset=0, kv_offset=0,
              kv_pos=None, chunk=1024, scale=None, softcap=0.0):
    """GQA attention, memory-O(chunk) in KV length, differentiable.

    q [B, Hq, Sq, D]; k, v [B, Hkv, Skv, D].  q_offset: global position of
    q[…,0] (scalar or [B]); kv positions are either contiguous from kv_offset
    or given explicitly via kv_pos [B, Skv] (ring-buffer caches; slots with
    negative positions are masked out).  Returns [B, Hq, Sq, D].
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    scale = d ** -0.5 if scale is None else scale
    qg = q.reshape(b, hkv, g, sq, d)
    q_offset = jnp.asarray(q_offset)
    q_pos = q_offset.reshape(-1, 1) + jnp.arange(sq)[None, :]  # [B or 1, Sq]
    q_pos = jnp.broadcast_to(q_pos, (b, sq))

    if kv_pos is None:
        kv_pos = kv_offset + jnp.arange(skv)[None, :]
        kv_pos = jnp.broadcast_to(kv_pos, (b, skv))

    if sq == 1:
        # decode fast path: one masked softmax over the (possibly seq-sharded)
        # cache — GSPMD turns the S-axis reductions into partial-softmax psums
        # (flash-decoding); no scan, so the sharded S axis is never gathered.
        # NB: contract in the cache dtype with f32 accumulation — an explicit
        # .astype(f32) on k/v gets hoisted out of the layer scan by XLA and
        # materializes the whole stacked cache in f32.
        s = jnp.einsum("bhgqd,bhcd->bhgqc", qg, k.astype(qg.dtype),
                       preferred_element_type=F32) * scale
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        kk = kv_pos[:, None, None, None, :]
        qp = q_pos[:, None, None, :, None]
        mask = kk >= 0
        if causal:
            mask = mask & (kk <= qp)
        if window > 0:
            mask = mask & (kk > qp - window)
        s = jnp.where(mask, s, _NEG)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhgqc,bhcd->bhgqd", p.astype(v.dtype), v,
                         preferred_element_type=F32)
        return out.reshape(b, hq, sq, d).astype(q.dtype)

    chunkc = min(chunk, skv)
    pad = (-skv) % chunkc
    if pad:  # pad KV to a chunk multiple; padded slots get position -1 -> masked
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
    nc = k.shape[2] // chunkc
    ks = k.reshape(b, hkv, nc, chunkc, d).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(b, hkv, nc, chunkc, d).transpose(2, 0, 1, 3, 4)
    pos_c = kv_pos.reshape(b, nc, chunkc).transpose(1, 0, 2)  # [nc, B, C]

    def step(carry, xs):
        k_c, v_c, p_c = xs  # [B,Hkv,C,D], [B,C]
        m_prev, l_prev, acc = carry
        s = jnp.einsum("bhgqd,bhcd->bhgqc", qg, k_c.astype(qg.dtype),
                       preferred_element_type=F32) * scale
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        kk = p_c[:, None, None, None, :]  # [B,1,1,1,C]
        qp = q_pos[:, None, None, :, None]  # [B,1,1,Sq,1]
        mask = kk >= 0
        if causal:
            mask = mask & (kk <= qp)
        if window > 0:
            mask = mask & (kk > qp - window)
        s = jnp.where(mask, s, _NEG)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqc,bhcd->bhgqd", p.astype(v_c.dtype), v_c,
                        preferred_element_type=F32)
        return (m_new, l_new, alpha[..., None] * acc + pv), None

    m0 = jnp.full((b, hkv, g, sq), _NEG, F32)
    l0 = jnp.zeros((b, hkv, g, sq), F32)
    a0 = jnp.zeros((b, hkv, g, sq, d), F32)
    (m, l, acc), _ = lax.scan(step, (m0, l0, a0), (ks, vs, pos_c))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, hq, sq, d).astype(q.dtype)


# --------------------------------------------------------------- MLP variants
def mlp_init(key, d, f, kind):
    ks = nn.split_keys(key, ["in", "gate", "out"])
    p = {"w_in": nn.dense_init(ks["in"], (d, f)), "w_out": nn.dense_init(ks["out"], (f, d))}
    if kind == "swiglu":
        p["w_gate"] = nn.dense_init(ks["gate"], (d, f))
    return p


def mlp_apply(p, x, kind):
    h = dot(x, p["w_in"])
    if kind == "swiglu":
        h = jax.nn.silu(dot(x, p["w_gate"]).astype(F32)).astype(x.dtype) * h
    elif kind == "gelu":
        h = jax.nn.gelu(h.astype(F32)).astype(x.dtype)
    elif kind == "relu2":
        h = jnp.square(jax.nn.relu(h.astype(F32))).astype(x.dtype)
    else:
        raise ValueError(kind)
    h = shard(h, "dp", None, "tp")
    return dot(h, p["w_out"])


# ----------------------------------------------------------------------- MoE
def moe_init(key, d, f, n_experts, kind):
    ks = nn.split_keys(key, ["router", "in", "gate", "out"])
    p = {
        "router": nn.dense_init(ks["router"], (d, n_experts)),
        "w_in": nn.dense_init(ks["in"], (n_experts, d, f), in_axis=1),
        "w_out": nn.dense_init(ks["out"], (n_experts, f, d), in_axis=1),
    }
    if kind == "swiglu":
        p["w_gate"] = nn.dense_init(ks["gate"], (n_experts, d, f), in_axis=1)
    return p


def moe_apply(p, x, *, top_k, kind, capacity_factor=1.25, seq_chunk=512):
    """Capacity-based top-k MoE (GShard-style dispatch), seq-chunked so the
    dispatch one-hot stays O(chunk * E * C) instead of O(S * E * C).

    x [B, S, d] -> [B, S, d].  Over-capacity tokens are dropped (standard).
    """
    b, s, d = x.shape
    e = p["router"].shape[1]
    seq_chunk = min(seq_chunk, s)
    pad = (-s) % seq_chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    ns = x.shape[1] // seq_chunk
    cap = max(int(seq_chunk * top_k * capacity_factor / e), 4)

    def chunk_fn(x_c):
        # x_c [B, C_s, d]
        logits = dot(x_c, p["router"]).astype(F32)  # [B, Cs, E]
        gate_all = jax.nn.softmax(logits, axis=-1)
        gates, ids = lax.top_k(gate_all, top_k)  # [B, Cs, K]
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
        onehot = jax.nn.one_hot(ids, e, dtype=F32)  # [B, Cs, K, E]
        # position of each (token, k) within its expert, over the chunk
        pos = jnp.cumsum(onehot.reshape(b, -1, e), axis=1).reshape(b, seq_chunk, top_k, e)
        pos = (pos - 1) * onehot  # zero where not routed
        keep = (pos < cap) * onehot  # drop over-capacity
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=F32) * keep[..., None]
        # dispatch [B, Cs, K, E, cap] -> combine over (Cs)
        disp = pos_oh  # [B, Cs, K, E, cap]
        xin = jnp.einsum("bskec,bsd->becd", disp, x_c.astype(F32)).astype(x_c.dtype)
        xin = shard(xin, "dp", "tp", None, None)
        h = jnp.einsum("becd,edf->becf", xin, p["w_in"].astype(xin.dtype),
                       preferred_element_type=F32).astype(xin.dtype)
        if kind == "swiglu":
            g = jnp.einsum("becd,edf->becf", xin, p["w_gate"].astype(xin.dtype),
                           preferred_element_type=F32)
            h = jax.nn.silu(g).astype(h.dtype) * h
        else:
            h = jax.nn.gelu(h.astype(F32)).astype(h.dtype)
        y_e = jnp.einsum("becf,efd->becd", h, p["w_out"].astype(h.dtype),
                         preferred_element_type=F32)  # [B, E, cap, d] f32
        comb = disp * gates[..., None, None]  # [B, Cs, K, E, cap]
        y = jnp.einsum("bskec,becd->bsd", comb, y_e)
        return y.astype(x_c.dtype)

    xs = x.reshape(b, ns, seq_chunk, d).transpose(1, 0, 2, 3)
    ys = lax.map(chunk_fn, xs)  # scan keeps dispatch memory O(chunk)
    y = ys.transpose(1, 0, 2, 3).reshape(b, ns * seq_chunk, d)
    return y[:, :s]
