"""Host-side BSP work-stealing simulator over real enumeration trees.

The container has one physical core, so wall-clock at P in the hundreds is
meaningless; and the device engine tops out at the simulated-device count.
This module extends the makespan model (benchmarks/common.py) to the
paper's regime — P in the hundreds to thousands (Fig. 5's 1175x point is
1216 cores) — by *replaying the engine's superstep semantics in numpy*
over the real deferred-PPC enumeration tree of a dataset:

  * the tree comes from the same traversal `core.lcm.lcm_closed` runs
    (including the duplicate candidates the engine pops and rejects — they
    cost real pops), so node counts and subtree shapes are not synthetic;
  * each superstep pops <= expand_batch nodes LIFO per miner, pushes that
    node's children, takes the hunger census, and runs one steal round of
    the given lifeline schedule with the engine's exact donation rule
    (victim donates bottom floor(sp/2) capped at steal_max iff its round
    requester is hungry);
  * per-superstep cost = c_node * max_p popped[p] + census + (steal-round
    latency iff anyone is hungry — the engine's `lax.cond` gate).

The round latency is what the topology changes: an intra-host hop costs
`c_local`, a cross-host hop `c_cross` (an order of magnitude more — DCN vs
ICI scale).  Hierarchical schedules pay `c_cross` only on their rare
cross rounds; a *flat* schedule's rounds are costed honestly per round
under the block rank->host mapping — hypercube dims below log2(
devices_per_host) stay intra-host, everything else (all random perms)
crosses hosts.  That bimodal steal latency is exactly the effect the
paper's hierarchical redesign (§4.2) targets.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from repro.core.lifeline import LifelineSchedule

from .topology import Topology

__all__ = [
    "C_NODE_S",
    "C_LOCAL_ROUND_S",
    "C_CROSS_ROUND_S",
    "Tree",
    "extract_tree",
    "SimResult",
    "simulate_mine",
    "sync_cost",
    "round_costs",
]

C_NODE_S = 2e-6         # default per-node expand cost (calibratable)
C_LOCAL_ROUND_S = 5e-6  # intra-host collective hop (ICI/shared-memory scale)
C_CROSS_ROUND_S = 50e-6  # cross-host collective hop (DCN scale)


@dataclass(frozen=True)
class Tree:
    """A real deferred-PPC enumeration tree: `children[i]` are the node ids
    pushed when node i is popped (empty for leaves and PPC rejects)."""

    children: tuple  # tuple[tuple[int, ...], ...]

    @property
    def n_nodes(self) -> int:
        return len(self.children)

    @property
    def roots(self) -> tuple:
        """Depth-1 nodes — what the engine's host preprocessing deals."""
        return self.children[0]


def extract_tree(db_bool: np.ndarray, min_sup: int = 1,
                 max_nodes: int = 2_000_000) -> Tree:
    """The enumeration tree `core.lcm.lcm_closed` walks, as children lists.

    Mirrors the lcm_closed loop (static min_sup) but records structure:
    every node the engine would *pop* gets an id — including deferred-PPC
    duplicates, which become childless nodes (popped, then rejected).
    """
    from repro.core.bitmap import full_occ, pack_db, support_np, supports_np

    db_bool = np.asarray(db_bool, dtype=bool)
    n, m = db_bool.shape
    db_bits = pack_db(db_bool)
    children: list[list[int]] = [[]]
    # work stack: (node_id, occ, core_item, prefix_count)
    stack = [(0, full_occ(n), -1, 0)]
    while stack:
        nid, occ, core, pc = stack.pop()
        sup = int(support_np(occ))
        s = supports_np(occ, db_bits)
        in_closure = s == sup
        if core >= 0 and int(np.count_nonzero(in_closure[:core])) != pc:
            continue  # PPC reject: popped by the engine, no children
        cand = np.flatnonzero(
            (~in_closure) & (s >= min_sup) & (np.arange(m) > core)
        )
        clo_cum = np.cumsum(in_closure)
        for e in cand[::-1]:
            cid = len(children)
            if cid > max_nodes:
                raise RuntimeError(
                    f"enumeration tree exceeds {max_nodes} nodes; raise "
                    "min_sup or shrink the dataset"
                )
            children.append([])
            children[nid].append(cid)
            child_pc = int(clo_cum[e - 1]) if e > 0 else 0
            stack.append((cid, occ & db_bits[e], int(e), child_pc))
    return Tree(children=tuple(tuple(c) for c in children))


def sync_cost(topology: Topology, c_local: float = C_LOCAL_ROUND_S,
              c_cross: float = C_CROSS_ROUND_S) -> float:
    """Modeled hunger-census latency.

    Intra-host stage: a log-tree over local links.  Host stage: the census
    payload is 4 bytes per rank, so the cross-host allreduce is pure
    latency — modeled as one up-sweep plus one down-sweep over the
    interconnect (switch-assisted/in-network reduction; a software
    recursive-doubling tree would pay ceil(log2 H) hops instead, which
    penalizes *both* schedules equally — the census is global either way,
    so this cost is schedule-independent)."""
    c = 0.0
    if topology.devices_per_host > 1:
        c += c_local * math.ceil(math.log2(topology.devices_per_host))
    if topology.n_hosts > 1:
        c += 2 * c_cross
    return c


def round_costs(schedule: LifelineSchedule, topology: Topology,
                c_local: float = C_LOCAL_ROUND_S,
                c_cross: float = C_CROSS_ROUND_S) -> list:
    """Per-round steal-exchange latency from the reply pairs themselves,
    under the block rank->host mapping (flat and hierarchical rounds are
    costed by one rule — no tier is taken on faith):

      * fully intra-host permutation -> `c_local`;
      * crossing hosts -> `c_cross`, plus `c_local` per *additional
        distinct peer host* any single source host scatters to.

    The fan-out term is what separates the schedules at equal "did it
    cross" granularity: a hierarchical cross round pairs whole hosts
    (every message from host g lands on one host j — fan-out 1), while a
    flat random derangement scatters each host's D messages over up to D
    distinct peer hosts, serializing D message setups on one NIC."""
    out = []
    for req, rep in schedule.rounds:
        fan: dict = {}
        for s, d in rep:
            if s != d and not topology.same_host(s, d):
                fan.setdefault(topology.host_of(s), set()).add(
                    topology.host_of(d)
                )
        if not fan:
            out.append(c_local)
        else:
            widest = max(len(peers) for peers in fan.values())
            out.append(c_cross + (widest - 1) * c_local)
    return out


@dataclass(frozen=True)
class SimResult:
    supersteps: int
    makespan_s: float
    total_popped: int
    popped_per_miner: tuple     # lifetime pops by rank
    steals: int                 # successful receptions
    steal_rounds_fired: int     # supersteps whose exchange actually ran
    cross_round_s: float        # latency paid on cross-host steal rounds
    local_round_s: float        # latency paid on intra-host steal rounds
    sync_s: float               # latency paid on hunger censuses
    node_s: float               # critical-path expand seconds


def simulate_mine(tree: Tree, schedule: LifelineSchedule,
                  topology: Topology, *,
                  expand_batch: int = 16, steal_max: int = 256,
                  steal_enabled: bool = True,
                  c_node: float = C_NODE_S,
                  c_local: float = C_LOCAL_ROUND_S,
                  c_cross: float = C_CROSS_ROUND_S,
                  max_steps: int = 1_000_000) -> SimResult:
    """Replay one count-phase mine of `tree` on P simulated miners.

    Semantics mirror core/engine.py's superstep: EXPAND pops up to
    expand_batch LIFO and pushes children; the census counts empty stacks;
    STEAL runs round t % R — victims with a hungry round-requester donate
    the bottom half of their stack (oldest, shallowest subtrees), capped at
    steal_max; termination when every stack is empty.  Root deal is the
    engine's round-robin: depth-1 node i goes to miner i mod P.
    """
    P = topology.n_proc
    if schedule.n_proc != P:
        raise ValueError(
            f"schedule is sized for {schedule.n_proc} miners, topology has {P}"
        )
    children = tree.children
    roots = tree.roots
    stacks: list[list] = [[] for _ in range(P)]
    for i, nid in enumerate(roots):
        stacks[i % P].append(nid)
    R = schedule.n_rounds
    costs = round_costs(schedule, topology, c_local, c_cross)
    c_sync = sync_cost(topology, c_local, c_cross)
    popped_total = [0] * P
    steals = 0
    fired = 0
    node_s = sync_s = local_s = cross_s = 0.0
    t = 0
    while True:
        if t >= max_steps:
            raise RuntimeError(f"simulation exceeded {max_steps} supersteps")
        # EXPAND: batch-pop then push all children (engine order)
        step_max = 0
        for p in range(P):
            st = stacks[p]
            k = min(expand_batch, len(st))
            if k:
                popped = [st.pop() for _ in range(k)]
                for nid in popped:
                    st.extend(children[nid])
                popped_total[p] += k
                step_max = max(step_max, k)
        node_s += c_node * step_max
        sync_s += c_sync
        t += 1
        # census (exact termination, doubles as the REQUEST side)
        hungry = [not stacks[p] for p in range(P)]
        n_hungry = sum(hungry)
        if n_hungry == P:
            break
        # STEAL: one gated exchange round
        if steal_enabled and n_hungry > 0:
            r = (t - 1) % R
            fired += 1
            if costs[r] >= c_cross:
                cross_s += costs[r]
            else:
                local_s += costs[r]
            req_pairs, _rep = schedule.rounds[r]
            moves = []
            for s, d in req_pairs:
                if s == d or not hungry[s]:
                    continue
                sp = len(stacks[d])
                if sp > 1:
                    moves.append((s, d, min(sp // 2, steal_max)))
            for s, d, k in moves:  # apply simultaneously (one collective)
                stacks[s] = stacks[d][:k]   # bottom k: oldest subtrees
                stacks[d] = stacks[d][k:]
                steals += 1
    return SimResult(
        supersteps=t,
        makespan_s=node_s + sync_s + local_s + cross_s,
        total_popped=sum(popped_total),
        popped_per_miner=tuple(popped_total),
        steals=steals,
        steal_rounds_fired=fired,
        cross_round_s=cross_s,
        local_round_s=local_s,
        sync_s=sync_s,
        node_s=node_s,
    )
