"""repro.topo — multi-host topology as a first-class engine concept.

The paper's headline 1175x-on-1200-cores result rests on lifeline-graph
load balancing whose communication stays evenly distributed as the machine
grows past one host.  This package makes the machine shape explicit:

  topology.py   frozen `Topology(n_hosts, devices_per_host)` — detected from
                jax.distributed process metadata or forced for simulation;
                hashable, so it rides the compiled-program cache key.
  hierarchy.py  the two-level lifeline schedule: cheap intra-host rounds
                interleaved with less-frequent cross-host rounds, emitted in
                the same round format `core/steal.py` already consumes.
  bootstrap.py  `jax.distributed.initialize`-based multi-process bring-up,
                global-array argument/result marshalling, and a local
                subprocess cluster launcher so multi-host paths are testable
                in CI on one machine.
  simulate.py   host-side BSP work-stealing simulator over real enumeration
                trees — the makespan model behind benchmarks/bench_scaling.

`bootstrap` is imported lazily (it touches jax.distributed); the topology
model and the schedule builder are importable with no side effects.
"""

from .hierarchy import build_hierarchical_schedule
from .topology import Topology, detect_topology

__all__ = ["Topology", "detect_topology", "build_hierarchical_schedule"]
