"""Two-level lifeline schedule: intra-host rounds + aligned cross-host rounds.

The flat schedule (core/lifeline.build_schedule) treats all P miners as
equidistant; on a multi-host mesh that makes most steal rounds pay
cross-host latency.  The survey literature's fix — and the natural reading
of the paper's §4.2 lifeline graph at scale — is locality: steal often from
host-mates (cheap), rarely across hosts (the random lifeline edges become
the *global* tier that keeps the whole machine connected).

This builder emits the exact same cyclic `(request_pairs, reply_pairs)`
round format `core/steal.py` consumes, in global miner-rank coordinates —
so a hierarchical schedule runs unchanged on a 1-D mesh (useful for
single-process oracles).  It *additionally* factorizes every round onto
exactly one axis of the 2-D topo mesh:

  * a **local** round applies the same intra-host pairing on every host —
    one `ppermute` over the "local" axis;
  * a **cross** round pairs host h with host h' at equal local rank — one
    `ppermute` over the "hosts" axis.

Each tier is itself the paper's hypercube-with-holes + frozen random
derangements, built at its own size (devices_per_host resp. n_hosts).  The
cycle inserts one cross round after every `cross_every` local rounds
(cycling the local list as needed — a cyclic schedule may repeat a round
within one grand cycle), so the cross-traffic fraction is pinned at
1 / (cross_every + 1) *regardless of H*: fatter machines don't drift
toward cross-dominated cycles just because log2(H) outgrows log2(D).

Round naming (`loc_*` / `x_*`) is load-bearing: obs/trace groups steal
telemetry by round name and splits Jain's fairness by the schedule's
`tiers` tuple, so intra- vs cross-host steal volume is observable.
"""

from __future__ import annotations

import numpy as np

from repro.core.collectives import HOSTS_AXIS, LOCAL_AXIS
from repro.core.lifeline import (
    LifelineSchedule,
    _hypercube_pairs,
    _random_perm_pairs,
)

from .topology import Topology

__all__ = ["build_hierarchical_schedule"]


def _expand_local(pairs, topology: Topology):
    """Intra-host (a, b) pairs -> global pairs, replicated on every host."""
    d = topology.devices_per_host
    return tuple(
        (h * d + a, h * d + b)
        for h in range(topology.n_hosts)
        for (a, b) in pairs
    )


def _expand_cross(host_pairs, topology: Topology):
    """Host-level (g, j) pairs -> global pairs at every equal local rank."""
    d = topology.devices_per_host
    return tuple(
        (g * d + local, j * d + local)
        for (g, j) in host_pairs
        for local in range(d)
    )


def _tier_rounds(p: int, n_random: int, rng) -> tuple[list, list, int]:
    """One tier's flat-style cycle at size `p`: (rounds, labels, z).

    Mirrors core/lifeline.build_schedule: rand/hc interleaved per hypercube
    dim, then extra random derangements up to `n_random`.  Rounds are in
    tier-local coordinates ([0, p) ranks).
    """
    z = max(1, int(np.ceil(np.log2(max(p, 2)))))
    rounds, labels = [], []
    ri = 0
    for d in range(z):
        rounds.append(_random_perm_pairs(p, rng))
        labels.append(f"rand{ri}")
        ri += 1
        hc = _hypercube_pairs(p, d)
        rounds.append((hc, hc))
        labels.append(f"hc{d}")
    for _ in range(max(0, n_random - z)):
        rounds.append(_random_perm_pairs(p, rng))
        labels.append(f"rand{ri}")
        ri += 1
    return rounds, labels, z


def build_hierarchical_schedule(
    topology: Topology, n_random: int = 4, seed: int = 0,
    cross_every: int = 1,
) -> LifelineSchedule:
    """Cyclic two-level steal schedule for an H x D topology.

    `cross_every` local rounds separate consecutive cross rounds — the
    knob trading global spread speed (small values) against cross-host
    latency share (large values).  The default of 1 is what the scaling
    model (topo/simulate.py) favors under a 10x cross/local latency
    ratio: a cross round's real saving over a flat round is *alignment*
    (whole-host pairings, fan-out 1 over the interconnect), so starving
    the global tier costs more supersteps than it saves in latency.

    Degenerate shapes stay sensible: H == 1 emits the local tier only
    (equivalent to a flat schedule over one host's devices), D == 1 emits
    the cross tier only (a flat schedule over hosts).  P == 1 yields one
    no-op round so the engine's round indexing stays well-defined.
    """
    H, D = topology.n_hosts, topology.devices_per_host
    rng = np.random.default_rng(seed)
    n_random = max(1, n_random)

    local, cross = [], []  # [(name, axis_pairs, global_pairs_pair)]
    z_loc = z_host = 0
    if D > 1:
        rounds, labels, z_loc = _tier_rounds(D, n_random, rng)
        for (req, rep), label in zip(rounds, labels):
            local.append((
                f"loc_{label}", (req, rep),
                (_expand_local(req, topology), _expand_local(rep, topology)),
            ))
    if H > 1:
        # the global tier cycles every dim but skips the extra decorrelation
        # randoms — the cycle length (and so the cross fraction) stays
        # governed by cross_every alone
        rounds, labels, z_host = _tier_rounds(H, 1, rng)
        for (req, rep), label in zip(rounds, labels):
            cross.append((
                f"x_{label}", (req, rep),
                (_expand_cross(req, topology), _expand_cross(rep, topology)),
            ))
    if not local and not cross:  # P == 1: one empty round, nothing to steal
        return LifelineSchedule(
            n_proc=1, dim=1, rounds=(((), ()),), names=("loc_noop",),
            round_axes=(LOCAL_AXIS,), axis_rounds=(((), ()),),
            tiers=("local",),
        )

    # pin the cross fraction: `cross_every` local rounds (cycling the local
    # list) before each cross round.  One grand cycle visits every cross
    # round once and every local round at least once.
    entries = []
    if not cross:
        entries = [("local", e) for e in local]
    elif not local:
        entries = [("cross", e) for e in cross]
    else:
        cross_every = max(1, cross_every)
        li = 0
        for xe in cross:
            for _ in range(cross_every):
                entries.append(("local", local[li % len(local)]))
                li += 1
            entries.append(("cross", xe))
        while li < len(local):  # short cross tier: finish the local cycle
            entries.append(("local", local[li]))
            li += 1

    names, axis_rounds, global_rounds, round_axes, tiers = [], [], [], [], []
    for tier, (name, axis_pair, global_pair) in entries:
        names.append(name)
        axis_rounds.append(axis_pair)
        global_rounds.append(global_pair)
        round_axes.append(LOCAL_AXIS if tier == "local" else HOSTS_AXIS)
        tiers.append(tier)
    return LifelineSchedule(
        n_proc=topology.n_proc,
        dim=z_loc + z_host,
        rounds=tuple(global_rounds),
        names=tuple(names),
        round_axes=tuple(round_axes),
        axis_rounds=tuple(axis_rounds),
        tiers=tuple(tiers),
    )
