"""The machine shape: hosts x devices-per-host, frozen and hashable.

A `Topology` answers one question for the rest of the system: which global
miner ranks share a host (cheap steals) and which do not (expensive ones).
Global rank follows the mesh layout `make_topo_mesh` builds — devices
reshaped [n_hosts, devices_per_host] row-major, so

    rank = host * devices_per_host + local

matches both jax.distributed's device ordering (process i owns the i-th
contiguous block of global devices) and a single process *simulating* a
multi-host shape by reshaping its local devices.  The dataclass is frozen
and hashable on purpose: it lands in `EngineConfig`/`RuntimeConfig`, so
flat and hierarchical programs can never collide in a session's
compiled-program cache.
"""

from __future__ import annotations

from dataclasses import dataclass

__all__ = ["Topology", "detect_topology"]


@dataclass(frozen=True)
class Topology:
    """`n_hosts` x `devices_per_host` grid of miners, row-major global rank."""

    n_hosts: int
    devices_per_host: int

    def __post_init__(self):
        if self.n_hosts < 1 or self.devices_per_host < 1:
            raise ValueError(
                f"topology needs n_hosts >= 1 and devices_per_host >= 1, got "
                f"({self.n_hosts}, {self.devices_per_host})"
            )

    @property
    def n_proc(self) -> int:
        """Total miner count P = n_hosts * devices_per_host."""
        return self.n_hosts * self.devices_per_host

    # ------------------------------------------------------- rank arithmetic
    def host_of(self, rank: int) -> int:
        """Which host owns global miner `rank`."""
        self._check_rank(rank)
        return rank // self.devices_per_host

    def local_of(self, rank: int) -> int:
        """`rank`'s intra-host position (0..devices_per_host-1)."""
        self._check_rank(rank)
        return rank % self.devices_per_host

    def rank_of(self, host: int, local: int) -> int:
        """Global rank of (host, local) — inverse of host_of/local_of."""
        if not (0 <= host < self.n_hosts):
            raise ValueError(f"host {host} outside [0, {self.n_hosts})")
        if not (0 <= local < self.devices_per_host):
            raise ValueError(
                f"local rank {local} outside [0, {self.devices_per_host})"
            )
        return host * self.devices_per_host + local

    def same_host(self, rank_a: int, rank_b: int) -> bool:
        return self.host_of(rank_a) == self.host_of(rank_b)

    def _check_rank(self, rank: int) -> None:
        if not (0 <= rank < self.n_proc):
            raise ValueError(f"rank {rank} outside [0, {self.n_proc})")

    def __str__(self) -> str:  # "2x4" — compact for labels and cache keys
        return f"{self.n_hosts}x{self.devices_per_host}"


def detect_topology() -> Topology:
    """The running process layout, from jax.distributed metadata.

    Multi-process (after `bootstrap.init_distributed` /
    `jax.distributed.initialize`): one "host" per process, each contributing
    its local devices.  Single-process: a 1 x device_count topology —
    callers simulating a multi-host shape on one process should construct
    `Topology(n_hosts, devices_per_host)` directly instead ("forced" mode).
    """
    import jax

    n_proc = jax.process_count()
    if n_proc > 1:
        return Topology(n_hosts=n_proc, devices_per_host=jax.local_device_count())
    return Topology(n_hosts=1, devices_per_host=jax.device_count())
