"""Multi-process mesh bring-up + argument/result marshalling (DESIGN.md §12).

Three concerns, all version-portable behind this module:

1. **Bring-up** — `init_distributed()` wraps `jax.distributed.initialize`
   with the CPU-collectives (gloo) configuration a simulated multi-host run
   needs.  It must run before the first jax backend touch in the process;
   the device-count XLA flag must already be in the environment (the
   launcher below sets both).

2. **Marshalling** — the engine's host pre/postprocess is deterministic
   numpy: every process derives the *identical* full argument arrays from
   the same dataset, so `globalize_args` just wraps them as global
   `jax.Array`s (each process contributing its local shards via
   `make_array_from_callback`) matching the phase program's PartitionSpecs,
   and `fetch_outputs` brings results back — `process_allgather` for
   miner-sharded outputs, the local replica for replicated ones.  Every
   process ends up with the same numpy outputs, so the existing
   single-process postprocess (and ResultSet construction) runs unchanged
   everywhere.

3. **CI testability** — `launch_local_cluster` spawns N local processes x
   M simulated devices against a 127.0.0.1 coordinator, mirroring
   tests/engine_subproc_main.py's launcher: each child runs a harness
   script with the cluster coordinates folded into its JSON spec, and the
   parent returns process 0's JSON stdout.  Multi-host code paths get
   exercised on one machine, every commit.
"""

from __future__ import annotations

import json
import socket
import subprocess
import sys

import numpy as np

__all__ = [
    "init_distributed",
    "is_multiprocess",
    "globalize_args",
    "fetch_outputs",
    "free_port",
    "launch_local_cluster",
]


def init_distributed(
    coordinator_address: str, num_processes: int, process_id: int
) -> None:
    """`jax.distributed.initialize` with gloo CPU collectives.

    Call before any other jax API in the process (the backend locks its
    device/process view on first use).  On CPU the cross-process collective
    transport must be selected explicitly — without it the processes come
    up as P isolated singletons.
    """
    import jax

    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:
        # flag absent on this jax version: TPU/GPU backends bring their own
        # transport; CPU multi-process will fail loudly at initialize()
        pass
    jax.distributed.initialize(
        coordinator_address=coordinator_address,
        num_processes=num_processes,
        process_id=process_id,
    )


def is_multiprocess() -> bool:
    """True under a live jax.distributed runtime spanning > 1 process."""
    import jax

    return jax.process_count() > 1


# ----------------------------------------------------------- marshalling
def globalize_args(args, mesh, specs):
    """Host numpy argument tuple -> global jax.Arrays on `mesh` per `specs`.

    Every process must pass the *same* full arrays (engine preprocessing is
    deterministic, so they do); each wraps only its addressable shards.
    Single-process meshes pass through unchanged — the dispatch path stays
    zero-cost there.
    """
    import jax
    from jax.sharding import NamedSharding

    if not is_multiprocess():
        return tuple(args)
    out = []
    for arg, spec in zip(args, specs):
        arr = np.asarray(arg)
        sharding = NamedSharding(mesh, spec)
        out.append(
            jax.make_array_from_callback(
                arr.shape, sharding, lambda idx, a=arr: a[idx]
            )
        )
    return tuple(out)


def fetch_outputs(raw, specs):
    """Global jax.Array outputs -> full numpy arrays on every process.

    Miner-sharded outputs (non-empty spec) are allgathered across
    processes; replicated outputs are read from the local replica.  After
    this, every process holds identical numpy results and the ordinary
    host postprocess produces the same ResultSet everywhere.
    """
    import jax
    from jax.experimental import multihost_utils

    if not is_multiprocess():
        return raw
    out = []
    for x, spec in zip(raw, specs):
        if isinstance(x, jax.Array) and any(s is not None for s in spec):
            out.append(
                np.asarray(multihost_utils.process_allgather(x, tiled=True))
            )
        elif isinstance(x, jax.Array):
            out.append(np.asarray(x.addressable_data(0)))
        else:
            out.append(np.asarray(x))
    return tuple(out)


# ------------------------------------------------------- local CI cluster
def free_port() -> int:
    """An OS-assigned free TCP port on localhost (for the coordinator)."""
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def launch_local_cluster(
    harness_path: str,
    spec: dict,
    *,
    n_processes: int,
    devices_per_process: int,
    timeout: float = 900.0,
    env: dict | None = None,
):
    """Run `harness_path` as an N-process gloo cluster on this machine.

    Each child gets `spec` plus the cluster coordinates
    (coordinator/num_processes/process_id) as its argv[1] JSON, and an
    environment forcing `devices_per_process` simulated host devices
    (replacing any inherited device-count flag — the harness itself must
    not touch jax before calling `init_distributed`).  Returns the last
    stdout line of process 0 parsed as JSON; raises with the children's
    stderr on any nonzero exit.
    """
    from repro.core.collectives import host_device_count_env

    coordinator = f"127.0.0.1:{free_port()}"
    child_env = host_device_count_env(devices_per_process, env)
    procs = []
    for pid in range(n_processes):
        child_spec = dict(
            spec,
            coordinator=coordinator,
            num_processes=n_processes,
            process_id=pid,
        )
        procs.append(
            subprocess.Popen(
                [sys.executable, harness_path, json.dumps(child_spec)],
                stdout=subprocess.PIPE,
                stderr=subprocess.PIPE,
                text=True,
                env=child_env,
            )
        )
    outs = []
    try:
        for p in procs:
            outs.append(p.communicate(timeout=timeout))
    except subprocess.TimeoutExpired:
        for p in procs:
            p.kill()
        raise
    failures = [
        f"process {i} exit {p.returncode}:\n{outs[i][1][-4000:]}"
        for i, p in enumerate(procs)
        if p.returncode != 0
    ]
    if failures:
        raise RuntimeError(
            f"local cluster ({n_processes}x{devices_per_process}) failed:\n"
            + "\n".join(failures)
        )
    return json.loads(outs[0][0].strip().splitlines()[-1])
