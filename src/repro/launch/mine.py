"""Distributed pattern-mining launcher (the paper's workload).

  python -m repro.launch.mine --problem hapmap_dom_10 --scale-items 0.02 \
      --devices 8 --alpha 0.05

One-shot front-end over the query API (`repro.api`): builds a `Dataset`
(packed once, SNP-style item names) and a `MinerSession`, runs one query
object, and prints the typed `MineReport`.  The objective is selectable:

  --query significant       LAMP staging at --alpha (default)
  --query closed-frequent   every closed itemset with support >= --min-sup
  --query topk              the --k most significant patterns, alpha-free

and so is the test statistic (--stat fisher|chi2) for the testing
objectives.  For sustained query traffic against a warm session use
`repro.launch.mine_serve`.

Set --devices N to fork with XLA_FLAGS=--xla_force_host_platform_device_count=N
(one miner per device, as on a real pod slice); with --devices 0 the current
jax device set is used.  --no-steal reproduces the paper's naive baseline.
--top-k prints the most significant mined itemsets (the run's actual
deliverable) and --patterns-out exports the full ResultSet as TSV/JSON.
Per-miner stacks are auto-sized by `RuntimeConfig.resolve` (items per miner,
clamped by word-width-aware stack memory); --stack-cap overrides.

Observability (repro.obs, DESIGN.md §9): --verbose streams structured
JSON-lines run records (kernel provenance, per-phase walls, cache state) to
stderr; --trace-period N samples the on-device superstep trace every N
supersteps and prints its load-balance summary; --trace-out exports the
host span timeline as Chrome-trace JSON (open in ui.perfetto.dev);
--metrics-out snapshots the session's Prometheus metrics.
"""

from __future__ import annotations

import argparse
import json
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--problem", default="hapmap_dom_10")
    ap.add_argument("--scale-items", type=float, default=0.02)
    ap.add_argument("--scale-trans", type=float, default=1.0)
    ap.add_argument("--alpha", type=float, default=0.05)
    ap.add_argument("--query", default="significant",
                    choices=["significant", "closed-frequent", "topk"],
                    help="mining objective (a repro.api.QUERIES key)")
    ap.add_argument("--stat", default="fisher", choices=["fisher", "chi2"],
                    help="test statistic (a repro.stats registry key; "
                         "ignored by --query closed-frequent)")
    ap.add_argument("--min-sup", type=int, default=0,
                    help="support threshold for --query closed-frequent "
                         "(required there; ignored elsewhere)")
    ap.add_argument("--k", type=int, default=10,
                    help="patterns to mine for --query topk")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--hosts", type=int, default=0,
                    help="simulate a hosts x devices-per-host machine "
                         "(repro.topo): 2-D mesh + hierarchical two-level "
                         "lifeline schedule, single process")
    ap.add_argument("--devices-per-host", type=int, default=0,
                    help="local devices per simulated host (with --hosts)")
    ap.add_argument("--no-steal", action="store_true")
    ap.add_argument("--expand-batch", type=int, default=16)
    ap.add_argument("--steal-max", type=int, default=128)
    ap.add_argument("--stack-cap", type=int, default=0,
                    help="per-miner stack capacity (0 = auto-size)")
    ap.add_argument("--kernel", default="auto",
                    choices=["auto", "ref", "pallas", "pallas_interpret",
                             "pallas_gpu"],
                    help="support-count kernel (auto: pallas on TPU, "
                         "pallas_gpu on GPU, ref elsewhere)")
    ap.add_argument("--sync-period", type=int, default=4,
                    help="supersteps between lambda/histogram syncs "
                         "(staleness costs work, never results)")
    ap.add_argument("--pipeline", default="three_phase",
                    help="LAMP pipeline (an api.PIPELINES key, e.g. "
                         "three_phase | fused23)")
    ap.add_argument("--top-k", type=int, default=10,
                    help="print the k most significant mined patterns")
    ap.add_argument("--patterns-out", default="",
                    help="write the full mined ResultSet (.tsv or .json)")
    ap.add_argument("--out-cap", type=int, default=4096,
                    help="per-miner pattern emission buffer capacity")
    ap.add_argument("--json-out", default="")
    ap.add_argument("--verbose", action="store_true",
                    help="stream structured JSON-lines run records to stderr")
    ap.add_argument("--trace-period", type=int, default=0,
                    help="sample the device superstep trace every N "
                         "supersteps (0 = off)")
    ap.add_argument("--trace-cap", type=int, default=0,
                    help="trace ring slots per miner (0 = default when "
                         "tracing)")
    ap.add_argument("--trace-out", default="",
                    help="write the host span timeline as Chrome-trace JSON")
    ap.add_argument("--metrics-out", default="",
                    help="write a Prometheus text-format metrics snapshot")
    ap.add_argument("--ckpt-dir", default="",
                    help="write frontier checkpoints under this directory "
                         "(requires --ckpt-period)")
    ap.add_argument("--ckpt-period", type=int, default=0,
                    help="supersteps between frontier checkpoints "
                         "(0 = off; enables the segmented engine)")
    ap.add_argument("--resume", default="",
                    help="resume from the newest valid checkpoint under "
                         "this directory (elastic: the saved frontier is "
                         "re-dealt onto the current device count)")
    args = ap.parse_args(argv)

    if (args.ckpt_dir or args.resume) and args.ckpt_period < 1:
        ap.error("--ckpt-dir/--resume need --ckpt-period N (N >= 1): "
                 "checkpoints are cut at segment boundaries of the "
                 "segmented engine")
    if args.query == "closed-frequent" and args.min_sup < 1:
        ap.error("--query closed-frequent needs --min-sup N (N >= 1): the "
                 "objective is every closed itemset with support >= N")

    topology = None
    if args.hosts or args.devices_per_host:
        if args.hosts < 1 or args.devices_per_host < 1:
            ap.error("--hosts and --devices-per-host go together (both >= 1)")
        from repro.topo import Topology

        topology = Topology(args.hosts, args.devices_per_host)
        if args.devices and args.devices != topology.n_proc:
            ap.error(f"--devices {args.devices} contradicts --hosts x "
                     f"--devices-per-host = {topology.n_proc}")
        args.devices = topology.n_proc

    if args.devices:
        from repro.core.collectives import force_host_device_count

        if not force_host_device_count(args.devices):
            print(f"[warn] jax already initialized; --devices {args.devices} "
                  "ignored (set XLA_FLAGS before launch)", file=sys.stderr)

    from repro.api import (
        PIPELINES,
        AlgorithmConfig,
        ClosedFrequentQuery,
        Dataset,
        MinerSession,
        RuntimeConfig,
        SignificantPatternQuery,
        TopKSignificantQuery,
    )
    from repro.obs import JsonlLogger
    from repro.results import score_planted

    if args.pipeline not in PIPELINES:
        ap.error(f"--pipeline: unknown {args.pipeline!r}; "
                 f"available: {sorted(PIPELINES)}")

    log = JsonlLogger() if args.verbose else None
    ds = Dataset.from_paper_problem(
        args.problem, args.scale_items, args.scale_trans
    )
    spec = ds.spec
    print(f"[data] {spec.name}: {spec.n_items} items x {spec.n_transactions} "
          f"transactions, density {spec.density:.3f}, N_pos {spec.n_pos}")
    if log:
        log.event("data", problem=spec.name, items=spec.n_items,
                  transactions=spec.n_transactions, n_pos=spec.n_pos,
                  density=round(spec.density, 4))

    session = MinerSession(
        algorithm=AlgorithmConfig(alpha=args.alpha, statistic=args.stat,
                                  pipeline=args.pipeline),
        runtime=RuntimeConfig(
            expand_batch=args.expand_batch,
            steal_max=args.steal_max,
            steal_enabled=not args.no_steal,
            kernel_impl=args.kernel,
            sync_period=args.sync_period,
            out_cap=args.out_cap,
            trace_period=args.trace_period,
            trace_cap=args.trace_cap,
            ckpt_period=args.ckpt_period,
            topology=topology,
            # stack_cap=None: sized by RuntimeConfig.resolve for the
            # dataset's bucket and the devices actually available
            stack_cap=args.stack_cap or None,
        ),
    )
    if args.query == "closed-frequent":
        query = ClosedFrequentQuery(min_sup=args.min_sup)
    elif args.query == "topk":
        query = TopKSignificantQuery(k=args.k, statistic=args.stat)
    else:
        query = SignificantPatternQuery(
            alpha=args.alpha, statistic=args.stat, pipeline=args.pipeline
        )
    t0 = time.time()
    report = session.run(ds, query,
                         ckpt_dir=args.ckpt_dir or None,
                         resume_from=args.resume or None)
    dt = time.time() - t0
    if any(p.resumed for p in report.phases):
        resumed = [p.mode for p in report.phases if p.resumed]
        print(f"[ckpt] resumed phase(s) {resumed} from {args.resume}",
              file=sys.stderr)
    if log:
        for p in report.phases:
            log.event(
                "phase", mode=p.mode, wall_s=round(p.wall_s, 4),
                compile_s=round(p.compile_s, 4), cache_hit=p.cache_hit,
                supersteps=p.supersteps, lam_final=p.lam_final,
                n_nodes=p.n_nodes, steal_rounds=p.steal_rounds,
                kernel_impl=p.kernel_impl, kernel_blocks=p.kernel_blocks,
                item_tile=p.item_tile, emit_dropped=p.emit_dropped,
                trace_dropped=p.trace_dropped,
            )
    # per-device work telemetry: the count phase for the LAMP staging
    # (phases[1], the historical meaning of these JSON keys); objectives
    # with a single/variable staging report their last traversal
    work_phase = (report.phases[1] if report.query == "significant"
                  and len(report.phases) > 1 else report.phases[-1]).output
    rs = report.results
    import math

    out = {
        "problem": spec.name,
        "query": report.query,
        "statistic": report.statistic,
        "pipeline": report.pipeline,
        "lambda": report.lambda_final,
        "min_sup": report.min_sup,
        "closed_sets": report.correction_factor,
        "delta": None if math.isnan(report.delta) else report.delta,
        "significant": report.n_significant,
        "patterns": len(rs),
        "patterns_complete": rs.complete,
        "wall_s": round(dt, 3),
        "supersteps": [p.supersteps for p in report.phases],
        "per_device_popped": work_phase.stats["popped"].tolist(),
        "steals": int(sum(work_phase.stats["steals_got"])),
    }
    if args.ckpt_period:
        out["ckpt"] = {
            "partial": report.partial,
            "resumed": [p.mode for p in report.phases if p.resumed],
            "writes": sum(p.ckpt_writes for p in report.phases),
            "bytes": sum(p.ckpt_bytes for p in report.phases),
            "path": report.ckpt_path,
        }
    if report.query == "significant":
        out["planted_recall"] = score_planted(rs, ds.planted)["recall"]
    if args.trace_period:
        # the work phase's decoded device timeline, as load-balance metrics
        wp = (report.phases[1] if report.query == "significant"
              and len(report.phases) > 1 else report.phases[-1])
        if wp.trace is not None:
            out["superstep_trace"] = wp.trace.summary()
    print(json.dumps(out, indent=1, default=str))
    if log:
        ci = session.cache_info()
        log.event("run", **out,
                  cache={"hits": ci.hits, "misses": ci.misses,
                         "evictions": ci.evictions,
                         "programs": ci.n_programs})

    planted = ds.planted if report.statistic is not None else None
    print("\n" + rs.describe(args.top_k, planted=planted))

    if args.patterns_out:
        rs.save(args.patterns_out)
        print(f"[out] wrote {len(rs)} patterns to {args.patterns_out}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(out, f, default=str)
    if args.trace_out:
        session.tracer.save(args.trace_out)
        print(f"[out] wrote host span timeline to {args.trace_out} "
              "(open in ui.perfetto.dev)")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(session.metrics.expose_text())
        print(f"[out] wrote metrics snapshot to {args.metrics_out}")


if __name__ == "__main__":
    main()
