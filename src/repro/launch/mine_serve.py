"""Query-serving CLI: N significant-pattern queries through repro.serve.

  python -m repro.launch.mine_serve --problem hapmap_dom_10 --scale-items 0.02 \
      --devices 8 --queries 16 --concurrency 2

A thin client of the async mining service (DESIGN.md §10): it pre-builds
the query workload (reseeded same-shape synthetic cohorts × a cycle of
significance levels), starts a `MiningService` — a fleet of
`--concurrency` warm sessions behind the admission-controlled scheduler —
warms the workload's shape bucket before any traffic, then drains the
queries closed-loop and prints per-query lines as results resolve.
`--concurrency 1` is the serial mode (one session, one in flight), the
like-for-like successor of the old in-process loop.

Every query should dispatch fully warm (the bucket is pre-compiled at
startup); queries that still compiled something are *counted* and
surfaced as `warm_violations` in the summary instead of tripping an
assert, so operators see degradation without the tool dying mid-run.

  --smoke        CI-sized: tiny scales, 4 queries (used by the slow-system job)
  --json-out     machine-readable latencies + cache stats
  --verbose      structured JSON-lines query records to stderr (repro.obs)
  --metrics-out  Prometheus text snapshot of the shared service registry:
                 serve_* scheduler metrics + miner_* session metrics
"""

from __future__ import annotations

import argparse
import asyncio
import json
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--problem", default="hapmap_dom_10")
    ap.add_argument("--scale-items", type=float, default=0.02)
    ap.add_argument("--scale-trans", type=float, default=1.0)
    ap.add_argument("--queries", type=int, default=16)
    ap.add_argument("--alphas", default="0.05,0.01",
                    help="comma-separated significance levels cycled across queries")
    ap.add_argument("--pipeline", default="three_phase")
    ap.add_argument("--stat", default="fisher", choices=["fisher", "chi2"],
                    help="test statistic served by the sessions")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--expand-batch", type=int, default=16)
    ap.add_argument("--kernel", default="ref",
                    choices=["ref", "pallas", "pallas_interpret"])
    ap.add_argument("--top-k", type=int, default=3,
                    help="patterns shown per query (0 = summary line only)")
    ap.add_argument("--concurrency", type=int, default=1,
                    help="session fleet size AND in-flight clients "
                         "(1 = serial serving)")
    ap.add_argument("--queue-capacity", type=int, default=64,
                    help="admission bound of the scheduler queue")
    ap.add_argument("--timeout-s", type=float, default=None,
                    help="per-query deadline (default: none)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: tiny scales and 4 queries")
    ap.add_argument("--json-out", default="")
    ap.add_argument("--verbose", action="store_true",
                    help="stream structured JSON-lines query records to stderr")
    ap.add_argument("--metrics-out", default="",
                    help="write a Prometheus text-format metrics snapshot")
    args = ap.parse_args(argv)
    if args.queries < 1:
        ap.error("--queries must be >= 1")
    if args.concurrency < 1:
        ap.error("--concurrency must be >= 1")
    if args.smoke:
        args.scale_items = min(args.scale_items, 0.01)
        args.queries = min(args.queries, 4)

    if args.devices:
        from repro.core.collectives import force_host_device_count

        if not force_host_device_count(args.devices):
            print(f"[warn] jax already initialized; --devices {args.devices} "
                  "ignored (set XLA_FLAGS before launch)", file=sys.stderr)

    from repro.api import (
        PIPELINES, AlgorithmConfig, Dataset, RuntimeConfig,
        SignificantPatternQuery,
    )
    from repro.obs import JsonlLogger
    from repro.serve import (
        MiningService, ServeConfig, WarmupSpec, latency_histogram,
        percentile,
    )

    log = JsonlLogger() if args.verbose else None
    if args.pipeline not in PIPELINES:
        ap.error(f"--pipeline: unknown {args.pipeline!r}; "
                 f"available: {sorted(PIPELINES)}")
    alphas = [float(a) for a in args.alphas.split(",") if a]

    # the workload: reseeded same-shape cohorts (same bucket -> warm) at
    # cycling significance levels, built client-side before the clock
    work = []
    for q in range(args.queries):
        ds = Dataset.from_paper_problem(
            args.problem, args.scale_items, args.scale_trans, seed=q
        )
        query = SignificantPatternQuery(
            alpha=alphas[q % len(alphas)], statistic=args.stat,
            pipeline=args.pipeline,
        )
        work.append((ds, query))

    service = MiningService(
        size=args.concurrency,
        algorithm=AlgorithmConfig(pipeline=args.pipeline, statistic=args.stat),
        runtime=RuntimeConfig(expand_batch=args.expand_batch,
                              kernel_impl=args.kernel),
        config=ServeConfig(queue_capacity=args.queue_capacity,
                           default_timeout_s=args.timeout_s),
        warmups=[WarmupSpec(work[0][0].bucket, statistic=args.stat,
                            pipeline=args.pipeline)],
    )

    async def drive():
        t0 = time.perf_counter()
        compiled = await service.start()
        warmup_s = time.perf_counter() - t0
        n_dev = service.fleet.workers[0].session.n_devices
        print(f"[serve] fleet of {service.size} session(s) x {n_dev} miners, "
              f"pipeline={args.pipeline}, stat={args.stat}, alphas={alphas}; "
              f"warmup compiled {compiled} programs in {warmup_s:.2f}s")

        results: list = [None] * len(work)
        counter = iter(range(len(work)))

        async def client(cid: int):
            for q in counter:
                ds, query = work[q]
                res = await service.mine(ds, query, client=f"cli-{cid}")
                results[q] = res
                if res.ok or res.outcome == "partial":
                    rep = res.report
                    tag = ("partial" if res.outcome == "partial"
                           else "cold" if rep.cold else "warm")
                    print(f"[q{q:03d}] {tag} {res.total_s * 1e3:9.1f}ms  "
                          f"alpha={query.alpha:<5} min_sup={rep.min_sup} "
                          f"k={rep.correction_factor} "
                          f"significant={rep.n_significant} "
                          f"sess={res.session_id} "
                          f"batch={res.batch_index}/{res.batch_size}"
                          + (f" attempts={res.attempts}"
                             if res.attempts > 1 else ""))
                    if log:
                        log.event(
                            "query", q=q, cold=rep.cold,
                            outcome=res.outcome, attempts=res.attempts,
                            wall_s=round(res.total_s, 4),
                            queued_s=round(res.queued_s, 4),
                            service_s=round(res.service_s, 4),
                            alpha=query.alpha, min_sup=rep.min_sup,
                            k=rep.correction_factor,
                            significant=rep.n_significant,
                            kernel_impl=rep.kernel_impl,
                            session=res.session_id,
                            phase_wall_s=[round(p.wall_s, 4)
                                          for p in rep.phases],
                        )
                    if args.top_k:
                        for line in rep.results.describe(
                                args.top_k).splitlines()[1:]:
                            print("   " + line)
                else:
                    print(f"[q{q:03d}] {res.outcome} after "
                          f"{res.total_s * 1e3:9.1f}ms  ({res.reason})")
                    if log:
                        log.event("query", q=q, outcome=res.outcome,
                                  wall_s=round(res.total_s, 4))

        t_serve = time.perf_counter()
        await asyncio.gather(*[client(c) for c in range(args.concurrency)])
        total = time.perf_counter() - t_serve
        await service.stop()
        return results, total, warmup_s, compiled

    results, total, warmup_s, compiled = asyncio.run(drive())

    ok = [r for r in results if r is not None and r.ok]
    partial = [r for r in results
               if r is not None and r.outcome == "partial"]
    failed = [r for r in results
              if r is None or r.outcome not in ("ok", "partial")]
    retried = sum(1 for r in results
                  if r is not None and getattr(r, "attempts", 1) > 1)
    lat = [r.total_s for r in ok]
    # with startup warmup, *no* served query should ever compile — count
    # the ones that did instead of asserting (surfaced, not fatal)
    warm_violations = sum(1 for r in ok if r.report.cold)
    summary = {
        "problem": args.problem,
        "pipeline": args.pipeline,
        "statistic": args.stat,
        "concurrency": args.concurrency,
        "devices_per_session": (service.fleet.workers[0].session.n_devices),
        "queries": len(results),
        "ok": len(ok),
        "partial": len(partial),
        "retried": retried,
        "failed": len(failed),
        "total_wall_s": round(total, 3),
        "achieved_qps": round(len(ok) / total, 2) if total > 0 else None,
        "warmup_s": round(warmup_s, 3),
        "warmup_compiles": compiled,
        "warm_violations": warm_violations,
        "mean_s": round(sum(lat) / len(lat), 4) if lat else None,
        "p50_s": round(percentile(lat, 50), 4) if lat else None,
        "p90_s": round(percentile(lat, 90), 4) if lat else None,
        "max_s": round(max(lat), 4) if lat else None,
    }
    print("\n[latency] " + json.dumps(summary))
    print(latency_histogram(lat))
    infos = [w.session.cache_info() for w in service.fleet.workers]
    for w, ci in zip(service.fleet.workers, infos):
        print(f"session {w.wid}: {ci}")
    if warm_violations:
        print(f"[warn] {warm_violations} queries compiled despite warmup "
              "(warm_violations)", file=sys.stderr)
    if log:
        log.event("serve", **{k: v for k, v in summary.items()},
                  cache_hits=sum(ci.hits for ci in infos),
                  cache_misses=sum(ci.misses for ci in infos))
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(service.metrics.expose_text())
        print(f"[out] wrote metrics snapshot to {args.metrics_out}")

    if args.json_out:
        payload = dict(
            summary,
            per_query_s=[round(r.total_s, 4) if r is not None else None
                         for r in results],
            cache={"hits": sum(ci.hits for ci in infos),
                   "misses": sum(ci.misses for ci in infos),
                   "programs": sum(ci.n_programs for ci in infos)},
        )
        with open(args.json_out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"[out] {args.json_out}")
    return 0 if not failed else 1


if __name__ == "__main__":
    sys.exit(main())
