"""Query-serving loop: N significant-pattern queries against one warm session.

  python -m repro.launch.mine_serve --problem hapmap_dom_10 --scale-items 0.02 \
      --devices 8 --queries 16

The deployment mode the session API exists for (ROADMAP north star: heavy
repeated query traffic): a `MinerSession` is built once; a queue of queries
— fresh same-shape datasets (reseeded synthetic cohorts) × a cycle of
significance levels — drains against it.  Query 0 is cold (compiles one
program per phase); every later query replays warm compiled programs with
zero re-traces.  Prints per-query latencies, a latency histogram, the
cold/warm ratio, and the session's program-cache stats.

  --smoke        CI-sized: tiny scales, 4 queries (used by the slow-system job)
  --json-out     machine-readable latencies + cache stats
  --verbose      structured JSON-lines query records to stderr (repro.obs)
  --metrics-out  Prometheus text snapshot of the session registry: cache
                 hits/misses/evictions, per-phase and per-query latency
                 histograms, telemetry-loss counters (DESIGN.md §9)
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from collections import deque


def percentile(xs, q):
    xs = sorted(xs)
    if not xs:
        return 0.0
    i = min(int(round(q / 100 * (len(xs) - 1))), len(xs) - 1)
    return xs[i]


def latency_histogram(lat_s, width=40) -> str:
    """Log2-bucket text histogram over milliseconds."""
    if not lat_s:
        return "(no samples)"
    ms = [x * 1e3 for x in lat_s]
    lo = min(ms)
    edge = 1.0
    while edge > lo:
        edge /= 2
    buckets: dict[float, int] = {}
    for x in ms:
        e = edge
        while e * 2 <= x:
            e *= 2
        buckets[e] = buckets.get(e, 0) + 1
    peak = max(buckets.values())
    lines = []
    for e in sorted(buckets):
        n = buckets[e]
        bar = "#" * max(1, round(width * n / peak))
        lines.append(f"  [{e:9.1f}ms, {e * 2:9.1f}ms)  {n:4d}  {bar}")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--problem", default="hapmap_dom_10")
    ap.add_argument("--scale-items", type=float, default=0.02)
    ap.add_argument("--scale-trans", type=float, default=1.0)
    ap.add_argument("--queries", type=int, default=16)
    ap.add_argument("--alphas", default="0.05,0.01",
                    help="comma-separated significance levels cycled across queries")
    ap.add_argument("--pipeline", default="three_phase")
    ap.add_argument("--stat", default="fisher", choices=["fisher", "chi2"],
                    help="test statistic served by the session")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--expand-batch", type=int, default=16)
    ap.add_argument("--kernel", default="ref",
                    choices=["ref", "pallas", "pallas_interpret"])
    ap.add_argument("--top-k", type=int, default=3,
                    help="patterns shown per query (0 = summary line only)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: tiny scales and 4 queries")
    ap.add_argument("--json-out", default="")
    ap.add_argument("--verbose", action="store_true",
                    help="stream structured JSON-lines query records to stderr")
    ap.add_argument("--metrics-out", default="",
                    help="write a Prometheus text-format metrics snapshot")
    args = ap.parse_args(argv)
    if args.queries < 1:
        ap.error("--queries must be >= 1")
    if args.smoke:
        args.scale_items = min(args.scale_items, 0.01)
        args.queries = min(args.queries, 4)

    if args.devices:
        from repro.core.collectives import force_host_device_count

        if not force_host_device_count(args.devices):
            print(f"[warn] jax already initialized; --devices {args.devices} "
                  "ignored (set XLA_FLAGS before launch)", file=sys.stderr)

    from repro.api import (
        PIPELINES, AlgorithmConfig, Dataset, MinerSession, RuntimeConfig,
    )
    from repro.obs import JsonlLogger

    log = JsonlLogger() if args.verbose else None
    if args.pipeline not in PIPELINES:
        ap.error(f"--pipeline: unknown {args.pipeline!r}; "
                 f"available: {sorted(PIPELINES)}")
    alphas = [float(a) for a in args.alphas.split(",") if a]

    session = MinerSession(
        algorithm=AlgorithmConfig(pipeline=args.pipeline, statistic=args.stat),
        runtime=RuntimeConfig(expand_batch=args.expand_batch,
                              kernel_impl=args.kernel),
    )
    print(f"[serve] session over {session.n_devices} miners, "
          f"pipeline={args.pipeline}, stat={args.stat}, alphas={alphas}")

    # the query queue: reseeded same-shape cohorts (same bucket -> warm) at
    # cycling significance levels
    queue = deque(
        (q, q, alphas[q % len(alphas)]) for q in range(args.queries)
    )
    lat, n_phases = [], 0
    t_serve = time.time()
    while queue:
        q, seed, alpha = queue.popleft()
        ds = Dataset.from_paper_problem(
            args.problem, args.scale_items, args.scale_trans, seed=seed
        )
        t0 = time.perf_counter()
        report = session.mine(ds, alpha=alpha)
        dt = time.perf_counter() - t0
        lat.append(dt)
        n_phases = len(report.phases)
        tag = "cold" if report.cold else "warm"
        print(f"[q{q:03d}] {tag} {dt * 1e3:9.1f}ms  alpha={alpha:<5} "
              f"min_sup={report.min_sup} k={report.correction_factor} "
              f"significant={report.n_significant}")
        if log:
            log.event(
                "query", q=q, cold=report.cold, wall_s=round(dt, 4),
                alpha=alpha, min_sup=report.min_sup,
                k=report.correction_factor,
                significant=report.n_significant,
                kernel_impl=report.kernel_impl,
                phase_wall_s=[round(p.wall_s, 4) for p in report.phases],
            )
        if args.top_k:
            for line in report.results.describe(args.top_k).splitlines()[1:]:
                print("   " + line)
    total = time.time() - t_serve

    warm = lat[1:] if len(lat) > 1 else []
    cold_s = lat[0]
    summary = {
        "problem": args.problem,
        "pipeline": args.pipeline,
        "statistic": args.stat,
        "devices": session.n_devices,
        "queries": len(lat),
        "total_wall_s": round(total, 3),
        "cold_s": round(cold_s, 4),
        "warm_mean_s": round(sum(warm) / len(warm), 4) if warm else None,
        "warm_p50_s": round(percentile(warm, 50), 4) if warm else None,
        "warm_p90_s": round(percentile(warm, 90), 4) if warm else None,
        "warm_max_s": round(max(warm), 4) if warm else None,
        "cold_over_warm": (round(cold_s * len(warm) / sum(warm), 1)
                           if warm else None),
        "qps_warm": round(len(warm) / sum(warm), 2) if warm else None,
    }
    print("\n[latency] " + json.dumps(summary))
    print(latency_histogram(lat))
    ci = session.cache_info()
    print(ci)
    # every query after the first must have been fully warm: exactly one
    # compile per phase of the pipeline, ever
    assert ci.misses == n_phases, \
        f"expected {n_phases} compiles, saw {ci.misses}"
    if log:
        log.event("serve", **{k: v for k, v in summary.items()},
                  cache_hits=ci.hits, cache_misses=ci.misses)
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(session.metrics.expose_text())
        print(f"[out] wrote metrics snapshot to {args.metrics_out}")

    if args.json_out:
        payload = dict(
            summary,
            per_query_s=[round(x, 4) for x in lat],
            cache={"hits": ci.hits, "misses": ci.misses,
                   "programs": ci.n_programs},
        )
        with open(args.json_out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"[out] {args.json_out}")


if __name__ == "__main__":
    main()
