"""Serving launcher: batched prefill + decode loop (CPU demo scale).

  python -m repro.launch.serve --arch granite-3-2b --preset tiny \
      --batch 4 --prompt-len 32 --gen 16

Runs the same prefill/decode step programs the dry-run lowers for the
production mesh, at reduced scale, with continuous-batching bookkeeping
(per-slot lengths; finished slots refilled from the queue).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from ..configs import ALL_ARCHS, get_config
from ..models.transformer import forward_decode, forward_prefill, init_cache, init_params
from .steps import build_decode_step, build_prefill_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=list(ALL_ARCHS))
    ap.add_argument("--preset", default="tiny", choices=["tiny", "reduced"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args(argv)

    base = get_config(args.arch).reduced()
    if args.preset == "tiny":
        base = dataclasses.replace(base, vocab=512, d_model=128, head_dim=32,
                                   d_ff=256 if base.d_ff else 0)
    cfg = base
    assert "decode_32k" in cfg.supported_shapes, "encoder-only archs don't serve decode"

    params = init_params(cfg, jax.random.PRNGKey(0))
    prefill = jax.jit(build_prefill_step(cfg))
    decode = jax.jit(build_decode_step(cfg), donate_argnums=(2,))

    rng = np.random.default_rng(0)
    b, s = args.batch, args.prompt_len
    max_len = s + args.gen
    served = 0
    t0 = time.time()
    tokens_out = 0
    while served < args.requests:
        prompts = rng.integers(0, cfg.vocab, size=(b, s), dtype=np.int32)
        batch = {
            "inputs": jnp.asarray(prompts),
            "labels": jnp.zeros((b, s), jnp.int32),
            "positions": (
                jnp.broadcast_to(jnp.arange(s)[None, :, None], (b, s, 3))
                if cfg.m_rope_sections
                else jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
            ),
        }
        cache = init_cache(cfg, b, max_len=max_len)
        logits, cache = prefill(params, batch, cache)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        outs = [np.asarray(tok)]
        for _ in range(args.gen - 1):
            logits, cache = decode(params, tok, cache)
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            outs.append(np.asarray(tok))
        served += b
        tokens_out += b * args.gen
        gen = np.concatenate(outs, axis=1)
        print(f"[batch] served {served}/{args.requests}; sample: {gen[0][:12].tolist()}")
    dt = time.time() - t0
    print(f"{tokens_out} tokens in {dt:.2f}s -> {tokens_out/dt:.1f} tok/s "
          f"(CPU demo scale)")


if __name__ == "__main__":
    main()
