"""jit-able train/serve steps with full sharding annotations.

These are the exact programs the dry-run lowers and the trainers run:

  train_step(params, opt_state, batch)          -> params', opt', metrics
  prefill_step(params, batch, cache)            -> last_logits, cache'
  decode_step(params, tokens, cache)            -> logits, cache'

Microbatching (grad accumulation) expects the batch pre-shaped
[accum, micro, ...] with the micro axis sharded over dp — no resharding
reshape inside the step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, RunShape
from ..models.sharding import ShardingRules, use_rules
from ..models.transformer import (
    abstract_cache, abstract_params, cache_partition_specs, forward_decode,
    forward_prefill, forward_train, param_partition_specs,
)
from ..optim.adamw import AdamWConfig, apply_updates, init_state

F32 = jnp.float32


def build_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig):
    accum = cfg.grad_accum

    def train_step(params, opt_state, batch):
        def loss_fn(p, mb):
            # mixed precision at the step boundary: parameters are cast to
            # bf16 BEFORE use, so every FSDP all-gather moves bf16, and the
            # weight-gradient all-reduces run in bf16 too (the cast-backward
            # converts to f32 after the reduction).  f32 master weights and
            # optimizer state are untouched.  (§Perf it1: halves the dominant
            # collective term.)
            if cfg.dtype == "bf16":
                from ..models import nn as _nn

                p = _nn.cast_tree(p, jnp.bfloat16)
            return forward_train(p, cfg, mb)

        if accum == 1:
            mb = jax.tree.map(lambda x: x[0], batch)
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
        else:
            zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, F32), params)

            def mstep(carry, mb):
                l_acc, g_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(F32), g_acc, g)
                return (l_acc + l, g_acc), None

            (loss, grads), _ = lax.scan(mstep, (jnp.float32(0.0), zeros), batch)
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)

        new_params, new_opt, metrics = apply_updates(opt_cfg, params, grads, opt_state)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def build_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch, cache):
        return forward_prefill(params, cfg, batch, cache)

    return prefill_step


def build_decode_step(cfg: ArchConfig):
    def decode_step(params, tokens, cache):
        return forward_decode(params, cfg, tokens, cache)

    return decode_step


# ----------------------------------------------------------------- input specs
def batch_specs(cfg: ArchConfig, shape: RunShape, rules: ShardingRules):
    """ShapeDtypeStructs + PartitionSpecs for a run shape's inputs.

    Returns (abstract_batch, batch_pspecs) for train/prefill; decode adds the
    cache separately (see dryrun.py).
    """
    s, gb = shape.seq_len, shape.global_batch
    dp = rules.dp if len(rules.dp) > 1 else rules.dp[0]
    emb = cfg.embed_inputs and shape.kind != "decode"
    pos_shape = (gb, s, 3) if cfg.m_rope_sections else (gb, s)

    def sds(shp, dt):
        return jax.ShapeDtypeStruct(shp, dt)

    if shape.kind == "train":
        a = cfg.grad_accum
        assert gb % a == 0
        mb = gb // a

        def lead(shp):
            return (a, mb) + shp[1:]

        batch = {
            "inputs": sds(lead((gb, s, cfg.d_model)), jnp.bfloat16) if emb
            else sds(lead((gb, s)), jnp.int32),
            "labels": sds(lead((gb, s)), jnp.int32),
            "positions": sds(lead(pos_shape), jnp.int32),
        }
        specs = jax.tree.map(
            lambda x: P(*((None, dp) + (None,) * (len(x.shape) - 2))), batch
        )
        return batch, specs

    batch = {
        "inputs": sds((gb, s, cfg.d_model), jnp.bfloat16) if emb
        else sds((gb, s), jnp.int32),
        "labels": sds((gb, s), jnp.int32),
        "positions": sds(pos_shape, jnp.int32),
    }
    specs = jax.tree.map(lambda x: P(*((dp,) + (None,) * (len(x.shape) - 1))), batch)
    return batch, specs


def opt_specs(param_specs):
    return {"m": param_specs, "v": param_specs, "step": P()}


def sanitize_specs(spec_tree, abstract_tree, mesh):
    """Drop mesh axes from dims they don't divide (pjit input shardings must
    divide exactly; e.g. hubert's vocab=504 vs the 16-way 'model' axis)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def ax_size(entry):
        if entry is None:
            return 1
        if isinstance(entry, (tuple, list)):
            n = 1
            for e in entry:
                n *= sizes[e]
            return n
        return sizes[entry]

    def fix(s, a):
        if not isinstance(s, P):
            return s
        entries = tuple(s) + (None,) * (len(a.shape) - len(tuple(s)))
        out = tuple(
            e if (e is None or dim % ax_size(e) == 0) else None
            for e, dim in zip(entries, a.shape)
        )
        return P(*out)

    return jax.tree.map(
        fix, spec_tree, abstract_tree, is_leaf=lambda x: isinstance(x, P)
    )


def abstract_opt_state(opt_cfg: AdamWConfig, aparams):
    return jax.eval_shape(lambda p: init_state(opt_cfg, p), aparams)
