"""HLO-text cost model for the roofline analysis.

`compiled.cost_analysis()` counts a while-loop body ONCE regardless of trip
count (verified empirically), which under-counts scanned-layer models by the
layer count.  This parser walks the compiled HLO text, builds the computation
call graph (fusion/call/while), extracts per-computation dot FLOPs, memory
traffic (operand+result bytes per top-level op — a fusion reads its inputs
once and writes its outputs once), and collective payload bytes, then
multiplies while bodies by their trip counts (parsed from the loop-condition's
comparison constant).

Link-traffic convention for the collective roofline term (ring algorithms on
a torus): all-reduce costs 2(G-1)/G payloads per link, all-gather /
reduce-scatter / all-to-all cost (G-1)/G, collective-permute costs 1, where G
is the replica-group size parsed from the op.
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "e4m3fn": 1, "e5m2": 1, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_SKIP_BYTES = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota", "partition-id", "replica-id",
}
_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start",
}


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _first_shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


@dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_payload: dict = field(default_factory=lambda: defaultdict(float))
    coll_link: float = 0.0
    calls: list = field(default_factory=list)  # (comp_name, multiplier, kind)


def _parse_operand_names(args: str):
    return re.findall(r"%([\w.\-]+)", args)


def parse_hlo_costs(hlo_text: str) -> dict:
    """Returns totals: {"flops", "bytes", "coll_payload": {kind: B}, "coll_link"}.

    All values are whole-program (per-device, since SPMD HLO is per-device).
    """
    # ---- split into computations
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        hdr = _COMP_HDR_RE.match(line.strip())
        if hdr and ("{" in line):
            cur = hdr.group(1)
            comps[cur] = []
            if line.strip().startswith("ENTRY"):
                entry = cur
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
                continue
            comps[cur].append(line)
    if entry is None:  # fall back: first computation
        entry = next(iter(comps)) if comps else None

    # ---- header parameter types (for fusion byte attribution)
    comp_params: dict[str, dict[str, str]] = {}
    for cname in comps:
        comp_params[cname] = {}
    cur = None
    for line in hlo_text.splitlines():
        hdr = _COMP_HDR_RE.match(line.strip())
        if hdr and ("{" in line):
            pm = re.findall(r"%?([\w.\-]+):\s*((?:\([^)]*\)|[\w\[\],]+))",
                            hdr.group(2))
            comp_params[hdr.group(1)] = {name: typ for name, typ in pm}

    def fusion_bytes(fname: str):
        """Memory traffic of a fused computation: parameters consumed through
        a dynamic-slice/gather are charged at the slice size (XLA fuses the
        slice, so only the window is read — charging the full operand blows
        up scan bodies that index hoisted per-step arrays).

        Returns (input_bytes, result_bytes_override) — override is not None
        when the fusion ROOT is a dynamic-update-slice (scan collecting ys
        writes one window per iteration into an aliased buffer, not the whole
        result array)."""
        lines = comps.get(fname, [])
        tmap_f: dict[str, str] = dict(comp_params.get(fname, {}))
        first_use: dict[str, tuple] = {}  # param -> (opcode, result type, args)
        root_override = None
        for line in lines:
            m = _OP_RE.match(line)
            if not m:
                continue
            op_name, rtype, opcode, args = m.groups()
            tmap_f[op_name] = rtype
            if line.strip().startswith("ROOT") and opcode == "dynamic-update-slice":
                ops = _parse_operand_names(args)
                upd = tmap_f.get(ops[1], "") if len(ops) > 1 else ""
                root_override = _shape_bytes(upd)
            for o in _parse_operand_names(args):
                if o in comp_params.get(fname, {}) and o not in first_use:
                    first_use[o] = (opcode, rtype, args)
        total = 0.0
        for pname, ptype in comp_params.get(fname, {}).items():
            use = first_use.get(pname)
            if use and use[0] in ("dynamic-slice", "gather"):
                total += _shape_bytes(use[1])
            elif use and use[0] == "dynamic-update-slice":
                ops = _parse_operand_names(use[2])
                upd = tmap_f.get(ops[1], "") if len(ops) > 1 else use[1]
                total += 2.0 * _shape_bytes(upd)  # window read+write, in place
            else:
                total += _shape_bytes(ptype)
        return total, root_override

    # ---- per-computation parse
    types: dict[str, dict[str, str]] = {}  # comp -> op -> result type
    costs: dict[str, CompCost] = {}
    trip_consts: dict[str, int] = {}  # condition comp -> max int constant

    for cname, lines in comps.items():
        cc = CompCost()
        tmap: dict[str, str] = {}
        max_const = 0
        for line in lines:
            m = _OP_RE.match(line)
            if not m:
                continue
            op_name, rtype, opcode, args = m.groups()
            tmap[op_name] = rtype
            if opcode == "constant":
                cm = re.search(r"constant\((-?\d+)\)", line)
                if cm:
                    max_const = max(max_const, int(cm.group(1)))
            rbytes = _shape_bytes(rtype)

            if opcode == "dot":
                _, out_dims = _first_shape_dims(rtype)
                out_prod = 1
                for d in out_dims:
                    out_prod *= d
                ops = _parse_operand_names(args)
                lhs_t = tmap.get(ops[0], "") if ops else ""
                _, lhs_dims = _first_shape_dims(lhs_t)
                cdim_m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
                contract = 1
                if cdim_m and cdim_m.group(1):
                    for ax in cdim_m.group(1).split(","):
                        ax = int(ax)
                        if ax < len(lhs_dims):
                            contract *= lhs_dims[ax]
                cc.flops += 2.0 * out_prod * contract
            elif opcode in ("convolution",):
                # rare here; approximate via output * window (skip)
                pass

            base = opcode.replace("-start", "")
            if base in ("all-reduce", "all-gather", "reduce-scatter",
                        "all-to-all", "collective-permute"):
                # payload: operand bytes (result for all-gather)
                ops = _parse_operand_names(args)
                op_bytes = sum(_shape_bytes(tmap.get(o, "")) for o in ops
                               if o in tmap)
                payload = max(op_bytes, rbytes if base == "all-gather" else 0)
                if payload == 0:
                    payload = rbytes
                g = 0
                gm = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
                if gm:
                    g = int(gm.group(2))
                else:
                    gm2 = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
                    if gm2:
                        g = len(gm2.group(1).split(","))
                g = max(g, 2)
                if base == "all-reduce":
                    factor = 2.0 * (g - 1) / g
                elif base == "collective-permute":
                    factor = 1.0
                else:
                    factor = (g - 1) / g
                cc.coll_payload[base] += payload
                cc.coll_link += payload * factor

            if opcode not in _SKIP_BYTES and not opcode.endswith("-done"):
                if opcode == "fusion":
                    fm = re.search(r"calls=%?([\w.\-]+)", line)
                    if fm:
                        in_b, root_override = fusion_bytes(fm.group(1))
                        out_b = rbytes if root_override is None else root_override
                        cc.bytes += out_b + in_b
                    else:
                        cc.bytes += rbytes
                elif opcode in ("dynamic-slice", "gather"):
                    # reads only the sliced window, not the whole operand
                    cc.bytes += 2.0 * rbytes
                elif opcode in ("dynamic-update-slice", "scatter"):
                    # in-place window write: read+write the update, not the buffer
                    ops = _parse_operand_names(args)
                    upd = _shape_bytes(tmap.get(ops[1], "")) if len(ops) > 1 else rbytes
                    cc.bytes += 2.0 * upd
                else:
                    ops = _parse_operand_names(args)
                    in_bytes = sum(
                        _shape_bytes(tmap.get(o, "")) for o in ops if o in tmap
                    )
                    cc.bytes += rbytes + in_bytes

            # ---- call edges
            if opcode == "while":
                bm = re.search(r"body=%?([\w.\-]+)", line)
                cm_ = re.search(r"condition=%?([\w.\-]+)", line)
                if bm:
                    cc.calls.append((bm.group(1), None, "while",
                                     cm_.group(1) if cm_ else None))
            elif opcode == "fusion":
                fm = re.search(r"calls=%?([\w.\-]+)", line)
                if fm:
                    cc.calls.append((fm.group(1), 1.0, "fusion", None))
            elif opcode in ("call", "custom-call"):
                fm = re.search(r"to_apply=%?([\w.\-]+)", line)
                if fm:
                    cc.calls.append((fm.group(1), 1.0, "call", None))
            elif opcode == "conditional":
                for bm in re.finditer(r"(?:branch_computations=\{|true_computation=|false_computation=)%?([\w.\-]+)", line):
                    cc.calls.append((bm.group(1), 1.0, "cond", None))
        types[cname] = tmap
        costs[cname] = cc
        trip_consts[cname] = max_const

    # ---- aggregate with loop multipliers (memoized DFS)
    memo: dict[str, tuple] = {}

    def total(cname: str):
        if cname in memo:
            return memo[cname]
        cc = costs.get(cname)
        if cc is None:
            return 0.0, 0.0, defaultdict(float), 0.0
        memo[cname] = (0.0, 0.0, defaultdict(float), 0.0)  # cycle guard
        fl, by, cl, lk = cc.flops, cc.bytes, defaultdict(float, cc.coll_payload), cc.coll_link
        for entry_ in cc.calls:
            sub, mult, kind, cond = entry_
            if kind == "while":
                trip = max(trip_consts.get(cond, 1), 1) if cond else 1
                mult = float(trip)
            sfl, sby, scl, slk = total(sub)
            if kind == "fusion":
                # fusion bytes already counted at the call site; only add
                # inner dot flops (rare on CPU, common on TPU backends)
                fl += sfl * mult
                for k, v in scl.items():
                    cl[k] += v * mult
                lk += slk * mult
            else:
                fl += sfl * mult
                by += sby * mult
                for k, v in scl.items():
                    cl[k] += v * mult
                lk += slk * mult
        memo[cname] = (fl, by, cl, lk)
        return memo[cname]

    fl, by, cl, lk = total(entry) if entry else (0.0, 0.0, {}, 0.0)
    return {
        "flops": fl,
        "bytes": by,
        "coll_payload": dict(cl),
        "coll_link_bytes": lk,
    }
