import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: prove every (architecture x input shape x mesh) cell
lowers, SPMD-partitions, and compiles on the production mesh, and extract the
roofline raw terms from the compiled artifact.

The XLA_FLAGS line above MUST precede any jax import (jax locks the device
count at first init); nothing else in the repo sets it globally.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all            # every valid cell, both meshes
  python -m repro.launch.dryrun --all --mesh multi

Artifacts: experiments/dryrun/<arch>__<shape>__<mesh>.json
(memory_analysis, cost_analysis, parsed HLO flops/bytes/collectives, timings).
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ALL_ARCHS, SHAPES, get_config
from repro.core.collectives import normalize_cost_analysis
from repro.launch.hlo_cost import parse_hlo_costs
from repro.launch.mesh import make_production_mesh, make_rules
from repro.launch.steps import (
    abstract_opt_state, batch_specs, build_decode_step, build_prefill_step,
    build_train_step, opt_specs, sanitize_specs,
)
from repro.models import nn as _nn
from repro.models.sharding import use_rules
from repro.models.transformer import (
    abstract_cache, abstract_params, cache_partition_specs, param_partition_specs,
)
from repro.optim.adamw import AdamWConfig

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _bf16_params(aparams):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(
            x.shape, jnp.bfloat16 if jnp.issubdtype(x.dtype, jnp.floating) else x.dtype
        ),
        aparams,
    )


def lower_cell(arch: str, shape_name: str, multi_pod: bool):
    """Lower + compile one cell; returns the record dict."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(multi_pod=multi_pod, decode=shape.kind == "decode")
    if cfg.pure_dp and shape.kind != "decode":
        from repro.models.sharding import ShardingRules

        rules = ShardingRules(dp=tuple(mesh.axis_names), tp=None, fsdp=None,
                              sp=None)
    t0 = time.time()

    with mesh, use_rules(rules):
        pspecs = param_partition_specs(cfg)
        aparams = abstract_params(cfg)
        pspecs = sanitize_specs(pspecs, aparams, mesh)
        batch, bspecs = batch_specs(cfg, shape, rules)
        bspecs = sanitize_specs(bspecs, batch, mesh)

        if shape.kind == "train":
            opt_cfg = AdamWConfig()
            aopt = abstract_opt_state(opt_cfg, aparams)
            step = build_train_step(cfg, opt_cfg)
            in_shardings = (
                _named(mesh, pspecs), _named(mesh, opt_specs(pspecs)),
                _named(mesh, bspecs),
            )
            jitted = jax.jit(
                step, in_shardings=in_shardings,
                out_shardings=(in_shardings[0], in_shardings[1], None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(aparams, aopt, batch)
        elif shape.kind == "prefill":
            sparams = _bf16_params(aparams)
            step = build_prefill_step(cfg)
            if "decode_32k" in cfg.supported_shapes:
                acache = abstract_cache(cfg, shape.global_batch, shape.seq_len)
                cspecs = sanitize_specs(
                    cache_partition_specs(cfg, acache), acache, mesh)
                jitted = jax.jit(
                    step,
                    in_shardings=(_named(mesh, pspecs), _named(mesh, bspecs),
                                  _named(mesh, cspecs)),
                    donate_argnums=(2,),
                )
                lowered = jitted.lower(sparams, batch, acache)
            else:  # encoder-only forward
                jitted = jax.jit(
                    lambda p, b: step(p, b, None),
                    in_shardings=(_named(mesh, pspecs), _named(mesh, bspecs)),
                )
                lowered = jitted.lower(sparams, batch)
        else:  # decode
            sparams = _bf16_params(aparams)
            step = build_decode_step(cfg)
            acache = abstract_cache(cfg, shape.global_batch, shape.seq_len)
            dp_size = 1
            for ax_ in rules.dp:
                dp_size *= dict(zip(mesh.axis_names, mesh.devices.shape))[ax_]
            dp_divides = shape.global_batch % dp_size == 0
            cspecs = cache_partition_specs(cfg, acache, dp_divides=dp_divides)
            cspecs = sanitize_specs(cspecs, acache, mesh)
            dp = rules.dp if len(rules.dp) > 1 else rules.dp[0]
            tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
            jitted = jax.jit(
                step,
                in_shardings=(
                    _named(mesh, pspecs),
                    NamedSharding(mesh, P(dp if dp_divides else None, None)),
                    _named(mesh, cspecs),
                ),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(sparams, tokens, acache)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = normalize_cost_analysis(compiled.cost_analysis())
        hlo = compiled.as_text()
        parsed = parse_hlo_costs(hlo)

    n_params = _nn.count_params(aparams)
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
        "kind": shape.kind,
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "n_params": n_params,
        "n_active_params": cfg.n_active_params(),
        "grad_accum": cfg.grad_accum if shape.kind == "train" else 1,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "per_device_total": mem.argument_size_in_bytes + mem.temp_size_in_bytes,
        },
        "xla_cost": {k: v for k, v in cost.items() if isinstance(v, (int, float))},
        "hlo_parsed": parsed,
        "collective_op_counts": {
            op: hlo.count(f" {op}(") + hlo.count(f" {op}-start(")
            for op in ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                       "collective-permute")
        },
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
    }
    return record, hlo


def valid_cells():
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        for shape_name in cfg.supported_shapes:
            yield arch, shape_name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ALL_ARCHS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true", help="recompute existing records")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells = list(valid_cells()) if args.all else [(args.arch, args.shape)]

    failures = []
    for arch, shape_name in cells:
        for multi in meshes:
            tag = f"{arch}__{shape_name}__{'multi' if multi else 'single'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path) and not args.force:
                print(f"[skip] {tag} (exists)")
                continue
            print(f"[cell] {tag} ...", flush=True)
            try:
                rec, hlo = lower_cell(arch, shape_name, multi)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                import gzip

                hlo_dir = os.path.join(args.out, "hlo")
                os.makedirs(hlo_dir, exist_ok=True)
                with gzip.open(os.path.join(hlo_dir, tag + ".txt.gz"), "wt") as f:
                    f.write(hlo)
                print(
                    f"  ok: mem/dev={rec['memory']['per_device_total']/2**30:.2f} GiB"
                    f" flops/dev={rec['hlo_parsed']['flops']:.3e}"
                    f" coll={rec['hlo_parsed']['coll_link_bytes']:.3e}B"
                    f" compile={rec['compile_s']}s",
                    flush=True,
                )
            except Exception as e:
                failures.append((tag, repr(e)))
                print(f"  FAIL: {e}\n{traceback.format_exc()[-2000:]}", flush=True)

    print(f"\n{len(cells)*len(meshes) - len(failures)} ok, {len(failures)} failed")
    for tag, err in failures:
        print(f"  FAILED {tag}: {err[:200]}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
