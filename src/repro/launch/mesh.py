"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required for the dry-run's device-count
override to work (launch/dryrun.py sets XLA_FLAGS before any jax init).
"""

from __future__ import annotations

import jax

from ..models.sharding import ShardingRules


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_rules(*, multi_pod: bool = False, decode: bool = False) -> ShardingRules:
    """Logical->mesh axis rules matching the production mesh.

    Sequence parallelism (sp) shards the residual stream over 'model' between
    blocks during training; decode uses 'model' for the KV-cache sequence dim
    (flash-decode style partial-softmax sharding).
    """
    dp = ("pod", "data") if multi_pod else ("data",)
    return ShardingRules(
        dp=dp, tp="model", fsdp="data", sp="model", shard_kv_seq=True
    )


def make_mining_mesh(devices=None):
    """1-D mesh over all devices for the pattern-mining engine."""
    from ..core.collectives import make_miner_mesh

    return make_miner_mesh(devices)
