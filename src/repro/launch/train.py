"""Training launcher: fault-tolerant loop around build_train_step.

  python -m repro.launch.train --arch xlstm-125m --preset tiny --steps 50

Features exercised even at CPU scale:
  * checkpoint every --ckpt-every steps; automatic restore-on-start
  * deterministic data replay from the restored step (data/pipeline.py)
  * --fail-at N simulates a node failure (process aborts mid-run); a rerun
    with the same --ckpt-dir resumes and converges to the same trajectory
  * on a real pod slice the same script runs under jax.distributed with the
    production mesh (see launch/dryrun.py for the mesh/sharding wiring)
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from ..ckpt import checkpoint as ckpt
from ..configs import ALL_ARCHS, get_config
from ..data.pipeline import DataConfig, make_batch
from ..models.transformer import init_params
from ..optim.adamw import AdamWConfig, init_state
from .steps import build_train_step


def make_train_setup(cfg, opt_cfg, seed=0):
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = init_state(opt_cfg, params)
    step_fn = jax.jit(build_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
    return params, opt_state, step_fn


def run(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m", choices=list(ALL_ARCHS))
    ap.add_argument("--preset", default="tiny", choices=["tiny", "reduced", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, default=0, help="simulate failure at step N")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args(argv)

    base = get_config(args.arch)
    if args.preset == "full":
        cfg = base
    elif args.preset == "reduced":
        cfg = base.reduced()
    else:  # tiny: fast convergence demo on 1 CPU core
        cfg = dataclasses.replace(
            base.reduced(), vocab=512, d_model=128, d_ff=256 if base.d_ff else 0,
            n_heads=4, head_dim=32,
        )
    cfg = dataclasses.replace(cfg, grad_accum=1)

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                          total_steps=args.steps, compress=args.compress_grads)
    params, opt_state, step_fn = make_train_setup(cfg, opt_cfg)

    dcfg = DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        grad_accum=cfg.grad_accum, m_rope=bool(cfg.m_rope_sections),
        embed_inputs=cfg.embed_inputs, d_model=cfg.d_model,
    )

    start_step = 0
    if args.ckpt_dir:
        restored, manifest = ckpt.restore_latest(
            args.ckpt_dir, {"params": params, "opt": opt_state}
        )
        if restored is not None:
            params, opt_state = restored["params"], restored["opt"]
            start_step = manifest["step"]
            print(f"[restore] resumed from step {start_step}")

    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = make_batch(dcfg, step)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append({"step": step, "loss": loss,
                       "grad_norm": float(metrics["grad_norm"])})
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss {loss:8.4f}  gnorm "
                  f"{float(metrics['grad_norm']):7.3f}  {time.time()-t0:6.1f}s",
                  flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save({"params": params, "opt": opt_state}, args.ckpt_dir,
                      step + 1, meta={"arch": cfg.name})
        if args.fail_at and step + 1 == args.fail_at:
            print(f"[fault-injection] simulated node failure at step {step + 1}",
                  flush=True)
            os._exit(42)

    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(losses, f)
    print(f"final loss {losses[-1]['loss']:.4f} (first {losses[0]['loss']:.4f})")
    return losses


if __name__ == "__main__":
    run()
