"""Qwen2-VL-2B — VLM backbone with M-RoPE [arXiv:2409.12191].

Backbone only: the vision tower is a stub; train/prefill inputs are
precomputed patch embeddings plus M-RoPE (t,h,w) position ids.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab=151936,
    m_rope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    embed_inputs=True,
    attn_sharding="context",
    shape_skips={"long_500k": "pure full attention (O(S^2)); skipped per spec"},
    grad_accum=2,
    source="arXiv:2409.12191 (hf)",
)
