"""HuBERT-XLarge — encoder-only audio transformer [arXiv:2106.07447].

Backbone only: the audio frontend (conv feature extractor) is a stub per the
assignment; inputs are precomputed frame embeddings [B, S, d_model].
Encoder-only => no autoregressive decode shapes (DESIGN.md §Shape-applicability).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab=504,
    causal=False,
    mlp="gelu",
    embed_inputs=True,
    supported_shapes=("train_4k", "prefill_32k"),
    shape_skips={
        "decode_32k": "encoder-only: no autoregressive decode / KV cache",
        "long_500k": "encoder-only + full quadratic attention",
    },
    grad_accum=2,
    source="arXiv:2106.07447 (unverified)",
)
