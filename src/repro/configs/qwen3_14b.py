"""Qwen3-14B — dense decoder, GQA(8), qk-norm [hf:Qwen/Qwen3-8B family]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    attn_sharding="context",
    shape_skips={"long_500k": "pure full attention (O(S^2)); skipped per spec"},
    grad_accum=4,
    source="hf:Qwen/Qwen3-8B (hf)",
)
