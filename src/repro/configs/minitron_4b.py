"""Minitron-4B — width/depth-pruned Nemotron [arXiv:2407.14679].

Nemotron family uses squared-ReLU (non-gated) MLP.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab=256000,
    mlp="relu2",
    attn_sharding="context",
    shape_skips={"long_500k": "pure full attention (O(S^2)); skipped per spec"},
    grad_accum=2,
    source="arXiv:2407.14679 (hf)",
)
