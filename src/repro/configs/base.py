"""Architecture + run-shape configuration system.

Every assigned architecture is a frozen `ArchConfig` in its own module
(`repro.configs.<id>`), selectable by `--arch <id>` in the launchers.
`reduced()` derives the small same-family config used by CPU smoke tests.

Run shapes (the assigned input-shape set; see DESIGN.md §4 for the
applicability matrix):

    train_4k     train_step   seq 4096,   global_batch 256
    prefill_32k  serve prefill seq 32768, global_batch 32
    decode_32k   serve decode  1 new token, KV len 32768, global_batch 128
    long_500k    serve decode  1 new token, context 524288, global_batch 1
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

__all__ = ["ArchConfig", "RunShape", "SHAPES", "REGISTRY", "register", "get_config"]


@dataclass(frozen=True)
class RunShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, RunShape] = {
    "train_4k": RunShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": RunShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": RunShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": RunShape("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encoder | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # attention variants
    causal: bool = True
    qk_norm: bool = False
    local_window: int = 0  # >0: sliding-window attention
    rope_theta: float = 10_000.0
    m_rope_sections: tuple[int, ...] = ()  # Qwen2-VL M-RoPE (t,h,w) pairs split
    attn_logit_softcap: float = 0.0

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_capacity_factor: float = 1.25

    # block pattern: smallest repeating unit, e.g. ("rglru","rglru","attn").
    # () means ("attn",) * 1 homogeneous transformer blocks.
    block_pattern: tuple[str, ...] = ()
    # extra (unscanned) layers appended after the scanned units, for depths
    # not divisible by the pattern length (recurrentgemma: 38 = 12*3 + 2)
    block_tail: tuple[str, ...] = ()

    # modality frontend stub: inputs are precomputed embeddings, not token ids
    embed_inputs: bool = False
    tie_embeddings: bool = False

    # mlp variant: "swiglu" | "gelu"
    mlp: str = "swiglu"
    # parallel attention+FFN residual block (Cohere/GPT-J layout): one shared
    # norm feeds both branches -> one TP gather + one reduce per layer
    parallel_block: bool = False
    # attention sharding for train/prefill (EXPERIMENTS.md §Perf):
    #   "tp_heads": q-heads shard over 'model', KV replicated+expanded —
    #               best when n_heads % 16 == 0 (cmd-r+, dbrx, phi, granite…)
    #   "context":  batch+seq sharding, heads replicated — best when head
    #               padding / KV expansion outweighs TP (qwen3 40H, 24H, 12H)
    attn_sharding: str = "tp_heads"
    # RG-LRU / xLSTM hyper-params
    rnn_width: int = 0  # RG-LRU recurrent width (recurrentgemma: d_model)
    conv_width: int = 4

    # small models: map BOTH mesh axes to data parallelism (params
    # replicated; per-layer TP collectives vanish).  Train/prefill only.
    pure_dp: bool = False
    # training
    remat: bool = True
    grad_accum: int = 1  # microbatch count for train_step
    # compute dtype: "bf16" on TPU; reduced CPU smoke configs use "f32"
    # (this container's XLA:CPU cannot execute bf16 dots — lowering is fine)
    dtype: str = "bf16"

    # which run shapes apply (DESIGN.md §Shape-applicability)
    supported_shapes: tuple[str, ...] = ("train_4k", "prefill_32k", "decode_32k")
    shape_skips: dict = field(default_factory=dict)  # name -> reason

    source: str = ""

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pattern(self) -> tuple[str, ...]:
        return self.block_pattern or ("attn",)

    @property
    def n_units(self) -> int:
        scanned = self.n_layers - len(self.block_tail)
        assert scanned % len(self.pattern) == 0, (self.name, self.pattern)
        return scanned // len(self.pattern)

    def n_params(self) -> int:
        """Approximate parameter count (embedding + blocks), for rooflines."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.head_dim_
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb
        for kind in self.pattern:
            if kind in ("attn", "local_attn"):
                attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
                total += self.n_units * attn
            if kind == "rglru":
                w = self.rnn_width or d
                total += self.n_units * (2 * d * w + w * d + 2 * w * w // 8 + self.conv_width * w)
            if kind == "mlstm":
                total += self.n_units * (2 * d * 2 * d + 2 * d * d + 3 * 2 * d * (2 * d // self.n_heads))
            if kind == "slstm":
                total += self.n_units * (4 * d * d + 2 * d * int(d * 4 / 3))
        # mlp per block (except pure lstm blocks, which embed their own)
        mlp_blocks = sum(1 for k in self.pattern if k in ("attn", "local_attn", "rglru"))
        if self.n_experts:
            total += self.n_layers * self.n_experts * 3 * d * f
        else:
            n_mlp = self.n_units * mlp_blocks
            mult = 3 if self.mlp == "swiglu" else 2
            total += n_mlp * mult * d * f
        return total

    def n_active_params(self) -> int:
        if not self.n_experts:
            return self.n_params()
        d, f = self.d_model, self.d_ff
        dense = self.n_params() - self.n_layers * self.n_experts * 3 * d * f
        return dense + self.n_layers * self.top_k * 3 * d * f

    def reduced(self) -> "ArchConfig":
        """Same-family tiny config for CPU smoke tests."""
        unit = len(self.pattern)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=unit * (2 if unit == 1 else 1) + len(self.block_tail),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads > 1 else 1,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            # no capacity drops at smoke-test scale (keeps decode == prefill)
            moe_capacity_factor=8.0 if self.n_experts else 1.25,
            local_window=min(self.local_window, 16) if self.local_window else 0,
            rnn_width=64 if self.rnn_width else 0,
            m_rope_sections=(2, 3, 3) if self.m_rope_sections else (),
            grad_accum=1,
            dtype="f32",
        )


REGISTRY: dict[str, str] = {}


def register(arch_id: str, module: str):
    REGISTRY[arch_id] = module


def get_config(arch_id: str) -> ArchConfig:
    import importlib

    if arch_id not in REGISTRY:
        from . import ALL_ARCHS  # noqa: F401  (populates REGISTRY)
    mod = importlib.import_module(REGISTRY[arch_id])
    return mod.CONFIG
