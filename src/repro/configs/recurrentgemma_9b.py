"""RecurrentGemma-9B — Griffin hybrid: RG-LRU + local attention, 1:2
[arXiv:2402.19427].

38 layers = 12 x (rglru, rglru, local_attn) units + a 2-layer recurrent tail.
Sub-quadratic: runs the long_500k shape (bounded window cache + RNN state).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    local_window=2048,
    rnn_width=4096,
    block_pattern=("rglru", "rglru", "local_attn"),
    block_tail=("rglru", "rglru"),
    supported_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    grad_accum=4,
    source="arXiv:2402.19427 (unverified)",
)
