"""xLSTM-125M — sLSTM + mLSTM blocks [arXiv:2405.04517].

12 blocks = 3 x (mlstm, mlstm, mlstm, slstm) units (the paper's xLSTM[a:b]
notation; ratio choice documented in DESIGN.md).  d_ff=0: xLSTM blocks carry
their own projections instead of a separate FFN.  Constant-size state =>
runs long_500k.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab=50304,
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    supported_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    grad_accum=1,
    pure_dp=True,
    source="arXiv:2405.04517 (unverified)",
)
