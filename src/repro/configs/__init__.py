"""Architecture registry: one module per assigned architecture."""

from .base import REGISTRY, SHAPES, ArchConfig, RunShape, get_config, register

ALL_ARCHS = (
    "hubert-xlarge",
    "qwen3-14b",
    "minitron-4b",
    "granite-3-2b",
    "command-r-plus-104b",
    "qwen2-vl-2b",
    "phi3.5-moe-42b-a6.6b",
    "dbrx-132b",
    "recurrentgemma-9b",
    "xlstm-125m",
)

register("hubert-xlarge", "repro.configs.hubert_xlarge")
register("qwen3-14b", "repro.configs.qwen3_14b")
register("minitron-4b", "repro.configs.minitron_4b")
register("granite-3-2b", "repro.configs.granite_3_2b")
register("command-r-plus-104b", "repro.configs.command_r_plus_104b")
register("qwen2-vl-2b", "repro.configs.qwen2_vl_2b")
register("phi3.5-moe-42b-a6.6b", "repro.configs.phi35_moe")
register("dbrx-132b", "repro.configs.dbrx_132b")
register("recurrentgemma-9b", "repro.configs.recurrentgemma_9b")
register("xlstm-125m", "repro.configs.xlstm_125m")
