"""Command-R+ 104B — dense decoder, GQA(8), no biases, parallel
attention+FFN residual blocks [hf:CohereForAI] (also halves the per-layer TP
boundary collectives — EXPERIMENTS.md §Perf)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab=256000,
    grad_accum=8,
    parallel_block=True,
    shape_skips={"long_500k": "pure full attention (O(S^2)); skipped per spec"},
    source="hf:CohereForAI/c4ai-command-r-v01 (unverified)",
)
