"""Structured JSON-lines run records for the launchers (DESIGN.md §9).

One record per line, one ``event`` key naming the record type, everything
else flat JSON-able fields — the format every log shipper ingests without
configuration.  The launchers use this instead of ad-hoc prints when
``--verbose`` is set::

    log = JsonlLogger()                       # stderr by default
    log.event("phase", mode="count", wall_s=0.14, cache_hit=True)
    # {"ts": 1754700000.123456, "event": "phase", "mode": "count", ...}

Values that aren't JSON-serializable are stringified rather than raised on:
a telemetry path must never take the run down.
"""

from __future__ import annotations

import json
import sys
import time

__all__ = ["JsonlLogger"]


class JsonlLogger:
    """Writes one JSON object per line to a stream (default: stderr)."""

    def __init__(self, stream=None, *, clock=time.time):
        self.stream = stream if stream is not None else sys.stderr
        self._clock = clock

    def event(self, event: str, **fields) -> dict:
        """Emit one record; returns the dict that was written."""
        rec = {"ts": round(self._clock(), 6), "event": event, **fields}
        self.stream.write(json.dumps(rec, default=str) + "\n")
        flush = getattr(self.stream, "flush", None)
        if flush is not None:
            flush()
        return rec
