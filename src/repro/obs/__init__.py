"""repro.obs — observability for the mining engine (DESIGN.md §9).

Three layers, one per time base:

  trace.py    device superstep trace — a [trace_cap, N_FIELDS] i32 ring
              threaded through the BSP carry, sampled every trace_period
              supersteps, decoded host-side into per-miner timelines and
              load-balance metrics (Jain's fairness over donations, idle
              fractions, stack-depth imbalance).
  span.py     host span tracer — nested context-manager spans around
              pack/compile/dispatch/postprocess/reconstruct, exported as
              Chrome-trace (Perfetto) JSON, with an optional jax.profiler
              bridge so host and device timelines line up.
  metrics.py  metrics registry — counters/gauges/histograms with
              Prometheus text exposition, fed by MinerSession (cache
              hits/misses/evictions, latency histograms, telemetry-loss
              counters) and snapshot-exported by launch.mine_serve.

Plus log.py (structured JSON-lines run records for the launchers) and
validate.py (artifact schema validators, used by CI and the tests).

Dependency direction: repro.core imports obs.trace for the record layout;
nothing in obs imports repro.core, so there is no cycle.
"""

from .log import JsonlLogger
from .metrics import DEFAULT_LATENCY_BUCKETS, MetricsRegistry
from .span import SpanTracer
from .trace import (
    DEFAULT_TRACE_CAP,
    N_FIELDS,
    SuperstepTrace,
    TraceField,
    decode_trace,
    jain_fairness,
)

__all__ = [
    "DEFAULT_LATENCY_BUCKETS",
    "DEFAULT_TRACE_CAP",
    "JsonlLogger",
    "MetricsRegistry",
    "N_FIELDS",
    "SpanTracer",
    "SuperstepTrace",
    "TraceField",
    "decode_trace",
    "jain_fairness",
]
