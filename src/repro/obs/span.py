"""Host span tracer — nested timing spans with Chrome-trace export.

The device superstep trace (obs/trace.py) answers "where did the miners'
time go"; this module answers the same question for the host orchestration
around them: pack, lower/compile, dispatch, postprocess, reconstruct.  A
`SpanTracer` is a context-manager factory::

    tracer = SpanTracer()
    with tracer.span("phase:count", mode="count"):
        with tracer.span("dispatch"):
            ...
    tracer.save("trace.json")          # open in ui.perfetto.dev / chrome://tracing

Spans record wall-clock complete events (Chrome trace ``ph: "X"``) with
microsecond timestamps relative to the tracer's epoch; nesting follows the
with-statement structure, which is exactly what the Chrome trace viewer's
flame layout expects on one thread track.  `MinerSession` owns a tracer by
default and wraps every phase of every query, so a serving process gets a
queryable host timeline for free.

`jax_profiler=True` additionally enters a ``jax.profiler.TraceAnnotation``
per span, so when a device profile is being captured (``jax.profiler.trace``)
the host spans line up with the XLA device timeline in the same viewer.
The bridge is best-effort: absent/old jax profiler APIs degrade to plain
span recording.
"""

from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager

__all__ = ["SpanTracer"]


class SpanTracer:
    """Collects nested wall-clock spans; exports Chrome-trace JSON."""

    def __init__(self, *, jax_profiler: bool = False):
        self.jax_profiler = jax_profiler
        self._events: list[dict] = []
        self._epoch_ns = time.perf_counter_ns()
        self._lock = threading.Lock()
        self._annotation = None
        if jax_profiler:
            try:
                from jax.profiler import TraceAnnotation

                self._annotation = TraceAnnotation
            except Exception:  # profiler API moved/absent: spans still record
                self._annotation = None

    def _now_us(self) -> float:
        return (time.perf_counter_ns() - self._epoch_ns) / 1e3

    @contextmanager
    def span(self, name: str, **args):
        """Time a nested region; extra kwargs land in the event's args."""
        ann = self._annotation(name) if self._annotation is not None else None
        if ann is not None:
            ann.__enter__()
        t0 = self._now_us()
        try:
            yield self
        finally:
            t1 = self._now_us()
            if ann is not None:
                ann.__exit__(None, None, None)
            event = {
                "name": name,
                "ph": "X",
                "ts": t0,
                "dur": t1 - t0,
                "pid": os.getpid(),
                "tid": threading.get_ident() & 0xFFFF,
            }
            if args:
                event["args"] = {k: _jsonable(v) for k, v in args.items()}
            with self._lock:
                self._events.append(event)

    # ------------------------------------------------------------- export
    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()

    def to_chrome_trace(self) -> dict:
        """The Chrome trace-event JSON object (ts/dur in microseconds)."""
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f, indent=1)
            f.write("\n")
        return path


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)
