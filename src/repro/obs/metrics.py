"""Metrics registry — counters, gauges, histograms with Prometheus exposition.

The third observability layer (DESIGN.md §9): cumulative run-state a
serving process can snapshot at any time, as opposed to the per-run device
trace and the per-span host timeline.  Dependency-free (stdlib only) and
deliberately tiny — the Prometheus *text exposition format* is the
interface, so anything that scrapes .prom files or an HTTP endpoint can
consume it without a client library::

    reg = MetricsRegistry()
    hits = reg.counter("cache_hits_total", "program cache hits")
    lat = reg.histogram("query_seconds", "query latency", labels=("query",))
    hits.inc()
    lat.labels(query="significant").observe(0.12)
    print(reg.expose_text())

`MinerSession` owns a registry by default and feeds it the program-cache
hit/miss/eviction counters, per-phase and per-query latency histograms,
and the telemetry-loss counters (emit_dropped / trace_dropped);
`launch.mine_serve --metrics-out` snapshots the session registry next to
its latency JSON.  Instruments are re-entrant: requesting an existing name
returns the same family (mismatched kind/labels raise).
"""

from __future__ import annotations

import math
import threading

__all__ = [
    "Counter",
    "DEFAULT_LATENCY_BUCKETS",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
]

#: log-ish spread from 1 ms to 1 min — mining phase/query latencies span
#: cold compiles (seconds) to warm dispatches (milliseconds)
DEFAULT_LATENCY_BUCKETS = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 30.0, 60.0,
)


class Counter:
    """Monotonically increasing value."""

    __slots__ = ("_value", "_lock")

    def __init__(self):
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counters only go up (inc({amount}))")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """Settable value."""

    __slots__ = ("_value", "_lock")

    def __init__(self):
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Cumulative-bucket histogram (Prometheus ``le`` semantics)."""

    __slots__ = ("buckets", "_counts", "_sum", "_count", "_lock")

    def __init__(self, buckets=DEFAULT_LATENCY_BUCKETS):
        b = tuple(sorted(float(x) for x in buckets))
        if not b:
            raise ValueError("histogram needs at least one bucket bound")
        self.buckets = b
        self._counts = [0] * (len(b) + 1)  # +1: the +Inf bucket
        self._sum = 0.0
        self._count = 0
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        value = float(value)
        with self._lock:
            for i, bound in enumerate(self.buckets):
                if value <= bound:
                    self._counts[i] += 1
                    break
            else:
                self._counts[-1] += 1
            self._sum += value
            self._count += 1

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def cumulative_counts(self) -> list[int]:
        """Per-bound cumulative counts, ending with the +Inf total."""
        out, acc = [], 0
        with self._lock:
            for c in self._counts:
                acc += c
                out.append(acc)
        return out


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}

_NAME_OK = set("abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789_:")


def _check_name(name: str) -> str:
    if not name or name[0].isdigit() or not set(name) <= _NAME_OK:
        raise ValueError(f"invalid metric name {name!r}")
    return name


class _Family:
    """One named metric: either a single child () or per-label children."""

    __slots__ = ("name", "help", "kind", "labelnames", "_children", "_kwargs",
                 "_lock")

    def __init__(self, name, help_, kind, labelnames, **kwargs):
        self.name = _check_name(name)
        self.help = help_
        self.kind = kind
        self.labelnames = tuple(labelnames)
        self._children: dict[tuple, object] = {}
        self._kwargs = kwargs
        self._lock = threading.Lock()
        if not self.labelnames:
            self._children[()] = _KINDS[kind](**kwargs)

    def labels(self, **kv):
        if set(kv) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, got "
                f"{tuple(kv)}"
            )
        key = tuple(str(kv[k]) for k in self.labelnames)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = self._children[key] = _KINDS[self.kind](**self._kwargs)
            return child

    @property
    def default(self):
        """The unlabelled child (only for label-free families)."""
        return self._children[()]

    def children(self):
        with self._lock:
            return dict(self._children)


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt(value: float) -> str:
    if math.isinf(value):
        return "+Inf" if value > 0 else "-Inf"
    if float(value).is_integer() and abs(value) < 1e15:
        return str(int(value))
    return repr(float(value))


def _labelstr(names, values, extra=()):
    pairs = [f'{n}="{_escape(v)}"' for n, v in zip(names, values)]
    pairs += [f'{n}="{_escape(v)}"' for n, v in extra]
    return "{" + ",".join(pairs) + "}" if pairs else ""


class MetricsRegistry:
    """A named set of metric families with text exposition."""

    def __init__(self):
        self._families: dict[str, _Family] = {}
        self._lock = threading.Lock()

    def _family(self, name, help_, kind, labels, **kwargs) -> _Family:
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if fam.kind != kind or fam.labelnames != tuple(labels):
                    raise ValueError(
                        f"metric {name!r} already registered as {fam.kind} "
                        f"with labels {fam.labelnames}"
                    )
                return fam
            fam = _Family(name, help_, kind, labels, **kwargs)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "", labels=()):
        """A counter (or, with labels, a family — call .labels(...) on it)."""
        fam = self._family(name, help, "counter", labels)
        return fam if labels else fam.default

    def gauge(self, name: str, help: str = "", labels=()):
        fam = self._family(name, help, "gauge", labels)
        return fam if labels else fam.default

    def histogram(self, name: str, help: str = "", labels=(),
                  buckets=DEFAULT_LATENCY_BUCKETS):
        fam = self._family(name, help, "histogram", labels, buckets=buckets)
        return fam if labels else fam.default

    def expose_text(self) -> str:
        """Prometheus text exposition format 0.0.4 snapshot."""
        lines = []
        with self._lock:
            families = sorted(self._families.values(), key=lambda f: f.name)
        for fam in families:
            if fam.help:
                lines.append(f"# HELP {fam.name} {_escape(fam.help)}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            for key, child in sorted(fam.children().items()):
                if fam.kind in ("counter", "gauge"):
                    lines.append(
                        f"{fam.name}{_labelstr(fam.labelnames, key)} "
                        f"{_fmt(child.value)}"
                    )
                else:  # histogram
                    cum = child.cumulative_counts()
                    for bound, c in zip(child.buckets, cum):
                        le = _labelstr(fam.labelnames, key,
                                       extra=[("le", _fmt(bound))])
                        lines.append(f"{fam.name}_bucket{le} {c}")
                    inf = _labelstr(fam.labelnames, key, extra=[("le", "+Inf")])
                    lines.append(f"{fam.name}_bucket{inf} {cum[-1]}")
                    ls = _labelstr(fam.labelnames, key)
                    lines.append(f"{fam.name}_sum{ls} {_fmt(child.sum)}")
                    lines.append(f"{fam.name}_count{ls} {child.count}")
        return "\n".join(lines) + "\n"
