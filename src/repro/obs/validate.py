"""Schema validators for the exported observability artifacts.

CI's slow-system job runs a traced smoke mine and pipes its artifacts
through this module, so a malformed Chrome-trace JSON or Prometheus
exposition snapshot fails the job instead of silently producing files no
viewer or scraper can load::

    python -m repro.obs.validate --chrome trace.json --prom metrics.prom

Both validators raise ``ValueError`` with the offending line/event named;
the test suite reuses them to pin the exporters' formats.
"""

from __future__ import annotations

import argparse
import json
import re

__all__ = ["validate_chrome_trace", "validate_prometheus_text"]

_METRIC_NAME = r"[a-zA-Z_:][a-zA-Z0-9_:]*"
_LABELS = r"\{[a-zA-Z_][a-zA-Z0-9_]*=\"(?:[^\"\\\n]|\\.)*\"(?:,[a-zA-Z_][a-zA-Z0-9_]*=\"(?:[^\"\\\n]|\\.)*\")*\}"
_VALUE = r"(?:[+-]?(?:\d+\.?\d*(?:[eE][+-]?\d+)?|\.\d+(?:[eE][+-]?\d+)?)|[+-]?Inf|NaN)"
_SAMPLE_RE = re.compile(
    rf"^({_METRIC_NAME})({_LABELS})? ({_VALUE})(?: [+-]?\d+)?$"
)
_TYPE_RE = re.compile(
    rf"^# TYPE ({_METRIC_NAME}) (counter|gauge|histogram|summary|untyped)$"
)
_HELP_RE = re.compile(rf"^# HELP ({_METRIC_NAME}) .*$")


def validate_chrome_trace(obj_or_path) -> int:
    """Validate a Chrome trace-event JSON file/object; returns event count.

    Checks the envelope (``traceEvents`` list) and, per event, the fields
    the Perfetto/chrome://tracing importers require: a string ``name``, a
    one-char ``ph``, numeric ``ts`` (and ``dur`` >= 0 for complete events),
    integer ``pid``/``tid``, and JSON-object ``args`` when present.
    """
    if isinstance(obj_or_path, str):
        with open(obj_or_path) as f:
            obj = json.load(f)
    else:
        obj = obj_or_path
    if not isinstance(obj, dict) or not isinstance(obj.get("traceEvents"), list):
        raise ValueError("chrome trace: top level must be an object with a "
                         "'traceEvents' list")
    for i, ev in enumerate(obj["traceEvents"]):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            raise ValueError(f"{where}: event must be an object")
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            raise ValueError(f"{where}: missing/empty 'name'")
        ph = ev.get("ph")
        if not isinstance(ph, str) or len(ph) != 1:
            raise ValueError(f"{where}: 'ph' must be a 1-char string")
        if not isinstance(ev.get("ts"), (int, float)):
            raise ValueError(f"{where}: 'ts' must be a number (microseconds)")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"{where}: complete event needs 'dur' >= 0")
        for key in ("pid", "tid"):
            if key in ev and not isinstance(ev[key], int):
                raise ValueError(f"{where}: '{key}' must be an int")
        if "args" in ev and not isinstance(ev["args"], dict):
            raise ValueError(f"{where}: 'args' must be an object")
    return len(obj["traceEvents"])


def validate_prometheus_text(text_or_path) -> int:
    """Validate Prometheus text exposition format 0.0.4; returns sample count.

    Checks line syntax (HELP/TYPE comments, sample lines), that every
    sample's base name was TYPE-declared, and histogram structure: a
    ``+Inf`` bucket per series, cumulative bucket counts, and
    ``_bucket{+Inf} == _count``.
    """
    if "\n" not in text_or_path and text_or_path.endswith((".prom", ".txt")):
        with open(text_or_path) as f:
            text = f.read()
    else:
        text = text_or_path
    types: dict[str, str] = {}
    samples: list[tuple[str, str, float]] = []  # (name, labelstr, value)
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("#"):
            if line.startswith("# TYPE "):
                m = _TYPE_RE.match(line)
                if not m:
                    raise ValueError(f"line {lineno}: malformed TYPE: {line!r}")
                types[m.group(1)] = m.group(2)
            elif line.startswith("# HELP "):
                if not _HELP_RE.match(line):
                    raise ValueError(f"line {lineno}: malformed HELP: {line!r}")
            continue  # other comments are legal and ignored
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"line {lineno}: malformed sample: {line!r}")
        name, labels, value = m.group(1), m.group(2) or "", m.group(3)
        samples.append((name, labels, float(value)))
        base = re.sub(r"_(bucket|sum|count)$", "", name)
        if name not in types and base not in types:
            raise ValueError(
                f"line {lineno}: sample {name!r} has no preceding TYPE"
            )

    # histogram structure: cumulative buckets ending at +Inf == _count
    hists = [n for n, k in types.items() if k == "histogram"]
    for name in hists:
        series: dict[str, list[tuple[float, float]]] = {}
        counts: dict[str, float] = {}
        for sname, labels, value in samples:
            if sname == f"{name}_bucket":
                mm = re.search(r'le="([^"]*)"', labels)
                if not mm:
                    raise ValueError(f"{name}_bucket sample missing le label")
                rest = re.sub(r',?le="[^"]*"', "", labels)
                bound = float("inf") if mm.group(1) == "+Inf" else float(mm.group(1))
                series.setdefault(rest, []).append((bound, value))
            elif sname == f"{name}_count":
                counts[labels] = value
        for key, buckets in series.items():
            buckets.sort()
            vals = [v for _, v in buckets]
            if vals != sorted(vals):
                raise ValueError(f"{name}{key}: bucket counts not cumulative")
            if buckets[-1][0] != float("inf"):
                raise ValueError(f"{name}{key}: missing +Inf bucket")
            if key in counts and counts[key] != buckets[-1][1]:
                raise ValueError(
                    f"{name}{key}: +Inf bucket {buckets[-1][1]} != _count "
                    f"{counts[key]}"
                )
    return len(samples)


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="validate exported observability artifacts"
    )
    ap.add_argument("--chrome", action="append", default=[],
                    help="Chrome-trace JSON file to validate")
    ap.add_argument("--prom", action="append", default=[],
                    help="Prometheus text exposition file to validate")
    args = ap.parse_args(argv)
    if not args.chrome and not args.prom:
        ap.error("nothing to validate: pass --chrome and/or --prom")
    for path in args.chrome:
        n = validate_chrome_trace(path)
        print(f"[ok] {path}: valid chrome trace ({n} events)")
    for path in args.prom:
        with open(path) as f:
            n = validate_prometheus_text(f.read())
        print(f"[ok] {path}: valid prometheus exposition ({n} samples)")


if __name__ == "__main__":
    main()
