"""Device superstep trace — record layout and host-side decode (DESIGN.md §9).

The paper's headline claim is *evenly distributed communication*: global
load balancing over hypercube lifelines is what buys the speedup.  This
module makes that claim measurable.  The engine threads a fixed-size
``[trace_cap, N_FIELDS] i32`` ring buffer through the BSP carry and, every
``trace_period`` supersteps, writes one record per miner — the lambda in
force, the live stack depth, the hunger census, whether the steal exchange
fired, and the superstep's pop/push/close/emit/donate/receive volumes.
Recording is **psum-free**: every field is a value the superstep already
holds (the census psum runs regardless), so tracing adds one ``[N_FIELDS]``
scatter per sampled step and nothing to the collective footprint.

``trace_period == 0`` (the default) compiles the trace out entirely; the
period is part of ``EngineConfig`` and therefore of the session's compiled-
program cache key.  When the ring wraps, older records are overwritten and
the overwrite count lands in the ``trace_dropped`` engine stat so the host
can warn (mirroring ``emit_dropped`` — telemetry loss is never silent).

`decode_trace` turns the raw per-miner rings into a `SuperstepTrace`: field
arrays ordered by superstep id (the surviving window after any wrap), plus
the load-balance metrics the ROADMAP's multi-host work will be debugged
with — per-miner idle fractions, max/mean stack depth, and Jain's fairness
index over donation volumes (1.0 = perfectly even steal traffic, 1/P =
one miner does all the donating).

This module is pure numpy + stdlib so the engine can import the field
layout without a dependency cycle (core -> obs only).
"""

from __future__ import annotations

import enum
from dataclasses import dataclass

import numpy as np

__all__ = [
    "DEFAULT_TRACE_CAP",
    "N_FIELDS",
    "SuperstepTrace",
    "TraceField",
    "decode_trace",
    "jain_fairness",
]

#: ring size RuntimeConfig.resolve supplies when tracing is on but no
#: explicit trace_cap was given (4096 sampled steps outlasts every
#: committed benchmark problem at trace_period=1)
DEFAULT_TRACE_CAP = 4096


class TraceField(enum.IntEnum):
    """Column of each per-superstep trace record ([N_FIELDS] i32 per miner).

    STEP/LAMBDA/HUNGRY/FIRED are replicated across miners (they derive from
    psum results every miner holds); the rest are genuinely per-miner.
    """

    STEP = 0       # superstep id t (monotone; the decode sort key)
    LAMBDA = 1     # lambda in force during this superstep (pre-sync)
    DEPTH = 2      # live stack depth after EXPAND + STEAL (sp entering t+1)
    HUNGRY = 3     # n_hungry: miners with empty stacks after EXPAND (global)
    FIRED = 4      # 1 iff the gated steal exchange ran this superstep
    POPPED = 5     # nodes popped alive by EXPAND this superstep
    PUSHED = 6     # children pushed this superstep
    CLOSED = 7     # closed sets counted into the histogram this superstep
    EMITTED = 8    # pattern records emitted this superstep
    DONATED = 9    # nodes this miner donated in this round's GIVE
    RECEIVED = 10  # nodes this miner received in this round's reply


N_FIELDS = len(TraceField)


def jain_fairness(x) -> float:
    """Jain's fairness index (sum x)^2 / (n * sum x^2), in [1/n, 1].

    1.0 = perfectly even shares, 1/n = one participant holds everything.
    The all-zero vector (nothing to share) is defined as perfectly fair.
    """
    x = np.asarray(x, dtype=np.float64)
    if x.size == 0:
        return 1.0
    sq = float(np.sum(x * x))
    if sq == 0.0:
        return 1.0
    return float(np.sum(x)) ** 2 / (x.size * sq)


@dataclass(frozen=True)
class SuperstepTrace:
    """Decoded per-miner superstep timeline + load-balance metrics.

    Scalar series (`steps`, `lam`, `n_hungry`, `fired`) are [S]; per-miner
    series are [P, S].  S = min(sampled steps, trace_cap): after a ring
    wrap only the most recent window survives and `dropped` counts the
    overwritten records.
    """

    period: int            # sampling period (supersteps between records)
    cap: int               # ring capacity the engine ran with
    dropped: int           # sampled records overwritten by ring wrap
    steps: np.ndarray      # [S] superstep ids, strictly increasing
    lam: np.ndarray        # [S] lambda in force per sampled step
    n_hungry: np.ndarray   # [S] global hunger census per sampled step
    fired: np.ndarray      # [S] 1 iff the steal exchange ran
    depth: np.ndarray      # [P, S] live stack depth per miner
    popped: np.ndarray     # [P, S] nodes popped alive per miner
    pushed: np.ndarray     # [P, S] children pushed per miner
    closed: np.ndarray     # [P, S] closed sets counted per miner
    emitted: np.ndarray    # [P, S] pattern records emitted per miner
    donated: np.ndarray    # [P, S] per-round donation volume per miner
    received: np.ndarray   # [P, S] per-round received volume per miner
    # the lifeline schedule the engine cycled (LifelineSchedule.names /
    # .tiers), when the decoder was given it: superstep t ran round
    # t % len(schedule_names), which keys the per-round steal attribution
    # below.  None = schedule unknown (legacy decode) — per-round methods
    # then return empty/flat aggregates.
    schedule_names: tuple | None = None
    schedule_tiers: tuple | None = None  # "local" | "cross" | "flat" per round

    @property
    def n_miners(self) -> int:
        return int(self.depth.shape[0])

    @property
    def n_steps(self) -> int:
        return int(self.steps.shape[0])

    # ------------------------------------------------------------- metrics
    def idle_fraction(self) -> np.ndarray:
        """[P] fraction of sampled supersteps each miner popped zero nodes."""
        if self.n_steps == 0:
            return np.zeros(self.n_miners)
        return (self.popped == 0).mean(axis=1)

    def donation_fairness(self) -> float:
        """Jain's index over per-miner total donated nodes — the paper's
        "evenly distributed communication", as one number in [1/P, 1]."""
        return jain_fairness(self.donated.sum(axis=1))

    def work_fairness(self) -> float:
        """Jain's index over per-miner total popped nodes (load balance)."""
        return jain_fairness(self.popped.sum(axis=1))

    def _round_of_step(self) -> np.ndarray | None:
        """[S] schedule-round index of each sampled superstep, or None."""
        if self.schedule_names is None or self.n_steps == 0:
            return None
        return np.asarray(self.steps) % len(self.schedule_names)

    def steal_by_round(self) -> dict:
        """Per-schedule-round steal attribution, keyed by round name.

        Each value: {tier, steps, fired, donated, received} summed over the
        sampled window (all miners).  The multi-host question this answers:
        how much steal volume moved on cheap intra-host rounds vs expensive
        cross-host ones.  Empty when the decoder wasn't given the schedule.
        """
        rounds = self._round_of_step()
        if rounds is None:
            return {}
        names = self.schedule_names
        tiers = self.schedule_tiers or ("flat",) * len(names)
        out: dict = {}
        for r, name in enumerate(names):
            mask = rounds == r
            agg = out.setdefault(name, {
                "tier": tiers[r], "steps": 0, "fired": 0,
                "donated": 0, "received": 0,
            })
            agg["steps"] += int(mask.sum())
            agg["fired"] += int(self.fired[mask].sum())
            agg["donated"] += int(self.donated[:, mask].sum())
            agg["received"] += int(self.received[:, mask].sum())
        return out

    def tier_fairness(self) -> dict:
        """Jain's donation fairness split by schedule tier.

        {tier: index in [1/P, 1]} over per-miner donated volumes restricted
        to that tier's rounds — the paper's "evenly distributed
        communication" claim, now answerable separately for the intra-host
        and cross-host planes.  {} when the schedule is unknown.
        """
        rounds = self._round_of_step()
        if rounds is None:
            return {}
        tiers = self.schedule_tiers or ("flat",) * len(self.schedule_names)
        out = {}
        for tier in dict.fromkeys(tiers):  # stable unique order
            round_ids = [r for r, t in enumerate(tiers) if t == tier]
            mask = np.isin(rounds, round_ids)
            out[tier] = jain_fairness(self.donated[:, mask].sum(axis=1))
        return out

    def depth_imbalance(self) -> float:
        """Mean over sampled steps of max/mean live stack depth across
        miners (steps where every stack is empty contribute 1.0)."""
        if self.n_steps == 0:
            return 1.0
        d = self.depth.astype(np.float64)
        mean = d.mean(axis=0)
        ratio = np.where(mean > 0, d.max(axis=0) / np.maximum(mean, 1e-300), 1.0)
        return float(ratio.mean())

    def summary(self) -> dict:
        """JSON-able metrics blob (benchmarks, --verbose run records)."""
        donated_tot = self.donated.sum(axis=1)
        out = {
            "sampled_steps": self.n_steps,
            "period": self.period,
            "dropped": self.dropped,
            "steal_rounds_fired": int(self.fired.sum()),
            "fired_fraction": round(float(self.fired.mean()), 4)
            if self.n_steps else 0.0,
            "donation_fairness": round(self.donation_fairness(), 4),
            "work_fairness": round(self.work_fairness(), 4),
            "depth_imbalance": round(self.depth_imbalance(), 3),
            "idle_fraction": [round(float(x), 4) for x in self.idle_fraction()],
            "donated_nodes": [int(x) for x in donated_tot],
            "depth_mean": [round(float(x), 1) for x in
                           self.depth.mean(axis=1)] if self.n_steps else [],
            "depth_max": [int(x) for x in self.depth.max(axis=1)]
            if self.n_steps else [],
        }
        if self.schedule_names is not None:
            out["steal_by_round"] = self.steal_by_round()
            out["tier_fairness"] = {
                k: round(v, 4) for k, v in self.tier_fairness().items()
            }
        return out


def expected_samples(supersteps: int, period: int) -> int:
    """Records a `supersteps`-long run writes: steps 0, p, 2p, ... < T."""
    if period <= 0 or supersteps <= 0:
        return 0
    return (supersteps - 1) // period + 1


def decode_trace(
    raw: np.ndarray, *, supersteps: int, period: int,
    round_names: tuple | None = None, round_tiers: tuple | None = None,
) -> SuperstepTrace:
    """Raw device rings [P, cap, N_FIELDS] -> decoded `SuperstepTrace`.

    The engine writes sample idx = t // period into slot idx % cap, so
    after a wrap the ring holds the *last* cap samples with the oldest at
    slot (n_sampled % cap); ordering by the recorded STEP field recovers
    the window.  All miners sample the same steps (t is replicated), so
    miner 0's STEP column orders every miner's ring identically.

    `round_names`/`round_tiers` (LifelineSchedule.names / .tiers) attribute
    each sampled step to its steal round (t mod n_rounds), enabling the
    per-round and per-tier steal aggregations on the decoded trace.
    """
    raw = np.asarray(raw)
    if raw.ndim != 3 or raw.shape[2] != N_FIELDS:
        raise ValueError(
            f"expected raw trace [P, cap, {N_FIELDS}], got {raw.shape}"
        )
    cap = raw.shape[1]
    n_sampled = expected_samples(supersteps, period)
    valid = min(n_sampled, cap)
    dropped = n_sampled - valid
    window = raw[:, :valid, :]
    order = np.argsort(window[0, :, TraceField.STEP], kind="stable")
    window = window[:, order, :]

    def scalar(f):
        return window[0, :, f].copy()

    def per_miner(f):
        return window[:, :, f].copy()

    return SuperstepTrace(
        period=period,
        cap=cap,
        dropped=dropped,
        steps=scalar(TraceField.STEP),
        lam=scalar(TraceField.LAMBDA),
        n_hungry=scalar(TraceField.HUNGRY),
        fired=scalar(TraceField.FIRED),
        depth=per_miner(TraceField.DEPTH),
        popped=per_miner(TraceField.POPPED),
        pushed=per_miner(TraceField.PUSHED),
        closed=per_miner(TraceField.CLOSED),
        emitted=per_miner(TraceField.EMITTED),
        donated=per_miner(TraceField.DONATED),
        received=per_miner(TraceField.RECEIVED),
        schedule_names=tuple(round_names) if round_names is not None else None,
        schedule_tiers=tuple(round_tiers) if round_tiers is not None else None,
    )
