"""Checkpoint/restore with elastic resharding.

Format: <dir>/step_<N>/
  manifest.json       tree structure, shapes/dtypes, mesh metadata, step
  arrays.npz          one entry per leaf (flattened key path)

Restore resharding: arrays are stored unsharded (gathered); on restore they
are device_put against whatever mesh/sharding the *new* topology defines, so
a job restarted on a different device count resumes transparently (elastic
scaling).  Production deployments would swap the .npz backend for a
tensorstore/OCDBT driver behind the same manifest; the resharding logic —
the part that matters for elasticity — is identical.

The miner checkpoints its frontier (stacks, histogram, lambda) through the
same API; `examples/fault_tolerant_mining.py` kills and resumes a search.
"""

from __future__ import annotations

import json
import os
import re
import shutil

import ml_dtypes
import numpy as np

import jax
import jax.numpy as jnp

_SEP = "::"
# dtypes numpy's npz cannot store natively: save as a same-width integer view
_VIEW_AS = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
}


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out[key] = leaf
    return out, treedef


def save(tree, directory: str, step: int, *, meta: dict | None = None, keep: int = 3):
    """Atomic checkpoint write (tmp dir + rename); prunes old steps."""
    tmp = os.path.join(directory, f".tmp_step_{step}")
    final = os.path.join(directory, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    flat, _ = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    manifest = {
        "step": step,
        "meta": meta or {},
        "leaves": {
            k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in arrays.items()
        },
    }
    stored = {
        k: (v.view(_VIEW_AS[str(v.dtype)]) if str(v.dtype) in _VIEW_AS else v)
        for k, v in arrays.items()
    }
    np.savez(os.path.join(tmp, "arrays.npz"), **stored)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # prune
    steps = sorted(list_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s}"), ignore_errors=True)
    return final


def list_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str):
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, target_tree, shardings=None):
    """Restore into the structure of target_tree (abstract or concrete).

    shardings: optional matching pytree of NamedSharding for elastic
    resharding onto the current mesh; None -> plain host arrays.
    """
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_t, treedef = _flatten(target_tree)
    flat_s, _ = _flatten(shardings) if shardings is not None else ({}, None)
    leaves = []
    for key, target in flat_t.items():
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        want = manifest["leaves"][key]
        if want["dtype"] in _VIEW_AS:
            arr = arr.view(getattr(ml_dtypes, want["dtype"]))
        assert list(arr.shape) == want["shape"]
        if tuple(arr.shape) != tuple(target.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {target.shape}")
        arr = arr.astype(target.dtype)
        if key in flat_s:
            arr = jax.device_put(arr, flat_s[key])
        leaves.append((key, arr))
    order = {k: i for i, (k, _) in enumerate(flat_t.items())}
    leaves.sort(key=lambda kv: order[kv[0]])
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target_tree), [v for _, v in leaves]
    ), manifest


def restore_latest(directory: str, target_tree, shardings=None):
    step = latest_step(directory)
    if step is None:
        return None, None
    tree, manifest = restore(directory, step, target_tree, shardings)
    return tree, manifest
