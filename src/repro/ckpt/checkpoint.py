"""Checkpoint/restore with elastic resharding and corruption detection.

Format: <dir>/step_<N>/
  manifest.json       tree structure, shapes/dtypes/crc32s, metadata, step
  arrays.npz          one entry per leaf (flattened key path)

Crash safety (DESIGN.md §11): a step is staged into a dot-prefixed tmp dir
(invisible to `list_steps`) and *published* by a rename sequence that keeps
a complete copy on disk at every instant — rename the old step aside,
rename the tmp in, delete the aside.  A crash anywhere leaves either the
old or the new step fully intact; `.old_step_N`/`.tmp_step_N` leftovers are
dot-prefixed and never mistaken for steps.

Corruption detection: the manifest records a crc32 per stored leaf;
`restore`/`load_step` verify on read and raise `CorruptCheckpoint`, and
`restore_latest` falls back to the newest step that still verifies (with a
RuntimeWarning naming the ones it skipped).

Restore resharding: arrays are stored unsharded (gathered); on restore they
are device_put against whatever mesh/sharding the *new* topology defines, so
a job restarted on a different device count resumes transparently (elastic
scaling).  Production deployments would swap the .npz backend for a
tensorstore/OCDBT driver behind the same manifest; the resharding logic —
the part that matters for elasticity — is identical.

The miner checkpoints its frontier (stacks, histogram, lambda) through the
same API (`repro.ckpt.mining`); `examples/fault_tolerant_mining.py` kills
and resumes a search.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import warnings
import zipfile
import zlib

import ml_dtypes
import numpy as np

import jax
import jax.numpy as jnp  # noqa: F401  (kept: public module surface)

from repro.testing import faults

__all__ = [
    "CheckpointError",
    "CorruptCheckpoint",
    "latest_step",
    "list_steps",
    "load_step",
    "restore",
    "restore_latest",
    "save",
]

_SEP = "::"
# dtypes numpy's npz cannot store natively: save as a same-width integer view
_VIEW_AS = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
}


class CheckpointError(RuntimeError):
    """A checkpoint could not be written or read."""


class CorruptCheckpoint(CheckpointError):
    """A step dir exists but fails structural or checksum verification."""


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out[key] = leaf
    return out, treedef


def _crc32(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def save(tree, directory: str, step: int, *, meta: dict | None = None, keep: int = 3):
    """Crash-safe checkpoint write; prunes old steps.

    Publish ordering (a complete step dir exists on disk at every instant):
    stage into `.tmp_step_N`, rename any existing `step_N` aside to
    `.old_step_N`, rename the tmp in, delete the aside.  The manifest
    carries a crc32 per stored leaf for corruption detection on restore.
    """
    tmp = os.path.join(directory, f".tmp_step_{step}")
    final = os.path.join(directory, f"step_{step}")
    aside = os.path.join(directory, f".old_step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, _ = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    stored = {
        k: (v.view(_VIEW_AS[str(v.dtype)]) if str(v.dtype) in _VIEW_AS else v)
        for k, v in arrays.items()
    }
    manifest = {
        "step": step,
        "meta": meta or {},
        "leaves": {
            k: {
                "shape": list(v.shape),
                "dtype": str(v.dtype),
                # checksum of the *stored* bytes (post-_VIEW_AS view)
                "crc32": _crc32(stored[k]),
            }
            for k, v in arrays.items()
        },
    }
    np.savez(os.path.join(tmp, "arrays.npz"), **stored)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    faults.check("ckpt.pre_publish", step=step, path=tmp)
    # publish: old aside -> tmp in -> aside gone.  A crash between any two
    # renames leaves a complete copy (`step_N` or `.old_step_N`) on disk.
    if os.path.exists(aside):
        shutil.rmtree(aside)
    if os.path.exists(final):
        os.rename(final, aside)
    os.rename(tmp, final)
    if os.path.exists(aside):
        shutil.rmtree(aside)
    faults.check("ckpt.published", step=step, path=final)
    # prune
    steps = sorted(list_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s}"), ignore_errors=True)
    return final


def list_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str):
    steps = list_steps(directory)
    return steps[-1] if steps else None


def load_step(directory: str, step: int, *, verify: bool = True):
    """Raw read of one step: (dict key -> ndarray, manifest).

    Arrays come back in their manifest dtypes (`_VIEW_AS` views undone).
    Raises `CorruptCheckpoint` on structural damage (unreadable manifest or
    zip) or — with `verify` (default) — on any per-leaf crc32/shape
    mismatch.  This is the reader `restore`/`restore_latest` and the
    frontier restore (`repro.ckpt.mining`) build on.
    """
    path = os.path.join(directory, f"step_{step}")
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
    except (OSError, json.JSONDecodeError, zipfile.BadZipFile, ValueError) as e:
        raise CorruptCheckpoint(
            f"step {step} in {directory} is unreadable: {e}") from e
    out = {}
    for key, want in manifest.get("leaves", {}).items():
        try:
            arr = data[key]
        except Exception as e:  # zip-level damage raises varied types
            raise CorruptCheckpoint(
                f"step {step}: leaf {key!r} unreadable: {e}") from e
        if verify:
            crc = want.get("crc32")
            if crc is not None and _crc32(arr) != crc:
                raise CorruptCheckpoint(
                    f"step {step}: leaf {key!r} failed its crc32 check "
                    "(bytes on disk do not match the manifest)")
        if want["dtype"] in _VIEW_AS:
            arr = arr.view(getattr(ml_dtypes, want["dtype"]))
        if verify and list(arr.shape) != want["shape"]:
            raise CorruptCheckpoint(
                f"step {step}: leaf {key!r} shape {list(arr.shape)} != "
                f"manifest {want['shape']}")
        out[key] = arr
    return out, manifest


def restore(directory: str, step: int, target_tree, shardings=None):
    """Restore into the structure of target_tree (abstract or concrete).

    shardings: optional matching pytree of NamedSharding for elastic
    resharding onto the current mesh; None -> plain host arrays.
    Raises KeyError when the checkpoint lacks a target leaf, ValueError on
    a target shape mismatch, and `CorruptCheckpoint` on damaged data.
    """
    data, manifest = load_step(directory, step)
    flat_t, _ = _flatten(target_tree)
    flat_s, _ = _flatten(shardings) if shardings is not None else ({}, None)
    leaves = []
    for key, target in flat_t.items():
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        if tuple(arr.shape) != tuple(target.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {target.shape}")
        arr = arr.astype(target.dtype)
        if key in flat_s:
            arr = jax.device_put(arr, flat_s[key])
        leaves.append((key, arr))
    order = {k: i for i, (k, _) in enumerate(flat_t.items())}
    leaves.sort(key=lambda kv: order[kv[0]])
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target_tree), [v for _, v in leaves]
    ), manifest


def restore_latest(directory: str, target_tree, shardings=None):
    """Restore the newest step that verifies; corrupt steps are skipped
    (with a RuntimeWarning) and the next-newest is tried.  Returns
    (None, None) when no valid step exists."""
    for step in reversed(list_steps(directory)):
        try:
            return restore(directory, step, target_tree, shardings)
        except CorruptCheckpoint as e:
            warnings.warn(
                f"skipping corrupt checkpoint step {step} in {directory}: "
                f"{e}", RuntimeWarning, stacklevel=2)
    return None, None
