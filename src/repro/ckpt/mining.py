"""Mining-frontier checkpoints: BSP carry ⇄ `ckpt.checkpoint` steps.

The BSP carry *is* the search frontier (deque stacks + head/sp pointers,
lamp1 histogram + sync state, lambda, stats, emitted records) — task-
parallel FPM's free fault tolerance, DESIGN.md §11.  This module maps the
host-side carry dict (`engine.CARRY_FIELDS`) onto the generic step format
of `repro.ckpt.checkpoint` and adds the two things a *mining* checkpoint
needs on top:

provenance
    The manifest carries the dataset fingerprint (sha256 of the packed
    bitmap + label mask + dims) and the query-determining knobs (mode,
    statistic, alpha, start_sup, delta).  A resume against a checkpoint
    whose provenance does not match raises `ProvenanceMismatch` loudly —
    it never silently falls back to an older step, because *every* step
    in that directory is equally wrong for this query.

elastic resharding
    A frontier saved at P miners restores onto P′ devices: each miner's
    deque is linearized in logical order, the concatenated node list is
    re-dealt round-robin, additive state (histograms, n_sig, counter
    stats) merges onto miner 0, replicated state (lambda, t, lamp1 sync
    accumulators) is broadcast, and emitted records re-split contiguously.
    Correctness does not depend on the re-deal order — steals migrate
    self-contained node payloads during the run, and the final lambda is
    replayed exactly from the global histogram in postprocess — which is
    why the resumed mine's ResultSet is bit-identical for P→P′.
"""

from __future__ import annotations

import hashlib

import numpy as np

from repro.core.engine import CARRY_FIELDS, EngineConfig
from repro.core.stats import Stat
from repro.obs.trace import N_FIELDS

from . import checkpoint

__all__ = [
    "FORMAT",
    "ProvenanceMismatch",
    "dataset_fingerprint",
    "make_provenance",
    "reshard_frontier",
    "restore_frontier",
    "save_frontier",
    "verify_provenance",
]

FORMAT = "mining-frontier-v1"

#: provenance keys that must match exactly for a resume to be legal
_MATCH_KEYS = (
    "format", "fingerprint", "mode", "statistic", "alpha", "start_sup",
    "delta",
)

#: stats columns that are per-superstep (identical on every miner), not
#: additive — on reshard they are broadcast from old miner 0, not summed
_REPLICATED_STATS = (Stat.SUPERSTEPS, Stat.STEAL_ROUNDS)


class ProvenanceMismatch(ValueError):
    """Checkpoint was written by a different dataset/query — resume refused."""


def dataset_fingerprint(packed) -> str:
    """sha256 over the packed database bytes, label mask, and actual dims."""
    h = hashlib.sha256()
    h.update(np.ascontiguousarray(packed.db_bits).tobytes())
    h.update(np.ascontiguousarray(packed.pos_mask).tobytes())
    h.update(repr((packed.n, packed.n_pos, packed.m,
                   packed.n_pad, packed.npos_pad, packed.m_pad)).encode())
    return h.hexdigest()


def make_provenance(
    packed, *, mode: str, statistic: str | None, alpha: float,
    start_sup: int, delta: float,
) -> dict:
    """The identity a frontier checkpoint must match to be resumable."""
    return {
        "format": FORMAT,
        "fingerprint": dataset_fingerprint(packed),
        "mode": mode,
        "statistic": statistic,
        "alpha": float(alpha),
        "start_sup": int(start_sup),
        "delta": float(delta),
    }


def verify_provenance(meta: dict, provenance: dict) -> None:
    """Raise ProvenanceMismatch naming every key that disagrees."""
    bad = [
        f"{k}: checkpoint={meta.get(k)!r} != current={provenance.get(k)!r}"
        for k in _MATCH_KEYS
        if meta.get(k) != provenance.get(k)
    ]
    if bad:
        raise ProvenanceMismatch(
            "checkpoint provenance does not match this mine (refusing to "
            "resume): " + "; ".join(bad)
        )


def save_frontier(
    carry: dict[str, np.ndarray], directory: str, *, provenance: dict,
    keep: int = 3,
):
    """Write one frontier step (step number = the carry's superstep count).

    Returns (published path, payload bytes).
    """
    step = int(carry["t"][0])
    meta = dict(provenance, n_miners=int(carry["sp"].shape[0]))
    path = checkpoint.save(carry, directory, step, meta=meta, keep=keep)
    nbytes = int(sum(np.asarray(v).nbytes for v in carry.values()))
    return path, nbytes


def load_frontier(directory: str, step: int):
    """Raw read of one frontier step -> (carry dict, manifest).

    Raises CorruptCheckpoint on damage, including a missing carry leaf.
    """
    data, manifest = checkpoint.load_step(directory, step)
    missing = [k for k in CARRY_FIELDS if k not in data]
    if missing:
        raise checkpoint.CorruptCheckpoint(
            f"step {step}: frontier leaves missing: {missing}"
        )
    return {k: data[k] for k in CARRY_FIELDS}, manifest


def restore_frontier(
    directory: str,
    *,
    provenance: dict,
    n_proc: int,
    cfg: EngineConfig,
    mode: str,
    step: int | None = None,
):
    """Newest valid frontier step, elastically resharded onto n_proc miners.

    Corrupt steps fall back newest→oldest (via `checkpoint.restore_latest`
    semantics); a provenance mismatch raises immediately — older steps in
    the same directory were written by the same mine and are equally
    mismatched.  Returns None when the directory holds no steps at all.
    """
    import warnings

    steps = checkpoint.list_steps(directory)
    if step is not None:
        steps = [s for s in steps if s == step]
        if not steps:
            raise checkpoint.CheckpointError(
                f"no step {step} in {directory} (have {checkpoint.list_steps(directory)})"
            )
    if not steps:
        return None
    for s in reversed(steps):
        try:
            carry, manifest = load_frontier(directory, s)
        except checkpoint.CorruptCheckpoint as e:
            warnings.warn(
                f"skipping corrupt frontier step {s} in {directory}: {e}",
                RuntimeWarning, stacklevel=2)
            continue
        verify_provenance(manifest.get("meta", {}), provenance)
        return reshard_frontier(carry, n_proc=n_proc, cfg=cfg, mode=mode)
    return None


def reshard_frontier(
    carry: dict[str, np.ndarray], *, n_proc: int, cfg: EngineConfig,
    mode: str,
) -> dict[str, np.ndarray]:
    """Repartition a P-miner frontier onto n_proc miners (the re-deal).

    Same miner count *and* same buffer capacities passes the carry through
    untouched (bit-identical resume at fixed topology).  Otherwise:

    - stacks: each deque linearized bottom→top from its ring
      (`(head+i) % cap`), concatenated miner-major, node j dealt to new
      miner j % P′; new heads are 0.
    - additive state (hist/hist2d/n_sig/counter stats): totals onto new
      miner 0, zeros elsewhere — global sums (all the engine ever reads)
      are preserved exactly.
    - replicated state (lambda, t, superstep-counting stats): broadcast
      from old miner 0.
    - lamp1 sync state: by the sync invariant g_hist_acc == Σ_p
      hist_snap[p], setting hist_snap[0] = Σ hist and g_hist_acc = Σ hist
      on every miner re-establishes a consistent just-synced state.
    - emitted records: re-split contiguously across the new out buffers.
    - trace ring: per-miner diagnostic, not portable — zeroed.

    Raises ValueError when a new miner's share exceeds stack_cap/out_cap.
    """
    old_p = int(carry["sp"].shape[0])
    cap_old = int(carry["occ_stack"].shape[1])
    out_cap_old = int(carry["out_occ"].shape[1])
    trace_shape = (max(cfg.trace_cap, 1), N_FIELDS)
    if (
        old_p == n_proc
        and cap_old == cfg.stack_cap
        and out_cap_old == cfg.out_cap
        and tuple(carry["trace"].shape[1:]) == trace_shape
    ):
        return {k: np.ascontiguousarray(v) for k, v in carry.items()}

    i32 = np.int32
    w = carry["occ_stack"].shape[2]
    sp = np.asarray(carry["sp"], i32)
    head = np.asarray(carry["head"], i32)

    # --- stacks: linearize every deque in logical order, re-deal round-robin
    occ_rows, meta_rows = [], []
    for p in range(old_p):
        idx = (int(head[p]) + np.arange(int(sp[p]))) % cap_old
        occ_rows.append(carry["occ_stack"][p, idx])
        meta_rows.append(carry["meta"][p, idx])
    occ_all = (np.concatenate(occ_rows) if occ_rows
               else np.zeros((0, w), np.uint32))
    meta_all = (np.concatenate(meta_rows) if meta_rows
                else np.zeros((0, carry["meta"].shape[2]), i32))
    total = occ_all.shape[0]

    new_occ = np.zeros((n_proc, cfg.stack_cap, w), np.uint32)
    new_meta = np.zeros((n_proc, cfg.stack_cap, carry["meta"].shape[2]), i32)
    new_sp = np.zeros(n_proc, i32)
    for p in range(n_proc):
        sel = np.arange(p, total, n_proc)
        k = sel.size
        if k > cfg.stack_cap:
            raise ValueError(
                f"elastic reshard: miner {p} would receive {k} frontier "
                f"nodes > stack_cap={cfg.stack_cap}; raise stack_cap or "
                "restore onto more devices"
            )
        new_occ[p, :k] = occ_all[sel]
        new_meta[p, :k] = meta_all[sel]
        new_sp[p] = k

    # --- additive state: totals on miner 0 preserve every global sum
    def totals_on_zero(arr):
        out = np.zeros((n_proc,) + arr.shape[1:], arr.dtype)
        out[0] = arr.sum(axis=0, dtype=arr.dtype)
        return out

    new_hist = totals_on_zero(np.asarray(carry["hist"], i32))
    new_hist2d = totals_on_zero(np.asarray(carry["hist2d"], i32))
    new_n_sig = totals_on_zero(np.asarray(carry["n_sig"], i32))

    new_stats = totals_on_zero(np.asarray(carry["stats"], i32))
    for col in _REPLICATED_STATS:
        new_stats[:, col] = carry["stats"][0, col]

    # --- lamp1 sync state (dummies of width 1 in other modes merge the same
    # way: sums of zeros stay zero)
    snb = carry["hist_snap"].shape[1]
    hist_tot = np.asarray(carry["hist"], i32).sum(axis=0, dtype=i32)
    new_snap = np.zeros((n_proc, snb), i32)
    new_acc = np.zeros((n_proc, snb), i32)
    if mode == "lamp1":
        new_snap[0] = hist_tot[:snb]
        new_acc[:] = hist_tot[:snb]

    # --- emitted records: contiguous re-split
    out_ptr = np.asarray(carry["out_ptr"], i32)
    live = (np.arange(out_cap_old)[None, :] < out_ptr[:, None]).reshape(-1)
    rec_occ = carry["out_occ"].reshape(old_p * out_cap_old, -1)[live]
    rec_meta = carry["out_meta"].reshape(old_p * out_cap_old, -1)[live]
    k_out = rec_occ.shape[0]
    base, extra = divmod(k_out, n_proc)
    if base + (1 if extra else 0) > cfg.out_cap:
        raise ValueError(
            f"elastic reshard: {k_out} emitted records do not fit "
            f"{n_proc} x out_cap={cfg.out_cap}; raise out_cap"
        )
    new_out_occ = np.zeros((n_proc, cfg.out_cap, w), np.uint32)
    new_out_meta = np.zeros(
        (n_proc, cfg.out_cap, carry["out_meta"].shape[2]), i32)
    new_out_ptr = np.zeros(n_proc, i32)
    off = 0
    for p in range(n_proc):
        k = base + (1 if p < extra else 0)
        new_out_occ[p, :k] = rec_occ[off:off + k]
        new_out_meta[p, :k] = rec_meta[off:off + k]
        new_out_ptr[p] = k
        off += k

    return {
        "occ_stack": new_occ,
        "meta": new_meta,
        "sp": new_sp,
        "head": np.zeros(n_proc, i32),
        "hist": new_hist,
        "hist_snap": new_snap,
        "g_hist_acc": new_acc,
        "hist2d": new_hist2d,
        "lam": np.full(n_proc, int(carry["lam"][0]), i32),
        "t": np.full(n_proc, int(carry["t"][0]), i32),
        "stats": new_stats,
        "out_occ": new_out_occ,
        "out_meta": new_out_meta,
        "out_ptr": new_out_ptr,
        "n_sig": new_n_sig,
        "trace": np.zeros((n_proc,) + trace_shape, i32),
        "work": np.full(n_proc, int((new_sp > 0).sum()), i32),
    }
