"""Synthetic transaction databases matched to the paper's Table 1 statistics.

HapMap / Alzheimer GWAS matrices are access-controlled, so benchmarks run on
synthetic datasets that match the published (items, transactions, density,
N_pos) and contain *planted* significant itemsets so phase 3 has real signal.

The planting scheme: pick `n_planted` itemsets of size 2-4; choose a positive-
enriched occurrence pattern for each (present in a fraction of positives and a
much smaller fraction of negatives); the remaining cells are iid Bernoulli at
the target density.  Items are mildly power-law weighted so the LCM tree is
*unbalanced* — the property that breaks the naive search-space split (paper
§5.4) and motivates work stealing.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = [
    "SyntheticSpec",
    "PAPER_PROBLEMS",
    "generate",
    "generate_packed",
    "paper_problem",
    "paper_problem_packed",
]


@dataclass(frozen=True)
class SyntheticSpec:
    name: str
    n_items: int
    n_transactions: int
    density: float
    n_pos: int
    n_planted: int = 3
    planted_pos_rate: float = 0.6
    planted_neg_rate: float = 0.05
    skew: float = 1.2  # power-law exponent for per-item frequency skew
    seed: int = 0


# Table 1 of the paper, scaled where noted by benchmarks (full sizes kept here).
PAPER_PROBLEMS = {
    "hapmap_dom_10": SyntheticSpec("hapmap_dom_10", 11_253, 697, 0.0102, 105),
    "hapmap_dom_20": SyntheticSpec("hapmap_dom_20", 11_914, 697, 0.0191, 105),
    "alz_dom_5": SyntheticSpec("alz_dom_5", 44_052, 364, 0.0540, 176),
    "alz_dom_10": SyntheticSpec("alz_dom_10", 91_126, 364, 0.0978, 176),
    "alz_rec_30": SyntheticSpec("alz_rec_30", 250_120, 364, 0.0290, 176),
    "mcf7": SyntheticSpec("mcf7", 397, 12_773, 0.0294, 1_129),
}


def generate(spec: SyntheticSpec) -> tuple[np.ndarray, np.ndarray, list[list[int]]]:
    """Returns (db_bool [N, M], labels [N] bool, planted itemsets)."""
    rng = np.random.default_rng(spec.seed)
    n, m = spec.n_transactions, spec.n_items
    labels = np.zeros(n, dtype=bool)
    labels[rng.choice(n, size=spec.n_pos, replace=False)] = True

    # skewed per-item marginal frequencies with mean = density
    w = rng.pareto(spec.skew, size=m) + 1.0
    p_item = w / w.mean() * spec.density
    p_item = np.clip(p_item, 0.0, 0.95)
    db = rng.random((n, m)) < p_item[None, :]

    planted: list[list[int]] = []
    for _ in range(spec.n_planted):
        size = int(rng.integers(2, 5))
        items = rng.choice(m, size=size, replace=False).tolist()
        carrier = np.where(
            labels, rng.random(n) < spec.planted_pos_rate, rng.random(n) < spec.planted_neg_rate
        )
        for j in items:
            db[carrier, j] = True
        planted.append(sorted(items))
    return db, labels, planted


def generate_packed(
    spec: SyntheticSpec, item_chunk: int = 8192,
) -> tuple[np.ndarray, np.ndarray, list[list[int]]]:
    """`generate` straight into packed words: (db_bits [M, W] u32, labels, planted).

    The paper-scale generator (alz_rec_30: 250,120 items x 364 transactions).
    `generate` draws a dense [n, m] float64 matrix — ~728 MB for alz_rec_30 —
    before a single superstep runs; here item columns are drawn `item_chunk`
    at a time and packed immediately, so peak memory is the packed output
    (M * W * 4 bytes, ~12 MB at alz_rec_30) plus one chunk.

    Same model as `generate` (skewed marginals, planted positive-enriched
    itemsets) but a *different* random stream — the chunked draw order
    differs — so packed and dense problems of one spec are statistically
    matched, not bit-equal.  Planting ORs the carrier's packed words into
    the chosen item columns, exactly mirroring `db[carrier, j] = True`.
    """
    from repro.core.bitmap import num_words, pack_db

    rng = np.random.default_rng(spec.seed)
    n, m = spec.n_transactions, spec.n_items
    labels = np.zeros(n, dtype=bool)
    labels[rng.choice(n, size=spec.n_pos, replace=False)] = True

    w = rng.pareto(spec.skew, size=m) + 1.0
    p_item = w / w.mean() * spec.density
    p_item = np.clip(p_item, 0.0, 0.95)

    nw = num_words(n)
    db_bits = np.empty((m, nw), dtype=np.uint32)
    for lo in range(0, m, item_chunk):
        hi = min(lo + item_chunk, m)
        cols = rng.random((n, hi - lo)) < p_item[None, lo:hi]
        db_bits[lo:hi] = pack_db(cols)

    planted: list[list[int]] = []
    for _ in range(spec.n_planted):
        size = int(rng.integers(2, 5))
        items = rng.choice(m, size=size, replace=False).tolist()
        carrier = np.where(
            labels,
            rng.random(n) < spec.planted_pos_rate,
            rng.random(n) < spec.planted_neg_rate,
        )
        carrier_bits = pack_db(carrier[:, None])[0]  # [W] u32
        for j in items:
            db_bits[j] |= carrier_bits
        planted.append(sorted(items))
    db_bits.flags.writeable = False
    return db_bits, labels, planted


def paper_problem(name: str, scale_items: float = 1.0, scale_trans: float = 1.0,
                  seed: int | None = None) -> tuple[np.ndarray, np.ndarray, list[list[int]], SyntheticSpec]:
    """A (possibly scaled-down) instance of one of the paper's Table-1 problems."""
    base = PAPER_PROBLEMS[name]
    spec = SyntheticSpec(
        name=base.name,
        n_items=max(8, int(base.n_items * scale_items)),
        n_transactions=max(16, int(base.n_transactions * scale_trans)),
        density=base.density,
        n_pos=max(4, int(base.n_pos * scale_trans)),
        n_planted=base.n_planted,
        seed=base.seed if seed is None else seed,
    )
    db, labels, planted = generate(spec)
    return db, labels, planted, spec


def paper_problem_packed(
    name: str, scale_items: float = 1.0, scale_trans: float = 1.0,
    seed: int | None = None,
) -> tuple[np.ndarray, np.ndarray, list[list[int]], SyntheticSpec]:
    """`paper_problem` via the packed generator: (db_bits [M, W], labels,
    planted, spec) with no dense [n, m] intermediate — the entry for
    full-size Table-1 problems (alz_rec_30 at 250k items)."""
    base = PAPER_PROBLEMS[name]
    spec = SyntheticSpec(
        name=base.name,
        n_items=max(8, int(base.n_items * scale_items)),
        n_transactions=max(16, int(base.n_transactions * scale_trans)),
        density=base.density,
        n_pos=max(4, int(base.n_pos * scale_trans)),
        n_planted=base.n_planted,
        seed=base.seed if seed is None else seed,
    )
    db_bits, labels, planted = generate_packed(spec)
    return db_bits, labels, planted, spec
