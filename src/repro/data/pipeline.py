"""Deterministic synthetic LM data pipeline with document packing.

Documents have Pareto-skewed lengths (the unbalanced-workload property the
paper's GLB exists for — the mining engine balances the analogous skew in
subtree sizes).  Tokens come from a seeded per-document Markov chain so the
loss has learnable structure; sequences are packed end-to-end with -1 labels
masking document boundaries.

The pipeline is stateless-resumable: batch t is a pure function of
(seed, step), so a restarted job replays from its checkpoint step with no
data-state checkpointing (production pattern for deterministic streams).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    grad_accum: int = 1
    seed: int = 0
    mean_doc_len: float = 512.0
    skew: float = 1.3
    m_rope: bool = False
    embed_inputs: bool = False
    d_model: int = 0


def _doc(rng: np.random.Generator, length: int, vocab: int) -> np.ndarray:
    """Order-1 Markov doc: token t+1 = (a * t + drift) % vocab with noise."""
    a = int(rng.integers(3, 17)) | 1
    drift = int(rng.integers(1, vocab - 1))
    noise = rng.integers(0, vocab, size=length)
    mask = rng.random(length) < 0.15
    toks = np.empty(length, dtype=np.int64)
    toks[0] = rng.integers(0, vocab)
    for i in range(1, length):
        toks[i] = (a * toks[i - 1] + drift) % vocab
    toks[mask] = noise[mask]
    return toks


def _packed_sequence(cfg: DataConfig, rng: np.random.Generator):
    toks = np.empty(cfg.seq_len + 1, dtype=np.int64)
    labels_mask = np.ones(cfg.seq_len + 1, dtype=bool)
    pos = 0
    while pos < cfg.seq_len + 1:
        ln = int(min((rng.pareto(cfg.skew) + 1.0) * cfg.mean_doc_len / 2.0,
                     cfg.seq_len + 1 - pos))
        ln = max(ln, 8) if pos + 8 <= cfg.seq_len + 1 else cfg.seq_len + 1 - pos
        toks[pos : pos + ln] = _doc(rng, ln, cfg.vocab)
        if pos:
            labels_mask[pos] = False  # don't predict across doc boundary
        pos += ln
    return toks, labels_mask


def make_batch(cfg: DataConfig, step: int):
    """Returns {"inputs", "labels", "positions"} shaped [A, GB/A, S(...)]. """
    rng = np.random.default_rng((cfg.seed, step))
    a, mb, s = cfg.grad_accum, cfg.global_batch // cfg.grad_accum, cfg.seq_len
    inputs = np.empty((cfg.global_batch, s), dtype=np.int32)
    labels = np.empty((cfg.global_batch, s), dtype=np.int32)
    for i in range(cfg.global_batch):
        toks, lm = _packed_sequence(cfg, rng)
        inputs[i] = toks[:-1]
        lab = toks[1:].copy()
        lab[~lm[1:]] = -1
        labels[i] = lab
    positions = np.broadcast_to(np.arange(s, dtype=np.int32), (cfg.global_batch, s))
    if cfg.m_rope:
        positions = np.repeat(positions[..., None], 3, axis=-1)
    batch = {
        "inputs": inputs.reshape(a, mb, s),
        "labels": labels.reshape(a, mb, s),
        "positions": np.ascontiguousarray(positions.reshape((a, mb, s) + positions.shape[2:])),
    }
    if cfg.embed_inputs:
        # modality-frontend stub: deterministic pseudo-embeddings from token ids
        emb_rng = np.random.default_rng((cfg.seed, step, 7))
        proj = emb_rng.standard_normal((cfg.vocab, cfg.d_model)).astype(np.float32)
        batch["inputs"] = proj[inputs.reshape(-1)].reshape(a, mb, s, cfg.d_model)
    return batch
