"""Deterministic fault injection for the fault-tolerance machinery.

Production code calls `faults.check("<point>", **ctx)` at the places a real
deployment can die (the fault-point catalog, DESIGN.md §11):

  engine.superstep      host segment boundary, before the checkpoint write
                        (a kill here loses the running segment's progress)
  ckpt.pre_publish      checkpoint fully staged in the tmp dir, not yet
                        renamed in (a kill here must leave the previous
                        step intact and restorable)
  ckpt.published        checkpoint renamed into place (the corrupt-step
                        fault point flips bytes in the published payload
                        here, exercising checksum detection + fallback)
  serve.attempt         a fleet worker about to run one served request
                        (a death here must be retried, never dropped)

With no plan installed `check` is a near-free no-op, so the hooks cost
nothing in production.  A `FaultPlan` is installed process-globally
(`install`/`clear`, or the `injected` context manager); counters are
lock-guarded because serve faults fire on fleet worker threads.  Every
fault is deterministic — same plan, same sequence of `check` calls, same
failure — which is what lets the kill-and-resume tests assert bit-identical
recovery.
"""

from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass

__all__ = [
    "FaultPlan",
    "SimulatedFault",
    "check",
    "clear",
    "corrupt_step_dir",
    "injected",
    "install",
]


class SimulatedFault(RuntimeError):
    """An injected failure (never raised unless a FaultPlan is installed)."""

    def __init__(self, point: str, detail: str = ""):
        self.point = point
        super().__init__(f"simulated fault at {point}" +
                         (f": {detail}" if detail else ""))


@dataclass(frozen=True)
class FaultPlan:
    """What to break, deterministically.

    die_at_superstep      raise at the engine segment boundary whose
                          superstep counter t >= this value (-1 = never)
    die_after_segments    raise at the N-th engine segment boundary counted
                          globally across phases (-1 = never) — use this to
                          land a death in phase 2/3 of a staging, where the
                          per-phase t has reset
    die_in_ckpt_write     raise between staging a checkpoint and publishing
                          it (the crash-window test; -1 = never, else the
                          N-th write, 0-based)
    corrupt_after_step    after publishing step N, flip bytes in its
                          arrays.npz (checksum-detection test; -1 = never)
    serve_fail_first_n    fail the first N served attempts, globally across
                          workers (0 = never)
    seed                  byte-flip determinism for corrupt_step_dir
    """

    die_at_superstep: int = -1
    die_after_segments: int = -1
    die_in_ckpt_write: int = -1
    corrupt_after_step: int = -1
    serve_fail_first_n: int = 0
    seed: int = 0


_lock = threading.Lock()
_ACTIVE: FaultPlan | None = None
_counters: dict[str, int] = {}


def install(plan: FaultPlan) -> None:
    """Install `plan` process-globally (replacing any previous plan)."""
    global _ACTIVE
    with _lock:
        _ACTIVE = plan
        _counters.clear()


def clear() -> None:
    """Remove the active plan; `check` becomes a no-op again."""
    global _ACTIVE
    with _lock:
        _ACTIVE = None
        _counters.clear()


@contextlib.contextmanager
def injected(plan: FaultPlan):
    """`with injected(FaultPlan(...)):` — install for the block, then clear."""
    install(plan)
    try:
        yield plan
    finally:
        clear()


def _bump(key: str) -> int:
    """Increment and return the pre-increment value of a named counter."""
    with _lock:
        n = _counters.get(key, 0)
        _counters[key] = n + 1
        return n


def check(point: str, **ctx) -> None:
    """Raise SimulatedFault if the active plan targets this fault point."""
    plan = _ACTIVE
    if plan is None:
        return
    if point == "engine.superstep":
        seg = _bump("engine.superstep")
        t = int(ctx.get("t", -1))
        if plan.die_after_segments >= 0 and seg >= plan.die_after_segments:
            raise SimulatedFault(point, f"segment {seg} (t={t})")
        if plan.die_at_superstep >= 0 and t >= plan.die_at_superstep:
            raise SimulatedFault(point, f"t={t}")
    elif point == "ckpt.pre_publish":
        if plan.die_in_ckpt_write >= 0 and \
                _bump("ckpt.write") == plan.die_in_ckpt_write:
            raise SimulatedFault(point, f"step={ctx.get('step')}")
    elif point == "ckpt.published":
        if plan.corrupt_after_step >= 0 and \
                int(ctx.get("step", -1)) == plan.corrupt_after_step:
            corrupt_step_dir(str(ctx["path"]), plan.seed)
    elif point == "serve.attempt":
        if _bump("serve.attempt") < plan.serve_fail_first_n:
            raise SimulatedFault(
                point, f"rid={ctx.get('rid')} worker={ctx.get('worker')}")


def corrupt_step_dir(path: str, seed: int = 0) -> None:
    """Deterministically flip bytes in a published step dir's arrays.npz.

    Flips land in the back half of the file (the zip payload region for the
    uncompressed npz format), so the corruption models bit rot in array
    data rather than a torn directory — exactly what the per-leaf checksums
    exist to catch.
    """
    import os
    import random

    target = os.path.join(path, "arrays.npz")
    with open(target, "r+b") as f:
        f.seek(0, os.SEEK_END)
        size = f.tell()
        rng = random.Random(seed)
        for _ in range(8):
            pos = rng.randrange(size // 2, size)
            f.seek(pos)
            byte = f.read(1)
            f.seek(pos)
            f.write(bytes([byte[0] ^ 0xFF]))
