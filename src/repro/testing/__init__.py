"""Test-support machinery shipped with the library (not the test suite).

`repro.testing.faults` is the deterministic fault-injection plane used by
the fault-tolerance tests, the CI kill-and-resume smoke, and
`examples/fault_tolerant_mining.py` (DESIGN.md §11).
"""

from .faults import (
    FaultPlan,
    SimulatedFault,
    check,
    clear,
    corrupt_step_dir,
    injected,
    install,
)

__all__ = [
    "FaultPlan",
    "SimulatedFault",
    "check",
    "clear",
    "corrupt_step_dir",
    "injected",
    "install",
]
