"""ResultSet — the materialized output of a significant-pattern mining run.

The engine's histograms answer "how many patterns are significant"; this
module answers "*which* patterns" (the paper's actual §5.6 deliverable).
`build_result_set` turns the emitted device records into a `ResultSet`:

  gather (done in engine.mine) -> closure reconstruction (reconstruct.py)
  -> dedup by closure -> exact float64 P-values (the registered
  `repro.stats` statistic that gated emission) + Bonferroni q-values
  -> sort by P-value.  With statistic=None (closed-frequent queries)
  patterns stay untested — NaN P/q, sorted by support.

Two filtering regimes (DESIGN.md §4):

  * mode="test" records were already filtered at delta on device — pass
    ``filter_host=False`` and every record is kept (the device decision *is*
    the result, so counts stay consistent with MineOutput.sig_count).
  * mode="count2d" records are the alpha-level superset — pass
    ``filter_host=True`` and the host keeps exactly those with exact
    P <= delta, reproducing the fused pipeline's histogram-derived count.

Streaming (DESIGN.md §10): pass a `ResultStream` and the builder processes
records in significance order — P-values need only (sup, pos_sup), so they
are computed for every record *before* any closure reconstruction — and
invokes `on_head` with the final top-`head_k` patterns as soon as that head
is provably complete (every unreconstructed record sorts strictly after the
k-th), while the rest of the reconstruction is still running.  The streamed
head is guaranteed equal to ``result.patterns[:head_k]`` of the returned
ResultSet, which is itself bit-identical to the non-streaming build.
"""

from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro.stats import get_statistic

from .reconstruct import dedup_by_closure, reconstruct_closures

__all__ = ["Pattern", "ResultSet", "ResultStream", "build_result_set"]

TSV_COLUMNS = ("rank", "items", "size", "support", "pos_support", "pvalue", "qvalue")


@dataclass(frozen=True)
class ResultStream:
    """Incremental top-k delivery from `build_result_set` (DESIGN.md §10).

    `on_head` is invoked exactly once per build, from the building thread,
    with the final ``patterns[:head_k]`` — as soon as the head is provably
    complete, which is typically long before the full record set has been
    reconstructed (P-values are cheap margin arithmetic; closure
    reconstruction is the popcount-GEMM that dominates).  `chunk` is the
    number of records reconstructed between finality checks.
    """

    head_k: int
    on_head: Callable[[list["Pattern"]], None]
    chunk: int = 256

    def __post_init__(self):
        if not (isinstance(self.head_k, int) and self.head_k >= 1):
            raise ValueError(
                f"ResultStream.head_k must be an int >= 1, got {self.head_k!r}"
            )
        if not (isinstance(self.chunk, int) and self.chunk >= 1):
            raise ValueError(
                f"ResultStream.chunk must be an int >= 1, got {self.chunk!r}"
            )


@dataclass(frozen=True)
class Pattern:
    """One mined closed itemset with its exact test statistics.

    Untested patterns (closed-frequent queries: statistic=None) carry NaN
    P/q-values; exports map them to null.
    """

    items: tuple[int, ...]      # the closure, sorted item ids
    support: int                # x(I): transactions containing the itemset
    pos_support: int            # n(I): positive transactions containing it
    pvalue: float               # exact one-sided P (float64, host); NaN = untested
    qvalue: float               # Bonferroni-adjusted: min(1, P * k); NaN = untested

    def as_dict(self) -> dict:
        return {
            "items": list(self.items),
            "support": int(self.support),
            "pos_support": int(self.pos_support),
            "pvalue": None if math.isnan(self.pvalue) else float(self.pvalue),
            "qvalue": None if math.isnan(self.qvalue) else float(self.qvalue),
        }


@dataclass
class ResultSet:
    """Significant patterns plus the run's testing context, export-ready."""

    patterns: list[Pattern] = field(default_factory=list)  # sorted by pvalue
    n_transactions: int = 0
    n_pos: int = 0
    alpha: float = 0.05
    min_sup: int = 1
    correction_factor: int = 1   # k: number of testable (closed) patterns
    delta: float = 0.05          # alpha / k, the corrected level
    n_dropped: int = 0           # device emissions lost to out_cap saturation
    item_names: tuple[str, ...] | None = None  # column id -> display name
    statistic: str | None = "fisher"  # registered test; None = untested (frequent)
    #: True when the mine stopped at a soft deadline before draining its
    #: frontier (DESIGN.md §11): patterns cover only the explored region
    truncated: bool = False

    @property
    def complete(self) -> bool:
        """False when the pattern list is a subset: out_cap overflowed
        (n_dropped) or the mine stopped early at a soft deadline
        (truncated)."""
        return self.n_dropped == 0 and not self.truncated

    def names_of(self, pattern: Pattern) -> list[str]:
        """Display names of a pattern's items (falls back to the indices)."""
        if self.item_names is None:
            return [str(j) for j in pattern.items]
        return [self.item_names[j] for j in pattern.items]

    def __len__(self) -> int:
        return len(self.patterns)

    def __iter__(self):
        return iter(self.patterns)

    def top(self, k: int | None = None) -> list[Pattern]:
        """The k most significant patterns (all when k is None)."""
        return self.patterns[:k] if k is not None else list(self.patterns)

    def describe(self, top_k: int | None = 10, planted=None) -> str:
        """Human-readable top-k summary — the one formatter the CLI and
        examples share, so pattern-line wording never drifts between them."""
        shown = min(top_k, len(self)) if top_k is not None else len(self)
        kind = "significant" if self.statistic is not None else "closed frequent"
        lines = [
            f"top {shown} of {len(self)} {kind} patterns"
            + ("" if self.complete else "  [INCOMPLETE: "
               + ("partial mine" if self.truncated
                  else f"{self.n_dropped} dropped") + "]")
        ]
        for rank, p in enumerate(self.top(top_k), start=1):
            shown = "[" + ", ".join(self.names_of(p)) + "]"
            line = (f" {rank:3d}  items={shown}  sup={p.support} "
                    f"pos={p.pos_support}")
            if not math.isnan(p.pvalue):
                line += f"  p={p.pvalue:.3e}  q={p.qvalue:.3e}"
            lines.append(line)
        if planted is not None:
            from .scoring import score_planted

            s = score_planted(self, planted)
            lines.append(
                f"planted-signal recovery: {len(s['recovered'])}/{s['n_planted']} "
                f"(recall {s['recall']:.2f}, precision {s['precision']:.2f})"
            )
        return "\n".join(lines)

    # ------------------------------------------------------------- export
    def to_tsv(self, path: str | None = None, top_k: int | None = None) -> str:
        # the `items` column stays raw column indices (machine-readable);
        # a trailing `names` column is appended when the dataset named them
        cols = TSV_COLUMNS + (("names",) if self.item_names else ())
        lines = ["\t".join(cols)]

        def fmt(v):  # untested (NaN) values export as empty cells, not "nan"
            return "" if math.isnan(v) else f"{v:.6e}"

        for rank, p in enumerate(self.top(top_k), start=1):
            row = (
                f"{rank}\t{','.join(map(str, p.items))}\t{len(p.items)}\t"
                f"{p.support}\t{p.pos_support}\t{fmt(p.pvalue)}\t{fmt(p.qvalue)}"
            )
            if self.item_names:
                row += "\t" + ",".join(self.names_of(p))
            lines.append(row)
        text = "\n".join(lines) + "\n"
        if path:
            with open(path, "w") as f:
                f.write(text)
        return text

    def to_json(self, path: str | None = None, top_k: int | None = None) -> str:
        def pattern_dict(p: Pattern) -> dict:
            d = p.as_dict()   # "items" stays indices — machine-readable
            if self.item_names:
                d["names"] = self.names_of(p)
            return d

        def nan_null(v):  # NaN is not valid JSON; untested runs export null
            return None if isinstance(v, float) and math.isnan(v) else v

        payload = {
            "n_transactions": self.n_transactions,
            "n_pos": self.n_pos,
            "statistic": self.statistic,
            "alpha": nan_null(self.alpha),
            "min_sup": self.min_sup,
            "correction_factor": self.correction_factor,
            "delta": nan_null(self.delta),
            "n_patterns": len(self.patterns),
            "complete": self.complete,
            "n_dropped": self.n_dropped,
            "patterns": [pattern_dict(p) for p in self.top(top_k)],
        }
        text = json.dumps(payload, indent=1)
        if path:
            with open(path, "w") as f:
                f.write(text)
        return text

    def save(self, path: str, top_k: int | None = None) -> None:
        """Write TSV or JSON by file extension (.tsv/.txt vs .json)."""
        if path.endswith(".json"):
            self.to_json(path, top_k)
        else:
            self.to_tsv(path, top_k)


def build_result_set(
    occ: np.ndarray,
    sup: np.ndarray,
    pos_sup: np.ndarray,
    db_bits: np.ndarray,
    *,
    n: int,
    n_pos: int,
    alpha: float,
    min_sup: int,
    correction_factor: int,
    delta: float,
    filter_host: bool = False,
    dropped: int = 0,
    item_names: tuple[str, ...] | None = None,
    statistic: str | None = "fisher",
    stream: ResultStream | None = None,
) -> ResultSet:
    """Emitted records -> deduped, exactly-(re)tested, sorted ResultSet.

    `statistic` names the registered test used for the exact host P-values
    (it must match the device test that emitted the records); None skips
    testing entirely — patterns carry NaN P/q and sort by support (the
    closed-frequent objective).  `stream` delivers the top-`head_k` head to
    a callback mid-build (see `ResultStream`); the returned ResultSet is
    identical either way.
    """
    occ = np.asarray(occ, dtype=np.uint32).reshape(-1, db_bits.shape[1])
    sup = np.asarray(sup, dtype=np.int64).reshape(-1)
    pos_sup = np.asarray(pos_sup, dtype=np.int64).reshape(-1)

    k = max(int(correction_factor), 1)
    if stream is not None:
        patterns = _build_patterns_streaming(
            occ, sup, pos_sup, db_bits, n=n, n_pos=n_pos, k=k, delta=delta,
            filter_host=filter_host, statistic=statistic, stream=stream,
        )
        return ResultSet(
            patterns=patterns,
            n_transactions=n,
            n_pos=n_pos,
            alpha=alpha,
            min_sup=min_sup,
            correction_factor=int(correction_factor),
            delta=delta,
            n_dropped=int(dropped),
            item_names=tuple(item_names) if item_names is not None else None,
            statistic=statistic,
        )

    closures = reconstruct_closures(occ, sup, db_bits)
    closures, sup, pos_sup = dedup_by_closure(closures, sup, pos_sup)

    patterns = []
    if len(closures) and statistic is None:
        for i in range(len(closures)):
            patterns.append(Pattern(
                items=closures[i],
                support=int(sup[i]),
                pos_support=int(pos_sup[i]),
                pvalue=float("nan"),
                qvalue=float("nan"),
            ))
    elif len(closures):
        pvals = get_statistic(statistic).pvalue(sup, pos_sup, n, n_pos)
        keep = pvals <= delta if filter_host else np.ones(len(closures), bool)
        for i in np.flatnonzero(keep):
            p = float(pvals[i])
            patterns.append(Pattern(
                items=closures[i],
                support=int(sup[i]),
                pos_support=int(pos_sup[i]),
                pvalue=p,
                qvalue=min(1.0, p * k),
            ))

    # The root closed set (closure of the empty itemset) never rides the
    # device buffers, so it only appears here if the caller appended its
    # record to the inputs.  Under Fisher it never qualifies (its one-sided
    # P-value is exactly 1 — support n covers all n_pos positives by the
    # margins — and delta = alpha/k < 1 always); other statistics can make
    # it significant (chi2's root P is 0.5), and the session pipelines /
    # ClosedFrequentQuery append it exactly when their host-side root count
    # does, keeping the pattern list consistent with n_significant.

    patterns.sort(key=_sort_key(statistic))
    return ResultSet(
        patterns=patterns,
        n_transactions=n,
        n_pos=n_pos,
        alpha=alpha,
        min_sup=min_sup,
        correction_factor=int(correction_factor),
        delta=delta,
        n_dropped=int(dropped),
        item_names=tuple(item_names) if item_names is not None else None,
        statistic=statistic,
    )


def _sort_key(statistic: str | None):
    """The one canonical pattern ordering (streaming finality depends on it:
    the partial key (pvalue, -support) must be a prefix of this full key)."""
    if statistic is None:
        return lambda p: (-p.support, p.items)
    return lambda p: (p.pvalue, -p.support, p.items)


def _build_patterns_streaming(
    occ, sup, pos_sup, db_bits, *, n, n_pos, k, delta, filter_host,
    statistic, stream: ResultStream,
) -> list[Pattern]:
    """Reconstruct records in significance order, stream the head early.

    P-values depend only on the margins (sup, pos_sup, n, n_pos), so every
    record is tested *before* any reconstruction; records are then
    reconstructed most-significant-first in `stream.chunk` batches.  Two
    records with the same closure are exact duplicates (the closure fixes
    occ, hence sup/pos_sup/P), so incremental dedup keeps content identical
    to the batch path's first-in-emission-order dedup.  The head is final
    once the next unreconstructed record's (pvalue, -support) key sorts
    strictly after the current k-th pattern's — the items tie-break can
    only reorder *within* an equal (pvalue, -support) class.
    """
    n_rec = len(sup)
    full_key = _sort_key(statistic)
    if statistic is None:
        pvals = None
        idx = np.arange(n_rec)
        order = idx[np.lexsort((idx, -sup))] if n_rec else idx
        partial = lambda j: (-int(sup[j]),)                    # noqa: E731
        partial_p = lambda p: (-p.support,)                    # noqa: E731
    else:
        pvals = (get_statistic(statistic).pvalue(sup, pos_sup, n, n_pos)
                 if n_rec else np.zeros(0))
        idx = np.flatnonzero(pvals <= delta) if filter_host else np.arange(n_rec)
        order = (idx[np.lexsort((idx, -sup[idx], pvals[idx]))]
                 if len(idx) else idx)
        partial = lambda j: (float(pvals[j]), -int(sup[j]))    # noqa: E731
        partial_p = lambda p: (p.pvalue, -p.support)           # noqa: E731

    seen: set[tuple[int, ...]] = set()
    patterns: list[Pattern] = []
    head_sent = False
    for lo in range(0, max(len(order), 1), stream.chunk):
        sel = order[lo:lo + stream.chunk]
        closures = reconstruct_closures(occ[sel], sup[sel], db_bits)
        for j, c in zip(sel, closures):
            if c in seen:
                continue
            seen.add(c)
            if pvals is None:
                p = q = float("nan")
            else:
                p = float(pvals[j])
                q = min(1.0, p * k)
            patterns.append(Pattern(
                items=c, support=int(sup[j]), pos_support=int(pos_sup[j]),
                pvalue=p, qvalue=q,
            ))
        if head_sent:
            continue
        patterns.sort(key=full_key)
        nxt = lo + stream.chunk
        if nxt >= len(order):
            head_sent = True   # everything reconstructed: the head is final
        elif (len(patterns) >= stream.head_k
              and partial(order[nxt]) > partial_p(patterns[stream.head_k - 1])):
            head_sent = True
        if head_sent:
            stream.on_head(patterns[: stream.head_k])
    patterns.sort(key=full_key)
    return patterns
