"""Host-side closure reconstruction from emitted pattern records (DESIGN.md §4).

The engine emits fixed-size records — an occurrence bitmap `occ [W]u32` plus
(core, sup, pos_sup) — not itemsets: itemset identity is *derived* state, and
shipping variable-length item lists through the compiled superstep would break
the dense fixed-payload collectives the whole engine is built on.  The closure
is recovered on the host with the same popcount-GEMM used everywhere else:

    item j  is in  clo(occ)   <=>   |occ & db_bits[j]| == |occ| == sup

i.e. the closure is exactly the set of items whose column support under `occ`
equals the pattern's support.  This is the standard closed-itemset identity
(LCM's clo() operator) evaluated in bulk over all emitted records.
"""

from __future__ import annotations

import numpy as np

__all__ = ["reconstruct_closures", "dedup_by_closure"]


def reconstruct_closures(
    occ: np.ndarray, sup: np.ndarray, db_bits: np.ndarray, chunk: int = 512,
) -> list[tuple[int, ...]]:
    """[K, W] occurrence bitmaps + [K] supports -> K closure itemsets.

    Routed through the support-count dispatch point (DESIGN.md §8), which
    tiles the item axis internally — at GWAS scale (250k items) the old
    in-place numpy contraction materialized a [chunk, M, W] intermediate of
    several GB per chunk; the tiled op's working set is [chunk, m_tile].
    Chunked over records so the [chunk, M] *output* stays small too.
    """
    from repro.kernels.support_count.ops import support_counts

    occ = np.asarray(occ, dtype=np.uint32)
    sup = np.asarray(sup)
    k = occ.shape[0]
    out: list[tuple[int, ...]] = []
    for lo in range(0, k, chunk):
        hi = min(lo + chunk, k)
        s = np.asarray(support_counts(occ[lo:hi], db_bits))  # [chunk, M]
        in_clo = s == sup[lo:hi, None]
        for r in range(hi - lo):
            out.append(tuple(np.flatnonzero(in_clo[r]).tolist()))
    return out


def dedup_by_closure(closures, *fields):
    """Keep the first record of every distinct closure.

    closures: list of item tuples; fields: parallel arrays/lists to subset.
    Returns (closures, *fields) with duplicates removed, order preserved.
    Closure-duplicate records are expected only across pipeline stages (e.g.
    the root added host-side) — within one traversal each closed set is
    enumerated exactly once — but dedup here makes the result set robust to
    any future emission source.
    """
    seen: set[tuple[int, ...]] = set()
    keep: list[int] = []
    for i, c in enumerate(closures):
        if c not in seen:
            seen.add(c)
            keep.append(i)
    kept_closures = [closures[i] for i in keep]
    kept_fields = tuple(np.asarray(f)[keep] for f in fields)
    return (kept_closures, *kept_fields)
