"""Planted-pattern recovery scoring (the §5.6 sanity check, automated).

`data/synthetic.py` plants positive-enriched itemsets into its case-control
matrices; a correct end-to-end run must rediscover them.  A planted itemset
counts as *recovered* when some mined pattern's closure contains it — the
closure of a planted set usually picks up the planted items plus any items
that co-occur by construction, so subset containment (not equality) is the
right match criterion (benchmarks/mining_suite.py uses the same rule).
"""

from __future__ import annotations

from typing import Iterable, Sequence

__all__ = ["score_planted"]


def score_planted(patterns: Iterable, planted: Sequence[Sequence[int]]) -> dict:
    """Precision/recall of mined patterns against the planted ground truth.

    patterns: an iterable of Pattern (or anything with .items) — pass a
    ResultSet directly.  planted: list of item-id lists from generate().

    recall     = fraction of planted itemsets contained in >= 1 mined pattern
    precision  = fraction of mined patterns containing >= 1 planted itemset
                 (the rest are statistically significant background discoveries,
                 not necessarily errors — synthetic noise can be significant)
    """
    mined = [set(p.items) for p in patterns]
    planted_sets = [set(pl) for pl in planted]

    recovered = [sorted(pl) for pl in planted_sets
                 if any(pl <= s for s in mined)]
    missed = [sorted(pl) for pl in planted_sets
              if not any(pl <= s for s in mined)]
    matched = sum(1 for s in mined if any(pl <= s for pl in planted_sets))

    return {
        "n_planted": len(planted_sets),
        "n_mined": len(mined),
        "recovered": recovered,
        "missed": missed,
        "recall": len(recovered) / len(planted_sets) if planted_sets else 1.0,
        "precision": matched / len(mined) if mined else 0.0,
    }
