"""Pattern emission results subsystem (DESIGN.md §4).

Turns the engine's device-side pattern records (occurrence bitmap + core +
sup + pos_sup) into the run's actual deliverable: the identified significant
itemsets with exact statistics, ready for top-k selection, export, and
planted-signal scoring.
"""

from .reconstruct import dedup_by_closure, reconstruct_closures
from .resultset import Pattern, ResultSet, ResultStream, build_result_set
from .scoring import score_planted

__all__ = [
    "Pattern",
    "ResultSet",
    "ResultStream",
    "build_result_set",
    "dedup_by_closure",
    "reconstruct_closures",
    "score_planted",
]
