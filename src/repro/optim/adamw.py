"""AdamW with global-norm clipping, cosine schedule, and optional int8
error-feedback gradient compression — built from scratch (no optax).

Optimizer state mirrors the parameter pytree, so NamedSharding specs for
params apply verbatim to m/v (ZeRO-style: params are already FSDP-sharded
over the 'data' axis, so the moments are too).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    compress: bool = False  # int8 error-feedback gradient compression


def schedule(cfg: AdamWConfig, step):
    step = step.astype(F32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_state(cfg: AdamWConfig, params):
    state = {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params),
        "step": jnp.zeros((), jnp.int32),
    }
    if cfg.compress:
        state["ef"] = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
    return state


def clip_by_global_norm(grads, max_norm):
    gn = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(F32))) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(F32) * scale), grads), gn


def _quantize_int8(x):
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads(grads, ef):
    """int8 error-feedback compression (1-bit-Adam style memory).

    On a real multi-pod deployment this wraps the cross-pod reduce-scatter
    (compressed payload over the slow inter-pod links); here it is applied to
    the already-reduced gradient with identical convergence semantics.
    """

    def one(g, e):
        x = g.astype(F32) + e
        q, scale = _quantize_int8(x)
        deq = q.astype(F32) * scale
        return deq, x - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), tdef.unflatten([o[1] for o in out])


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    if cfg.compress:
        grads, new_ef = compress_grads(grads, state["ef"])
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(F32)
    b2c = 1.0 - cfg.b2 ** step.astype(F32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        new_p = p.astype(F32) - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                                      + cfg.weight_decay * p.astype(F32))
        return new_p.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_state = {
        "m": tdef.unflatten([o[1] for o in out]),
        "v": tdef.unflatten([o[2] for o in out]),
        "step": step,
    }
    if cfg.compress:
        new_state["ef"] = new_ef
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
