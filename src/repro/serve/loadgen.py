"""Load generation against a MiningService (DESIGN.md §10).

Two standard serving-benchmark modes:

  * **open loop** — Poisson arrivals at a target offered qps, submitted
    regardless of completion (the honest tail-latency measurement: queue
    growth, admission rejections and timeouts all show up instead of the
    closed-loop coordinated-omission artifact);
  * **closed loop** — `concurrency` clients, each submitting its next
    query the moment the previous resolves (the throughput ceiling
    measurement).

Work items are *pre-built* `(dataset, query)` pairs: dataset construction
and packing is client-side work and must not pollute service latency.
Both runners cycle the item list when asked for more requests than items.
"""

from __future__ import annotations

import asyncio
import random
import time
from dataclasses import dataclass, field

from .request import AdmissionError
from .stats_util import latency_summary

__all__ = ["LoadReport", "run_closed_loop", "run_open_loop"]


@dataclass
class LoadReport:
    """What one load-generation run measured."""

    mode: str                       # "open" | "closed"
    offered_qps: float | None       # open loop: the arrival-rate target
    concurrency: int | None         # closed loop: in-flight clients
    n_requests: int = 0             # arrivals (admitted + rejected)
    n_ok: int = 0
    n_partial: int = 0              # soft-deadline truncated reports
    n_rejected: int = 0             # AdmissionError at submit
    n_timeout: int = 0
    n_cancelled: int = 0
    n_error: int = 0
    n_retried: int = 0              # resolved requests that took > 1 attempt
    duration_s: float = 0.0         # first arrival -> last resolution
    latencies_s: list = field(default_factory=list)   # ok requests only
    queue_s: list = field(default_factory=list)       # ok time-in-queue
    depth_samples: list = field(default_factory=list)  # queue depth/arrival
    cold_ok: int = 0                # ok requests that compiled something

    @property
    def achieved_qps(self) -> float:
        return self.n_ok / self.duration_s if self.duration_s > 0 else 0.0

    @property
    def rejection_rate(self) -> float:
        return self.n_rejected / self.n_requests if self.n_requests else 0.0

    def as_dict(self) -> dict:
        d = {
            "mode": self.mode,
            "offered_qps": self.offered_qps,
            "concurrency": self.concurrency,
            "n_requests": self.n_requests,
            "n_ok": self.n_ok,
            "n_partial": self.n_partial,
            "n_rejected": self.n_rejected,
            "n_timeout": self.n_timeout,
            "n_cancelled": self.n_cancelled,
            "n_error": self.n_error,
            "n_retried": self.n_retried,
            "duration_s": round(self.duration_s, 3),
            "achieved_qps": round(self.achieved_qps, 3),
            "rejection_rate": round(self.rejection_rate, 4),
            "cold_ok": self.cold_ok,
        }
        d.update(latency_summary(self.latencies_s, prefix="latency_"))
        d.update(latency_summary(self.queue_s, prefix="queue_"))
        if self.depth_samples:
            d["depth_mean"] = round(
                sum(self.depth_samples) / len(self.depth_samples), 2)
            d["depth_max"] = max(self.depth_samples)
        return d

    def _absorb(self, result) -> None:
        if getattr(result, "attempts", 1) > 1:
            self.n_retried += 1
        if result.ok:
            self.n_ok += 1
            self.latencies_s.append(result.total_s)
            self.queue_s.append(result.queued_s)
            if result.report is not None and result.report.cold:
                self.cold_ok += 1
        elif result.outcome == "partial":
            self.n_partial += 1  # a real (truncated) report, not a failure
        elif result.outcome == "timeout":
            self.n_timeout += 1
        elif result.outcome == "cancelled":
            self.n_cancelled += 1
        else:
            self.n_error += 1


async def run_open_loop(service, work, *, qps: float, n_requests: int,
                        seed: int = 0, timeout_s: float | None = None,
                        client: str = "loadgen") -> LoadReport:
    """Fire `n_requests` Poisson arrivals at `qps` against `service`.

    Arrivals never wait for completions; rejected submissions are counted
    and dropped (the open-loop clock keeps ticking).  `work` is a sequence
    of pre-built (dataset, query) pairs, cycled.
    """
    if qps <= 0:
        raise ValueError(f"qps must be positive, got {qps}")
    if not work:
        raise ValueError("run_open_loop needs at least one work item")
    rng = random.Random(seed)
    report = LoadReport(mode="open", offered_qps=qps, concurrency=None)
    pending = []
    t0 = time.perf_counter()
    due = t0
    for i in range(n_requests):
        delay = due - time.perf_counter()
        if delay > 0:
            await asyncio.sleep(delay)
        due += rng.expovariate(qps)
        dataset, query = work[i % len(work)]
        report.n_requests += 1
        report.depth_samples.append(service.depth)
        try:
            req = service.submit(dataset, query, timeout_s=timeout_s,
                                 client=f"{client}-{i}")
        except AdmissionError:
            report.n_rejected += 1
            continue
        pending.append(req.future)
    for result in await asyncio.gather(*pending):
        report._absorb(result)
    report.duration_s = time.perf_counter() - t0
    return report


async def run_closed_loop(service, work, *, concurrency: int,
                          n_requests: int, timeout_s: float | None = None,
                          client: str = "loadgen") -> LoadReport:
    """`concurrency` always-busy clients issuing `n_requests` total."""
    if concurrency < 1:
        raise ValueError(f"concurrency must be >= 1, got {concurrency}")
    if not work:
        raise ValueError("run_closed_loop needs at least one work item")
    report = LoadReport(mode="closed", offered_qps=None,
                        concurrency=concurrency)
    counter = iter(range(n_requests))
    t0 = time.perf_counter()

    async def _client(cid: int) -> None:
        for i in counter:
            dataset, query = work[i % len(work)]
            report.n_requests += 1
            report.depth_samples.append(service.depth)
            try:
                result = await service.mine(
                    dataset, query, timeout_s=timeout_s,
                    client=f"{client}-c{cid}",
                )
            except AdmissionError:
                report.n_rejected += 1
                continue
            report._absorb(result)

    await asyncio.gather(*[_client(c) for c in range(concurrency)])
    report.duration_s = time.perf_counter() - t0
    return report
