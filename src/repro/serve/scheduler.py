"""The serving scheduler: admission, queueing, dispatch (DESIGN.md §10).

One asyncio event loop owns a bounded FIFO request queue in front of the
session fleet:

  * **admission control** — `submit` raises `AdmissionError("queue_full")`
    the moment the queue is at capacity (callers see backpressure as a
    typed rejection, not unbounded latency) and
    `AdmissionError("shutting_down")` after `stop()`;
  * **deadlines** — a per-request timeout arms a loop timer; expiry while
    queued resolves the request as a timeout and removes it (it never
    touches a device), and `try_start`'s re-check catches deadlines that
    lapse between timer granularity and dispatch;
  * **cancellation** — `cancel(request)` terminates a *queued* request;
    running requests are not interruptible (BSP supersteps);
  * **dispatch** — the dispatcher awaits an idle worker chosen by warm-
    program/residency affinity for the queue head, coalesces the head's
    same-signature run (serve.batch) and drains it on the worker's thread,
    so the loop keeps admitting while miners mine;
  * **backpressure signal** — `backpressure` in [0, 1] is queue depth over
    capacity; it is also exported as a gauge so clients and load
    generators can shed before admission starts rejecting.

`MiningService` is the facade gluing one fleet + one scheduler + one
shared `MetricsRegistry` into the thing launchers and benchmarks start.
"""

from __future__ import annotations

import asyncio
from collections import deque
from dataclasses import dataclass

from repro.obs import MetricsRegistry
from repro.results import ResultStream

from .batch import collect_batch, program_signature, run_batch
from .fleet import SessionFleet
from .request import AdmissionError, ServeRequest, ServeResult

__all__ = ["MiningService", "Scheduler", "ServeConfig"]


@dataclass(frozen=True)
class ServeConfig:
    """Scheduler policy knobs."""

    queue_capacity: int = 64       # admission bound (requests, not batches)
    max_batch: int = 8             # same-signature coalescing bound
    default_timeout_s: float | None = None  # per-request deadline default

    def __post_init__(self):
        if self.queue_capacity < 1:
            raise ValueError(
                f"queue_capacity must be >= 1, got {self.queue_capacity}")
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.default_timeout_s is not None and self.default_timeout_s <= 0:
            raise ValueError(
                f"default_timeout_s must be positive, got "
                f"{self.default_timeout_s}")


class Scheduler:
    """Admission + bounded queue + affinity dispatch over one fleet."""

    def __init__(self, fleet: SessionFleet, config: ServeConfig | None = None,
                 *, metrics: MetricsRegistry | None = None):
        self.fleet = fleet
        self.config = config or ServeConfig()
        self.metrics = metrics or MetricsRegistry()
        m = self.metrics
        self._m_depth = m.gauge(
            "serve_queue_depth", "requests waiting for a session")
        self._m_pressure = m.gauge(
            "serve_backpressure", "queue depth over capacity, [0, 1]")
        self._m_requests = m.counter(
            "serve_requests_total", "served requests by terminal outcome",
            labels=("outcome",))
        self._m_rejected = m.counter(
            "serve_admission_rejections_total",
            "requests refused at admission", labels=("reason",))
        self._m_queue_s = m.histogram(
            "serve_time_in_queue_seconds", "admission -> dispatch wait")
        self._m_request_s = m.histogram(
            "serve_request_seconds", "admission -> resolution wall time")
        self._m_batch = m.histogram(
            "serve_batch_size", "requests per coalesced dispatch",
            buckets=(1.0, 2.0, 4.0, 8.0, 16.0, 32.0))
        self._m_cold = m.counter(
            "serve_cold_queries_total",
            "served queries that compiled at least one program")
        self._queue: deque[ServeRequest] = deque()
        self._running = False
        self._loop: asyncio.AbstractEventLoop | None = None
        self._dispatcher: asyncio.Task | None = None
        self._batches: set[asyncio.Task] = set()
        self._wake = asyncio.Event()

    # ------------------------------------------------------------ lifecycle
    async def start(self) -> int:
        """Warm the fleet and start dispatching; returns programs compiled."""
        if self._running:
            return 0
        self._loop = asyncio.get_running_loop()
        self._running = True
        compiled = await self.fleet.start()
        self._dispatcher = asyncio.create_task(
            self._dispatch_loop(), name="serve-dispatch")
        return compiled

    async def stop(self, *, drain: bool = True) -> None:
        """Stop admitting; drain (default) or cancel the queue; join workers."""
        if not self._running:
            return
        self._running = False  # submit() rejects from here on
        if not drain:
            for req in list(self._queue):
                self.cancel(req)
        self._wake.set()
        if self._dispatcher is not None:
            await self._dispatcher
            self._dispatcher = None
        if self._batches:
            await asyncio.gather(*self._batches)
        await self.fleet.shutdown()

    # ------------------------------------------------------------ admission
    @property
    def depth(self) -> int:
        return len(self._queue)

    @property
    def backpressure(self) -> float:
        return len(self._queue) / self.config.queue_capacity

    def submit(self, dataset, query, *, timeout_s: float | None = None,
               client: str = "", stream: ResultStream | None = None,
               ) -> ServeRequest:
        """Admit one request; returns it (await `request.future`).

        Raises `AdmissionError` instead of queueing when the scheduler is
        stopped ("shutting_down") or the queue is full ("queue_full").
        `stream.on_head` is re-dispatched onto this event loop, so client
        callbacks never run on a miner thread.
        """
        if not self._running or self._loop is None:
            self._m_rejected.labels(reason="shutting_down").inc()
            raise AdmissionError("shutting_down",
                                 "scheduler is not accepting requests")
        if len(self._queue) >= self.config.queue_capacity:
            self._m_rejected.labels(reason="queue_full").inc()
            raise AdmissionError(
                "queue_full",
                f"queue at capacity ({self.config.queue_capacity}); "
                "retry with backoff",
            )
        if stream is not None:
            loop, user_cb = self._loop, stream.on_head
            stream = ResultStream(
                head_k=stream.head_k, chunk=stream.chunk,
                on_head=lambda pats: loop.call_soon_threadsafe(user_cb, pats),
            )
        if timeout_s is None:
            timeout_s = self.config.default_timeout_s
        req = ServeRequest(
            dataset, query, client=client, stream=stream,
            signature=program_signature(dataset, query),
            timeout_s=timeout_s, loop=self._loop,
        )
        if req.deadline is not None:
            req.timer = self._loop.call_later(timeout_s, self._expire, req)
        self._queue.append(req)
        self._gauges()
        self._wake.set()
        return req

    def cancel(self, req: ServeRequest) -> bool:
        """Cancel a queued request; False once it started (or finished)."""
        if not req.try_terminate("cancelled"):
            return False
        self._drop(req)
        result = ServeResult(outcome="cancelled", reason="client cancelled",
                             queued_s=req.elapsed(), total_s=req.elapsed())
        self._record(req, result)
        req.resolve(self._loop, result)
        return True

    def _expire(self, req: ServeRequest) -> None:
        if not req.try_terminate("timeout"):
            return  # started first; the worker owns it now
        self._drop(req)
        result = ServeResult(
            outcome="timeout", reason="deadline expired in queue",
            queued_s=req.elapsed(), total_s=req.elapsed(),
        )
        self._record(req, result)
        req.resolve(self._loop, result)

    def _drop(self, req: ServeRequest) -> None:
        try:
            self._queue.remove(req)
        except ValueError:
            pass  # already collected into a batch
        self._gauges()

    def _gauges(self) -> None:
        self._m_depth.set(len(self._queue))
        self._m_pressure.set(self.backpressure)

    def _record(self, req: ServeRequest, result: ServeResult) -> None:
        """Per-result metrics; thread-safe (runs on miner threads too)."""
        self._m_requests.labels(outcome=result.outcome).inc()
        self._m_queue_s.observe(result.queued_s)
        self._m_request_s.observe(result.total_s)
        if result.ok and result.report is not None and result.report.cold:
            self._m_cold.inc()

    # ------------------------------------------------------------- dispatch
    async def _dispatch_loop(self) -> None:
        while self._running or self._queue:
            if not self._queue:
                self._wake.clear()
                if not self._running:
                    break
                await self._wake.wait()
                continue
            head = self._queue[0]
            worker = await self.fleet.acquire(head.signature, head.dataset)
            # the queue may have drained (expiry/cancel) while we waited
            if not self._queue:
                self.fleet.release(worker)
                continue
            # fairness: never batch so greedily that other idle workers
            # starve — split a deep queue across every available session
            avail = 1 + sum(1 for w in self.fleet.workers if not w.busy)
            limit = min(self.config.max_batch,
                        -(-len(self._queue) // avail))
            batch = collect_batch(self._queue, limit)
            self._gauges()
            if not batch:
                self.fleet.release(worker)
                continue
            self._m_batch.observe(len(batch))
            task = asyncio.create_task(self._run_batch(worker, batch))
            self._batches.add(task)
            task.add_done_callback(self._batches.discard)

    async def _run_batch(self, worker, batch) -> None:
        try:
            await self._loop.run_in_executor(
                worker.executor, run_batch, worker, batch, self._loop,
                self._record,
            )
        finally:
            self.fleet.release(worker)
            self._wake.set()


class MiningService:
    """Fleet + scheduler + one metrics surface: the thing you start.

        service = MiningService(size=2, warmups=[WarmupSpec(bucket)])
        await service.start()
        result = await service.mine(dataset, SignificantPatternQuery(alpha=0.05))
        await service.stop()
    """

    def __init__(self, *, size: int = 2, algorithm=None, runtime=None,
                 config: ServeConfig | None = None, warmups=(),
                 metrics: MetricsRegistry | None = None, devices=None,
                 partition_devices: bool = True,
                 residency_budget_mb: float = 256.0):
        self.metrics = metrics or MetricsRegistry()
        self.fleet = SessionFleet.build(
            size, algorithm=algorithm, runtime=runtime, metrics=self.metrics,
            devices=devices, partition_devices=partition_devices,
            warmups=warmups, residency_budget_mb=residency_budget_mb,
        )
        self.scheduler = Scheduler(self.fleet, config, metrics=self.metrics)

    async def start(self) -> int:
        return await self.scheduler.start()

    async def stop(self, *, drain: bool = True) -> None:
        await self.scheduler.stop(drain=drain)

    def submit(self, dataset, query, **kw) -> ServeRequest:
        return self.scheduler.submit(dataset, query, **kw)

    async def mine(self, dataset, query, **kw) -> ServeResult:
        """Submit and await one request (admission errors still raise)."""
        return await self.submit(dataset, query, **kw).future

    def cancel(self, req: ServeRequest) -> bool:
        return self.scheduler.cancel(req)

    @property
    def depth(self) -> int:
        return self.scheduler.depth

    @property
    def backpressure(self) -> float:
        return self.scheduler.backpressure

    @property
    def size(self) -> int:
        return self.fleet.size
