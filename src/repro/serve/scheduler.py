"""The serving scheduler: admission, queueing, dispatch (DESIGN.md §10).

One asyncio event loop owns a bounded FIFO request queue in front of the
session fleet:

  * **admission control** — `submit` raises `AdmissionError("queue_full")`
    the moment the queue is at capacity (callers see backpressure as a
    typed rejection, not unbounded latency) and
    `AdmissionError("shutting_down")` after `stop()`;
  * **deadlines** — a per-request timeout arms a loop timer; expiry while
    queued resolves the request as a timeout and removes it (it never
    touches a device), and `try_start`'s re-check catches deadlines that
    lapse between timer granularity and dispatch;
  * **cancellation** — `cancel(request)` terminates a *queued* request;
    running requests are not interruptible (BSP supersteps);
  * **dispatch** — the dispatcher awaits an idle worker chosen by warm-
    program/residency affinity for the queue head, coalesces the head's
    same-signature run (serve.batch) and drains it on the worker's thread,
    so the loop keeps admitting while miners mine;
  * **backpressure signal** — `backpressure` in [0, 1] is queue depth over
    capacity; it is also exported as a gauge so clients and load
    generators can shed before admission starts rejecting.

`MiningService` is the facade gluing one fleet + one scheduler + one
shared `MetricsRegistry` into the thing launchers and benchmarks start.
"""

from __future__ import annotations

import asyncio
import os
import random
from collections import deque
from dataclasses import dataclass

from repro.obs import MetricsRegistry
from repro.results import ResultStream

from .batch import collect_batch, program_signature, run_batch
from .fleet import SessionFleet
from .request import AdmissionError, ServeRequest, ServeResult

__all__ = ["MiningService", "Scheduler", "ServeConfig"]


@dataclass(frozen=True)
class ServeConfig:
    """Scheduler policy knobs."""

    queue_capacity: int = 64       # admission bound (requests, not batches)
    max_batch: int = 8             # same-signature coalescing bound
    default_timeout_s: float | None = None  # per-request deadline default
    # ---- fault tolerance (DESIGN.md §11) ----
    max_retries: int = 2           # extra attempts per request after the 1st
    retry_backoff_s: float = 0.05  # base requeue delay, doubles per retry
    retry_jitter: float = 0.25     # uniform backoff inflation, [0, jitter)
    breaker_threshold: int = 3     # consecutive failures ejecting a worker
    ckpt_root: str | None = None   # per-request frontier checkpoints go here

    def __post_init__(self):
        if self.queue_capacity < 1:
            raise ValueError(
                f"queue_capacity must be >= 1, got {self.queue_capacity}")
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.default_timeout_s is not None and self.default_timeout_s <= 0:
            raise ValueError(
                f"default_timeout_s must be positive, got "
                f"{self.default_timeout_s}")
        if self.max_retries < 0:
            raise ValueError(
                f"max_retries must be >= 0, got {self.max_retries}")
        if self.retry_backoff_s < 0 or self.retry_jitter < 0:
            raise ValueError(
                "retry_backoff_s and retry_jitter must be >= 0, got "
                f"{self.retry_backoff_s} / {self.retry_jitter}")
        if self.breaker_threshold < 1:
            raise ValueError(
                f"breaker_threshold must be >= 1, got {self.breaker_threshold}")


class Scheduler:
    """Admission + bounded queue + affinity dispatch over one fleet."""

    def __init__(self, fleet: SessionFleet, config: ServeConfig | None = None,
                 *, metrics: MetricsRegistry | None = None):
        self.fleet = fleet
        self.config = config or ServeConfig()
        self.metrics = metrics or MetricsRegistry()
        m = self.metrics
        self._m_depth = m.gauge(
            "serve_queue_depth", "requests waiting for a session")
        self._m_pressure = m.gauge(
            "serve_backpressure", "queue depth over capacity, [0, 1]")
        self._m_requests = m.counter(
            "serve_requests_total", "served requests by terminal outcome",
            labels=("outcome",))
        self._m_rejected = m.counter(
            "serve_admission_rejections_total",
            "requests refused at admission", labels=("reason",))
        self._m_queue_s = m.histogram(
            "serve_time_in_queue_seconds", "admission -> dispatch wait")
        self._m_request_s = m.histogram(
            "serve_request_seconds", "admission -> resolution wall time")
        self._m_batch = m.histogram(
            "serve_batch_size", "requests per coalesced dispatch",
            buckets=(1.0, 2.0, 4.0, 8.0, 16.0, 32.0))
        self._m_cold = m.counter(
            "serve_cold_queries_total",
            "served queries that compiled at least one program")
        self._m_retries = m.counter(
            "serve_retries_total", "failed attempts handed back for requeue",
            labels=("reason",))
        self._m_partial = m.counter(
            "serve_partial_results_total",
            "requests resolved with a soft-deadline truncated report")
        self._m_breaker = m.gauge(
            "serve_worker_breaker_state",
            "per-worker circuit breaker (0 closed, 1 open)",
            labels=("worker",))
        for w in self.fleet.workers:
            w.breaker_threshold = self.config.breaker_threshold
            self._m_breaker.labels(worker=str(w.wid)).set(0)
        self._rng = random.Random(0)  # deterministic backoff jitter
        self._queue: deque[ServeRequest] = deque()
        self._running = False
        self._loop: asyncio.AbstractEventLoop | None = None
        self._dispatcher: asyncio.Task | None = None
        self._batches: set[asyncio.Task] = set()
        self._retry_timers: dict[int, tuple] = {}  # rid -> (timer, request)
        self._wake = asyncio.Event()

    # ------------------------------------------------------------ lifecycle
    async def start(self) -> int:
        """Warm the fleet and start dispatching; returns programs compiled."""
        if self._running:
            return 0
        self._loop = asyncio.get_running_loop()
        self._running = True
        compiled = await self.fleet.start()
        self._dispatcher = asyncio.create_task(
            self._dispatch_loop(), name="serve-dispatch")
        return compiled

    async def stop(self, *, drain: bool = True) -> None:
        """Stop admitting; drain (default) or cancel the queue; join workers."""
        if not self._running:
            return
        self._running = False  # submit() rejects from here on
        if not drain:
            for req in list(self._queue):
                self.cancel(req)
        self._wake.set()
        if self._dispatcher is not None:
            await self._dispatcher
            self._dispatcher = None
        while self._batches:  # batches can spawn rebuild tasks; drain all
            await asyncio.gather(*self._batches)
        # flush requeue callbacks still in flight from worker threads, then
        # resolve every request parked in retry backoff as a terminal error
        await asyncio.sleep(0)
        for timer, req in list(self._retry_timers.values()):
            timer.cancel()
            self._fail_retry(req, "scheduler stopped during retry backoff")
        self._retry_timers.clear()
        await self.fleet.shutdown()

    # ------------------------------------------------------------ admission
    @property
    def depth(self) -> int:
        return len(self._queue)

    @property
    def backpressure(self) -> float:
        return len(self._queue) / self.config.queue_capacity

    def submit(self, dataset, query, *, timeout_s: float | None = None,
               client: str = "", stream: ResultStream | None = None,
               ) -> ServeRequest:
        """Admit one request; returns it (await `request.future`).

        Raises `AdmissionError` instead of queueing when the scheduler is
        stopped ("shutting_down") or the queue is full ("queue_full").
        `stream.on_head` is re-dispatched onto this event loop, so client
        callbacks never run on a miner thread.
        """
        if not self._running or self._loop is None:
            self._m_rejected.labels(reason="shutting_down").inc()
            raise AdmissionError("shutting_down",
                                 "scheduler is not accepting requests")
        if len(self._queue) >= self.config.queue_capacity:
            self._m_rejected.labels(reason="queue_full").inc()
            raise AdmissionError(
                "queue_full",
                f"queue at capacity ({self.config.queue_capacity}); "
                "retry with backoff",
            )
        if stream is not None:
            loop, user_cb = self._loop, stream.on_head
            stream = ResultStream(
                head_k=stream.head_k, chunk=stream.chunk,
                on_head=lambda pats: loop.call_soon_threadsafe(user_cb, pats),
            )
        if timeout_s is None:
            timeout_s = self.config.default_timeout_s
        req = ServeRequest(
            dataset, query, client=client, stream=stream,
            signature=program_signature(dataset, query),
            timeout_s=timeout_s, loop=self._loop,
        )
        if req.deadline is not None:
            req.timer = self._loop.call_later(timeout_s, self._expire, req)
        self._queue.append(req)
        self._gauges()
        self._wake.set()
        return req

    def cancel(self, req: ServeRequest) -> bool:
        """Cancel a queued request; False once it started (or finished)."""
        if not req.try_terminate("cancelled"):
            return False
        self._drop(req)
        result = ServeResult(outcome="cancelled", reason="client cancelled",
                             queued_s=req.elapsed(), total_s=req.elapsed())
        self._record(req, result)
        req.resolve(self._loop, result)
        return True

    def _expire(self, req: ServeRequest) -> None:
        if not req.try_terminate("timeout"):
            return  # started first; the worker owns it now
        self._drop(req)
        result = ServeResult(
            outcome="timeout", reason="deadline expired in queue",
            queued_s=req.elapsed(), total_s=req.elapsed(),
        )
        self._record(req, result)
        req.resolve(self._loop, result)

    def _drop(self, req: ServeRequest) -> None:
        try:
            self._queue.remove(req)
        except ValueError:
            pass  # already collected into a batch
        self._gauges()

    def _gauges(self) -> None:
        self._m_depth.set(len(self._queue))
        self._m_pressure.set(self.backpressure)

    def _record(self, req: ServeRequest, result: ServeResult) -> None:
        """Per-result metrics; thread-safe (runs on miner threads too)."""
        self._m_requests.labels(outcome=result.outcome).inc()
        self._m_queue_s.observe(result.queued_s)
        self._m_request_s.observe(result.total_s)
        if result.outcome == "partial":
            self._m_partial.inc()
        if result.ok and result.report is not None and result.report.cold:
            self._m_cold.inc()

    # ---------------------------------------------------------- retry (§11)
    def _ckpt_dir_for(self, req: ServeRequest) -> str | None:
        """Where one request's frontier checkpoints live (None = no ckpt)."""
        root = self.config.ckpt_root
        return os.path.join(root, f"req_{req.rid}") if root else None

    def _on_failure(self, req: ServeRequest, exc, worker) -> bool:
        """Retry-budget decision for one failed attempt (worker thread).

        True => the request was reset to queued and a backoff requeue is
        armed on the loop; the caller leaves its future pending.  False =>
        budget exhausted (or the scheduler is stopping): the caller resolves
        the request as a terminal error.
        """
        if not self._running:
            return False
        if req.attempts > self.config.max_retries:
            return False  # attempt 1 + max_retries retries all consumed
        if not req.reset_for_retry():
            return False  # a terminal transition won the race
        self._m_retries.labels(reason=type(exc).__name__).inc()
        self._loop.call_soon_threadsafe(self._arm_requeue, req)
        return True

    def _arm_requeue(self, req: ServeRequest) -> None:
        """Schedule the delayed requeue of a reset request (loop thread).

        Backoff doubles per retry (attempt 2 waits the base delay) with
        deterministic uniform jitter so same-worker retries decorrelate.
        """
        if not self._running:
            self._fail_retry(req, "scheduler stopped before retry")
            return
        backoff = (self.config.retry_backoff_s * 2 ** (req.attempts - 2)
                   * (1.0 + self.config.retry_jitter * self._rng.random()))
        timer = self._loop.call_later(backoff, self._requeue, req)
        self._retry_timers[req.rid] = (timer, req)

    def _requeue(self, req: ServeRequest) -> None:
        """Put a backed-off request at the queue tail (loop thread).

        Bypasses admission capacity on purpose: the request was already
        admitted once and holds a pending client future.  Skips silently if
        a deadline/cancel resolved it while parked.
        """
        self._retry_timers.pop(req.rid, None)
        if req.state != "queued":
            return
        if not self._running:
            self._fail_retry(req, "scheduler stopped during retry backoff")
            return
        self._queue.append(req)
        self._gauges()
        self._wake.set()

    def _requeue_now(self, req: ServeRequest) -> None:
        """Immediate no-penalty requeue for requests whose batch runner died
        before their attempt started (loop thread): no backoff, no attempt
        bump — the request itself never failed."""
        if req.state != "queued":
            return
        self._queue.append(req)
        self._gauges()
        self._wake.set()

    def _fail_retry(self, req: ServeRequest, why: str) -> None:
        """Terminal error for a request stuck in retry limbo (loop thread)."""
        if not req.try_terminate("error"):
            return
        result = ServeResult(
            outcome="error", reason=why, queued_s=req.elapsed(),
            total_s=req.elapsed(), attempts=req.attempts,
        )
        self._record(req, result)
        req.resolve(self._loop, result)

    async def _rebuild_worker(self, worker) -> None:
        """Swap a tripped worker's session for a fresh one on its own thread,
        then close its breaker.  A rebuild that itself raises leaves the
        breaker open permanently (graceful degradation: the fleet keeps
        serving on the survivors)."""
        try:
            await self._loop.run_in_executor(
                worker.executor, self.fleet.rebuild_worker, worker)
        except Exception:
            return  # breaker stays open, rebuilding stays latched
        self._m_breaker.labels(worker=str(worker.wid)).set(0)
        self.fleet.note_repaired(worker)

    # ------------------------------------------------------------- dispatch
    async def _dispatch_loop(self) -> None:
        while self._running or self._queue:
            if not self._queue:
                self._wake.clear()
                if not self._running:
                    break
                await self._wake.wait()
                continue
            head = self._queue[0]
            worker = await self.fleet.acquire(head.signature, head.dataset)
            # the queue may have drained (expiry/cancel) while we waited
            if not self._queue:
                self.fleet.release(worker)
                continue
            # fairness: never batch so greedily that other idle workers
            # starve — split a deep queue across every available session
            avail = 1 + sum(1 for w in self.fleet.workers
                            if not w.busy and not w.broken)
            limit = min(self.config.max_batch,
                        -(-len(self._queue) // avail))
            batch = collect_batch(self._queue, limit)
            self._gauges()
            if not batch:
                self.fleet.release(worker)
                continue
            self._m_batch.observe(len(batch))
            task = asyncio.create_task(self._run_batch(worker, batch))
            self._batches.add(task)
            task.add_done_callback(self._batches.discard)

    async def _run_batch(self, worker, batch) -> None:
        try:
            await self._loop.run_in_executor(
                worker.executor, run_batch, worker, batch, self._loop,
                self._record, self._on_failure, self._ckpt_dir_for,
            )
        except Exception as exc:
            # the batch RUNNER died (not one request's engine call — those
            # are caught inside run_batch): nothing in this batch may be
            # lost.  Never-started members requeue free; the in-flight one
            # burns an attempt through the normal retry budget.
            worker.record_failure()
            for req in batch:
                if req.state == "queued":
                    self._requeue_now(req)
                elif req.state == "running":
                    if self._on_failure(req, exc, worker):
                        pass  # reset + requeue armed; retry counted inside
                    elif req.try_terminate_running("error"):
                        result = ServeResult(
                            outcome="error",
                            reason=f"batch runner died: "
                                   f"{type(exc).__name__}: {exc}",
                            queued_s=req.elapsed(), total_s=req.elapsed(),
                            session_id=worker.wid, attempts=req.attempts,
                        )
                        self._record(req, result)
                        req.resolve(self._loop, result)
        finally:
            self.fleet.release(worker)
            if worker.broken and not worker.rebuilding:
                worker.rebuilding = True
                self._m_breaker.labels(worker=str(worker.wid)).set(1)
                task = asyncio.create_task(
                    self._rebuild_worker(worker),
                    name=f"serve-rebuild-{worker.wid}")
                self._batches.add(task)
                task.add_done_callback(self._batches.discard)
            self._wake.set()


class MiningService:
    """Fleet + scheduler + one metrics surface: the thing you start.

        service = MiningService(size=2, warmups=[WarmupSpec(bucket)])
        await service.start()
        result = await service.mine(dataset, SignificantPatternQuery(alpha=0.05))
        await service.stop()
    """

    def __init__(self, *, size: int = 2, algorithm=None, runtime=None,
                 config: ServeConfig | None = None, warmups=(),
                 metrics: MetricsRegistry | None = None, devices=None,
                 partition_devices: bool = True,
                 residency_budget_mb: float = 256.0):
        self.metrics = metrics or MetricsRegistry()
        self.fleet = SessionFleet.build(
            size, algorithm=algorithm, runtime=runtime, metrics=self.metrics,
            devices=devices, partition_devices=partition_devices,
            warmups=warmups, residency_budget_mb=residency_budget_mb,
        )
        self.scheduler = Scheduler(self.fleet, config, metrics=self.metrics)

    async def start(self) -> int:
        return await self.scheduler.start()

    async def stop(self, *, drain: bool = True) -> None:
        await self.scheduler.stop(drain=drain)

    def submit(self, dataset, query, **kw) -> ServeRequest:
        return self.scheduler.submit(dataset, query, **kw)

    async def mine(self, dataset, query, **kw) -> ServeResult:
        """Submit and await one request (admission errors still raise)."""
        return await self.submit(dataset, query, **kw).future

    def cancel(self, req: ServeRequest) -> bool:
        return self.scheduler.cancel(req)

    @property
    def depth(self) -> int:
        return self.scheduler.depth

    @property
    def backpressure(self) -> float:
        return self.scheduler.backpressure

    @property
    def size(self) -> int:
        return self.fleet.size
