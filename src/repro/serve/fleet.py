"""The session fleet: warm MinerSessions behind the scheduler (DESIGN.md §10).

A fleet is N `MinerSession`s, each pinned to its own single-thread
executor — the sessions' one-query-at-a-time contract becomes a structural
property instead of a convention — plus the two policies that make repeat
traffic cheap:

  * **warmup**: at startup every worker pre-compiles the configured
    `WarmupSpec`s (shape bucket × statistic × staging) from placeholder
    datasets, so the first real query of a configured shape dispatches
    with zero compiles on *any* worker;
  * **residency + affinity**: each worker remembers the datasets it served
    (strong refs, LRU over a byte budget, so their packed device buffers
    stay alive) and `acquire` prefers an idle worker whose program cache
    is warm for the request's signature — and, among warm workers, one
    where the dataset's buffers are already resident.

Device partitioning: `build` splits the visible devices into disjoint
contiguous slices when there are enough to go around (true parallel
service), and falls back to sharing the full mesh across sessions
otherwise (time-sliced by the backend; still correct).
"""

from __future__ import annotations

import asyncio
from collections import OrderedDict
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass

from repro.api.dataset import Dataset, ShapeBucket

__all__ = ["FleetWorker", "SessionFleet", "WarmupSpec"]


@dataclass(frozen=True)
class WarmupSpec:
    """One startup pre-compile target: a shape bucket under a statistic.

    `statistic=None` warms the statistic-free programs (closed-frequent
    traffic); `pipeline=None` uses the session's configured staging.
    """

    bucket: ShapeBucket
    statistic: str | None = "fisher"
    pipeline: str | None = None
    alpha: float | None = None


class FleetWorker:
    """One warm session + its confinement thread + its resident datasets.

    Circuit breaker (DESIGN.md §11): consecutive failed attempts trip
    `broken` at `breaker_threshold`, ejecting the worker from `acquire`
    until the scheduler rebuilds its session (`SessionFleet.rebuild_worker`
    + `note_repaired`); any success resets the count.
    """

    def __init__(self, wid: int, session, *, residency_budget_bytes: int,
                 session_factory=None, breaker_threshold: int = 3):
        self.wid = wid
        self.session = session
        #: zero-arg callable rebuilding a fresh session for this worker's
        #: device slice; None = externally-owned sessions (rebuild resets
        #: the breaker but keeps the session)
        self.session_factory = session_factory
        self.executor = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix=f"miner-{wid}"
        )
        self.busy = False
        self.served = 0
        self.failures = 0          # consecutive failed attempts
        self.broken = False        # breaker open: excluded from acquire
        self.rebuilding = False    # a rebuild task is in flight
        self.breaker_threshold = breaker_threshold
        self._budget = residency_budget_bytes
        # id(dataset) -> (dataset, nbytes); insertion order = LRU order.
        # Strong refs on purpose: residency means the packed buffers live.
        self._resident: OrderedDict[int, tuple[Dataset, int]] = OrderedDict()
        self._resident_bytes = 0

    # ---------------------------------------------------------- residency
    @staticmethod
    def _nbytes(dataset: Dataset) -> int:
        packed = getattr(dataset, "packed", None)
        bits = getattr(packed, "db_bits", None)
        return int(bits.nbytes) if bits is not None else 0

    def is_resident(self, dataset: Dataset) -> bool:
        return id(dataset) in self._resident

    @property
    def resident_bytes(self) -> int:
        return self._resident_bytes

    @property
    def n_resident(self) -> int:
        return len(self._resident)

    def note_served(self, dataset: Dataset) -> None:
        """Mark `dataset` most-recently-served; evict LRU over the budget.

        Called from this worker's own thread (run_batch) — each worker's
        residency map is confined to its thread plus the loop thread's
        read-only affinity scoring, where a stale read only mis-ranks."""
        key = id(dataset)
        if key in self._resident:
            self._resident.move_to_end(key)
            return
        nbytes = self._nbytes(dataset)
        self._resident[key] = (dataset, nbytes)
        self._resident_bytes += nbytes
        # keep at least the newest entry even when it alone busts the budget
        while self._resident_bytes > self._budget and len(self._resident) > 1:
            _, (_, dropped) = self._resident.popitem(last=False)
            self._resident_bytes -= dropped

    # ----------------------------------------------------------- affinity
    def score(self, signature, dataset: Dataset) -> tuple:
        """Dispatch preference: warm programs first, resident data second,
        then fewest-served for balance."""
        try:
            warm = 1 if signature.warm_on(self.session) else 0
        except ValueError:  # signature names a staging this build lacks
            warm = 0
        return (warm, 1 if self.is_resident(dataset) else 0, -self.served)

    # ----------------------------------------------------- circuit breaker
    def record_failure(self) -> None:
        """One failed attempt (worker thread).  Trips the breaker open at
        `breaker_threshold` consecutive failures."""
        self.failures += 1
        if self.failures >= self.breaker_threshold:
            self.broken = True

    def record_success(self) -> None:
        """One successful attempt (worker thread): closes the count."""
        self.failures = 0

    def shutdown(self) -> None:
        self.executor.shutdown(wait=True)


class SessionFleet:
    """N warm workers + the acquire/release gate the scheduler drives."""

    def __init__(self, sessions, *, warmups=(),
                 residency_budget_mb: float = 256.0):
        if not sessions:
            raise ValueError("SessionFleet needs at least one session")
        budget = int(residency_budget_mb * 1e6)
        self.workers = [
            FleetWorker(i, s, residency_budget_bytes=budget)
            for i, s in enumerate(sessions)
        ]
        self.warmups = tuple(warmups)
        self._idle_event = asyncio.Event()
        self._idle_event.set()

    # ------------------------------------------------------- construction
    @classmethod
    def build(cls, size: int, *, algorithm=None, runtime=None, metrics=None,
              devices=None, partition_devices: bool = True, warmups=(),
              residency_budget_mb: float = 256.0) -> "SessionFleet":
        """Build `size` sessions over the visible devices.

        With `partition_devices` (default) and >= `size` devices, each
        session gets a disjoint contiguous slice of the mesh; otherwise
        every session shares the full device list (backend time-slicing).
        `metrics` is shared across all sessions (one scrape surface)."""
        import jax

        from repro.api.session import MinerSession

        if size < 1:
            raise ValueError(f"fleet size must be >= 1, got {size}")
        devices = list(jax.devices()) if devices is None else list(devices)
        if partition_devices and len(devices) >= size:
            per = len(devices) // size
            slices = [devices[i * per:(i + 1) * per] for i in range(size)]
        else:
            slices = [devices] * size
        sessions = [
            MinerSession(devs, algorithm=algorithm, runtime=runtime,
                         metrics=metrics)
            for devs in slices
        ]
        fleet = cls(sessions, warmups=warmups,
                    residency_budget_mb=residency_budget_mb)
        # each worker can rebuild a fresh session over its own device slice
        # (circuit-breaker recovery); default-arg binding pins the slice
        for worker, devs in zip(fleet.workers, slices):
            worker.session_factory = (
                lambda devs=devs: MinerSession(
                    devs, algorithm=algorithm, runtime=runtime,
                    metrics=metrics)
            )
        return fleet

    @property
    def size(self) -> int:
        return len(self.workers)

    # ------------------------------------------------------------- warmup
    async def start(self) -> int:
        """Run every warmup spec on every worker (on the workers' own
        threads, concurrently across workers).  Returns total programs
        compiled."""
        if not self.warmups:
            return 0
        loop = asyncio.get_running_loop()

        def _warm(worker: FleetWorker) -> int:
            n = 0
            for spec in self.warmups:
                n += worker.session.warmup(
                    spec.bucket, statistic=spec.statistic,
                    pipeline=spec.pipeline, alpha=spec.alpha,
                )
            return n

        totals = await asyncio.gather(*[
            loop.run_in_executor(w.executor, _warm, w) for w in self.workers
        ])
        return sum(totals)

    # ---------------------------------------------------- acquire/release
    def acquire_nowait(self, signature, dataset) -> FleetWorker | None:
        """Claim the best-affinity idle worker, or None if all are busy.
        Loop-thread only."""
        idle = [w for w in self.workers if not w.busy and not w.broken]
        if not idle:
            return None
        best = max(idle, key=lambda w: w.score(signature, dataset))
        best.busy = True
        best.served += 1
        return best

    async def acquire(self, signature, dataset) -> FleetWorker:
        """Wait for an idle worker, then claim by affinity."""
        while True:
            worker = self.acquire_nowait(signature, dataset)
            if worker is not None:
                return worker
            self._idle_event.clear()
            await self._idle_event.wait()

    def release(self, worker: FleetWorker) -> None:
        worker.busy = False
        self._idle_event.set()

    # ------------------------------------------------------------- repair
    def rebuild_worker(self, worker: FleetWorker) -> None:
        """Replace a broken worker's session with a fresh one and re-warm it.

        MUST run on the worker's own executor thread (session confinement);
        the scheduler dispatches it there and calls `note_repaired` after.
        Without a `session_factory` (externally-owned sessions) the session
        is kept and only the failure count resets — a cool-off semantics.
        """
        if worker.session_factory is not None:
            worker.session = worker.session_factory()
            for spec in self.warmups:
                worker.session.warmup(
                    spec.bucket, statistic=spec.statistic,
                    pipeline=spec.pipeline, alpha=spec.alpha,
                )
        worker.failures = 0

    def note_repaired(self, worker: FleetWorker) -> None:
        """Re-admit a rebuilt worker to `acquire` (loop thread)."""
        worker.broken = False
        worker.rebuilding = False
        self._idle_event.set()

    @property
    def n_busy(self) -> int:
        return sum(1 for w in self.workers if w.busy)

    async def shutdown(self) -> None:
        """Join every worker thread (after the scheduler drained them)."""
        loop = asyncio.get_running_loop()
        await asyncio.gather(*[
            loop.run_in_executor(None, w.shutdown) for w in self.workers
        ])
