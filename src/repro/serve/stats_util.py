"""Latency statistics helpers shared by the serving layer and launchers.

Moved out of `launch.mine_serve` so the load generator
(`serve.loadgen`), the serving benchmark (`benchmarks.bench_serving`)
and the CLI client all consume one implementation instead of drifting
copies.
"""

from __future__ import annotations

__all__ = ["latency_histogram", "latency_summary", "percentile"]


def percentile(xs, q):
    """Nearest-rank percentile over a small sample (q in [0, 100])."""
    xs = sorted(xs)
    if not xs:
        return 0.0
    i = min(int(round(q / 100 * (len(xs) - 1))), len(xs) - 1)
    return xs[i]


def latency_histogram(lat_s, width=40) -> str:
    """Log2-bucket text histogram over milliseconds."""
    if not lat_s:
        return "(no samples)"
    ms = [x * 1e3 for x in lat_s]
    lo = min(ms)
    edge = 1.0
    while edge > lo:
        edge /= 2
    buckets: dict[float, int] = {}
    for x in ms:
        e = edge
        while e * 2 <= x:
            e *= 2
        buckets[e] = buckets.get(e, 0) + 1
    peak = max(buckets.values())
    lines = []
    for e in sorted(buckets):
        n = buckets[e]
        bar = "#" * max(1, round(width * n / peak))
        lines.append(f"  [{e:9.1f}ms, {e * 2:9.1f}ms)  {n:4d}  {bar}")
    return "\n".join(lines)


def latency_summary(lat_s, *, prefix: str = "") -> dict:
    """The standard percentile block every serving report carries."""
    if not lat_s:
        return {f"{prefix}n": 0}
    return {
        f"{prefix}n": len(lat_s),
        f"{prefix}mean_s": round(sum(lat_s) / len(lat_s), 4),
        f"{prefix}p50_s": round(percentile(lat_s, 50), 4),
        f"{prefix}p90_s": round(percentile(lat_s, 90), 4),
        f"{prefix}p99_s": round(percentile(lat_s, 99), 4),
        f"{prefix}max_s": round(max(lat_s), 4),
    }
