"""Same-program batching for the serving scheduler (DESIGN.md §10).

Two requests share a *program signature* when a warm session could serve
them back-to-back with zero compiles: same shape bucket, same traced
statistic, same staging.  `collect_batch` coalesces the queue head with
every same-signature request behind it (FIFO order within the batch is
preserved — clients that submitted earlier complete earlier), and
`run_batch` drains the coalesced batch on one fleet worker's thread,
resolving each request's future the moment its report is ready (the k-th
request of a batch does not wait for the batch).

Cancellation granularity: a queued request can be cancelled or expired,
a *running* one cannot — the engine's BSP supersteps are not
interruptible mid-dispatch — so `run_batch` re-checks each request's
deadline at start time (`try_start`) and resolves late ones as timeouts
without touching the device.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

from repro.api.dataset import Dataset, ShapeBucket
from repro.api.query import (
    ClosedFrequentQuery,
    Query,
    SignificantPatternQuery,
    TopKSignificantQuery,
)

from .request import ServeRequest, ServeResult

__all__ = ["BatchStats", "ProgramSignature", "collect_batch",
           "program_signature", "run_batch"]


@dataclass(frozen=True)
class ProgramSignature:
    """What a compiled-program working set depends on, per request.

    Equal signatures => the same warm session serves both with zero
    compiles, so they may coalesce into one batch.  `pipeline` is the LAMP
    staging whose phase modes the request replays; objectives outside the
    stagings (top-k bisection, closed-frequent) ride "three_phase"'s
    "test" program, so they map onto it for affinity purposes.
    """

    bucket: ShapeBucket
    statistic: str | None
    pipeline: str

    def warm_on(self, session) -> bool:
        """True when `session` already holds every compiled program this
        request needs (the fleet's affinity predicate)."""
        return session.has_programs(self.bucket, self.statistic,
                                    pipeline=self.pipeline)


def program_signature(dataset: Dataset, query: Query) -> ProgramSignature:
    """Batching/affinity identity of one (dataset, query) request."""
    bucket = dataset.bucket
    if isinstance(query, SignificantPatternQuery):
        return ProgramSignature(bucket, query.statistic, query.pipeline)
    if isinstance(query, TopKSignificantQuery):
        # bisection probes replay the "test" program of the classic staging
        return ProgramSignature(bucket, query.statistic, "three_phase")
    if isinstance(query, ClosedFrequentQuery):
        return ProgramSignature(bucket, None, "three_phase")
    # unknown objective: conservative identity from declared attributes
    return ProgramSignature(bucket, getattr(query, "statistic", None),
                            getattr(query, "pipeline", "three_phase"))


def collect_batch(queue, max_batch: int) -> list[ServeRequest]:
    """Pop the queue head plus up to `max_batch - 1` same-signature
    requests behind it, preserving FIFO order.  Other-signature requests
    keep their queue positions.  Loop-thread only (the queue is not
    locked)."""
    if not queue:
        return []
    head = queue.popleft()
    batch = [head]
    if max_batch > 1:
        rest = []
        while queue and len(batch) < max_batch:
            req = queue.popleft()
            if req.signature == head.signature:
                batch.append(req)
            else:
                rest.append(req)
        for req in reversed(rest):
            queue.appendleft(req)
    return batch


@dataclass
class BatchStats:
    """What one drained batch did (scheduler metrics feed)."""

    n_ok: int = 0
    n_timeout: int = 0
    n_error: int = 0
    n_cold: int = 0          # ok queries whose report compiled anything
    service_s: float = 0.0   # summed engine+result wall time
    n_partial: int = 0       # soft-deadline stops (truncated reports)
    n_retried: int = 0       # failed attempts handed back for requeue


def _ckpt_capable(worker) -> bool:
    """True when the worker's session runs the segmented program — the only
    program with superstep boundaries to stop at or checkpoint from."""
    return bool(getattr(getattr(worker.session, "runtime", None),
                        "ckpt_period", 0))


def run_batch(worker, batch: list[ServeRequest], loop,
              on_result=None, on_failure=None,
              ckpt_dir_for=None) -> BatchStats:
    """Drain one coalesced batch on `worker`'s session (worker thread).

    Each request's future resolves (thread-safely, on the loop) as soon as
    its own report is ready.  `on_result(request, result)` — optional —
    fires on this worker thread right before resolution; implementations
    must be thread-safe (the scheduler passes its metrics recorder).

    Fault tolerance (DESIGN.md §11): `on_failure(request, exc, worker)` —
    optional — decides retry vs terminal error for a failed attempt; when
    it returns True the request has been handed back to the scheduler
    (future left pending) and this runner moves on.  On a ckpt-capable
    session a deadlined request gets an engine-cooperative `should_stop`
    (stop at a superstep boundary, outcome "partial" with a truncated
    report) and `ckpt_dir_for(request)` names where its frontier
    checkpoints go.
    """
    from repro.testing import faults

    stats = BatchStats()
    size = len(batch)
    capable = _ckpt_capable(worker)
    for i, req in enumerate(batch):
        now = time.perf_counter()
        if not req.try_start():
            # lost the race to a terminator (its timer already resolved the
            # future), or the deadline lapsed in-queue before any timer
            # fired — resolve the latter here
            if req.try_terminate("timeout"):
                result = ServeResult(
                    outcome="timeout",
                    reason="deadline expired before dispatch",
                    queued_s=now - req.submitted,
                    total_s=now - req.submitted,
                    session_id=worker.wid, batch_size=size, batch_index=i,
                    attempts=req.attempts,
                )
                stats.n_timeout += 1
                if on_result is not None:
                    on_result(req, result)
                req.resolve(loop, result)
            continue
        try:
            faults.check("serve.attempt", rid=req.rid, worker=worker.wid)
            kw = {}
            if capable:
                if req.deadline is not None:
                    kw["should_stop"] = (
                        lambda d=req.deadline: time.perf_counter() >= d)
                ckpt_dir = (ckpt_dir_for(req)
                            if ckpt_dir_for is not None else None)
                if ckpt_dir:
                    kw["ckpt_dir"] = ckpt_dir
            report = worker.session.run(req.dataset, req.query,
                                        stream=req.stream, **kw)
        except Exception as exc:  # engine/query failure -> retry or fail
            worker.record_failure()
            end = time.perf_counter()
            started = req.started
            if on_failure is not None and on_failure(req, exc, worker):
                # handed back to the scheduler: the future stays pending and
                # the request is (or will be) queued again
                stats.n_retried += 1
                continue
            req.finish("error")
            result = ServeResult(
                outcome="error",
                reason=f"{type(exc).__name__}: {exc}",
                queued_s=started - req.submitted,
                service_s=end - started,
                total_s=end - req.submitted,
                session_id=worker.wid, batch_size=size, batch_index=i,
                attempts=req.attempts,
            )
            stats.n_error += 1
        else:
            worker.record_success()
            partial = bool(getattr(report, "partial", False))
            req.finish("partial" if partial else "ok")
            end = time.perf_counter()
            result = ServeResult(
                outcome="partial" if partial else "ok", report=report,
                queued_s=req.started - req.submitted,
                service_s=end - req.started,
                total_s=end - req.submitted,
                session_id=worker.wid, batch_size=size, batch_index=i,
                attempts=req.attempts,
                ckpt_path=getattr(report, "ckpt_path", None),
            )
            if partial:
                stats.n_partial += 1
            else:
                stats.n_ok += 1
            stats.n_cold += 1 if report.cold else 0
            stats.service_s += result.service_s
            worker.note_served(req.dataset)
        if on_result is not None:
            on_result(req, result)
        req.resolve(loop, result)
    return stats
