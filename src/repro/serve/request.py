"""Request lifecycle types of the serving layer (DESIGN.md §10).

A `ServeRequest` is one admitted query riding the scheduler queue: it
carries the (dataset, query) pair, the client tag, an optional absolute
deadline, an optional `ResultStream` for top-k-first delivery, and an
`asyncio.Future` that resolves to a `ServeResult`.  Its tiny state machine

    queued -> running -> ok | error
    queued -> timeout | cancelled            (never started)

is guarded by a `threading.Lock` because the two sides race by design: the
deadline timer and `cancel()` fire on the event-loop thread while
`try_start()` fires on a fleet worker thread.  Whichever transition wins
owns the future's resolution (always completed via
`loop.call_soon_threadsafe`, so consumers only ever see it resolve on the
loop thread).

Fault tolerance (DESIGN.md §11) adds two non-terminal arcs: a worker
failure may send running -> queued again (`reset_for_retry`, attempt count
incremented — the scheduler's retry budget decides), and a soft deadline
may end a running request with outcome "partial": a real, truncated
`MineReport` (results.complete == False) plus the frontier checkpoint path
instead of a bare timeout.

Requests that the scheduler refuses to enqueue never become `ServeRequest`s
at all — admission control raises `AdmissionError(reason)` at `submit()`.
"""

from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass
from typing import Any

__all__ = [
    "AdmissionError",
    "ServeRequest",
    "ServeResult",
]

#: terminal outcomes a request can resolve with (ServeResult.outcome) —
#: "rejected" never appears in a future (admission raises instead) but is
#: the label admission rejections count under in the metrics surface;
#: "partial" is a soft-deadline stop carrying a truncated report (§11)
OUTCOMES = ("ok", "partial", "timeout", "cancelled", "error", "rejected")

_ids = itertools.count()


class AdmissionError(RuntimeError):
    """The scheduler refused to enqueue a request; `.reason` says why
    ("queue_full" | "shutting_down")."""

    def __init__(self, reason: str, detail: str = ""):
        self.reason = reason
        super().__init__(detail or reason)


@dataclass(frozen=True)
class ServeResult:
    """The answer to one served request (what the request future resolves to).

    `ok` requests carry the full `MineReport`; failed ones carry the
    outcome + reason.  Timing splits the request's life into time-in-queue
    and service time so tail-latency regressions are attributable.
    """

    outcome: str                  # "ok" | "partial" | "timeout" | "cancelled" | "error"
    report: Any = None            # repro.api.MineReport (outcome "ok"/"partial")
    reason: str | None = None     # human-readable failure detail
    queued_s: float = 0.0         # admission -> start (or terminal, if never run)
    service_s: float = 0.0        # engine + result-build wall time
    total_s: float = 0.0          # admission -> resolution
    session_id: int | None = None  # fleet worker that served it
    batch_size: int = 1           # size of the coalesced batch it rode
    batch_index: int = 0          # its position within that batch
    attempts: int = 1             # serve attempts consumed (retries + 1)
    ckpt_path: str | None = None  # frontier checkpoint (outcome "partial")

    @property
    def ok(self) -> bool:
        return self.outcome == "ok"


class ServeRequest:
    """One admitted request: payload + deadline + state machine + future."""

    __slots__ = (
        "rid", "dataset", "query", "client", "stream", "signature",
        "deadline", "submitted", "started", "future", "timer", "attempts",
        "_state", "_lock",
    )

    def __init__(self, dataset, query, *, client: str = "", stream=None,
                 signature=None, timeout_s: float | None = None, loop=None):
        self.rid = next(_ids)
        self.dataset = dataset
        self.query = query
        self.client = client
        self.stream = stream
        # batching identity: requests with equal signatures share warm
        # programs and may coalesce onto one session (serve.batch)
        self.signature = signature
        self.submitted = time.perf_counter()
        self.started: float | None = None
        self.deadline = (self.submitted + timeout_s
                         if timeout_s is not None else None)
        self.future = loop.create_future()
        self.timer = None          # loop.call_later handle (scheduler-owned)
        self.attempts = 1          # serve attempts, counting the current one
        self._state = "queued"
        self._lock = threading.Lock()

    # ------------------------------------------------------------ state
    @property
    def state(self) -> str:
        return self._state

    def try_start(self) -> bool:
        """queued -> running (worker thread); False if a terminal transition
        (timeout/cancel) won the race or the deadline has already passed."""
        with self._lock:
            if self._state != "queued":
                return False
            if self.deadline is not None and time.perf_counter() > self.deadline:
                return False       # caller resolves it as a timeout
            self._state = "running"
            self.started = time.perf_counter()
            return True

    def try_terminate(self, state: str) -> bool:
        """queued -> timeout|cancelled (loop thread); False if started."""
        with self._lock:
            if self._state != "queued":
                return False
            self._state = state
            return True

    def try_terminate_running(self, state: str) -> bool:
        """running -> error (loop thread; batch-runner death cleanup)."""
        with self._lock:
            if self._state != "running":
                return False
            self._state = state
            return True

    def reset_for_retry(self) -> bool:
        """running -> queued (worker thread, after a failed attempt).

        Bumps the attempt count; the deadline timer stays armed, so a
        retry that outlives its deadline still expires normally.  False if
        the request was not running (a terminal transition won).
        """
        with self._lock:
            if self._state != "running":
                return False
            self._state = "queued"
            self.started = None
            self.attempts += 1
            return True

    def finish(self, state: str) -> None:
        """running -> ok|partial|error (worker thread, post-engine)."""
        with self._lock:
            self._state = state

    # ----------------------------------------------------------- results
    def resolve(self, loop, result: ServeResult) -> None:
        """Complete the future from any thread (delivered on the loop)."""

        def _set():
            if self.timer is not None:
                self.timer.cancel()  # TimerHandle is loop-thread-only
                self.timer = None
            if not self.future.done():
                self.future.set_result(result)

        loop.call_soon_threadsafe(_set)

    def elapsed(self, now: float | None = None) -> float:
        return (now if now is not None else time.perf_counter()) - self.submitted
