"""repro.serve — the async mining service (DESIGN.md §10).

An asyncio scheduler (bounded queue, admission control, deadlines,
cancellation, backpressure) in front of a fleet of warm `MinerSession`s
(startup warmup of configured shape buckets, dataset residency, warm-
program affinity dispatch), with same-program batching, streaming
top-k-first delivery, and an open/closed-loop load generator.

    from repro.serve import MiningService, WarmupSpec

    service = MiningService(size=2, warmups=[WarmupSpec(dataset.bucket)])
    await service.start()
    result = await service.mine(dataset, SignificantPatternQuery(alpha=0.05))
    await service.stop()
"""

from .batch import ProgramSignature, collect_batch, program_signature
from .fleet import FleetWorker, SessionFleet, WarmupSpec
from .loadgen import LoadReport, run_closed_loop, run_open_loop
from .request import AdmissionError, ServeRequest, ServeResult
from .scheduler import MiningService, Scheduler, ServeConfig
from .stats_util import latency_histogram, latency_summary, percentile

__all__ = [
    "AdmissionError",
    "FleetWorker",
    "LoadReport",
    "MiningService",
    "ProgramSignature",
    "Scheduler",
    "ServeConfig",
    "ServeRequest",
    "ServeResult",
    "SessionFleet",
    "WarmupSpec",
    "collect_batch",
    "latency_histogram",
    "latency_summary",
    "percentile",
    "program_signature",
    "run_closed_loop",
    "run_open_loop",
]
