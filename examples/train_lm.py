"""End-to-end LM training driver: a small model for a few hundred steps with
checkpointing, on any of the 10 assigned architectures (reduced configs).

  PYTHONPATH=src python examples/train_lm.py                 # ~10M-param tiny
  PYTHONPATH=src python examples/train_lm.py --arch recurrentgemma-9b
  PYTHONPATH=src python examples/train_lm.py --steps 300

The same train_step program lowers for the 16x16 / 2x16x16 production meshes
in repro.launch.dryrun; here it runs the CPU-scale configuration end to end
(loss should drop well below the uniform baseline ln(vocab)).
"""

import argparse
import math


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.launch import train

    vocab = 512
    losses = train.run([
        "--arch", args.arch, "--preset", "tiny",
        "--steps", str(args.steps), "--seq", "128", "--batch", "8",
        "--lr", "3e-3", "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
        "--log-every", "20",
    ])
    first, last = losses[0]["loss"], losses[-1]["loss"]
    uniform = math.log(vocab)
    print(f"\nloss {first:.3f} -> {last:.3f} (uniform baseline {uniform:.3f})")
    assert last < first - 0.5, "training did not learn"
    print("OK: model learned the synthetic Markov structure")


if __name__ == "__main__":
    main()
