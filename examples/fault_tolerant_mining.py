"""Fault-tolerant mining: kill a run mid-flight, resume it elastically on
fewer devices, get the bit-identical answer (DESIGN.md §11).

  PYTHONPATH=src python examples/fault_tolerant_mining.py [--devices 8]

Demonstrates the checkpoint/resume path end to end:

  1. a baseline mine on the full device set (the reference answer);
  2. the same mine with periodic frontier checkpoints and an injected
     fault (`repro.testing.faults`) that kills the engine a few segments
     in — exactly what a preempted host looks like;
  3. an **elastic** resume of the killed run on HALF the devices: the
     saved frontier (cut at P miners) is re-dealt onto P/2 miners and
     mining continues from the checkpointed superstep;
  4. the proof: the resumed report's ResultSet — patterns, p-values,
     min_sup, correction factor — is identical to the uninterrupted
     baseline.  Work-stealing trajectories differ, answers never do.

--smoke shrinks the problem for CI (the slow-system job runs it).
"""

import argparse
import os
import tempfile
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--scale-items", type=float, default=0.02)
    ap.add_argument("--ckpt-period", type=int, default=4,
                    help="supersteps between frontier checkpoints")
    ap.add_argument("--die-after", type=int, default=2,
                    help="checkpointed segments to survive before the "
                         "injected kill")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: tiny problem, fast checkpoints")
    args = ap.parse_args()
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}"
    )
    if args.smoke:
        args.scale_items = min(args.scale_items, 0.01)

    import jax

    from repro.api import (
        Dataset, MinerSession, RuntimeConfig, SignificantPatternQuery,
    )
    from repro.testing import FaultPlan, SimulatedFault, injected

    ds = Dataset.from_paper_problem("hapmap_dom_10", args.scale_items, 1.0)
    spec = ds.spec
    print(f"problem: {spec.name} scaled to {spec.n_items} items x "
          f"{spec.n_transactions} transactions")

    runtime = RuntimeConfig(expand_batch=8, ckpt_period=args.ckpt_period)
    query = SignificantPatternQuery(alpha=0.05)
    devices = jax.devices()

    # 1. the uninterrupted reference answer on the full device set
    t0 = time.time()
    baseline = MinerSession(devices, runtime=runtime).run(ds, query)
    print(f"\nbaseline on {len(devices)} miners in {time.time()-t0:.1f}s: "
          f"min_sup={baseline.min_sup} k={baseline.correction_factor} "
          f"significant={baseline.n_significant}")

    with tempfile.TemporaryDirectory(prefix="ft_mine_") as ckpt_dir:
        # 2. same mine, checkpointing every --ckpt-period supersteps, with
        #    a simulated host death after --die-after completed segments
        plan = FaultPlan(die_after_segments=args.die_after)
        try:
            with injected(plan):
                MinerSession(devices, runtime=runtime).run(
                    ds, query, ckpt_dir=ckpt_dir)
            raise SystemExit("fault never fired — problem too small? "
                             "lower --ckpt-period")
        except SimulatedFault as exc:
            print(f"\ninjected kill: {exc}")
        saved = sorted(os.listdir(ckpt_dir))
        print(f"checkpoints on disk: {saved}")

        # 3. elastic resume on HALF the devices: the frontier saved at
        #    {len(devices)} miners is re-dealt onto the smaller mesh
        half = devices[: max(1, len(devices) // 2)]
        t0 = time.time()
        resumed = MinerSession(half, runtime=runtime).run(
            ds, query, resume_from=ckpt_dir)
        n_resumed = [p.mode for p in resumed.phases if p.resumed]
        print(f"\nresumed on {len(half)} miners in {time.time()-t0:.1f}s "
              f"(phases restored from checkpoint: {n_resumed}): "
              f"min_sup={resumed.min_sup} k={resumed.correction_factor} "
              f"significant={resumed.n_significant}")

    # 4. bit-identical answers, different trajectories
    base_pats = [(p.items, p.support, p.pvalue)
                 for p in baseline.results.patterns]
    res_pats = [(p.items, p.support, p.pvalue)
                for p in resumed.results.patterns]
    assert base_pats == res_pats, "resumed ResultSet diverged from baseline"
    assert (baseline.min_sup, baseline.correction_factor,
            baseline.n_significant) == (resumed.min_sup,
                                        resumed.correction_factor,
                                        resumed.n_significant)
    print(f"\nOK: {len(res_pats)} patterns bit-identical across the kill, "
          f"the resume, and the {len(devices)}->{len(half)} reshard")


if __name__ == "__main__":
    main()
