"""End-to-end GWAS-style significant pattern mining at paper-problem scale
(scaled to CPU), with fault-tolerant restart of the mining engine.

  PYTHONPATH=src python examples/gwas_mining.py [--devices 8]

Demonstrates: the three LAMP phases on a Table-1-matched problem, the GLB vs
naive comparison, and checkpoint/restart of a long search (kill-resume).
"""

import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    args = ap.parse_args()
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}"
    )

    import numpy as np

    from repro.core.engine import EngineConfig, lamp_distributed, mine
    from repro.data.synthetic import paper_problem

    db, labels, planted, spec = paper_problem("hapmap_dom_10", 0.05, 1.0)
    print(f"problem: {spec.name} scaled to {spec.n_items} items x "
          f"{spec.n_transactions} transactions (density {spec.density:.3f})")

    cfg = EngineConfig(expand_batch=16, trace_cap=8192)
    t0 = time.time()
    res = lamp_distributed(db, labels, alpha=0.05, cfg=cfg)
    print(f"\nthree-phase LAMP in {time.time()-t0:.1f}s: "
          f"lambda={res['lambda_final']} min_sup={res['min_sup']} "
          f"k={res['correction_factor']} significant={res['n_significant']}")

    rs = res["results"]
    print("\n" + rs.describe(10, planted=planted))

    p2 = res["phase_outputs"][1]
    work = p2.stats["popped"]
    print(f"phase-2 work per miner: min={work.min()} mean={work.mean():.0f} "
          f"max={work.max()}  (imbalance {work.max()/max(work.mean(),1):.2f}x, "
          f"steals={p2.stats['steals_got'].sum()})")

    naive = mine(db, labels, mode="count", min_sup=res["min_sup"],
                 cfg=EngineConfig(expand_batch=16, steal_enabled=False))
    nwork = naive.stats["popped"]
    print(f"naive split (no stealing): imbalance "
          f"{nwork.max()/max(nwork.mean(),1):.2f}x  — the paper's §5.4 gap")


if __name__ == "__main__":
    main()
