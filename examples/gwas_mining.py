"""End-to-end GWAS-style significant pattern mining at paper-problem scale
(scaled to CPU), on the session API.

  PYTHONPATH=src python examples/gwas_mining.py [--devices 8]

Demonstrates: the three LAMP phases on a Table-1-matched problem via a
compile-once `MinerSession` driven by first-class `Query` objects, the
mined itemsets printed with SNP names, a chi-square query reusing the warm
lamp1/count programs (only the statistic's own test program compiles), the
GLB vs naive comparison, and a warm repeat query with zero recompiles.
"""

import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    args = ap.parse_args()
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}"
    )

    from repro.api import (
        Dataset, MinerSession, RuntimeConfig, SignificantPatternQuery,
    )

    ds = Dataset.from_paper_problem("hapmap_dom_10", 0.05, 1.0)
    spec = ds.spec
    print(f"problem: {spec.name} scaled to {spec.n_items} items x "
          f"{spec.n_transactions} transactions (density {spec.density:.3f})")

    session = MinerSession(
        runtime=RuntimeConfig(expand_batch=16, trace_period=1, trace_cap=8192)
    )
    t0 = time.time()
    report = session.run(ds, SignificantPatternQuery(alpha=0.05))
    print(f"\nthree-phase LAMP in {time.time()-t0:.1f}s: "
          f"lambda={report.lambda_final} min_sup={report.min_sup} "
          f"k={report.correction_factor} significant={report.n_significant}")

    print("\n" + report.results.describe(10, planted=ds.planted))

    # same engine, different test: the chi-square query shares the session's
    # warm lamp1/count programs — only its own emission test compiles
    before = session.cache_info()
    rep_chi2 = session.run(ds, SignificantPatternQuery(alpha=0.05,
                                                       statistic="chi2"))
    extra = session.cache_info().misses - before.misses
    print(f"\nchi2 query on the same session: "
          f"significant={rep_chi2.n_significant} "
          f"({extra} new compile{'s' if extra != 1 else ''} — "
          f"lamp1/count programs are statistic-free and stay warm)")

    p2 = report.phases[1]
    work = p2.stats["popped"]
    print(f"phase-2 work per miner: min={work.min()} mean={work.mean():.0f} "
          f"max={work.max()}  (imbalance {work.max()/max(work.mean(),1):.2f}x, "
          f"steals={p2.steals})")

    # the decoded device superstep trace (DESIGN.md §9): the paper's "evenly
    # distributed communication" claim, measured per superstep per miner
    tr = p2.trace
    print(f"phase-2 trace: {tr.n_steps} supersteps sampled, steal exchange "
          f"fired {int(tr.fired.sum())}x, donation fairness "
          f"{tr.donation_fairness():.2f}, work fairness "
          f"{tr.work_fairness():.2f}, idle fraction "
          f"{tr.idle_fraction().mean():.2f} mean")

    # paper §5.4: same search without stealing — a separate runtime config,
    # hence separate compiled programs, in a session of its own
    naive_session = MinerSession(
        runtime=RuntimeConfig(expand_batch=16, steal_enabled=False)
    )
    naive = naive_session.run_phase(ds, "count", min_sup=report.min_sup)
    nwork = naive.output.stats["popped"]
    print(f"naive split (no stealing): imbalance "
          f"{nwork.max()/max(nwork.mean(),1):.2f}x  — the paper's §5.4 gap")

    # warm repeat: a fresh same-shape dataset reuses every compiled program
    ds2 = Dataset.from_paper_problem("hapmap_dom_10", 0.05, 1.0, seed=1)
    before = session.cache_info()
    rep2 = session.run(ds2, SignificantPatternQuery(alpha=0.05))
    assert session.cache_info().misses == before.misses
    print(f"\nwarm repeat query ({ds2.name} reseeded): {rep2.wall_s:.2f}s vs "
          f"cold {report.wall_s:.2f}s, zero new compiles")
    print(session.cache_info())


if __name__ == "__main__":
    main()
