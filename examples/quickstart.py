"""Quickstart: significant pattern mining (LAMP) on a small synthetic GWAS
matrix — sequential oracle vs the distributed BSP engine, in ~20 seconds.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.engine import EngineConfig, lamp_distributed
from repro.core.lamp import lamp
from repro.data.synthetic import SyntheticSpec, generate
from repro.results import score_planted


def main():
    spec = SyntheticSpec(
        name="demo", n_items=120, n_transactions=300, density=0.06, n_pos=100,
        n_planted=2, planted_pos_rate=0.7, planted_neg_rate=0.03, seed=1,
    )
    db, labels, planted = generate(spec)
    print(f"dataset: {spec.n_items} items x {spec.n_transactions} transactions, "
          f"{spec.n_pos} positives; planted itemsets: {planted}")

    # --- sequential reference (host numpy LCM+LAMP)
    ref = lamp(db, labels, alpha=0.05)
    print(f"\n[sequential] lambda={ref.lambda_final} min_sup={ref.min_sup} "
          f"closed@min_sup={ref.correction_factor} delta={ref.delta:.2e} "
          f"significant={len(ref.significant)}")
    for s in ref.significant[:5]:
        print(f"   items={sorted(s.items)} support={s.support} "
              f"pos={s.pos_support} p={s.pvalue:.3e}")

    # --- distributed BSP engine (all local devices; same three phases)
    res = lamp_distributed(db, labels, alpha=0.05,
                           cfg=EngineConfig(expand_batch=16))
    print(f"\n[engine]     lambda={res['lambda_final']} min_sup={res['min_sup']} "
          f"closed@min_sup={res['correction_factor']} delta={res['delta']:.2e} "
          f"significant={res['n_significant']}")
    rs = res["results"]  # the mined patterns themselves, not just the count
    for p in rs.top(5):
        print(f"   items={list(p.items)} support={p.support} "
              f"pos={p.pos_support} p={p.pvalue:.3e} q={p.qvalue:.3e}")
    score = score_planted(rs, planted)
    print(f"planted itemsets recovered: {len(score['recovered'])}/"
          f"{score['n_planted']} (recall {score['recall']:.2f})")

    assert res["min_sup"] == ref.min_sup
    assert res["correction_factor"] == ref.correction_factor
    assert res["n_significant"] == len(ref.significant)
    got = {(p.items, p.support, p.pos_support) for p in rs}
    want = {(tuple(sorted(s.items)), s.support, s.pos_support)
            for s in ref.significant if s.items}
    assert got == want, "engine pattern identities must match the oracle"
    print("\nengine patterns match the sequential oracle — OK")


if __name__ == "__main__":
    main()
