"""Quickstart: significant pattern mining (LAMP) on a small synthetic GWAS
matrix — sequential oracle vs the session-based distributed BSP engine, in
~20 seconds.

  PYTHONPATH=src python examples/quickstart.py

Shows the canonical API (repro.api): a `Dataset` packed once, a
`MinerSession` whose compiled programs are cached, first-class `Query`
objects executed via `session.run(...)` (a typed `MineReport` each), and a
second (warm) query that reuses every compiled program.
"""

from repro.api import (
    Dataset,
    MinerSession,
    RuntimeConfig,
    SignificantPatternQuery,
)
from repro.core.lamp import lamp
from repro.data.synthetic import SyntheticSpec, generate
from repro.results import score_planted


def main():
    spec = SyntheticSpec(
        name="demo", n_items=120, n_transactions=300, density=0.06, n_pos=100,
        n_planted=2, planted_pos_rate=0.7, planted_neg_rate=0.03, seed=1,
    )
    db, labels, planted = generate(spec)
    print(f"dataset: {spec.n_items} items x {spec.n_transactions} transactions, "
          f"{spec.n_pos} positives; planted itemsets: {planted}")

    # --- sequential reference (host numpy LCM+LAMP)
    ref = lamp(db, labels, alpha=0.05)
    print(f"\n[sequential] lambda={ref.lambda_final} min_sup={ref.min_sup} "
          f"closed@min_sup={ref.correction_factor} delta={ref.delta:.2e} "
          f"significant={len(ref.significant)}")
    for s in ref.significant[:5]:
        print(f"   items={sorted(s.items)} support={s.support} "
              f"pos={s.pos_support} p={s.pvalue:.3e}")

    # --- distributed BSP engine behind the session API (all local devices)
    session = MinerSession(runtime=RuntimeConfig(expand_batch=16))
    ds = Dataset.from_dense(
        db, labels, name="demo",
        item_names=[f"snp{j:05d}" for j in range(spec.n_items)],
    )
    # session.run(dataset, query): the query object IS the objective —
    # swap statistic="chi2", or a ClosedFrequentQuery/TopKSignificantQuery,
    # without touching the engine (session.mine(ds) builds this same query)
    query = SignificantPatternQuery(alpha=0.05, statistic="fisher")
    report = session.run(ds, query)   # cold: compiles one program per phase
    print(f"\n[engine]     lambda={report.lambda_final} min_sup={report.min_sup} "
          f"closed@min_sup={report.correction_factor} delta={report.delta:.2e} "
          f"significant={report.n_significant}")
    rs = report.results  # the mined patterns themselves, not just the count
    for p in rs.top(5):
        print(f"   items={rs.names_of(p)} support={p.support} "
              f"pos={p.pos_support} p={p.pvalue:.3e} q={p.qvalue:.3e}")
    score = score_planted(rs, planted)
    print(f"planted itemsets recovered: {len(score['recovered'])}/"
          f"{score['n_planted']} (recall {score['recall']:.2f})")

    assert report.min_sup == ref.min_sup
    assert report.correction_factor == ref.correction_factor
    assert report.n_significant == len(ref.significant)
    got = {(p.items, p.support, p.pos_support) for p in rs}
    want = {(tuple(sorted(s.items)), s.support, s.pos_support)
            for s in ref.significant if s.items}
    assert got == want, "engine pattern identities must match the oracle"
    print("\nengine patterns match the sequential oracle — OK")

    # --- repeat query on a warm session: zero new compiles
    db2, labels2, _ = generate(SyntheticSpec(
        name="demo2", n_items=120, n_transactions=300, density=0.06, n_pos=100,
        n_planted=2, planted_pos_rate=0.7, planted_neg_rate=0.03, seed=2,
    ))
    before = session.cache_info()
    report2 = session.run(Dataset.from_dense(db2, labels2, name="demo2"), query)
    after = session.cache_info()
    assert after.misses == before.misses, "warm query must not recompile"
    print(f"warm repeat query: {report2.wall_s:.3f}s vs cold "
          f"{report.wall_s:.3f}s — zero new compiles "
          f"({after.hits} cache hits)\n{after}")


if __name__ == "__main__":
    main()
