"""Batched serving demo: prefill + iterative decode with a KV cache, using
the same serve-step programs the dry-run lowers for the production mesh.

  PYTHONPATH=src python examples/serve_lm.py --arch qwen3-14b --gen 24
"""

import argparse

from repro.launch import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    serve.main([
        "--arch", args.arch, "--preset", "tiny", "--batch", "4",
        "--prompt-len", "32", "--gen", str(args.gen), "--requests", "8",
    ])


if __name__ == "__main__":
    main()
