"""Fisher exact test + Tarone bound vs scipy oracles."""

import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")  # dev dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st
from scipy import stats as sps

from repro.core.fisher import (
    fisher_pvalue,
    fisher_pvalue_jnp,
    lamp_count_thresholds,
    min_attainable_pvalue,
    min_attainable_pvalue_jnp,
)


@given(
    N=st.integers(4, 120),
    data=st.data(),
)
@settings(max_examples=80, deadline=None)
def test_fisher_matches_scipy(N, data):
    N_pos = data.draw(st.integers(1, N - 1))
    x = data.draw(st.integers(1, N))
    n = data.draw(st.integers(max(0, x - (N - N_pos)), min(x, N_pos)))
    p = fisher_pvalue(x, n, N, N_pos)[0]
    table = [[n, x - n], [N_pos - n, (N - N_pos) - (x - n)]]
    p_ref = sps.fisher_exact(table, alternative="greater")[1]
    assert p == pytest.approx(p_ref, rel=1e-9, abs=1e-12)


@given(N=st.integers(4, 200), data=st.data())
@settings(max_examples=60, deadline=None)
def test_min_attainable_is_lower_bound_and_attained(N, data):
    N_pos = data.draw(st.integers(1, N - 1))
    x = data.draw(st.integers(1, N))
    f = min_attainable_pvalue(x, N, N_pos)
    n_star = min(x, N_pos)
    # attained at n = n_star
    p_at = fisher_pvalue(x, n_star, N, N_pos)[0]
    assert f == pytest.approx(p_at, rel=1e-9, abs=1e-12)
    # lower-bounds every achievable n
    lo = max(0, x - (N - N_pos))
    for n in range(lo, n_star + 1):
        assert fisher_pvalue(x, n, N, N_pos)[0] >= f - 1e-12


def test_min_attainable_monotone_up_to_npos():
    N, N_pos = 120, 30
    f = min_attainable_pvalue(np.arange(0, N_pos + 1), N, N_pos)
    assert np.all(np.diff(f) <= 1e-15)


def test_threshold_table_monotone_and_capped():
    N, N_pos, alpha = 100, 25, 0.05
    thr = lamp_count_thresholds(N, N_pos, alpha)
    # monotone non-decreasing over the valid range
    assert np.all(np.diff(thr[1 : N_pos + 2]) >= -1e-9)
    assert thr[1] == pytest.approx(alpha)  # f(0) = 1
    assert np.all(np.isinf(thr[N_pos + 2 :]))


def test_jnp_matches_numpy():
    N, N_pos = 97, 23
    rng = np.random.default_rng(0)
    x = rng.integers(1, N, size=64)
    n = np.minimum(x, rng.integers(0, N_pos + 1, size=64))
    n = np.maximum(n, np.maximum(0, x - (N - N_pos)))
    p_np = fisher_pvalue(x, n, N, N_pos)
    p_j = np.asarray(fisher_pvalue_jnp(x, n, N, N_pos))
    np.testing.assert_allclose(p_j, p_np, rtol=2e-4, atol=1e-7)
    f_np = min_attainable_pvalue(x, N, N_pos)
    f_j = np.asarray(min_attainable_pvalue_jnp(x, N, N_pos))
    np.testing.assert_allclose(f_j, f_np, rtol=2e-4, atol=1e-7)
