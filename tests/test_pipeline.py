"""Data pipeline: determinism (restart replay), packing, label masking."""

import numpy as np

from repro.data.pipeline import DataConfig, make_batch
from repro.data.synthetic import PAPER_PROBLEMS, SyntheticSpec, generate


def test_batches_deterministic_by_step():
    cfg = DataConfig(vocab=512, seq_len=64, global_batch=8, grad_accum=2)
    a = make_batch(cfg, step=7)
    b = make_batch(cfg, step=7)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    c = make_batch(cfg, step=8)
    assert not np.array_equal(a["inputs"], c["inputs"])


def test_batch_shapes_and_masking():
    cfg = DataConfig(vocab=512, seq_len=128, global_batch=8, grad_accum=4)
    b = make_batch(cfg, 0)
    assert b["inputs"].shape == (4, 2, 128)
    assert b["labels"].shape == (4, 2, 128)
    assert b["positions"].shape == (4, 2, 128)
    assert b["inputs"].min() >= 0 and b["inputs"].max() < 512
    # document boundaries are masked with -1 (never predicted across docs)
    assert (b["labels"] == -1).sum() >= 0
    valid = b["labels"] >= 0
    assert valid.mean() > 0.8


def test_labels_are_shifted_inputs():
    cfg = DataConfig(vocab=512, seq_len=64, global_batch=4, mean_doc_len=1e9)
    b = make_batch(cfg, 0)
    # single huge doc -> labels == inputs shifted by one
    np.testing.assert_array_equal(b["labels"][0, 0, :-1], b["inputs"][0, 0, 1:])


def test_embed_inputs_stub():
    cfg = DataConfig(vocab=128, seq_len=32, global_batch=2, embed_inputs=True,
                     d_model=16)
    b = make_batch(cfg, 0)
    assert b["inputs"].shape == (1, 2, 32, 16)
    assert b["inputs"].dtype == np.float32


def test_mrope_positions():
    cfg = DataConfig(vocab=128, seq_len=32, global_batch=2, m_rope=True)
    b = make_batch(cfg, 0)
    assert b["positions"].shape == (1, 2, 32, 3)


def test_synthetic_matches_spec_stats():
    spec = SyntheticSpec(name="x", n_items=300, n_transactions=400,
                         density=0.05, n_pos=100, n_planted=0, seed=3)
    db, labels, _ = generate(spec)
    assert db.shape == (400, 300)
    assert labels.sum() == 100
    got_density = db.mean()
    assert abs(got_density - 0.05) / 0.05 < 0.5  # skewed marginals, mean close


def test_paper_problem_registry_complete():
    assert set(PAPER_PROBLEMS) == {
        "hapmap_dom_10", "hapmap_dom_20", "alz_dom_5", "alz_dom_10",
        "alz_rec_30", "mcf7",
    }
