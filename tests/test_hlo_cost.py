"""HLO cost parser vs XLA cost_analysis (and scan trip-count handling)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax import lax

from repro.core.collectives import normalize_cost_analysis
from repro.launch.hlo_cost import parse_hlo_costs


def test_matches_cost_analysis_unrolled():
    @jax.jit
    def f(x, w1, w2):
        h = jnp.einsum("bd,df->bf", x, w1)
        return jnp.einsum("bf,fd->bd", jnp.tanh(h), w2)

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w1 = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w2 = jax.ShapeDtypeStruct((256, 128), jnp.float32)
    comp = f.lower(x, w1, w2).compile()
    got = parse_hlo_costs(comp.as_text())
    want = normalize_cost_analysis(comp.cost_analysis())["flops"]
    theory = 2 * 64 * 128 * 256 * 2
    assert got["flops"] == pytest.approx(theory, rel=0.01)
    assert got["flops"] == pytest.approx(want, rel=0.05)


def test_scan_trip_count_multiplied():
    N = 8

    @jax.jit
    def f(x, ws):
        y, _ = lax.scan(lambda c, w: (jnp.einsum("bd,df->bf", c, w), None), x, ws)
        return y

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((N, 64, 64), jnp.float32)
    comp = f.lower(x, ws).compile()
    got = parse_hlo_costs(comp.as_text())
    theory = 2 * 32 * 64 * 64 * N
    assert got["flops"] == pytest.approx(theory, rel=0.02), got["flops"]
    # XLA's own analysis counts the body once -> we must exceed it ~N-fold
    assert got["flops"] > 4 * normalize_cost_analysis(comp.cost_analysis())["flops"]


def test_nested_scan():
    @jax.jit
    def f(x, ws):
        def outer(c, w):
            def inner(ci, _):
                return jnp.einsum("bd,df->bf", ci, w), None
            y, _ = lax.scan(inner, c, None, length=3)
            return y, None
        y, _ = lax.scan(outer, x, ws)
        return y

    x = jax.ShapeDtypeStruct((16, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, 32, 32), jnp.float32)
    comp = f.lower(x, ws).compile()
    got = parse_hlo_costs(comp.as_text())
    theory = 2 * 16 * 32 * 32 * 3 * 4
    assert got["flops"] == pytest.approx(theory, rel=0.05), got["flops"]


def test_collective_bytes_parsed():
    import os
    devs = jax.devices()
    if len(devs) < 2:
        pytest.skip("needs >1 device (run in dryrun subprocess instead)")


def test_collective_bytes_in_subprocess():
    """ppermute/psum byte accounting with forced multi-device CPU."""
    import json
    import os
    import subprocess
    import sys
    import textwrap

    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import json, sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.hlo_cost import parse_hlo_costs

        mesh = jax.make_mesh((4,), ("d",))

        @jax.jit
        def f(x):
            y = jax.lax.with_sharding_constraint(
                jnp.broadcast_to(x.sum(), (128, 128)), NamedSharding(mesh, P()))
            return y

        x = jax.ShapeDtypeStruct((128, 128), jnp.float32,
                                 sharding=NamedSharding(mesh, P("d", None)))
        comp = jax.jit(lambda x: x.sum()).lower(x).compile()
        got = parse_hlo_costs(comp.as_text())
        print(json.dumps(got["coll_payload"]))
    """)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."), timeout=300,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    assert "all-reduce" in payload and payload["all-reduce"] >= 4.0
