"""Per-architecture smoke tests on reduced configs (CPU).

For every assigned architecture: instantiate the reduced same-family config,
run one forward/train step and (where supported) prefill+decode; assert output
shapes and the absence of NaNs.  Also checks that the partition-spec tree
mirrors the parameter tree exactly (structure drift guard).
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, get_config
from repro.models import nn
from repro.models.transformer import (
    abstract_cache, abstract_params, cache_partition_specs, forward_decode,
    forward_prefill, forward_train, init_cache, init_params,
    param_partition_specs,
)

B, S = 2, 32


def make_batch(cfg, key):
    kt, kl, ke = jax.random.split(key, 3)
    if cfg.embed_inputs:
        inputs = jax.random.normal(ke, (B, S, cfg.d_model), jnp.float32)
    else:
        inputs = jax.random.randint(kt, (B, S), 0, cfg.vocab)
    labels = jax.random.randint(kl, (B, S), 0, cfg.vocab)
    if cfg.m_rope_sections:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :, None], (B, S, 3))
    else:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    return {"inputs": inputs, "labels": labels, "positions": positions}


@pytest.fixture(scope="module")
def reduced_setups():
    out = {}
    for arch in ALL_ARCHS:
        cfg = get_config(arch).reduced()
        params = init_params(cfg, jax.random.PRNGKey(0))
        out[arch] = (cfg, params)
    return out


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_loss_finite(arch, reduced_setups):
    cfg, params = reduced_setups[arch]
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    loss, grads = jax.value_and_grad(
        lambda p: forward_train(p, cfg, batch)
    )(params)
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    leaves = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g))) for g in leaves), f"{arch}: bad grads"
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in leaves) ** 0.5
    assert gnorm > 0.0, f"{arch}: zero gradient"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_and_decode(arch, reduced_setups):
    cfg, params = reduced_setups[arch]
    batch = make_batch(cfg, jax.random.PRNGKey(2))
    if "decode_32k" not in cfg.supported_shapes:
        # encoder-only: prefill (forward) only, no cache
        logits, _ = forward_prefill(params, cfg, batch, None)
        assert logits.shape == (B, cfg.vocab)
        assert np.all(np.isfinite(np.asarray(logits)))
        return
    cache = init_cache(cfg, B, max_len=S + 8)
    logits, cache = forward_prefill(params, cfg, batch, cache)
    assert logits.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))
    assert int(cache["len"]) == S
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    for _ in range(3):
        logits, cache = forward_decode(params, cfg, tok, cache)
        assert logits.shape == (B, cfg.vocab)
        assert np.all(np.isfinite(np.asarray(logits)))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    assert int(cache["len"]) == S + 3


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_prefill_logits(arch, reduced_setups):
    """Prefill of N tokens == prefill of N-1 then decode of token N."""
    cfg, params = reduced_setups[arch]
    if "decode_32k" not in cfg.supported_shapes or cfg.embed_inputs:
        pytest.skip("no token decode path")
    batch = make_batch(cfg, jax.random.PRNGKey(3))
    cache_a = init_cache(cfg, B, max_len=S + 8)
    logits_a, _ = forward_prefill(params, cfg, batch, cache_a)

    short = {
        "inputs": batch["inputs"][:, : S - 1],
        "labels": batch["labels"][:, : S - 1],
        "positions": batch["positions"][:, : S - 1],
    }
    cache_b = init_cache(cfg, B, max_len=S + 8)
    _, cache_b = forward_prefill(params, cfg, short, cache_b)
    logits_b, _ = forward_decode(params, cfg, batch["inputs"][:, S - 1 :], cache_b)
    np.testing.assert_allclose(
        np.asarray(logits_a), np.asarray(logits_b), rtol=5e-2, atol=5e-2
    )


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_partition_specs_mirror_params(arch, reduced_setups):
    cfg, params = reduced_setups[arch]
    specs = param_partition_specs(cfg)
    s1 = jax.tree.structure(params)
    s2 = jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
    )
    assert s1 == s2, f"{arch}: spec tree != param tree\n{s1}\n{s2}"
    if "decode_32k" in cfg.supported_shapes:
        cache = abstract_cache(cfg, B, 64)
        cspecs = cache_partition_specs(cfg, cache)
        assert jax.tree.structure(cache) == jax.tree.structure(
            cspecs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
        )


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_abstract_params_match_real(arch, reduced_setups):
    cfg, params = reduced_setups[arch]
    ab = abstract_params(cfg)
    real_shapes = jax.tree.map(lambda x: (x.shape, str(x.dtype)), params)
    ab_shapes = jax.tree.map(lambda x: (x.shape, str(x.dtype)), ab)
    assert real_shapes == ab_shapes


def test_full_config_abstract_param_counts():
    """Full (unreduced) configs: abstract init must land near published sizes."""
    expected = {
        "qwen3-14b": 14.8e9, "minitron-4b": 4.2e9, "granite-3-2b": 2.5e9,
        "command-r-plus-104b": 107e9, "phi3.5-moe-42b-a6.6b": 42e9,
        "dbrx-132b": 132e9, "recurrentgemma-9b": 9.3e9,
        "hubert-xlarge": 0.96e9, "qwen2-vl-2b": 1.8e9,
        # xlstm: full (non-block-diagonal) qkv projections + untied embeddings
        # land at ~0.19B for the 125m layer plan (see DESIGN.md)
        "xlstm-125m": 0.19e9,
    }
    for arch, want in expected.items():
        cfg = get_config(arch)
        n = nn.count_params(abstract_params(cfg))
        assert abs(n - want) / want < 0.15, f"{arch}: {n/1e9:.2f}B vs {want/1e9:.2f}B"
