"""Flash-attention Pallas kernel vs naive softmax oracle (interpret mode)."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels.flash_attn.ops import flash_attention
from repro.kernels.flash_attn.ref import attention_ref


def make_qkv(rng, b, hq, hkv, sq, skv, d, dtype):
    q = rng.normal(size=(b, hq, sq, d)).astype(dtype)
    k = rng.normal(size=(b, hkv, skv, d)).astype(dtype)
    v = rng.normal(size=(b, hkv, skv, d)).astype(dtype)
    return q, k, v


def ref_gqa(q, k, v, causal):
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    out = attention_ref(
        q.reshape(b * hq, sq, d), k.reshape(b * hq, skv, d), v.reshape(b * hq, skv, d),
        causal=causal, q_offset=skv - sq if causal else 0,
    )
    return np.asarray(out).reshape(b, hq, sq, d)


@pytest.mark.parametrize("sq,skv", [(128, 128), (256, 256), (128, 384), (100, 100), (257, 300)])
@pytest.mark.parametrize("causal", [True, False])
def test_shapes_and_causal(sq, skv, causal):
    rng = np.random.default_rng(sq + skv)
    q, k, v = make_qkv(rng, 1, 2, 2, sq, skv, 64, np.float32)
    got = np.asarray(flash_attention(q, k, v, causal=causal, interpret=True))
    want = ref_gqa(q, k, v, causal)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
def test_gqa_head_groups(hq, hkv):
    rng = np.random.default_rng(hq * 10 + hkv)
    q, k, v = make_qkv(rng, 2, hq, hkv, 128, 128, 32, np.float32)
    got = np.asarray(flash_attention(q, k, v, causal=True, interpret=True))
    want = ref_gqa(q, k, v, True)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(np.float32, 2e-5), (jnp.bfloat16, 3e-2)])
def test_dtypes(dtype, tol):
    rng = np.random.default_rng(9)
    q, k, v = make_qkv(rng, 1, 2, 2, 128, 128, 64, np.float32)
    q, k, v = (x.astype(dtype) for x in (q, k, v))
    got = np.asarray(flash_attention(q, k, v, causal=True, interpret=True), dtype=np.float32)
    want = ref_gqa(
        jnp.asarray(q, jnp.float32), jnp.asarray(k, jnp.float32), jnp.asarray(v, jnp.float32), True
    )
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@pytest.mark.parametrize("block_q,block_k", [(128, 128), (128, 256), (256, 128)])
def test_block_sweep(block_q, block_k):
    rng = np.random.default_rng(11)
    q, k, v = make_qkv(rng, 1, 2, 2, 300, 300, 64, np.float32)
    got = np.asarray(
        flash_attention(q, k, v, causal=True, block_q=block_q, block_k=block_k, interpret=True)
    )
    want = ref_gqa(q, k, v, True)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_scale_override():
    rng = np.random.default_rng(13)
    q, k, v = make_qkv(rng, 1, 1, 1, 128, 128, 64, np.float32)
    got = np.asarray(flash_attention(q, k, v, causal=False, scale=0.5, interpret=True))
    want = np.asarray(
        attention_ref(q.reshape(1, 128, 64), k.reshape(1, 128, 64), v.reshape(1, 128, 64),
                      causal=False, scale=0.5)
    ).reshape(1, 1, 128, 64)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
