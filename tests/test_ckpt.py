"""Direct unit tests for repro.ckpt.checkpoint: crash-safe publish, per-leaf
checksums, corruption detection/fallback, elastic restore (DESIGN.md §11).

The crash-window tests use the deterministic fault points in
`repro.testing.faults`: a writer killed after staging but before the
publish renames must leave the previous step fully restorable, and byte
rot in a published payload must be caught by the per-leaf crc32s and
skipped by `restore_latest`'s newest-valid fallback.
"""

import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp
import ml_dtypes

from repro.ckpt import checkpoint as ckpt
from repro.testing import FaultPlan, SimulatedFault, corrupt_step_dir, injected


def tree_eq(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.asarray(x).dtype == np.asarray(y).dtype
        and np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb)
    )


# ---------------------------------------------------------------- roundtrip
def test_roundtrip_view_as_dtypes(tmp_path):
    """bf16/fp8 leaves ride npz as integer views and come back bit-exact."""
    tree = {
        "bf16": jnp.arange(8, dtype=jnp.bfloat16) / 3,
        "e4m3": jnp.ones(4, jnp.float8_e4m3fn) * 1.5,
        "e5m2": jnp.full(3, 0.25, jnp.float8_e5m2),
        "f32": jnp.linspace(0, 1, 5),
        "i32": jnp.int32(11),
    }
    ckpt.save(tree, str(tmp_path), 3, meta={"tag": "v"})
    restored, manifest = ckpt.restore(str(tmp_path), 3, tree)
    assert manifest["meta"]["tag"] == "v"
    assert tree_eq(tree, restored)
    # the raw reader also undoes the views
    data, _ = ckpt.load_step(str(tmp_path), 3)
    assert data["bf16"].dtype == ml_dtypes.bfloat16
    assert data["e4m3"].dtype == ml_dtypes.float8_e4m3fn


def test_prune_keeps_newest_k(tmp_path):
    tree = {"w": jnp.zeros(2)}
    for s in range(1, 6):
        ckpt.save(tree, str(tmp_path), s, keep=3)
    assert ckpt.list_steps(str(tmp_path)) == [3, 4, 5]


def test_list_steps_ignores_junk(tmp_path):
    ckpt.save({"w": jnp.zeros(2)}, str(tmp_path), 7)
    # junk that must be invisible: leftover tmp/aside dirs, a step dir with
    # no manifest, non-step names
    os.makedirs(tmp_path / ".tmp_step_9")
    os.makedirs(tmp_path / ".old_step_7")
    os.makedirs(tmp_path / "step_8")          # no manifest inside
    os.makedirs(tmp_path / "step_x")
    (tmp_path / "notes.txt").write_text("hi")
    assert ckpt.list_steps(str(tmp_path)) == [7]
    assert ckpt.latest_step(str(tmp_path)) == 7


# -------------------------------------------------------------- error paths
def test_restore_missing_leaf_and_shape_mismatch(tmp_path):
    ckpt.save({"a": jnp.zeros(3)}, str(tmp_path), 1)
    with pytest.raises(KeyError, match="missing leaf"):
        ckpt.restore(str(tmp_path), 1, {"a": jnp.zeros(3), "b": jnp.zeros(2)})
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore(str(tmp_path), 1, {"a": jnp.zeros(4)})


def test_elastic_restore_resharding(tmp_path):
    """Arrays saved unsharded restore against a new mesh's shardings."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    n = len(jax.devices())
    tree = {"x": jnp.arange(4 * n, dtype=jnp.float32).reshape(n, 4)}
    ckpt.save(tree, str(tmp_path), 1)
    mesh = Mesh(np.array(jax.devices()), ("d",))
    shardings = {"x": NamedSharding(mesh, P("d", None))}
    restored, _ = ckpt.restore(str(tmp_path), 1, tree, shardings)
    assert restored["x"].sharding == shardings["x"]
    assert np.array_equal(np.asarray(restored["x"]), np.asarray(tree["x"]))


# ------------------------------------------------------------- crash window
def test_crash_before_publish_keeps_old_step(tmp_path):
    """A writer killed between staging and publishing must leave the
    previous step untouched and restorable (the overwrite-window fix)."""
    tree1 = {"w": jnp.full(3, 1.0)}
    tree2 = {"w": jnp.full(3, 2.0)}
    ckpt.save(tree1, str(tmp_path), 5)
    # second write OF THE SAME STEP dies after staging, before the renames
    with injected(FaultPlan(die_in_ckpt_write=0)):
        with pytest.raises(SimulatedFault):
            ckpt.save(tree2, str(tmp_path), 5)
    restored, _ = ckpt.restore(str(tmp_path), 5, tree1)
    assert tree_eq(tree1, restored)          # old bytes, not the new ones
    assert ckpt.list_steps(str(tmp_path)) == [5]
    # a later clean write of the same step succeeds over the leftovers
    ckpt.save(tree2, str(tmp_path), 5)
    restored, _ = ckpt.restore(str(tmp_path), 5, tree1)
    assert tree_eq(tree2, restored)


def test_corruption_detected_and_restore_latest_falls_back(tmp_path):
    tree_a = {"w": jnp.arange(64, dtype=jnp.float32)}
    tree_b = {"w": jnp.arange(64, dtype=jnp.float32) * 2}
    ckpt.save(tree_a, str(tmp_path), 1, keep=5)
    ckpt.save(tree_b, str(tmp_path), 2, keep=5)
    corrupt_step_dir(str(tmp_path / "step_2"))
    with pytest.raises(ckpt.CorruptCheckpoint):
        ckpt.load_step(str(tmp_path), 2)
    # newest-valid fallback: step 2 skipped (with a warning), step 1 used
    with pytest.warns(RuntimeWarning, match="skipping corrupt"):
        restored, manifest = ckpt.restore_latest(str(tmp_path), tree_a)
    assert manifest["step"] == 1
    assert tree_eq(tree_a, restored)


def test_restore_latest_empty_dir(tmp_path):
    restored, manifest = ckpt.restore_latest(str(tmp_path), {"w": jnp.zeros(1)})
    assert restored is None and manifest is None
