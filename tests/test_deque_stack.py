"""Circular-deque stack (core/deque.py) vs the old shift-stack semantics.

The deque refactor must be *invisible*: the same pop order at the top, the
same donated nodes at the bottom, the same overflow behavior — only the
physical addressing changed.  A hypothesis property test drives randomized
push/pop/donate/receive sequences through the deque primitives and a NumPy
oracle implementing the pre-deque shift-stack, comparing every externally
visible value.  The engine-level companion
(`test_engine.py::test_sync_period_equivalence`) asserts the full miner's
results are bit-identical across `sync_period` settings.
"""

import numpy as np

try:  # dev dep (requirements-dev.txt); a seeded sweep covers its absence
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.deque import (
    advance_head,
    bottom_indices,
    push_positions,
    top_indices,
)

CAP = 16
STEAL_MAX = 5


class ShiftStackOracle:
    """The pre-deque semantics: slot 0 pinned to row 0, shift on donate."""

    def __init__(self, cap=CAP):
        self.buf = np.zeros(cap, np.int64)
        self.sp = 0
        self.cap = cap

    def push(self, vals):
        vals = vals[: self.cap - self.sp]  # tests never overflow; clamp anyway
        self.buf[self.sp: self.sp + len(vals)] = vals
        self.sp += len(vals)
        return len(vals)

    def pop(self, k):
        k = min(k, self.sp)
        out = self.buf[self.sp - k: self.sp][::-1].copy()  # top-first
        self.sp -= k
        return out

    def donate(self, want):
        k = min(self.sp // 2, want, STEAL_MAX)
        out = self.buf[:k].copy()                     # bottom-k, oldest first
        self.buf[: self.sp - k] = self.buf[k: self.sp]  # the O(cap) shift
        self.sp -= k
        return out

    def receive(self, vals):
        assert self.sp == 0
        self.buf[: len(vals)] = vals
        self.sp = len(vals)


class DequeModel:
    """The same operations through the core/deque.py primitives."""

    def __init__(self, cap=CAP):
        self.buf = np.zeros(cap, np.int64)
        self.sp = 0
        self.head = 0
        self.cap = cap

    def push(self, vals):
        n = len(vals)
        offsets = np.arange(n)
        valid = np.ones(n, bool)
        pos, overflow = push_positions(self.head, self.sp, offsets, valid, self.cap)
        pos, overflow = np.asarray(pos), bool(overflow)
        assert not overflow
        self.buf[pos] = vals
        self.sp += n
        return n

    def pop(self, k):
        k = min(k, self.sp)
        idx = np.asarray(top_indices(self.head, self.sp, np.arange(k), self.cap))
        out = self.buf[idx].copy()                    # top-first by construction
        self.sp -= k
        return out

    def donate(self, want):
        k = min(self.sp // 2, want, STEAL_MAX)
        src = np.asarray(bottom_indices(self.head, np.arange(k), self.cap))
        out = self.buf[src].copy()
        self.head = int(advance_head(self.head, k, self.cap))
        self.sp -= k
        return out

    def receive(self, vals):
        assert self.sp == 0
        dst = np.asarray(bottom_indices(self.head, np.arange(len(vals)), self.cap))
        self.buf[dst] = vals
        self.sp = len(vals)


def run_sequence(ops):
    """Drive both models through one op sequence, comparing every visible
    value: pop order, donated nodes, stack size, and full stack content."""
    oracle, deque = ShiftStackOracle(), DequeModel()
    next_val = 1
    for kind, arg in ops:
        if kind == "push":
            # keep headroom so neither model overflows (same clamp in both)
            arg = min(arg, CAP - oracle.sp)
            vals = np.arange(next_val, next_val + arg)
            next_val += arg
            assert oracle.push(vals) == deque.push(vals)
        elif kind == "pop":
            np.testing.assert_array_equal(oracle.pop(arg), deque.pop(arg))
        elif kind == "donate":
            np.testing.assert_array_equal(oracle.donate(arg), deque.donate(arg))
        else:  # receive: only meaningful into an empty stack (a requester)
            if oracle.sp != 0:
                continue
            vals = np.arange(next_val, next_val + arg)
            next_val += arg
            oracle.receive(vals)
            deque.receive(vals)
        assert oracle.sp == deque.sp
        # the full visible stack content agrees (bottom-first)
        if oracle.sp:
            got = deque.pop(deque.sp)
            want = oracle.pop(oracle.sp)
            np.testing.assert_array_equal(got, want)
            oracle.receive(want[::-1].copy())
            deque.receive(np.asarray(got)[::-1].copy())


OP_KINDS = ("push", "pop", "donate", "receive")
OP_MAX = {"push": 6, "pop": 6, "donate": STEAL_MAX + 2, "receive": STEAL_MAX}


def test_deque_matches_shift_stack_oracle_seeded():
    """Seeded random sweep — always runs, even without hypothesis."""
    rng = np.random.default_rng(0)
    for _ in range(200):
        ops = [
            (kind, int(rng.integers(1, OP_MAX[kind] + 1)))
            for kind in rng.choice(OP_KINDS, size=int(rng.integers(1, 40)))
        ]
        run_sequence(ops)


if HAVE_HYPOTHESIS:

    @st.composite
    def op_sequences(draw):
        n_ops = draw(st.integers(1, 40))
        return [
            (kind, draw(st.integers(1, OP_MAX[kind])))
            for kind in (
                draw(st.sampled_from(OP_KINDS)) for _ in range(n_ops)
            )
        ]

    @settings(max_examples=60, deadline=None)
    @given(op_sequences())
    def test_deque_matches_shift_stack_oracle(ops):
        run_sequence(ops)


def test_push_overflow_is_flagged_and_dropped():
    pos, overflow = push_positions(
        head=3, base_sp=CAP - 2, offsets=np.arange(4), valid=np.ones(4, bool),
        cap=CAP,
    )
    pos = np.asarray(pos)
    assert bool(overflow)
    # the two in-capacity pushes land (wrapped), the rest hit the drop row
    np.testing.assert_array_equal(pos[:2], [(3 + CAP - 2) % CAP, (3 + CAP - 1) % CAP])
    assert (pos[2:] == CAP).all()


def test_wrapped_addressing_round_trips():
    d = DequeModel()
    d.head = CAP - 2  # force wraparound
    d.push(np.arange(1, 7))
    np.testing.assert_array_equal(d.pop(3), [6, 5, 4])
    np.testing.assert_array_equal(d.donate(10), [1])  # min(sp//2=1, STEAL_MAX)
    np.testing.assert_array_equal(d.pop(2), [3, 2])
    assert d.sp == 0
