"""Subprocess entry for multi-device engine tests.

Must set XLA_FLAGS before importing jax — pytest's process already initialized
jax with 1 device, so multi-device engine tests run this script instead.

Usage: python engine_subproc_main.py '<json spec>'   -> prints a json result.
"""

import json
import os
import sys


def main():
    spec = json.loads(sys.argv[1])
    # replace (not just prepend to) any inherited device-count flag — CI runs
    # the whole suite under --xla_force_host_platform_device_count=8
    inherited = [
        f for f in os.environ.get("XLA_FLAGS", "").split()
        if not f.startswith("--xla_force_host_platform_device_count")
    ]
    os.environ["XLA_FLAGS"] = " ".join(
        [f"--xla_force_host_platform_device_count={spec['n_devices']}"] + inherited
    )
    import numpy as np

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

    from repro.core.engine import EngineConfig, lamp_distributed, mine
    from repro.data.synthetic import SyntheticSpec, generate

    gspec = SyntheticSpec(
        name="sub",
        n_items=spec["n_items"],
        n_transactions=spec["n_transactions"],
        density=spec["density"],
        n_pos=spec["n_pos"],
        n_planted=spec.get("n_planted", 2),
        seed=spec.get("seed", 0),
    )
    db, labels, _ = generate(gspec)
    cfg = EngineConfig(
        expand_batch=spec.get("expand_batch", 8),
        stack_cap=spec.get("stack_cap", 4096),
        steal_max=spec.get("steal_max", 64),
        push_cap=spec.get("push_cap", 256),
        out_cap=spec.get("out_cap", 1024),
        steal_enabled=spec.get("steal_enabled", True),
        seed=spec.get("engine_seed", 0),
        kernel_impl=spec.get("kernel_impl", "ref"),
    )
    out = {}
    if spec["mode"] == "run_vs_legacy":
        # the query-object path vs the legacy one-shot shim, same devices:
        # session.run(SignificantPatternQuery) must reproduce the
        # lamp_distributed dict bit-identically (incl. exact P-values)
        import warnings

        from repro.api import Dataset, MinerSession, RuntimeConfig, SignificantPatternQuery

        def patterns_of(rs):
            return [
                [list(p.items), p.support, p.pos_support, p.pvalue, p.qvalue]
                for p in rs
            ]

        pipeline = spec.get("pipeline", "three_phase")
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            legacy = lamp_distributed(
                db, labels, alpha=spec.get("alpha", 0.05), cfg=cfg,
                pipeline=pipeline,
            )
        session = MinerSession(
            runtime=RuntimeConfig.from_engine_config(cfg))
        rep = session.run(
            Dataset.from_dense(db, labels),
            SignificantPatternQuery(alpha=spec.get("alpha", 0.05),
                                    statistic="fisher", pipeline=pipeline),
        )
        out = {
            "legacy": {
                "min_sup": legacy["min_sup"],
                "correction_factor": legacy["correction_factor"],
                "delta": legacy["delta"],
                "n_significant": legacy["n_significant"],
                "patterns": patterns_of(legacy["results"]),
            },
            "run": {
                "min_sup": rep.min_sup,
                "correction_factor": rep.correction_factor,
                "delta": rep.delta,
                "n_significant": rep.n_significant,
                "patterns": patterns_of(rep.results),
            },
        }
    elif spec["mode"] == "session":
        # two queries (reseeded same-shape datasets) on one MinerSession:
        # returns both pattern sets plus the program-cache counters so the
        # parent can assert the second query compiled nothing
        from repro.api import AlgorithmConfig, Dataset, MinerSession, RuntimeConfig

        session = MinerSession(
            algorithm=AlgorithmConfig(alpha=spec.get("alpha", 0.05),
                                      pipeline=spec.get("pipeline", "three_phase")),
            runtime=RuntimeConfig.from_engine_config(cfg).with_options(
                stack_cap=None),
        )
        queries = []
        misses = []
        for seed in (spec.get("seed", 0), spec.get("seed2", 1)):
            db_q, labels_q, _ = generate(
                SyntheticSpec(
                    name="sub", n_items=spec["n_items"],
                    n_transactions=spec["n_transactions"],
                    density=spec["density"], n_pos=spec["n_pos"],
                    n_planted=spec.get("n_planted", 2), seed=seed,
                )
            )
            rep = session.mine(Dataset.from_dense(db_q, labels_q, name=f"q{seed}"))
            queries.append({
                "min_sup": rep.min_sup,
                "correction_factor": rep.correction_factor,
                "delta": rep.delta,
                "n_significant": rep.n_significant,
                "cold": rep.cold,
                "patterns": [
                    [list(p.items), p.support, p.pos_support, p.pvalue, p.qvalue]
                    for p in rep.results
                ],
            })
            ci = session.cache_info()
            misses.append(ci.misses)
        out = {
            "queries": queries,
            "misses_per_query": misses,
            "hits": ci.hits,
            "n_programs": ci.n_programs,
        }
    elif spec["mode"] == "lamp_full":
        res = lamp_distributed(db, labels, alpha=spec.get("alpha", 0.05), cfg=cfg,
                               pipeline=spec.get("pipeline", "three_phase"))
        p1, p2 = res["phase_outputs"][:2]
        rs = res["results"]
        out = {
            "lambda_final": res["lambda_final"],
            "min_sup": res["min_sup"],
            "correction_factor": res["correction_factor"],
            "delta": res["delta"],
            "n_significant": res["n_significant"],
            "p1_supersteps": p1.supersteps,
            "steals_got": p1.stats["steals_got"].tolist(),
            "closed_per_dev": p2.stats["closed"].tolist(),
            "popped_per_dev": p2.stats["popped"].tolist(),
            "patterns": [
                [list(p.items), p.support, p.pos_support, p.pvalue, p.qvalue]
                for p in rs
            ],
            "patterns_complete": rs.complete,
        }
    elif spec["mode"] == "count":
        res = mine(db, labels, mode="count", min_sup=spec["min_sup"], cfg=cfg)
        out = {
            "hist": res.hist.tolist(),
            "supersteps": res.supersteps,
            "closed_per_dev": res.stats["closed"].tolist(),
            "steals_got": res.stats["steals_got"].tolist(),
            "gives": res.stats["gives"].tolist(),
        }
    elif spec["mode"] == "trace_parity":
        # the same pass traced vs untraced on this device count: results
        # must be bit-identical, and the decoded trace must reconcile with
        # the engine's cumulative per-miner counters
        import dataclasses

        res_off = mine(db, labels, mode="lamp1", cfg=cfg)
        cfg_on = dataclasses.replace(
            cfg, trace_period=spec.get("trace_period", 1),
            trace_cap=spec.get("trace_cap", 4096),
        )
        res_on = mine(db, labels, mode="lamp1", cfg=cfg_on)
        tr = res_on.trace
        out = {
            "hist_equal": res_off.hist.tolist() == res_on.hist.tolist(),
            "lam_equal": res_off.lam_final == res_on.lam_final,
            "supersteps_equal": res_off.supersteps == res_on.supersteps,
            "supersteps": res_on.supersteps,
            "sampled_steps": tr.n_steps,
            "dropped": tr.dropped,
            "steps_monotone": bool(np.all(np.diff(tr.steps) > 0)),
            "depth_nonneg": bool(np.all(tr.depth >= 0)),
            "popped_matches_stats": (
                tr.popped.sum(axis=1).tolist()
                == res_on.stats["popped"].tolist()
            ),
            "fired_matches_stats": (
                int(tr.fired.sum()) == int(res_on.stats["steal_rounds"][0])
            ),
            "donation_fairness": tr.donation_fairness(),
            "summary": tr.summary(),
        }
    print(json.dumps(out))


if __name__ == "__main__":
    main()
