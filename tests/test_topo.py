"""repro.topo: topology model, hierarchical schedule, simulator, and the
machine-shape end-to-end oracles (DESIGN.md §12).

The load-bearing claim is schedule-invariance: steals only redistribute
work and every reduction commutes, so the SAME ResultSet — p-values
included — must come out of a flat 8-device run, a forced 2x4-topology
single-process run, and a real 2-process x 4-device gloo cluster.  The
[slow] oracles assert exactly that; the fast tests pin the schedule and
cost-model invariants the oracles rely on.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.lifeline import build_schedule
from repro.topo import Topology, build_hierarchical_schedule, detect_topology
from repro.topo.simulate import (
    C_CROSS_ROUND_S,
    C_LOCAL_ROUND_S,
    extract_tree,
    round_costs,
    simulate_mine,
    sync_cost,
)

HARNESS = os.path.join(os.path.dirname(__file__), "topo_subproc_main.py")

TOPOS = [
    Topology(2, 4),
    Topology(4, 8),
    Topology(16, 8),
    Topology(125, 8),   # P = 1000: hosts are a non-power-of-two
    Topology(128, 8),   # P = 1024
    Topology(150, 8),   # P = 1200: holes in the host hypercube
]


# ----------------------------------------------------------------- topology
def test_topology_rank_maps_roundtrip():
    topo = Topology(3, 5)
    assert topo.n_proc == 15
    for rank in range(topo.n_proc):
        h, ll = topo.host_of(rank), topo.local_of(rank)
        assert 0 <= h < 3 and 0 <= ll < 5
        assert topo.rank_of(h, ll) == rank
    assert topo.same_host(5, 9) and not topo.same_host(4, 5)
    assert str(topo) == "3x5"


def test_topology_validates():
    with pytest.raises(ValueError):
        Topology(0, 4)
    with pytest.raises(ValueError):
        Topology(2, -1)


def test_detect_topology_single_process():
    import jax

    topo = detect_topology()
    assert topo.n_hosts == 1
    assert topo.devices_per_host == jax.local_device_count()


# ------------------------------------------------- hierarchical schedule
@pytest.fixture(params=TOPOS, ids=[str(t) for t in TOPOS])
def topo_schedule(request):
    return request.param, build_hierarchical_schedule(request.param)


def test_rounds_are_valid_pairings_with_inverse_replies(topo_schedule):
    topo, sch = topo_schedule
    p = topo.n_proc
    assert sch.n_proc == p
    for (req, rep), name in zip(sch.rounds, sch.names):
        srcs = [s for s, _ in req]
        dsts = [d for _, d in req]
        assert all(0 <= s < p for s in srcs), name
        assert len(set(srcs)) == len(srcs), name
        assert len(set(dsts)) == len(dsts), name
        assert set(srcs) == set(dsts), name
        assert set(rep) == {(d, s) for s, d in req}, name


def test_round_names_tiers_axes_agree(topo_schedule):
    _topo, sch = topo_schedule
    assert sch.factorized
    assert len(sch.names) == len(sch.tiers) == len(sch.round_axes) \
        == len(sch.axis_rounds) == sch.n_rounds
    for name, tier, axis in zip(sch.names, sch.tiers, sch.round_axes):
        if tier == "local":
            assert name.startswith("loc_") and axis == "local"
        else:
            assert tier == "cross"
            assert name.startswith("x_") and axis == "hosts"


def test_local_rounds_stay_on_host_cross_rounds_keep_local_rank(topo_schedule):
    topo, sch = topo_schedule
    for (req, _rep), tier in zip(sch.rounds, sch.tiers):
        for s, d in req:
            if tier == "local":
                assert topo.same_host(s, d)
            else:
                assert not topo.same_host(s, d)
                assert topo.local_of(s) == topo.local_of(d)


def test_axis_rounds_expand_to_global_rounds(topo_schedule):
    topo, sch = topo_schedule
    d = topo.devices_per_host
    for (greq, _), (areq, _), tier in zip(sch.rounds, sch.axis_rounds,
                                          sch.tiers):
        if tier == "local":
            want = {(h * d + a, h * d + b)
                    for h in range(topo.n_hosts) for a, b in areq}
        else:
            want = {(g * d + ll, j * d + ll)
                    for g, j in areq for ll in range(d)}
        assert set(greq) == want


def test_lifeline_union_connects_the_whole_machine(topo_schedule):
    topo, sch = topo_schedule
    p = topo.n_proc
    adj = {i: set() for i in range(p)}
    for req, _rep in sch.rounds:
        for s, d in req:
            adj[s].add(d)
            adj[d].add(s)
    reach, frontier = {0}, [0]
    while frontier:
        nxt = adj[frontier.pop()] - reach
        reach |= nxt
        frontier.extend(nxt)
    assert reach == set(range(p)), f"steal graph disconnected for {topo}"


def test_cross_fraction_is_pinned_regardless_of_host_count():
    # the cycle inserts cross_every locals before each cross round, so the
    # cross share never drifts up as log2(H) outgrows log2(D)
    for topo in (Topology(16, 8), Topology(128, 8)):
        for ce in (1, 3):
            sch = build_hierarchical_schedule(topo, cross_every=ce)
            n_cross = sum(t == "cross" for t in sch.tiers)
            n_local = sum(t == "local" for t in sch.tiers)
            assert n_local >= ce * n_cross


def test_single_miner_schedule_is_one_noop_round():
    sch = build_hierarchical_schedule(Topology(1, 1))
    assert sch.n_rounds == 1 and sch.rounds == (((), ()),)
    assert sch.factorized


def test_one_host_hierarchy_matches_flat_schedule():
    # H == 1: the local tier is built exactly like the flat schedule at
    # size D with the same rng stream, so the global rounds coincide
    sch_h = build_hierarchical_schedule(Topology(1, 8), n_random=4, seed=0)
    sch_f = build_schedule(8, n_random=4, seed=0)
    assert sch_h.rounds == sch_f.rounds
    assert all(t == "local" for t in sch_h.tiers)


def test_flat_schedule_rejects_topo_mesh_axis():
    from repro.core.engine import EngineConfig
    from repro.core.steal import build_steal_round

    cfg = EngineConfig(expand_batch=4, stack_cap=512, steal_max=16,
                       push_cap=64, out_cap=64)
    with pytest.raises(ValueError, match="flat"):
        build_steal_round(build_schedule(8), cfg, axis=("hosts", "local"))


def test_engine_config_topology_mismatch_raises():
    import jax

    from repro.core.engine import EngineConfig, make_mesh_and_schedule

    cfg = EngineConfig(expand_batch=4, stack_cap=512, steal_max=16,
                       push_cap=64, out_cap=64,
                       topology=Topology(2, 4))
    with pytest.raises(ValueError, match="topology"):
        make_mesh_and_schedule(cfg, jax.devices()[:1])


# ------------------------------------------------------------- simulator
@pytest.fixture(scope="module")
def small_tree():
    rng = np.random.default_rng(7)
    db = rng.random((120, 30)) < 0.3
    return extract_tree(db, min_sup=4)


def test_simulator_conserves_work(small_tree):
    topo = Topology(2, 4)
    res = simulate_mine(small_tree, build_hierarchical_schedule(topo), topo)
    # every node except the host-dealt root is popped exactly once,
    # regardless of how the steal schedule shuffled the subtrees
    assert res.total_popped == small_tree.n_nodes - 1
    assert sum(res.popped_per_miner) == res.total_popped
    assert res.supersteps > 0 and res.makespan_s > 0


def test_simulator_schedule_invariance_of_totals(small_tree):
    topo = Topology(2, 4)
    flat = simulate_mine(small_tree, build_schedule(8), topo)
    hier = simulate_mine(small_tree, build_hierarchical_schedule(topo), topo)
    static = simulate_mine(small_tree, build_schedule(8), topo,
                           steal_enabled=False)
    assert flat.total_popped == hier.total_popped == static.total_popped
    assert static.steals == 0


def test_one_host_simulation_identical_for_both_schedules(small_tree):
    topo = Topology(1, 8)
    flat = simulate_mine(small_tree, build_schedule(8), topo)
    hier = simulate_mine(small_tree, build_hierarchical_schedule(topo), topo)
    assert flat == hier  # same rounds, same costs, same trajectory


def test_round_costs_tier_structure():
    topo = Topology(8, 8)
    hier = build_hierarchical_schedule(topo)
    costs = round_costs(hier, topo)
    for c, tier in zip(costs, hier.tiers):
        if tier == "local":
            assert c == C_LOCAL_ROUND_S
        else:
            # aligned host pairing: fan-out 1, exactly one cross latency
            assert c == C_CROSS_ROUND_S
    flat_costs = round_costs(build_schedule(64), topo)
    # a flat random derangement scatters hosts across many peers: at least
    # one round pays the fan-out serialization premium
    assert max(flat_costs) > C_CROSS_ROUND_S
    # low hypercube dims stay intra-host under the block rank mapping
    assert min(flat_costs) == C_LOCAL_ROUND_S


def test_sync_cost_shape():
    assert sync_cost(Topology(1, 1)) == 0.0
    assert sync_cost(Topology(1, 8)) == 3 * C_LOCAL_ROUND_S
    assert sync_cost(Topology(4, 1)) == 2 * C_CROSS_ROUND_S
    assert sync_cost(Topology(4, 8)) == \
        3 * C_LOCAL_ROUND_S + 2 * C_CROSS_ROUND_S


# ------------------------------------------------- per-round telemetry
def _mk_trace(names, tiers, steps, fired, donated, received):
    from repro.obs.trace import SuperstepTrace

    steps = np.asarray(steps)
    shape = (donated.shape[0], steps.size)
    z = np.zeros(shape, np.int64)
    return SuperstepTrace(
        period=1, cap=64, dropped=0, steps=steps,
        lam=np.zeros(steps.size, np.int64),
        n_hungry=np.zeros(steps.size, np.int64),
        fired=np.asarray(fired),
        depth=z, popped=z, pushed=z, closed=z, emitted=z,
        donated=donated, received=received,
        schedule_names=names, schedule_tiers=tiers,
    )


def test_steal_by_round_attributes_and_accumulates_duplicates():
    # cyclic 3-round schedule with a repeated name (cross_every repeats
    # local rounds inside one grand cycle): both positions must pool
    names = ("loc_a", "x_b", "loc_a")
    tiers = ("local", "cross", "local")
    donated = np.array([[4, 0, 2, 0], [0, 6, 0, 0]])
    received = np.array([[0, 6, 0, 0], [4, 0, 2, 0]])
    tr = _mk_trace(names, tiers, steps=[0, 1, 2, 3], fired=[1, 1, 1, 0],
                   donated=donated, received=received)
    by_round = tr.steal_by_round()
    assert set(by_round) == {"loc_a", "x_b"}
    # steps 0, 2 (both loc_a) and step 3 (loc_a again, round 3 % 3 == 0)
    assert by_round["loc_a"]["steps"] == 3
    assert by_round["loc_a"]["donated"] == 4 + 2 + 0
    assert by_round["loc_a"]["tier"] == "local"
    assert by_round["x_b"] == {
        "tier": "cross", "steps": 1, "fired": 1, "donated": 6, "received": 6,
    }


def test_tier_fairness_splits_by_tier():
    names = ("loc_a", "x_b")
    tiers = ("local", "cross")
    # local donations all from miner 0 (unfair); cross split evenly (fair)
    donated = np.array([[10, 3, 10, 3], [0, 3, 0, 3]])
    tr = _mk_trace(names, tiers, steps=[0, 1, 2, 3], fired=[1, 1, 1, 1],
                   donated=donated, received=donated)
    tf = tr.tier_fairness()
    assert set(tf) == {"local", "cross"}
    assert tf["cross"] == pytest.approx(1.0)
    assert tf["local"] == pytest.approx(0.5)  # jain([20, 0]) with P=2


def test_untraced_sessions_report_empty_round_telemetry():
    tr = _mk_trace(None, None, steps=[0, 1], fired=[0, 0],
                   donated=np.zeros((2, 2), np.int64),
                   received=np.zeros((2, 2), np.int64))
    assert tr.steal_by_round() == {}
    assert tr.tier_fairness() == {}


# ------------------------------------------------------- [slow] oracles
def _run_standalone(spec):
    r = subprocess.run(
        [sys.executable, HARNESS, json.dumps(spec)],
        capture_output=True, text=True, timeout=900,
    )
    assert r.returncode == 0, r.stderr[-4000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


DATA = dict(n_items=24, n_transactions=60, density=0.15, n_pos=20, alpha=0.05)

IDENTITY_KEYS = ("lambda_final", "min_sup", "correction_factor", "delta",
                 "n_significant", "patterns")


@pytest.mark.slow
def test_forced_topology_bit_identical_to_flat():
    """2x4 simulated topology (one process, 8 devices, hierarchical
    schedule on the 2-D mesh) vs the flat 8-device run: same ResultSet,
    p-values included."""
    flat = _run_standalone(dict(DATA, n_devices=8, topology="flat"))
    hier = _run_standalone(dict(DATA, n_devices=8, topology="hier",
                                n_hosts=2, devices_per_host=4,
                                trace_period=1))
    for k in IDENTITY_KEYS:
        assert flat[k] == hier[k], k
    # the traced hierarchical run attributes steals to named rounds
    assert hier["steal_by_round"]
    assert {v["tier"] for v in hier["steal_by_round"].values()} \
        <= {"local", "cross"}
    assert set(hier["tier_fairness"]) <= {"local", "cross"}


@pytest.mark.slow
def test_multiprocess_cluster_bit_identical_to_flat():
    """A real 2-process x 4-device gloo cluster (jax.distributed) vs the
    flat single-process 8-device run: same ResultSet, p-values included."""
    from repro.topo.bootstrap import launch_local_cluster

    flat = _run_standalone(dict(DATA, n_devices=8, topology="flat"))
    hier = launch_local_cluster(
        HARNESS, dict(DATA, topology="hier"),
        n_processes=2, devices_per_process=4,
    )
    assert hier["n_devices_global"] == 8
    for k in IDENTITY_KEYS:
        assert flat[k] == hier[k], k
