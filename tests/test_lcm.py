"""LCM closed-itemset enumeration vs the exponential oracle + closure properties."""

import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")  # dev dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core.bitmap import (
    full_occ,
    pack_db,
    support_np,
    supports_np,
    unpack_occ,
)
from repro.core.lcm import brute_force_closed, closure_np, lcm_closed


def random_db(rng, n, m, density):
    return rng.random((n, m)) < density


@st.composite
def small_dbs(draw):
    n = draw(st.integers(4, 40))
    m = draw(st.integers(2, 10))
    density = draw(st.floats(0.05, 0.8))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    return random_db(rng, n, m, density)


@given(db=small_dbs(), min_sup=st.integers(1, 6))
@settings(max_examples=60, deadline=None)
def test_lcm_matches_bruteforce(db, min_sup):
    oracle = brute_force_closed(db, min_sup=min_sup)
    got, stats = lcm_closed(db, min_sup=min_sup)
    got_dict = {items: sup for items, sup in got}
    assert len(got) == len(got_dict), "LCM emitted a duplicate closed set"
    assert got_dict == oracle
    assert stats.closed_found == len(oracle)


@given(db=small_dbs())
@settings(max_examples=40, deadline=None)
def test_closure_operator_properties(db):
    """Closure is extensive, monotone, idempotent (on occurrence bitmaps)."""
    n, m = db.shape
    db_bits = pack_db(db)
    rng = np.random.default_rng(0)
    items = rng.choice(m, size=min(3, m), replace=False)
    occ = full_occ(n)
    for j in items:
        occ = occ & db_bits[j]
    clo = closure_np(occ, db_bits)
    # extensive: any item whose column contains occ is in the closure,
    # in particular every generator item (if occ nonempty)
    if support_np(occ) > 0:
        assert set(items).issubset(set(clo.tolist()))
    # idempotent: closing the closure's occurrence changes nothing
    occ2 = full_occ(n)
    for j in clo:
        occ2 = occ2 & db_bits[j]
    assert np.array_equal(occ2, occ) or support_np(occ) == 0
    clo2 = closure_np(occ2, db_bits)
    if support_np(occ) > 0:
        assert np.array_equal(clo, clo2)


@given(db=small_dbs())
@settings(max_examples=30, deadline=None)
def test_supports_gemm_matches_naive(db):
    n, m = db.shape
    db_bits = pack_db(db)
    occ = full_occ(n)
    s = supports_np(occ, db_bits)
    np.testing.assert_array_equal(s, db.sum(axis=0))


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(1)
    for n in [1, 31, 32, 33, 64, 97, 697]:
        db = rng.random((n, 5)) < 0.3
        bits = pack_db(db)
        back = np.stack([unpack_occ(bits[j], n) for j in range(5)], axis=1)
        np.testing.assert_array_equal(back, db)


def test_tail_bits_are_zero():
    db = np.ones((33, 2), dtype=bool)
    bits = pack_db(db)
    assert support_np(bits[0]) == 33  # not 64: tail of word 1 must be zero
    occ = full_occ(33)
    assert support_np(occ) == 33


def test_min_sup_filters():
    rng = np.random.default_rng(2)
    db = random_db(rng, 30, 8, 0.4)
    all_closed, _ = lcm_closed(db, min_sup=1)
    for ms in [2, 4, 8]:
        got, _ = lcm_closed(db, min_sup=ms)
        expect = {(i, s) for i, s in all_closed if s >= ms}
        assert {(i, s) for i, s in got} == expect
