"""Results subsystem: emission -> closure reconstruction -> dedup -> exact test.

The acceptance bar (ISSUE 2): on a synthetic case-control problem with
planted significant patterns, `lamp_distributed` returns a ResultSet whose
exported top-k contains every planted pattern's closure with its exact Fisher
P-value (recall 1.0 when out_cap suffices), identically for 1-device and
8-simulated-device runs and for both three_phase and fused23 pipelines.
"""

import json
import os
import subprocess
import sys

import pytest

import jax

from repro.core.engine import EngineConfig, lamp_distributed, mine
from repro.core.fisher import fisher_pvalue
from repro.core.lamp import lamp
from repro.data.synthetic import SyntheticSpec, generate
from repro.results import Pattern, ResultSet, score_planted

HERE = os.path.dirname(os.path.abspath(__file__))

CFG = EngineConfig(expand_batch=8, stack_cap=2048, steal_max=32, push_cap=128)


def small_problem(seed=0, n=60, m=24, density=0.15, n_pos=20, planted=2):
    spec = SyntheticSpec(
        name="t", n_items=m, n_transactions=n, density=density, n_pos=n_pos,
        n_planted=planted, seed=seed,
    )
    return generate(spec)


def planted_problem():
    """Strong planted signal: the engine must recover every planted closure."""
    spec = SyntheticSpec(
        name="planted", n_items=48, n_transactions=120, density=0.06, n_pos=40,
        n_planted=2, planted_pos_rate=0.75, planted_neg_rate=0.02, seed=7,
    )
    return generate(spec)


def _pattern_key(p):
    return (p.items, p.support, p.pos_support)


def _oracle_patterns(db, labels, alpha=0.05):
    ref = lamp(db, labels, alpha=alpha)
    return ref, sorted(
        (tuple(sorted(s.items)), s.support, s.pos_support, s.pvalue)
        for s in ref.significant if s.items
    )


# ------------------------------------------------------- oracle equivalence
@pytest.mark.parametrize("seed", [0, 4])
def test_three_phase_resultset_matches_oracle(seed):
    db, labels, _ = small_problem(seed=seed)
    res = lamp_distributed(db, labels, alpha=0.05, cfg=CFG)
    rs = res["results"]
    assert isinstance(rs, ResultSet)
    assert rs.complete and rs.n_dropped == 0
    assert len(rs) == res["n_significant"]
    ref, want = _oracle_patterns(db, labels)
    got = sorted((p.items, p.support, p.pos_support, p.pvalue) for p in rs)
    assert got == want  # identities AND exact float64 P-values
    # Bonferroni q-values and P-value ordering
    k = res["correction_factor"]
    for p in rs:
        assert p.qvalue == min(1.0, p.pvalue * k)
        assert p.pvalue <= res["delta"]
    pv = [p.pvalue for p in rs]
    assert pv == sorted(pv)


def test_fused23_resultset_identical_to_three_phase():
    db, labels, _ = small_problem(seed=4)
    a = lamp_distributed(db, labels, alpha=0.05, cfg=CFG)
    b = lamp_distributed(db, labels, alpha=0.05, cfg=CFG, pipeline="fused23")
    assert b["results"].delta == a["results"].delta
    pa = [(p.items, p.support, p.pos_support, p.pvalue, p.qvalue)
          for p in a["results"]]
    pb = [(p.items, p.support, p.pos_support, p.pvalue, p.qvalue)
          for p in b["results"]]
    assert pa == pb
    assert len(b["results"]) == b["n_significant"]


def test_single_device_matches_all_devices():
    """devices=[d0] vs the full local device set: identical ResultSet."""
    db, labels, _ = small_problem(seed=2)
    one = lamp_distributed(db, labels, alpha=0.05, cfg=CFG,
                           devices=jax.devices()[:1])
    full = lamp_distributed(db, labels, alpha=0.05, cfg=CFG)
    assert ([_pattern_key(p) + (p.pvalue,) for p in one["results"]]
            == [_pattern_key(p) + (p.pvalue,) for p in full["results"]])


# ------------------------------------------------ planted recovery + export
@pytest.mark.parametrize("pipeline", ["three_phase", "fused23"])
def test_planted_recovery_and_topk_export(tmp_path, pipeline):
    db, labels, planted = planted_problem()
    res = lamp_distributed(db, labels, alpha=0.05, cfg=CFG, pipeline=pipeline)
    rs = res["results"]
    assert rs.complete, "out_cap must suffice for the acceptance criterion"

    score = score_planted(rs, planted)
    assert score["recall"] == 1.0, f"missed planted itemsets: {score['missed']}"

    n, n_pos = db.shape[0], int(labels.sum())
    top = rs.top(len(rs))

    # TSV export round-trip: every planted closure appears with its exact P
    tsv_path = tmp_path / "patterns.tsv"
    rs.save(str(tsv_path))
    lines = tsv_path.read_text().strip().splitlines()
    header = lines[0].split("\t")
    rows = [dict(zip(header, ln.split("\t"))) for ln in lines[1:]]
    assert len(rows) == len(top)
    by_items = {tuple(map(int, r["items"].split(","))): r for r in rows}
    for pl in planted:
        match = [items for items in by_items if set(pl) <= set(items)]
        assert match, f"planted {pl} not in TSV export"
        for items in match:
            r = by_items[items]
            exact = fisher_pvalue(int(r["support"]), int(r["pos_support"]),
                                  n, n_pos)[0]
            assert float(r["pvalue"]) == pytest.approx(exact, rel=1e-5)

    # JSON export round-trip carries the full testing context
    json_path = tmp_path / "patterns.json"
    rs.save(str(json_path))
    payload = json.loads(json_path.read_text())
    assert payload["n_patterns"] == len(rs)
    assert payload["complete"] is True
    assert payload["delta"] == res["delta"]
    assert payload["correction_factor"] == res["correction_factor"]
    got = {tuple(p["items"]) for p in payload["patterns"]}
    for pl in planted:
        assert any(set(pl) <= set(items) for items in got)


def test_top_k_selection_is_prefix_of_pvalue_order():
    db, labels, _ = small_problem(seed=0)
    rs = lamp_distributed(db, labels, alpha=0.05, cfg=CFG)["results"]
    assert rs.top(3) == rs.patterns[:3]
    assert rs.top(None) == rs.patterns
    assert len(rs.to_tsv(top_k=3).strip().splitlines()) == 1 + min(3, len(rs))


# ------------------------------------------------------------ overflow path
def test_emission_overflow_warns_counts_and_flags_incomplete():
    db, labels, _ = small_problem(seed=0)
    base = lamp_distributed(db, labels, alpha=0.05, cfg=CFG)
    assert base["n_significant"] > 2
    tiny = EngineConfig(expand_batch=8, stack_cap=2048, steal_max=32,
                        push_cap=128, out_cap=2)
    with pytest.warns(RuntimeWarning, match="emission overflow"):
        res = mine(db, labels, mode="test", min_sup=base["min_sup"],
                   delta=base["delta"], cfg=tiny)
    # counts stay exact; only the materialized pattern list is clipped
    assert res.sig_count == base["n_significant"]
    n_devices = len(jax.devices())
    assert res.emit_dropped >= base["n_significant"] - 2 * n_devices
    assert res.emit_dropped == int(res.stats["emit_dropped"].sum())
    with pytest.warns(RuntimeWarning, match="emission overflow"):
        rs = lamp_distributed(db, labels, alpha=0.05, cfg=tiny)["results"]
    assert not rs.complete and rs.n_dropped > 0
    assert len(rs) < base["n_significant"]
    base_keys = {_pattern_key(p) for p in base["results"]}
    assert {_pattern_key(p) for p in rs} <= base_keys


# ------------------------------------------------------------------ scoring
def test_score_planted_precision_recall():
    mined = [
        Pattern(items=(1, 2, 3), support=10, pos_support=9, pvalue=1e-6, qvalue=1e-4),
        Pattern(items=(7,), support=8, pos_support=7, pvalue=1e-4, qvalue=1e-2),
    ]
    score = score_planted(mined, planted=[[1, 2], [4, 5]])
    assert score["recall"] == 0.5
    assert score["precision"] == 0.5
    assert score["recovered"] == [[1, 2]]
    assert score["missed"] == [[4, 5]]
    empty = score_planted([], planted=[[1, 2]])
    assert empty["recall"] == 0.0 and empty["precision"] == 0.0


# ----------------------------------------------------- multi-device oracles
def run_subproc(spec: dict) -> dict:
    from repro.core.collectives import host_device_count_env

    env = host_device_count_env(spec["n_devices"])
    env["PYTHONPATH"] = os.path.join(HERE, "..", "src")
    out = subprocess.run(
        [sys.executable, os.path.join(HERE, "engine_subproc_main.py"),
         json.dumps(spec)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
@pytest.mark.parametrize("pipeline", ["three_phase", "fused23"])
def test_multidevice_resultset_matches_single_device(pipeline):
    """8 simulated miners return byte-identical patterns to the P=1 run."""
    prob = dict(n_items=24, n_transactions=60, density=0.15, n_pos=20, seed=1)
    got = run_subproc(dict(prob, mode="lamp_full", n_devices=8,
                           pipeline=pipeline))
    db, labels, _ = small_problem(seed=1)
    one = lamp_distributed(db, labels, alpha=0.05, cfg=CFG,
                           devices=jax.devices()[:1], pipeline=pipeline)
    want = [[list(p.items), p.support, p.pos_support] for p in one["results"]]
    assert [p[:3] for p in got["patterns"]] == want
    for (_, _, _, pv, qv), p in zip(got["patterns"], one["results"]):
        assert pv == pytest.approx(p.pvalue, rel=1e-12)
        assert qv == pytest.approx(p.qvalue, rel=1e-12)
    assert got["patterns_complete"]
    assert got["n_significant"] == one["n_significant"]
