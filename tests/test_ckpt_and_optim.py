"""Checkpoint round-trip, elastic restore, fault-tolerant restart, AdamW."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as ckpt
from repro.optim.adamw import (
    AdamWConfig, apply_updates, clip_by_global_norm, compress_grads, init_state,
    schedule,
)

HERE = os.path.dirname(os.path.abspath(__file__))


def tree_eq(a, b):
    return all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones(5, jnp.bfloat16), "step": jnp.int32(7)},
        "tup": (jnp.zeros(2), jnp.ones(3)),
    }
    ckpt.save(tree, str(tmp_path), 10, meta={"note": "x"})
    restored, manifest = ckpt.restore(str(tmp_path), 10, tree)
    assert manifest["step"] == 10 and manifest["meta"]["note"] == "x"
    assert tree_eq(tree, restored)


def test_checkpoint_prune_and_latest(tmp_path):
    tree = {"w": jnp.zeros(3)}
    for s in [1, 2, 3, 4, 5]:
        ckpt.save(tree, str(tmp_path), s, keep=3)
    assert ckpt.list_steps(str(tmp_path)) == [3, 4, 5]
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_checkpoint_atomicity_no_partial(tmp_path):
    tree = {"w": jnp.zeros(3)}
    ckpt.save(tree, str(tmp_path), 1)
    # a leftover tmp dir from a crashed writer must be invisible
    os.makedirs(tmp_path / ".tmp_step_2", exist_ok=True)
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=200)
    params = {"x": jnp.array([5.0, -3.0])}
    state = init_state(cfg, params)
    for _ in range(150):
        grads = {"x": 2 * params["x"]}  # d/dx x^2
        params, state, _ = apply_updates(cfg, params, grads, state)
    assert float(jnp.abs(params["x"]).max()) < 0.3


def test_grad_clip():
    grads = {"a": jnp.full(4, 10.0)}
    clipped, gn = clip_by_global_norm(grads, 1.0)
    assert gn == pytest.approx(20.0)
    total = jnp.sqrt(jnp.sum(jnp.square(clipped["a"])))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(schedule(cfg, jnp.int32(0))) == 0.0
    assert float(schedule(cfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(schedule(cfg, jnp.int32(100))) == pytest.approx(0.1, rel=1e-3)


def test_error_feedback_compression_unbiased_over_time():
    """EF accumulates quantization error: sum of dequantized ~= sum of true."""
    rng = np.random.default_rng(0)
    g_true = [rng.normal(size=(64,)).astype(np.float32) * 0.01 for _ in range(50)]
    ef = {"g": jnp.zeros(64)}
    total_deq = np.zeros(64)
    for g in g_true:
        deq, new_e = compress_grads({"g": jnp.array(g)}, ef)
        ef = {"g": new_e["g"]} if isinstance(new_e, dict) else {"g": new_e}
        total_deq += np.asarray(deq["g"])
    total_true = np.sum(g_true, axis=0)
    # residual bounded by one quantization step, not accumulated drift
    assert np.max(np.abs(total_deq - total_true)) < 0.02


def test_compressed_training_still_converges():
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0,
                      total_steps=300, compress=True)
    params = {"x": jnp.array([4.0, -2.0, 1.0])}
    state = init_state(cfg, params)
    for _ in range(200):
        grads = {"x": 2 * params["x"]}
        params, state, _ = apply_updates(cfg, params, grads, state)
    assert float(jnp.abs(params["x"]).max()) < 0.5


@pytest.mark.slow
def test_fault_tolerant_restart_resumes_trajectory(tmp_path):
    """Kill a training run mid-flight; a rerun resumes and matches an
    uninterrupted run's final loss (deterministic data replay)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(HERE, "..", "src"))
    base_args = [
        sys.executable, "-m", "repro.launch.train", "--arch", "granite-3-2b",
        "--preset", "tiny", "--steps", "12", "--seq", "32", "--batch", "4",
        "--ckpt-every", "4", "--log-every", "50",
    ]
    # uninterrupted reference
    ref_metrics = str(tmp_path / "ref.json")
    out = subprocess.run(
        base_args + ["--ckpt-dir", str(tmp_path / "ref_ckpt"),
                     "--metrics-out", ref_metrics],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    # failing run
    ck = str(tmp_path / "ckpt")
    out = subprocess.run(
        base_args + ["--ckpt-dir", ck, "--fail-at", "8"],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 42  # simulated failure
    # resume
    res_metrics = str(tmp_path / "res.json")
    out = subprocess.run(
        base_args + ["--ckpt-dir", ck, "--metrics-out", res_metrics],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "[restore] resumed from step 8" in out.stdout
    ref = {m["step"]: m["loss"] for m in json.load(open(ref_metrics))}
    res = {m["step"]: m["loss"] for m in json.load(open(res_metrics))}
    for s in range(8, 12):
        assert res[s] == pytest.approx(ref[s], rel=1e-4), f"step {s} diverged"
