"""Lifeline steal-schedule invariants (core/lifeline.py), property-style.

Every round of the schedule is consumed by a paired `ppermute` exchange
(core/steal.py), which silently mis-routes work if a round is not a valid
pairing or the reply permutation is not the inverse of the request one — so
these invariants are load-bearing for correctness, not style.  Checked over
P in {1, 2, 3, 5, 8, 13}: powers of two AND the "hypercube with holes" cases;
and over P in {128, 1000, 1024, 1200} — the paper's machine scale (Fig. 5
runs 1216 cores), where the builder must stay correct with 10+ hypercube
dims and derangements over four-digit rank counts (repro.topo sizes its
cross tier with exactly these builders at n_hosts up to the hundreds).
"""

import pytest

from repro.core.lifeline import build_schedule

PS = [1, 2, 3, 5, 8, 13, 128, 1000, 1024, 1200]


@pytest.fixture(params=PS, ids=[f"P{p}" for p in PS])
def schedule(request):
    return request.param, build_schedule(request.param, n_random=4, seed=0)


def test_request_maps_are_valid_pairings(schedule):
    p, sch = schedule
    for (req, _rep), name in zip(sch.rounds, sch.names):
        srcs = [s for s, _ in req]
        dsts = [d for _, d in req]
        assert all(0 <= s < p for s in srcs), name
        assert all(0 <= d < p for d in dsts), name
        # each endpoint appears at most once on each side, and the round is
        # a permutation of the participating subset
        assert len(set(srcs)) == len(srcs), name
        assert len(set(dsts)) == len(dsts), name
        assert set(srcs) == set(dsts), name


def test_reply_pairs_invert_request_pairs(schedule):
    _p, sch = schedule
    for (req, rep), name in zip(sch.rounds, sch.names):
        assert set(rep) == {(d, s) for s, d in req}, name


def test_random_rounds_have_no_self_steals(schedule):
    p, sch = schedule
    rand_rounds = [(r, n) for r, n in zip(sch.rounds, sch.names)
                   if n.startswith("rand")]
    assert rand_rounds, "schedule must contain random steal rounds"
    if p == 1:
        return  # a lone miner can only pair with itself
    for (req, _rep), name in rand_rounds:
        assert all(s != d for s, d in req), f"self-steal in {name}"
        # full permutation: every miner sends a request every random round
        assert len(req) == p, name


def test_hypercube_rounds_cover_non_power_of_two(schedule):
    p, sch = schedule
    hc_rounds = [r for r, n in zip(sch.rounds, sch.names) if n.startswith("hc")]
    assert len(hc_rounds) == sch.dim
    edges = set()
    for d, (req, rep) in enumerate(hc_rounds):
        # exactly the paper's lifeline involution i <-> i XOR 2^d, restricted
        # to endpoints that exist ("hypercube with holes")
        want = {(i, i ^ (1 << d)) for i in range(p) if (i ^ (1 << d)) < p}
        assert set(req) == want, f"hc{d}"
        assert req == rep, f"hc{d} must be an involution"
        edges |= {frozenset(e) for e in req}
    if p == 1:
        assert not edges
        return
    # the union of lifeline edges must connect all P miners, or some miner
    # could starve with work available elsewhere
    reach = {0}
    frontier = [0]
    adj = {i: set() for i in range(p)}
    for e in edges:
        a, b = tuple(e)
        adj[a].add(b)
        adj[b].add(a)
    while frontier:
        nxt = adj[frontier.pop()] - reach
        reach |= nxt
        frontier.extend(nxt)
    assert reach == set(range(p)), f"lifeline graph disconnected for P={p}"


def test_schedule_shape_and_round_mix(schedule):
    p, sch = schedule
    assert sch.n_proc == p
    assert sch.n_rounds == len(sch.names) == len(sch.rounds)
    n_rand = sum(n.startswith("rand") for n in sch.names)
    n_hc = sum(n.startswith("hc") for n in sch.names)
    assert n_hc == sch.dim
    assert n_rand == max(4, sch.dim)  # n_random=4 requested above
    # the cyclic schedule interleaves: every hc round is preceded by a rand
    for i, name in enumerate(sch.names):
        if name.startswith("hc"):
            assert sch.names[i - 1].startswith("rand")
