"""End-to-end behaviour tests for the paper's system.

The full pipeline on a realistic (small) GWAS-like problem: three-phase
distributed LAMP == fused two-phase == sequential oracle == brute force,
with planted signal recovered and the work-stealing telemetry consistent.
"""

import numpy as np
import pytest

from repro.core.engine import EngineConfig, lamp_distributed
from repro.core.lamp import lamp
from repro.core.lcm import brute_force_closed
from repro.data.synthetic import SyntheticSpec, generate


@pytest.fixture(scope="module")
def problem():
    spec = SyntheticSpec(
        name="e2e", n_items=60, n_transactions=150, density=0.08, n_pos=50,
        n_planted=2, planted_pos_rate=0.75, planted_neg_rate=0.03, seed=11,
    )
    return generate(spec)


def test_end_to_end_pipeline_consistency(problem):
    db, labels, planted = problem
    ref = lamp(db, labels, alpha=0.05)
    three = lamp_distributed(db, labels, alpha=0.05,
                             cfg=EngineConfig(expand_batch=16, trace_cap=4096))
    fused = lamp_distributed(db, labels, alpha=0.05,
                             cfg=EngineConfig(expand_batch=16),
                             fuse_phase23=True)
    for got in (three, fused):
        assert got["min_sup"] == ref.min_sup
        assert got["correction_factor"] == ref.correction_factor
        assert got["n_significant"] == len(ref.significant)
    # planted signal recovered
    sig_sets = [set(s.items) for s in ref.significant]
    assert any(any(set(p) <= s for s in sig_sets) for p in planted)
    # telemetry: supersteps and work accounted
    p1 = three["phase_outputs"][0]
    assert p1.supersteps > 0
    assert int(p1.stats["popped"].sum()) >= p1.stats["closed"].sum()


def test_correction_factor_matches_bruteforce_on_tiny(problem):
    rng = np.random.default_rng(5)
    db = rng.random((40, 10)) < 0.3
    labels = np.zeros(40, bool)
    labels[rng.choice(40, 14, replace=False)] = True
    ref = lamp(db, labels, alpha=0.05)
    oracle = brute_force_closed(db, min_sup=ref.min_sup)
    got = lamp_distributed(db, labels, alpha=0.05,
                           cfg=EngineConfig(expand_batch=8))
    assert got["correction_factor"] == len(oracle) == ref.correction_factor
