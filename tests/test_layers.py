"""Layer correctness: chunked attention vs naive oracle, recurrent blocks'
parallel-form vs step-form equivalence, MoE routing sanity, RoPE properties."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.flash_attn.ref import attention_ref
from repro.models.layers import apply_rope, attention, moe_apply, moe_init, rms_norm
from repro.models.recurrent import (
    conv1d_apply, conv1d_init, mlstm_chunked, mlstm_step, rglru_block, rglru_init,
)


def test_chunked_attention_matches_naive():
    rng = np.random.default_rng(0)
    b, hq, hkv, s, d = 2, 4, 2, 96, 16
    q = rng.normal(size=(b, hq, s, d)).astype(np.float32)
    k = rng.normal(size=(b, hkv, s, d)).astype(np.float32)
    v = rng.normal(size=(b, hkv, s, d)).astype(np.float32)
    got = np.asarray(attention(jnp.array(q), jnp.array(k), jnp.array(v),
                               causal=True, chunk=32))
    kr = np.repeat(k, hq // hkv, axis=1)
    vr = np.repeat(v, hq // hkv, axis=1)
    want = np.asarray(attention_ref(
        q.reshape(b * hq, s, d), kr.reshape(b * hq, s, d), vr.reshape(b * hq, s, d),
        causal=True)).reshape(b, hq, s, d)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_windowed_attention_masks_correctly():
    rng = np.random.default_rng(1)
    b, h, s, d, w = 1, 2, 64, 8, 16
    q = rng.normal(size=(b, h, s, d)).astype(np.float32)
    k = rng.normal(size=(b, h, s, d)).astype(np.float32)
    v = rng.normal(size=(b, h, s, d)).astype(np.float32)
    got = np.asarray(attention(jnp.array(q), jnp.array(k), jnp.array(v),
                               causal=True, window=w, chunk=16))
    # oracle: full attention with window mask
    s_ = np.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(d)
    ii, jj = np.arange(s)[:, None], np.arange(s)[None, :]
    mask = (jj <= ii) & (jj > ii - w)
    s_ = np.where(mask, s_, -1e30)
    p = np.exp(s_ - s_.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    want = np.einsum("bhqk,bhkd->bhqd", p, v)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_attention_with_explicit_kv_pos_ring_buffer():
    """Decode against a rotated ring buffer must equal contiguous attention."""
    rng = np.random.default_rng(2)
    b, h, d, size = 1, 2, 8, 32
    # contiguous recent keys at positions 40..71; ring stores them rotated
    pos = np.arange(40, 72)
    k = rng.normal(size=(b, h, size, d)).astype(np.float32)
    v = rng.normal(size=(b, h, size, d)).astype(np.float32)
    q = rng.normal(size=(b, h, 1, d)).astype(np.float32)
    rot = np.argsort(pos % size)  # ring layout
    k_ring, v_ring = k[:, :, rot], v[:, :, rot]
    pos_ring = np.broadcast_to(pos[rot], (b, size)).astype(np.int32)
    got = np.asarray(attention(jnp.array(q), jnp.array(k_ring), jnp.array(v_ring),
                               causal=True, q_offset=jnp.array([71]),
                               kv_pos=jnp.array(pos_ring), chunk=16))
    want = np.asarray(attention(jnp.array(q), jnp.array(k), jnp.array(v),
                                causal=True, q_offset=jnp.array([71]),
                                kv_offset=40, chunk=16))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("chunk", [4, 16, 64])
def test_mlstm_chunked_matches_step_scan(chunk):
    rng = np.random.default_rng(3)
    b, h, s, dh = 2, 3, 48, 8
    q = rng.normal(size=(b, h, s, dh)).astype(np.float32)
    k = rng.normal(size=(b, h, s, dh)).astype(np.float32) * 0.3
    v = rng.normal(size=(b, h, s, dh)).astype(np.float32)
    ig = rng.normal(size=(b, h, s)).astype(np.float32)
    fg = rng.normal(size=(b, h, s)).astype(np.float32) + 2.0

    got, (C, n, m) = mlstm_chunked(*map(jnp.array, (q, k, v, ig, fg)), chunk=chunk)

    state = (jnp.zeros((b, h, dh, dh)), jnp.zeros((b, h, dh)), jnp.full((b, h), -1e30))
    outs = []
    for t in range(s):
        o, state = mlstm_step(
            jnp.array(q[:, :, t]), jnp.array(k[:, :, t]), jnp.array(v[:, :, t]),
            jnp.array(ig[:, :, t]), jnp.array(fg[:, :, t]), state,
        )
        outs.append(np.asarray(o))
    want = np.stack(outs, axis=2)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(C), np.asarray(state[0]), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(m), np.asarray(state[2]), rtol=2e-4, atol=2e-4)


def test_mlstm_chunked_state_carry_consistency():
    """Running two halves with carried state == one full pass."""
    rng = np.random.default_rng(4)
    b, h, s, dh = 1, 2, 64, 8
    args = [rng.normal(size=(b, h, s, dh)).astype(np.float32) for _ in range(3)]
    gates = [rng.normal(size=(b, h, s)).astype(np.float32) for _ in range(2)]
    full, _ = mlstm_chunked(*map(jnp.array, args + gates), chunk=16)
    h1, st = mlstm_chunked(*[jnp.array(a[:, :, :32]) for a in args],
                           *[jnp.array(g[:, :, :32]) for g in gates], chunk=16)
    h2, _ = mlstm_chunked(*[jnp.array(a[:, :, 32:]) for a in args],
                          *[jnp.array(g[:, :, 32:]) for g in gates], state=st, chunk=16)
    got = jnp.concatenate([h1, h2], axis=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full), rtol=2e-4, atol=2e-4)


def test_rglru_train_scan_matches_decode_steps():
    rng = np.random.default_rng(5)
    d, w, s, b = 16, 16, 12, 2
    key = jax.random.PRNGKey(0)
    p = rglru_init(key, d, w, conv_width=4)
    x = jnp.array(rng.normal(size=(b, s, d)).astype(np.float32))
    y_train, _ = rglru_block(p, x, None)
    # decode token by token
    state = {"h": jnp.zeros((b, w)), "conv": jnp.zeros((b, 3, w))}
    outs = []
    for t in range(s):
        y, state = rglru_block(p, x[:, t : t + 1], state)
        outs.append(np.asarray(y)[:, 0])
    want = np.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_train), want, rtol=2e-4, atol=2e-4)


def test_conv1d_streaming_matches_batch():
    rng = np.random.default_rng(6)
    key = jax.random.PRNGKey(1)
    p = conv1d_init(key, 4, 8)
    x = jnp.array(rng.normal(size=(2, 10, 8)).astype(np.float32))
    y_full, _ = conv1d_apply(p, x)
    state = jnp.zeros((2, 3, 8))
    ys = []
    for t in range(10):
        y, state = conv1d_apply(p, x[:, t : t + 1], state)
        ys.append(np.asarray(y)[:, 0])
    np.testing.assert_allclose(np.asarray(y_full), np.stack(ys, 1), rtol=1e-5, atol=1e-5)


def test_moe_routes_and_shapes():
    key = jax.random.PRNGKey(2)
    d, f, e, k = 16, 32, 4, 2
    p = moe_init(key, d, f, e, "swiglu")
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 24, d), jnp.float32)
    y = moe_apply(p, x, top_k=k, kind="swiglu", seq_chunk=8)
    assert y.shape == x.shape
    assert not np.any(np.isnan(np.asarray(y)))
    # capacity sanity: single-expert router (all tokens to expert 0) must drop
    p0 = dict(p, router=jnp.zeros_like(p["router"]).at[:, 0].set(100.0))
    y0 = moe_apply(p0, x, top_k=1, kind="swiglu", seq_chunk=8, capacity_factor=0.5)
    # over-capacity tokens produce zero output rows
    zero_rows = np.isclose(np.abs(np.asarray(y0)).sum(-1), 0.0)
    assert zero_rows.sum() > 0


def test_rope_preserves_norm_and_relative_phase():
    rng = np.random.default_rng(7)
    x = jnp.array(rng.normal(size=(1, 2, 8, 16)).astype(np.float32))
    pos = jnp.arange(8)[None, :]
    y = apply_rope(x, pos, theta=10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5,
    )
    # shifting all positions by c leaves q.k inner products unchanged
    q = apply_rope(x, pos, 10000.0)
    k = apply_rope(x, pos, 10000.0)
    q2 = apply_rope(x, pos + 17, 10000.0)
    k2 = apply_rope(x, pos + 17, 10000.0)
    dots1 = np.einsum("bhqd,bhkd->bhqk", np.asarray(q), np.asarray(k))
    dots2 = np.einsum("bhqd,bhkd->bhqk", np.asarray(q2), np.asarray(k2))
    np.testing.assert_allclose(dots1, dots2, rtol=1e-3, atol=1e-3)


def test_mrope_sections():
    rng = np.random.default_rng(8)
    x = jnp.array(rng.normal(size=(1, 1, 4, 16)).astype(np.float32))
    pos3 = jnp.stack([jnp.arange(4), jnp.arange(4) * 2, jnp.arange(4) * 3], axis=-1)[None]
    y = apply_rope(x, pos3, 10000.0, m_rope_sections=(2, 3, 3))
    assert y.shape == x.shape
    assert not np.any(np.isnan(np.asarray(y)))
    # all-equal components == plain rope
    pos_eq = jnp.stack([jnp.arange(4)] * 3, axis=-1)[None]
    y_eq = apply_rope(x, pos_eq, 10000.0, m_rope_sections=(2, 3, 3))
    y_plain = apply_rope(x, jnp.arange(4)[None], 10000.0)
    np.testing.assert_allclose(np.asarray(y_eq), np.asarray(y_plain), rtol=1e-5, atol=1e-5)
