"""Subprocess entry for multi-process (simulated multi-host) topo tests.

Two invocation styles share this harness:

- **Cluster child** (via `repro.topo.bootstrap.launch_local_cluster`): the
  spec carries coordinator/num_processes/process_id and the launcher's
  environment already forces the per-process device count, so the child
  calls `init_distributed` *before any other jax use* and mines on the
  2-D topo mesh spanning all processes.

- **Standalone** (plain `python topo_subproc_main.py '<spec>'` with
  `n_devices` in the spec): mirrors tests/engine_subproc_main.py — sets
  the device-count XLA flag itself and runs single-process, either flat
  (no topology) or with a *forced* topology simulated on local devices.

Prints one JSON line: the full pattern set (items, support, pos_support,
pvalue, qvalue) plus the LAMP quantities, so the parent can assert
bit-identity across machine shapes.
"""

import json
import os
import sys


def main():
    spec = json.loads(sys.argv[1])
    if "n_devices" in spec:
        # standalone mode: replace (not just prepend to) any inherited
        # device-count flag, exactly as engine_subproc_main does
        inherited = [
            f for f in os.environ.get("XLA_FLAGS", "").split()
            if not f.startswith("--xla_force_host_platform_device_count")
        ]
        os.environ["XLA_FLAGS"] = " ".join(
            [f"--xla_force_host_platform_device_count={spec['n_devices']}"]
            + inherited
        )
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

    n_proc = int(spec.get("num_processes", 1))
    if n_proc > 1:
        # must run before the first jax backend touch in this process
        from repro.topo.bootstrap import init_distributed

        init_distributed(spec["coordinator"], n_proc, spec["process_id"])

    import jax

    from repro.api import (
        AlgorithmConfig,
        Dataset,
        MinerSession,
        RuntimeConfig,
    )
    from repro.data.synthetic import SyntheticSpec, generate
    from repro.topo import Topology

    # every process derives the identical dataset deterministically
    db, labels, _ = generate(SyntheticSpec(
        name="topo",
        n_items=spec["n_items"],
        n_transactions=spec["n_transactions"],
        density=spec["density"],
        n_pos=spec["n_pos"],
        n_planted=spec.get("n_planted", 2),
        seed=spec.get("seed", 0),
    ))

    topo = None
    if spec.get("topology") == "hier":
        if n_proc > 1:
            topo = Topology(n_proc, jax.local_device_count())
        else:
            topo = Topology(spec["n_hosts"], spec["devices_per_host"])

    runtime = RuntimeConfig(
        expand_batch=spec.get("expand_batch", 8),
        stack_cap=spec.get("stack_cap", 4096),
        steal_max=spec.get("steal_max", 64),
        push_cap=spec.get("push_cap", 256),
        out_cap=spec.get("out_cap", 1024),
        kernel_impl=spec.get("kernel_impl", "ref"),
        trace_period=spec.get("trace_period", 0),
        topology=topo,
    )
    session = MinerSession(
        algorithm=AlgorithmConfig(alpha=spec.get("alpha", 0.05)),
        runtime=runtime,
    )
    rep = session.mine(Dataset.from_dense(db, labels, name="topo"))

    out = {
        "process_id": spec.get("process_id", 0),
        "n_devices_global": jax.device_count(),
        "lambda_final": rep.lambda_final,
        "min_sup": rep.min_sup,
        "correction_factor": rep.correction_factor,
        "delta": rep.delta,
        "n_significant": rep.n_significant,
        "patterns": [
            [list(p.items), p.support, p.pos_support, p.pvalue, p.qvalue]
            for p in rep.results
        ],
        "supersteps": [p.supersteps for p in rep.phases],
    }
    if spec.get("trace_period", 0):
        p1 = rep.phases[0]
        out["steal_by_round"] = p1.steal_by_round
        out["tier_fairness"] = p1.tier_fairness
    print(json.dumps(out))


if __name__ == "__main__":
    main()
