"""Observability subsystem (repro.obs, DESIGN.md §9).

Three layers, three contracts:

* device superstep trace — tracing must be *free of observable effect*
  (traced and untraced runs bit-identical), the decoded timeline must
  reconcile with the engine's cumulative counters, and ring wrap must be
  loud (trace_dropped + RuntimeWarning, mirroring emit_dropped);
* host span tracer — Chrome-trace JSON any viewer loads;
* metrics registry — Prometheus text exposition any scraper parses.

The exporter formats are pinned by the same validators CI runs against the
artifacts of a real traced mine (repro.obs.validate).  Multi-device trace
parity runs in a subprocess (pytest's jax is already initialized with one
device); decode invariants are property-tested under hypothesis with a
seeded sweep fallback.
"""

import io
import json
import os
import subprocess
import sys
import warnings

import numpy as np
import pytest

from repro.obs import (
    DEFAULT_TRACE_CAP,
    JsonlLogger,
    MetricsRegistry,
    N_FIELDS,
    SpanTracer,
    TraceField,
    decode_trace,
    jain_fairness,
)
from repro.obs.trace import expected_samples
from repro.obs.validate import validate_chrome_trace, validate_prometheus_text

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

HERE = os.path.dirname(os.path.abspath(__file__))


def run_subproc(spec: dict) -> dict:
    from repro.core.collectives import host_device_count_env

    env = host_device_count_env(spec["n_devices"])
    env["PYTHONPATH"] = os.path.join(HERE, "..", "src")
    out = subprocess.run(
        [sys.executable, os.path.join(HERE, "engine_subproc_main.py"),
         json.dumps(spec)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


# ------------------------------------------------------------------ trace unit
def make_ring(n_miners, cap, supersteps, period, seed=0):
    """Simulate the engine's ring writes exactly (slot = idx % cap)."""
    rng = np.random.default_rng(seed)
    raw = np.zeros((n_miners, cap, N_FIELDS), np.int32)
    for t in range(supersteps):
        if t % period:
            continue
        idx = t // period
        rec = rng.integers(0, 100, size=(n_miners, N_FIELDS)).astype(np.int32)
        rec[:, TraceField.STEP] = t
        raw[:, idx % cap, :] = rec
    return raw


def check_invariants(tr, n_miners, cap, supersteps, period):
    n_sampled = expected_samples(supersteps, period)
    assert tr.n_steps == min(n_sampled, cap)
    assert tr.dropped == n_sampled - tr.n_steps
    assert tr.n_miners == n_miners
    # superstep ids strictly increasing, all multiples of the period,
    # and — after a wrap — exactly the most recent window
    assert np.all(np.diff(tr.steps) > 0)
    assert np.all(tr.steps % period == 0)
    if tr.dropped:
        assert tr.steps[0] == tr.dropped * period
    per_miner = (tr.depth, tr.popped, tr.pushed, tr.closed, tr.emitted,
                 tr.donated, tr.received)
    for arr in per_miner:
        assert arr.shape == (n_miners, tr.n_steps)
    for f in (tr.donation_fairness(), tr.work_fairness()):
        assert 0.0 <= f <= 1.0 + 1e-12
    idle = tr.idle_fraction()
    assert idle.shape == (n_miners,)
    assert np.all((idle >= 0) & (idle <= 1))
    json.dumps(tr.summary())  # metrics blob must be JSON-able


def test_decode_no_wrap():
    raw = make_ring(4, cap=64, supersteps=40, period=1)
    tr = decode_trace(raw, supersteps=40, period=1)
    check_invariants(tr, 4, 64, 40, 1)
    assert tr.steps.tolist() == list(range(40))


def test_decode_wrap_keeps_most_recent_window():
    raw = make_ring(2, cap=8, supersteps=30, period=1)
    tr = decode_trace(raw, supersteps=30, period=1)
    check_invariants(tr, 2, 8, 30, 1)
    assert tr.dropped == 22
    assert tr.steps.tolist() == list(range(22, 30))


def test_decode_sampled_period():
    raw = make_ring(3, cap=16, supersteps=50, period=4)
    tr = decode_trace(raw, supersteps=50, period=4)
    check_invariants(tr, 3, 16, 50, 4)
    assert tr.steps.tolist() == list(range(0, 50, 4))


def test_decode_rejects_wrong_shape():
    with pytest.raises(ValueError, match="expected raw trace"):
        decode_trace(np.zeros((2, 8, N_FIELDS + 1)), supersteps=8, period=1)


if HAVE_HYPOTHESIS:

    @settings(max_examples=60, deadline=None)
    @given(
        n_miners=st.integers(1, 6),
        cap=st.integers(1, 32),
        supersteps=st.integers(0, 120),
        period=st.integers(1, 7),
    )
    def test_decode_invariants_property(n_miners, cap, supersteps, period):
        raw = make_ring(n_miners, cap, supersteps, period, seed=cap)
        tr = decode_trace(raw, supersteps=supersteps, period=period)
        check_invariants(tr, n_miners, cap, supersteps, period)


def test_decode_invariants_seeded_sweep():
    """Seeded sweep of the same invariants — always runs, even without
    hypothesis."""
    rng = np.random.default_rng(7)
    for _ in range(40):
        n_miners = int(rng.integers(1, 7))
        cap = int(rng.integers(1, 33))
        supersteps = int(rng.integers(0, 121))
        period = int(rng.integers(1, 8))
        raw = make_ring(n_miners, cap, supersteps, period, seed=cap)
        tr = decode_trace(raw, supersteps=supersteps, period=period)
        check_invariants(tr, n_miners, cap, supersteps, period)


def test_jain_fairness():
    assert jain_fairness([1, 1, 1, 1]) == pytest.approx(1.0)
    assert jain_fairness([4, 0, 0, 0]) == pytest.approx(0.25)
    assert jain_fairness([0, 0, 0]) == 1.0  # nothing to share = fair
    assert jain_fairness([]) == 1.0
    x = np.random.default_rng(0).integers(0, 50, 16)
    assert 1 / 16 <= jain_fairness(x) <= 1.0


# ------------------------------------------------------------- engine tracing
def _problem(seed=0):
    from repro.data.synthetic import SyntheticSpec, generate

    return generate(SyntheticSpec(
        name="obs", n_items=24, n_transactions=60, density=0.15, n_pos=20,
        n_planted=2, seed=seed,
    ))


def _cfg(**kw):
    from repro.core.engine import EngineConfig

    return EngineConfig(expand_batch=8, stack_cap=2048, steal_max=32,
                        push_cap=128, **kw)


@pytest.mark.parametrize("mode", ["lamp1", "count", "test"])
def test_tracing_is_bit_identical(mode):
    """The tentpole's contract: trace_period changes the carry, never the
    answer — histogram, lambda, and emitted records all match exactly."""
    from repro.core.engine import mine

    db, labels, _ = _problem(seed=0)
    kw = dict(min_sup=3) if mode != "lamp1" else {}
    off = mine(db, labels, mode=mode, cfg=_cfg(), **kw)
    on = mine(db, labels, mode=mode,
              cfg=_cfg(trace_period=1, trace_cap=1024), **kw)
    np.testing.assert_array_equal(off.hist, on.hist)
    assert off.lam_final == on.lam_final
    assert off.supersteps == on.supersteps
    assert off.sig_count == on.sig_count
    if mode == "test":
        np.testing.assert_array_equal(off.sig_occ, on.sig_occ)
        np.testing.assert_array_equal(off.sig_sup, on.sig_sup)
    assert off.trace is None
    assert on.trace is not None


def test_trace_reconciles_with_stats():
    """Per-step trace volumes summed over time == the cumulative counters."""
    from repro.core.engine import mine

    db, labels, _ = _problem(seed=1)
    res = mine(db, labels, mode="lamp1",
               cfg=_cfg(trace_period=1, trace_cap=1024))
    tr = res.trace
    assert tr.n_steps == res.supersteps and tr.dropped == 0
    np.testing.assert_array_equal(tr.popped.sum(axis=1), res.stats["popped"])
    np.testing.assert_array_equal(tr.pushed.sum(axis=1), res.stats["pushed"])
    np.testing.assert_array_equal(tr.closed.sum(axis=1), res.stats["closed"])
    assert int(tr.fired.sum()) == int(res.stats["steal_rounds"][0])
    assert np.all(tr.depth >= 0)
    assert np.all(np.diff(tr.lam) >= 0)  # LAMP lambda only ratchets up
    assert tr.lam[-1] <= res.lam_final  # recorded pre-sync


def test_ring_wrap_warns_and_counts():
    from repro.core.engine import mine

    db, labels, _ = _problem(seed=0)
    full = mine(db, labels, mode="count", min_sup=3,
                cfg=_cfg(trace_period=1, trace_cap=1024))
    assert full.trace_dropped == 0
    cap = 4
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        res = mine(db, labels, mode="count", min_sup=3,
                   cfg=_cfg(trace_period=1, trace_cap=cap))
    assert any("trace ring wrapped" in str(x.message) for x in w)
    assert res.trace_dropped == res.supersteps - cap
    # the device-side counter agrees with the host-side decode; every miner
    # samples on the same global step cadence, so the [P] counter is uniform
    np.testing.assert_array_equal(
        res.stats["trace_dropped"],
        np.full_like(res.stats["trace_dropped"], res.trace_dropped),
    )
    # the surviving window is the most recent one, results still exact
    assert res.trace.steps.tolist() == list(
        range(res.supersteps - cap, res.supersteps)
    )
    np.testing.assert_array_equal(res.hist, full.hist)


def test_trace_period_validation():
    from repro.core.engine import mine

    db, labels, _ = _problem(seed=0)
    with pytest.raises(ValueError, match="requires trace_cap"):
        mine(db, labels, mode="count", min_sup=3, cfg=_cfg(trace_period=1))
    with pytest.raises(ValueError, match="trace_period"):
        mine(db, labels, mode="count", min_sup=3,
             cfg=_cfg(trace_period=-1, trace_cap=8))


@pytest.mark.slow
@pytest.mark.parametrize("n_devices", [8])
def test_multidevice_trace_parity(n_devices):
    """8 simulated miners: tracing stays bit-identical with real steal
    traffic in flight, and the decoded timeline reconciles per miner."""
    got = run_subproc(dict(
        n_items=24, n_transactions=60, density=0.15, n_pos=20, seed=0,
        mode="trace_parity", n_devices=n_devices, trace_period=1,
        trace_cap=4096,
    ))
    assert got["hist_equal"] and got["lam_equal"] and got["supersteps_equal"]
    assert got["dropped"] == 0
    assert got["sampled_steps"] == got["supersteps"]
    assert got["steps_monotone"] and got["depth_nonneg"]
    assert got["popped_matches_stats"] and got["fired_matches_stats"]
    assert 1 / n_devices <= got["donation_fairness"] <= 1.0 + 1e-12


# -------------------------------------------------------------- session layer
def test_session_trace_and_metrics_wiring():
    from repro.api import Dataset, MinerSession, RuntimeConfig

    db, labels, _ = _problem(seed=2)
    ds = Dataset.from_dense(db, labels, name="obs")
    session = MinerSession(
        runtime=RuntimeConfig(trace_period=1, trace_cap=512))
    rep = session.mine(ds)
    rep2 = session.mine(ds)  # warm
    for p in rep.phases + rep2.phases:
        assert p.trace is not None
        assert p.trace.n_steps == p.supersteps
    # metrics mirror cache_info
    ci = session.cache_info()
    text = session.metrics.expose_text()
    assert validate_prometheus_text(text) > 0
    assert f"miner_cache_hits_total {ci.hits}" in text
    assert f"miner_cache_misses_total {ci.misses}" in text
    assert f"miner_cached_programs {ci.n_programs}" in text
    # per-phase and per-query latency histograms observed every pass
    n_phases = len(rep.phases) + len(rep2.phases)
    first_mode = rep.phases[0].mode
    assert f'miner_phase_seconds_count{{mode="{first_mode}"}}' in text
    counts = sum(
        int(float(line.rsplit(" ", 1)[1]))
        for line in text.splitlines()
        if line.startswith("miner_phase_seconds_count")
    )
    assert counts == n_phases
    assert 'miner_query_seconds_count{query="significant"} 2' in text
    # span timeline: one phase span per pass, nested sub-spans, valid JSON
    ct = session.tracer.to_chrome_trace()
    assert validate_chrome_trace(ct) > 0
    names = [e["name"] for e in ct["traceEvents"]]
    for p in rep.phases:
        assert f"phase:{p.mode}" in names
    assert sum(n.startswith("phase:") for n in names) == n_phases
    assert "dispatch" in names and "postprocess" in names
    assert "compile" in names and "reconstruct" in names
    assert names.count("query:SignificantPatternQuery") == 2


def test_session_untraced_has_no_trace():
    from repro.api import Dataset, MinerSession

    db, labels, _ = _problem(seed=2)
    ds = Dataset.from_dense(db, labels, name="obs")
    rep = MinerSession().mine(ds)
    assert all(p.trace is None for p in rep.phases)


def test_resolve_defaults_trace_cap():
    from repro.api import Dataset, RuntimeConfig

    db, labels, _ = _problem(seed=2)
    bucket = Dataset.from_dense(db, labels, name="obs").bucket
    cfg = RuntimeConfig(trace_period=4).resolve(bucket, 1)
    assert cfg.trace_period == 4
    assert cfg.trace_cap == DEFAULT_TRACE_CAP
    cfg = RuntimeConfig(trace_period=4, trace_cap=128).resolve(bucket, 1)
    assert cfg.trace_cap == 128
    cfg = RuntimeConfig().resolve(bucket, 1)
    assert cfg.trace_period == 0 and cfg.trace_cap == 0


def test_trace_period_joins_cache_key():
    """Traced and untraced sessions must not share compiled programs."""
    from repro.api import Dataset, MinerSession, RuntimeConfig

    db, labels, _ = _problem(seed=2)
    ds = Dataset.from_dense(db, labels, name="obs")
    session = MinerSession()
    session.run_phase(ds, "count", min_sup=3)
    misses0 = session.cache_info().misses
    traced = MinerSession(runtime=RuntimeConfig(trace_period=1, trace_cap=64))
    r1 = traced.runtime.resolve(ds.bucket, 1)
    r0 = session.runtime.resolve(ds.bucket, 1)
    assert r1 != r0  # distinct EngineConfigs -> distinct cache keys
    assert misses0 == 1


# ----------------------------------------------------------------- span layer
def test_span_tracer_nesting_and_export(tmp_path):
    tracer = SpanTracer()
    with tracer.span("outer", query="q1"):
        with tracer.span("inner"):
            pass
        with tracer.span("inner"):
            pass
    events = tracer.events()
    assert [e["name"] for e in events] == ["inner", "inner", "outer"]
    outer = events[-1]
    for inner in events[:2]:  # nested spans lie inside the outer interval
        assert outer["ts"] <= inner["ts"]
        assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6
    assert outer["args"] == {"query": "q1"}
    path = tracer.save(str(tmp_path / "trace.json"))
    assert validate_chrome_trace(path) == 3
    tracer.clear()
    assert tracer.events() == []


def test_span_tracer_records_on_exception():
    tracer = SpanTracer()
    with pytest.raises(RuntimeError):
        with tracer.span("boom"):
            raise RuntimeError("x")
    assert [e["name"] for e in tracer.events()] == ["boom"]


def test_span_tracer_jax_profiler_bridge():
    """jax_profiler=True must degrade to plain recording, never raise."""
    tracer = SpanTracer(jax_profiler=True)
    with tracer.span("bridged"):
        pass
    assert len(tracer.events()) == 1


# -------------------------------------------------------------- metrics layer
def test_metrics_exposition_format():
    reg = MetricsRegistry()
    c = reg.counter("jobs_total", "jobs processed")
    g = reg.gauge("queue_depth", "live queue depth")
    h = reg.histogram("latency_seconds", "op latency", buckets=(0.1, 1.0))
    lab = reg.counter("errors_total", "errors by kind", labels=("kind",))
    c.inc()
    c.inc(2)
    g.set(5)
    g.inc(-2)
    h.observe(0.05)
    h.observe(0.5)
    h.observe(30.0)
    lab.labels(kind="io").inc()
    lab.labels(kind='we"ird\\').inc(3)
    text = reg.expose_text()
    # jobs_total + queue_depth + 2 errors_total children + histogram's
    # (2 bounds + Inf + sum + count) = 9 samples
    assert validate_prometheus_text(text) == 9
    assert "jobs_total 3" in text
    assert "queue_depth 3" in text
    assert 'latency_seconds_bucket{le="0.1"} 1' in text
    assert 'latency_seconds_bucket{le="1"} 2' in text
    assert 'latency_seconds_bucket{le="+Inf"} 3' in text
    assert "latency_seconds_count 3" in text
    assert 'errors_total{kind="io"} 1' in text
    assert 'errors_total{kind="we\\"ird\\\\"} 3' in text


def test_metrics_semantics():
    reg = MetricsRegistry()
    c = reg.counter("c_total")
    with pytest.raises(ValueError, match="only go up"):
        c.inc(-1)
    # idempotent re-registration returns the same instrument
    assert reg.counter("c_total") is c
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("c_total")
    lab = reg.histogram("h_seconds", labels=("op",))
    with pytest.raises(ValueError, match="expected labels"):
        lab.labels(wrong="x")
    with pytest.raises(ValueError, match="invalid metric name"):
        reg.counter("0bad")
    h = reg.histogram("h2_seconds", buckets=(1.0, 0.1))  # sorted for you
    h.observe(0.5)  # > 0.1, <= 1.0
    assert h.cumulative_counts() == [0, 1, 1]


# ------------------------------------------------------------------ log layer
def test_jsonl_logger():
    buf = io.StringIO()
    log = JsonlLogger(buf, clock=lambda: 123.456)
    rec = log.event("phase", mode="count", wall_s=0.5, arr=np.arange(2))
    lines = buf.getvalue().splitlines()
    assert len(lines) == 1
    parsed = json.loads(lines[0])
    assert parsed["ts"] == 123.456
    assert parsed["event"] == "phase"
    assert parsed["mode"] == "count"
    assert parsed["arr"] == "[0 1]"  # non-JSON values stringified, not raised
    assert rec["mode"] == "count"


# ------------------------------------------------------------------ validators
def test_chrome_validator_rejects_malformed():
    with pytest.raises(ValueError, match="traceEvents"):
        validate_chrome_trace({"events": []})
    bad = {"traceEvents": [{"name": "x", "ph": "X", "ts": 0}]}
    with pytest.raises(ValueError, match="dur"):
        validate_chrome_trace(bad)
    bad = {"traceEvents": [{"name": "", "ph": "X", "ts": 0, "dur": 1}]}
    with pytest.raises(ValueError, match="name"):
        validate_chrome_trace(bad)


def test_prometheus_validator_rejects_malformed():
    with pytest.raises(ValueError, match="no preceding TYPE"):
        validate_prometheus_text("mystery_metric 1\n")
    with pytest.raises(ValueError, match="malformed sample"):
        validate_prometheus_text("# TYPE a counter\na 1 2 3\n")
    bad_hist = (
        "# TYPE h histogram\n"
        'h_bucket{le="1"} 5\nh_bucket{le="+Inf"} 3\nh_count 3\n'
    )
    with pytest.raises(ValueError, match="not cumulative"):
        validate_prometheus_text(bad_hist)
    no_inf = "# TYPE h histogram\n" 'h_bucket{le="1"} 1\n'
    with pytest.raises(ValueError, match=r"\+Inf"):
        validate_prometheus_text(no_inf)
