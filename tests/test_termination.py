"""Mattern time-algorithm DTD on a simulated async transport (paper §4.3)."""

import random

import pytest

from repro.core.termination import TerminationDetector, TernaryTree


class SimTransport:
    """Async message simulator with arbitrary (seeded) delivery order."""

    def __init__(self, n, seed=0):
        self.tree = TernaryTree(n)
        self.det = [TerminationDetector(i, self.tree) for i in range(n)]
        self.control: list[tuple[int, object]] = []
        self.basic: list[tuple[int, int]] = []  # (dst, stamp)
        self.rng = random.Random(seed)

    def send_basic(self, src, dst):
        stamp = self.det[src].on_basic_send()
        self.basic.append((dst, stamp))

    def deliver_one_basic(self):
        if not self.basic:
            return False
        i = self.rng.randrange(len(self.basic))
        dst, stamp = self.basic.pop(i)
        self.det[dst].on_basic_receive(stamp)
        return True

    def run_wave(self):
        msgs = list(self.det[0].start_wave())
        while msgs:
            i = self.rng.randrange(len(msgs))
            dst, payload = msgs.pop(i)
            msgs.extend(self.det[dst].handle_control(payload))
        return self.det[0].terminated


@pytest.mark.parametrize("n", [1, 2, 3, 7, 13])
def test_quiet_system_terminates(n):
    sim = SimTransport(n)
    assert sim.run_wave()


def test_in_flight_message_defers_termination():
    """The classic race: counters sum to zero only after delivery."""
    sim = SimTransport(5)
    sim.send_basic(1, 3)  # one basic message in flight
    assert not sim.run_wave()  # counter sum = +1 -> not terminated
    sim.deliver_one_basic()
    # first wave after delivery sees a stale stamp (crossed the boundary)
    assert not sim.run_wave()
    # quiet since -> next wave terminates
    assert sim.run_wave()


def test_crossing_send_receive_pair_is_caught():
    """Equal send/recv counts must not fake termination (time-stamp check)."""
    sim = SimTransport(4, seed=3)
    # message sent in epoch 0, still in flight
    sim.send_basic(2, 1)
    sim.run_wave()  # epoch 1 begins; counter nonzero -> no termination
    # deliver the old message (stamp 0 < clock 1) and send+deliver a fresh pair
    sim.deliver_one_basic()
    sim.send_basic(1, 2)
    sim.deliver_one_basic()
    # counters all zero now, but the stale receive must veto this wave
    assert not sim.run_wave()
    assert sim.run_wave()


def test_busy_process_blocks_termination():
    sim = SimTransport(3)
    sim.det[2].is_idle = lambda: False
    assert not sim.run_wave()
    sim.det[2].is_idle = lambda: True
    assert sim.run_wave()


@pytest.mark.parametrize("seed", range(5))
def test_random_traffic_never_false_terminates(seed):
    """Property: termination is declared only when no message is in flight.

    Termination latches (it is permanent in a real system), so the traffic
    generator stops once a wave first declares it.
    """
    rng = random.Random(seed)
    sim = SimTransport(9, seed=seed)
    for _ in range(200):
        action = rng.random()
        if action < 0.4:
            sim.send_basic(rng.randrange(9), rng.randrange(9))
        elif action < 0.8:
            sim.deliver_one_basic()
        else:
            if sim.run_wave():
                assert not sim.basic, "false termination with in-flight messages"
                return
    # drain and require termination within two clean waves
    while sim.deliver_one_basic():
        pass
    sim.run_wave()
    assert sim.run_wave()
