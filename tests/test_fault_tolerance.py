"""Fault-tolerant mining: kill-and-resume bit-identity, elastic resharding,
provenance refusal, corrupt-step fallback, cooperative partial results
(DESIGN.md §11).

Everything runs in-process: a "kill" is a `SimulatedFault` raised at an
engine segment boundary (`repro.testing.faults`), and "fewer devices" is a
fresh `MinerSession` over a subset of `jax.devices()` — no subprocesses, so
the bit-identical asserts compare real ResultSets object-for-object.
"""

import pytest
import jax

from repro.api import Dataset, MinerSession, RuntimeConfig
from repro.api.query import ClosedFrequentQuery, SignificantPatternQuery
from repro.ckpt.mining import ProvenanceMismatch
from repro.data.synthetic import SyntheticSpec, generate
from repro.testing import FaultPlan, SimulatedFault, corrupt_step_dir, injected

CKPT_CFG = RuntimeConfig(expand_batch=4, ckpt_period=2)
Q = SignificantPatternQuery(alpha=0.05)


def small_dataset(seed=0, n=60, m=24):
    spec = SyntheticSpec(name=f"ft{seed}", n_items=m, n_transactions=n,
                         density=0.15, n_pos=20, n_planted=2, seed=seed)
    db, labels, _ = generate(spec)
    return Dataset.from_dense(db, labels, name=f"ft{seed}")


def _keys(rs):
    return [(p.items, p.support, p.pos_support, p.pvalue, p.qvalue)
            for p in rs]


def _expect(ds, devices=None):
    return MinerSession(devices, runtime=CKPT_CFG).run(ds, Q)


def _assert_identical(a, b):
    assert (a.min_sup, a.correction_factor, a.delta, a.n_significant) == (
        b.min_sup, b.correction_factor, b.delta, b.n_significant)
    assert _keys(a.results.patterns) == _keys(b.results.patterns)


# ------------------------------------------------------------ kill + resume
def test_kill_and_resume_bit_identical(tmp_path):
    ds = small_dataset(seed=1)
    baseline = _expect(ds)
    with injected(FaultPlan(die_after_segments=2)):
        with pytest.raises(SimulatedFault):
            MinerSession(runtime=CKPT_CFG).run(ds, Q, ckpt_dir=str(tmp_path))
    resumed = MinerSession(runtime=CKPT_CFG).run(
        ds, Q, resume_from=str(tmp_path))
    assert any(p.resumed for p in resumed.phases)
    assert not resumed.partial and resumed.results.complete
    _assert_identical(baseline, resumed)


def test_completed_run_restores_every_phase(tmp_path):
    """The terminal carry of each phase is checkpointed too, so resuming a
    finished mine short-circuits every phase (work == 0 skips the loop) and
    still reproduces the answer exactly."""
    ds = small_dataset(seed=2)
    first = MinerSession(runtime=CKPT_CFG).run(ds, Q, ckpt_dir=str(tmp_path))
    again = MinerSession(runtime=CKPT_CFG).run(
        ds, Q, resume_from=str(tmp_path))
    assert all(p.resumed for p in again.phases)
    _assert_identical(first, again)


def test_ckpt_flags_require_ckpt_period(tmp_path):
    ds = small_dataset(seed=1)
    with pytest.raises(ValueError, match="ckpt_period"):
        MinerSession(runtime=RuntimeConfig(expand_batch=4)).run(
            ds, Q, ckpt_dir=str(tmp_path))


def test_ckpt_writes_counted_in_phase_reports(tmp_path):
    from repro.obs.validate import validate_prometheus_text

    ds = small_dataset(seed=1)
    session = MinerSession(runtime=CKPT_CFG)
    report = session.run(ds, Q, ckpt_dir=str(tmp_path))
    assert sum(p.ckpt_writes for p in report.phases) > 0
    assert sum(p.ckpt_bytes for p in report.phases) > 0
    assert all(p.ckpt_path for p in report.phases)
    # the checkpoint latency/bytes metrics ride the session registry and
    # pass the CI Prometheus validator
    text = session.metrics.expose_text()
    assert validate_prometheus_text(text) > 0
    assert "miner_ckpt_write_seconds" in text
    assert "miner_ckpt_bytes_total" in text
    again = MinerSession(runtime=CKPT_CFG)
    again.run(ds, Q, resume_from=str(tmp_path))
    assert "miner_ckpt_restore_seconds" in again.metrics.expose_text()


# --------------------------------------------------------------- provenance
def test_provenance_mismatch_refused(tmp_path):
    ds = small_dataset(seed=1)
    MinerSession(runtime=CKPT_CFG).run(ds, Q, ckpt_dir=str(tmp_path))
    other = small_dataset(seed=9)  # same shape bucket, different bytes
    with pytest.raises(ProvenanceMismatch, match="fingerprint"):
        MinerSession(runtime=CKPT_CFG).run(
            other, Q, resume_from=str(tmp_path))


def test_corrupt_newest_step_falls_back(tmp_path):
    """Byte rot in the newest frontier step: resume warns, falls back to an
    older valid step, and the answer is still bit-identical."""
    import os

    ds = small_dataset(seed=3)
    baseline = _expect(ds)
    cfg = RuntimeConfig(expand_batch=1, steal_enabled=False, ckpt_period=1)
    with injected(FaultPlan(die_after_segments=6)):
        with pytest.raises(SimulatedFault):
            MinerSession(runtime=cfg).run(ds, Q, ckpt_dir=str(tmp_path))
    phase_dir = os.path.join(str(tmp_path), "00_lamp1")
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(phase_dir)
        if d.startswith("step_"))
    assert len(steps) >= 2, "need >= 2 saved steps for the fallback test"
    corrupt_step_dir(os.path.join(phase_dir, f"step_{steps[-1]}"))
    with pytest.warns(RuntimeWarning, match="corrupt"):
        resumed = MinerSession(runtime=cfg).run(
            ds, Q, resume_from=str(tmp_path))
    _assert_identical(baseline, resumed)


# --------------------------------------------------------- partial results
def test_soft_stop_returns_partial_resumable_result(tmp_path):
    """An immediately-expiring should_stop still completes one segment,
    returns a truncated-but-real ResultSet plus a checkpoint path, and the
    checkpoint resumes to the full answer."""
    ds = small_dataset(seed=4, n=80, m=32)
    cfg = RuntimeConfig(expand_batch=1, steal_enabled=False, ckpt_period=1)
    q = ClosedFrequentQuery(min_sup=1)
    full = MinerSession(runtime=cfg).run(ds, q)
    # stop after a bounded number of one-superstep segments: enough traversal
    # for real emissions, far short of the full enumeration.  How many
    # supersteps pass before the first closure is emitted depends on the
    # device count (one miner walks the lattice serially, eight walk it in
    # parallel), so grow the budget until the partial answer is non-empty.
    part = None
    for budget in (5, 20, 40, 80):
        polls = {"n": 0}

        def stop_soon(polls=polls, budget=budget):
            polls["n"] += 1
            return polls["n"] > budget

        part = MinerSession(runtime=cfg).run(
            ds, q, ckpt_dir=str(tmp_path), should_stop=stop_soon)
        assert part.partial and not part.results.complete
        assert part.ckpt_path is not None
        if part.results.patterns:
            break
    assert 0 < len(part.results.patterns) < len(full.results.patterns)
    # closed-frequent p/q-values are NaN (no statistic): key on the
    # NaN-free fields
    def keys(rs):
        return [(p.items, p.support, p.pos_support) for p in rs]

    # every partial pattern is a real pattern of the full answer
    assert set(keys(part.results.patterns)) <= set(keys(full.results.patterns))
    # and the checkpoint it left behind resumes to the complete answer
    done = MinerSession(runtime=cfg).run(ds, q, resume_from=str(tmp_path))
    assert done.results.complete
    assert keys(done.results.patterns) == keys(full.results.patterns)


# ------------------------------------------------------- elastic resharding
@pytest.mark.slow
@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="elastic reshard tests need 8 devices")
@pytest.mark.parametrize("new_devices", [4, 1])
def test_elastic_resume_8_to_fewer(tmp_path, new_devices):
    ds = small_dataset(seed=5, n=100, m=32)
    devices = jax.devices()
    baseline = _expect(ds, devices[:8])
    with injected(FaultPlan(die_after_segments=2)):
        with pytest.raises(SimulatedFault):
            MinerSession(devices[:8], runtime=CKPT_CFG).run(
                ds, Q, ckpt_dir=str(tmp_path))
    resumed = MinerSession(devices[:new_devices], runtime=CKPT_CFG).run(
        ds, Q, resume_from=str(tmp_path))
    assert any(p.resumed for p in resumed.phases)
    _assert_identical(baseline, resumed)
