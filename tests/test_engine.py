"""Distributed engine vs sequential oracle.

In-process tests run with the default single device (P=1 exercises the full
BSP machinery minus real steals).  Multi-device tests spawn a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=P — pytest's jax is already
initialized with one device, and the flag must precede first jax init.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.engine import EngineConfig, lamp_distributed, mine
from repro.core.lamp import lamp
from repro.core.lcm import lcm_closed
from repro.data.synthetic import SyntheticSpec, generate

HERE = os.path.dirname(os.path.abspath(__file__))


def small_problem(seed=0, n=60, m=24, density=0.15, n_pos=20, planted=2):
    spec = SyntheticSpec(
        name="t", n_items=m, n_transactions=n, density=density, n_pos=n_pos,
        n_planted=planted, seed=seed,
    )
    return generate(spec)


CFG = EngineConfig(expand_batch=8, stack_cap=2048, steal_max=32, push_cap=128)


# ------------------------------------------------------------- in-process P=1
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_count_mode_matches_sequential(seed):
    db, labels, _ = small_problem(seed=seed)
    min_sup = 3
    res = mine(db, labels, mode="count", min_sup=min_sup, cfg=CFG)
    seq, _ = lcm_closed(db, min_sup=min_sup)
    want = np.zeros_like(res.hist)
    for _, s in seq:
        want[s] += 1
    np.testing.assert_array_equal(res.hist, want)


def test_lamp1_matches_sequential():
    db, labels, _ = small_problem(seed=3)
    res = mine(db, labels, mode="lamp1", alpha=0.05, cfg=CFG)
    ref = lamp(db, labels, alpha=0.05)
    assert res.lam_final == ref.lambda_final


def test_full_pipeline_matches_sequential():
    db, labels, _ = small_problem(seed=4)
    got = lamp_distributed(db, labels, alpha=0.05, cfg=CFG)
    ref = lamp(db, labels, alpha=0.05)
    assert got["min_sup"] == ref.min_sup
    assert got["correction_factor"] == ref.correction_factor
    assert got["n_significant"] == len(ref.significant)
    # sample buffer contents agree with reference (sup, pos_sup) multiset
    p3 = got["phase_outputs"][2]
    got_pairs = sorted(zip(p3.sig_sup.tolist(), p3.sig_pos_sup.tolist()))
    ref_pairs = sorted(
        (s.support, s.pos_support) for s in ref.significant if len(s.items) > 0
    )
    assert got_pairs == ref_pairs


def test_push_cap_resume_path():
    """Tiny push cap forces resume nodes; result must not change."""
    db, labels, _ = small_problem(seed=5, m=16)
    tight = EngineConfig(expand_batch=4, stack_cap=2048, steal_max=16, push_cap=8)
    res_tight = mine(db, labels, mode="count", min_sup=2, cfg=tight)
    res_wide = mine(db, labels, mode="count", min_sup=2, cfg=CFG)
    np.testing.assert_array_equal(res_tight.hist, res_wide.hist)


def test_expand_batch_sweep():
    db, labels, _ = small_problem(seed=6)
    ref_hist = None
    for b in [1, 4, 16]:
        cfg = EngineConfig(expand_batch=b, stack_cap=2048, steal_max=32, push_cap=128)
        res = mine(db, labels, mode="count", min_sup=2, cfg=cfg)
        if ref_hist is None:
            ref_hist = res.hist
        else:
            np.testing.assert_array_equal(res.hist, ref_hist)


# ------------------------------------------------------------ subprocess P>=2
def run_subproc(spec: dict) -> dict:
    from repro.core.collectives import host_device_count_env

    env = host_device_count_env(spec["n_devices"])
    env["PYTHONPATH"] = os.path.join(HERE, "..", "src")
    out = subprocess.run(
        [sys.executable, os.path.join(HERE, "engine_subproc_main.py"), json.dumps(spec)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
@pytest.mark.parametrize("n_devices", [4, 6, 8])
def test_multidevice_count_matches_oracle(n_devices):
    prob = dict(n_items=24, n_transactions=60, density=0.15, n_pos=20, seed=0)
    spec = dict(prob, mode="count", min_sup=3, n_devices=n_devices)
    got = run_subproc(spec)
    db, labels, _ = small_problem(seed=0)
    seq, _ = lcm_closed(db, min_sup=3)
    want = np.zeros(62, dtype=np.int64)
    for _, s in seq:
        want[s] += 1
    np.testing.assert_array_equal(np.array(got["hist"]), want)
    assert sum(got["steals_got"]) > 0, "multi-device run should exercise steals"


@pytest.mark.slow
def test_multidevice_lamp_full_matches_oracle():
    prob = dict(n_items=24, n_transactions=60, density=0.15, n_pos=20, seed=1)
    spec = dict(prob, mode="lamp_full", n_devices=8)
    got = run_subproc(spec)
    db, labels, _ = small_problem(seed=1)
    ref = lamp(db, labels, alpha=0.05)
    assert got["min_sup"] == ref.min_sup
    assert got["correction_factor"] == ref.correction_factor
    assert got["n_significant"] == len(ref.significant)


@pytest.mark.slow
def test_steal_disabled_naive_mode_still_correct():
    """Paper §5.4's naive split: correct results, worse balance."""
    prob = dict(n_items=24, n_transactions=60, density=0.15, n_pos=20, seed=0)
    spec = dict(prob, mode="count", min_sup=3, n_devices=8, steal_enabled=False)
    got = run_subproc(spec)
    db, labels, _ = small_problem(seed=0)
    seq, _ = lcm_closed(db, min_sup=3)
    assert int(np.sum(got["hist"])) == len(seq)
    assert sum(got["steals_got"]) == 0


@pytest.mark.slow
def test_pallas_kernel_in_engine():
    """Engine with the Pallas support-count kernel (interpret mode)."""
    prob = dict(n_items=16, n_transactions=40, density=0.2, n_pos=12, seed=2)
    spec = dict(
        prob, mode="count", min_sup=2, n_devices=2, kernel_impl="pallas_interpret"
    )
    got = run_subproc(spec)
    db, labels, _ = small_problem(seed=2, m=16, n=40, density=0.2, n_pos=12)
    seq, _ = lcm_closed(db, min_sup=2)
    want = np.zeros(len(got["hist"]), dtype=np.int64)
    for _, s in seq:
        want[s] += 1
    # full histogram (not just the count): the Pallas popcount-GEMM must be
    # bit-exact against the jnp reference contraction
    np.testing.assert_array_equal(np.array(got["hist"]), want)


@pytest.mark.parametrize("pipeline", ["three_phase", "fused23"])
def test_sync_period_equivalence(pipeline):
    """Lambda-sync staleness costs work, never results (DESIGN.md §6).

    ResultSet (patterns incl. p/q-values), final lambda, min_sup, k, delta,
    and every static-lambda histogram must be bit-identical across
    sync_period settings; the lamp1 traversal may only differ in sub-lambda
    diagnostic bins (a closed set with sup >= the final lambda survives
    every stale pruning decision, so those bins cannot move).
    """
    from repro.api import AlgorithmConfig, Dataset, MinerSession, RuntimeConfig

    db, labels, _ = small_problem(seed=4)
    ds = Dataset.from_dense(db, labels, name="sync-eq")

    def run(sync):
        session = MinerSession(
            algorithm=AlgorithmConfig(alpha=0.05, pipeline=pipeline),
            runtime=RuntimeConfig(expand_batch=8, stack_cap=2048, steal_max=32,
                                  push_cap=128, sync_period=sync),
        )
        return session.mine(ds)

    def patterns(rep):
        return sorted(
            (tuple(p.items), p.support, p.pos_support, p.pvalue, p.qvalue)
            for p in rep.results
        )

    ref = run(1)
    for sync in (4, 16):
        rep = run(sync)
        assert rep.lambda_final == ref.lambda_final
        assert rep.min_sup == ref.min_sup
        assert rep.correction_factor == ref.correction_factor
        assert rep.delta == ref.delta
        assert rep.n_significant == ref.n_significant
        assert patterns(rep) == patterns(ref)
        for pr, pf in zip(rep.phases, ref.phases):
            assert pr.mode == pf.mode
            if pr.mode == "lamp1":
                np.testing.assert_array_equal(
                    pr.output.hist[rep.lambda_final:],
                    pf.output.hist[ref.lambda_final:],
                )
            else:
                np.testing.assert_array_equal(pr.output.hist, pf.output.hist)
                if pr.output.hist2d is not None:
                    np.testing.assert_array_equal(pr.output.hist2d,
                                                  pf.output.hist2d)


def test_fused_phase23_matches_three_phase():
    """Beyond-paper: 2-pass (hist2d) LAMP == the paper's 3-phase pipeline."""
    for seed in [0, 4, 7]:
        db, labels, _ = small_problem(seed=seed)
        a = lamp_distributed(db, labels, alpha=0.05, cfg=CFG)
        b = lamp_distributed(db, labels, alpha=0.05, cfg=CFG, fuse_phase23=True)
        assert b["min_sup"] == a["min_sup"]
        assert b["correction_factor"] == a["correction_factor"]
        assert b["delta"] == a["delta"]
        assert b["n_significant"] == a["n_significant"]
        assert len(b["phase_outputs"]) == 2  # one traversal saved
