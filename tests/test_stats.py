"""repro.stats — the pluggable test-statistic layer.

chi2 is verified against an independent scipy oracle (chi2 distribution
tail vs our normal-tail log-space path); every *registered* statistic is
property-checked against the soundness contract the LAMP staging relies on
(stats/base.py): min_attainable_pvalue really lower-bounds every attainable
P-value, and count_thresholds is monotone non-decreasing on [1, N_pos+1].
Hypothesis drives the property tests when available; a seeded sweep covers
the same properties otherwise.
"""

import numpy as np
import pytest

from repro.stats import (
    STATISTICS,
    chi2_pvalue,
    chi2_pvalue_jnp,
    fisher_pvalue,
    get_statistic,
    register_statistic,
)
from repro.stats.base import TestStatistic

scipy_stats = pytest.importorskip("scipy.stats")


def margins(N, N_pos, x, n):
    """Clamp (x, n) to a valid 2x2 table for the given margins."""
    x = max(0, min(x, N))
    n = max(max(0, x - (N - N_pos)), min(n, x, N_pos))
    return x, n


def oracle_chi2(x, n, N, N_pos):
    """Independent path: Yates T + chi-square-distribution tail (scipy)."""
    a, b = n, x - n
    c, d = N_pos - n, N - N_pos - x + n
    num = abs(a * d - b * c) - N / 2.0
    denom = (a + b) * (c + d) * (a + c) * (b + d)
    t = N * max(num, 0.0) ** 2 / denom if denom > 0 else 0.0
    p_two = scipy_stats.chi2.sf(t, df=1)
    enriched = a * d - b * c > 0
    return p_two / 2.0 if enriched else 1.0 - p_two / 2.0


# ------------------------------------------------------------------ registry
def test_registry_lookup_and_unknown_name():
    assert {"fisher", "chi2"} <= set(STATISTICS)
    assert get_statistic("fisher").name == "fisher"
    assert get_statistic("chi2").name == "chi2"
    with pytest.raises(ValueError, match="unknown test statistic.*fisher"):
        get_statistic("mann-whitney")


def test_register_requires_name():
    class Nameless(TestStatistic):
        name = ""

        def pvalue(self, x, n, N, N_pos):  # pragma: no cover - never called
            raise NotImplementedError

        pvalue_device = min_attainable_pvalue = count_thresholds = pvalue

    with pytest.raises(ValueError, match="non-empty"):
        register_statistic(Nameless())


def test_core_fisher_shim_reexports_same_objects():
    """The legacy import path must stay alive and alias the moved functions."""
    from repro.core import fisher as shim
    from repro.stats import fisher as moved

    for name in ("fisher_pvalue", "min_attainable_pvalue",
                 "lamp_count_thresholds", "fisher_pvalue_jnp",
                 "min_attainable_pvalue_jnp", "log_comb"):
        assert getattr(shim, name) is getattr(moved, name)
    assert get_statistic("fisher").pvalue(10, 8, 60, 20)[0] == \
        fisher_pvalue(10, 8, 60, 20)[0]


# ------------------------------------------------------------- chi2 vs scipy
def test_chi2_matches_scipy_oracle_grid():
    N, N_pos = 60, 20
    for x in range(0, N + 1, 3):
        for n_raw in range(0, N_pos + 1, 2):
            x2, n = margins(N, N_pos, x, n_raw)
            got = chi2_pvalue(x2, n, N, N_pos)[0]
            want = oracle_chi2(x2, n, N, N_pos)
            assert got == pytest.approx(want, rel=1e-10, abs=1e-300), (x2, n)


def test_chi2_matches_scipy_oracle_random_margins():
    rng = np.random.default_rng(7)
    for _ in range(300):
        N = int(rng.integers(2, 2000))
        N_pos = int(rng.integers(1, N))
        x, n = margins(N, N_pos, int(rng.integers(0, N + 1)),
                       int(rng.integers(0, N_pos + 1)))
        got = chi2_pvalue(x, n, N, N_pos)[0]
        want = oracle_chi2(x, n, N, N_pos)
        assert got == pytest.approx(want, rel=1e-9, abs=1e-300), (N, N_pos, x, n)


def test_chi2_log_space_survives_the_deep_tail():
    """At GWAS scales T reaches the thousands; sf() — and even scipy's
    chi2.logsf, which is log(sf) — is 0/-inf there.  Our log-space path
    must agree with the Mills-ratio asymptotic expansion of the normal
    tail:  log sf(z) ~ -z^2/2 - log(z) - log(2*pi)/2 + log1p(-1/z^2 + 3/z^4)."""
    from scipy.special import log_ndtr

    N, N_pos = 12000, 4000
    x = np.array([3000])
    n = np.array([3000])  # all support in positives: extreme enrichment
    num = n * N - x * N_pos
    denom = x * (N - x) * N_pos * (N - N_pos)
    t = N * (np.abs(num) - N / 2.0) ** 2 / denom
    z = np.sqrt(t[0])
    want_log = (-z * z / 2 - np.log(z) - 0.5 * np.log(2 * np.pi)
                + np.log1p(-1 / z**2 + 3 / z**4))
    got_log = log_ndtr(-np.sqrt(t))[0]
    assert got_log == pytest.approx(want_log, rel=1e-9)
    assert want_log < -700  # genuinely beyond float64 sf territory
    assert scipy_stats.chi2.logsf(t[0], df=1) == -np.inf  # why sf is no oracle
    # the clipped host P-value stays a positive subnormal-free float
    p = chi2_pvalue(x, n, N, N_pos)[0]
    assert 0.0 < p <= np.exp(-745.0) * 1.01


def test_chi2_device_matches_host_float32():
    N, N_pos = 300, 100
    rng = np.random.default_rng(3)
    xs, ns = [], []
    for _ in range(64):
        x, n = margins(N, N_pos, int(rng.integers(0, N + 1)),
                       int(rng.integers(0, N_pos + 1)))
        xs.append(x)
        ns.append(n)
    host = chi2_pvalue(np.array(xs), np.array(ns), N, N_pos)
    dev = np.asarray(chi2_pvalue_jnp(np.array(xs), np.array(ns), N, N_pos))
    assert np.allclose(dev, np.clip(host, np.exp(-87.0), 1.0), rtol=2e-4)


def test_chi2_null_and_degenerate_tables():
    N, N_pos = 50, 25
    # observed == expected (and inside the continuity band): p = 0.5
    assert chi2_pvalue(10, 5, N, N_pos)[0] == pytest.approx(0.5)
    # degenerate margins: denominator 0 -> T = 0 -> p = 0.5
    assert chi2_pvalue(0, 0, N, N_pos)[0] == pytest.approx(0.5)
    assert chi2_pvalue(N, N_pos, N, N_pos)[0] == pytest.approx(0.5)
    # enrichment below expectation lands in the upper half
    assert chi2_pvalue(20, 2, N, N_pos)[0] > 0.5


# -------------------------------------------- contract: every registered stat
def check_lower_bound(stat, N, N_pos, x, n):
    x, n = margins(N, N_pos, x, n)
    p = float(stat.pvalue(x, n, N, N_pos)[0])
    f = float(np.asarray(stat.min_attainable_pvalue(np.array([x]), N, N_pos))[0])
    assert f <= p * (1 + 1e-9) + 1e-300, \
        f"{stat.name}: f({x})={f} exceeds p({x},{n})={p} [N={N}, N_pos={N_pos}]"


def check_thresholds_monotone(stat, N, N_pos, alpha):
    thr = np.asarray(stat.count_thresholds(N, N_pos, alpha), dtype=np.float64)
    assert thr.shape == (N + 2,)
    cap = min(N_pos + 1, N + 1)
    window = thr[1: cap + 1]
    assert np.all(np.diff(window) >= -1e-9 * np.abs(window[:-1])), \
        f"{stat.name}: thresholds not monotone on [1, {cap}]"
    assert np.all(np.isinf(thr[cap + 1:]))


@pytest.mark.parametrize("name", sorted(STATISTICS))
def test_statistic_contract_seeded_sweep(name):
    stat = get_statistic(name)
    rng = np.random.default_rng(11)
    for _ in range(60):
        N = int(rng.integers(2, 400))
        N_pos = int(rng.integers(1, N))
        check_lower_bound(stat, N, N_pos, int(rng.integers(0, N + 1)),
                          int(rng.integers(0, N_pos + 1)))
    for N, N_pos in ((10, 3), (60, 20), (97, 13), (300, 150)):
        for alpha in (0.05, 0.01):
            check_thresholds_monotone(stat, N, N_pos, alpha)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # property tests degrade to the seeded sweep above
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @settings(max_examples=80, deadline=None)
    @given(
        name=st.sampled_from(sorted(STATISTICS)),
        N=st.integers(min_value=2, max_value=600),
        data=st.data(),
    )
    def test_min_attainable_is_a_lower_bound(name, N, data):
        N_pos = data.draw(st.integers(min_value=1, max_value=N - 1))
        x = data.draw(st.integers(min_value=0, max_value=N))
        n = data.draw(st.integers(min_value=0, max_value=min(x, N_pos)))
        check_lower_bound(get_statistic(name), N, N_pos, x, n)

    @settings(max_examples=40, deadline=None)
    @given(
        name=st.sampled_from(sorted(STATISTICS)),
        N=st.integers(min_value=2, max_value=600),
        alpha=st.floats(min_value=1e-6, max_value=0.5),
        data=st.data(),
    )
    def test_count_thresholds_monotone_on_tarone_window(name, N, alpha, data):
        N_pos = data.draw(st.integers(min_value=1, max_value=N - 1))
        check_thresholds_monotone(get_statistic(name), N, N_pos, alpha)
