"""Session API: Dataset packing/bucketing, compile-once MinerSession,
typed reports, and the legacy lamp_distributed shim.

The acceptance bar (ISSUE 3): a repeated query on a warm session (same
shape bucket) triggers **zero** recompiles — asserted via cache_info() —
and returns bit-identical ResultSets (incl. exact P-values) to a fresh
`lamp_distributed` run, on 1 in-process device and on 8 simulated devices
(subprocess); the shim still returns the documented dict and warns.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.api import (
    EXACT_BUCKETS,
    BucketPolicy,
    Dataset,
    MinerSession,
    RuntimeConfig,
    ShapeBucket,
)
from repro.core.engine import EngineConfig, MineOutput, lamp_distributed
from repro.data.synthetic import SyntheticSpec, generate
from repro.results import ResultSet

HERE = os.path.dirname(os.path.abspath(__file__))

CFG = EngineConfig(expand_batch=8, stack_cap=2048, steal_max=32, push_cap=128)
RUNTIME = RuntimeConfig.from_engine_config(CFG)


def small_problem(seed=0, n=60, m=24, density=0.15, n_pos=20, planted=2):
    spec = SyntheticSpec(
        name="t", n_items=m, n_transactions=n, density=density, n_pos=n_pos,
        n_planted=planted, seed=seed,
    )
    return generate(spec)


def _keys(rs):
    return [(p.items, p.support, p.pos_support, p.pvalue, p.qvalue) for p in rs]


def _legacy(db, labels, **kw):
    with pytest.warns(DeprecationWarning):
        return lamp_distributed(db, labels, alpha=0.05, cfg=CFG, **kw)


# ------------------------------------------------------------------ Dataset
def test_bucket_policy_rounding():
    pol = BucketPolicy()  # x2 growth from (64, 16, 64)
    assert pol.bucket_for(60, 20, 24) == ShapeBucket(64, 32, 64)
    assert pol.bucket_for(64, 16, 64) == ShapeBucket(64, 16, 64)
    assert pol.bucket_for(65, 17, 65) == ShapeBucket(128, 32, 128)
    assert pol.bucket_for(697, 105, 225) == ShapeBucket(1024, 128, 256)
    assert pol.bucket_for(1, 1, 1) == ShapeBucket(64, 16, 64)
    exact = EXACT_BUCKETS.bucket_for(697, 105, 225)
    assert exact == ShapeBucket(697, 105, 225)


def test_dataset_packs_once_padded_and_immutable():
    db, labels, _ = small_problem()
    ds = Dataset.from_dense(db, labels, name="d0")
    b = ds.bucket
    assert (ds.n_transactions, ds.n_pos, ds.n_items) == (60, 20, 24)
    assert ds.db_bits.shape == (b.items, b.words)
    assert ds.packed.occ0.shape == (b.words,)
    assert not ds.db_bits.flags.writeable
    assert not ds.labels.flags.writeable
    # padded item columns are all-zero bits — they can never gain support
    assert not ds.db_bits[ds.n_items:].any()
    # exact policy pads nothing
    ds_exact = Dataset.from_dense(db, labels, bucket_policy=EXACT_BUCKETS)
    assert ds_exact.db_bits.shape == (24, 2)


def test_dataset_from_transactions_and_tsv(tmp_path):
    txns = [["rs17", "rs3"], ["rs3"], ["rs17", "rs3", "rs99"]]
    labels = np.array([True, False, True])
    ds = Dataset.from_transactions(txns, labels, name="toy")
    assert ds.item_names == ("rs17", "rs3", "rs99")  # sorted vocabulary
    assert ds.n_items == 3 and ds.n_transactions == 3 and ds.n_pos == 2
    dense = np.array([[1, 1, 0], [0, 1, 0], [1, 1, 1]], dtype=bool)
    np.testing.assert_array_equal(
        ds.db_bits[:3], Dataset.from_dense(dense, labels).db_bits[:3]
    )

    path = tmp_path / "toy.tsv"
    path.write_text("1\trs17\trs3\n0\trs3\n1\trs17\trs3\trs99\n")
    ds2 = Dataset.from_tsv(str(path))
    assert ds2.item_names == ds.item_names
    np.testing.assert_array_equal(ds2.db_bits, ds.db_bits)
    np.testing.assert_array_equal(ds2.labels, labels)


# ------------------------------------------------------- RuntimeConfig.resolve
def test_runtime_resolve_moves_launcher_heuristic_into_library():
    rt = RuntimeConfig()
    cfg = rt.resolve(ShapeBucket(1024, 128, 256), n_devices=8)
    # small problems keep the old items-based floor
    assert cfg.stack_cap == 8192
    # the heuristic grows with items per miner exactly as the CLI rule did
    cfg_big = rt.resolve(ShapeBucket(1024, 128, 262144), n_devices=8)
    assert cfg_big.stack_cap == 2 * 262144 // 8 + 64


def test_runtime_resolve_accounts_for_word_width():
    rt = RuntimeConfig(stack_mem_mb=4)
    wide = rt.resolve(ShapeBucket(1 << 20, 128, 65536), n_devices=1)   # W=32768
    thin = rt.resolve(ShapeBucket(64, 16, 65536), n_devices=1)         # W=2
    # same items: the transaction-heavy bucket must get a smaller stack
    assert wide.stack_cap < thin.stack_cap
    node_bytes = 4 * ((1 << 20) // 32 + 4)
    assert wide.stack_cap * node_bytes <= 4 * 2**20 or \
        wide.stack_cap == 2 * (rt.push_cap + rt.steal_max + rt.expand_batch)
    # explicit stack_cap is never overridden
    assert RuntimeConfig(stack_cap=777).resolve(
        ShapeBucket(1 << 20, 128, 65536), 1).stack_cap == 777


def test_runtime_resolve_is_bucket_deterministic():
    """Same-bucket datasets resolve to the same EngineConfig (cache key)."""
    db1, l1, _ = small_problem(seed=0)
    db2, l2, _ = small_problem(seed=9)
    ds1, ds2 = Dataset.from_dense(db1, l1), Dataset.from_dense(db2, l2)
    assert ds1.bucket == ds2.bucket
    rt = RuntimeConfig()
    assert rt.resolve(ds1.bucket, 4) == rt.resolve(ds2.bucket, 4)


def test_kernel_impl_auto_resolves_per_backend(monkeypatch):
    """"auto" picks the Pallas kernel on TPU and the jnp ref elsewhere."""
    import jax

    from repro.core.expand import resolve_kernel_impl

    assert resolve_kernel_impl("auto", backend="tpu") == "pallas"
    assert resolve_kernel_impl("auto", backend="cpu") == "ref"
    assert resolve_kernel_impl("auto", backend="gpu") == "ref"
    # explicit choices always pass through untouched
    assert resolve_kernel_impl("pallas_interpret", backend="tpu") == "pallas_interpret"
    assert resolve_kernel_impl("ref", backend="tpu") == "ref"

    bucket = ShapeBucket(64, 16, 64)
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert RuntimeConfig().resolve(bucket, 1).kernel_impl == "pallas"
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert RuntimeConfig().resolve(bucket, 1).kernel_impl == "ref"
    # the resolved config is the cache key: "auto" never leaks into it
    assert "auto" not in (
        RuntimeConfig().resolve(bucket, 1).kernel_impl,
        RuntimeConfig(kernel_impl="pallas").resolve(bucket, 1).kernel_impl,
    )


def test_sync_period_lands_in_resolved_config_and_cache_key():
    bucket = ShapeBucket(64, 16, 64)
    a = RuntimeConfig(sync_period=1).resolve(bucket, 1)
    b = RuntimeConfig(sync_period=8).resolve(bucket, 1)
    assert a.sync_period == 1 and b.sync_period == 8
    assert a != b  # different cadences must never share a compiled program


# ------------------------------------------------- warm-vs-cold equivalence
def test_warm_query_zero_compiles_and_bit_identical_results():
    db1, l1, _ = small_problem(seed=0)
    db2, l2, _ = small_problem(seed=4)
    session = MinerSession(runtime=RUNTIME)

    rep1 = session.mine(Dataset.from_dense(db1, l1, name="q1"))
    ci1 = session.cache_info()
    assert rep1.cold
    assert ci1.misses == len(rep1.phases) == 3
    assert all(p.compile_s > 0 for p in rep1.phases)

    # second query, same bucket: ZERO new compiles, all phases warm
    rep2 = session.mine(Dataset.from_dense(db2, l2, name="q2"))
    ci2 = session.cache_info()
    assert ci2.misses == ci1.misses
    assert ci2.hits == ci1.hits + len(rep2.phases)
    assert not rep2.cold
    assert all(p.cache_hit and p.compile_s == 0.0 for p in rep2.phases)

    # both queries bit-identical to fresh legacy runs (incl. exact P-values)
    for rep, (db, labels) in ((rep1, (db1, l1)), (rep2, (db2, l2))):
        ref = _legacy(db, labels)
        assert rep.min_sup == ref["min_sup"]
        assert rep.correction_factor == ref["correction_factor"]
        assert rep.delta == ref["delta"]
        assert rep.n_significant == ref["n_significant"]
        assert _keys(rep.results) == _keys(ref["results"])


def test_warm_alpha_change_reuses_programs():
    """alpha enters as runtime data (thresholds/delta), never the cache key."""
    db, labels, _ = small_problem(seed=2)
    session = MinerSession(runtime=RUNTIME)
    ds = Dataset.from_dense(db, labels)
    session.mine(ds)
    before = session.cache_info()
    rep = session.mine(ds, alpha=0.01)
    after = session.cache_info()
    assert after.misses == before.misses
    assert rep.alpha == 0.01
    ref = _legacy(db, labels)  # alpha=0.05 sanity: stricter level, fewer hits
    assert rep.n_significant <= ref["n_significant"]


def test_fused23_session_matches_three_phase():
    db, labels, _ = small_problem(seed=4)
    session = MinerSession(runtime=RUNTIME)
    ds = Dataset.from_dense(db, labels)
    a = session.mine(ds, pipeline="three_phase")
    b = session.mine(ds, pipeline="fused23")
    assert len(b.phases) == 2
    assert (b.min_sup, b.correction_factor, b.delta, b.n_significant) == \
        (a.min_sup, a.correction_factor, a.delta, a.n_significant)
    assert _keys(b.results) == _keys(a.results)
    # fused23 reuses the already-warm lamp1 program: only count2d compiles
    assert session.cache_info().misses == 4


def test_unknown_pipeline_raises():
    db, labels, _ = small_problem()
    session = MinerSession(runtime=RUNTIME)
    with pytest.raises(ValueError, match="unknown pipeline"):
        session.mine(Dataset.from_dense(db, labels), pipeline="nope")


# ----------------------------------------------------------- legacy shim
def test_lamp_distributed_shim_dict_and_deprecation():
    db, labels, _ = small_problem(seed=0)
    res = _legacy(db, labels)
    assert set(res) == {
        "lambda_final", "min_sup", "correction_factor", "delta",
        "n_significant", "results", "phase_outputs",
    }
    assert isinstance(res["results"], ResultSet)
    assert len(res["phase_outputs"]) == 3
    assert all(isinstance(p, MineOutput) for p in res["phase_outputs"])
    fused = _legacy(db, labels, pipeline="fused23")
    assert len(fused["phase_outputs"]) == 2
    assert fused["n_significant"] == res["n_significant"]
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="unknown pipeline"):
            lamp_distributed(db, labels, pipeline="nope")
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="conflicts"):
            lamp_distributed(db, labels, fuse_phase23=True, pipeline="three_phase")


def test_engine_pipelines_reexport():
    from repro.core.engine import PIPELINES

    assert set(PIPELINES) == {"three_phase", "fused23"}


# ------------------------------------------------------------- item names
def test_item_names_flow_to_describe_and_exports(tmp_path):
    db, labels, _ = small_problem(seed=0)
    names = tuple(f"rs{j:04d}" for j in range(db.shape[1]))
    session = MinerSession(runtime=RUNTIME)
    rep = session.mine(Dataset.from_dense(db, labels, item_names=names))
    rs = rep.results
    assert len(rs) > 0
    p0 = rs.patterns[0]

    # human-readable output shows names
    text = rs.describe(3)
    assert names[p0.items[0]] in text

    # TSV keeps the machine-readable index column AND adds a names column
    tsv = rs.to_tsv(str(tmp_path / "p.tsv"))
    header = tsv.splitlines()[0].split("\t")
    assert header[:7] == ["rank", "items", "size", "support", "pos_support",
                          "pvalue", "qvalue"]
    assert header[7] == "names"
    row = dict(zip(header, tsv.splitlines()[1].split("\t")))
    assert tuple(map(int, row["items"].split(","))) == p0.items
    assert row["names"] == ",".join(names[j] for j in p0.items)

    # JSON: indices stay, names added per pattern
    payload = json.loads(rs.to_json())
    assert payload["patterns"][0]["items"] == list(p0.items)
    assert payload["patterns"][0]["names"] == [names[j] for j in p0.items]

    # unnamed datasets keep the legacy formats exactly
    rep2 = MinerSession(runtime=RUNTIME).mine(Dataset.from_dense(db, labels))
    assert "names" not in rep2.results.to_tsv().splitlines()[0].split("\t")
    assert "names" not in json.loads(rep2.results.to_json())["patterns"][0]


# ----------------------------------------------- multi-device warm session
def run_subproc(spec: dict) -> dict:
    from repro.core.collectives import host_device_count_env

    env = host_device_count_env(spec["n_devices"])
    env["PYTHONPATH"] = os.path.join(HERE, "..", "src")
    out = subprocess.run(
        [sys.executable, os.path.join(HERE, "engine_subproc_main.py"),
         json.dumps(spec)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_session_8dev_warm_query_zero_compiles_and_matches_1dev():
    """8 simulated miners: the warm query compiles nothing and both queries
    return byte-identical patterns to a 1-device in-process session."""
    prob = dict(n_items=24, n_transactions=60, density=0.15, n_pos=20,
                seed=1, seed2=5)
    got = run_subproc(dict(prob, mode="session", n_devices=8))
    assert got["misses_per_query"][0] == 3          # cold: one per phase
    assert got["misses_per_query"][1] == 3          # warm: zero new compiles
    assert got["n_programs"] == 3
    assert got["queries"][0]["cold"] and not got["queries"][1]["cold"]

    session = MinerSession(devices=jax.devices()[:1], runtime=RUNTIME)
    for q, seed in zip(got["queries"], (1, 5)):
        db, labels, _ = small_problem(seed=seed)
        rep = session.mine(Dataset.from_dense(db, labels))
        assert q["min_sup"] == rep.min_sup
        assert q["correction_factor"] == rep.correction_factor
        assert q["n_significant"] == rep.n_significant
        want = [[list(p.items), p.support, p.pos_support] for p in rep.results]
        assert [p[:3] for p in q["patterns"]] == want
        for (_, _, _, pv, qv), p in zip(q["patterns"], rep.results):
            assert pv == pytest.approx(p.pvalue, rel=1e-12)
            assert qv == pytest.approx(p.qvalue, rel=1e-12)
