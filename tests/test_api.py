"""Session API: Dataset packing/bucketing, compile-once MinerSession,
first-class Query objects, typed reports, and the legacy shim.

Acceptance bars: a repeated query on a warm session (same shape bucket)
triggers **zero** recompiles — asserted via cache_info() — and returns
bit-identical ResultSets (incl. exact P-values) to a fresh
`lamp_distributed` run, on 1 in-process device and on 8 simulated devices
(subprocess); `session.run(SignificantPatternQuery(statistic="fisher"))`
reproduces the legacy `mine()` path bit-identically on both device counts;
chi2 / closed-frequent / top-k queries match sequential host oracles;
fisher and chi2 occupy distinct test-program cache entries while sharing
lamp1/count; the program cache is LRU-bounded.
"""

import json
import math
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.api import (
    EXACT_BUCKETS,
    BucketPolicy,
    ClosedFrequentQuery,
    Dataset,
    MinerSession,
    RuntimeConfig,
    ShapeBucket,
    SignificantPatternQuery,
    TopKSignificantQuery,
)
from repro.core.engine import EngineConfig, MineOutput, lamp_distributed
from repro.data.synthetic import SyntheticSpec, generate
from repro.results import ResultSet

HERE = os.path.dirname(os.path.abspath(__file__))

CFG = EngineConfig(expand_batch=8, stack_cap=2048, steal_max=32, push_cap=128)
RUNTIME = RuntimeConfig.from_engine_config(CFG)


def small_problem(seed=0, n=60, m=24, density=0.15, n_pos=20, planted=2):
    spec = SyntheticSpec(
        name="t", n_items=m, n_transactions=n, density=density, n_pos=n_pos,
        n_planted=planted, seed=seed,
    )
    return generate(spec)


def _keys(rs):
    return [(p.items, p.support, p.pos_support, p.pvalue, p.qvalue) for p in rs]


def _legacy(db, labels, **kw):
    with pytest.warns(DeprecationWarning):
        return lamp_distributed(db, labels, alpha=0.05, cfg=CFG, **kw)


# ------------------------------------------------------------------ Dataset
def test_bucket_policy_rounding():
    pol = BucketPolicy()  # x2 growth from (64, 16, 64)
    assert pol.bucket_for(60, 20, 24) == ShapeBucket(64, 32, 64)
    assert pol.bucket_for(64, 16, 64) == ShapeBucket(64, 16, 64)
    assert pol.bucket_for(65, 17, 65) == ShapeBucket(128, 32, 128)
    assert pol.bucket_for(697, 105, 225) == ShapeBucket(1024, 128, 256)
    assert pol.bucket_for(1, 1, 1) == ShapeBucket(64, 16, 64)
    exact = EXACT_BUCKETS.bucket_for(697, 105, 225)
    assert exact == ShapeBucket(697, 105, 225)


def test_dataset_packs_once_padded_and_immutable():
    db, labels, _ = small_problem()
    ds = Dataset.from_dense(db, labels, name="d0")
    b = ds.bucket
    assert (ds.n_transactions, ds.n_pos, ds.n_items) == (60, 20, 24)
    assert ds.db_bits.shape == (b.items, b.words)
    assert ds.packed.occ0.shape == (b.words,)
    assert not ds.db_bits.flags.writeable
    assert not ds.labels.flags.writeable
    # padded item columns are all-zero bits — they can never gain support
    assert not ds.db_bits[ds.n_items:].any()
    # exact policy pads nothing
    ds_exact = Dataset.from_dense(db, labels, bucket_policy=EXACT_BUCKETS)
    assert ds_exact.db_bits.shape == (24, 2)


def test_dataset_from_transactions_and_tsv(tmp_path):
    txns = [["rs17", "rs3"], ["rs3"], ["rs17", "rs3", "rs99"]]
    labels = np.array([True, False, True])
    ds = Dataset.from_transactions(txns, labels, name="toy")
    assert ds.item_names == ("rs17", "rs3", "rs99")  # sorted vocabulary
    assert ds.n_items == 3 and ds.n_transactions == 3 and ds.n_pos == 2
    dense = np.array([[1, 1, 0], [0, 1, 0], [1, 1, 1]], dtype=bool)
    np.testing.assert_array_equal(
        ds.db_bits[:3], Dataset.from_dense(dense, labels).db_bits[:3]
    )

    path = tmp_path / "toy.tsv"
    path.write_text("1\trs17\trs3\n0\trs3\n1\trs17\trs3\trs99\n")
    ds2 = Dataset.from_tsv(str(path))
    assert ds2.item_names == ds.item_names
    np.testing.assert_array_equal(ds2.db_bits, ds.db_bits)
    np.testing.assert_array_equal(ds2.labels, labels)


# ------------------------------------------------------- RuntimeConfig.resolve
def test_runtime_resolve_moves_launcher_heuristic_into_library():
    rt = RuntimeConfig()
    cfg = rt.resolve(ShapeBucket(1024, 128, 256), n_devices=8)
    # small problems keep the old items-based floor
    assert cfg.stack_cap == 8192
    # the heuristic grows with items per miner exactly as the CLI rule did
    cfg_big = rt.resolve(ShapeBucket(1024, 128, 262144), n_devices=8)
    assert cfg_big.stack_cap == 2 * 262144 // 8 + 64


def test_runtime_resolve_accounts_for_word_width():
    rt = RuntimeConfig(stack_mem_mb=4)
    wide = rt.resolve(ShapeBucket(1 << 20, 128, 65536), n_devices=1)   # W=32768
    thin = rt.resolve(ShapeBucket(64, 16, 65536), n_devices=1)         # W=2
    # same items: the transaction-heavy bucket must get a smaller stack
    assert wide.stack_cap < thin.stack_cap
    node_bytes = 4 * ((1 << 20) // 32 + 4)
    assert wide.stack_cap * node_bytes <= 4 * 2**20 or \
        wide.stack_cap == 2 * (rt.push_cap + rt.steal_max + rt.expand_batch)
    # explicit stack_cap is never overridden
    assert RuntimeConfig(stack_cap=777).resolve(
        ShapeBucket(1 << 20, 128, 65536), 1).stack_cap == 777


def test_runtime_resolve_is_bucket_deterministic():
    """Same-bucket datasets resolve to the same EngineConfig (cache key)."""
    db1, l1, _ = small_problem(seed=0)
    db2, l2, _ = small_problem(seed=9)
    ds1, ds2 = Dataset.from_dense(db1, l1), Dataset.from_dense(db2, l2)
    assert ds1.bucket == ds2.bucket
    rt = RuntimeConfig()
    assert rt.resolve(ds1.bucket, 4) == rt.resolve(ds2.bucket, 4)


def test_kernel_impl_auto_resolves_per_backend(monkeypatch):
    """"auto" picks the Pallas kernel on TPU, its Triton lowering on GPU,
    and the jnp ref elsewhere."""
    import jax

    from repro.core.expand import resolve_kernel_impl

    assert resolve_kernel_impl("auto", backend="tpu") == "pallas"
    assert resolve_kernel_impl("auto", backend="cpu") == "ref"
    assert resolve_kernel_impl("auto", backend="gpu") == "pallas_gpu"
    # explicit choices always pass through untouched
    assert resolve_kernel_impl("pallas_interpret", backend="tpu") == "pallas_interpret"
    assert resolve_kernel_impl("ref", backend="tpu") == "ref"

    bucket = ShapeBucket(64, 16, 64)
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert RuntimeConfig().resolve(bucket, 1).kernel_impl == "pallas"
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert RuntimeConfig().resolve(bucket, 1).kernel_impl == "ref"
    # the resolved config is the cache key: "auto" never leaks into it
    assert "auto" not in (
        RuntimeConfig().resolve(bucket, 1).kernel_impl,
        RuntimeConfig(kernel_impl="pallas").resolve(bucket, 1).kernel_impl,
    )


def test_sync_period_lands_in_resolved_config_and_cache_key():
    bucket = ShapeBucket(64, 16, 64)
    a = RuntimeConfig(sync_period=1).resolve(bucket, 1)
    b = RuntimeConfig(sync_period=8).resolve(bucket, 1)
    assert a.sync_period == 1 and b.sync_period == 8
    assert a != b  # different cadences must never share a compiled program


# ------------------------------------------------- warm-vs-cold equivalence
def test_warm_query_zero_compiles_and_bit_identical_results():
    db1, l1, _ = small_problem(seed=0)
    db2, l2, _ = small_problem(seed=4)
    session = MinerSession(runtime=RUNTIME)

    rep1 = session.mine(Dataset.from_dense(db1, l1, name="q1"))
    ci1 = session.cache_info()
    assert rep1.cold
    assert ci1.misses == len(rep1.phases) == 3
    assert all(p.compile_s > 0 for p in rep1.phases)

    # second query, same bucket: ZERO new compiles, all phases warm
    rep2 = session.mine(Dataset.from_dense(db2, l2, name="q2"))
    ci2 = session.cache_info()
    assert ci2.misses == ci1.misses
    assert ci2.hits == ci1.hits + len(rep2.phases)
    assert not rep2.cold
    assert all(p.cache_hit and p.compile_s == 0.0 for p in rep2.phases)

    # both queries bit-identical to fresh legacy runs (incl. exact P-values)
    for rep, (db, labels) in ((rep1, (db1, l1)), (rep2, (db2, l2))):
        ref = _legacy(db, labels)
        assert rep.min_sup == ref["min_sup"]
        assert rep.correction_factor == ref["correction_factor"]
        assert rep.delta == ref["delta"]
        assert rep.n_significant == ref["n_significant"]
        assert _keys(rep.results) == _keys(ref["results"])


def test_warm_alpha_change_reuses_programs():
    """alpha enters as runtime data (thresholds/delta), never the cache key."""
    db, labels, _ = small_problem(seed=2)
    session = MinerSession(runtime=RUNTIME)
    ds = Dataset.from_dense(db, labels)
    session.mine(ds)
    before = session.cache_info()
    rep = session.mine(ds, alpha=0.01)
    after = session.cache_info()
    assert after.misses == before.misses
    assert rep.alpha == 0.01
    ref = _legacy(db, labels)  # alpha=0.05 sanity: stricter level, fewer hits
    assert rep.n_significant <= ref["n_significant"]


def test_fused23_session_matches_three_phase():
    db, labels, _ = small_problem(seed=4)
    session = MinerSession(runtime=RUNTIME)
    ds = Dataset.from_dense(db, labels)
    a = session.mine(ds, pipeline="three_phase")
    b = session.mine(ds, pipeline="fused23")
    assert len(b.phases) == 2
    assert (b.min_sup, b.correction_factor, b.delta, b.n_significant) == \
        (a.min_sup, a.correction_factor, a.delta, a.n_significant)
    assert _keys(b.results) == _keys(a.results)
    # fused23 reuses the already-warm lamp1 program: only count2d compiles
    assert session.cache_info().misses == 4


def test_unknown_pipeline_raises():
    db, labels, _ = small_problem()
    session = MinerSession(runtime=RUNTIME)
    with pytest.raises(ValueError, match="unknown pipeline"):
        session.mine(Dataset.from_dense(db, labels), pipeline="nope")


# ----------------------------------------------------------- legacy shim
def test_lamp_distributed_shim_dict_and_deprecation():
    db, labels, _ = small_problem(seed=0)
    res = _legacy(db, labels)
    assert set(res) == {
        "lambda_final", "min_sup", "correction_factor", "delta",
        "n_significant", "results", "phase_outputs",
    }
    assert isinstance(res["results"], ResultSet)
    assert len(res["phase_outputs"]) == 3
    assert all(isinstance(p, MineOutput) for p in res["phase_outputs"])
    fused = _legacy(db, labels, pipeline="fused23")
    assert len(fused["phase_outputs"]) == 2
    assert fused["n_significant"] == res["n_significant"]
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="unknown pipeline"):
            lamp_distributed(db, labels, pipeline="nope")
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="conflicts"):
            lamp_distributed(db, labels, fuse_phase23=True, pipeline="three_phase")


def test_engine_pipelines_reexport():
    from repro.core.engine import PIPELINES

    assert set(PIPELINES) == {"three_phase", "fused23"}


# ------------------------------------------------------------- item names
def test_item_names_flow_to_describe_and_exports(tmp_path):
    db, labels, _ = small_problem(seed=0)
    names = tuple(f"rs{j:04d}" for j in range(db.shape[1]))
    session = MinerSession(runtime=RUNTIME)
    rep = session.mine(Dataset.from_dense(db, labels, item_names=names))
    rs = rep.results
    assert len(rs) > 0
    p0 = rs.patterns[0]

    # human-readable output shows names
    text = rs.describe(3)
    assert names[p0.items[0]] in text

    # TSV keeps the machine-readable index column AND adds a names column
    tsv = rs.to_tsv(str(tmp_path / "p.tsv"))
    header = tsv.splitlines()[0].split("\t")
    assert header[:7] == ["rank", "items", "size", "support", "pos_support",
                          "pvalue", "qvalue"]
    assert header[7] == "names"
    row = dict(zip(header, tsv.splitlines()[1].split("\t")))
    assert tuple(map(int, row["items"].split(","))) == p0.items
    assert row["names"] == ",".join(names[j] for j in p0.items)

    # JSON: indices stay, names added per pattern
    payload = json.loads(rs.to_json())
    assert payload["patterns"][0]["items"] == list(p0.items)
    assert payload["patterns"][0]["names"] == [names[j] for j in p0.items]

    # unnamed datasets keep the legacy formats exactly
    rep2 = MinerSession(runtime=RUNTIME).mine(Dataset.from_dense(db, labels))
    assert "names" not in rep2.results.to_tsv().splitlines()[0].split("\t")
    assert "names" not in json.loads(rep2.results.to_json())["patterns"][0]


# ----------------------------------------------- multi-device warm session
def run_subproc(spec: dict) -> dict:
    from repro.core.collectives import host_device_count_env

    env = host_device_count_env(spec["n_devices"])
    env["PYTHONPATH"] = os.path.join(HERE, "..", "src")
    out = subprocess.run(
        [sys.executable, os.path.join(HERE, "engine_subproc_main.py"),
         json.dumps(spec)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return json.loads(out.stdout.strip().splitlines()[-1])


# ------------------------------------------------- first-class Query objects
def _closed_oracle(db, labels, min_sup):
    """Sequential closed-frequent oracle: [(frozenset, sup, pos_sup)]."""
    from repro.core.bitmap import unpack_occ
    from repro.core.lcm import lcm_closed

    n = db.shape[0]
    out = []

    def on_closed(occ, sup, clo):
        pos = int(np.count_nonzero(unpack_occ(occ, n) & labels)) \
            if labels is not None else 0
        out.append((frozenset(clo.tolist()), sup, pos))

    lcm_closed(db, min_sup=min_sup, on_closed=on_closed)
    return out


def test_run_fisher_query_bit_identical_to_legacy_mine():
    """session.run(SignificantPatternQuery(statistic="fisher")) reproduces
    the legacy mine()/lamp_distributed path bit-for-bit, both pipelines."""
    db, labels, _ = small_problem(seed=3)
    for pipeline in ("three_phase", "fused23"):
        session = MinerSession(runtime=RUNTIME)
        rep = session.run(
            Dataset.from_dense(db, labels),
            SignificantPatternQuery(alpha=0.05, statistic="fisher",
                                    pipeline=pipeline),
        )
        ref = _legacy(db, labels, pipeline=pipeline)
        assert rep.min_sup == ref["min_sup"]
        assert rep.correction_factor == ref["correction_factor"]
        assert rep.delta == ref["delta"]
        assert rep.n_significant == ref["n_significant"]
        assert _keys(rep.results) == _keys(ref["results"])
        assert rep.statistic == "fisher" and rep.query == "significant"


def test_chi2_query_matches_sequential_oracle():
    from repro.core.lamp import lamp

    db, labels, _ = small_problem(seed=1)
    session = MinerSession(runtime=RUNTIME)
    ds = Dataset.from_dense(db, labels)
    for pipeline in ("three_phase", "fused23"):
        rep = session.run(ds, SignificantPatternQuery(
            alpha=0.05, statistic="chi2", pipeline=pipeline))
        ref = lamp(db, labels, alpha=0.05, statistic="chi2")
        assert rep.min_sup == ref.min_sup
        assert rep.correction_factor == ref.correction_factor
        assert rep.delta == ref.delta
        assert rep.n_significant == len(ref.significant)
        got = {(p.items, p.support, p.pos_support) for p in rep.results}
        want = {(tuple(sorted(s.items)), s.support, s.pos_support)
                for s in ref.significant}
        assert got == want
        # exact host P-values match the oracle's
        oracle_p = {tuple(sorted(s.items)): s.pvalue for s in ref.significant}
        for p in rep.results:
            assert p.pvalue == pytest.approx(oracle_p[p.items], rel=1e-12)


def test_fisher_chi2_distinct_programs_lamp1_count_shared():
    """The statistic joins the cache key for the traced modes only: fisher
    and chi2 test programs are distinct entries; lamp1/count are shared, so
    the second statistic compiles exactly one new program — and warm repeat
    queries of either statistic re-trace zero times."""
    db, labels, _ = small_problem(seed=2)
    session = MinerSession(runtime=RUNTIME)
    ds = Dataset.from_dense(db, labels)

    session.mine(ds)                                   # fisher: 3 compiles
    ci1 = session.cache_info()
    assert ci1.misses == 3
    session.run(ds, SignificantPatternQuery(statistic="chi2"))
    ci2 = session.cache_info()
    assert ci2.misses == 4                             # only the chi2 test
    test_entries = {p.statistic for p in ci2.programs if p.mode == "test"}
    assert test_entries == {"fisher", "chi2"}
    shared = {p.statistic for p in ci2.programs if p.mode in ("lamp1", "count")}
    assert shared == {None}

    # warm repeats of BOTH statistics: zero new compiles
    for stat in ("fisher", "chi2"):
        before = session.cache_info().misses
        rep = session.run(ds, SignificantPatternQuery(statistic=stat))
        assert session.cache_info().misses == before
        assert not rep.cold


def test_closed_frequent_query_matches_lcm_oracle():
    db, labels, _ = small_problem(seed=0)
    session = MinerSession(runtime=RUNTIME)
    rep = session.run(Dataset.from_dense(db, labels),
                      ClosedFrequentQuery(min_sup=10))
    oracle = _closed_oracle(db, labels, 10)
    assert rep.n_significant == len(oracle)
    from repro.api import QUERIES

    assert rep.query == "closed-frequent" and rep.statistic is None
    assert rep.query in QUERIES  # the tag round-trips into the registry
    got = {(frozenset(p.items), p.support, p.pos_support) for p in rep.results}
    want = set(oracle)
    assert got == want
    # untested patterns carry NaN P/q, sort by support, export null
    assert all(math.isnan(p.pvalue) and math.isnan(p.qvalue)
               for p in rep.results)
    sups = [p.support for p in rep.results]
    assert sups == sorted(sups, reverse=True)
    payload = json.loads(rep.results.to_json())
    assert payload["statistic"] is None
    assert payload["patterns"][0]["pvalue"] is None
    # TSV exports untested P/q as empty cells, never the string "nan"
    tsv_row = rep.results.to_tsv().splitlines()[1].split("\t")
    assert tsv_row[5] == "" and tsv_row[6] == ""

    # top_k truncates the ResultSet; the count stays exact
    rep_k = session.run(Dataset.from_dense(db, labels),
                        ClosedFrequentQuery(min_sup=10, top_k=3))
    assert len(rep_k.results) == 3
    assert rep_k.n_significant == len(oracle)
    assert [p.support for p in rep_k.results] == sups[:3]


def test_closed_frequent_works_without_labels():
    db, _, _ = small_problem(seed=5)
    session = MinerSession(runtime=RUNTIME)
    rep = session.run(Dataset.from_dense(db, None), ClosedFrequentQuery(min_sup=12))
    oracle = _closed_oracle(db, None, 12)
    assert rep.n_significant == len(oracle)
    assert {frozenset(p.items) for p in rep.results} == \
        {c[0] for c in oracle}


def test_topk_query_matches_oracle_and_probes_stay_warm():
    from repro.stats import get_statistic

    db, labels, _ = small_problem(seed=4)
    n, n_pos = db.shape[0], int(labels.sum())
    session = MinerSession(runtime=RUNTIME)
    ds = Dataset.from_dense(db, labels)
    rep = session.run(ds, TopKSignificantQuery(k=6))
    # every probe reuses ONE compiled test program
    assert session.cache_info().misses == 1
    assert len(rep.phases) >= 1
    assert sum(not p.cache_hit for p in rep.phases) == 1

    oracle = _closed_oracle(db, labels, 1)
    pv = get_statistic("fisher").pvalue(
        np.array([c[1] for c in oracle]), np.array([c[2] for c in oracle]),
        n, n_pos)
    want = np.sort(pv)[:6]
    got = np.array([p.pvalue for p in rep.results])
    assert len(got) == 6
    assert np.all(np.diff(got) >= 0)
    assert np.allclose(got, want, rtol=1e-12)
    assert rep.n_significant == 6 and rep.query == "topk"

    # warm second top-k (different k): still zero new compiles
    rep2 = session.run(ds, TopKSignificantQuery(k=2))
    assert session.cache_info().misses == 1
    assert [p.pvalue for p in rep2.results] == [p.pvalue for p in rep.results][:2]


def test_query_constructors_validate_parameters():
    with pytest.raises(ValueError, match="alpha.*\\(0, 1\\)"):
        SignificantPatternQuery(alpha=1.5)
    with pytest.raises(ValueError, match="alpha"):
        SignificantPatternQuery(alpha=0.0)
    with pytest.raises(ValueError, match="unknown test statistic"):
        SignificantPatternQuery(statistic="nope")
    with pytest.raises(ValueError, match="min_sup must be an int >= 1"):
        ClosedFrequentQuery(min_sup=0)
    with pytest.raises(ValueError, match="top_k"):
        ClosedFrequentQuery(min_sup=5, top_k=0)
    with pytest.raises(ValueError, match="k must be an int >= 1"):
        TopKSignificantQuery(k=0)
    with pytest.raises(ValueError, match="unknown test statistic"):
        TopKSignificantQuery(k=3, statistic="nope")


def test_run_phase_and_run_validate_inputs():
    db, labels, _ = small_problem()
    session = MinerSession(runtime=RUNTIME)
    ds = Dataset.from_dense(db, labels)
    # a bare assert would vanish under python -O; this must stay a ValueError
    with pytest.raises(ValueError, match="unknown engine mode.*lamp1"):
        session.run_phase(ds, "count3d")
    with pytest.raises(ValueError, match="unknown test statistic"):
        session.run_phase(ds, "test", statistic="nope")
    with pytest.raises(TypeError, match="repro.api.Query"):
        session.run(ds, "significant")
    with pytest.raises(ValueError, match="unknown pipeline"):
        session.run(ds, SignificantPatternQuery(pipeline="nope"))
    # testing objectives refuse unlabelled datasets with an actionable error
    ds_unlabelled = Dataset.from_dense(db, None)
    with pytest.raises(ValueError, match="labels"):
        session.run(ds_unlabelled, SignificantPatternQuery())
    with pytest.raises(ValueError, match="labels"):
        session.run(ds_unlabelled, TopKSignificantQuery(k=3))
    # statistic=None means "no test" elsewhere; mine() must not read it as
    # "session default" silently
    with pytest.raises(ValueError, match="ClosedFrequentQuery"):
        session.mine(ds, statistic=None)


def test_engine_mine_rejects_unknown_mode():
    from repro.core.engine import mine

    db, labels, _ = small_problem()
    with pytest.raises(ValueError, match="unknown engine mode"):
        mine(db, labels, mode="bogus")


# ------------------------------------------------------- bounded program cache
def test_program_cache_lru_eviction_and_clear():
    db, labels, _ = small_problem(seed=0)
    session = MinerSession(runtime=RUNTIME.with_options(max_programs=2))
    ds = Dataset.from_dense(db, labels)

    session.run_phase(ds, "lamp1")
    session.run_phase(ds, "count", min_sup=5)
    ci = session.cache_info()
    assert (ci.n_programs, ci.evictions) == (2, 0)

    # third program evicts the least recently used (lamp1)
    session.run_phase(ds, "test", min_sup=5, delta=1e-4)
    ci = session.cache_info()
    assert (ci.n_programs, ci.evictions) == (2, 1)
    assert {p.mode for p in ci.programs} == {"count", "test"}
    assert "evicted" in str(ci)

    # a hit refreshes recency: count survives the next insertion
    session.run_phase(ds, "count", min_sup=5)
    session.run_phase(ds, "lamp1")
    ci = session.cache_info()
    assert {p.mode for p in ci.programs} == {"count", "lamp1"}
    assert ci.evictions == 2

    # evicted programs recompile on return (a new miss)
    misses = ci.misses
    session.run_phase(ds, "test", min_sup=5, delta=1e-4)
    assert session.cache_info().misses == misses + 1

    # clear_cache drops everything but keeps the counters
    n = session.clear_cache()
    ci2 = session.cache_info()
    assert n == 2 and ci2.n_programs == 0
    assert ci2.misses == misses + 1 and ci2.evictions == 3

    with pytest.raises(ValueError, match="max_programs"):
        MinerSession(runtime=RUNTIME.with_options(max_programs=0))


@pytest.mark.slow
def test_run_vs_legacy_8dev_bit_identical():
    """8 simulated miners: session.run(SignificantPatternQuery) reproduces
    the legacy lamp_distributed dict bit-identically (incl. P-values)."""
    prob = dict(n_items=24, n_transactions=60, density=0.15, n_pos=20, seed=1)
    for pipeline in ("three_phase", "fused23"):
        got = run_subproc(dict(prob, mode="run_vs_legacy", n_devices=8,
                               pipeline=pipeline))
        assert got["run"] == got["legacy"], pipeline


@pytest.mark.slow
def test_session_8dev_warm_query_zero_compiles_and_matches_1dev():
    """8 simulated miners: the warm query compiles nothing and both queries
    return byte-identical patterns to a 1-device in-process session."""
    prob = dict(n_items=24, n_transactions=60, density=0.15, n_pos=20,
                seed=1, seed2=5)
    got = run_subproc(dict(prob, mode="session", n_devices=8))
    assert got["misses_per_query"][0] == 3          # cold: one per phase
    assert got["misses_per_query"][1] == 3          # warm: zero new compiles
    assert got["n_programs"] == 3
    assert got["queries"][0]["cold"] and not got["queries"][1]["cold"]

    session = MinerSession(devices=jax.devices()[:1], runtime=RUNTIME)
    for q, seed in zip(got["queries"], (1, 5)):
        db, labels, _ = small_problem(seed=seed)
        rep = session.mine(Dataset.from_dense(db, labels))
        assert q["min_sup"] == rep.min_sup
        assert q["correction_factor"] == rep.correction_factor
        assert q["n_significant"] == rep.n_significant
        want = [[list(p.items), p.support, p.pos_support] for p in rep.results]
        assert [p[:3] for p in q["patterns"]] == want
        for (_, _, _, pv, qv), p in zip(q["patterns"], rep.results):
            assert pv == pytest.approx(p.pvalue, rel=1e-12)
            assert qv == pytest.approx(p.qvalue, rel=1e-12)
