"""LAMP support-increase procedure vs exhaustive lambda search + FWER sanity."""

import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")  # dev dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core.fisher import fisher_pvalue, lamp_count_thresholds, min_attainable_pvalue
from repro.core.lamp import Phase1State, lamp, lamp_phase1
from repro.core.lcm import brute_force_closed
from repro.data.synthetic import SyntheticSpec, generate


@st.composite
def labelled_dbs(draw):
    n = draw(st.integers(10, 48))
    m = draw(st.integers(3, 9))
    density = draw(st.floats(0.1, 0.7))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    db = rng.random((n, m)) < density
    n_pos = draw(st.integers(2, n - 2))
    labels = np.zeros(n, dtype=bool)
    labels[rng.choice(n, size=n_pos, replace=False)] = True
    return db, labels


def exhaustive_min_sup(db, n_pos, alpha):
    """Reference: largest lambda with CS(lambda) * f(lambda-1) > alpha  (Eq 3.1)."""
    n = db.shape[0]
    closed = brute_force_closed(db, min_sup=1)
    sups = np.array(sorted(s for s in closed.values()))
    thr = lamp_count_thresholds(n, n_pos, alpha)
    best = 1
    for lam in range(1, min(n_pos + 1, n) + 1):
        cs = int((sups >= lam).sum())
        if cs > thr[lam]:
            best = lam
    return best, int((sups >= best).sum())


@given(data=labelled_dbs(), alpha=st.sampled_from([0.01, 0.05, 0.2]))
@settings(max_examples=40, deadline=None)
def test_support_increase_matches_exhaustive(data, alpha):
    db, labels = data
    n_pos = int(labels.sum())
    lam_final, min_sup, _ = lamp_phase1(db, n_pos, alpha)
    ref_min_sup, _ = exhaustive_min_sup(db, n_pos, alpha)
    assert min_sup == ref_min_sup
    assert lam_final == ref_min_sup + 1 or (lam_final == 1 and ref_min_sup == 1)


@given(data=labelled_dbs())
@settings(max_examples=20, deadline=None)
def test_lamp_correction_counts_match_oracle(data):
    db, labels = data
    res = lamp(db, labels, alpha=0.05)
    oracle = brute_force_closed(db, min_sup=res.min_sup)
    assert res.correction_factor == len(oracle)
    # every reported significant pattern is a closed set with p <= delta
    n, n_pos = res.n_transactions, res.n_pos
    for sig in res.significant:
        assert sig.items in oracle
        p = fisher_pvalue(sig.support, sig.pos_support, n, n_pos)[0]
        assert p == pytest.approx(sig.pvalue, rel=1e-9)
        assert p <= res.delta
    # and no closed set with p <= delta was missed
    from repro.core.bitmap import pack_db, full_occ, support_np, unpack_occ

    bits = pack_db(db)
    found = {s.items for s in res.significant}
    for items, sup in oracle.items():
        occ = full_occ(n)
        for j in items:
            occ = occ & bits[j]
        psup = int(np.count_nonzero(unpack_occ(occ, n) & labels))
        p = fisher_pvalue(sup, psup, n, n_pos)[0]
        if p <= res.delta:
            assert items in found


def test_planted_patterns_are_found():
    spec = SyntheticSpec(
        name="t", n_items=40, n_transactions=120, density=0.08, n_pos=40,
        n_planted=2, planted_pos_rate=0.8, planted_neg_rate=0.02, seed=7,
    )
    db, labels, planted = generate(spec)
    res = lamp(db, labels, alpha=0.05)
    assert res.significant, "planted signal must be detected"
    sig_sets = [set(s.items) for s in res.significant]
    hits = sum(any(set(p) <= s for s in sig_sets) for p in planted)
    assert hits >= 1


def test_fwer_control_on_null_data():
    """On label-permuted (null) data, findings should be rare (FWER <= alpha-ish)."""
    rng = np.random.default_rng(3)
    false_hits = 0
    trials = 30
    for t in range(trials):
        db = rng.random((40, 7)) < 0.3
        labels = np.zeros(40, dtype=bool)
        labels[rng.choice(40, size=15, replace=False)] = True
        res = lamp(db, labels, alpha=0.05)
        false_hits += bool(res.significant)
    # binomial(30, 0.05): P(>=6) ~ 0.0003 — generous bound, catches gross errors
    assert false_hits <= 5
