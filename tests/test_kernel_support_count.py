"""Support-count dispatch point vs oracles: parity across impls, tilings,
ragged shapes (DESIGN.md §8).

Everything here is *exact* integer math (popcount sums), so every kernel
variant, block size, and item tiling must be bit-identical — any mismatch
is a real bug, never a tolerance question.

Property tests run under hypothesis when the dev dep is installed
(requirements-dev.txt); without it the same properties run over a
deterministic pseudo-random shape sample, so the parity suite never
silently skips.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # property tests fall back to deterministic sweeps
    HAVE_HYPOTHESIS = False

from repro.core.bitmap import BitmapLayout, item_tiling, pack_db, supports_np
from repro.kernels.support_count import autotune
from repro.kernels.support_count.ops import (
    VALID_IMPLS,
    resolve_impl,
    support_counts,
    support_counts_tiled,
)

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed (dev dep)"
)


def rand_words(rng, shape):
    return rng.integers(0, 2**32, size=shape, dtype=np.uint32)


def _sample_shapes(n, dims, seed):
    """Deterministic pseudo-random shape tuples within per-dim (lo, hi)."""
    rng = np.random.default_rng(seed)
    return [
        tuple(int(rng.integers(lo, hi + 1)) for lo, hi in dims)
        for _ in range(n)
    ]


# ------------------------------------------------------------------ dispatch
def test_resolve_impl():
    assert resolve_impl("auto", backend="tpu") == "pallas"
    assert resolve_impl("auto", backend="gpu") == "pallas_gpu"
    assert resolve_impl("auto", backend="cpu") == "ref"
    for impl in VALID_IMPLS:
        assert resolve_impl(impl, backend="tpu") == impl
    with pytest.raises(ValueError, match="unknown kernel impl"):
        resolve_impl("cuda")


# ------------------------------------------------------------- shape parity
@pytest.mark.parametrize("b", [1, 3, 8, 17])
@pytest.mark.parametrize("m", [1, 5, 512, 700])
@pytest.mark.parametrize("w", [1, 7, 32, 40])
def test_shape_sweep(b, m, w):
    """Interpreted Pallas kernel == numpy oracle at ragged shapes (every dim
    both below and astride its block/floor sizes)."""
    rng = np.random.default_rng(b * 1000 + m * 10 + w)
    occ = rand_words(rng, (b, w))
    db = rand_words(rng, (m, w))
    got = np.asarray(support_counts(occ, db, impl="pallas_interpret"))
    want = supports_np(occ, db)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("blocks", [(8, 128, 8), (8, 512, 32), (16, 256, 16)])
def test_block_shape_sweep(blocks):
    """Explicit block triples (overriding the autotuner) stay bit-exact."""
    rng = np.random.default_rng(0)
    occ = rand_words(rng, (24, 50))
    db = rand_words(rng, (300, 50))
    got = np.asarray(
        support_counts(occ, db, impl="pallas_interpret", blocks=blocks)
    )
    np.testing.assert_array_equal(got, supports_np(occ, db))


def _check_packed_real_db(n, m, b, seed):
    """End-to-end: packed boolean DB + real occurrence bitmaps (all-zero
    tail bits in the last packed word exercise the padding invariance)."""
    rng = np.random.default_rng(seed)
    db = rng.random((n, m)) < 0.4
    bits = pack_db(db)  # [M, W]
    occ_rows = bits[rng.integers(0, m, size=b)]  # item columns as occurrences
    got = np.asarray(support_counts(occ_rows, bits, impl="pallas_interpret"))
    want = supports_np(occ_rows, bits)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize(
    "n,m,b,seed", _sample_shapes(8, [(1, 130), (1, 40), (1, 9), (0, 2**31 - 1)], 1)
)
def test_vs_packed_real_db(n, m, b, seed):
    _check_packed_real_db(n, m, b, seed)


if HAVE_HYPOTHESIS:

    @needs_hypothesis
    @given(
        n=st.integers(1, 130),
        m=st.integers(1, 40),
        b=st.integers(1, 9),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=25, deadline=None)
    def test_vs_packed_real_db_hyp(n, m, b, seed):
        _check_packed_real_db(n, m, b, seed)


def test_ref_impl_path():
    rng = np.random.default_rng(5)
    occ = rand_words(rng, (4, 10))
    db = rand_words(rng, (33, 10))
    got = np.asarray(support_counts(occ, db, impl="ref"))
    np.testing.assert_array_equal(got, supports_np(occ, db))


# ----------------------------------------------------------- tiling parity
def _check_tiled_vs_untiled(b, m, w, m_tile, seed, impl):
    """Tiled sweep == untiled contraction for arbitrary (m, m_tile): m below
    one tile, m a multiple, and m astride a tile boundary all occur."""
    rng = np.random.default_rng(seed)
    occ = rand_words(rng, (b, w))
    db = rand_words(rng, (m, w))
    want = supports_np(occ, db)
    got = np.asarray(support_counts(occ, db, impl=impl, m_tile=m_tile))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize(
    "b,m,w,seed", _sample_shapes(10, [(1, 10), (1, 300), (1, 12), (0, 10**6)], 2)
)
@pytest.mark.parametrize("m_tile", [1, 64, 100, 128])
def test_tiled_vs_untiled_ref(b, m, w, m_tile, seed):
    _check_tiled_vs_untiled(b, m, w, m_tile, seed, "ref")


@pytest.mark.parametrize(
    "b,m,w,seed", _sample_shapes(5, [(1, 6), (1, 200), (1, 10), (0, 10**6)], 3)
)
def test_tiled_interpret_vs_ref(b, m, w, seed):
    """pallas_interpret through the tiled path == ref, ragged shapes."""
    _check_tiled_vs_untiled(b, m, w, 64, seed, "pallas_interpret")


if HAVE_HYPOTHESIS:

    @needs_hypothesis
    @given(
        b=st.integers(1, 10),
        m=st.integers(1, 300),
        w=st.integers(1, 12),
        m_tile=st.sampled_from([1, 8, 64, 100, 128]),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=40, deadline=None)
    def test_tiled_vs_untiled_ref_hyp(b, m, w, m_tile, seed):
        _check_tiled_vs_untiled(b, m, w, m_tile, seed, "ref")

    @needs_hypothesis
    @given(
        b=st.integers(1, 6),
        m=st.integers(1, 200),
        w=st.integers(1, 10),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=15, deadline=None)
    def test_tiled_interpret_vs_ref_hyp(b, m, w, seed):
        _check_tiled_vs_untiled(b, m, w, 64, seed, "pallas_interpret")


def test_tiled_entry_direct():
    """support_counts_tiled (the engine's traced entry) over a BitmapLayout:
    padded tail items report zero support."""
    rng = np.random.default_rng(7)
    m, w = 150, 4
    db = rand_words(rng, (m, w))
    layout = BitmapLayout.from_db_bits(db, m_tile=64)  # m_pad = 192
    occ = rand_words(rng, (5, w))
    got = np.asarray(support_counts_tiled(occ, layout.tiles, impl="ref"))
    assert got.shape == (5, layout.m_pad)
    np.testing.assert_array_equal(got[:, :m], supports_np(occ, db))
    assert (got[:, m:] == 0).all()


def test_all_zero_tail_words():
    """Columns whose trailing words are all zero (transactions << capacity)
    count exactly; the kernel's w-padding adds nothing."""
    rng = np.random.default_rng(11)
    occ = rand_words(rng, (6, 9))
    db = rand_words(rng, (70, 9))
    occ[:, 5:] = 0
    db[:, 5:] = 0
    for impl in ("ref", "pallas_interpret"):
        got = np.asarray(support_counts(occ, db, impl=impl))
        np.testing.assert_array_equal(got, supports_np(occ, db))


# ---------------------------------------------------------------- autotune
def test_choose_blocks_divides_bucket():
    for b, m, w in [(16, 4096, 12), (697, 11914, 22), (3, 5, 1), (64, 250112, 12)]:
        bp, mp, wp = autotune.bucket_dims(b, m, w)
        for impl in ("pallas", "pallas_interpret", "pallas_gpu"):
            bb, bm, bw = autotune.choose_blocks(b, m, w, impl)
            assert bp % bb == 0 and mp % bm == 0 and wp % bw == 0
            assert autotune.vmem_bytes(bb, bm, bw) <= autotune.VMEM_BUDGET
    assert autotune.choose_blocks(16, 4096, 12, "ref") == (0, 0, 0)


def test_choose_blocks_is_bucket_stable():
    """Every shape in one power-of-two bucket gets the same blocks — the
    program cache key never varies within a bucket."""
    picks = {
        autotune.choose_blocks(b, m, w)
        for b in (9, 12, 16) for m in (1100, 2048) for w in (5, 8)
    }
    assert len(picks) == 1


def test_seed_table_wins(tmp_path):
    b, m, w = 16, 1024, 8
    bucket = list(autotune.bucket_dims(b, m, w))
    path = tmp_path / "seed.json"
    autotune.save_seed_table(
        str(path),
        [{"impl": "pallas", "bucket": bucket, "blocks": [8, 128, 8],
          "time_us": 1.0}],
    )
    try:
        autotune.load_seed_table(str(path))
        assert autotune.choose_blocks(b, m, w, "pallas") == (8, 128, 8)
    finally:
        autotune.clear_seed_table()
    # cleared: back to the analytic pick (whatever it is, divides the bucket)
    bb, bm, bw = autotune.choose_blocks(b, m, w, "pallas")
    assert (bb, bm, bw) != (0, 0, 0)


def test_stable_jit_across_ragged_shapes():
    """The eager wrapper pads to pow2 buckets before its inner jit: every
    shape in one bucket reuses one traced program (the old wrapper re-jitted
    per distinct (b, m, w) and re-specialized block_b per odd batch)."""
    from repro.kernels.support_count.ops import _support_counts_padded

    rng = np.random.default_rng(3)
    base = _support_counts_padded._cache_size()
    for b, m, w in [(9, 1100, 5), (12, 2048, 8), (16, 1500, 7)]:
        occ = rand_words(rng, (b, w))
        db = rand_words(rng, (m, w))
        got = np.asarray(support_counts(occ, db, impl="ref"))
        np.testing.assert_array_equal(got, supports_np(occ, db))
    assert _support_counts_padded._cache_size() - base <= 1


def test_item_tiling():
    assert item_tiling(100) == (100, 100)          # single tile, zero pad
    assert item_tiling(4096) == (4096, 4096)
    assert item_tiling(4097) == (8192, 4096)
    assert item_tiling(250_120) == (253_952, 4096)  # 62 tiles
    assert item_tiling(10, 4) == (12, 4)
