"""Support-count Pallas kernel vs jnp oracle (interpret mode), shape sweeps."""

import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")  # dev dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core.bitmap import pack_db, supports_np
from repro.kernels.support_count.ops import support_counts
from repro.kernels.support_count.ref import support_count_ref


def rand_words(rng, shape):
    return rng.integers(0, 2**32, size=shape, dtype=np.uint32)


@pytest.mark.parametrize("b", [1, 3, 8, 17])
@pytest.mark.parametrize("m", [1, 5, 512, 700])
@pytest.mark.parametrize("w", [1, 7, 32, 40])
def test_shape_sweep(b, m, w):
    rng = np.random.default_rng(b * 1000 + m * 10 + w)
    occ = rand_words(rng, (b, w))
    db_t = rand_words(rng, (w, m))
    got = np.asarray(support_counts(occ, db_t, interpret=True))
    want = np.asarray(support_count_ref(occ, db_t))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("block_b,block_m,block_w", [(8, 128, 8), (8, 512, 32), (16, 256, 16)])
def test_block_shape_sweep(block_b, block_m, block_w):
    rng = np.random.default_rng(0)
    occ = rand_words(rng, (24, 50))
    db_t = rand_words(rng, (50, 300))
    got = np.asarray(
        support_counts(occ, db_t, block_b=block_b, block_m=block_m, block_w=block_w,
                       interpret=True)
    )
    want = np.asarray(support_count_ref(occ, db_t))
    np.testing.assert_array_equal(got, want)


@given(
    n=st.integers(1, 130),
    m=st.integers(1, 40),
    b=st.integers(1, 9),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_vs_packed_real_db(n, m, b, seed):
    """End-to-end: packed boolean DB + real occurrence bitmaps."""
    rng = np.random.default_rng(seed)
    db = rng.random((n, m)) < 0.4
    bits = pack_db(db)  # [M, W]
    occ_rows = bits[rng.integers(0, m, size=b)]  # item columns as occurrences
    got = np.asarray(support_counts(occ_rows, np.ascontiguousarray(bits.T), interpret=True))
    want = supports_np(occ_rows, bits)
    np.testing.assert_array_equal(got, want)


def test_ref_impl_path():
    rng = np.random.default_rng(5)
    occ = rand_words(rng, (4, 10))
    db_t = rand_words(rng, (10, 33))
    got = np.asarray(support_counts(occ, db_t, impl="ref"))
    want = np.asarray(support_count_ref(occ, db_t))
    np.testing.assert_array_equal(got, want)
