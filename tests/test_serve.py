"""repro.serve: scheduler/fleet/batch semantics on a fake instant session,
plus real-engine streamed-head and served-vs-direct parity gates.

The fake-session tests pin the serving-layer contracts without paying
engine time: admission control rejects at capacity with a typed reason,
deadline expiry terminates queued work before it touches a session,
same-signature batching preserves per-client FIFO order, cancellation
only reaches queued requests.  The real-engine tests close the loop: a
ResultStream's head equals the final ResultSet head, and a concurrency-4
fleet returns results bit-identical (p-values included) to a direct
session.
"""

import asyncio
import threading

import pytest

from repro.api import Dataset, MinerSession, RuntimeConfig
from repro.api.dataset import ShapeBucket
from repro.api.query import SignificantPatternQuery
from repro.data.synthetic import SyntheticSpec, generate
from repro.obs import MetricsRegistry
from repro.results import ResultStream
from repro.serve import (
    AdmissionError,
    MiningService,
    Scheduler,
    ServeConfig,
    SessionFleet,
    WarmupSpec,
    collect_batch,
    program_signature,
)

CFG = RuntimeConfig(expand_batch=8)


def small_dataset(seed=0, n=60, m=24):
    spec = SyntheticSpec(name=f"t{seed}", n_items=m, n_transactions=n,
                         density=0.15, n_pos=20, n_planted=2, seed=seed)
    db, labels, _ = generate(spec)
    return Dataset.from_dense(db, labels, name=f"t{seed}")


def _keys(rs):
    return [(p.items, p.support, p.pos_support, p.pvalue, p.qvalue)
            for p in rs]


# --------------------------------------------------------------- fakes
class FakeBits:
    nbytes = 64


class FakePacked:
    db_bits = FakeBits()


class FakeDataset:
    """Just enough surface for the serving layer: a bucket and a name."""

    def __init__(self, bucket, name="fake"):
        self.bucket = bucket
        self.name = name
        self.packed = FakePacked()


class FakeReport:
    cold = False
    query = "significant"


class FakeSession:
    """Instant MinerSession stand-in recording execution order.

    `gate` (a threading.Event), when given, blocks every run until set —
    the tests use it to hold a worker busy so the queue fills
    deterministically.
    """

    def __init__(self, gate=None):
        self.gate = gate
        self.ran = []          # request names in execution order
        self._lock = threading.Lock()
        self.metrics = MetricsRegistry()
        self.n_devices = 1
        self.started = threading.Event()

    def run(self, dataset, query, *, stream=None, **kw):
        self.started.set()
        if self.gate is not None:
            assert self.gate.wait(10.0), "test gate never opened"
        with self._lock:
            self.ran.append(dataset.name)
        return FakeReport()

    def has_programs(self, bucket, statistic=None, *, pipeline=None):
        return True

    def warmup(self, target, *, statistic=None, pipeline=None, alpha=None):
        return 0


def fake_service(gate=None, *, capacity=4, max_batch=8, size=1, **cfg):
    sessions = [FakeSession(gate) for _ in range(size)]
    fleet = SessionFleet(sessions)
    sched = Scheduler(fleet, ServeConfig(queue_capacity=capacity,
                                         max_batch=max_batch, **cfg))
    return sched, sessions


BUCKET_A = ShapeBucket(transactions=64, positives=32, items=32)
BUCKET_B = ShapeBucket(transactions=128, positives=32, items=32)
Q = SignificantPatternQuery(alpha=0.05)


async def _drain_until(predicate, timeout=5.0):
    loop = asyncio.get_running_loop()
    t0 = loop.time()
    while not predicate():
        if loop.time() - t0 > timeout:
            raise AssertionError("condition never reached")
        await asyncio.sleep(0.005)


# ---------------------------------------------------------- admission
def test_admission_rejects_at_capacity():
    async def main():
        gate = threading.Event()
        sched, (fake,) = fake_service(gate, capacity=2)
        await sched.start()
        first = sched.submit(FakeDataset(BUCKET_A, "r0"), Q)
        # wait until the worker picked it up (queue empty again)
        await _drain_until(lambda: fake.started.is_set() and sched.depth == 0)
        queued = [sched.submit(FakeDataset(BUCKET_A, f"r{i}"), Q)
                  for i in (1, 2)]
        assert sched.depth == 2 and sched.backpressure == 1.0
        with pytest.raises(AdmissionError) as ei:
            sched.submit(FakeDataset(BUCKET_A, "r3"), Q)
        assert ei.value.reason == "queue_full"
        gate.set()
        results = await asyncio.gather(first.future,
                                       *[r.future for r in queued])
        assert [r.outcome for r in results] == ["ok"] * 3
        await sched.stop()
        # stopped scheduler refuses with its own reason
        with pytest.raises(AdmissionError) as ei:
            sched.submit(FakeDataset(BUCKET_A, "r4"), Q)
        assert ei.value.reason == "shutting_down"
        assert fake.ran == ["r0", "r1", "r2"]

    asyncio.run(main())


def test_deadline_expires_queued_request():
    async def main():
        gate = threading.Event()
        sched, (fake,) = fake_service(gate)
        await sched.start()
        blocker = sched.submit(FakeDataset(BUCKET_A, "blocker"), Q)
        await _drain_until(lambda: fake.started.is_set() and sched.depth == 0)
        doomed = sched.submit(FakeDataset(BUCKET_A, "doomed"), Q,
                              timeout_s=0.05)
        result = await doomed.future      # resolves while the worker is held
        assert result.outcome == "timeout"
        assert result.queued_s >= 0.05 and result.service_s == 0.0
        gate.set()
        assert (await blocker.future).outcome == "ok"
        await sched.stop()
        assert fake.ran == ["blocker"]    # the expired request never ran

    asyncio.run(main())


def test_cancel_hits_queued_not_running():
    async def main():
        gate = threading.Event()
        sched, (fake,) = fake_service(gate)
        await sched.start()
        running = sched.submit(FakeDataset(BUCKET_A, "running"), Q)
        await _drain_until(lambda: fake.started.is_set() and sched.depth == 0)
        queued = sched.submit(FakeDataset(BUCKET_A, "queued"), Q)
        assert sched.cancel(queued) is True
        assert (await queued.future).outcome == "cancelled"
        assert sched.cancel(running) is False   # already started
        gate.set()
        assert (await running.future).outcome == "ok"
        await sched.stop()
        assert fake.ran == ["running"]

    asyncio.run(main())


# ----------------------------------------------------------- batching
def test_program_signature_groups_by_bucket_and_statistic():
    ds_a, ds_b = FakeDataset(BUCKET_A), FakeDataset(BUCKET_B)
    assert program_signature(ds_a, Q) == program_signature(ds_a, Q)
    assert program_signature(ds_a, Q) != program_signature(ds_b, Q)
    chi = SignificantPatternQuery(alpha=0.05, statistic="chi2")
    assert program_signature(ds_a, Q) != program_signature(ds_a, chi)


def test_collect_batch_preserves_fifo_and_queue_order():
    from collections import deque

    class R:  # minimal stand-in: collect_batch only reads .signature
        def __init__(self, sig, tag):
            self.signature, self.tag = sig, tag

    q = deque([R("a", 1), R("b", 1), R("a", 2), R("a", 3), R("b", 2)])
    batch = collect_batch(q, max_batch=8)
    assert [(r.signature, r.tag) for r in batch] == [("a", 1), ("a", 2),
                                                     ("a", 3)]
    # the other-signature requests keep their relative order
    assert [(r.signature, r.tag) for r in q] == [("b", 1), ("b", 2)]
    assert [r.tag for r in collect_batch(q, max_batch=1)] == [1]


def test_same_bucket_batching_fifo_end_to_end():
    async def main():
        gate = threading.Event()
        sched, (fake,) = fake_service(gate, capacity=16)
        await sched.start()
        blocker = sched.submit(FakeDataset(BUCKET_A, "warm"), Q)
        await _drain_until(lambda: fake.started.is_set() and sched.depth == 0)
        # interleaved submit order: a0 b0 a1 a2 b1 — same-bucket requests
        # coalesce, per-client FIFO survives
        subs = {}
        for name, bucket in [("a0", BUCKET_A), ("b0", BUCKET_B),
                             ("a1", BUCKET_A), ("a2", BUCKET_A),
                             ("b1", BUCKET_B)]:
            subs[name] = sched.submit(FakeDataset(bucket, name), Q)
        gate.set()
        results = {n: await s.future for n, s in subs.items()}
        await sched.stop()
        assert fake.ran[0] == "warm"
        order = fake.ran[1:]
        assert order.index("a0") < order.index("a1") < order.index("a2")
        assert order.index("b0") < order.index("b1")
        # the A-group rode one coalesced batch, in submit order
        assert [results[n].batch_size for n in ("a0", "a1", "a2")] == [3, 3, 3]
        assert [results[n].batch_index for n in ("a0", "a1", "a2")] == [0, 1, 2]
        assert [results[n].batch_size for n in ("b0", "b1")] == [2, 2]

    asyncio.run(main())


def test_fleet_spreads_one_signature_over_idle_workers():
    async def main():
        sched, fakes = fake_service(None, capacity=16, size=2)
        await sched.start()
        subs = [sched.submit(FakeDataset(BUCKET_A, f"r{i}"), Q)
                for i in range(8)]
        results = await asyncio.gather(*[s.future for s in subs])
        await sched.stop()
        assert {r.outcome for r in results} == {"ok"}
        # fairness: a deep same-signature queue must not pin to one session
        assert all(fake.ran for fake in fakes)

    asyncio.run(main())


# ------------------------------------------------------- real engine
def test_streamed_head_equals_final_head():
    session = MinerSession(runtime=CFG)
    ds = small_dataset(seed=3)
    heads = []
    stream = ResultStream(head_k=5, on_head=heads.append)
    report = session.run(ds, Q, stream=stream)
    assert len(heads) == 1, "head must be delivered exactly once"
    assert _keys(heads[0]) == _keys(report.results.patterns[:5])
    # and the streamed run is bit-identical to an unstreamed one
    again = session.run(ds, Q)
    assert _keys(report.results.patterns) == _keys(again.results.patterns)


def test_served_concurrency4_parity_with_direct_session():
    datasets = [small_dataset(seed=s) for s in range(6)]
    queries = [SignificantPatternQuery(alpha=a)
               for a in (0.05, 0.01, 0.05, 0.01, 0.05, 0.01)]

    direct = MinerSession(runtime=CFG)
    expected = [direct.run(ds, q) for ds, q in zip(datasets, queries)]

    async def main():
        heads = []
        svc = MiningService(
            size=4, runtime=CFG,
            warmups=[WarmupSpec(datasets[0].bucket)],
        )
        await svc.start()
        results = await asyncio.gather(*[
            svc.mine(ds, q, stream=(
                ResultStream(head_k=3, on_head=heads.append)
                if i == 0 else None))
            for i, (ds, q) in enumerate(zip(datasets, queries))
        ])
        await svc.stop()
        return results, heads

    results, heads = asyncio.run(main())
    assert all(r.ok for r in results)
    # warmup happened before traffic: no served query may compile
    assert sum(1 for r in results if r.report.cold) == 0
    for exp, res in zip(expected, results):
        rep = res.report
        assert rep.min_sup == exp.min_sup
        assert rep.correction_factor == exp.correction_factor
        assert rep.delta == exp.delta
        assert rep.n_significant == exp.n_significant
        # bit-identical patterns, p-values included
        assert _keys(rep.results.patterns) == _keys(exp.results.patterns)
    # the streamed head of request 0 equals its final head
    assert len(heads) == 1
    assert _keys(heads[0]) == _keys(results[0].report.results.patterns[:3])


# ----------------------------------------------- fault tolerance (§11)
def test_retry_to_success_counts_attempts():
    """Two injected worker failures, then success: one resolved request,
    three attempts, retries surfaced in the metrics."""
    from repro.testing import FaultPlan, injected

    async def main():
        sched, (fake,) = fake_service(
            None, max_retries=2, retry_backoff_s=0.005)
        await sched.start()
        with injected(FaultPlan(serve_fail_first_n=2)):
            req = sched.submit(FakeDataset(BUCKET_A, "r0"), Q)
            result = await req.future
        await sched.stop()
        return result, fake

    result, fake = asyncio.run(main())
    assert result.outcome == "ok"
    assert result.attempts == 3          # 1 original + 2 retries
    assert fake.ran == ["r0"]            # the successful attempt ran once


def test_retries_exhausted_is_terminal_error():
    from repro.testing import FaultPlan, injected

    async def main():
        sched, _ = fake_service(
            None, max_retries=2, retry_backoff_s=0.005,
            breaker_threshold=99)        # isolate the retry budget
        await sched.start()
        with injected(FaultPlan(serve_fail_first_n=50)):
            req = sched.submit(FakeDataset(BUCKET_A, "r0"), Q)
            result = await req.future
        await sched.stop()
        return result

    result = asyncio.run(main())
    assert result.outcome == "error"
    assert result.attempts == 3          # budget fully consumed
    assert "SimulatedFault" in result.reason


def test_breaker_ejects_then_rebuilds_single_worker():
    """Three consecutive failures trip the size-1 fleet's only worker; the
    scheduler rebuilds it (fake sessions: breaker reset) and the retried
    request completes on the repaired worker."""
    from repro.testing import FaultPlan, injected

    async def main():
        sched, (fake,) = fake_service(
            None, max_retries=3, retry_backoff_s=0.005, breaker_threshold=3)
        await sched.start()
        worker = sched.fleet.workers[0]
        # record every rebuild the scheduler dispatches (the fake rebuild is
        # instant, so polling `worker.broken` would race the repair)
        rebuilt = []
        orig = sched.fleet.rebuild_worker
        sched.fleet.rebuild_worker = (
            lambda w: (rebuilt.append(w.wid), orig(w))[1])
        with injected(FaultPlan(serve_fail_first_n=3)):
            req = sched.submit(FakeDataset(BUCKET_A, "r0"), Q)
            result = await req.future
        await sched.stop()
        return result, worker, rebuilt

    result, worker, rebuilt = asyncio.run(main())
    assert rebuilt == [0], "3 consecutive failures must trip + rebuild"
    assert result.outcome == "ok" and result.attempts == 4
    assert not worker.broken and worker.failures == 0  # repaired + closed


def test_worker_death_loses_zero_requests():
    """A burst of injected deaths across a 2-worker fleet: every admitted
    request still resolves ok (retries + breaker rebuilds, never drops)."""
    from repro.testing import FaultPlan, injected

    async def main():
        sched, fakes = fake_service(
            None, capacity=32, max_batch=2, size=2,
            max_retries=4, retry_backoff_s=0.005, breaker_threshold=3)
        await sched.start()
        with injected(FaultPlan(serve_fail_first_n=6)):
            reqs = [sched.submit(FakeDataset(BUCKET_A, f"r{i}"), Q)
                    for i in range(12)]
            results = await asyncio.gather(*[r.future for r in reqs])
        await sched.stop()
        return results, fakes

    results, fakes = asyncio.run(main())
    assert [r.outcome for r in results] == ["ok"] * 12
    assert sum(r.attempts for r in results) == 12 + 6  # every death retried
    ran = sorted(n for f in fakes for n in f.ran)
    assert ran == sorted(f"r{i}" for i in range(12))  # each ran exactly once


def test_deadline_partial_result_real_engine(tmp_path):
    """A request whose deadline expires mid-mine stops at a superstep
    boundary and resolves "partial": a truncated-but-real ResultSet plus
    the frontier checkpoint path, not a bare timeout."""
    from repro.api.query import ClosedFrequentQuery

    ds = small_dataset(seed=7, n=100, m=40)
    cfg = RuntimeConfig(expand_batch=1, steal_enabled=False, ckpt_period=4)
    query = ClosedFrequentQuery(min_sup=1)

    async def main():
        svc = MiningService(
            size=1, runtime=cfg,
            config=ServeConfig(ckpt_root=str(tmp_path)),
            warmups=[WarmupSpec(ds.bucket, statistic=None)],
        )
        await svc.start()
        res = await svc.mine(ds, query, timeout_s=0.3)
        await svc.stop()
        return res, svc.metrics.expose_text()

    res, metrics = asyncio.run(main())
    assert res.outcome == "partial"
    rep = res.report
    assert rep.partial and not rep.results.complete
    assert len(rep.results.patterns) > 0      # real work, not a bare timeout
    assert res.ckpt_path and res.ckpt_path.startswith(str(tmp_path))
    assert "serve_partial_results_total 1" in metrics
