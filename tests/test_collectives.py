"""Version-portability layer (core/collectives.py) unit tests.

Covers the three shims the engine depends on: shard_map resolution across
JAX versions (incl. the check_vma/check_rep kwarg rename), cost_analysis()
normalization (dict vs list-of-dict returns), and simulated multi-device
mesh setup on CPU (subprocess: the flag must precede first jax init).
"""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import collectives
from repro.core.collectives import (
    MINERS_AXIS,
    host_device_count_env,
    make_miner_mesh,
    normalize_cost_analysis,
    resolve_shard_map,
)

HERE = os.path.dirname(os.path.abspath(__file__))


# ----------------------------------------------------------------- shard_map
def test_resolve_shard_map_finds_a_callable():
    fn = resolve_shard_map()
    assert callable(fn)
    # resolution must agree with whatever this jax actually exposes
    candidates = [getattr(jax, "shard_map", None),
                  getattr(jax.sharding, "shard_map", None)]
    try:
        from jax.experimental.shard_map import shard_map as exp_sm
        candidates.append(exp_sm)
    except ImportError:
        pass
    assert any(fn is c for c in candidates if c is not None)


def test_shard_map_wrapper_runs_collectives():
    """The wrapped shard_map compiles a psum+ppermute program (any P>=1)."""
    mesh = make_miner_mesh()
    p = mesh.devices.size

    def prog(x):
        total = collectives.psum(x[0], MINERS_AXIS)
        shifted = collectives.ppermute(
            x[0], [(i, (i + 1) % p) for i in range(p)], MINERS_AXIS
        )
        return total, shifted[None]

    f = collectives.shard_map(
        prog, mesh=mesh, in_specs=(P(MINERS_AXIS),), out_specs=(P(), P(MINERS_AXIS)),
    )
    x = np.arange(p, dtype=np.int32)
    total, shifted = jax.jit(f)(x)
    assert int(total) == x.sum()
    np.testing.assert_array_equal(np.asarray(shifted), np.roll(x, 1))


def test_shard_map_wrapper_mixed_replication_specs():
    """check_replication=False must tolerate replicated + sharded out_specs
    (the engine mixes psum'd globals with per-miner outputs)."""
    mesh = make_miner_mesh()

    def prog(x):
        return collectives.psum(x[0], MINERS_AXIS), x * 2

    f = collectives.shard_map(
        prog, mesh=mesh, in_specs=(P(MINERS_AXIS),),
        out_specs=(P(), P(MINERS_AXIS)),
    )
    g, local = jax.jit(f)(np.ones(mesh.devices.size, np.int32))
    assert int(g) == mesh.devices.size
    assert np.asarray(local).tolist() == [2] * mesh.devices.size


# ---------------------------------------------------------- cost_analysis()
def test_normalize_cost_analysis_shapes():
    assert normalize_cost_analysis(None) == {}
    assert normalize_cost_analysis([]) == {}
    assert normalize_cost_analysis({"flops": 2.0}) == {"flops": 2.0}
    assert normalize_cost_analysis([{"flops": 2.0}]) == {"flops": 2.0}
    # multi-partition lists merge by summing numerics
    got = normalize_cost_analysis(
        [{"flops": 2.0, "name": "a"}, {"flops": 3.0, "bytes": 1.0}]
    )
    assert got["flops"] == 5.0 and got["bytes"] == 1.0 and got["name"] == "a"
    with pytest.raises(TypeError):
        normalize_cost_analysis(42)


def test_normalize_cost_analysis_on_real_compiled():
    comp = jax.jit(lambda x: x @ x).lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float32)
    ).compile()
    got = normalize_cost_analysis(comp.cost_analysis())
    assert isinstance(got, dict)
    assert got.get("flops", 0) > 0


# ------------------------------------------------- simulated devices + mesh
def test_host_device_count_env_replaces_stale_flag():
    env = {"XLA_FLAGS": "--xla_force_host_platform_device_count=2 --foo=bar"}
    out = host_device_count_env(8, env)
    flags = out["XLA_FLAGS"].split()
    assert "--xla_force_host_platform_device_count=8" in flags
    assert "--foo=bar" in flags
    assert sum(f.startswith("--xla_force_host_platform_device_count") for f in flags) == 1
    assert env["XLA_FLAGS"].endswith("--foo=bar")  # input not mutated


def test_miner_mesh_1d_axis():
    mesh = make_miner_mesh()
    assert mesh.axis_names == (MINERS_AXIS,)
    assert mesh.devices.ndim == 1
    assert mesh.devices.size == len(jax.devices())


def test_simulated_8_device_mesh_setup():
    """8 simulated CPU devices: mesh + shard_map psum in a fresh subprocess
    (pytest's jax is already initialized with this process's device count)."""
    code = textwrap.dedent("""
        import json
        import numpy as np
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.core import collectives
        from repro.core.collectives import MINERS_AXIS, make_miner_mesh

        mesh = make_miner_mesh()
        f = collectives.shard_map(
            lambda x: (collectives.psum(x[0], MINERS_AXIS),),
            mesh=mesh, in_specs=(P(MINERS_AXIS),), out_specs=(P(),),
        )
        (total,) = jax.jit(f)(np.arange(mesh.devices.size, dtype=np.int32))
        print(json.dumps({
            "n_devices": len(jax.devices()),
            "axis_names": list(mesh.axis_names),
            "mesh_size": int(mesh.devices.size),
            "psum": int(total),
        }))
    """)
    env = host_device_count_env(8)
    env["PYTHONPATH"] = os.path.join(HERE, "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=300,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    got = json.loads(out.stdout.strip().splitlines()[-1])
    assert got == {
        "n_devices": 8, "axis_names": [MINERS_AXIS], "mesh_size": 8,
        "psum": sum(range(8)),
    }
