"""BitmapLayout + tiled engine equivalence (DESIGN.md §8).

The item-tiled database layout is a pure re-arrangement of exact integer
math: a full mine under any tiling (and any kernel variant) must reproduce
the untiled ref-kernel ResultSet bit-for-bit.  These tests pin that, plus
the layout invariants the engine relies on (zero-padded tail, free flat
view, bucket tile propagation into reports and cache keys).
"""

import numpy as np
import pytest

from repro.api import AlgorithmConfig, Dataset, MinerSession, RuntimeConfig
from repro.api.dataset import BucketPolicy, ShapeBucket
from repro.core.bitmap import BitmapLayout, pack_db
from repro.core.engine import mine, pack_problem

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


def small_problem(seed=0, n=60, m=40):
    rng = np.random.default_rng(seed)
    db = rng.random((n, m)) < 0.3
    labels = rng.random(n) < 0.4
    # plant one enriched pair so phase 3 has signal
    carrier = np.where(labels, rng.random(n) < 0.7, rng.random(n) < 0.05)
    db[carrier, 3] = True
    db[carrier, 17] = True
    return db, labels


# ----------------------------------------------------------------- layout
def test_layout_roundtrip_and_tail():
    rng = np.random.default_rng(0)
    db = rng.integers(0, 2**32, size=(100, 3), dtype=np.uint32)
    layout = BitmapLayout.from_db_bits(db, m_tile=32)
    assert layout.n_tiles == 4 and layout.m_tile == 32 and layout.m_pad == 128
    np.testing.assert_array_equal(layout.flat[:100], db)
    assert (layout.flat[100:] == 0).all()          # padded tail is zero
    np.testing.assert_array_equal(
        layout.tail_mask(), np.arange(128) < 100
    )
    assert not layout.tiles.flags.writeable


def test_layout_single_tile_default():
    db = np.ones((10, 2), dtype=np.uint32)
    layout = BitmapLayout.from_db_bits(db)
    assert layout.n_tiles == 1 and layout.m_tile == 10 and layout.m_pad == 10


def test_layout_validation():
    db = np.ones((10, 2), dtype=np.uint32)
    with pytest.raises(ValueError, match="not a multiple"):
        BitmapLayout.from_db_bits(db, m_tile=4, m_pad=10)
    with pytest.raises(ValueError, match="smaller than"):
        BitmapLayout.from_db_bits(db, m_tile=4, m_pad=8)


def test_packed_problem_tiled_views():
    db, labels = small_problem()
    packed = pack_problem(db, labels, m_tile=16)
    assert packed.db_tiles.shape == (3, 16, packed.w_pad)  # 40 -> 48 pad
    assert packed.m_pad == 48 and packed.m == 40
    np.testing.assert_array_equal(
        packed.db_bits[:40], pack_db(db)
    )


# ------------------------------------------------- engine-level bit parity
def test_tiled_mine_reproduces_untiled_bitexact():
    """Full mine under forced multi-tile layout == untiled seed behavior:
    histogram, lambda, supersteps' results, and the ResultSet all equal."""
    db, labels = small_problem(seed=4)
    ref = mine(db, labels, mode="lamp1", alpha=0.05)
    tiled = mine(db, labels, mode="lamp1", alpha=0.05,
                 packed=pack_problem(db, labels, m_tile=8))  # 5 tiles
    assert tiled.lam_final == ref.lam_final
    np.testing.assert_array_equal(tiled.hist, ref.hist)


@pytest.mark.parametrize("kernel", ["ref", "pallas_interpret"])
def test_tiled_session_resultset_bitexact(kernel):
    """Session-level: tiled layout (+ either kernel) reproduces the untiled
    ref-kernel ResultSet bit-for-bit — patterns, supports, p/q-values.

    This is also the tier-1 expand-path kernel smoke: kernel="pallas_interpret"
    runs the actual Pallas kernel body (interpreted) inside a real mine's
    superstep loop, not just the unit contraction.
    """
    db, labels = small_problem(seed=7)
    ds_ref = Dataset.from_dense(db, labels, name="untiled")
    # item_tile=16 forces a 4-tile layout for the 64-item bucket
    ds_tiled = Dataset.from_dense(
        db, labels, name="tiled",
        bucket_policy=BucketPolicy(item_tile=16),
    )
    assert ds_tiled.bucket.item_tile == 16
    assert ds_tiled.packed.db_tiles.shape[0] == 4

    def run(ds, kernel_impl):
        session = MinerSession(
            algorithm=AlgorithmConfig(alpha=0.05),
            runtime=RuntimeConfig(expand_batch=8, stack_cap=2048,
                                  steal_max=32, push_cap=128,
                                  kernel_impl=kernel_impl),
        )
        return session.mine(ds)

    def patterns(rep):
        return sorted(
            (tuple(p.items), p.support, p.pos_support, p.pvalue, p.qvalue)
            for p in rep.results
        )

    ref = run(ds_ref, "ref")
    rep = run(ds_tiled, kernel)
    assert rep.lambda_final == ref.lambda_final
    assert rep.min_sup == ref.min_sup
    assert rep.correction_factor == ref.correction_factor
    assert rep.delta == ref.delta
    assert rep.n_significant == ref.n_significant
    assert patterns(rep) == patterns(ref)
    # provenance recorded (S1): the resolved impl, never "auto"
    assert rep.kernel_impl == kernel
    assert rep.item_tile == 16
    if kernel == "ref":
        assert rep.kernel_blocks is None
    else:
        assert len(rep.kernel_blocks) == 3


# --------------------------------------------------- bucket / cache keying
def test_bucket_item_tile_field():
    pol = BucketPolicy(item_tile=32)
    b = pol.bucket_for(60, 20, 100)  # items round to 128, 4 tiles of 32
    assert b == ShapeBucket(64, 32, 128, item_tile=32)
    assert b.tile == 32 and b.n_tiles == 4
    # small item dims stay single-tile with item_tile=0 (legacy equality)
    b2 = BucketPolicy().bucket_for(60, 20, 24)
    assert b2 == ShapeBucket(64, 32, 64)
    assert b2.item_tile == 0 and b2.tile == 64 and b2.n_tiles == 1


def test_exact_policy_still_tiles_huge_items():
    pol = BucketPolicy(exact=True, item_tile=64)
    b = pol.bucket_for(100, 30, 150)
    assert b.items == 192 and b.item_tile == 64 and b.n_tiles == 3


def test_kernel_blocks_in_resolved_config():
    bucket = ShapeBucket(64, 16, 4096, item_tile=0)
    cfg_ref = RuntimeConfig(kernel_impl="ref").resolve(bucket, 1)
    assert cfg_ref.kernel_blocks is None
    cfg_k = RuntimeConfig(kernel_impl="pallas_interpret").resolve(bucket, 1)
    assert cfg_k.kernel_blocks is not None and len(cfg_k.kernel_blocks) == 3
    # explicit blocks pass through and distinguish the resolved config
    cfg_exp = RuntimeConfig(
        kernel_impl="pallas_interpret", kernel_blocks=(8, 128, 8)
    ).resolve(bucket, 1)
    assert cfg_exp.kernel_blocks == (8, 128, 8)
    assert cfg_exp != cfg_k or cfg_k.kernel_blocks == (8, 128, 8)


def test_tiled_and_untiled_buckets_never_share_programs():
    """item_tile is part of the bucket, hence of the session cache key."""
    db, labels = small_problem()
    ds_a = Dataset.from_dense(db, labels, name="a")
    ds_b = Dataset.from_dense(
        db, labels, name="b", bucket_policy=BucketPolicy(item_tile=16)
    )
    assert ds_a.bucket != ds_b.bucket
    session = MinerSession(
        runtime=RuntimeConfig(expand_batch=8, stack_cap=2048, steal_max=32,
                              push_cap=128)
    )
    session.run_phase(ds_a, "count", min_sup=5)
    session.run_phase(ds_b, "count", min_sup=5)
    info = session.cache_info()
    assert info.misses == 2 and info.hits == 0  # distinct programs


def test_packed_words_dataset_matches_dense():
    """Dataset.from_packed_words == Dataset.from_dense for the same bits."""
    db, labels = small_problem(seed=2)
    bits = pack_db(db)
    ds_dense = Dataset.from_dense(db, labels, name="dense")
    ds_packed = Dataset.from_packed_words(
        bits, labels, n_transactions=db.shape[0], name="packed"
    )
    assert ds_packed.bucket == ds_dense.bucket
    np.testing.assert_array_equal(
        ds_packed.packed.db_tiles, ds_dense.packed.db_tiles
    )
    np.testing.assert_array_equal(
        ds_packed.packed.pos_mask, ds_dense.packed.pos_mask
    )
    assert ds_packed.n_pos == ds_dense.n_pos

    session = MinerSession(
        runtime=RuntimeConfig(expand_batch=8, stack_cap=2048, steal_max=32,
                              push_cap=128)
    )
    rep_d = session.mine(ds_dense)
    rep_p = session.mine(ds_packed)
    assert rep_p.n_significant == rep_d.n_significant
    assert rep_p.lambda_final == rep_d.lambda_final
    assert not rep_p.cold  # same bucket: fully warm replay


def test_generate_packed_matches_spec():
    """generate_packed: right shapes, plausible density, planted support."""
    from repro.core.bitmap import popcount_np
    from repro.data.synthetic import SyntheticSpec, generate_packed

    spec = SyntheticSpec("t", n_items=500, n_transactions=200, density=0.05,
                         n_pos=60, seed=1)
    bits, labels, planted = generate_packed(spec, item_chunk=128)
    assert bits.shape == (500, 7)  # ceil(200/32)
    assert labels.sum() == 60
    density = popcount_np(bits).sum() / (500 * 200)
    assert 0.02 < density < 0.15
    for itemset in planted:
        for j in itemset:
            assert popcount_np(bits[j]).sum() >= spec.planted_pos_rate * 30
