"""Collective breakdown from a stored dry-run HLO: top ops by bytes x trips.

  python benchmarks/coll_breakdown.py command-r-plus-104b__train_4k__single
"""

import gzip
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import hlo_cost as hc  # noqa: E402

HLO_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun", "hlo")


def breakdown(tag: str, top: int = 18):
    with gzip.open(os.path.join(HLO_DIR, tag + ".txt.gz"), "rt") as f:
        txt = f.read()

    comps: dict[str, list[str]] = {}
    cur = None
    entry = None
    for line in txt.splitlines():
        hdr = hc._COMP_HDR_RE.match(line.strip())
        if hdr and "{" in line:
            cur = hdr.group(1)
            comps[cur] = []
            if line.strip().startswith("ENTRY"):
                entry = cur
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)

    # trip count per while body + caller chains
    trips = {}
    parents = {}
    for cname, lines in comps.items():
        for line in lines:
            m = re.search(r"condition=%?([\w.\-]+).*?body=%?([\w.\-]+)", line)
            if not m:
                m2 = re.search(r"body=%?([\w.\-]+).*?condition=%?([\w.\-]+)", line)
                m = None
                if m2:
                    trips_body, cond = m2.group(1), m2.group(2)
                    const = max(
                        [int(c) for l2 in comps.get(cond, [])
                         for c in re.findall(r"constant\((\d+)\)", l2)] + [1]
                    )
                    trips[trips_body] = const
                    parents[trips_body] = cname
                continue
            cond, body = m.group(1), m.group(2)
            const = max(
                [int(c) for l2 in comps.get(cond, [])
                 for c in re.findall(r"constant\((\d+)\)", l2)] + [1]
            )
            trips[body] = const
            parents[body] = cname
        for line in lines:
            fm = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", line)
            if fm:
                parents.setdefault(fm.group(1), cname)

    def total_mult(cname):
        mult, seen = 1.0, set()
        while cname in parents and cname not in seen:
            seen.add(cname)
            mult *= trips.get(cname, 1)
            cname = parents[cname]
        return mult

    rows = []
    for cname, lines in comps.items():
        mult = total_mult(cname)
        tmap = {}
        for line in lines:
            m = hc._OP_RE.match(line)
            if not m:
                continue
            opn, rtype, opcode, args = m.groups()
            tmap[opn] = rtype
            base = opcode.replace("-start", "")
            if base in ("all-reduce", "all-gather", "reduce-scatter",
                        "all-to-all", "collective-permute"):
                b = hc._shape_bytes(rtype)
                meta = re.search(r'op_name="([^"]*)"', line)
                rows.append((b * mult, b, mult, base, rtype[:42],
                             (meta.group(1) if meta else "")[-86:]))
    rows.sort(reverse=True)
    print(f"{'tot GiB':>8s} {'each MiB':>9s} {'trips':>6s} kind               shape")
    for tot, b, mult, kind, rt, meta in rows[:top]:
        print(f"{tot/2**30:8.2f} {b/2**20:9.1f} {mult:6.0f} {kind:18s} {rt}")
        if meta:
            print(f"{'':26s}{meta}")


if __name__ == "__main__":
    breakdown(sys.argv[1] if len(sys.argv) > 1 else
              "command-r-plus-104b__train_4k__single")
