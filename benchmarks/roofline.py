"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json and derives, per (arch x shape x mesh):

  compute term    = HLO_FLOPs_per_device / peak_FLOPs          [s]
  memory term     = HLO_bytes_per_device / HBM_bw              [s]
  collective term = coll_link_bytes_per_device / ICI_link_bw   [s]

(the dry-run HLO is the per-device SPMD program, so the "/(chips)" in the
assignment's formulas is already applied).  MODEL_FLOPS uses the standard
6·N·D (train) / 2·N·D (single forward / per-token decode) accounting with
N = active params, D = processed tokens, plus the useful-compute ratio
MODEL_FLOPS / (HLO_FLOPs x chips).

Hardware constants (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def model_flops(rec: dict) -> float:
    n = rec["n_active_params"]
    if rec["kind"] == "train":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 6.0 * n * tokens
    if rec["kind"] == "prefill":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * rec["global_batch"]


def analyze(rec: dict) -> dict:
    chips = rec["chips"]
    flops_dev = rec["hlo_parsed"]["flops"]
    bytes_dev = rec["hlo_parsed"]["bytes"]
    coll_dev = rec["hlo_parsed"]["coll_link_bytes"]
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(rec)
    useful_ratio = mf / max(flops_dev * chips, 1.0)
    t_bound = max(terms.values())
    t_ideal = mf / chips / PEAK_FLOPS  # time if only useful math at peak
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "kind": rec["kind"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "bottleneck": bottleneck,
        "model_flops": mf,
        "useful_ratio": useful_ratio,
        "roofline_fraction": t_ideal / t_bound if t_bound > 0 else 0.0,
        "mem_gib_per_dev": rec["memory"]["per_device_total"] / 2**30,
        "fits_16g": rec["memory"]["per_device_total"] / 2**30 < 16.0,
        "compile_s": rec["compile_s"],
        "coll_payload": rec["hlo_parsed"]["coll_payload"],
    }


FIX_HINTS = {
    "collective": "reduce SP/FSDP gather volume: bf16 collectives, 2D-sharded "
                  "attention, overlap param gathers with compute",
    "memory": "raise arithmetic intensity: larger per-device batch/fused "
              "kernels; decode is cache-read bound -> quantized KV",
    "compute": "already MXU-bound: improve useful-ratio (less remat/padding)",
}


def load_all(dryrun_dir: str = DRYRUN_DIR):
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def emit_markdown(rows):
    """Inject the single-pod roofline table into EXPERIMENTS.md (marker)."""
    lines = [
        "| arch | shape | compute s | memory s | coll s | bound | useful | roofl% | GiB/dev | fits 16G |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["mesh"] != "16x16":
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3f} | "
            f"{r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} | "
            f"{r['bottleneck']} | {r['useful_ratio']:.2f} | "
            f"{100*r['roofline_fraction']:.1f}% | {r['mem_gib_per_dev']:.2f} | "
            f"{'✓' if r['fits_16g'] else '✗ (CPU f32-promotion; see §Dry-run)'} |"
        )
    lines.append("")
    lines.append(
        "Multi-pod (2×16×16) rows track the single-pod terms at ~0.5× per-device "
        "compute/memory with near-identical collective terms (the pod axis adds "
        "cross-pod gradient reduction); full table in `roofline_summary.json`."
    )
    table = "\n".join(lines)
    exp = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")
    with open(exp) as f:
        text = f.read()
    marker = "<!-- ROOFLINE_TABLE -->"
    if marker in text:
        pre, rest = text.split(marker, 1)
        # keep everything from the sentinel paragraph on (idempotent re-inject)
        idx = rest.find("\nDecode cells sit")
        tail = rest[idx:] if idx >= 0 else rest
        text = pre + marker + "\n\n" + table + "\n" + tail
        with open(exp, "w") as f:
            f.write(text)
        print(f"injected roofline table into {os.path.normpath(exp)}")


def main():
    rows = [analyze(r) for r in load_all()]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    hdr = (f"{'arch':22s} {'shape':11s} {'mesh':7s} {'compute':>9s} {'memory':>9s} "
           f"{'coll':>9s} {'bound':>10s} {'useful':>7s} {'roofl%':>7s} {'GiB':>6s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(
            f"{r['arch']:22s} {r['shape']:11s} {r['mesh']:7s} "
            f"{r['t_compute_s']:9.3f} {r['t_memory_s']:9.3f} {r['t_collective_s']:9.3f} "
            f"{r['bottleneck']:>10s} {r['useful_ratio']:7.2f} "
            f"{100*r['roofline_fraction']:6.1f}% {r['mem_gib_per_dev']:6.2f}"
        )
    out = os.path.join(DRYRUN_DIR, "..", "roofline_summary.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\nwrote {os.path.normpath(out)}")
    emit_markdown(rows)
    # worst cells per criterion (hillclimb candidates)
    single = [r for r in rows if r["mesh"] == "16x16"]
    worst = min(single, key=lambda r: r["roofline_fraction"])
    collb = max(single, key=lambda r: r["t_collective_s"])
    print(f"worst roofline fraction: {worst['arch']}/{worst['shape']} "
          f"({100*worst['roofline_fraction']:.1f}%)")
    print(f"most collective-bound:   {collb['arch']}/{collb['shape']} "
          f"(coll {collb['t_collective_s']:.2f}s)")


if __name__ == "__main__":
    main()
