import os

# The mining benchmarks emulate a pool of miners (one per device), exactly as
# the engine runs on a pod slice.  16 host devices is the benchmark pool — set
# here, before any jax import, and ONLY here (the dry-run uses its own 512).
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"

"""Benchmark runner: one artifact per paper table/figure + kernel roofline
+ the large-P topology-scaling curve.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only fig6_speedup
"""

import argparse
import time


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def _print_table(title, rows, cols):
    print(f"\n== {title} ==")
    if not rows:
        print("(empty)")
        return
    widths = {c: max(len(c), *(len(_fmt(r.get(c))) for r in rows)) for c in cols}
    print("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        print("  ".join(_fmt(r.get(c)).ljust(widths[c]) for c in cols))


def _run_fig6(mining_suite):
    out = mining_suite.fig6_speedup()
    for name, data in out.items():
        _print_table(
            f"Fig 6 — speedup: {name} (c_node {data['c_node_s']*1e6:.1f} us, "
            f"{data['nodes']} nodes)",
            data["curve"],
            ["P", "speedup", "efficiency", "supersteps", "work_imbalance",
             "steals", "stolen_nodes"],
        )


def _run_fig7(mining_suite):
    out = mining_suite.fig7_breakdown()
    for name, rows in out.items():
        print(f"\n== Fig 7 — breakdown: {name} ==")
        for r in rows:
            popped = r["popped_per_dev"]
            idle = r["idle_steps_per_dev"]
            print(f" P={r['P']:3d} supersteps={r['supersteps']:6d} "
                  f"popped[min/mean/max]={min(popped)}/"
                  f"{int(sum(popped)/len(popped))}/{max(popped)} "
                  f"idle[mean]={int(sum(idle)/len(idle))} "
                  f"steals={sum(r['steals_got_per_dev'])}")


def _run_kernels(kernel_roofline):
    out = kernel_roofline.run()
    _print_table(
        "Pallas support-count kernel roofline (v5e)", out["support_count"],
        ["shape", "block", "t_compute_us", "t_memory_us", "bound",
         "vmem_per_step_kib", "fits_vmem", "verified_vs_oracle"],
    )


def _run_scaling():
    from . import bench_scaling

    out = bench_scaling.run(bench_scaling.SMOKE_DATASET,
                            bench_scaling.SMOKE_MIN_SUP,
                            bench_scaling.SMOKE_P_VALUES, None)
    _print_table(
        "Topology scaling (smoke; full curve: -m benchmarks.bench_scaling)",
        [
            {
                "P": pt["P"], "topology": pt["topology"],
                "hier_x": pt["speedup"]["hierarchical"],
                "flat_x": pt["speedup"]["flat"],
                "static_x": pt["speedup"]["naive_static"],
            }
            for pt in out["curve"]
        ],
        ["P", "topology", "hier_x", "flat_x", "static_x"],
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    t0 = time.time()

    from . import kernel_roofline, mining_suite

    sections = {
        "table1": lambda: _print_table(
            "Table 1 — problems (synthetic, matched to paper stats)",
            mining_suite.table1_problems(),
            ["name", "items", "trans", "density", "lambda", "min_sup",
             "closed_sets", "significant", "t1_host_s", "t_engine_wall_s"],
        ),
        "fig6_speedup": lambda: _run_fig6(mining_suite),
        "table2": lambda: _print_table(
            "Table 2 — GLB vs naive split (P=8, modeled makespan)",
            mining_suite.table2_naive(),
            ["name", "t1_s", "glb_T_s", "glb_speedup", "glb_imbalance",
             "naive_T_s", "naive_speedup", "naive_imbalance"],
        ),
        "fig7": lambda: _run_fig7(mining_suite),
        "significant": lambda: _print_table(
            "§5.6 — significant patterns (planted-signal recovery)",
            mining_suite.significant_patterns(),
            ["name", "planted", "recovered", "n_significant", "delta",
             "wall_s", "engine_matches_host"],
        ),
        "kernels": lambda: _run_kernels(kernel_roofline),
        "scaling": _run_scaling,
    }
    for name, fn in sections.items():
        if args.only and args.only != name:
            continue
        fn()
    print(f"\ntotal {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
