import os

# One miner per simulated device, set before any jax import (same pool shape
# as benchmarks.run, but self-contained so this entry runs standalone in CI).
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Mining perf baseline: the BSP makespan-model suite on two paper problems,
plus the repeated-query (cold vs warm session) latency benchmark.

  PYTHONPATH=src python -m benchmarks.bench_mining            # full baseline
  PYTHONPATH=src python -m benchmarks.bench_mining --smoke    # CI-sized

Writes BENCH_mining.json at the repo root: per problem, the expanded node
count, the calibrated per-node cost, measured wall seconds, and the modeled
speedup vs miner count P (benchmarks/common.py documents the makespan model —
this container is single-core, so multi-miner wall-clock is meaningless and
the per-superstep trace gives the exact parallel schedule instead).  The
`repeated_query` section drives one `repro.api.MinerSession` with reseeded
same-bucket queries: the first is cold (compiles one program per phase),
the rest replay warm compiled programs — `cold_over_warm` is the latency
win the session API exists for, and `compiles` must equal the phase count.

The committed BENCH_mining.json is the perf trajectory's anchor: later perf
PRs rerun this entry point and compare against it.
"""

import argparse
import json
import time

import jax

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_mining.json")
TRACE_CAP = 16384

# two representative Table-1 problems: sparse-wide (hapmap) + dense-tall (mcf7)
BENCH_PROBLEMS = {
    "hapmap_dom_10": dict(scale_items=0.08, scale_trans=1.0),
    "mcf7": dict(scale_items=1.0, scale_trans=0.04),
}
SMOKE_PROBLEMS = {
    "hapmap_dom_10": dict(scale_items=0.03, scale_trans=1.0),
    "mcf7": dict(scale_items=1.0, scale_trans=0.02),
}


def bench_problem(name: str, scales: dict, p_values) -> dict:
    from repro.core.engine import EngineConfig, mine
    from repro.core.lamp import lamp
    from repro.data.synthetic import paper_problem

    from .common import C_ROUND_S, makespan

    db, labels, _, spec = paper_problem(
        name, scales["scale_items"], scales["scale_trans"]
    )
    ref = lamp(db, labels, alpha=0.05)
    ms = ref.min_sup
    devices = jax.devices()
    cfg = EngineConfig(expand_batch=16, trace_cap=TRACE_CAP)

    # single-device run calibrates c_node (warm-up excludes compile time)
    mine(db, labels, mode="count", min_sup=ms, cfg=cfg, devices=devices[:1])
    t0 = time.time()
    r1 = mine(db, labels, mode="count", min_sup=ms, cfg=cfg, devices=devices[:1])
    wall1 = time.time() - t0
    nodes = int(r1.stats["popped"].sum())
    c_node = wall1 / max(nodes, 1)
    t1 = makespan(r1.trace, r1.supersteps, c_node)

    speedup, wall_s = {"1": 1.0}, {"1": round(wall1, 3)}
    for p in p_values:
        if p <= 1 or p > len(devices):
            continue
        t0 = time.time()
        rp = mine(db, labels, mode="count", min_sup=ms, cfg=cfg,
                  devices=devices[:p])
        wall_s[str(p)] = round(time.time() - t0, 3)
        tp = makespan(rp.trace, rp.supersteps, c_node)
        speedup[str(p)] = round(t1 / tp, 3)
    return {
        "problem": spec.name,
        "items": spec.n_items,
        "transactions": spec.n_transactions,
        "min_sup": ms,
        "nodes": nodes,
        "c_node_us": round(c_node * 1e6, 3),
        "c_round_us": C_ROUND_S * 1e6,
        "modeled_speedup_vs_P": speedup,
        "wall_s": wall_s,
    }


def bench_repeated_queries(name: str, scales: dict, n_queries: int = 6) -> dict:
    """Cold-vs-warm query latency on one compile-once MinerSession."""
    from repro.api import Dataset, MinerSession, RuntimeConfig

    session = MinerSession(runtime=RuntimeConfig(expand_batch=16))
    lat, n_phases = [], 0
    for q in range(n_queries):
        ds = Dataset.from_paper_problem(
            name, scales["scale_items"], scales["scale_trans"], seed=q
        )
        t0 = time.time()
        report = session.mine(ds)
        lat.append(time.time() - t0)
        n_phases = len(report.phases)
    ci = session.cache_info()
    warm = lat[1:]
    assert ci.misses == n_phases, "warm queries must not recompile"
    return {
        "problem": name,
        "pipeline": "three_phase",
        "queries": n_queries,
        "cold_s": round(lat[0], 3),
        "warm_mean_s": round(sum(warm) / len(warm), 4),
        "warm_max_s": round(max(warm), 4),
        "cold_over_warm": round(lat[0] * len(warm) / sum(warm), 1),
        "compiles": ci.misses,
        "cache_hits": ci.hits,
        "compile_s_total": round(sum(p.compile_s for p in ci.programs), 3),
    }


def run(problems: dict, p_values=(1, 2, 4, 8), out_path: str = DEFAULT_OUT) -> dict:
    t0 = time.time()
    rq_name = next(iter(problems))
    payload = {
        "suite": "mining-makespan-baseline",
        "host_devices": len(jax.devices()),
        "problems": [bench_problem(n, s, p_values) for n, s in problems.items()],
        "repeated_query": bench_repeated_queries(rq_name, problems[rq_name]),
        "total_wall_s": None,
    }
    payload["total_wall_s"] = round(time.time() - t0, 3)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized problems (same schema, smaller scales)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    payload = run(SMOKE_PROBLEMS if args.smoke else BENCH_PROBLEMS,
                  out_path=args.out)
    print(json.dumps(payload, indent=1))
    print(f"[out] {args.out}")


if __name__ == "__main__":
    main()
