import os

# One miner per simulated device, set before any jax import (same pool shape
# as benchmarks.run, but self-contained so this entry runs standalone in CI).
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Mining perf baseline: the BSP makespan-model suite on two paper problems,
plus the repeated-query (cold vs warm session) latency benchmark.

  PYTHONPATH=src python -m benchmarks.bench_mining            # full baseline
  PYTHONPATH=src python -m benchmarks.bench_mining --smoke    # CI-sized
  PYTHONPATH=src python -m benchmarks.bench_mining --compare OLD.json NEW.json

Writes BENCH_mining.json at the repo root: per problem, the expanded node
count, the calibrated per-node cost, measured wall seconds (warm: each P
runs on a MinerSession whose program is already compiled, so the timed call
is a zero-trace dispatch and wall_s measures the engine, not jit), and the
modeled speedup vs miner count P (benchmarks/common.py documents the
makespan model — this container is single-core, so multi-miner wall-clock
is meaningless and the per-superstep trace gives the exact parallel
schedule instead).  The
`superstep_breakdown` section is built from the engine's on-device
superstep trace (repro.obs, DESIGN.md §9): exact steal-round/fired counts,
Jain's fairness over per-miner donation and work volumes, per-miner
idle-fraction series, and the measured per-step overhead of tracing itself
(a traced vs untraced warm run — the only run pair left; the old
phase-attribution-by-run-differencing is gone, the trace reads the same
quantities off the device directly).  It also tabulates bytes moved per
round before vs after the deque/gating redesign (DESIGN.md §6), now with
the fired fraction taken from the trace rather than inferred.
The `repeated_query` section drives one `repro.api.MinerSession` with
reseeded same-bucket queries: the first is cold (compiles one program per
phase), the rest replay warm compiled programs — `cold_over_warm` is the
latency win the session API exists for, and `compiles` must equal the phase
count.  The `per_statistic` section records warm full-query latency for
each registered test statistic (fisher, chi2) against one shared session,
asserting that the second statistic compiles only its own emission-test
program (lamp1/count are statistic-free and stay warm).

The `paper_scale` section (DESIGN.md §8) runs FULL Table-1 item counts
through the item-tiled expand path: hapmap_dom_20 (11,914 items) with the
interpreted Pallas kernel inside the superstep loop and alz_rec_30
(250,120 items, 64 tiles of 4096) on the ref kernel, recording the
resolved kernel impl / block triple / tile geometry from the PhaseReport,
plus a downscaled tiled-vs-untiled-ref bit-exactness gate.  `--paper-scale`
runs only that section (the slow-system CI smoke) and writes
experiments/bench/paper_scale.json.

The committed BENCH_mining.json is the perf trajectory's anchor: later perf
PRs rerun this entry point and compare against it (`--compare` prints the
old-vs-new warm wall table as markdown; CI appends it to the job summary).
"""

import argparse
import json
import time

import jax

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_mining.json")
PAPER_SCALE_OUT = os.path.join(ROOT, "experiments", "bench", "paper_scale.json")
TRACE_CAP = 16384

# two representative Table-1 problems: sparse-wide (hapmap) + dense-tall (mcf7)
BENCH_PROBLEMS = {
    "hapmap_dom_10": dict(scale_items=0.08, scale_trans=1.0),
    "mcf7": dict(scale_items=1.0, scale_trans=0.04),
}
SMOKE_PROBLEMS = {
    "hapmap_dom_10": dict(scale_items=0.03, scale_trans=1.0),
    "mcf7": dict(scale_items=1.0, scale_trans=0.02),
}

# Table-1-scale entries (DESIGN.md §8): FULL item counts, packed generation,
# item-tiled buckets.  hapmap_dom_20 carries the kernel-in-the-loop claim
# (pallas_interpret is the Pallas kernel body, interpreted, on CPU CI);
# alz_rec_30 carries the 250k-item tiled-capacity claim on the ref kernel
# (interpret-mode wall time at 64 tiles says nothing a 4-tile run doesn't).
#
# min_sup sits in the probed "valley" of each (seeded, deterministic)
# synthetic instance: the pareto item-frequency tail plants a clique of
# near-universal items (hapmap: ~21 items at support >= 0.9N; alz: ~583),
# and any threshold below that clique's k-deep co-occurrence band admits an
# exponential closed-set lattice no miner completes.  The values below keep
# a few-hundred-node tree (singles + dense pairs/triples), so the entry
# measures the tiled expand path at full item width with a bounded
# traversal; max_steps is a hard safety and `completed` asserts it was
# never the stopper.
PAPER_SCALE_PROBLEMS = {
    "hapmap_dom_20": dict(kernel="pallas_interpret", min_sup=625),
    "alz_rec_30": dict(kernel="ref", min_sup=347),
}
PAPER_SCALE_MAX_STEPS = 4000


def _session(devices, runtime):
    from repro.api import MinerSession

    return MinerSession(devices=devices, runtime=runtime)


def _timed_warm(session, ds, mode, min_sup, repeats: int = 3):
    """(wall_s, MineOutput) of a *warm* engine pass: the first call compiles
    (or hits the session cache), then the best of `repeats` timed calls is
    reported — a zero-trace dispatch each, so wall_s measures the engine,
    not jit, and the min damps this container's scheduling noise."""
    session.run_phase(ds, mode, min_sup=min_sup)
    best, out = None, None
    for _ in range(repeats):
        t0 = time.time()
        ph = session.run_phase(ds, mode, min_sup=min_sup)
        wall = time.time() - t0
        if best is None or wall < best:
            best, out = wall, ph.output
    return best, out


def superstep_breakdown(ds, ms, devices, runtime, base) -> dict:
    """Per-superstep telemetry, read off the device trace (DESIGN.md §9).

    `base` is bench_problem's warm traced count run at this P (trace_period=1,
    so every superstep is sampled).  The decoded `SuperstepTrace` supplies
    exactly what the deleted run-differencing estimated: how many exchange
    rounds fired, how evenly the donation traffic spread (Jain's index — the
    paper's "evenly distributed communication" as one number), per-miner
    idle fractions, and depth imbalance.  The one run pair left measures the
    *trace's own* cost: an untraced warm run gives per-step µs without the
    ring write, and `trace_overhead_pct` is the regression tracing costs
    (acceptance: < 5% at trace_period=1) — results are asserted
    bit-identical between the two.

    The bytes-per-round table is analytic from the config: the old design
    moved the full [stack_cap, W+4] stack twice per round (shift-on-steal),
    sent 4 ppermutes, and psum'd the [n+2] histogram every round; the deque
    moves one packed [steal_max, W+5] payload on fired rounds only (fraction
    now exact, from the trace) and syncs the histogram delta every
    sync_period rounds (plus the [P]-int hunger census).
    """
    import numpy as np

    p = len(devices)
    cfg = runtime.resolve(ds.bucket, p)
    w = ds.bucket.words
    node_words = w + 4  # occ [W]u32 + meta [4]i32

    wall_t, r_t = base  # bench_problem's warm *traced* count run at this P
    s_t = max(r_t.supersteps, 1)
    tr = r_t.trace
    # the cost of tracing itself: same program minus the ring write
    wall_u, r_u = _timed_warm(
        _session(devices, runtime.with_options(trace_period=0, trace_cap=0)),
        ds, "count", ms)
    np.testing.assert_array_equal(r_t.hist, r_u.hist)  # tracing never perturbs
    traced_us = wall_t / s_t * 1e6
    untraced_us = wall_u / max(r_u.supersteps, 1) * 1e6
    overhead_pct = (traced_us - untraced_us) / untraced_us * 100

    fired = int(tr.fired.sum())
    fired_frac = fired / s_t
    payload = (cfg.steal_max * (node_words + 1)) * 4  # packed occ|meta|k rows
    nb = ds.n_transactions + 2
    return {
        "P": p,
        "supersteps": s_t,
        "sampled_steps": tr.n_steps,
        "trace_dropped": tr.dropped,
        "steal_rounds_fired": fired,
        "fired_fraction": round(fired_frac, 4),
        "per_step_us": {
            "traced": round(traced_us, 1),
            "untraced": round(untraced_us, 1),
        },
        "trace_overhead_pct": round(overhead_pct, 2),
        # load balance, per miner, off the device timeline:
        "steal_fairness": {
            "donation": round(tr.donation_fairness(), 4),  # Jain, [1/P, 1]
            "work": round(tr.work_fairness(), 4),
            "depth_imbalance": round(tr.depth_imbalance(), 3),
        },
        "idle_fraction": [round(float(x), 4) for x in tr.idle_fraction()],
        "donated_nodes": [int(x) for x in tr.donated.sum(axis=1)],
        # per miner per round; "before" = the pre-deque shift-on-steal design
        "bytes_per_round": {
            "stack_shift_before": 2 * cfg.stack_cap * node_words * 4,
            "stack_shift_after": 0,
            "steal_payload_before": payload,                    # every round
            "steal_payload_after": round(payload * fired_frac),  # gated rounds
            "hist_sync_before": nb * 4,                          # every round
            "hist_sync_after": round(nb * 4 / cfg.sync_period + 4 * p),  # +census
        },
    }


def bench_problem(name: str, scales: dict, p_values) -> dict:
    from repro.api import Dataset, RuntimeConfig
    from repro.core.lamp import lamp
    from repro.data.synthetic import paper_problem

    from .common import C_ROUND_S, makespan

    db, labels, _, spec = paper_problem(
        name, scales["scale_items"], scales["scale_trans"]
    )
    ds = Dataset.from_dense(db, labels, name=spec.name)
    ref = lamp(db, labels, alpha=0.05)
    ms = ref.min_sup
    devices = jax.devices()
    runtime = RuntimeConfig(expand_batch=16, stack_cap=8192,
                            trace_period=1, trace_cap=TRACE_CAP)

    # warm single-device run calibrates c_node (zero-compile dispatch)
    wall1, r1 = _timed_warm(_session(devices[:1], runtime), ds, "count", ms)
    nodes = int(r1.stats["popped"].sum())
    c_node = wall1 / max(nodes, 1)
    t1 = makespan(r1.trace.popped, r1.supersteps, c_node)

    speedup, wall_s = {"1": 1.0}, {"1": round(wall1, 3)}
    base = (wall1, r1)  # the warm count run at p_max, reused by the breakdown
    p_max = 1
    for p in p_values:
        if p <= 1 or p > len(devices):
            continue
        wall_p, rp = _timed_warm(_session(devices[:p], runtime), ds, "count", ms)
        wall_s[str(p)] = round(wall_p, 3)
        tp = makespan(rp.trace.popped, rp.supersteps, c_node)
        speedup[str(p)] = round(t1 / tp, 3)
        if p > p_max:
            base, p_max = (wall_p, rp), p
    return {
        "problem": ds.name,
        "items": ds.n_items,
        "transactions": ds.n_transactions,
        "min_sup": ms,
        "nodes": nodes,
        "c_node_us": round(c_node * 1e6, 3),
        "c_round_us": C_ROUND_S * 1e6,
        "modeled_speedup_vs_P": speedup,
        "wall_s": wall_s,
        "superstep_breakdown": superstep_breakdown(
            ds, ms, devices[:p_max], runtime, base
        ),
    }


def bench_repeated_queries(name: str, scales: dict, n_queries: int = 6) -> dict:
    """Cold-vs-warm query latency on one compile-once MinerSession."""
    from repro.api import Dataset, MinerSession, RuntimeConfig

    session = MinerSession(runtime=RuntimeConfig(expand_batch=16))
    lat, n_phases = [], 0
    for q in range(n_queries):
        ds = Dataset.from_paper_problem(
            name, scales["scale_items"], scales["scale_trans"], seed=q
        )
        t0 = time.time()
        report = session.mine(ds)
        lat.append(time.time() - t0)
        n_phases = len(report.phases)
    ci = session.cache_info()
    warm = lat[1:]
    assert ci.misses == n_phases, "warm queries must not recompile"
    return {
        "problem": name,
        "pipeline": "three_phase",
        "queries": n_queries,
        "cold_s": round(lat[0], 3),
        "warm_mean_s": round(sum(warm) / len(warm), 4),
        "warm_max_s": round(max(warm), 4),
        "cold_over_warm": round(lat[0] * len(warm) / sum(warm), 1),
        "compiles": ci.misses,
        "cache_hits": ci.hits,
        "compile_s_total": round(sum(p.compile_s for p in ci.programs), 3),
    }


def bench_per_statistic(name: str, scales: dict, n_queries: int = 4) -> dict:
    """Warm full-query latency per registered statistic, ONE shared session.

    Runs fisher then chi2 significant-pattern queries against the same
    `MinerSession`: the first fisher query compiles one program per phase;
    the first chi2 query compiles only its own emission-test program (the
    lamp1/count programs are statistic-free and stay warm — `extra_compiles`
    records exactly that), and every later query is a zero-trace dispatch.
    `warm_mean_s` per statistic is the serving-latency number the query
    layer exists for.
    """
    from repro.api import (
        Dataset, MinerSession, RuntimeConfig, SignificantPatternQuery,
    )

    session = MinerSession(runtime=RuntimeConfig(expand_batch=16))
    out = {}
    misses_before = 0
    for stat in ("fisher", "chi2"):
        query = SignificantPatternQuery(alpha=0.05, statistic=stat)
        lat = []
        for q in range(n_queries):
            ds = Dataset.from_paper_problem(
                name, scales["scale_items"], scales["scale_trans"], seed=q
            )
            t0 = time.time()
            session.run(ds, query)
            lat.append(time.time() - t0)
        ci = session.cache_info()
        warm = lat[1:]
        out[stat] = {
            "queries": n_queries,
            "first_s": round(lat[0], 4),
            "warm_mean_s": round(sum(warm) / len(warm), 4),
            "warm_max_s": round(max(warm), 4),
            "extra_compiles": ci.misses - misses_before,
        }
        misses_before = ci.misses
    assert out["fisher"]["extra_compiles"] == 3, "phase programs compile once"
    assert out["chi2"]["extra_compiles"] == 1, "chi2 reuses warm lamp1/count"
    return {"problem": name, "statistics": out}


def bench_paper_scale(problems=None) -> dict:
    """Full Table-1-scale tiled mining entries.

    Each problem is generated straight into packed words
    (`paper_problem_packed` — no dense [n, m] intermediate; alz_rec_30's
    dense float draw alone would be ~728 MB), wrapped as a `Dataset` whose
    bucket carries the item tiling, and run through the session expand path
    with the named kernel.  The resolved impl, block triple, and tile
    geometry come back in the PhaseReport and are recorded per entry —
    the committed JSON is the artifact that the Pallas kernel body ran
    inside a real mine's superstep loop at >= 11,914 items, and that a
    250,120-item mine completes under the tiled layout (supports are
    produced per 4096-item tile, never as one [B, 250k] residency choice
    the kernel can't honor).

    `downscale_bitexact` then reruns alz_rec_30 at 2% items through the
    full three-phase significant-pattern query, tiled vs untiled-ref, and
    asserts the ResultSets match bit-for-bit — exact integer math, so the
    250k capacity run above inherits correctness from this check plus the
    tiling-parity unit suite, without an (infeasible) 250k oracle pass.
    """
    from repro.api import Dataset, MinerSession, RuntimeConfig
    from repro.data.synthetic import paper_problem_packed

    if problems is None:
        problems = PAPER_SCALE_PROBLEMS
    entries = []
    for name, opts in problems.items():
        db_bits, labels, planted, spec = paper_problem_packed(name)
        ds = Dataset.from_packed_words(
            db_bits, labels, n_transactions=spec.n_transactions,
            name=spec.name, planted=planted,
        )
        ms = opts["min_sup"]
        session = _session(
            jax.devices()[:1],
            RuntimeConfig(expand_batch=16, kernel_impl=opts["kernel"],
                          max_steps=PAPER_SCALE_MAX_STEPS),
        )
        t0 = time.time()
        ph = session.run_phase(ds, "count", min_sup=ms)
        cold = time.time() - t0
        t0 = time.time()
        ph = session.run_phase(ds, "count", min_sup=ms)
        warm = time.time() - t0
        assert ph.kernel_impl == opts["kernel"], "resolved impl must be recorded"
        completed = ph.output.supersteps < PAPER_SCALE_MAX_STEPS
        assert completed, f"{spec.name}: traversal hit max_steps"
        entries.append({
            "problem": spec.name,
            "items": spec.n_items,
            "transactions": spec.n_transactions,
            "bucket_items": ds.bucket.items,
            "item_tile": ph.item_tile,
            "n_item_tiles": ph.n_item_tiles,
            "kernel_impl": ph.kernel_impl,
            "kernel_blocks": ph.kernel_blocks,
            "min_sup": ms,
            "nodes": int(ph.output.stats["popped"].sum()),
            "supersteps": ph.output.supersteps,
            "closed_sets": int(ph.output.hist.sum()),
            "completed": completed,
            "cold_s": round(cold, 3),
            "warm_s": round(warm, 3),
        })
    return {"problems": entries, "downscale_bitexact": _downscale_bitexact()}


def _downscale_bitexact(scale_items: float = 0.02, min_sup: int = 320) -> dict:
    """alz_rec_30 at `scale_items`, same count-mode traversal as the
    capacity runs above: forced multi-tile layout + the interpreted Pallas
    kernel must reproduce the single-tile ref-kernel run bit-for-bit
    (support histogram, node count, superstep count).

    min_sup sits in the downscaled instance's probed valley for the same
    reason as PAPER_SCALE_PROBLEMS (a LAMP-staged query here descends the
    synthetic dense-clique lattice and never terminates — the full
    ResultSet-level tiled-vs-ref gate lives in tier-1
    tests/test_bitmap_layout.py at a clique-free size)."""
    import numpy as np

    from repro.api import Dataset, RuntimeConfig
    from repro.api.dataset import BucketPolicy
    from repro.data.synthetic import paper_problem

    db, labels, _, spec = paper_problem("alz_rec_30", scale_items, 1.0)
    # item_tile >= the item bucket forces the single-tile (untiled) layout
    ds_ref = Dataset.from_dense(
        db, labels, name="alz_down_untiled",
        bucket_policy=BucketPolicy(item_tile=8192),
    )
    ds_tiled = Dataset.from_dense(
        db, labels, name="alz_down_tiled",
        bucket_policy=BucketPolicy(item_tile=1024),
    )
    assert ds_ref.packed.db_tiles.shape[0] == 1
    n_tiles = int(ds_tiled.packed.db_tiles.shape[0])
    assert n_tiles > 1

    def run(ds, kernel):
        session = _session(
            jax.devices()[:1],
            RuntimeConfig(expand_batch=16, kernel_impl=kernel,
                          max_steps=PAPER_SCALE_MAX_STEPS),
        )
        return session.run_phase(ds, "count", min_sup=min_sup)

    ref = run(ds_ref, "ref")
    tiled = run(ds_tiled, "pallas_interpret")
    np.testing.assert_array_equal(tiled.output.hist, ref.output.hist)
    assert tiled.output.supersteps == ref.output.supersteps
    nodes = int(ref.output.stats["popped"].sum())
    assert int(tiled.output.stats["popped"].sum()) == nodes
    return {
        "problem": spec.name,
        "items": spec.n_items,
        "transactions": spec.n_transactions,
        "n_item_tiles": n_tiles,
        "kernel_impl": tiled.kernel_impl,
        "min_sup": min_sup,
        "nodes": nodes,
        "closed_sets": int(ref.output.hist.sum()),
        "bitexact_vs_untiled_ref": True,
    }


def compare_markdown(old: dict, new: dict) -> str:
    """Old-vs-new warm wall table (markdown; CI appends to the job summary)."""
    lines = [
        "### Mining perf: old vs new (warm wall_s)",
        "",
        "| problem | P | old s | new s | speedup |",
        "|---|---|---|---|---|",
    ]
    old_by = {p["problem"]: p for p in old.get("problems", [])}
    for prob in new.get("problems", []):
        ref = old_by.get(prob["problem"])
        for p, wall in sorted(prob["wall_s"].items(), key=lambda kv: int(kv[0])):
            old_wall = (ref or {}).get("wall_s", {}).get(p)
            ratio = f"{old_wall / wall:.2f}x" if old_wall and wall else "n/a"
            lines.append(
                f"| {prob['problem']} | {p} | "
                f"{old_wall if old_wall is not None else 'n/a'} | {wall} | {ratio} |"
            )
    rq_old = old.get("repeated_query", {}).get("warm_mean_s")
    rq_new = new.get("repeated_query", {}).get("warm_mean_s")
    if rq_new:
        ratio = f"{rq_old / rq_new:.2f}x" if rq_old else "n/a"
        lines.append(f"| repeated_query warm_mean | - | {rq_old} | {rq_new} | {ratio} |")
    for stat, row in new.get("per_statistic", {}).get("statistics", {}).items():
        s_old = (old.get("per_statistic", {}).get("statistics", {})
                 .get(stat, {}).get("warm_mean_s"))
        s_new = row.get("warm_mean_s")
        ratio = f"{s_old / s_new:.2f}x" if s_old and s_new else "n/a"
        lines.append(f"| stat={stat} warm_mean | - | {s_old} | {s_new} | {ratio} |")
    bd = next(iter(new.get("problems", [])), {}).get("superstep_breakdown")
    if bd:
        # schema-defensive: old baselines carry the differencing-era keys
        # (per_step_us.total/expand/steal), new ones the trace-based keys
        psu = bd.get("per_step_us", {})
        if "traced" in psu:
            head = (f"per-superstep (P={bd['P']}): {psu['traced']}µs traced / "
                    f"{psu['untraced']}µs untraced "
                    f"(trace overhead {bd.get('trace_overhead_pct', 'n/a')}%)")
        else:
            head = (f"per-superstep (P={bd['P']}): total "
                    f"{psu.get('total', 'n/a')}µs")
        line = (f"{head};"
                f" steal rounds fired {bd.get('steal_rounds_fired', 'n/a')}"
                f"/{bd.get('supersteps', 'n/a')},"
                f" bytes/round "
                f"{bd.get('bytes_per_round', {}).get('stack_shift_before', 'n/a')}"
                f" -> "
                f"{bd.get('bytes_per_round', {}).get('steal_payload_after', 'n/a')}")
        sf = bd.get("steal_fairness")
        if sf:
            line += (f"; donation fairness {sf['donation']},"
                     f" work fairness {sf['work']},"
                     f" depth imbalance {sf['depth_imbalance']}")
        lines += ["", line]
    return "\n".join(lines) + "\n"


def run(problems: dict, p_values=(1, 2, 4, 8), out_path: str = DEFAULT_OUT,
        paper_scale: bool = True) -> dict:
    t0 = time.time()
    rq_name = next(iter(problems))
    payload = {
        "suite": "mining-makespan-baseline",
        "host_devices": len(jax.devices()),
        "problems": [bench_problem(n, s, p_values) for n, s in problems.items()],
        "repeated_query": bench_repeated_queries(rq_name, problems[rq_name]),
        "per_statistic": bench_per_statistic(rq_name, problems[rq_name]),
        "total_wall_s": None,
    }
    if paper_scale:
        payload["paper_scale"] = bench_paper_scale()
    payload["total_wall_s"] = round(time.time() - t0, 3)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    return payload


def run_paper_scale(out_path: str = PAPER_SCALE_OUT) -> dict:
    """The paper_scale section alone (slow-system CI smoke): full-item-count
    tiled mines + the downscaled bit-exactness gate, no makespan suite."""
    t0 = time.time()
    payload = {
        "suite": "mining-paper-scale",
        "paper_scale": bench_paper_scale(),
        "total_wall_s": None,
    }
    payload["total_wall_s"] = round(time.time() - t0, 3)
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized problems (same schema, smaller scales); "
                         "skips the paper_scale section")
    ap.add_argument("--paper-scale", action="store_true",
                    help="run ONLY the paper_scale section (full Table-1 item "
                         "counts through the tiled kernel path) and write it "
                         "to experiments/bench/paper_scale.json")
    ap.add_argument("--out", default=None)
    ap.add_argument("--compare", nargs=2, metavar=("OLD", "NEW"),
                    help="print the old-vs-new warm-wall markdown table for "
                         "two existing result files and exit (no benchmark run)")
    args = ap.parse_args(argv)
    if args.compare:
        with open(args.compare[0]) as f_old, open(args.compare[1]) as f_new:
            print(compare_markdown(json.load(f_old), json.load(f_new)))
        return
    if args.paper_scale:
        out = args.out or PAPER_SCALE_OUT
        payload = run_paper_scale(out_path=out)
    else:
        out = args.out or DEFAULT_OUT
        payload = run(SMOKE_PROBLEMS if args.smoke else BENCH_PROBLEMS,
                      out_path=out, paper_scale=not args.smoke)
    print(json.dumps(payload, indent=1))
    print(f"[out] {out}")


if __name__ == "__main__":
    main()
