"""Shared helpers for the paper-reproduction benchmarks.

BSP makespan model (this container has one physical core, so multi-miner
wall-clock is meaningless; the engine's superstep trace — `MineOutput.trace`,
a decoded `repro.obs.SuperstepTrace` at trace_period=1 — gives the exact
parallel schedule instead; pass its `.popped` [P, S] series):

    T_P = sum_t [ max_p popped[p, t] * c_node ]  +  supersteps * c_round

c_node is measured from a single-device run (wall seconds per expanded node);
c_round models the per-superstep collective/steal latency (default 20 us — a
v5e all-reduce latency scale; the paper's §5.2 makes the same argument that
network latency only shifts the 'probe' share).  Speedup = T_1 / T_P.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

BENCH_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "bench")

PROBLEMS = {
    "hapmap_dom_10": dict(scale_items=0.08, scale_trans=1.0),
    "hapmap_dom_20": dict(scale_items=0.04, scale_trans=1.0),
    "alz_dom_5": dict(scale_items=0.015, scale_trans=1.0),
    "mcf7": dict(scale_items=1.0, scale_trans=0.04),
}

C_ROUND_S = 20e-6  # modeled per-superstep collective latency


def save_json(name: str, payload):
    os.makedirs(BENCH_DIR, exist_ok=True)
    path = os.path.join(BENCH_DIR, name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def makespan(popped: np.ndarray, supersteps: int, c_node: float,
             c_round: float = C_ROUND_S) -> float:
    """popped [P, S] per-superstep series (`SuperstepTrace.popped` at
    trace_period=1) -> modeled parallel seconds."""
    t = popped[:, :supersteps] if supersteps <= popped.shape[1] else popped
    return float(np.sum(t.max(axis=0)) * c_node + supersteps * c_round)


def measure_c_node(problem_db, labels, min_sup, cfg_cls, mine_fn, devices):
    """Single-device phase-2 run -> (seconds per node, nodes, wall)."""
    cfg = cfg_cls(expand_batch=16, trace_cap=0)
    mine_fn(problem_db, labels, mode="count", min_sup=min_sup, cfg=cfg,
            devices=devices[:1])  # warm up compile
    t0 = time.time()
    out = mine_fn(problem_db, labels, mode="count", min_sup=min_sup, cfg=cfg,
                  devices=devices[:1])
    wall = time.time() - t0
    nodes = int(out.stats["popped"].sum())
    return wall / max(nodes, 1), nodes, wall
