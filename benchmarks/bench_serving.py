"""Serving benchmark: the async mining service under load (DESIGN.md §10).

  PYTHONPATH=src python -m benchmarks.bench_serving           # full run
  PYTHONPATH=src python -m benchmarks.bench_serving --smoke   # CI-sized

Three sections, written to BENCH_serving.json at the repo root:

  serial_mine_serve_baseline
      The predecessor: one fresh session, queries served one at a time
      with the dataset built inside the loop and the first query paying
      its compiles inside the measured window — exactly what the old
      in-process `mine_serve` loop delivered end to end.  Its warm-only
      tail qps is reported alongside for transparency.

  closed_loop
      `MiningService` fleets of 1/2/4 warm sessions drained closed-loop
      (always-busy clients, pre-built payloads).  The acceptance figure:
      achieved qps at concurrency >= 2 must beat the serial baseline —
      the service wins by compiling *before* traffic (startup warmup) and
      amortizing it across the fleet, not by magicking extra cores into
      the container (single-core CI: concurrent sessions time-slice).

  open_loop
      Poisson arrivals swept across offered rates bracketing the measured
      closed-loop capacity, against a deliberately small admission queue:
      offered vs achieved qps, p50/p90/p99 latency, queue depth, and
      rejection counts — the overload row shows admission control doing
      its job (bounded latency, explicit rejections) instead of the queue
      growing without bound.

`--metrics-out` snapshots the last service's shared registry (serve_* +
miner_*) for `repro.obs.validate` in CI.
"""

import argparse
import asyncio
import json
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def build_work(problem, scale_items, scale_trans, n, alphas, statistic,
               pipeline):
    from repro.api import Dataset, SignificantPatternQuery

    work = []
    for q in range(n):
        ds = Dataset.from_paper_problem(problem, scale_items, scale_trans,
                                        seed=q)
        work.append((ds, SignificantPatternQuery(
            alpha=alphas[q % len(alphas)], statistic=statistic,
            pipeline=pipeline)))
    return work


def bench_serial_baseline(args, alphas):
    """The old mine_serve loop, verbatim semantics: fresh session, dataset
    built per query inside the loop, query 0 cold inside the clock."""
    from repro.api import (
        AlgorithmConfig, Dataset, MinerSession, RuntimeConfig,
    )

    session = MinerSession(
        algorithm=AlgorithmConfig(pipeline=args.pipeline, statistic=args.stat),
        runtime=RuntimeConfig(expand_batch=args.expand_batch),
    )
    lat = []
    t0 = time.perf_counter()
    for q in range(args.queries):
        ds = Dataset.from_paper_problem(
            args.problem, args.scale_items, args.scale_trans, seed=q)
        t1 = time.perf_counter()
        session.mine(ds, alpha=alphas[q % len(alphas)])
        lat.append(time.perf_counter() - t1)
    total = time.perf_counter() - t0
    warm = lat[1:]
    return {
        "n": len(lat),
        "total_wall_s": round(total, 3),
        "qps_end_to_end": round(len(lat) / total, 3),
        "cold_s": round(lat[0], 3),
        "warm_mean_s": round(sum(warm) / len(warm), 4) if warm else None,
        "qps_warm_only": round(len(warm) / sum(warm), 2) if warm else None,
    }


async def bench_closed(args, work, concurrency):
    from repro.api import AlgorithmConfig, RuntimeConfig
    from repro.serve import MiningService, WarmupSpec, run_closed_loop

    service = MiningService(
        size=concurrency,
        algorithm=AlgorithmConfig(pipeline=args.pipeline, statistic=args.stat),
        runtime=RuntimeConfig(expand_batch=args.expand_batch),
        warmups=[WarmupSpec(work[0][0].bucket, statistic=args.stat,
                            pipeline=args.pipeline)],
    )
    t0 = time.perf_counter()
    await service.start()
    warmup_s = time.perf_counter() - t0
    # settle: one untimed pass absorbs allocator/threadpool first-touch
    await run_closed_loop(service, work[:concurrency * 2],
                          concurrency=concurrency,
                          n_requests=concurrency * 2)
    report = await run_closed_loop(service, work, concurrency=concurrency,
                                   n_requests=len(work))
    await service.stop()
    out = report.as_dict()
    out["warmup_s"] = round(warmup_s, 3)
    out["warm_violations"] = report.cold_ok
    return out


async def bench_open(args, work, qps, service):
    from repro.serve import run_open_loop

    return await run_open_loop(
        service, work, qps=qps, n_requests=len(work), seed=17,
        timeout_s=args.timeout_s,
    )


async def bench_open_sweep(args, work, capacity_qps):
    """Sweep offered rates around the measured capacity against a small
    admission queue; the overload rows must show rejections."""
    from repro.api import AlgorithmConfig, RuntimeConfig
    from repro.serve import MiningService, ServeConfig, WarmupSpec

    service = MiningService(
        size=args.open_concurrency,
        algorithm=AlgorithmConfig(pipeline=args.pipeline, statistic=args.stat),
        runtime=RuntimeConfig(expand_batch=args.expand_batch),
        config=ServeConfig(queue_capacity=args.queue_capacity),
        warmups=[WarmupSpec(work[0][0].bucket, statistic=args.stat,
                            pipeline=args.pipeline)],
    )
    await service.start()
    rows = []
    for mult in args.rate_multipliers:
        rate = max(capacity_qps * mult, 0.5)
        report = await bench_open(args, work, rate, service)
        row = report.as_dict()
        row["rate_multiplier"] = mult
        rows.append(row)
        print(f"[open] offered {rate:6.1f} qps (x{mult}) -> achieved "
              f"{report.achieved_qps:6.1f} qps  p50 "
              f"{row.get('latency_p50_s')}s p99 {row.get('latency_p99_s')}s  "
              f"rejected {report.n_rejected}/{report.n_requests}")
    snapshot = service.metrics.expose_text()
    await service.stop()
    return rows, snapshot


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--problem", default="hapmap_dom_10")
    ap.add_argument("--scale-items", type=float, default=0.02)
    ap.add_argument("--scale-trans", type=float, default=1.0)
    ap.add_argument("--queries", type=int, default=32,
                    help="requests per measured run")
    ap.add_argument("--alphas", default="0.05,0.01")
    ap.add_argument("--pipeline", default="three_phase")
    ap.add_argument("--stat", default="fisher")
    ap.add_argument("--expand-batch", type=int, default=16)
    ap.add_argument("--concurrencies", default="1,2,4",
                    help="closed-loop fleet sizes")
    ap.add_argument("--open-concurrency", type=int, default=2,
                    help="fleet size behind the open-loop sweep")
    ap.add_argument("--rate-multipliers", default="0.5,1.0,2.0,4.0",
                    help="offered rate as multiples of measured capacity")
    ap.add_argument("--queue-capacity", type=int, default=8,
                    help="admission bound for the open-loop sweep (small on "
                         "purpose: the overload rows must reject)")
    ap.add_argument("--timeout-s", type=float, default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: tiny scale, few queries, short sweep")
    ap.add_argument("--json-out", default=str(ROOT / "BENCH_serving.json"))
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args(argv)
    if args.smoke:
        args.scale_items = min(args.scale_items, 0.01)
        args.queries = min(args.queries, 8)
        args.concurrencies = "1,2"
        args.rate_multipliers = "1.0,4.0"
    alphas = [float(a) for a in args.alphas.split(",") if a]
    concurrencies = [int(c) for c in args.concurrencies.split(",") if c]
    args.rate_multipliers = [float(m)
                             for m in args.rate_multipliers.split(",") if m]

    print(f"[baseline] serial mine_serve-style loop: {args.queries} queries")
    baseline = bench_serial_baseline(args, alphas)
    print(f"[baseline] {baseline['qps_end_to_end']} qps end-to-end "
          f"(cold {baseline['cold_s']}s inside the window; warm-only "
          f"{baseline['qps_warm_only']} qps)")

    print(f"[work] pre-building {args.queries} payloads")
    work = build_work(args.problem, args.scale_items, args.scale_trans,
                      args.queries, alphas, args.stat, args.pipeline)

    closed_rows = []
    for conc in concurrencies:
        row = asyncio.run(bench_closed(args, work, conc))
        closed_rows.append(row)
        print(f"[closed] concurrency {conc}: {row['achieved_qps']} qps, "
              f"p50 {row.get('latency_p50_s')}s p99 "
              f"{row.get('latency_p99_s')}s, warm_violations "
              f"{row['warm_violations']}")

    capacity = max(
        (r["achieved_qps"] for r in closed_rows
         if r["concurrency"] == args.open_concurrency),
        default=closed_rows[-1]["achieved_qps"],
    )
    open_rows, snapshot = asyncio.run(
        bench_open_sweep(args, work, capacity))

    served = {r["concurrency"]: r["achieved_qps"] for r in closed_rows}
    best_multi = max((q for c, q in served.items() if c >= 2), default=0.0)
    acceptance = {
        "serial_mine_serve_baseline_qps": baseline["qps_end_to_end"],
        "served_qps_at_concurrency_ge2": best_multi,
        "speedup_vs_baseline": (
            round(best_multi / baseline["qps_end_to_end"], 2)
            if baseline["qps_end_to_end"] else None),
        "met": best_multi > baseline["qps_end_to_end"],
        "note": ("the service wins by pre-compiling at startup (warmup "
                 "outside the serving window) and amortizing programs "
                 "across a warm fleet; the baseline pays its compiles "
                 "in-band, as the old serial mine_serve loop did. "
                 "single-core container: concurrent sessions time-slice, "
                 "so warm-vs-warm qps is roughly flat across fleet sizes "
                 "(see closed_loop rows)."),
    }
    payload = {
        "config": {
            "problem": args.problem,
            "scale_items": args.scale_items,
            "scale_trans": args.scale_trans,
            "queries": args.queries,
            "alphas": alphas,
            "pipeline": args.pipeline,
            "statistic": args.stat,
            "queue_capacity_open_loop": args.queue_capacity,
            "smoke": args.smoke,
        },
        "serial_mine_serve_baseline": baseline,
        "closed_loop": closed_rows,
        "open_loop": open_rows,
        "acceptance": acceptance,
    }
    with open(args.json_out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"[out] {args.json_out}")
    print(f"[acceptance] conc>=2 served {best_multi} qps vs baseline "
          f"{baseline['qps_end_to_end']} qps -> met={acceptance['met']}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(snapshot)
        print(f"[out] wrote metrics snapshot to {args.metrics_out}")
    return 0 if acceptance["met"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
