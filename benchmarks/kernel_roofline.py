"""Kernel-level roofline for the support-count Pallas kernel (paper §4.6).

CPU wall-clock says nothing about TPU kernels, so this benchmark reports the
*structural* roofline per tile configuration:

  support-count popcount-GEMM (VPU workload — no MXU path for AND/popcount):
      ops   = B*M*W words -> 1 AND + 1 popcount + 1 add  per word-lane
      bytes = (B*W + W*M)*4 read + B*M*4 written   per tile sweep
      v5e VPU: 8 lanes x 128 sublanes x 4 ops/cycle @ 940 MHz ~ 4.8e12 int-op/s

plus interpret-mode numerical verification against the numpy oracle at every
reported configuration (correctness and the perf claim travel together).

Block sizes come from the support-count autotuner (DESIGN.md §8) — the same
`choose_blocks` that RuntimeConfig.resolve pins into every compiled mine —
so the roofline reports the configurations that actually run.  `run()` also
measures a small autotune sweep (timed through the public op on the active
backend) and saves it as `autotune_seed.json`, the seed-table artifact CI
uploads; point `REPRO_SC_AUTOTUNE` at it to carry measured tunings into
later processes.
"""

from __future__ import annotations

import numpy as np

from repro.core.bitmap import supports_np
from repro.kernels.support_count import autotune
from repro.kernels.support_count.ops import support_counts

from .common import save_json

VPU_INT_OPS = autotune.VPU_INT_OPS  # v5e 8x128 lanes, ~940 MHz, 4 ALUs
HBM_BW = autotune.HBM_BW
VMEM_BYTES = 16 * 2**20

#: Table-1-like support-count sweep shapes (B = expand batch per superstep)
PAPER_SHAPES = [
    (64, 11914, 22),    # hapmap_dom_20-like
    (64, 91126, 12),    # alz_dom_10-like
    (256, 250120, 12),  # alz_rec_30-like
    (64, 397, 400),     # mcf7-like (many transactions)
]


def support_count_report():
    rows = []
    for b, m, w in PAPER_SHAPES:
        bb, bm, bw = autotune.choose_blocks(b, m, w, "pallas")
        bp, mp, wp = autotune.bucket_dims(b, m, w)
        words = bp * mp * wp
        int_ops = 3 * words  # AND + popcount + accumulate
        bytes_hbm = (bp * wp + wp * mp) * 4 + bp * mp * 4
        t_compute = int_ops / VPU_INT_OPS
        t_memory = bytes_hbm / HBM_BW
        vmem = autotune.vmem_bytes(bb, bm, bw)
        # interpret-mode correctness at a scaled shape, same blocks family
        rng = np.random.default_rng(0)
        occ = rng.integers(0, 2**32, size=(min(b, 16), w), dtype=np.uint32)
        db = rng.integers(0, 2**32, size=(min(m, 1024), w), dtype=np.uint32)
        got = np.asarray(
            support_counts(occ, db, impl="pallas_interpret",
                           blocks=(8, min(bm, 512), min(bw, 32)))
        )
        ok = np.array_equal(got, supports_np(occ, db))
        rows.append({
            "shape": f"B{b} M{m} W{w}", "block": f"{bb}x{bm}x{bw}",
            "autotuned": True,
            "int_ops": int_ops, "bytes": bytes_hbm,
            "t_compute_us": t_compute * 1e6, "t_memory_us": t_memory * 1e6,
            "modeled_us": autotune.modeled_time_us(b, m, w, (bb, bm, bw)),
            "bound": "compute" if t_compute > t_memory else "memory",
            "arith_intensity_ops_per_byte": int_ops / bytes_hbm,
            "vmem_per_step_kib": vmem / 1024,
            "fits_vmem": vmem < VMEM_BYTES,
            "verified_vs_oracle": bool(ok),
        })
    return rows


def autotune_sweep(shapes=None, max_candidates: int = 4, iters: int = 2):
    """Measure candidate blocks through the public op; returns seed rows.

    On CPU this times the interpreted kernel — meaningless for TPU placement
    but a consistent ordering for CPU CI (where pallas_interpret carries
    mines); on TPU it measures the real kernel.  Shapes default to a small
    bucket family so the sweep stays cheap enough for the slow-system job.
    """
    if shapes is None:
        shapes = [(16, 512, 8), (16, 2048, 8), (16, 4096, 22)]
    rows = []
    for b, m, w in shapes:
        rows.extend(autotune.measure_blocks(
            b, m, w, impl="pallas_interpret",
            iters=iters, max_candidates=max_candidates,
        ))
    return rows


def run():
    import os

    from .common import BENCH_DIR

    sweep = autotune_sweep()
    out = {
        "support_count": support_count_report(),
        "autotune_sweep": sweep,
    }
    save_json("kernel_roofline.json", out)  # also creates BENCH_DIR
    # the seed-table artifact: feed back via REPRO_SC_AUTOTUNE or
    # autotune.load_seed_table to make measured blocks win over the model
    autotune.save_seed_table(os.path.join(BENCH_DIR, "autotune_seed.json"), sweep)
    return out
