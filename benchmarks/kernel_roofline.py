"""Kernel-level roofline for the two Pallas kernels (paper §4.6 hot spot).

CPU wall-clock says nothing about TPU kernels, so this benchmark reports the
*structural* roofline per tile configuration:

  support-count popcount-GEMM (VPU workload — no MXU path for AND/popcount):
      ops   = B*M*W words -> 1 AND + 1 popcount + 1 add  per word-lane
      bytes = (B*W + W*M)*4 read + B*M*4 written   per tile sweep
      v5e VPU: 8 lanes x 128 sublanes x 4 ops/cycle @ 940 MHz ~ 4.8e12 int-op/s

  flash attention (MXU workload):
      flops = 4*B*H*Sq*Skv*D (QK^T + PV)
      bytes = streaming KV once per q-block row + resident q/acc

plus interpret-mode numerical verification against the jnp oracle at every
reported configuration (correctness and the perf claim travel together).
"""

from __future__ import annotations

import numpy as np

from repro.kernels.support_count.ops import support_counts
from repro.kernels.support_count.ref import support_count_ref

from .common import save_json

VPU_INT_OPS = 4.8e12  # v5e vector int ops/s (8x128 lanes, ~940 MHz, 4 ALUs)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
VMEM_BYTES = 16 * 2**20


def support_count_report():
    rows = []
    for b, m, w, bb, bm, bw in [
        (64, 11914, 22, 8, 512, 8),      # hapmap_dom_20-like
        (64, 91126, 12, 8, 512, 8),      # alz_dom_10-like
        (256, 250120, 12, 16, 1024, 8),  # alz_rec_30-like
        (64, 397, 400, 8, 128, 64),      # mcf7-like (many transactions)
    ]:
        w_pad = -(-w // bw) * bw
        m_pad = -(-m // bm) * bm
        words = b * m_pad * w_pad
        int_ops = 3 * words  # AND + popcount + accumulate
        bytes_hbm = (b * w_pad + w_pad * m_pad) * 4 + b * m_pad * 4
        t_compute = int_ops / VPU_INT_OPS
        t_memory = bytes_hbm / HBM_BW
        vmem = (bb * bw + bw * bm + bb * bm + bb * bw * bm) * 4
        # interpret-mode correctness at a scaled shape
        rng = np.random.default_rng(0)
        occ = rng.integers(0, 2**32, size=(min(b, 16), w), dtype=np.uint32)
        db_t = rng.integers(0, 2**32, size=(w, min(m, 1024)), dtype=np.uint32)
        got = np.asarray(support_counts(occ, db_t, block_b=8, block_m=min(bm, 512),
                                        block_w=min(bw, 32), interpret=True))
        ok = np.array_equal(got, np.asarray(support_count_ref(occ, db_t)))
        rows.append({
            "shape": f"B{b} M{m} W{w}", "block": f"{bb}x{bm}x{bw}",
            "int_ops": int_ops, "bytes": bytes_hbm,
            "t_compute_us": t_compute * 1e6, "t_memory_us": t_memory * 1e6,
            "bound": "compute" if t_compute > t_memory else "memory",
            "arith_intensity_ops_per_byte": int_ops / bytes_hbm,
            "vmem_per_step_kib": vmem / 1024,
            "fits_vmem": vmem < VMEM_BYTES,
            "verified_vs_oracle": bool(ok),
        })
    return rows


def flash_attention_report():
    rows = []
    for b, h, sq, skv, d, bq, bk in [
        (32, 40, 32768, 32768, 128, 128, 128),   # prefill_32k qwen3-like
        (2, 96, 32768, 32768, 128, 128, 256),    # prefill cmd-r+-like (per dev)
        (8, 16, 4096, 4096, 256, 128, 128),      # train_4k rg-like
    ]:
        flops = 4.0 * b * h * sq * skv * d / 2  # causal halves the work
        bytes_hbm = (b * h * (sq * d * 2 * 2)            # q read + out write
                     + b * h * (sq // bq) * skv * d * 2 * 2 / 2) / 1  # kv stream
        t_c = flops / PEAK_FLOPS
        t_m = bytes_hbm / HBM_BW
        vmem = (bq * d + 2 * bk * d) * 2 + bq * (d + 2) * 4
        rows.append({
            "shape": f"B{b} H{h} Sq{sq} Skv{skv} D{d}", "block": f"{bq}x{bk}",
            "tflops": flops / 1e12, "t_compute_s": t_c, "t_memory_s": t_m,
            "bound": "compute" if t_c > t_m else "memory",
            "vmem_per_step_kib": vmem / 1024,
            "note": "KV re-streamed once per q-row block; raising bq trades "
                    "VMEM for HBM traffic",
        })
    return rows


def run():
    out = {
        "support_count": support_count_report(),
        "flash_attention": flash_attention_report(),
    }
    save_json("kernel_roofline.json", out)
    return out
