"""Paper-table benchmarks (Tables 1-2, Figs 6-7, §5.6), one function per
artifact.  Invoked by benchmarks.run with a multi-device CPU pool.

All datasets are synthetics matched to the published Table-1 statistics
(items/transactions/density/N_pos scaled to CPU-benchmark size; see
repro.data.synthetic and EXPERIMENTS.md for the full caveat).
"""

from __future__ import annotations

import time

import numpy as np

import jax

from repro.core.engine import EngineConfig, MineOutput, lamp_distributed, mine
from repro.core.lamp import lamp
from repro.data.synthetic import paper_problem

from .common import C_ROUND_S, PROBLEMS, makespan, save_json

TRACE_CAP = 16384


def _load(name):
    kw = PROBLEMS[name]
    return paper_problem(name, kw["scale_items"], kw["scale_trans"])


def table1_problems():
    """Table 1 analogue: problem statistics + sequential + engine results."""
    rows = []
    for name in PROBLEMS:
        db, labels, planted, spec = _load(name)
        t0 = time.time()
        ref = lamp(db, labels, alpha=0.05)
        t1_host = time.time() - t0
        t0 = time.time()
        res = lamp_distributed(db, labels, alpha=0.05,
                               cfg=EngineConfig(expand_batch=16))
        wall_engine = time.time() - t0
        assert res["min_sup"] == ref.min_sup, (name, res["min_sup"], ref.min_sup)
        assert res["correction_factor"] == ref.correction_factor
        rows.append({
            "name": name, "items": spec.n_items, "trans": spec.n_transactions,
            "density": spec.density, "n_pos": spec.n_pos,
            "lambda": res["lambda_final"], "min_sup": res["min_sup"],
            "closed_sets": res["correction_factor"],
            "significant": res["n_significant"],
            "t1_host_s": round(t1_host, 3),
            "t_engine_wall_s": round(wall_engine, 3),
            "matches_sequential_oracle": True,
        })
    save_json("table1.json", rows)
    return rows


def fig6_speedup(p_values=(1, 2, 4, 8, 16)):
    """Fig 6 analogue: modeled speedup vs miner count from BSP traces."""
    devices = jax.devices()
    out = {}
    for name in PROBLEMS:
        db, labels, _, spec = _load(name)
        ref = lamp(db, labels, alpha=0.05)
        ms = ref.min_sup
        # c_node from the single-device engine run
        cfg1 = EngineConfig(expand_batch=16, trace_period=1, trace_cap=TRACE_CAP)
        r1 = mine(db, labels, mode="count", min_sup=ms, cfg=cfg1,
                  devices=devices[:1])
        t0 = time.time()
        mine(db, labels, mode="count", min_sup=ms,
             cfg=EngineConfig(expand_batch=16), devices=devices[:1])
        wall1 = time.time() - t0
        nodes1 = int(r1.stats["popped"].sum())
        c_node = wall1 / max(nodes1, 1)
        t_1 = makespan(r1.trace.popped, r1.supersteps, c_node)
        rows = []
        for p in p_values:
            if p > len(devices):
                continue
            res = mine(db, labels, mode="count", min_sup=ms,
                       cfg=EngineConfig(expand_batch=16, trace_period=1, trace_cap=TRACE_CAP),
                       devices=devices[:p])
            t_p = makespan(res.trace.popped, res.supersteps, c_node)
            work = res.stats["popped"].astype(float)
            rows.append({
                "P": p,
                "modeled_T_s": t_p,
                "speedup": t_1 / t_p,
                "efficiency": t_1 / t_p / p,
                "supersteps": res.supersteps,
                "work_imbalance": float(work.max() / max(work.mean(), 1e-9)),
                "steals": int(res.stats["steals_got"].sum()),
                "stolen_nodes": int(res.stats["stolen_nodes"].sum()),
            })
        out[name] = {"c_node_s": c_node, "nodes": nodes1, "curve": rows}
    save_json("fig6_speedup.json", out)
    return out


def table2_naive(p: int = 8):
    """Table 2 analogue: GLB vs the naive static split (steal disabled)."""
    devices = jax.devices()
    assert len(devices) >= p
    rows = []
    for name in PROBLEMS:
        db, labels, _, spec = _load(name)
        ref = lamp(db, labels, alpha=0.05)
        ms = ref.min_sup
        cfg1 = EngineConfig(expand_batch=16, trace_period=1, trace_cap=TRACE_CAP)
        r1 = mine(db, labels, mode="count", min_sup=ms, cfg=cfg1,
                  devices=devices[:1])
        t0 = time.time()
        mine(db, labels, mode="count", min_sup=ms,
             cfg=EngineConfig(expand_batch=16), devices=devices[:1])
        wall1 = time.time() - t0
        c_node = wall1 / max(int(r1.stats["popped"].sum()), 1)
        t_1 = makespan(r1.trace.popped, r1.supersteps, c_node)
        row = {"name": name, "t1_s": t_1}
        for steal, label in [(True, "glb"), (False, "naive")]:
            res = mine(db, labels, mode="count", min_sup=ms,
                       cfg=EngineConfig(expand_batch=16, trace_period=1, trace_cap=TRACE_CAP,
                                        steal_enabled=steal),
                       devices=devices[:p])
            t_p = makespan(res.trace.popped, res.supersteps, c_node)
            work = res.stats["popped"].astype(float)
            row[f"{label}_T_s"] = t_p
            row[f"{label}_speedup"] = t_1 / t_p
            row[f"{label}_imbalance"] = float(work.max() / max(work.mean(), 1e-9))
            # correctness under both schedules
            assert int(res.hist[ms:].sum()) == ref.correction_factor, name
        rows.append(row)
    save_json("table2.json", rows)
    return rows


def fig7_breakdown(p_values=(1, 4, 16)):
    """Fig 7 analogue: per-process work/steal/idle breakdown."""
    devices = jax.devices()
    out = {}
    for name in list(PROBLEMS)[:2]:  # two representative problems
        db, labels, _, spec = _load(name)
        ref = lamp(db, labels, alpha=0.05)
        rows = []
        for p in p_values:
            if p > len(devices):
                continue
            res = mine(db, labels, mode="count", min_sup=ref.min_sup,
                       cfg=EngineConfig(expand_batch=16, trace_period=1, trace_cap=TRACE_CAP),
                       devices=devices[:p])
            rows.append({
                "P": p,
                "popped_per_dev": res.stats["popped"].tolist(),
                "idle_steps_per_dev": res.stats["idle_steps"].tolist(),
                "supersteps": res.supersteps,
                "steals_got_per_dev": res.stats["steals_got"].tolist(),
                "gives_per_dev": res.stats["gives"].tolist(),
                "rejected_per_dev": res.stats["rejected"].tolist(),
            })
        out[name] = rows
    save_json("fig7_breakdown.json", out)
    return out


def significant_patterns():
    """§5.6 analogue: planted significant patterns are recovered."""
    rows = []
    for name in PROBLEMS:
        db, labels, planted, spec = _load(name)
        t0 = time.time()
        res = lamp_distributed(db, labels, alpha=0.05,
                               cfg=EngineConfig(expand_batch=16))
        wall = time.time() - t0
        ref = lamp(db, labels, alpha=0.05)
        sig_sets = [set(s.items) for s in ref.significant]
        recovered = sum(
            any(set(pl) <= s for s in sig_sets) for pl in planted
        )
        rows.append({
            "name": name, "planted": len(planted), "recovered": recovered,
            "n_significant": res["n_significant"], "delta": res["delta"],
            "wall_s": round(wall, 3),
            "engine_matches_host": res["n_significant"] == len(ref.significant),
        })
    save_json("significant_patterns.json", rows)
    return rows
