"""Fig.-5-style scaling curve: flat vs hierarchical vs naive-static at large P.

  PYTHONPATH=src python -m benchmarks.bench_scaling           # full curve
  PYTHONPATH=src python -m benchmarks.bench_scaling --smoke   # CI-sized

The device engine tops out at the simulated-device count and this container
has one core, so the paper's regime — P in the hundreds to thousands
(Fig. 5's 1175x point is 1216 cores) — is reached with the host-side BSP
simulator (repro.topo.simulate): it replays the engine's exact superstep
semantics (LIFO batch expand, hunger census, the gated lifeline steal round
with the bottom-half/steal_max donation rule) over the *real* deferred-PPC
enumeration tree of a dataset, and prices each superstep with
topology-aware latencies (intra-host vs cross-host rounds, per-host
fan-out of the round's permutation — see simulate.round_costs).

Three schedules per P, all on the same blocked topology (8 devices/host):

  * flat        — core/lifeline.build_schedule over all P ranks, priced
                  honestly (low hypercube dims stay intra-host; random
                  derangements scatter across hosts);
  * hierarchical — repro.topo.build_hierarchical_schedule (the schedule
                  the 2-D topo mesh actually runs);
  * naive-static — stealing disabled: the dealt depth-1 subtrees are the
                  final assignment, makespan is the largest subtree chain.

Writes BENCH_scaling.json at the repo root.  The committed file is this
PR's acceptance artifact: hierarchical >= flat at every P (they tie at
P = 8, a single host, where the schedules coincide) and naive-static
degrading as P grows.
"""

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ROOT = os.path.join(os.path.dirname(__file__), "..")

# the committed curve's workload: ~215k real tree nodes (extract_tree on
# this dataset), big enough that P = 1024 miners still see ~200 nodes each
DATASET = dict(n_items=80, n_transactions=400, density=0.22, n_pos=100,
               n_planted=3, seed=1)
MIN_SUP = 6
P_VALUES = (8, 64, 256, 1024)
DEVICES_PER_HOST = 8

SMOKE_DATASET = dict(n_items=64, n_transactions=300, density=0.25, n_pos=75,
                     n_planted=3, seed=1)
SMOKE_MIN_SUP = 5
SMOKE_P_VALUES = (8, 64)


def run(dataset: dict, min_sup: int, p_values, out_name: str | None):
    from repro.core.lifeline import build_schedule
    from repro.data.synthetic import SyntheticSpec, generate
    from repro.topo import Topology, build_hierarchical_schedule
    from repro.topo.simulate import (
        C_CROSS_ROUND_S,
        C_LOCAL_ROUND_S,
        C_NODE_S,
        extract_tree,
        simulate_mine,
    )

    db, _labels, _ = generate(SyntheticSpec(name="scaling", **dataset))
    t0 = time.time()
    tree = extract_tree(db, min_sup=min_sup)
    print(f"[tree] {tree.n_nodes} nodes, {len(tree.roots)} depth-1 roots "
          f"({time.time() - t0:.1f}s)")

    base = simulate_mine(tree, build_schedule(1), Topology(1, 1),
                         steal_enabled=False)
    print(f"[T1] {base.makespan_s * 1e3:.1f} ms modeled, "
          f"{base.supersteps} supersteps")

    curve = []
    for p in p_values:
        topo = Topology(max(p // DEVICES_PER_HOST, 1), min(p, DEVICES_PER_HOST))
        flat = simulate_mine(tree, build_schedule(p), topo)
        hier = simulate_mine(tree, build_hierarchical_schedule(topo), topo)
        static = simulate_mine(tree, build_schedule(p), topo,
                               steal_enabled=False)
        point = {
            "P": p,
            "topology": str(topo),
            "speedup": {
                "hierarchical": round(base.makespan_s / hier.makespan_s, 2),
                "flat": round(base.makespan_s / flat.makespan_s, 2),
                "naive_static": round(base.makespan_s / static.makespan_s, 2),
            },
            "supersteps": {
                "hierarchical": hier.supersteps,
                "flat": flat.supersteps,
                "naive_static": static.supersteps,
            },
            "cross_round_ms": {
                "hierarchical": round(hier.cross_round_s * 1e3, 3),
                "flat": round(flat.cross_round_s * 1e3, 3),
            },
            "steals": {"hierarchical": hier.steals, "flat": flat.steals},
        }
        curve.append(point)
        s = point["speedup"]
        print(f"[P={p:5d}] hier {s['hierarchical']:7.2f}x   "
              f"flat {s['flat']:7.2f}x   static {s['naive_static']:5.2f}x")

    # acceptance gates, enforced at generation time so the committed JSON
    # can never claim what the model didn't produce
    for point in curve:
        s = point["speedup"]
        assert s["hierarchical"] >= s["flat"], (
            f"hierarchical < flat at P={point['P']}: {s}")
    if len(curve) > 1:
        assert curve[-1]["speedup"]["naive_static"] <= \
            curve[0]["speedup"]["naive_static"], (
            "naive-static failed to degrade with P")

    payload = {
        "suite": "topology-scaling",
        "dataset": dataset,
        "min_sup": min_sup,
        "tree_nodes": tree.n_nodes,
        "devices_per_host": DEVICES_PER_HOST,
        "cost_model": {
            "c_node_s": C_NODE_S,
            "c_local_round_s": C_LOCAL_ROUND_S,
            "c_cross_round_s": C_CROSS_ROUND_S,
        },
        "t1_modeled_s": round(base.makespan_s, 6),
        "curve": curve,
    }
    if out_name:
        path = os.path.abspath(os.path.join(ROOT, out_name))
        with open(path, "w") as f:
            json.dump(payload, f, indent=1)
            f.write("\n")
        print(f"[write] {path}")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: small tree, P in (8, 64), no JSON")
    args = ap.parse_args(argv)
    if args.smoke:
        run(SMOKE_DATASET, SMOKE_MIN_SUP, SMOKE_P_VALUES, None)
    else:
        run(DATASET, MIN_SUP, P_VALUES, "BENCH_scaling.json")


if __name__ == "__main__":
    main()
