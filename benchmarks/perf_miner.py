"""§Perf hillclimb — cell 3: the mining engine itself (paper-representative).

Runs on real devices (the engine executes here, unlike the LM dry-run cells),
so each iteration reports BOTH wall-clock (total work; CPU serializes the
miners) and the modeled BSP makespan T_P (parallel schedule from traces).

Iterations (hypothesis -> change -> measure -> verdict) are appended to
experiments/bench/perf_miner.json and summarized in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import time

import numpy as np

import jax

from repro.core.engine import EngineConfig, lamp_distributed, mine
from repro.core.lamp import lamp
from repro.data.synthetic import paper_problem

from .common import makespan, save_json

TRACE = 16384
P = 16


def run_pipeline(db, labels, cfg, pipeline):
    t0 = time.time()
    res = lamp_distributed(db, labels, alpha=0.05, cfg=cfg,
                           devices=jax.devices()[:P], pipeline=pipeline)
    wall = time.time() - t0
    phases = res["phase_outputs"]
    steps = sum(p.supersteps for p in phases)
    popped = sum(int(p.stats["popped"].sum()) for p in phases)
    return res, wall, steps, popped, phases


def modeled_T(phases, c_node):
    return sum(makespan(p.trace.popped, p.supersteps, c_node) for p in phases)


def run():
    db, labels, _, spec = paper_problem("alz_dom_5", 0.015, 1.0)
    ref = lamp(db, labels, alpha=0.05)
    iterations = []

    def record(name, hypothesis, cfg, pipeline, baseline=None):
        # warm-up compile, then measure
        run_pipeline(db, labels, cfg, pipeline)
        res, wall, steps, popped, phases = run_pipeline(db, labels, cfg, pipeline)
        assert res["min_sup"] == ref.min_sup
        assert res["correction_factor"] == ref.correction_factor
        assert res["n_significant"] == len(ref.significant)
        c_node = wall / max(popped, 1)  # per-node cost incl. batching effects
        row = {
            "name": name, "hypothesis": hypothesis,
            "expand_batch": cfg.expand_batch, "steal_max": cfg.steal_max,
            "pipeline": pipeline, "wall_s": round(wall, 2), "supersteps": steps,
            "popped_total": popped,
            "modeled_T16_s": round(modeled_T(phases, c_node), 4),
            "round_payload_bytes": cfg.steal_max * (db.shape[0] // 32 + 1 + 4) * 4,
        }
        if baseline:
            for k in ("wall_s", "supersteps", "popped_total", "modeled_T16_s"):
                row[f"{k}_vs_base"] = round(row[k] / max(baseline[k], 1e-9), 3)
        iterations.append(row)
        print(f"[{name}] wall={wall:.2f}s steps={steps} popped={popped} "
              f"T16={row['modeled_T16_s']}s")
        return row

    base_cfg = EngineConfig(expand_batch=16, steal_max=128, trace_period=1, trace_cap=TRACE)
    base = record(
        "baseline", "paper-faithful 3-phase pipeline, B=16, T=128", base_cfg,
        "three_phase",
    )
    record(
        "it1-fuse23",
        "phase 3 re-traverses the tree only to re-test (sup,pos_sup) pairs; a "
        "2-D histogram in phase 2 carries the same information -> expect "
        "~1/3 fewer supersteps and ~1/3 less popcount-GEMM work",
        base_cfg, "fused23", base,
    )
    for b in (32, 64):
        record(
            f"it2-B{b}",
            f"B={b}: halve/quarter superstep count (collective latency "
            "amortization); risk: coarser steal granularity worsens tail "
            "balance — expect better modeled T16 until imbalance bites",
            EngineConfig(expand_batch=b, steal_max=128, trace_period=1, trace_cap=TRACE),
            "fused23", base,
        )
    record(
        "it3-T32",
        "steals move ~10-30 nodes (measured) so a 128-slot GIVE buffer is 4x "
        "oversized: T=32 cuts the per-round ppermute payload 4x with no "
        "makespan change",
        EngineConfig(expand_batch=32, steal_max=32, trace_period=1, trace_cap=TRACE),
        "fused23", base,
    )
    record(
        "it4-best",
        "combine the winners: fused 2-pass + B=16 (best modeled makespan) + "
        "T=32 (cheap rounds) — expect ~baseline/1.5 makespan",
        EngineConfig(expand_batch=16, steal_max=32, trace_period=1, trace_cap=TRACE),
        "fused23", base,
    )
    save_json("perf_miner.json", iterations)
    return iterations


if __name__ == "__main__":
    import os

    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=16")
    run()
